//! Engine scaling bench: events/s and peak live-event count under
//! 1x / 10x / 100x Fig-14 load, streamed.
//!
//! The streaming core's contract is that memory and heap depth depend
//! on *in-flight work*, not trace length: arrivals are pulled lazily
//! from per-model inhomogeneous Poisson streams (one pending event per
//! stream), duty timers live in one slot per assignment, and the heap
//! holds only in-flight `Done`s. Each ladder rung scales the Fig-14
//! fluctuation rates by k while shrinking the horizon to 1800/k s, so
//! every rung offers a comparable number of requests and the measured
//! events/s isolates per-event cost under growing instantaneous load —
//! the 10x and 100x rungs complete *without ever materializing an
//! arrival vector* (at 100x that vector alone would be tens of millions
//! of entries).
//!
//! A second pair runs the same 120 s 1x trace through the legacy
//! bulk-inject path (whole future in the heap) and the streamed path,
//! asserts their reports byte-identical, and reports both peak
//! live-event counts: O(trace) vs O(streams + assignments + gpu-lets).
//!
//! Writes BENCH_engine_scale.json; diff across PRs with
//! `gpulets bench-compare`.

use gpulets::coordinator::{ServingEngine, SimConfig};
use gpulets::interference::GroundTruth;
use gpulets::models::ModelId;
use gpulets::perfmodel::LatencyModel;
use gpulets::sched::{ElasticPartitioning, SchedCtx, Scheduler};
use gpulets::util::benchkit;
use gpulets::util::json::{obj, Json};
use gpulets::workload::{
    dyn_sources, generate_varying, varying_streams, DynSourceMux, FluctuationTrace,
    SourceMux,
};

fn fig14_mux(scale: f64, duration_s: f64, seed: u64) -> (DynSourceMux, usize) {
    let trace = FluctuationTrace::default();
    let streams = varying_streams(
        &ModelId::ALL,
        move |m, t| trace.rate_at(m, t) * scale,
        duration_s,
        1.0,
        seed,
    )
    .expect("fig14 rates are finite");
    let n = streams.len();
    (SourceMux::new(dyn_sources(streams)), n)
}

fn main() {
    let lm = LatencyModel::new();
    let gt = GroundTruth::default();
    let cfg = SimConfig::default();
    let ctx = SchedCtx::new(4, None);
    let schedule = ElasticPartitioning::gpulet()
        .schedule(&ctx, &[50.0; 5])
        .expect("the equal scenario fits four GPUs");
    let total_asgs: usize = schedule.lets.iter().map(|l| l.assignments.len()).sum();
    let n_lets = schedule.lets.len();

    let mut timings = Vec::new();
    let mut rungs = Vec::new();

    // --- scale ladder -----------------------------------------------------
    for &k in &[1u32, 10, 100] {
        let duration = 1800.0 / k as f64;
        let label = format!("engine: {k}x fig14 load, {duration:.0}s streamed");
        let (t, (events, peak, offered, bound)) = benchkit::bench(&label, 0, 1, || {
            let (mux, n_streams) = fig14_mux(k as f64, duration, 2024);
            let mut eng = ServingEngine::new(&lm, &gt, schedule.clone(), duration, &cfg);
            eng.attach_source(mux);
            eng.run_stream();
            eng.close();
            let bound = n_streams + total_asgs + n_lets;
            let offered: u64 = eng.injected_per_model().iter().sum();
            (eng.events_processed(), eng.peak_live_events(), offered, bound)
        });
        assert!(
            peak <= bound,
            "{k}x: peak live events {peak} exceeded the structural bound {bound}"
        );
        let events_per_s = if t.mean_ms > 0.0 { events as f64 / (t.mean_ms / 1000.0) } else { 0.0 };
        println!("{}", t.summary());
        println!(
            "  {k:>3}x: {offered} offered, {events} events, {events_per_s:.0} events/s, \
             peak {peak} live events (bound {bound})"
        );
        rungs.push(obj(vec![
            ("scale", Json::Num(k as f64)),
            ("duration_s", Json::Num(duration)),
            ("offered_requests", Json::Num(offered as f64)),
            ("events", Json::Num(events as f64)),
            ("events_per_s", Json::Num(events_per_s)),
            ("peak_live_events", Json::Num(peak as f64)),
            ("live_event_bound", Json::Num(bound as f64)),
        ]));
        timings.push(t);
    }

    // --- old-vs-new pair: bulk inject vs streaming, identical trace -------
    let pair_duration = 120.0;
    let trace = FluctuationTrace::default();
    let arrivals = generate_varying(
        &ModelId::ALL,
        |m, t| trace.rate_at(m, t),
        pair_duration,
        1.0,
        2024,
    )
    .expect("fig14 rates are finite");
    let n_arr = arrivals.len();

    // Trace generation runs INSIDE both timed closures — the old path
    // pays generate + sort + bulk heap fill, the new path pays the
    // same draws lazily; `arrivals`/`n_arr` above exist only for the
    // label and the byte-identity horizon sanity.
    let (t_bulk, (r_bulk, peak_bulk)) = benchkit::bench(
        &format!("engine: 120s fig14 trace, bulk inject ({n_arr} arrivals in heap)"),
        1,
        3,
        || {
            let tr = FluctuationTrace::default();
            let trace_vec = generate_varying(
                &ModelId::ALL,
                |m, t| tr.rate_at(m, t),
                pair_duration,
                1.0,
                2024,
            )
            .expect("fig14 rates are finite");
            let mut eng =
                ServingEngine::new(&lm, &gt, schedule.clone(), pair_duration, &cfg);
            eng.inject(&trace_vec);
            let horizon = gpulets::simclock::ms_to_us(
                trace_vec.last().map(|a| a.time_ms).unwrap_or(0.0),
            ) + gpulets::simclock::ms_to_us(cfg.drain_ms);
            eng.run_until(horizon);
            let peak = eng.peak_live_events();
            (eng.finish().to_json().to_string(), peak)
        },
    );
    println!("{}", t_bulk.summary());
    timings.push(t_bulk.clone());

    let (t_stream, (r_stream, peak_stream)) = benchkit::bench(
        "engine: 120s fig14 trace, streamed sources (O(active) events)",
        1,
        3,
        || {
            let (mux, _) = fig14_mux(1.0, pair_duration, 2024);
            let mut eng =
                ServingEngine::new(&lm, &gt, schedule.clone(), pair_duration, &cfg);
            eng.attach_source(mux);
            eng.run_stream();
            let peak = eng.peak_live_events();
            (eng.finish().to_json().to_string(), peak)
        },
    );
    println!("{}", t_stream.summary());
    timings.push(t_stream.clone());

    assert_eq!(
        r_bulk, r_stream,
        "bulk-inject and streamed reports must be byte-identical"
    );
    println!(
        "peak live events: bulk {peak_bulk} (O(trace)) vs streamed {peak_stream} \
         (O(active)); speedup {:.2}x",
        if t_stream.mean_ms > 0.0 { t_bulk.mean_ms / t_stream.mean_ms } else { f64::NAN }
    );

    let doc = obj(vec![
        (
            "bench",
            Json::Arr(timings.iter().map(benchkit::BenchResult::to_json).collect()),
        ),
        (
            "result",
            obj(vec![
                ("ladder", Json::Arr(rungs)),
                ("bulk_peak_live_events", Json::Num(peak_bulk as f64)),
                ("streamed_peak_live_events", Json::Num(peak_stream as f64)),
                ("pair_arrivals", Json::Num(n_arr as f64)),
            ]),
        ),
    ]);
    benchkit::write_json("BENCH_engine_scale.json", &doc)
        .expect("write BENCH_engine_scale.json");
    eprintln!("[wrote BENCH_engine_scale.json]");
}
