//! Micro-benchmarks of the L3 hot paths (the §Perf targets in
//! EXPERIMENTS.md):
//!
//! * one Elastic Partitioning scheduling pass (the 20 s-period planner)
//! * the full 1,023-scenario schedulability sweep
//! * the discrete-event simulator's event throughput
//! * batch-builder enqueue/dispatch
//! * interference-model prediction (called inside scheduler loops)
//! * PJRT end-to-end execution, when `artifacts/` is built

use gpulets::coordinator::batcher::{BatchBuilder, Queued};
use gpulets::coordinator::simserver::{simulate, SimConfig};
use gpulets::experiments::common::{fitted_interference, paper_ctx};
use gpulets::interference::GroundTruth;
use gpulets::models::ModelId;
use gpulets::perfmodel::LatencyModel;
use gpulets::sched::{ElasticPartitioning, Scheduler};
use gpulets::util::benchkit;
use gpulets::workload::{enumerate_all_scenarios, generate_arrivals};

fn main() {
    let ctx = paper_ctx(true);
    let gi = ElasticPartitioning::gpulet_int();

    // --- scheduler pass ---------------------------------------------------
    let rates = [100.0, 100.0, 100.0, 50.0, 50.0];
    benchkit::run("sched: one gpulet+int pass (short-skew)", 10, 200, || {
        gi.schedule(&ctx, &rates).is_ok()
    });

    let scenarios = enumerate_all_scenarios();
    benchkit::run("sched: 1023-scenario gpulet+int sweep", 1, 5, || {
        scenarios
            .iter()
            .filter(|sc| gi.schedule(&ctx, &sc.rates).is_ok())
            .count()
    });

    // --- interference prediction ------------------------------------------
    let model = fitted_interference();
    benchkit::run("intf: 10k pair predictions", 2, 50, || {
        let mut acc = 0.0;
        for i in 0..10_000u32 {
            let m1 = ModelId::from_index((i % 5) as usize);
            let m2 = ModelId::from_index(((i / 5) % 5) as usize);
            acc += model.predict_pair(m1, 8, 0.5, m2, 16, 0.5);
        }
        acc
    });

    // --- simulator event throughput ----------------------------------------
    let lm = LatencyModel::new();
    let gt = GroundTruth::default();
    let schedule = gi.schedule(&ctx, &rates).expect("schedulable");
    let arrivals = generate_arrivals(
        &[
            (ModelId::Lenet, 100.0),
            (ModelId::Googlenet, 100.0),
            (ModelId::Resnet, 100.0),
            (ModelId::SsdMobilenet, 50.0),
            (ModelId::Vgg, 50.0),
        ],
        10.0,
        5,
    );
    let n_arr = arrivals.len();
    benchkit::run(
        &format!("sim: 10 s short-skew trace ({n_arr} arrivals)"),
        2,
        20,
        || {
            simulate(&lm, &gt, &schedule, &arrivals, 10.0, &SimConfig::default())
                .throughput_rps()
        },
    );

    // --- batcher hot path ---------------------------------------------------
    benchkit::run("batcher: 100k enqueue/dispatch", 2, 20, || {
        let mut b = BatchBuilder::new(16, 50.0);
        let mut batches = 0usize;
        for i in 0..100_000u64 {
            if b.push(Queued { id: i, arrival_ms: i as f64 * 0.01 }).is_some() {
                batches += 1;
            }
        }
        batches
    });

    // --- PJRT execution (needs `make artifacts`) ----------------------------
    match gpulets::runtime::Engine::cpu().and_then(|engine| {
        gpulets::runtime::ModelRegistry::load_models(
            &engine,
            "artifacts",
            &[ModelId::Lenet],
        )
        .map(|r| (engine, r))
    }) {
        Ok((_engine, registry)) => {
            let entry = registry.manifest.entry(ModelId::Lenet).unwrap();
            let sample = vec![0.5f32; entry.input_shape.iter().product()];
            let batch8: Vec<Vec<f32>> = (0..8).map(|_| sample.clone()).collect();
            benchkit::run("pjrt: lenet batch-8 inference", 3, 50, || {
                registry.infer(ModelId::Lenet, &batch8).unwrap().len()
            });
        }
        Err(e) => {
            println!("bench pjrt: skipped (artifacts not built: {e})");
        }
    }
}
