//! Micro-benchmarks of the L3 hot paths (DESIGN.md §8 perf targets):
//!
//! * one Elastic Partitioning scheduling pass (the 20 s-period planner)
//! * the full 1,023-scenario schedulability sweep — serial (the
//!   cross-PR trend entry) and parallel (`GPULETS_THREADS` workers)
//! * head-to-head pairs proving the hot-path refactors in one run:
//!   capacity-table lookups vs `LatencyModel` batch rescans, the flat
//!   `ProfileTable` vs a `BTreeMap` replica of the old layout, and the
//!   ideal scheduler's 35-layout deduped search vs the full 4^4
//!   enumeration
//! * the discrete-event simulator's event throughput
//! * batch-builder enqueue/dispatch
//! * interference-model prediction (called inside scheduler loops)
//! * PJRT end-to-end execution, when `artifacts/` is built
//!
//! Writes BENCH_micro_hotpath.json with one timing entry per bench;
//! diff against a committed run with `gpulets bench-compare`.

use std::collections::BTreeMap;

use gpulets::coordinator::batcher::{BatchBuilder, Queued};
use gpulets::coordinator::simserver::{simulate, SimConfig};
use gpulets::coordinator::ServingEngine;
use gpulets::simclock::ms_to_us;
use gpulets::experiments::common::{fitted_interference, paper_ctx};
use gpulets::interference::GroundTruth;
use gpulets::models::ModelId;
use gpulets::perfmodel::profile_table::PARTITIONS;
use gpulets::perfmodel::{LatencyModel, ProfileTable, BATCHES};
use gpulets::sched::{ElasticPartitioning, IdealScheduler, Scheduler};
use gpulets::util::{benchkit, par};
use gpulets::workload::{
    dyn_sources, enumerate_all_scenarios, generate_arrivals, poisson_streams, SourceMux,
};

fn main() {
    let mut timings = Vec::new();
    let ctx = paper_ctx(true);
    let gi = ElasticPartitioning::gpulet_int();

    // --- scheduler pass ---------------------------------------------------
    let rates = [100.0, 100.0, 100.0, 50.0, 50.0];
    let (t, _) = benchkit::bench("sched: one gpulet+int pass (short-skew)", 10, 200, || {
        gi.schedule(&ctx, &rates).is_ok()
    });
    println!("{}", t.summary());
    timings.push(t);

    let scenarios = enumerate_all_scenarios();
    let (t, _) = benchkit::bench("sched: 1023-scenario gpulet+int sweep", 1, 5, || {
        scenarios
            .iter()
            .filter(|sc| gi.schedule(&ctx, &sc.rates).is_ok())
            .count()
    });
    println!("{}", t.summary());
    timings.push(t);

    let workers = par::threads();
    println!("(parallel sweep uses {workers} worker threads)");
    let (t, _) = benchkit::bench("sched: 1023-scenario gpulet+int sweep (parallel)", 1, 5, || {
        par::par_map(&scenarios, |sc| gi.schedule(&ctx, &sc.rates).is_ok())
            .into_iter()
            .filter(|&ok| ok)
            .count()
    });
    println!("{}", t.summary());
    timings.push(t);

    // --- capacity-table lookups vs the old batch rescan ---------------------
    // Old hot path: every feasibility probe called LatencyModel::max_rate,
    // scanning all 6 batch sizes. New: one memoized table read.
    let (t, acc_scan) = benchkit::bench("cap: 60k max_rate batch-rescans (old path)", 2, 50, || {
        let mut acc = 0.0;
        for _ in 0..2_000 {
            for m in ModelId::ALL {
                for &pct in &PARTITIONS {
                    if let Some((r, _)) = ctx.lm.max_rate(m, pct as f64 / 100.0) {
                        acc += r;
                    }
                }
            }
        }
        acc
    });
    println!("{}", t.summary());
    timings.push(t);
    let (t, acc_memo) = benchkit::bench("cap: 60k max_rate table lookups (new path)", 2, 50, || {
        let mut acc = 0.0;
        for _ in 0..2_000 {
            for m in ModelId::ALL {
                for &pct in &PARTITIONS {
                    if let Some((r, _)) = ctx.max_rate(m, pct) {
                        acc += r;
                    }
                }
            }
        }
        acc
    });
    println!("{}", t.summary());
    timings.push(t);
    assert_eq!(acc_scan, acc_memo, "capacity memo must be bit-identical");

    // --- flat profile table vs a BTreeMap replica of the old layout ---------
    let lm = LatencyModel::new();
    let flat = ProfileTable::build(&lm);
    let mut btree: BTreeMap<(ModelId, u32, u32), f64> = BTreeMap::new();
    for m in ModelId::ALL {
        for &b in &BATCHES {
            for &p in &PARTITIONS {
                btree.insert((m, b, p), lm.latency_ms(m, b, p as f64 / 100.0));
            }
        }
    }
    let (t, sum_tree) = benchkit::bench("profile: 180k grid gets (btreemap, old)", 2, 50, || {
        let mut acc = 0.0;
        for _ in 0..1_000 {
            for m in ModelId::ALL {
                for &b in &BATCHES {
                    for &p in &PARTITIONS {
                        acc += btree.get(&(m, b, p)).copied().unwrap_or(0.0);
                    }
                }
            }
        }
        acc
    });
    println!("{}", t.summary());
    timings.push(t);
    let (t, sum_flat) = benchkit::bench("profile: 180k grid gets (flat array, new)", 2, 50, || {
        let mut acc = 0.0;
        for _ in 0..1_000 {
            for m in ModelId::ALL {
                for &b in &BATCHES {
                    for &p in &PARTITIONS {
                        acc += flat.get(m, b, p).unwrap_or(0.0);
                    }
                }
            }
        }
        acc
    });
    println!("{}", t.summary());
    timings.push(t);
    assert_eq!(sum_tree, sum_flat, "flat table must match the btreemap grid");

    // --- ideal search: deduped multiset layouts vs full 4^4 enumeration -----
    let ctx_ideal = paper_ctx(false);
    let sub: Vec<_> = scenarios.iter().step_by(16).cloned().collect();
    let (t, n_full) = benchkit::bench("ideal: 64-scenario verdicts, full 4^4 layouts", 1, 3, || {
        sub.iter()
            .filter(|sc| IdealScheduler::schedule_with(&ctx_ideal, &sc.rates, false).is_ok())
            .count()
    });
    println!("{}", t.summary());
    timings.push(t);
    let (t, n_dedup) = benchkit::bench("ideal: 64-scenario verdicts, 35 deduped layouts", 1, 3, || {
        sub.iter()
            .filter(|sc| IdealScheduler::schedule_with(&ctx_ideal, &sc.rates, true).is_ok())
            .count()
    });
    println!("{}", t.summary());
    timings.push(t);
    assert_eq!(n_full, n_dedup, "layout dedup must not change verdicts");

    // --- interference prediction ------------------------------------------
    let model = fitted_interference();
    let (t, _) = benchkit::bench("intf: 10k pair predictions", 2, 50, || {
        let mut acc = 0.0;
        for i in 0..10_000u32 {
            let m1 = ModelId::from_index((i % 5) as usize);
            let m2 = ModelId::from_index(((i / 5) % 5) as usize);
            acc += model.predict_pair(m1, 8, 0.5, m2, 16, 0.5);
        }
        acc
    });
    println!("{}", t.summary());
    timings.push(t);

    // --- simulator event throughput ----------------------------------------
    let gt = GroundTruth::default();
    let schedule = gi.schedule(&ctx, &rates).expect("schedulable");
    let trace_pairs = [
        (ModelId::Lenet, 100.0),
        (ModelId::Googlenet, 100.0),
        (ModelId::Resnet, 100.0),
        (ModelId::SsdMobilenet, 50.0),
        (ModelId::Vgg, 50.0),
    ];
    let arrivals = generate_arrivals(&trace_pairs, 10.0, 5).expect("finite rates");
    let n_arr = arrivals.len();
    let (t, _) = benchkit::bench(
        &format!("sim: 10 s short-skew trace ({n_arr} arrivals)"),
        2,
        20,
        || {
            simulate(&lm, &gt, &schedule, &arrivals, 10.0, &SimConfig::default())
                .throughput_rps()
        },
    );
    println!("{}", t.summary());
    timings.push(t);

    // --- bulk-inject vs streaming arrivals (old vs new event core) ----------
    // Old: generate + sort the whole trace, then hold the entire
    // future in the heap (O(trace) entries, every pop O(log N)). New:
    // arrivals pull lazily from per-model Poisson streams, live events
    // stay O(streams + assignments + gpu-lets). Workload generation is
    // INSIDE both timed closures (it is part of each path's real
    // cost), and reports must be byte-identical.
    let sim_cfg = SimConfig::default();
    let (t, (rep_bulk, peak_bulk)) = benchkit::bench(
        "engine: 10 s trace, bulk-inject heap (old path)",
        2,
        20,
        || {
            let trace = generate_arrivals(&trace_pairs, 10.0, 5).expect("finite rates");
            let mut eng = ServingEngine::new(&lm, &gt, schedule.clone(), 10.0, &sim_cfg);
            eng.inject(&trace);
            let horizon = ms_to_us(trace.last().map(|a| a.time_ms).unwrap_or(0.0))
                + ms_to_us(sim_cfg.drain_ms);
            eng.run_until(horizon);
            let peak = eng.peak_live_events();
            (eng.finish().to_json().to_string(), peak)
        },
    );
    println!("{}", t.summary());
    timings.push(t);
    let (t, (rep_stream, peak_stream)) = benchkit::bench(
        "engine: 10 s trace, streaming sources (new path)",
        2,
        20,
        || {
            let streams =
                poisson_streams(&trace_pairs, 10.0, 5).expect("finite rates");
            let mut eng = ServingEngine::new(&lm, &gt, schedule.clone(), 10.0, &sim_cfg);
            eng.attach_source(SourceMux::new(dyn_sources(streams)));
            eng.run_stream();
            let peak = eng.peak_live_events();
            (eng.finish().to_json().to_string(), peak)
        },
    );
    println!("{}", t.summary());
    timings.push(t);
    assert_eq!(rep_bulk, rep_stream, "streaming must be byte-identical to bulk inject");
    println!(
        "peak live events: bulk {peak_bulk} (O(trace)) vs streamed {peak_stream} (O(active))"
    );

    // --- batcher hot path ---------------------------------------------------
    let (t, _) = benchkit::bench("batcher: 100k enqueue/dispatch", 2, 20, || {
        let mut b = BatchBuilder::new(16, 50.0);
        let mut batches = 0usize;
        for i in 0..100_000u64 {
            if b.push(Queued { id: i, arrival_ms: i as f64 * 0.01 }).is_some() {
                batches += 1;
            }
        }
        batches
    });
    println!("{}", t.summary());
    timings.push(t);

    // --- PJRT execution (needs `make artifacts` + --features pjrt) ----------
    match gpulets::runtime::Engine::cpu().and_then(|engine| {
        gpulets::runtime::ModelRegistry::load_models(
            &engine,
            "artifacts",
            &[ModelId::Lenet],
        )
        .map(|r| (engine, r))
    }) {
        Ok((_engine, registry)) => {
            let entry = registry.manifest.entry(ModelId::Lenet).unwrap();
            let sample = vec![0.5f32; entry.input_shape.iter().product()];
            let batch8: Vec<Vec<f32>> = (0..8).map(|_| sample.clone()).collect();
            let (t, _) = benchkit::bench("pjrt: lenet batch-8 inference", 3, 50, || {
                registry.infer(ModelId::Lenet, &batch8).unwrap().len()
            });
            println!("{}", t.summary());
            timings.push(t);
        }
        Err(e) => {
            println!("bench pjrt: skipped (runtime unavailable: {e})");
        }
    }

    benchkit::write_json("BENCH_micro_hotpath.json", &benchkit::timings_envelope(&timings))
        .expect("write BENCH_micro_hotpath.json");
    eprintln!("[wrote BENCH_micro_hotpath.json]");

    // In-run speedup table: pairs that prove the refactors without
    // needing a committed baseline file.
    for (old, new) in [
        (
            "sched: 1023-scenario gpulet+int sweep",
            "sched: 1023-scenario gpulet+int sweep (parallel)",
        ),
        (
            "cap: 60k max_rate batch-rescans (old path)",
            "cap: 60k max_rate table lookups (new path)",
        ),
        (
            "profile: 180k grid gets (btreemap, old)",
            "profile: 180k grid gets (flat array, new)",
        ),
        (
            "ideal: 64-scenario verdicts, full 4^4 layouts",
            "ideal: 64-scenario verdicts, 35 deduped layouts",
        ),
        (
            "engine: 10 s trace, bulk-inject heap (old path)",
            "engine: 10 s trace, streaming sources (new path)",
        ),
    ] {
        let pick = |name: &str| timings.iter().find(|t| t.name == name).map(|t| t.mean_ms);
        match (pick(old), pick(new)) {
            (Some(o), Some(n)) if n > 0.0 => {
                println!("speedup {:>6.2}x  {} -> {}", o / n, old, new);
            }
            _ => println!(
                "speedup     ??x  {} -> {} (bench entry missing — label drifted?)",
                old, new
            ),
        }
    }
}
