//! Micro-benchmarks of the L3 hot paths (DESIGN.md §8 perf targets):
//!
//! * one Elastic Partitioning scheduling pass (the 20 s-period planner)
//! * the full 1,023-scenario schedulability sweep
//! * the discrete-event simulator's event throughput
//! * batch-builder enqueue/dispatch
//! * interference-model prediction (called inside scheduler loops)
//! * PJRT end-to-end execution, when `artifacts/` is built
//!
//! Writes BENCH_micro_hotpath.json with one timing entry per bench.

use gpulets::coordinator::batcher::{BatchBuilder, Queued};
use gpulets::coordinator::simserver::{simulate, SimConfig};
use gpulets::experiments::common::{fitted_interference, paper_ctx};
use gpulets::interference::GroundTruth;
use gpulets::models::ModelId;
use gpulets::perfmodel::LatencyModel;
use gpulets::sched::{ElasticPartitioning, Scheduler};
use gpulets::util::benchkit;
use gpulets::workload::{enumerate_all_scenarios, generate_arrivals};

fn main() {
    let mut timings = Vec::new();
    let ctx = paper_ctx(true);
    let gi = ElasticPartitioning::gpulet_int();

    // --- scheduler pass ---------------------------------------------------
    let rates = [100.0, 100.0, 100.0, 50.0, 50.0];
    let (t, _) = benchkit::bench("sched: one gpulet+int pass (short-skew)", 10, 200, || {
        gi.schedule(&ctx, &rates).is_ok()
    });
    println!("{}", t.summary());
    timings.push(t);

    let scenarios = enumerate_all_scenarios();
    let (t, _) = benchkit::bench("sched: 1023-scenario gpulet+int sweep", 1, 5, || {
        scenarios
            .iter()
            .filter(|sc| gi.schedule(&ctx, &sc.rates).is_ok())
            .count()
    });
    println!("{}", t.summary());
    timings.push(t);

    // --- interference prediction ------------------------------------------
    let model = fitted_interference();
    let (t, _) = benchkit::bench("intf: 10k pair predictions", 2, 50, || {
        let mut acc = 0.0;
        for i in 0..10_000u32 {
            let m1 = ModelId::from_index((i % 5) as usize);
            let m2 = ModelId::from_index(((i / 5) % 5) as usize);
            acc += model.predict_pair(m1, 8, 0.5, m2, 16, 0.5);
        }
        acc
    });
    println!("{}", t.summary());
    timings.push(t);

    // --- simulator event throughput ----------------------------------------
    let lm = LatencyModel::new();
    let gt = GroundTruth::default();
    let schedule = gi.schedule(&ctx, &rates).expect("schedulable");
    let arrivals = generate_arrivals(
        &[
            (ModelId::Lenet, 100.0),
            (ModelId::Googlenet, 100.0),
            (ModelId::Resnet, 100.0),
            (ModelId::SsdMobilenet, 50.0),
            (ModelId::Vgg, 50.0),
        ],
        10.0,
        5,
    );
    let n_arr = arrivals.len();
    let (t, _) = benchkit::bench(
        &format!("sim: 10 s short-skew trace ({n_arr} arrivals)"),
        2,
        20,
        || {
            simulate(&lm, &gt, &schedule, &arrivals, 10.0, &SimConfig::default())
                .throughput_rps()
        },
    );
    println!("{}", t.summary());
    timings.push(t);

    // --- batcher hot path ---------------------------------------------------
    let (t, _) = benchkit::bench("batcher: 100k enqueue/dispatch", 2, 20, || {
        let mut b = BatchBuilder::new(16, 50.0);
        let mut batches = 0usize;
        for i in 0..100_000u64 {
            if b.push(Queued { id: i, arrival_ms: i as f64 * 0.01 }).is_some() {
                batches += 1;
            }
        }
        batches
    });
    println!("{}", t.summary());
    timings.push(t);

    // --- PJRT execution (needs `make artifacts` + --features pjrt) ----------
    match gpulets::runtime::Engine::cpu().and_then(|engine| {
        gpulets::runtime::ModelRegistry::load_models(
            &engine,
            "artifacts",
            &[ModelId::Lenet],
        )
        .map(|r| (engine, r))
    }) {
        Ok((_engine, registry)) => {
            let entry = registry.manifest.entry(ModelId::Lenet).unwrap();
            let sample = vec![0.5f32; entry.input_shape.iter().product()];
            let batch8: Vec<Vec<f32>> = (0..8).map(|_| sample.clone()).collect();
            let (t, _) = benchkit::bench("pjrt: lenet batch-8 inference", 3, 50, || {
                registry.infer(ModelId::Lenet, &batch8).unwrap().len()
            });
            println!("{}", t.summary());
            timings.push(t);
        }
        Err(e) => {
            println!("bench pjrt: skipped (runtime unavailable: {e})");
        }
    }

    benchkit::write_json("BENCH_micro_hotpath.json", &benchkit::timings_envelope(&timings))
        .expect("write BENCH_micro_hotpath.json");
    eprintln!("[wrote BENCH_micro_hotpath.json]");
}
