//! Bench target for Fig 13: SLO violation rates at the highest rates the
//! interference-oblivious scheduler accepts (gpulet vs gpulet+int).
use gpulets::util::benchkit;

fn main() {
    let out = benchkit::run("fig13: stress-point violation sweep", 0, 1, || {
        gpulets::experiments::fig13::run()
    });
    println!("\n{out}");
}
