//! Bench target for Fig 13: SLO violation rates at the highest rates the
//! interference-oblivious scheduler accepts (gpulet vs gpulet+int);
//! writes BENCH_fig13_slo_violation.json (timing + per-workload rows).
use gpulets::experiments::{common, fig13};

fn main() {
    common::run_and_write(&fig13::Experiment, 0, 1).expect("fig13 bench");
}
