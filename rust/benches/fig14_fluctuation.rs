//! Bench target for Fig 14: the full 1,800 s rate-fluctuation trace with
//! periodic rescheduling and background partition re-organization;
//! writes BENCH_fig14_fluctuation.json (timing + per-window series).
use gpulets::experiments::{common, fig14};

fn main() {
    common::run_and_write(&fig14::Experiment, 0, 1).expect("fig14 bench");
}
