//! Bench target for Fig 14: the full 1,800 s rate-fluctuation trace with
//! periodic rescheduling and background partition re-organization.
use gpulets::util::benchkit;

fn main() {
    let out = benchkit::run("fig14: 1800 s adaptive serving trace", 0, 1, || {
        gpulets::experiments::fig14::run()
    });
    println!("\n{out}");
}
