//! Bench target for the telemetry layer: the same 2-node fleet run at
//! three tracer settings (off / 1-in-1024 sampled / full capture);
//! writes BENCH_trace_overhead.json (events/s and wall overhead per
//! arm, trace-event counts, the results-identical and
//! ledger-reconciles invariants). Diff across PRs with
//! `gpulets bench-compare` — the traced arms must stay within noise of
//! the untraced one.
use gpulets::experiments::{common, trace_overhead};

fn main() {
    common::run_and_write(&trace_overhead::Experiment, 0, 1).expect("trace_overhead bench");
}
