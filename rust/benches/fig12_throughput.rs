//! Bench target for Fig 12 — the paper's HEADLINE table: maximum
//! achievable throughput of sbp / selftune / gpulet / gpulet+int over
//! the five evaluation workloads (rate escalation + simulation); writes
//! BENCH_fig12_throughput.json (timing + per-scheduler throughput,
//! scale and SLO-violation numbers).
use gpulets::experiments::{common, fig12};

fn main() {
    common::run_and_write(&fig12::Experiment, 0, 1).expect("fig12 bench");
}
