//! Bench target for Fig 12 — the paper's HEADLINE table: maximum
//! achievable throughput of sbp / selftune / gpulet / gpulet+int over
//! the five evaluation workloads (rate escalation + simulation).
use gpulets::util::benchkit;

fn main() {
    let out = benchkit::run("fig12: 4-scheduler max-throughput search", 0, 1, || {
        gpulets::experiments::fig12::run()
    });
    println!("\n{out}");
}
