//! Bench target for Fig 15: schedulable-scenario counts, ideal
//! exhaustive search vs gpulet+int, over the 1,023-scenario population.
use gpulets::util::benchkit;

fn main() {
    let out = benchkit::run("fig15: ideal-vs-elastic 1023 sweep", 0, 1, || {
        gpulets::experiments::fig15::run()
    });
    println!("\n{out}");
}
