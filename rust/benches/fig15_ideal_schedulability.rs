//! Bench target for Fig 15: schedulable-scenario counts, ideal
//! exhaustive search vs gpulet+int, over the 1,023-scenario population;
//! writes BENCH_fig15_ideal_schedulability.json (timing + counts).
use gpulets::experiments::{common, fig15};

fn main() {
    common::run_and_write(&fig15::Experiment, 0, 1).expect("fig15 bench");
}
