//! Bench target for Fig 16: max schedulable rate of gpulet+int
//! normalized to the ideal scheduler, per evaluation workload; writes
//! BENCH_fig16_ideal_rate.json (timing + normalized rows).
use gpulets::experiments::{common, fig16};

fn main() {
    common::run_and_write(&fig16::Experiment, 0, 1).expect("fig16 bench");
}
