//! Bench target for Fig 16: max schedulable rate of gpulet+int
//! normalized to the ideal scheduler, per evaluation workload.
use gpulets::util::benchkit;

fn main() {
    let out = benchkit::run("fig16: normalized max-rate search", 0, 1, || {
        gpulets::experiments::fig16::run()
    });
    println!("\n{out}");
}
