//! Bench target for Fig 6: consolidation-overhead CDF over the 250-pair
//! population (both victims observed).
use gpulets::util::benchkit;

fn main() {
    let out = benchkit::run("fig06: 500-observation overhead CDF", 2, 10, || {
        gpulets::experiments::fig06::run()
    });
    println!("\n{out}");
}
