//! Bench target for Fig 6: consolidation-overhead CDF over the 250-pair
//! population (both victims observed); writes
//! BENCH_fig06_interference_cdf.json (timing + quantiles).
use gpulets::experiments::{common, fig06};

fn main() {
    common::run_and_write(&fig06::Experiment, 2, 10).expect("fig06 bench");
}
