//! Bench target for the robustness tier: a 4-node fleet under a flash
//! crowd to 1.8x of its schedulable capacity loses one node mid-swell
//! and recovers it, once per admission mode (off / shed / degrade);
//! writes BENCH_fault_recovery.json (per-mode conservation ledger,
//! re-plan failures, recovery time, and the headline admitted-SLO-
//! attainment ordering: shed and degrade must beat the admit-everything
//! baseline). Diff across PRs with `gpulets bench-compare`.
use gpulets::experiments::{common, fault_recovery};

fn main() {
    common::run_and_write(&fault_recovery::Experiment, 0, 1).expect("fault_recovery bench");
}
