//! Bench target for Fig 4: SBP schedulability over the 1,023-scenario
//! population, with and without even 50:50 GPU partitioning; writes
//! BENCH_fig04_schedulability.json (timing + schedulable counts).
use gpulets::experiments::{common, fig04};

fn main() {
    common::run_and_write(&fig04::Experiment, 1, 3).expect("fig04 bench");
}
