//! Bench target for Fig 4: SBP schedulability over the 1,023-scenario
//! population, with and without even 50:50 GPU partitioning.
use gpulets::util::benchkit;

fn main() {
    let out = benchkit::run("fig04: 2x 1023-scenario SBP sweep", 1, 3, || {
        gpulets::experiments::fig04::run()
    });
    println!("\n{out}");
}
