//! Bench target for Fig 9: least-squares fit of the linear interference
//! model + held-out error CDF (the paper's 70/30 split).
use gpulets::util::benchkit;

fn main() {
    let out = benchkit::run("fig09: profile + fit + validate", 1, 5, || {
        gpulets::experiments::fig09::run()
    });
    println!("\n{out}");
}
