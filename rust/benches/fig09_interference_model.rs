//! Bench target for Fig 9: least-squares fit of the linear interference
//! model + held-out error CDF (the paper's 70/30 split); writes
//! BENCH_fig09_interference_model.json (timing + coefficients + errors).
use gpulets::experiments::{common, fig09};

fn main() {
    common::run_and_write(&fig09::Experiment, 1, 5).expect("fig09 bench");
}
