//! Bench target for Fig 5: SLO violation vs rate for LeNet+VGG under
//! temporal sharing, MPS(default) and MPS(20:80) static partitioning.
use gpulets::util::benchkit;

fn main() {
    let out = benchkit::run("fig05: 3-mode rate sweep (sim)", 0, 1, || {
        gpulets::experiments::fig05::run()
    });
    println!("\n{out}");
}
