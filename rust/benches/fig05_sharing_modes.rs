//! Bench target for Fig 5: SLO violation vs rate for LeNet+VGG under
//! temporal sharing, MPS(default) and MPS(20:80) static partitioning;
//! writes BENCH_fig05_sharing_modes.json (timing + per-rate rows).
use gpulets::experiments::{common, fig05};

fn main() {
    common::run_and_write(&fig05::Experiment, 0, 1).expect("fig05 bench");
}
