//! Bench target for Fig 3: regenerates the batch-latency vs gpu-let-size
//! table for all five models, times the latency-model evaluation, and
//! writes BENCH_fig03_latency.json (timing + full L(b,p) grid).
use gpulets::experiments::{common, fig03};

fn main() {
    common::run_and_write(&fig03::Experiment, 2, 10).expect("fig03 bench");
}
