//! Bench target for Fig 3: regenerates the batch-latency vs gpu-let-size
//! table for all five models and times the latency-model evaluation.
use gpulets::util::benchkit;

fn main() {
    let table = benchkit::run("fig03: full L(b,p) grid + knees", 2, 10, || {
        gpulets::experiments::fig03::run()
    });
    println!("\n{table}");
}
