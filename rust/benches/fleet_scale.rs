//! Bench target for the fleet tier: 1 / 4 / 16 / 64 nodes under scaled
//! Fig-14 traffic behind the deterministic front-end router, each rung
//! run under both a pinned-serial (1 worker) and the ambient-parallel
//! advance; writes BENCH_fleet_scale.json (events/s per (nodes,
//! threads) cell, parallel speedup incl. the 16-node headline row,
//! byte-equality vs the serial arm, SLO-violation share, and peak-RSS
//! proxies). Diff across PRs with `gpulets bench-compare`.
use gpulets::experiments::{common, fleet_scale};

fn main() {
    common::run_and_write(&fleet_scale::Experiment, 0, 1).expect("fleet_scale bench");
}
