//! Bench target for the fleet tier: 1 / 4 / 16 nodes under scaled
//! Fig-14 traffic behind the deterministic front-end router; writes
//! BENCH_fleet_scale.json (timing + per-rung events/s and SLO-violation
//! share). Diff across PRs with `gpulets bench-compare`.
use gpulets::experiments::{common, fleet_scale};

fn main() {
    common::run_and_write(&fleet_scale::Experiment, 0, 1).expect("fleet_scale bench");
}
