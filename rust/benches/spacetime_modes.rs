//! Bench target for the space-time comparison: the Fig-12/13 workloads
//! under spatial-only / temporal-only / combined scheduling at a zero
//! violation budget; writes BENCH_spacetime_modes.json. Diff across PRs
//! with `gpulets bench-compare`.
use gpulets::experiments::{common, spacetime};

fn main() {
    common::run_and_write(&spacetime::Experiment, 0, 1).expect("spacetime bench");
}
