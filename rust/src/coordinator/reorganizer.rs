//! Dynamic partition reorganization (§5, Fig 14).
//!
//! The serving loop re-evaluates the schedule every `period_s` (20 s on
//! the prototype) from EWMA-smoothed observed rates. When the new
//! schedule's physical layout differs, re-partitioning runs in the
//! background for `reorg_s` (10–15 s measured on the paper's testbed:
//! MPS daemon restart + kernel/model reload + warmup); the *old*
//! schedule keeps serving until the swap completes, so the cost shows
//! up as adaptation lag, not downtime.

use crate::interference::GroundTruth;
use crate::metrics::Report;
use crate::models::ModelId;
use crate::perfmodel::RateMonitor;
use crate::sched::{Schedule, Scheduler, SchedCtx};
use crate::workload::{generator::generate_varying, Arrival, FluctuationTrace};

use super::simserver::{simulate, SimConfig};

/// Per-window telemetry (one row of Fig 14's three stacked series).
#[derive(Clone, Debug)]
pub struct WindowStats {
    pub t_start_s: f64,
    /// Served req/s per model in this window.
    pub throughput: [f64; 5],
    /// Sum of allocated gpu-let sizes (percent of total cluster).
    pub allocated_pct: u32,
    /// SLO violation rate (drops included) in this window.
    pub violation_rate: f64,
    /// True if a re-organization started in this window.
    pub reorganized: bool,
}

/// Periodic re-scheduling server over a rate-fluctuation trace.
pub struct AdaptiveServer<'a, S: Scheduler> {
    pub ctx: &'a SchedCtx,
    pub scheduler: &'a S,
    pub gt: GroundTruth,
    pub period_s: f64,
    /// Background re-organization latency (s).
    pub reorg_s: f64,
    /// EWMA smoothing for observed rates.
    pub ewma_alpha: f64,
    /// Rate-change threshold that triggers rescheduling.
    pub change_threshold: f64,
}

impl<'a, S: Scheduler> AdaptiveServer<'a, S> {
    pub fn new(ctx: &'a SchedCtx, scheduler: &'a S) -> Self {
        AdaptiveServer {
            ctx,
            scheduler,
            gt: GroundTruth::default(),
            period_s: 20.0,
            reorg_s: 12.0,
            ewma_alpha: 0.6,
            change_threshold: 0.10,
        }
    }

    /// Run the Fig 14 experiment: serve `trace` for `duration_s`,
    /// rescheduling each period from observed (EWMA) rates.
    pub fn run_trace(
        &self,
        trace: &FluctuationTrace,
        duration_s: f64,
        seed: u64,
    ) -> Vec<WindowStats> {
        let arrivals = generate_varying(
            &ModelId::ALL,
            |m, t| trace.rate_at(m, t),
            duration_s,
            1.0,
            seed,
        );
        self.run_arrivals(&arrivals, duration_s)
    }

    /// Serve a pre-generated arrival trace window by window.
    pub fn run_arrivals(&self, arrivals: &[Arrival], duration_s: f64) -> Vec<WindowStats> {
        // Simulation/metrics view: true SLOs (ctx.lm is the tightened
        // planning view the scheduler uses).
        let lm_true = crate::perfmodel::LatencyModel::new();
        let lm = &lm_true;
        let mut monitor = RateMonitor::new(self.ewma_alpha);
        let mut stats = Vec::new();
        let mut current: Option<Schedule> = None;
        let mut pending: Option<(Schedule, f64)> = None; // (next schedule, ready at s)
        let mut last_sched_rates: [f64; 5] = [0.0; 5];

        let mut t = 0.0;
        while t < duration_s {
            let t_end = (t + self.period_s).min(duration_s);
            // Swap in a pending schedule whose re-org completed.
            let mut reorganized = false;
            if let Some((s, ready)) = pending.take() {
                if ready <= t {
                    current = Some(s);
                    reorganized = true;
                } else {
                    pending = Some((s, ready));
                }
            }

            // This window's arrivals (times re-based to window start).
            // Boundaries are compared in the sim clock's integer
            // microseconds so a window cut is exact: every arrival lands
            // in exactly one window even when `t * 1000.0` is not
            // representable, and the re-based times match what the
            // simulator would quantize to anyway.
            let (w0_us, w1_us) = (
                crate::simclock::ms_to_us(t * 1000.0),
                crate::simclock::ms_to_us(t_end * 1000.0),
            );
            let window: Vec<Arrival> = arrivals
                .iter()
                .map(|a| (crate::simclock::ms_to_us(a.time_ms), a))
                .filter(|&(u, _)| u >= w0_us && u < w1_us)
                .map(|(u, a)| Arrival {
                    time_ms: crate::simclock::us_to_ms(u - w0_us),
                    ..*a
                })
                .collect();

            // Observe rates.
            for a in &window {
                monitor.observe(a.model, 1);
            }
            monitor.tick(t_end - t);

            // Bootstrap: first window schedules immediately from observed.
            let observed: [f64; 5] = {
                let mut r = [0.0; 5];
                for m in ModelId::ALL {
                    r[m.index()] = monitor.rate(m);
                }
                r
            };
            if current.is_none() {
                // Initial schedule: no reorg latency at boot.
                current = self.scheduler.schedule(self.ctx, &headroomed(&observed)).ok();
                last_sched_rates = observed;
            }

            // Serve the window with the current schedule.
            let report = match &current {
                Some(s) => simulate(
                    lm,
                    &self.gt,
                    s,
                    &window,
                    t_end - t,
                    &SimConfig::default(),
                ),
                None => {
                    // Nothing schedulable: everything drops.
                    let mut r = Report::new(t_end - t);
                    for a in &window {
                        r.model_mut(a.model, lm.slo_ms(a.model)).record_drop();
                    }
                    r
                }
            };

            let mut throughput = [0.0; 5];
            for m in ModelId::ALL {
                if let Some(mm) = report.model(m) {
                    throughput[m.index()] = mm.served as f64 / (t_end - t);
                }
            }
            stats.push(WindowStats {
                t_start_s: t,
                throughput,
                allocated_pct: current.as_ref().map_or(0, |s| s.total_allocated_pct()),
                violation_rate: report.overall_violation_rate(),
                reorganized,
            });

            // Decide whether to re-schedule for the future.
            let changed = ModelId::ALL.iter().any(|&m| {
                let now = observed[m.index()];
                let base = last_sched_rates[m.index()];
                (now - base).abs() / base.max(1.0) > self.change_threshold
            });
            if changed && pending.is_none() {
                if let Ok(next) = self.scheduler.schedule(self.ctx, &headroomed(&observed)) {
                    let differs = match &current {
                        Some(cur) => {
                            let a = cur.layout(self.ctx.num_gpus).ok();
                            let b = next.layout(self.ctx.num_gpus).ok();
                            match (a, b) {
                                (Some(a), Some(b)) => !a.diff_gpus(&b).is_empty(),
                                _ => true,
                            }
                        }
                        None => true,
                    };
                    last_sched_rates = observed;
                    if differs {
                        pending = Some((next, t_end + self.reorg_s));
                    } else {
                        current = Some(next); // same layout: hot re-route
                    }
                }
            }

            t = t_end;
        }
        stats
    }
}

/// Rate-prediction headroom: schedule for slightly more than observed so
/// Poisson bursts and rising ramps don't immediately violate (the paper
/// notes "occasional SLO violations due to errors when predicting rates").
fn headroomed(rates: &[f64; 5]) -> [f64; 5] {
    let mut out = *rates;
    out.iter_mut().for_each(|r| *r *= 1.15);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::ElasticPartitioning;

    #[test]
    fn adapts_allocation_to_wave() {
        let ctx = SchedCtx::new(4, None);
        let sched = ElasticPartitioning::gpulet();
        let srv = AdaptiveServer::new(&ctx, &sched);
        let trace = FluctuationTrace::default();
        // Horizon covering wave-1 rise, peak and the start of the fall.
        let stats = srv.run_trace(&trace, 400.0, 11);
        assert!(stats.len() >= 19);
        // Allocation must grow as the wave rises (early windows see base
        // rates; the peak windows see 3-4x that).
        let early = stats
            .iter()
            .take(5)
            .map(|w| w.allocated_pct)
            .min()
            .unwrap();
        let peak = stats.iter().map(|w| w.allocated_pct).max().unwrap();
        assert!(peak > early, "peak {peak} <= early {early}");
        // Overall violations stay low (paper: 0.14% of requests).
        let avg_viol: f64 =
            stats.iter().map(|w| w.violation_rate).sum::<f64>() / stats.len() as f64;
        assert!(avg_viol < 0.08, "avg violation {avg_viol}");
    }

    #[test]
    fn shrinks_after_wave() {
        let ctx = SchedCtx::new(4, None);
        let sched = ElasticPartitioning::gpulet();
        let srv = AdaptiveServer::new(&ctx, &sched);
        let trace = FluctuationTrace::default();
        // 800 s covers wave-1 rise, peak, and fall back to baseline.
        let stats = srv.run_trace(&trace, 800.0, 13);
        let peak = stats.iter().map(|w| w.allocated_pct).max().unwrap();
        let last = stats.last().unwrap().allocated_pct;
        assert!(
            last < peak,
            "allocation must shrink after the wave: last {last} >= peak {peak}"
        );
    }
}
