//! Dynamic partition reorganization (§5, Fig 14).
//!
//! The serving loop re-evaluates the schedule every `period_s` (20 s on
//! the prototype) from EWMA-smoothed observed rates. When the new
//! schedule's physical layout differs, re-partitioning runs in the
//! background for `reorg_s` (10–15 s measured on the paper's testbed:
//! MPS daemon restart + kernel/model reload + warmup); the *old*
//! schedule keeps serving until the swap completes, so the cost shows
//! up as adaptation lag, not downtime.
//!
//! One persistent [`ServingEngine`] serves the whole trace: queued and
//! in-flight requests survive window boundaries and re-organizations
//! (`SwapMode::Migrate` re-routes the backlog; in-flight batches finish
//! under the old constants). Per-window telemetry is carved out of the
//! engine's accumulating report with `Report::snapshot_window` — no
//! state is ever reset. The previous implementation re-simulated each
//! 20 s window from a cold start, which silently destroyed queued and
//! in-flight work at every boundary and gave each window a free drain
//! with no competing next-window arrivals; the conservation test in
//! `tests/engine_conservation.rs` pins the fix.
//!
//! Since PR 4 the trace *streams*: `run_source` drives the engine from
//! a pull-based [`DynSourceMux`] (the Fig 14 fluctuation trace is
//! per-model inhomogeneous Poisson streams, never a `Vec<Arrival>`),
//! and a clone of the mux serves as the rate-observation tap — the
//! run's memory footprint depends on in-flight work, not on how long
//! the trace is.

use crate::error::Result;
use crate::interference::GroundTruth;
use crate::metrics::{CounterSnapshot, Report};
use crate::models::ModelId;
use crate::perfmodel::RateMonitor;
use crate::sched::{SchedCtx, Schedule, Scheduler};
use crate::simclock::ms_to_us;
use crate::workload::{
    dyn_sources, varying_streams, Arrival, DynSourceMux, FluctuationTrace, SourceMux,
};

use super::engine::{ServingEngine, SimConfig, SwapMode};

/// Per-window telemetry (one row of Fig 14's three stacked series).
#[derive(Clone, Debug, PartialEq)]
pub struct WindowStats {
    pub t_start_s: f64,
    /// Served req/s per model in this window.
    pub throughput: [f64; 5],
    /// Sum of allocated gpu-let sizes (percent of total cluster).
    pub allocated_pct: u32,
    /// SLO violation rate (drops included) in this window.
    pub violation_rate: f64,
    /// True if a re-organization started in this window.
    pub reorganized: bool,
}

/// Outcome of an adaptive serving run: the per-window Fig 14 series
/// plus the exact whole-trace accounting from the persistent engine.
#[derive(Clone, Debug)]
pub struct AdaptiveOutcome {
    pub windows: Vec<WindowStats>,
    /// Whole-trace report (drops from every source included).
    pub report: Report,
    /// Requests offered per model; conservation holds exactly:
    /// `offered[m] == report served + dropped` for every model.
    pub offered: [u64; 5],
}

impl AdaptiveOutcome {
    /// Whole-trace SLO violation share (drops included) — the paper's
    /// Fig 14 headline number (0.14%).
    pub fn overall_violation_share(&self) -> f64 {
        self.report.overall_violation_rate()
    }
}

/// Re-scheduling trigger: a model's smoothed rate moved by more than
/// `threshold` *relative to the last scheduled rate*, with a small
/// absolute floor so idle-noise (a stray request on a quiet model)
/// does not thrash the partitions. The floor replaces the old
/// `/ base.max(1.0)` denominator clamp, which silently turned the
/// relative test into an absolute `delta > threshold` for every model
/// under 1 req/s — masking e.g. a 0.05 -> 0.12 req/s (2.4x) change.
pub(crate) const MIN_TRIGGER_DELTA: f64 = 0.05;

/// Shared with the fleet tier's rebalance trigger (`fleet::engine`), so
/// one node's reorganization and the fleet's re-planning react to the
/// same notion of "the load moved".
pub(crate) fn rates_changed(observed: &[f64; 5], baseline: &[f64; 5], threshold: f64) -> bool {
    ModelId::ALL.iter().any(|&m| {
        let now = observed[m.index()];
        let base = baseline[m.index()];
        (now - base).abs() > (base * threshold).max(MIN_TRIGGER_DELTA)
    })
}

/// Periodic re-scheduling server over a rate-fluctuation trace.
pub struct AdaptiveServer<'a, S: Scheduler> {
    pub ctx: &'a SchedCtx,
    pub scheduler: &'a S,
    pub gt: GroundTruth,
    pub period_s: f64,
    /// Background re-organization latency (s).
    pub reorg_s: f64,
    /// EWMA smoothing for observed rates.
    pub ewma_alpha: f64,
    /// Rate-change threshold that triggers rescheduling.
    pub change_threshold: f64,
}

impl<'a, S: Scheduler> AdaptiveServer<'a, S> {
    pub fn new(ctx: &'a SchedCtx, scheduler: &'a S) -> Self {
        AdaptiveServer {
            ctx,
            scheduler,
            gt: GroundTruth::default(),
            period_s: 20.0,
            reorg_s: 12.0,
            ewma_alpha: 0.6,
            change_threshold: 0.10,
        }
    }

    /// Run the Fig 14 experiment: serve `trace` for `duration_s`,
    /// rescheduling each period from observed (EWMA) rates. The trace
    /// streams straight into the engine — per-model inhomogeneous
    /// Poisson streams, never materialized as a `Vec<Arrival>`.
    pub fn run_trace(
        &self,
        trace: &FluctuationTrace,
        duration_s: f64,
        seed: u64,
    ) -> Result<AdaptiveOutcome> {
        let tr = trace.clone();
        let streams = varying_streams(
            &ModelId::ALL,
            move |m, t| tr.rate_at(m, t),
            duration_s,
            1.0,
            seed,
        )?;
        Ok(self.run_source(SourceMux::new(dyn_sources(streams)), duration_s))
    }

    /// Serve a pre-generated arrival trace (sorted by time) on one
    /// persistent engine, with windowed metric snapshots. Adapter over
    /// [`AdaptiveServer::run_source`] for callers that already hold a
    /// materialized trace — copies it once into an `Arc` the
    /// observation tap then shares; streaming callers use `run_source`
    /// directly and never materialize.
    pub fn run_arrivals(&self, arrivals: &[Arrival], duration_s: f64) -> AdaptiveOutcome {
        self.run_source(DynSourceMux::of_trace(arrivals.to_vec()), duration_s)
    }

    /// Serve a pull-based arrival source on one persistent engine, with
    /// windowed metric snapshots. A clone of the mux acts as the rate-
    /// observation tap (it deterministically replays the same stream
    /// the engine serves), so observed rates per window match what the
    /// old materialized cursor counted, byte for byte.
    pub fn run_source(&self, source: DynSourceMux, duration_s: f64) -> AdaptiveOutcome {
        // Simulation/metrics view: true SLOs (ctx.lm is the tightened
        // planning view the scheduler uses).
        let lm_true = crate::perfmodel::LatencyModel::new();
        let cfg = SimConfig::default();
        let mut monitor = RateMonitor::new(self.ewma_alpha);
        let mut windows = Vec::new();
        // The engine starts with an empty schedule (drops everything)
        // until the bootstrap window installs the first real one.
        let mut engine =
            ServingEngine::new(&lm_true, &self.gt, Schedule::default(), duration_s, &cfg);
        // Observation tap: a clone of the source replays the identical
        // arrival stream one window ahead of the serving copy.
        let mut obs = source.clone();
        engine.attach_source(source);

        let mut current: Option<Schedule> = None;
        let mut pending: Option<(Schedule, f64)> = None; // (next schedule, ready at s)
        let mut last_sched_rates: [f64; 5] = [0.0; 5];
        let mut prev_counts = CounterSnapshot::default();

        let mut t = 0.0;
        while t < duration_s {
            let t_end = (t + self.period_s).min(duration_s);
            // Swap in a pending schedule whose re-org completed: the
            // engine migrates the backlog and retires in-flight work.
            let mut reorganized = false;
            if let Some((s, ready)) = pending.take() {
                if ready <= t {
                    engine.swap_schedule(s.clone(), SwapMode::Migrate);
                    current = Some(s);
                    reorganized = true;
                } else {
                    pending = Some((s, ready));
                }
            }

            // Observe this window's arrivals off the tap. Boundaries are
            // compared in the sim clock's integer microseconds so a
            // window cut is exact: every arrival lands in exactly one
            // window even when `t * 1000.0` is not representable. `<=`
            // matches the serving side — `run_until(w1_us)` processes
            // events AT the boundary too, so observation and serving
            // agree on which window a boundary arrival belongs to.
            let w1_us = ms_to_us(t_end * 1000.0);
            while obs.peek_time_ms().is_some_and(|t_ms| ms_to_us(t_ms) <= w1_us) {
                // Peek said an arrival is there; a None pull would mean
                // the tap lost it — stop observing rather than panic.
                let Some(a) = obs.pull() else { break };
                monitor.observe(a.model, 1);
            }
            monitor.tick(t_end - t);

            let observed: [f64; 5] = {
                let mut r = [0.0; 5];
                for m in ModelId::ALL {
                    r[m.index()] = monitor.rate(m);
                }
                r
            };
            // Bootstrap: first window schedules immediately from
            // observed rates (no reorg latency at boot).
            if current.is_none() {
                if let Ok(s) = self.scheduler.schedule(self.ctx, &headroomed(&observed)) {
                    engine.swap_schedule(s.clone(), SwapMode::Migrate);
                    current = Some(s);
                }
                last_sched_rates = observed;
            }

            // Serve up to the window end; at the trace end also run the
            // drain and close leftovers into the final window.
            if t_end >= duration_s {
                engine.run_until(w1_us + ms_to_us(cfg.drain_ms));
                engine.close();
            } else {
                engine.run_until(w1_us);
            }
            let win = engine.report().snapshot_window(&prev_counts, t_end - t);
            prev_counts = engine.report().counters();

            let mut throughput = [0.0; 5];
            for m in ModelId::ALL {
                throughput[m.index()] = win.throughput(m);
            }
            windows.push(WindowStats {
                t_start_s: t,
                throughput,
                allocated_pct: current.as_ref().map_or(0, |s| s.total_allocated_pct()),
                violation_rate: win.violation_rate(),
                reorganized,
            });

            // Decide whether to re-schedule for the future (pointless
            // once the final window has drained and closed the engine).
            if t_end < duration_s
                && rates_changed(&observed, &last_sched_rates, self.change_threshold)
                && pending.is_none()
            {
                if let Ok(next) = self.scheduler.schedule(self.ctx, &headroomed(&observed)) {
                    let differs = match &current {
                        Some(cur) => {
                            let a = cur.layout(self.ctx.num_gpus).ok();
                            let b = next.layout(self.ctx.num_gpus).ok();
                            match (a, b) {
                                (Some(a), Some(b)) => !a.diff_gpus(&b).is_empty(),
                                _ => true,
                            }
                        }
                        None => true,
                    };
                    last_sched_rates = observed;
                    if differs {
                        pending = Some((next, t_end + self.reorg_s));
                    } else {
                        // Same layout: hot re-route on the live engine.
                        engine.swap_schedule(next.clone(), SwapMode::Migrate);
                        current = Some(next);
                    }
                }
            }

            t = t_end;
        }
        let offered = engine.injected_per_model();
        AdaptiveOutcome { windows, report: engine.finish(), offered }
    }
}

/// Rate-prediction headroom: schedule for slightly more than observed so
/// Poisson bursts and rising ramps don't immediately violate (the paper
/// notes "occasional SLO violations due to errors when predicting rates").
pub(crate) fn headroomed(rates: &[f64; 5]) -> [f64; 5] {
    let mut out = *rates;
    out.iter_mut().for_each(|r| *r *= 1.15);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::ElasticPartitioning;

    #[test]
    fn adapts_allocation_to_wave() {
        let ctx = SchedCtx::new(4, None);
        let sched = ElasticPartitioning::gpulet();
        let srv = AdaptiveServer::new(&ctx, &sched);
        let trace = FluctuationTrace::default();
        // Horizon covering wave-1 rise, peak and the start of the fall.
        let out = srv.run_trace(&trace, 400.0, 11).unwrap();
        let stats = &out.windows;
        assert!(stats.len() >= 19);
        // Allocation must grow as the wave rises (early windows see base
        // rates; the peak windows see 3-4x that).
        let early = stats
            .iter()
            .take(5)
            .map(|w| w.allocated_pct)
            .min()
            .unwrap();
        let peak = stats.iter().map(|w| w.allocated_pct).max().unwrap();
        assert!(peak > early, "peak {peak} <= early {early}");
        // Overall violations stay low (paper: 0.14% of requests).
        let avg_viol: f64 =
            stats.iter().map(|w| w.violation_rate).sum::<f64>() / stats.len() as f64;
        assert!(avg_viol < 0.08, "avg violation {avg_viol}");
    }

    #[test]
    fn shrinks_after_wave() {
        let ctx = SchedCtx::new(4, None);
        let sched = ElasticPartitioning::gpulet();
        let srv = AdaptiveServer::new(&ctx, &sched);
        let trace = FluctuationTrace::default();
        // 800 s covers wave-1 rise, peak, and fall back to baseline.
        let out = srv.run_trace(&trace, 800.0, 13).unwrap();
        let peak = out.windows.iter().map(|w| w.allocated_pct).max().unwrap();
        let last = out.windows.last().unwrap().allocated_pct;
        assert!(
            last < peak,
            "allocation must shrink after the wave: last {last} >= peak {peak}"
        );
    }

    #[test]
    fn change_trigger_is_relative_with_absolute_floor() {
        let thr = 0.10;
        // Low-rate model: a 2.4x change the old `/ base.max(1.0)` clamp
        // masked (delta 0.07 < 0.10 absolute) must now trigger.
        let mut base = [10.0; 5];
        let mut now = [10.0; 5];
        base[2] = 0.05;
        now[2] = 0.12;
        assert!(rates_changed(&now, &base, thr));
        // Sub-floor noise on an idle model must NOT trigger.
        let mut quiet = [10.0; 5];
        quiet[2] = 0.0;
        let mut blip = quiet;
        blip[2] = 0.04;
        assert!(!rates_changed(&blip, &quiet, thr));
        // Stable high rates within the relative band must NOT trigger.
        let hi = [100.0; 5];
        let mut close = hi;
        close[0] = 105.0; // 5% < 10%
        assert!(!rates_changed(&close, &hi, thr));
        // And a 15% move at high rate must trigger.
        let mut far = hi;
        far[0] = 115.0;
        assert!(rates_changed(&far, &hi, thr));
    }
}
