//! The real serving path: duty-cycle batching over the PJRT runtime.
//!
//! This is the "prove all layers compose" loop (DESIGN.md §1 `real`
//! clock): wall-clock paced Poisson arrivals -> per-model batch
//! builders -> PJRT execution of the AOT artifacts -> per-request
//! latency accounting against Table 4 SLOs. Python is not involved.
//!
//! The CPU PJRT client executes one batch at a time (no MPS on CPUs),
//! so the real path corresponds to a single temporal-sharing gpu-let;
//! the partitioned multi-GPU behaviour is the simulator's job.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::coordinator::batcher::{slo_timeout_ms, BatchBuilder, Queued};
use crate::error::{Error, Result};
use crate::metrics::Report;
use crate::models::ModelId;
use crate::runtime::ModelRegistry;
use crate::util::rng::Pcg32;
use crate::workload::Arrival;

/// Outcome of one real serving run.
pub struct ServeOutcome {
    pub report: Report,
    /// Wall-clock execution time spent inside PJRT (s).
    pub exec_wall_s: f64,
    /// Total batches executed per model.
    pub batches: BTreeMap<ModelId, u64>,
}

/// Real serving loop configuration.
pub struct RealServer<'a> {
    pub registry: &'a ModelRegistry,
    /// Per-model target batch size.
    pub batch: BTreeMap<ModelId, u32>,
    /// Pace arrivals in wall-clock time (true) or replay as fast as
    /// possible with virtual queueing latency (false).
    pub realtime: bool,
    /// SLO scaling for the CPU substrate: Table 4's SLOs assume a
    /// 2080 Ti; the CPU PJRT client is orders of magnitude slower, so
    /// the real path serves against `slo * slo_scale` (documented in
    /// DESIGN.md §3 as part of the hardware substitution).
    pub slo_scale: f64,
}

impl<'a> RealServer<'a> {
    pub fn new(registry: &'a ModelRegistry) -> Self {
        RealServer { registry, batch: BTreeMap::new(), realtime: false, slo_scale: 25.0 }
    }

    /// Serve an arrival trace; returns per-model latency/SLO metrics.
    ///
    /// In non-realtime mode the "clock" for queueing is the later of the
    /// request's nominal arrival time and the executor's progress — the
    /// standard trace-replay discipline.
    pub fn serve(&self, arrivals: &[Arrival], window_s: f64) -> Result<ServeOutcome> {
        let mut report = Report::new(window_s);
        let mut builders: BTreeMap<ModelId, BatchBuilder> = BTreeMap::new();
        let mut batches: BTreeMap<ModelId, u64> = BTreeMap::new();
        let mut inputs_cache: BTreeMap<ModelId, Vec<f32>> = BTreeMap::new();
        let mut rng = Pcg32::seeded(0xFEED);

        let t0 = Instant::now();
        let mut exec_wall_s = 0.0;
        // Executor progress in trace-ms (non-realtime replay clock).
        let mut clock_ms = 0.0f64;

        let flush =
            |model: ModelId,
             batch: Vec<Queued>,
             clock_ms: &mut f64,
             report: &mut Report,
             exec_wall_s: &mut f64,
             batches: &mut BTreeMap<ModelId, u64>,
             inputs_cache: &mut BTreeMap<ModelId, Vec<f32>>,
             rng: &mut Pcg32|
             -> Result<f64> {
                let entry = self.registry.manifest.entry(model)?;
                let sample_len: usize = entry.input_shape.iter().product();
                let sample = inputs_cache.entry(model).or_insert_with(|| {
                    (0..sample_len).map(|_| rng.f64() as f32).collect()
                });
                let ins: Vec<Vec<f32>> =
                    batch.iter().map(|_| sample.clone()).collect();
                let start = Instant::now();
                let outs = self.registry.infer(model, &ins)?;
                let exec_ms = start.elapsed().as_secs_f64() * 1000.0;
                *exec_wall_s += exec_ms / 1000.0;
                debug_assert_eq!(outs.len(), batch.len());
                *batches.entry(model).or_insert(0) += 1;

                // Queueing + execution latency on the replay clock.
                let start_ms = clock_ms.max(batch.iter().map(|q| q.arrival_ms).fold(0.0, f64::max));
                let done_ms = start_ms + exec_ms;
                *clock_ms = done_ms;
                let slo = entry.slo_ms * self.slo_scale;
                for q in &batch {
                    report.model_mut(model, slo).record(done_ms - q.arrival_ms);
                }
                Ok(exec_ms)
            };

        for a in arrivals {
            if self.realtime {
                let target = std::time::Duration::from_secs_f64(a.time_ms / 1000.0);
                let elapsed = t0.elapsed();
                if target > elapsed {
                    std::thread::sleep(target - elapsed);
                }
            }
            let Ok(entry) = self.registry.manifest.entry(a.model) else {
                // Arrival for a model this registry does not serve:
                // count it as a drop instead of aborting the whole run
                // (the sim path's "unscheduled model" semantics), keyed
                // by the catalog SLO at this substrate's scale.
                let slo = crate::models::profile(a.model).slo_ms * self.slo_scale;
                report.model_mut(a.model, slo).record_drop();
                continue;
            };
            let b = self
                .batch
                .get(&a.model)
                .copied()
                .unwrap_or_else(|| entry.artifacts.keys().copied().max().unwrap_or(1));
            builders.entry(a.model).or_insert_with(|| {
                // A conservative 5 ms exec estimate seeds the timeout; it
                // only affects batching aggressiveness, not correctness.
                BatchBuilder::new(b, slo_timeout_ms(entry.slo_ms * self.slo_scale, 5.0))
            });
            // Timeout path: flush any model whose head is overdue.
            let now_ms = if self.realtime {
                t0.elapsed().as_secs_f64() * 1000.0
            } else {
                clock_ms.max(a.time_ms)
            };
            let overdue: Vec<ModelId> = builders
                .iter()
                .filter(|(_, bl)| bl.deadline_ms().is_some_and(|d| now_ms >= d))
                .map(|(&m, _)| m)
                .collect();
            for m in overdue {
                if let Some(batch) = builders.get_mut(&m).and_then(|bl| bl.flush()) {
                    let exec_ms = flush(
                        m, batch.requests, &mut clock_ms, &mut report,
                        &mut exec_wall_s, &mut batches, &mut inputs_cache, &mut rng,
                    )?;
                    retune(&mut builders, &self.registry.manifest, m, exec_ms, self.slo_scale);
                }
            }
            let builder = builders.get_mut(&a.model).ok_or_else(|| {
                Error::Model(format!("{}: no batch builder for arrival", a.model))
            })?;
            if let Some(batch) = builder.push(Queued { id: a.id, arrival_ms: a.time_ms }) {
                let exec_ms = flush(
                    a.model, batch.requests, &mut clock_ms, &mut report,
                    &mut exec_wall_s, &mut batches, &mut inputs_cache, &mut rng,
                )?;
                retune(&mut builders, &self.registry.manifest, a.model, exec_ms, self.slo_scale);
            }
        }
        // Drain all remaining queues.
        let leftover: Vec<ModelId> = builders.keys().copied().collect();
        for m in leftover {
            while let Some(batch) = builders.get_mut(&m).and_then(|bl| bl.flush()) {
                flush(
                    m, batch.requests, &mut clock_ms, &mut report,
                    &mut exec_wall_s, &mut batches, &mut inputs_cache, &mut rng,
                )?;
            }
        }

        Ok(ServeOutcome { report, exec_wall_s, batches })
    }
}

/// Re-derive a model's batching timeout from the latest measured
/// execution time (the real path's analogue of the paper's offline
/// profiling feeding the duty-cycle bound).
fn retune(
    builders: &mut BTreeMap<ModelId, BatchBuilder>,
    manifest: &crate::runtime::Manifest,
    m: ModelId,
    exec_ms: f64,
    slo_scale: f64,
) {
    if let (Some(bl), Ok(entry)) = (builders.get_mut(&m), manifest.entry(m)) {
        bl.timeout_ms = slo_timeout_ms(entry.slo_ms * slo_scale, exec_ms);
    }
}

// Exercised end-to-end (real artifacts + PJRT) by
// rust/tests/integration_runtime.rs and examples/quickstart.rs.
