//! Persistent continuous-time serving engine.
//!
//! This is the stateful core the ROADMAP's online/streaming workloads
//! build on: one `ServingEngine` owns the event queue, the per-(gpu-let,
//! model) FIFO queues, the in-flight sets, the deficit-weighted routing
//! counters, and the accumulating `Report`, and keeps all of them alive
//! across schedule changes. `simserver::simulate` is a thin one-shot
//! wrapper (attach a source → run to the drain horizon → finish); the
//! adaptive reorganizer drives one engine across the whole Fig 14 trace
//! and swaps schedules live instead of re-simulating each 20 s window
//! from a cold start.
//!
//! ## Streaming core: O(active) live events
//!
//! The engine's live event set is bounded by *in-flight work*, never by
//! trace length:
//!
//! * **Arrivals** come from an attached [`DynSourceMux`] — one pending
//!   arrival per stream, pulled lazily as virtual time reaches it. The
//!   legacy `inject(&[Arrival])` bulk path still exists (and is what
//!   the equivalence suite diffs against), but nothing requires
//!   materializing a trace anymore.
//! * **Duty timers** live in one slot per (gpu-let, assignment) instead
//!   of accumulating in the heap: arming overwrites the slot, which is
//!   exactly the old `timer_token` invalidation — a superseded timer's
//!   pop was already a provable no-op, so eliding it is behavior-
//!   preserving. Each arm still takes a tie-break ticket from the
//!   queue's sequence counter ([`EventQueue::alloc_seq`]), so merged
//!   pop order at equal timestamps is bit-identical to the all-in-the-
//!   heap implementation.
//! * **The heap** holds only in-flight `Done` events (≤ one per
//!   gpu-let) plus whatever the caller bulk-injected.
//!
//! Merged pop order: at equal microsecond timestamps, source arrivals
//! fire before simulator events — the same order bulk injection
//! produced, where every `Arrive` was pushed (and sequenced) before the
//! first runtime event. `tests/streaming_equivalence.rs` pins streamed
//! vs materialized reports byte-for-byte, and the frozen pre-extraction
//! reference in `tests/engine_equivalence.rs` still pins the whole
//! pipeline.
//!
//! ## Memory layout: flat arena, zero steady-state allocation
//!
//! Per-(gpu-let, assignment) state — FIFO queue, duty-timer slot,
//! precomputed constants, route position — lives in flat arenas indexed
//! by assignment id (`asg_base[let] + asg`, let-major), not in nested
//! per-let Vecs. `install_schedule` *reuses* the arenas across swaps
//! and probe resets (carried-over `VecDeque`s keep their capacity), and
//! the batch in-flight buffers rotate through a scratch `Vec` at each
//! `Done` instead of being reallocated per batch. Together with the
//! recycled fleet chunk path ([`ServingEngine::attach_chunk`]) the
//! steady-state event loop performs **no heap allocation**: every push/
//! pop lands in retained-capacity storage.
//!
//! ## The fleet chunk path
//!
//! `attach_chunk(Vec<Arrival>)` is the allocation-recycling form of
//! `attach_source(DynSourceMux::of_trace(chunk))` the fleet's lockstep
//! advance uses: the chunk is peeked/pulled through the same merged
//! arrival ordering (chunk head and source peek compete; the earlier
//! wins, chunk first on exact ties) and counts as (at most) one pending
//! live event, exactly like the single materialized stream it replaces.
//! The previous — by contract fully consumed — chunk's buffer is handed
//! back to the caller, so the same `Vec`s cycle router → fleet →
//! engine → fleet forever.
//!
//! ## Lifecycle
//!
//! ```text
//! let mut eng = ServingEngine::new(&lm, &gt, schedule, window_s, &cfg);
//! eng.attach_source(mux);         // pull-based; or eng.inject(&arrivals)
//! eng.run_until(t_us);            // process every event with time <= t
//! eng.swap_schedule(next, mode);  // live re-organization (see below)
//! eng.run_stream();               // drive the source dry + drain
//! let report = eng.finish();      // leftovers counted as drops
//! ```
//!
//! `reset(schedule, window_s)` rewinds an engine to the fresh state
//! while keeping its allocations — the max-rate searches reset one
//! engine across dozens of probe simulations instead of rebuilding
//! routes/queues/heap scratch every probe.
//!
//! ## Swap semantics (§5: background re-partitioning)
//!
//! `swap_schedule` models the paper's "the old schedule keeps serving
//! until the swap completes" hand-over at the instant the new partitions
//! come online:
//!
//! * **In-flight executions finish under the old constants.** Their
//!   `Done` events stay queued; the batches are moved to a retired set
//!   keyed by the old epoch and complete (or, at `finish`, drop) with
//!   the old schedule's model/SLO accounting. They are never lost.
//! * **Queued requests migrate** (`SwapMode::Migrate`) onto the new
//!   schedule's routes in FIFO order through the same deficit-weighted
//!   router as fresh arrivals. A request whose model lost every route
//!   is dropped *and counted* — nothing leaves the system silently.
//!   `SwapMode::DropQueued` instead drops (and counts) the whole
//!   backlog: the restart-the-world approximation, kept for A/B tests.
//! * Executor busy-state, routing counters, and duty-cycle constants
//!   are rebuilt for the new schedule; duty-timer slots die with the
//!   old schedule's state (the old epoch-tagged `Timeout` events used
//!   to be discarded on pop).
//!
//! Three deliberate approximations at the swap instant, noted here
//! because they bound the fidelity of the hand-over: a retired
//! execution no longer participates in interference (its co-resident is
//! gone with the old schedule); under `TemporalOnly` the physical GPU
//! is considered free for the new schedule even while a retired kernel
//! finishes; and the new schedule's executors all start idle, so a new
//! batch can overlap a retired one on the same resources. Each window
//! lasts at most one batch execution — the paper's 10–15 s
//! re-partitioning (MPS restart + reload + warmup) dwarfs it.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::gpu::ShareMode;
use crate::interference::ground_truth::{GroundTruth, TaskDemand};
use crate::metrics::Report;
use crate::models::{profile, ModelId};
use crate::perfmodel::LatencyModel;
use crate::sched::Schedule;
use crate::simclock::{ms_to_us, us_to_ms, EventQueue, SimTimeUs};
use crate::telemetry::{EventKind, LetQueueGauge, Tracer, NO_LET};
use crate::util::rng::Pcg32;
use crate::workload::{Arrival, DynSourceMux};

/// Simulation parameters (shared with the one-shot `simulate` wrapper).
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub mode: ShareMode,
    pub seed: u64,
    /// Extra wall time after the last arrival to drain queues (ms).
    pub drain_ms: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { mode: ShareMode::Partitioned, seed: 0xD15C0, drain_ms: 2_000.0 }
    }
}

/// What happens to queued (not yet executing) requests at a schedule
/// swap. In-flight executions always finish under the old constants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwapMode {
    /// Re-route the backlog onto the new schedule's assignments (the
    /// paper's background re-partitioning semantics). Requests whose
    /// model lost all routes are dropped and counted.
    Migrate,
    /// Drop (and count) the whole backlog — the restart-the-world
    /// approximation the per-window re-simulation used to make.
    DropQueued,
}

#[derive(Clone, Copy, Debug)]
enum Event {
    /// A bulk-injected request arriving; `token` is the engine-assigned
    /// unique id. (Streamed arrivals never enter the heap — they are
    /// pulled from the source mux.)
    Arrive { model: ModelId, token: u64 },
    /// Execution finished on a gpu-let (of the tagged epoch).
    Done { epoch: u32, let_idx: usize },
}

/// Per-assignment mutable state, arena-allocated in one flat `Vec`
/// indexed `asg_base[let] + asg` (let-major — the same scan order the
/// old nested layout had).
struct AsgState {
    queue: VecDeque<(u64, SimTimeUs)>, // (engine token, arrival µs)
    /// The (only) live duty timer for this assignment: `(fire_at_us,
    /// seq)`. Arming overwrites the slot — the old heap-resident timer
    /// plus `timer_token` invalidation collapsed to one cell.
    timer: Option<(SimTimeUs, u64)>,
}

/// Precomputed per-assignment constants (µs domain), flat-indexed in
/// parallel with the schedule's assignments.
#[derive(Clone, Copy)]
struct AsgConst {
    /// Planned-batch execution estimate at the effective fraction.
    exec_est_us: SimTimeUs,
    /// SLO bound.
    slo_us: SimTimeUs,
    /// Duty timeout (`batcher::slo_timeout_us` over the let's cycle).
    timeout_us: SimTimeUs,
    /// True SLO in ms for metrics keying.
    slo_ms: f64,
}

struct LetState {
    busy: bool,
    /// Round-robin pointer over assignments.
    next_asg: usize,
    /// Assignment/batch of the in-flight execution (for interference).
    running: Option<(usize, u32)>, // (asg_idx, actual batch)
    /// In-flight requests: (asg_idx, id, arrival µs). Batches are
    /// formed in place and the buffer's capacity is recycled through
    /// `done_scratch` at every `Done` — no per-batch allocation.
    inflight: Vec<(usize, u64, SimTimeUs)>,
}

impl LetState {
    fn fresh() -> Self {
        LetState { busy: false, next_asg: 0, running: None, inflight: Vec::new() }
    }
}

/// A retired (pre-swap) in-flight request: everything its `Done` event
/// needs to account it under the old schedule's constants.
type Retired = (ModelId, f64, u64, SimTimeUs); // (model, slo_ms, token, arrival µs)

/// What the merged three-way peek (heap / timer slots / source) decided
/// to process next.
#[derive(Clone, Copy)]
enum NextEvent {
    /// Pull the earliest source arrival (it wins time ties).
    Arrival(SimTimeUs),
    /// Fire the duty-timer slot of (let_idx, asg_idx).
    Timer(SimTimeUs, usize, usize),
    /// Pop the heap.
    Heap(SimTimeUs),
}

/// The persistent discrete-event serving core. See the module docs for
/// the lifecycle and swap semantics.
pub struct ServingEngine<'a> {
    lm: &'a LatencyModel,
    gt: &'a GroundTruth,
    cfg: SimConfig,
    schedule: Schedule,
    /// Bumped at every swap; events carry the epoch they were armed in.
    epoch: u32,
    /// Routing table: model index -> [(let_idx, asg_idx, weight)].
    routes: Vec<Vec<(usize, usize, f64)>>,
    /// Per-route in-system counters for deficit-weighted routing:
    /// incremented at enqueue, decremented when a queued request is
    /// dropped — so only work a route actually absorbed counts against
    /// it (dropped requests no longer skew the split under overload).
    served: Vec<Vec<f64>>,
    lets: Vec<LetState>,
    /// Flat per-assignment arena (queues + timer slots), indexed
    /// `asg_base[let] + asg`. Reused across schedule installs.
    asgs: Vec<AsgState>,
    /// Flat per-assignment constants, parallel to `asgs`.
    consts: Vec<AsgConst>,
    /// Flat reverse map: assignment id -> position in `routes[model]`.
    route_pos: Vec<usize>,
    /// Arena base index per gpu-let: let `li`'s assignments occupy
    /// `asg_base[li] .. asg_base[li] + lets[li].assignments.len()`.
    asg_base: Vec<usize>,
    /// Scratch buffer completed batches rotate through (see `handle`).
    done_scratch: Vec<(usize, u64, SimTimeUs)>,
    /// Pending fleet-dealt lockstep chunk (time-ordered), consumed via
    /// the merged arrival peek exactly like an attached source.
    chunk: Vec<Arrival>,
    /// Consumption cursor into `chunk`.
    chunk_pos: usize,
    /// Armed duty-timer slots (live count, for the O(active) metric).
    armed: usize,
    /// Per-GPU serialization for TemporalOnly.
    gpu_busy: Vec<bool>,
    gpu_waiters: Vec<VecDeque<usize>>,
    q: EventQueue<Event>,
    /// Lazily-pulled arrival streams (one pending event per stream).
    source: Option<DynSourceMux>,
    rng: Pcg32,
    report: Report,
    /// Next engine-assigned request token (unique across all injects,
    /// regardless of caller-side id schemes).
    next_token: u64,
    /// Pre-swap in-flight batches waiting for their old-epoch `Done`,
    /// keyed (epoch, let_idx). BTreeMap for deterministic drain order.
    retired: BTreeMap<(u32, usize), Vec<Retired>>,
    /// Injected request count per model (conservation accounting).
    injected: [u64; 5],
    /// High-water mark of live events (heap + timer slots + pending
    /// source arrivals) — the footprint the streaming core bounds by
    /// `#streams + #assignments + #gpu-lets`, trace length free.
    peak_live: usize,
    /// Events processed (arrivals, timer fires, heap pops) — the
    /// numerator of the `engine_scale` events/s metric.
    events_processed: u64,
    /// Double-serve guard over engine tokens, populated only under
    /// debug_assertions.
    served_ids: BTreeSet<u64>,
    /// Telemetry recorder (DESIGN.md §13). Defaults to `Tracer::off()`,
    /// where every hook is a single predictable branch — the no-alloc
    /// hot-loop contract holds with the hooks inlined. Span events are
    /// keyed by the engine token (deterministic in pull order).
    tracer: Tracer,
    closed: bool,
}

impl<'a> ServingEngine<'a> {
    /// A fresh engine serving `schedule`. `window_s` is the measurement
    /// window for throughput reporting; `Schedule::default()` (no lets)
    /// is valid and drops every arrival until a real schedule is
    /// swapped in.
    pub fn new(
        lm: &'a LatencyModel,
        gt: &'a GroundTruth,
        schedule: Schedule,
        window_s: f64,
        cfg: &SimConfig,
    ) -> Self {
        let mut eng = ServingEngine {
            lm,
            gt,
            cfg: cfg.clone(),
            schedule: Schedule::default(),
            epoch: 0,
            routes: vec![Vec::new(); 5],
            served: vec![Vec::new(); 5],
            lets: Vec::new(),
            asgs: Vec::new(),
            consts: Vec::new(),
            route_pos: Vec::new(),
            asg_base: Vec::new(),
            done_scratch: Vec::new(),
            chunk: Vec::new(),
            chunk_pos: 0,
            armed: 0,
            gpu_busy: Vec::new(),
            gpu_waiters: Vec::new(),
            q: EventQueue::new(),
            source: None,
            rng: Pcg32::seeded(cfg.seed),
            report: Report::new(window_s),
            next_token: 0,
            retired: BTreeMap::new(),
            injected: [0; 5],
            peak_live: 0,
            events_processed: 0,
            served_ids: BTreeSet::new(),
            tracer: Tracer::off(),
            closed: false,
        };
        eng.install_schedule(schedule);
        eng
    }

    /// Rewind to the fresh post-`new` state — same seed, new schedule
    /// and measurement window — while keeping every allocation (event
    /// heap, route tables, dedup sets). The max-rate searches reset one
    /// engine across their whole probe grid instead of constructing a
    /// new one per probe.
    pub fn reset(&mut self, schedule: Schedule, window_s: f64) {
        self.q.clear();
        self.source = None;
        self.chunk.clear();
        self.chunk_pos = 0;
        self.rng = Pcg32::seeded(self.cfg.seed);
        self.report = Report::new(window_s);
        self.epoch = 0;
        self.next_token = 0;
        self.retired.clear();
        self.injected = [0; 5];
        self.peak_live = 0;
        self.events_processed = 0;
        self.served_ids.clear();
        self.tracer = self.tracer.fresh();
        self.closed = false;
        self.install_schedule(schedule);
    }

    /// Install a telemetry recorder (default: disabled). The engine
    /// stamps every event with the tracer's node index; the fleet gives
    /// each node its own tracer so parallel advance never shares a sink.
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    /// The telemetry recorder (ledger access).
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// Mutable recorder access — the fleet drains per-node rings
    /// through this, serially, at merge points.
    pub fn tracer_mut(&mut self) -> &mut Tracer {
        &mut self.tracer
    }

    /// Append per-(gpu-let, model) queue depths in arena order
    /// (let-major — deterministic) for the window gauge snapshot.
    pub fn queue_gauges(&self, out: &mut Vec<LetQueueGauge>) {
        for (li, lp) in self.schedule.lets.iter().enumerate() {
            let base = self.asg_base[li];
            for (ai, a) in lp.assignments.iter().enumerate() {
                out.push(LetQueueGauge {
                    let_idx: li as u32,
                    model: a.model.index() as u8,
                    depth: self.asgs[base + ai].queue.len() as u32,
                });
            }
        }
    }

    /// Batches currently executing (≤ one per gpu-let).
    pub fn in_flight_batches(&self) -> u64 {
        self.lets.iter().filter(|l| l.busy).count() as u64
    }

    /// Share of gpu-lets mid-batch at this instant — the duty-cycle
    /// utilization proxy the window gauges record.
    pub fn busy_fraction(&self) -> f64 {
        if self.lets.is_empty() {
            return 0.0;
        }
        self.in_flight_batches() as f64 / self.lets.len() as f64
    }

    /// Attach a pull-based arrival source (replacing any previous one).
    /// The engine pulls lazily: one pending arrival per stream, pulled
    /// when virtual time reaches it — nothing is materialized.
    pub fn attach_source(&mut self, source: DynSourceMux) {
        debug_assert!(!self.closed, "attach_source after finish/close");
        self.source = Some(source);
        self.note_live();
    }

    /// Attach a lockstep chunk of pre-routed arrivals (the fleet path),
    /// returning the previous — by contract fully consumed — chunk's
    /// buffer, cleared, for reuse. Behaviorally equivalent to
    /// `attach_source(DynSourceMux::of_trace(chunk))` (same merged
    /// arrival ordering, same ≤1 pending-live-event accounting) but
    /// with zero per-window allocation. Times must be nondecreasing,
    /// which router chunks guarantee.
    pub fn attach_chunk(&mut self, chunk: Vec<Arrival>) -> Vec<Arrival> {
        debug_assert!(!self.closed, "attach_chunk after finish/close");
        debug_assert!(
            self.chunk_pos == self.chunk.len(),
            "previous chunk not fully consumed ({}/{})",
            self.chunk_pos,
            self.chunk.len()
        );
        debug_assert!(chunk.windows(2).all(|w| w[0].time_ms <= w[1].time_ms));
        let mut spent = std::mem::replace(&mut self.chunk, chunk);
        self.chunk_pos = 0;
        spent.clear();
        self.note_live();
        spent
    }

    /// Time of the chunk's next unconsumed arrival, if any.
    fn chunk_peek_ms(&self) -> Option<f64> {
        self.chunk.get(self.chunk_pos).map(|a| a.time_ms)
    }

    /// Earliest pending arrival across the chunk and the attached
    /// source (ms). The chunk wins exact ties — the two are never mixed
    /// in practice (the fleet uses chunks, everything else a source).
    fn arrival_peek_ms(&self) -> Option<f64> {
        let chunk = self.chunk_peek_ms();
        let src = self.source.as_ref().and_then(|s| s.peek_time_ms());
        match (chunk, src) {
            (Some(c), Some(s)) => Some(if s < c { s } else { c }),
            (c, s) => c.or(s),
        }
    }

    /// Pull the earliest pending arrival (chunk-first on exact ties,
    /// matching `arrival_peek_ms`).
    fn pull_arrival(&mut self) -> Option<Arrival> {
        let chunk = self.chunk_peek_ms();
        let src = self.source.as_ref().and_then(|s| s.peek_time_ms());
        match (chunk, src) {
            (Some(c), Some(s)) if s < c => self.source.as_mut().and_then(|m| m.pull()),
            (Some(_), _) => {
                let a = self.chunk[self.chunk_pos];
                self.chunk_pos += 1;
                Some(a)
            }
            (None, Some(_)) => self.source.as_mut().and_then(|m| m.pull()),
            (None, None) => None,
        }
    }

    /// Feed arrivals into the event queue (times are absolute ms on the
    /// engine's virtual clock; past times clamp to `now`). May be called
    /// repeatedly — nothing is retained per request beyond its pending
    /// event, and the engine assigns its own request tokens
    /// (caller-side `Arrival::id` schemes need not be unique across
    /// injects). Prefer [`ServingEngine::attach_source`]: bulk
    /// injection holds the whole future in the heap, O(trace) instead
    /// of O(active).
    pub fn inject(&mut self, arrivals: &[Arrival]) {
        debug_assert!(!self.closed, "inject after finish/close");
        self.q.reserve(arrivals.len());
        for a in arrivals {
            let token = self.next_token;
            self.next_token += 1;
            self.injected[a.model.index()] += 1;
            self.q.push_at_us(
                ms_to_us(a.time_ms),
                Event::Arrive { model: a.model, token },
            );
        }
        self.note_live();
    }

    /// Process every event with `time <= t_us`, then advance the clock
    /// to `t_us` so follow-up actions (swaps, further injections) see a
    /// consistent `now` even when the queue went quiet earlier.
    pub fn run_until(&mut self, t_us: SimTimeUs) {
        // lint: no-alloc — the PR 7 event loop: every step reuses the
        // engine's pre-sized buffers (queue slots, timer slots, scratch).
        loop {
            self.note_live();
            let Some(next) = self.next_event(t_us) else { break };
            self.events_processed += 1;
            match next {
                NextEvent::Arrival(at) => {
                    let a = self.pull_arrival().expect("peeked arrival vanished");
                    // Past-time arrivals (a source attached mid-run)
                    // clamp to `now` exactly like bulk `inject` does
                    // via `push_at_us`, so the two ingestion paths
                    // agree for late-fed workloads too.
                    let at = at.max(self.q.now_us());
                    self.q.advance_to(at);
                    let token = self.next_token;
                    self.next_token += 1;
                    self.injected[a.model.index()] += 1;
                    self.tracer.span(at, EventKind::Arrival, NO_LET, a.model, self.epoch, token);
                    self.route_request(token, a.model, at);
                }
                NextEvent::Timer(at, li, ai) => {
                    self.asgs[self.asg_base[li] + ai].timer = None;
                    self.armed -= 1;
                    self.q.advance_to(at);
                    self.fire_timer(li, ai);
                }
                NextEvent::Heap(_) => {
                    let (now, ev) = self.q.pop().expect("peeked event vanished");
                    self.handle(now, ev);
                }
            }
        }
        self.q.advance_to(t_us);
        // lint: end-no-alloc
    }

    /// Drive the attached source to exhaustion, then run the drain
    /// window (`cfg.drain_ms` past the last arrival) — the streaming
    /// equivalent of the old "inject everything, run to
    /// `arrivals.last() + drain`" one-shot, with the horizon derived
    /// from the source.
    pub fn run_stream(&mut self) {
        debug_assert!(!self.closed, "run_stream after finish/close");
        while let Some(t_ms) = self.arrival_peek_ms() {
            self.run_until(ms_to_us(t_ms));
        }
        // Drain horizon from the attached source; chunk consumers (the
        // fleet) manage their own horizon via the router.
        let last_ms = self.source.as_ref().map_or(0.0, |s| s.last_arrival_ms());
        self.run_until(ms_to_us(last_ms) + ms_to_us(self.cfg.drain_ms));
    }

    /// Live schedule hand-over. See the module docs for the exact
    /// semantics; `mode` picks what happens to the queued backlog.
    pub fn swap_schedule(&mut self, next: Schedule, mode: SwapMode) {
        // Retire in-flight batches: their Done events complete them
        // under the old schedule's model/SLO constants. Idle lets keep
        // their inflight buffer (and its capacity) untouched.
        for li in 0..self.lets.len() {
            if self.lets[li].inflight.is_empty() {
                continue;
            }
            let inflight = std::mem::take(&mut self.lets[li].inflight);
            let base = self.asg_base[li];
            let mut completions = Vec::with_capacity(inflight.len());
            for (ai, id, arr) in inflight {
                let m = self.schedule.lets[li].assignments[ai].model;
                completions.push((m, self.consts[base + ai].slo_ms, id, arr));
            }
            self.retired.insert((self.epoch, li), completions);
        }
        // Collect (or drop) the queued backlog in FIFO order per queue.
        let mut backlog: Vec<(ModelId, u64, SimTimeUs)> = Vec::new();
        for li in 0..self.lets.len() {
            let base = self.asg_base[li];
            for ai in 0..self.schedule.lets[li].assignments.len() {
                let m = self.schedule.lets[li].assignments[ai].model;
                let slo_ms = self.consts[base + ai].slo_ms;
                while let Some((id, arr)) = self.asgs[base + ai].queue.pop_front() {
                    match mode {
                        SwapMode::Migrate => backlog.push((m, id, arr)),
                        SwapMode::DropQueued => {
                            self.tracer.span(self.q.now_us(), EventKind::Drop, li as u32, m, self.epoch, id);
                            self.report.model_mut(m, slo_ms).record_drop()
                        }
                    }
                }
            }
        }
        self.epoch += 1;
        self.install_schedule(next);
        self.tracer.mark(self.q.now_us(), EventKind::Swap, self.epoch, 0, 1);
        // Re-route oldest-first across ALL old queues (stable on the
        // deterministic collection order), so a target queue's head is
        // its oldest request and the duty timer — armed from the head's
        // arrival — covers everything behind it.
        backlog.sort_by_key(|&(_, _, arr)| arr);
        for (m, id, arr) in backlog {
            self.route_request(id, m, arr);
        }
    }

    /// Currently installed schedule.
    pub fn schedule(&self) -> &Schedule {
        &self.schedule
    }

    /// Accumulated metrics so far (windowed views via
    /// `Report::snapshot_window`).
    pub fn report(&self) -> &Report {
        &self.report
    }

    /// Requests injected so far, per model (conservation: after `close`,
    /// equals served + dropped per model in the report). Streamed
    /// arrivals count when pulled — a stream's un-pulled future has not
    /// been offered yet.
    pub fn injected_per_model(&self) -> [u64; 5] {
        self.injected
    }

    /// Current virtual time (µs).
    pub fn now_us(&self) -> SimTimeUs {
        self.q.now_us()
    }

    /// High-water mark of simultaneously-live events: heap entries +
    /// armed duty-timer slots + pending source arrivals. With a source
    /// attached (no bulk injection) this is bounded by `#streams +
    /// #assignments + #gpu-lets` — independent of trace length.
    pub fn peak_live_events(&self) -> usize {
        self.peak_live
    }

    /// Total events processed (arrivals, duty-timer fires, heap pops).
    pub fn events_processed(&self) -> u64 {
        self.events_processed
    }

    /// End-of-trace accounting: everything still queued, in flight, or
    /// retired is dropped (and counted). Idempotent; the engine accepts
    /// no further work afterwards. A still-attached source is released
    /// un-pulled: arrivals that never reached the engine were never
    /// offered, so they appear in neither `injected` nor the report.
    pub fn close(&mut self) {
        if self.closed {
            return;
        }
        self.closed = true;
        self.source = None;
        self.chunk.clear();
        self.chunk_pos = 0;
        let now = self.q.now_us();
        for li in 0..self.lets.len() {
            let base = self.asg_base[li];
            for ai in 0..self.schedule.lets[li].assignments.len() {
                let m = self.schedule.lets[li].assignments[ai].model;
                let slo_ms = self.consts[base + ai].slo_ms;
                let pos = self.route_pos[base + ai];
                while let Some((id, _arr)) = self.asgs[base + ai].queue.pop_front() {
                    self.served[m.index()][pos] -= 1.0;
                    self.tracer.span(now, EventKind::Drop, li as u32, m, self.epoch, id);
                    self.report.model_mut(m, slo_ms).record_drop();
                }
            }
            let inflight = std::mem::take(&mut self.lets[li].inflight);
            for (ai, id, _arr) in inflight {
                let m = self.schedule.lets[li].assignments[ai].model;
                let pos = self.route_pos[base + ai];
                self.served[m.index()][pos] -= 1.0;
                self.tracer.span(now, EventKind::Drop, li as u32, m, self.epoch, id);
                self.report.model_mut(m, self.consts[base + ai].slo_ms).record_drop();
            }
        }
        let retired = std::mem::take(&mut self.retired);
        for ((ep, li), completions) in retired {
            for (m, slo_ms, id, _arr) in completions {
                self.tracer.span(now, EventKind::Drop, li as u32, m, ep, id);
                self.report.model_mut(m, slo_ms).record_drop();
            }
        }
        // Injected arrivals whose Arrive event never ran (a caller that
        // closes before running past the trace end) are drops too —
        // conservation must hold for every close point.
        while let Some((_, ev)) = self.q.pop() {
            if let Event::Arrive { model, token } = ev {
                self.tracer.span(now, EventKind::Drop, NO_LET, model, self.epoch, token);
                self.report.model_mut(model, self.lm.slo_ms(model)).record_drop();
            }
        }
    }

    /// Close out and return the final report.
    pub fn finish(mut self) -> Report {
        self.close();
        self.report
    }

    /// Tear the node down as a *failure*: every request queued, in
    /// flight, retired, or pending in the heap dies with the node and
    /// is accounted as `lost_to_failure` — the conservation identity
    /// becomes `offered == served + dropped + shed + lost_to_failure`.
    /// Unlike [`ServingEngine::close`] the engine stays open: the empty
    /// schedule is installed (arrivals routed here while down drop
    /// *counted*, like any unroutable model), the clock does not move,
    /// and a later `swap_schedule` re-admits the node with a real
    /// schedule. The epoch bump makes pre-failure `Done` events
    /// harmless: they find no retired entry and fall through.
    pub fn fail(&mut self) {
        debug_assert!(!self.closed, "fail after finish/close");
        let now = self.q.now_us();
        for li in 0..self.lets.len() {
            let base = self.asg_base[li];
            // In-flight batches die on the failed executor.
            let inflight = std::mem::take(&mut self.lets[li].inflight);
            for (ai, id, _arr) in inflight {
                let m = self.schedule.lets[li].assignments[ai].model;
                self.tracer.batch(now, EventKind::Lost, li as u32, m, self.epoch, id, 1);
                self.report.model_mut(m, self.consts[base + ai].slo_ms).record_lost();
            }
            // Queued backlog: nothing survives to migrate.
            for ai in 0..self.schedule.lets[li].assignments.len() {
                let m = self.schedule.lets[li].assignments[ai].model;
                let slo_ms = self.consts[base + ai].slo_ms;
                let depth = self.asgs[base + ai].queue.len() as u32;
                if depth > 0 {
                    let id0 = self.asgs[base + ai].queue.front().map_or(0, |&(id, _)| id);
                    self.tracer.batch(now, EventKind::Lost, li as u32, m, self.epoch, id0, depth);
                }
                while self.asgs[base + ai].queue.pop_front().is_some() {
                    self.report.model_mut(m, slo_ms).record_lost();
                }
            }
        }
        // Pre-failure retired batches (from earlier swaps) die too.
        let retired = std::mem::take(&mut self.retired);
        for ((ep, li), completions) in retired {
            if let Some(&(m0, _, id0, _)) = completions.first() {
                self.tracer.batch(now, EventKind::Lost, li as u32, m0, ep, id0, completions.len() as u32);
            }
            for (m, slo_ms, _id, _arr) in completions {
                self.report.model_mut(m, slo_ms).record_lost();
            }
        }
        // Bulk-injected arrivals still pending in the heap are destroyed
        // with the node; `Done` events drain with them (their batches
        // were accounted above). The clock must not move — the node
        // keeps lockstepping with the fleet while down.
        let mut heap_lost = [0u32; 5];
        for (_, ev) in self.q.drain_events() {
            if let Event::Arrive { model, .. } = ev {
                heap_lost[model.index()] += 1;
                self.report.model_mut(model, self.lm.slo_ms(model)).record_lost();
            }
        }
        for m in ModelId::ALL {
            if heap_lost[m.index()] > 0 {
                self.tracer.batch(now, EventKind::Lost, NO_LET, m, self.epoch, 0, heap_lost[m.index()]);
            }
        }
        self.epoch += 1;
        self.install_schedule(Schedule::default());
    }

    // ---- internals -------------------------------------------------------

    /// Merged three-way peek: the earliest of (pending source arrival,
    /// armed duty timers, heap head) at or before `t_us`. Simulator
    /// events order among themselves by `(time, seq)` — every arm/push
    /// consumed a ticket from the same counter — and a source arrival
    /// wins exact-time ties against simulator events, reproducing the
    /// bulk-inject order where all `Arrive` seqs preceded every runtime
    /// event's.
    fn next_event(&self, t_us: SimTimeUs) -> Option<NextEvent> {
        let heap = self.q.peek_time_seq_us();
        let timer = self.next_timer();
        let sim = match (heap, timer) {
            (Some((ht, hs)), Some((tt, ts, li, ai))) => {
                if (tt, ts) < (ht, hs) {
                    Some(NextEvent::Timer(tt, li, ai))
                } else {
                    Some(NextEvent::Heap(ht))
                }
            }
            (Some((ht, _)), None) => Some(NextEvent::Heap(ht)),
            (None, Some((tt, _, li, ai))) => Some(NextEvent::Timer(tt, li, ai)),
            (None, None) => None,
        };
        let sim_t = sim.map(|s| match s {
            NextEvent::Arrival(t) | NextEvent::Timer(t, _, _) | NextEvent::Heap(t) => t,
        });
        if let Some(at) = self.arrival_peek_ms() {
            let at = ms_to_us(at);
            if at <= t_us && sim_t.is_none_or(|st| at <= st) {
                return Some(NextEvent::Arrival(at));
            }
        }
        match sim_t {
            Some(st) if st <= t_us => sim,
            _ => None,
        }
    }

    /// Earliest armed duty timer as `(time, seq, let_idx, asg_idx)` —
    /// an O(#assignments) scan over the slots, which is O(active) and
    /// replaces O(log trace) heap churn for every arm/re-arm.
    fn next_timer(&self) -> Option<(SimTimeUs, u64, usize, usize)> {
        let mut best: Option<(SimTimeUs, u64, usize, usize)> = None;
        for (li, &base) in self.asg_base.iter().enumerate() {
            let n = self.schedule.lets[li].assignments.len();
            for ai in 0..n {
                if let Some((t, s)) = self.asgs[base + ai].timer {
                    if best.is_none_or(|(bt, bs, _, _)| (t, s) < (bt, bs)) {
                        best = Some((t, s, li, ai));
                    }
                }
            }
        }
        best
    }

    /// Arm (or re-arm) the duty timer of `(li, ai)` for `at_us`
    /// (clamped to now, like any event push). Overwriting the slot IS
    /// the invalidation of the previously-armed timer.
    fn arm_timer(&mut self, li: usize, ai: usize, at_us: SimTimeUs) {
        let t = at_us.max(self.q.now_us());
        let seq = self.q.alloc_seq();
        let slot = &mut self.asgs[self.asg_base[li] + ai].timer;
        if slot.is_none() {
            self.armed += 1;
        }
        *slot = Some((t, seq));
    }

    /// A duty timer fired: flush the partial batch if the executor is
    /// idle, otherwise check back shortly after the current run.
    fn fire_timer(&mut self, let_idx: usize, asg_idx: usize) {
        if self.asgs[self.asg_base[let_idx] + asg_idx].queue.is_empty() {
            return;
        }
        if !self.lets[let_idx].busy {
            self.try_start(let_idx);
        } else {
            let at = self.q.now_us() + 500;
            self.arm_timer(let_idx, asg_idx, at);
        }
    }

    /// Update the live-event high-water mark (heap + armed timers +
    /// pending source arrivals).
    fn note_live(&mut self) {
        // A nonempty chunk counts as one pending arrival — the same
        // footprint as the single materialized stream it replaced.
        let live = self.q.len()
            + self.armed
            + self.source.as_ref().map_or(0, |s| s.pending_len())
            + usize::from(self.chunk_pos < self.chunk.len());
        self.peak_live = self.peak_live.max(live);
    }

    /// Install `next` as the serving schedule: rebuild routes, queues,
    /// duty constants, and executor state in place (outer buffers keep
    /// their capacity across swaps and probe resets). Queues start
    /// empty — callers migrate any backlog afterwards
    /// (`swap_schedule`).
    fn install_schedule(&mut self, next: Schedule) {
        self.schedule = next;
        for r in &mut self.routes {
            r.clear();
        }
        self.route_pos.clear();
        self.asg_base.clear();
        let mut base = 0usize;
        for (li, lp) in self.schedule.lets.iter().enumerate() {
            self.asg_base.push(base);
            base += lp.assignments.len();
            for (ai, a) in lp.assignments.iter().enumerate() {
                self.routes[a.model.index()].push((li, ai, a.rate));
                self.route_pos.push(self.routes[a.model.index()].len() - 1);
            }
        }
        let total = base;
        // Reuse the arena across installs: carried-over entries keep
        // their VecDeque capacity, only the logical state is wiped.
        self.asgs.truncate(total);
        for a in &mut self.asgs {
            a.queue.clear();
            a.timer = None;
        }
        self.asgs
            .resize_with(total, || AsgState { queue: VecDeque::new(), timer: None });
        let n_lets = self.schedule.lets.len();
        self.lets.truncate(n_lets);
        for l in &mut self.lets {
            l.busy = false;
            l.next_asg = 0;
            l.running = None;
            l.inflight.clear();
        }
        self.lets.resize_with(n_lets, LetState::fresh);
        self.armed = 0;
        // At most one Done per gpu-let is outstanding; pre-reserving
        // keeps steady-state heap pushes growth-free.
        self.q.reserve(n_lets);
        // Per-let duty cycle: the sum of all assignments' planned
        // executions. The batching timeout must leave room for a full
        // duty cycle (the request may queue behind every co-assigned
        // model's slot), not just the model's own execution.
        let lm = self.lm;
        let mode = self.cfg.mode;
        self.consts.clear();
        self.consts.reserve(total);
        for lp in &self.schedule.lets {
            let p_exec = exec_fraction(mode, lp.spec.fraction());
            let duty_us: SimTimeUs = lp
                .assignments
                .iter()
                .map(|a| ms_to_us(lm.latency_ms(a.model, a.batch, p_exec)))
                .sum();
            for a in &lp.assignments {
                let slo_ms = lm.slo_ms(a.model);
                let slo_us = ms_to_us(slo_ms);
                self.consts.push(AsgConst {
                    exec_est_us: ms_to_us(lm.latency_ms(a.model, a.batch, p_exec)),
                    slo_us,
                    timeout_us: super::batcher::slo_timeout_us(slo_us, duty_us),
                    slo_ms,
                });
            }
        }
        let num_gpus = self.schedule.lets.iter().map(|l| l.spec.gpu + 1).max().unwrap_or(0);
        for (s, r) in self.served.iter_mut().zip(self.routes.iter()) {
            s.clear();
            s.resize(r.len(), 0.0);
        }
        self.gpu_busy.clear();
        self.gpu_busy.resize(num_gpus, false);
        self.gpu_waiters.clear();
        self.gpu_waiters.resize_with(num_gpus, VecDeque::new);
    }

    // lint: no-alloc — completion handling, routing and batch start are
    // the steady-state serving path: batches rotate through the
    // capacity-preserved scratch/inflight buffers and queues reuse
    // their slots (the engine_scale bench pins the events/s this buys).
    fn handle(&mut self, now: SimTimeUs, ev: Event) {
        match ev {
            Event::Arrive { model, token } => {
                self.tracer.span(now, EventKind::Arrival, NO_LET, model, self.epoch, token);
                self.route_request(token, model, now);
            }
            Event::Done { epoch, let_idx } => {
                if epoch != self.epoch {
                    // A pre-swap execution finishing under the old
                    // schedule's constants.
                    if let Some(completions) = self.retired.remove(&(epoch, let_idx)) {
                        if let Some(&(m0, _, id0, _)) = completions.first() {
                            self.tracer.batch(now, EventKind::BatchDone, let_idx as u32, m0, epoch, id0, completions.len() as u32);
                        }
                        for (m, slo_ms, id, arr) in completions {
                            self.record_completion(id, m, slo_ms, arr, now);
                        }
                    }
                    return;
                }
                let gpu = self.schedule.lets[let_idx].spec.gpu;
                // Rotate the batch through the scratch buffer: both Vecs
                // keep their capacity, so completing a batch (and
                // forming the next one in the emptied buffer) is
                // allocation-free in steady state.
                let mut done = std::mem::take(&mut self.done_scratch);
                std::mem::swap(&mut done, &mut self.lets[let_idx].inflight);
                let base = self.asg_base[let_idx];
                if let Some(&(ai0, id0, _)) = done.first() {
                    let m0 = self.schedule.lets[let_idx].assignments[ai0].model;
                    self.tracer.batch(now, EventKind::BatchDone, let_idx as u32, m0, epoch, id0, done.len() as u32);
                }
                for &(ai, id, arr) in &done {
                    let m = self.schedule.lets[let_idx].assignments[ai].model;
                    let slo_ms = self.consts[base + ai].slo_ms;
                    self.record_completion(id, m, slo_ms, arr, now);
                }
                done.clear();
                self.done_scratch = done;
                self.lets[let_idx].busy = false;
                self.lets[let_idx].running = None;
                if self.cfg.mode == ShareMode::TemporalOnly {
                    self.gpu_busy[gpu] = false;
                    if let Some(waiter) = self.gpu_waiters[gpu].pop_front() {
                        self.try_start(waiter);
                    }
                }
                // Keep draining this let's own queues.
                if !self.lets[let_idx].busy {
                    self.try_start(let_idx);
                }
            }
        }
    }

    fn record_completion(
        &mut self,
        id: u64,
        m: ModelId,
        slo_ms: f64,
        arrival_us: SimTimeUs,
        now: SimTimeUs,
    ) {
        if cfg!(debug_assertions) {
            assert!(self.served_ids.insert(id), "request {id} served twice");
        }
        self.report.model_mut(m, slo_ms).record(us_to_ms(now - arrival_us));
    }

    /// Deficit-weighted routing of one request (fresh arrival or
    /// migrated backlog entry): pick the route with the least in-system
    /// work relative to its planned share, enqueue, and kick off a batch
    /// or arm the duty timer. Requests for models with no route are
    /// dropped (and counted).
    fn route_request(&mut self, id: u64, model: ModelId, arrival_us: SimTimeUs) {
        let m_idx = model.index();
        if self.routes[m_idx].is_empty() {
            self.tracer.span(self.q.now_us(), EventKind::Drop, NO_LET, model, self.epoch, id);
            self.report.model_mut(model, self.lm.slo_ms(model)).record_drop();
            return;
        }
        let (pos, li, ai) = {
            let options = &self.routes[m_idx];
            let served = &self.served[m_idx];
            let (pos, &(li, ai, _w)) = options
                .iter()
                .enumerate()
                .min_by(|(i1, r1), (i2, r2)| {
                    let k1 = served[*i1] / r1.2.max(1e-9);
                    let k2 = served[*i2] / r2.2.max(1e-9);
                    k1.total_cmp(&k2)
                })
                .expect("non-empty routes");
            (pos, li, ai)
        };
        self.served[m_idx][pos] += 1.0;
        let aid = self.asg_base[li] + ai;
        self.asgs[aid].queue.push_back((id, arrival_us));
        self.tracer.span(self.q.now_us(), EventKind::Enqueue, li as u32, model, self.epoch, id);
        let b_target = self.schedule.lets[li].assignments[ai].batch as usize;
        if !self.lets[li].busy && self.asgs[aid].queue.len() >= b_target {
            self.try_start(li);
        } else if self.asgs[aid].queue.len() == 1 {
            // Arm the duty timeout for the queue head (absolute, so a
            // migrated head keeps only its remaining allowance).
            let at = arrival_us + self.consts[aid].timeout_us;
            self.arm_timer(li, ai, at);
        }
    }

    /// Try to start the next batch on `let_idx` (must be idle). Picks
    /// the next nonempty assignment round-robin, forms the batch,
    /// accounts drops, computes the (interfered) execution time, and
    /// schedules Done.
    fn try_start(&mut self, let_idx: usize) {
        if self.lets[let_idx].busy {
            return;
        }
        let now = self.q.now_us();
        let n_asgs = self.schedule.lets[let_idx].assignments.len();
        let base = self.asg_base[let_idx];

        // Pick next assignment with work, starting from the round-robin
        // pointer.
        let mut chosen: Option<usize> = None;
        for k in 0..n_asgs {
            let ai = (self.lets[let_idx].next_asg + k) % n_asgs;
            let model = self.schedule.lets[let_idx].assignments[ai].model;
            let batch = self.schedule.lets[let_idx].assignments[ai].batch;
            let AsgConst { exec_est_us, slo_us, timeout_us, slo_ms } =
                self.consts[base + ai];
            // Drop hopeless heads first: even starting right now, the
            // request would finish past its SLO.
            let epoch = self.epoch;
            let tracer = &mut self.tracer;
            let st = &mut self.asgs[base + ai];
            let before = st.queue.len();
            st.queue.retain(|&(id, arr)| {
                let keep = now + exec_est_us <= arr + slo_us;
                if !keep {
                    tracer.span(now, EventKind::Timeout, let_idx as u32, model, epoch, id);
                }
                keep
            });
            let dropped = before - st.queue.len();
            if dropped > 0 {
                // Dropped work no longer counts against the route.
                let pos = self.route_pos[base + ai];
                self.served[model.index()][pos] -= dropped as f64;
                for _ in 0..dropped {
                    self.report.model_mut(model, slo_ms).record_drop();
                }
            }
            let st = &self.asgs[base + ai];
            if !st.queue.is_empty() {
                let full = st.queue.len() >= batch as usize;
                let head_arr = st.queue.front().expect("nonempty queue").1;
                if full || now - head_arr >= timeout_us {
                    chosen = Some(ai);
                    break;
                }
                // Not ready: make sure a timer exists.
                self.arm_timer(let_idx, ai, head_arr + timeout_us);
            }
        }
        let Some(ai) = chosen else { return };

        let gpu = self.schedule.lets[let_idx].spec.gpu;
        if self.cfg.mode == ShareMode::TemporalOnly {
            if self.gpu_busy[gpu] {
                if !self.gpu_waiters[gpu].contains(&let_idx) {
                    self.gpu_waiters[gpu].push_back(let_idx);
                }
                return;
            }
            self.gpu_busy[gpu] = true;
        }

        let model = self.schedule.lets[let_idx].assignments[ai].model;
        let b_planned = self.schedule.lets[let_idx].assignments[ai].batch;
        let b_actual = (self.asgs[base + ai].queue.len() as u32).min(b_planned).max(1);
        // Form the batch in place: the inflight buffer was drained (and
        // capacity-preserved) at the last Done's scratch rotation, so
        // this is a no-allocation push in steady state.
        debug_assert!(self.lets[let_idx].inflight.is_empty());
        for _ in 0..b_actual {
            let (id, arr) =
                self.asgs[base + ai].queue.pop_front().expect("batch underflow");
            self.lets[let_idx].inflight.push((ai, id, arr));
        }
        if let Some(&(_, id0, _)) = self.lets[let_idx].inflight.first() {
            self.tracer.batch(now, EventKind::BatchForm, let_idx as u32, model, self.epoch, id0, b_actual);
            self.tracer.batch(now, EventKind::BatchStart, let_idx as u32, model, self.epoch, id0, b_actual);
        }

        let p_me = self.schedule.lets[let_idx].spec.fraction();
        let p_exec = exec_fraction(self.cfg.mode, p_me);
        let mut exec = self.lm.latency_ms(model, b_actual, p_exec);

        // Interference with the co-resident let (concurrent modes only).
        if self.cfg.mode != ShareMode::TemporalOnly {
            if let Some((co_idx, (co_ai, co_b))) = self.co_resident_running(let_idx) {
                let co_model = self.schedule.lets[co_idx].assignments[co_ai].model;
                let p_co = self.schedule.lets[co_idx].spec.fraction();
                let my_prof = profile(model);
                let co_prof = profile(co_model);
                let me = TaskDemand {
                    model,
                    batch: b_actual,
                    l2: my_prof.l2_util(p_me, b_actual),
                    bw: my_prof.bw_util(p_me, b_actual),
                };
                let other = TaskDemand {
                    model: co_model,
                    batch: co_b,
                    l2: co_prof.l2_util(p_co, co_b),
                    bw: co_prof.bw_util(p_co, co_b),
                };
                let base =
                    self.gt.factor(&me, &other) * self.cfg.mode.contention_amplification();
                let vol = self.cfg.mode.contention_volatility();
                let factor = (base * (1.0 + self.rng.normal(0.0, vol))).max(0.0);
                exec *= 1.0 + factor;
            }
        }

        self.lets[let_idx].busy = true;
        self.lets[let_idx].running = Some((ai, b_actual));
        self.lets[let_idx].next_asg = (ai + 1) % n_asgs;
        self.q.push_after_us(
            ms_to_us(exec),
            Event::Done { epoch: self.epoch, let_idx },
        );
    }
    // lint: end-no-alloc

    /// The co-resident gpu-let currently executing, if any.
    fn co_resident_running(&self, let_idx: usize) -> Option<(usize, (usize, u32))> {
        let gpu = self.schedule.lets[let_idx].spec.gpu;
        self.schedule
            .lets
            .iter()
            .enumerate()
            .filter(|(i, lp)| *i != let_idx && lp.spec.gpu == gpu)
            .find_map(|(i, _)| self.lets[i].running.map(|r| (i, r)))
    }
}

// The fleet tier advances per-node engines from worker threads
// (`util::par::par_for_each_mut`), which requires `ServingEngine: Send`.
// The `'a` borrows (`LatencyModel`'s profile tables, `GroundTruth`'s
// interference factors) are plain-data structs with no interior
// mutability — hence `Sync` — and every owned field is `Send`. Pinned
// at compile time so a future `Cell`/`Rc` regression fails the build:
const _: () = {
    const fn assert_send<T: Send>() {}
    const fn assert_sync<T: Sync>() {}
    assert_send::<ServingEngine<'static>>();
    assert_sync::<LatencyModel>();
    assert_sync::<GroundTruth>();
};

/// Effective execution fraction under a sharing mode: without static
/// provisioning (MPS default / temporal) a kernel sees the whole GPU.
fn exec_fraction(mode: ShareMode, nominal: f64) -> f64 {
    match mode {
        ShareMode::Partitioned => nominal,
        ShareMode::MpsDefault | ShareMode::TemporalOnly => 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::gpulet::GpuLetSpec;
    use crate::sched::types::{Assignment, LetPlan};
    use crate::sched::{ElasticPartitioning, SchedCtx, Scheduler};
    use crate::workload::{dyn_sources, generate_arrivals, poisson_streams, SourceMux};

    fn world() -> (LatencyModel, GroundTruth) {
        (LatencyModel::new(), GroundTruth::default())
    }

    fn sched_for(rates: &[f64; 5], gpus: usize) -> Schedule {
        let ctx = SchedCtx::new(gpus, None);
        ElasticPartitioning::gpulet().schedule(&ctx, rates).unwrap()
    }

    fn horizon_us(arrivals: &[Arrival], cfg: &SimConfig) -> SimTimeUs {
        arrivals.last().map(|a| ms_to_us(a.time_ms)).unwrap_or(0)
            + ms_to_us(cfg.drain_ms)
    }

    fn conserved(eng: &ServingEngine<'_>) {
        let injected = eng.injected_per_model();
        for m in ModelId::ALL {
            let total = eng.report().model(m).map_or(0, |mm| mm.total());
            assert_eq!(
                total,
                injected[m.index()],
                "{m}: {total} accounted vs {} injected",
                injected[m.index()]
            );
        }
    }

    #[test]
    fn empty_schedule_drops_everything() {
        let (lm, gt) = world();
        let cfg = SimConfig::default();
        let arrivals =
            generate_arrivals(&[(ModelId::Lenet, 50.0)], 2.0, 3).unwrap();
        let mut eng =
            ServingEngine::new(&lm, &gt, Schedule::default(), 2.0, &cfg);
        eng.inject(&arrivals);
        eng.run_until(horizon_us(&arrivals, &cfg));
        eng.close();
        conserved(&eng);
        let mm = eng.report().model(ModelId::Lenet).unwrap();
        assert_eq!(mm.served, 0);
        assert_eq!(mm.dropped as usize, arrivals.len());
    }

    #[test]
    fn early_close_counts_unprocessed_arrivals_as_drops() {
        // A caller may close before running past the trace end: the
        // Arrive events still pending in the queue must be counted.
        let (lm, gt) = world();
        let cfg = SimConfig::default();
        let schedule = sched_for(&[50.0, 0.0, 0.0, 0.0, 0.0], 1);
        let arrivals =
            generate_arrivals(&[(ModelId::Lenet, 50.0)], 10.0, 4).unwrap();
        let mut eng = ServingEngine::new(&lm, &gt, schedule, 10.0, &cfg);
        eng.inject(&arrivals);
        eng.run_until(ms_to_us(2_000.0)); // well before the last arrival
        eng.close();
        conserved(&eng);
        let mm = eng.report().model(ModelId::Lenet).unwrap();
        assert!(mm.dropped > 0, "tail arrivals must be counted as drops");
    }

    #[test]
    fn swap_to_same_layout_conserves_and_keeps_serving() {
        let (lm, gt) = world();
        let cfg = SimConfig::default();
        let rates = [80.0, 0.0, 0.0, 0.0, 40.0];
        let schedule = sched_for(&rates, 2);
        let arrivals = generate_arrivals(
            &[(ModelId::Lenet, 80.0), (ModelId::Vgg, 40.0)],
            10.0,
            9,
        )
        .unwrap();
        let mut eng = ServingEngine::new(&lm, &gt, schedule.clone(), 10.0, &cfg);
        eng.inject(&arrivals);
        // Three mid-trace hot swaps onto a clone of the same schedule.
        for k in 1..=3u64 {
            eng.run_until(ms_to_us(2_500.0 * k as f64));
            eng.swap_schedule(schedule.clone(), SwapMode::Migrate);
        }
        eng.run_until(horizon_us(&arrivals, &cfg));
        eng.close();
        conserved(&eng);
        let served: u64 = [ModelId::Lenet, ModelId::Vgg]
            .iter()
            .map(|&m| eng.report().model(m).map_or(0, |mm| mm.served))
            .sum();
        assert!(
            served as f64 > 0.95 * arrivals.len() as f64,
            "served {served}/{}",
            arrivals.len()
        );
    }

    #[test]
    fn model_losing_all_routes_drops_backlog_counted() {
        let (lm, gt) = world();
        let cfg = SimConfig::default();
        // VGG-only schedule, then swap to a LeNet-only schedule while
        // VGG work is queued and in flight.
        let vgg = sched_for(&[0.0, 0.0, 0.0, 0.0, 50.0], 1);
        let lenet = sched_for(&[50.0, 0.0, 0.0, 0.0, 0.0], 1);
        let arrivals =
            generate_arrivals(&[(ModelId::Vgg, 80.0)], 4.0, 5).unwrap();
        let mut eng = ServingEngine::new(&lm, &gt, vgg, 4.0, &cfg);
        eng.inject(&arrivals);
        eng.run_until(ms_to_us(2_000.0));
        eng.swap_schedule(lenet, SwapMode::Migrate);
        eng.run_until(horizon_us(&arrivals, &cfg));
        eng.close();
        conserved(&eng);
        let mm = eng.report().model(ModelId::Vgg).unwrap();
        // Arrivals after the swap and the migrated backlog all drop;
        // anything served completed before or across the boundary.
        assert!(mm.dropped > 0, "backlog must be dropped, not lost");
        assert!(mm.served > 0, "pre-swap work should have been served");
    }

    #[test]
    fn inflight_finishes_under_old_constants_after_swap() {
        let (lm, gt) = world();
        let cfg = SimConfig::default();
        let vgg = sched_for(&[0.0, 0.0, 0.0, 0.0, 50.0], 1);
        let lenet = sched_for(&[50.0, 0.0, 0.0, 0.0, 0.0], 1);
        // A single burst that is in flight when the swap hits: VGG@100%
        // takes tens of ms per batch, so swap at 5 ms mid-execution.
        let burst: Vec<Arrival> = (0..4)
            .map(|i| Arrival { time_ms: 0.1 * i as f64, model: ModelId::Vgg, id: i })
            .collect();
        let mut eng = ServingEngine::new(&lm, &gt, vgg, 1.0, &cfg);
        eng.inject(&burst);
        eng.run_until(ms_to_us(5.0));
        let busy = eng.lets.iter().any(|l| l.busy);
        assert!(busy, "a VGG batch must be executing at t=5ms");
        eng.swap_schedule(lenet, SwapMode::Migrate);
        assert!(!eng.retired.is_empty(), "in-flight batch must be retired");
        eng.run_until(ms_to_us(2_000.0));
        eng.close();
        conserved(&eng);
        let mm = eng.report().model(ModelId::Vgg).unwrap();
        assert!(mm.served > 0, "retired execution must complete and count");
    }

    #[test]
    fn drop_queued_mode_drops_backlog() {
        let (lm, gt) = world();
        let cfg = SimConfig::default();
        let schedule = sched_for(&[0.0, 0.0, 0.0, 0.0, 50.0], 1);
        let arrivals =
            generate_arrivals(&[(ModelId::Vgg, 200.0)], 3.0, 6).unwrap();
        let mut eng = ServingEngine::new(&lm, &gt, schedule.clone(), 3.0, &cfg);
        eng.inject(&arrivals);
        eng.run_until(ms_to_us(1_500.0));
        eng.swap_schedule(schedule.clone(), SwapMode::DropQueued);
        eng.run_until(horizon_us(&arrivals, &cfg));
        eng.close();
        conserved(&eng);
    }

    #[test]
    fn route_counters_track_in_system_work_not_drops() {
        // Satellite regression: deficit counters are decremented when a
        // queued request is dropped, so after close() they equal exactly
        // the served count — under the old enqueue-only accounting they
        // equaled served + dropped and overload drops skewed routing.
        let (lm, gt) = world();
        let cfg = SimConfig::default();
        // Two routes for LeNet with equal weights via a hand-built
        // schedule, overloaded 4x so hopeless-head drops occur.
        let schedule = Schedule {
            lets: vec![
                LetPlan {
                    spec: GpuLetSpec { gpu: 0, size_pct: 20 },
                    assignments: vec![Assignment {
                        model: ModelId::Lenet,
                        batch: 8,
                        rate: 300.0,
                    }],
                },
                LetPlan {
                    spec: GpuLetSpec { gpu: 1, size_pct: 20 },
                    assignments: vec![Assignment {
                        model: ModelId::Lenet,
                        batch: 8,
                        rate: 300.0,
                    }],
                },
            ],
        };
        let arrivals =
            generate_arrivals(&[(ModelId::Lenet, 2400.0)], 3.0, 8).unwrap();
        let mut eng = ServingEngine::new(&lm, &gt, schedule, 3.0, &cfg);
        eng.inject(&arrivals);
        eng.run_until(horizon_us(&arrivals, &cfg));
        eng.close();
        conserved(&eng);
        let mm = eng.report().model(ModelId::Lenet).unwrap();
        assert!(mm.dropped > 0, "overload must drop");
        let counter_total: f64 = eng.served.iter().flatten().sum();
        assert_eq!(
            counter_total as u64, mm.served,
            "route counters must equal served work exactly (drops decremented)"
        );
    }

    #[test]
    fn stepped_run_until_matches_one_shot() {
        let (lm, gt) = world();
        let cfg = SimConfig::default();
        let rates = [60.0, 0.0, 0.0, 0.0, 30.0];
        let schedule = sched_for(&rates, 2);
        let arrivals = generate_arrivals(
            &[(ModelId::Lenet, 60.0), (ModelId::Vgg, 30.0)],
            6.0,
            13,
        )
        .unwrap();
        let horizon = horizon_us(&arrivals, &cfg);

        let mut one = ServingEngine::new(&lm, &gt, schedule.clone(), 6.0, &cfg);
        one.inject(&arrivals);
        one.run_until(horizon);
        let r_one = one.finish();

        // Split injection + 250 ms stepping must be byte-identical.
        let mut stepped = ServingEngine::new(&lm, &gt, schedule, 6.0, &cfg);
        let (a, b) = arrivals.split_at(arrivals.len() / 2);
        stepped.inject(a);
        stepped.inject(b);
        let mut t = 0;
        while t < horizon {
            t = (t + 250_000).min(horizon);
            stepped.run_until(t);
        }
        let r_stepped = stepped.finish();
        assert_eq!(r_one.to_json().to_string(), r_stepped.to_json().to_string());
    }

    #[test]
    fn streamed_source_conserves_and_bounds_live_events() {
        let (lm, gt) = world();
        let cfg = SimConfig::default();
        let rates = [80.0, 0.0, 0.0, 0.0, 40.0];
        let schedule = sched_for(&rates, 2);
        let pairs = [(ModelId::Lenet, 80.0), (ModelId::Vgg, 40.0)];
        let streams = poisson_streams(&pairs, 10.0, 21).unwrap();
        let n_streams = streams.len();
        let mut eng = ServingEngine::new(&lm, &gt, schedule.clone(), 10.0, &cfg);
        eng.attach_source(SourceMux::new(dyn_sources(streams)));
        eng.run_stream();
        eng.close();
        conserved(&eng);
        let total: u64 = eng.injected_per_model().iter().sum();
        assert!(total > 1_000, "streamed load must be real, got {total}");
        // Structural O(active) bound: heap Dones (<= #lets) + timer
        // slots (<= #assignments) + pending arrivals (<= #streams).
        let asgs: usize = schedule.lets.iter().map(|l| l.assignments.len()).sum();
        let bound = n_streams + asgs + schedule.lets.len();
        assert!(
            eng.peak_live_events() <= bound,
            "peak live events {} exceeds structural bound {bound}",
            eng.peak_live_events()
        );
        assert!(eng.events_processed() >= total);
    }

    #[test]
    fn chunk_path_matches_of_trace_source_byte_identically() {
        // `attach_chunk` is advertised as behaviorally equivalent to
        // `attach_source(of_trace(..))` — pin that: same report, same
        // event count, and a peak-live footprint no larger than the
        // single-stream source path's.
        let (lm, gt) = world();
        let cfg = SimConfig::default();
        let rates = [80.0, 0.0, 0.0, 0.0, 40.0];
        let schedule = sched_for(&rates, 2);
        let arrivals = generate_arrivals(
            &[(ModelId::Lenet, 80.0), (ModelId::Vgg, 40.0)],
            6.0,
            17,
        )
        .unwrap();
        let horizon = horizon_us(&arrivals, &cfg);

        let mut src = ServingEngine::new(&lm, &gt, schedule.clone(), 6.0, &cfg);
        src.attach_source(SourceMux::of_trace(arrivals.clone()));
        src.run_until(horizon);
        let src_events = src.events_processed();
        let src_peak = src.peak_live_events();
        let r_src = src.finish();

        // Feed the same arrivals as 500 ms lockstep chunks, recycling
        // one buffer exactly like the fleet's advance does.
        let mut chk = ServingEngine::new(&lm, &gt, schedule, 6.0, &cfg);
        let mut buf: Vec<Arrival> = Vec::new();
        let mut i = 0;
        let mut t = 0;
        while t < horizon {
            t = (t + 500_000).min(horizon);
            buf.clear();
            while i < arrivals.len() && ms_to_us(arrivals[i].time_ms) <= t {
                buf.push(arrivals[i]);
                i += 1;
            }
            buf = chk.attach_chunk(buf);
            chk.run_until(t);
        }
        assert_eq!(chk.events_processed(), src_events);
        assert!(
            chk.peak_live_events() <= src_peak,
            "chunk path peak {} must not exceed source path peak {src_peak}",
            chk.peak_live_events()
        );
        let r_chk = chk.finish();
        assert_eq!(r_src.to_json().to_string(), r_chk.to_json().to_string());
    }

    #[test]
    fn reset_reproduces_a_fresh_engine_exactly() {
        let (lm, gt) = world();
        let cfg = SimConfig::default();
        let rates = [60.0, 0.0, 0.0, 0.0, 30.0];
        let schedule = sched_for(&rates, 2);
        let pairs = [(ModelId::Lenet, 60.0), (ModelId::Vgg, 30.0)];

        let run = |eng: &mut ServingEngine<'_>| {
            eng.attach_source(SourceMux::new(dyn_sources(
                poisson_streams(&pairs, 5.0, 33).unwrap(),
            )));
            eng.run_stream();
            eng.close();
            eng.report().to_json().to_string()
        };

        let mut fresh = ServingEngine::new(&lm, &gt, schedule.clone(), 5.0, &cfg);
        let r_fresh = run(&mut fresh);

        // Dirty an engine with a different run, then reset it: the
        // probe loop in `max_achievable_detail` depends on this being
        // indistinguishable from a new engine.
        let mut reused = ServingEngine::new(
            &lm,
            &gt,
            sched_for(&[40.0, 0.0, 0.0, 0.0, 0.0], 1),
            3.0,
            &cfg,
        );
        reused.attach_source(SourceMux::new(dyn_sources(
            poisson_streams(&[(ModelId::Lenet, 40.0)], 3.0, 7).unwrap(),
        )));
        reused.run_stream();
        reused.close();
        reused.reset(schedule, 5.0);
        let r_reused = run(&mut reused);
        assert_eq!(r_fresh, r_reused, "reset engine must be byte-identical to fresh");
    }

    #[test]
    fn shared_let_timeout_constants_use_the_summed_duty_cycle() {
        // White-box pin of the space-time contract at install_schedule:
        // a two-assignment let's batching timeout must leave room for
        // the whole duty cycle (own execution plus every co-tenant's
        // slot), i.e. `slo_timeout_us(slo, E_g + E_v)` — never the
        // assignment's solo execution. Interference is deliberately
        // absent from the constants: it is applied stochastically at
        // execution time.
        use crate::coordinator::batcher::slo_timeout_us;
        let (lm, gt) = world();
        let cfg = SimConfig::default();
        let mk = |assignments: Vec<Assignment>| Schedule {
            lets: vec![LetPlan {
                spec: GpuLetSpec { gpu: 0, size_pct: 100 },
                assignments,
            }],
        };
        let g = Assignment { model: ModelId::Googlenet, batch: 4, rate: 20.0 };
        let v = Assignment { model: ModelId::Vgg, batch: 2, rate: 10.0 };
        let shared = ServingEngine::new(&lm, &gt, mk(vec![g, v]), 1.0, &cfg);
        let solo = ServingEngine::new(&lm, &gt, mk(vec![v]), 1.0, &cfg);

        let p = exec_fraction(cfg.mode, 1.0);
        let e_g = ms_to_us(lm.latency_ms(ModelId::Googlenet, 4, p));
        let e_v = ms_to_us(lm.latency_ms(ModelId::Vgg, 2, p));
        let duty = e_g + e_v;
        let slo_g = ms_to_us(lm.slo_ms(ModelId::Googlenet));
        let slo_v = ms_to_us(lm.slo_ms(ModelId::Vgg));

        // Both co-tenants' timeouts are armed from the summed duty...
        // (the consts arena is flat, let-major: ids 0 and 1 here).
        assert_eq!(shared.consts[0].timeout_us, slo_timeout_us(slo_g, duty));
        assert_eq!(shared.consts[1].timeout_us, slo_timeout_us(slo_v, duty));
        // ...while the execution estimate stays per-assignment.
        assert_eq!(shared.consts[0].exec_est_us, e_g);
        assert_eq!(shared.consts[1].exec_est_us, e_v);
        // And the shared timeout is strictly tighter than the same
        // assignment's solo timeout: the co-tenant's slot comes out of
        // the allowable batching wait.
        assert_eq!(solo.consts[0].timeout_us, slo_timeout_us(slo_v, e_v));
        assert!(
            shared.consts[1].timeout_us < solo.consts[0].timeout_us,
            "shared timeout {} must be < solo timeout {}",
            shared.consts[1].timeout_us,
            solo.consts[0].timeout_us
        );
    }
}
