//! Discrete-event serving simulator: executes a `Schedule` against an
//! arrival trace under one of the three GPU sharing modes (Fig 2/5) and
//! reports per-model SLO metrics.
//!
//! Semantics per `ShareMode`:
//! * `Partitioned` — each gpu-let executes concurrently at its own
//!   fraction; when the co-resident gpu-let is mid-execution, the
//!   ground-truth interference stretches this execution (plus a small
//!   volatility term).
//! * `MpsDefault` — no static provisioning: every execution sees the
//!   whole GPU when alone, but overlapping executions contend hard and
//!   volatilely (amplified ground-truth factor).
//! * `TemporalOnly` — executions serialize on the physical GPU (whole-
//!   GPU kernels, coarse-grained switches): a busy GPU queues the next
//!   batch, at full-GPU latency.
//!
//! The frontend logic mirrors `batcher`: per-(let, model) FIFO queues,
//! dispatch on batch-full or duty timeout, hopeless requests dropped
//! and counted as violations.
//!
//! Time runs on the integer-microsecond `simclock` (exact deadline
//! compares, no f64 heap ordering); the per-assignment execution
//! estimates, SLO bounds, and duty timeouts are converted to µs once at
//! simulation start instead of being re-derived per event.

use std::collections::VecDeque;

use crate::gpu::ShareMode;
use crate::interference::ground_truth::{GroundTruth, TaskDemand};
use crate::metrics::Report;
use crate::models::profile;
use crate::perfmodel::LatencyModel;
use crate::sched::Schedule;
use crate::simclock::{ms_to_us, us_to_ms, EventQueue};
use crate::util::rng::Pcg32;
use crate::workload::Arrival;

/// Simulation parameters.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub mode: ShareMode,
    pub seed: u64,
    /// Extra wall time after the last arrival to drain queues (ms).
    pub drain_ms: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { mode: ShareMode::Partitioned, seed: 0xD15C0, drain_ms: 2_000.0 }
    }
}

#[derive(Clone, Copy, Debug)]
enum Event {
    Arrive(usize),
    /// Duty timeout for (let, assignment): flush a partial batch.
    Timeout { let_idx: usize, asg_idx: usize, armed_at: u64 },
    /// Execution finished on a gpu-let.
    Done { let_idx: usize },
}

struct AsgState {
    queue: VecDeque<(u64, u64)>, // (req id, arrival µs)
    /// Monotone token invalidating stale Timeout events.
    timer_token: u64,
}

/// Precomputed per-assignment constants (µs domain), flat-indexed in
/// parallel with the schedule's assignments.
struct AsgConst {
    /// Planned-batch execution estimate at the effective fraction.
    exec_est_us: u64,
    /// SLO bound.
    slo_us: u64,
    /// Duty timeout (`batcher::slo_timeout_us` over the let's cycle).
    timeout_us: u64,
    /// True SLO in ms for metrics keying.
    slo_ms: f64,
}

struct LetState {
    /// Parallel to the schedule's assignments.
    asgs: Vec<AsgState>,
    busy: bool,
    /// Round-robin pointer over assignments.
    next_asg: usize,
    /// Model/batch/fraction of the in-flight execution (for interference).
    running: Option<(usize, u32)>, // (asg_idx, actual batch)
    /// In-flight requests: (asg_idx, completions at Done)
    inflight: Vec<(usize, u64, u64)>, // (asg_idx, id, arrival µs)
}

/// Simulate `schedule` over `arrivals`; `window_s` is the measurement
/// window for throughput (usually the trace duration).
pub fn simulate(
    lm: &LatencyModel,
    gt: &GroundTruth,
    schedule: &Schedule,
    arrivals: &[Arrival],
    window_s: f64,
    cfg: &SimConfig,
) -> Report {
    let mut report = Report::new(window_s);
    let mut rng = Pcg32::seeded(cfg.seed);

    // Routing table: model index -> [(let_idx, asg_idx, weight)].
    let mut routes: Vec<Vec<(usize, usize, f64)>> = vec![Vec::new(); 5];
    for (li, lp) in schedule.lets.iter().enumerate() {
        for (ai, a) in lp.assignments.iter().enumerate() {
            routes[a.model.index()].push((li, ai, a.rate));
        }
    }
    // Per-route served counters for deficit-weighted routing.
    let mut served: Vec<Vec<f64>> = routes.iter().map(|r| vec![0.0; r.len()]).collect();

    let mut lets: Vec<LetState> = schedule
        .lets
        .iter()
        .map(|lp| LetState {
            asgs: lp
                .assignments
                .iter()
                .map(|_| AsgState { queue: VecDeque::new(), timer_token: 0 })
                .collect(),
            busy: false,
            next_asg: 0,
            running: None,
            inflight: Vec::new(),
        })
        .collect();

    // Per-let duty cycle: the sum of all assignments' planned
    // executions. The batching timeout must leave room for a full duty
    // cycle (the request may queue behind every co-assigned model's
    // slot), not just the model's own execution. All per-assignment
    // constants are derived once here, in µs.
    let consts: Vec<Vec<AsgConst>> = schedule
        .lets
        .iter()
        .map(|lp| {
            let p_exec = exec_fraction(cfg.mode, lp.spec.fraction());
            let duty_us: u64 = lp
                .assignments
                .iter()
                .map(|a| ms_to_us(lm.latency_ms(a.model, a.batch, p_exec)))
                .sum();
            lp.assignments
                .iter()
                .map(|a| {
                    let slo_ms = lm.slo_ms(a.model);
                    let slo_us = ms_to_us(slo_ms);
                    AsgConst {
                        exec_est_us: ms_to_us(lm.latency_ms(a.model, a.batch, p_exec)),
                        slo_us,
                        timeout_us: super::batcher::slo_timeout_us(slo_us, duty_us),
                        slo_ms,
                    }
                })
                .collect()
        })
        .collect();

    // Per-GPU serialization for TemporalOnly: FIFO of lets waiting to run.
    let num_gpus = schedule.lets.iter().map(|l| l.spec.gpu + 1).max().unwrap_or(0);
    let mut gpu_busy: Vec<bool> = vec![false; num_gpus];
    let mut gpu_waiters: Vec<VecDeque<usize>> = vec![VecDeque::new(); num_gpus];

    let mut q: EventQueue<Event> = EventQueue::new();
    let arr_us: Vec<u64> = arrivals.iter().map(|a| ms_to_us(a.time_ms)).collect();
    for (i, &t) in arr_us.iter().enumerate() {
        q.push_at_us(t, Event::Arrive(i));
    }
    let horizon = arr_us.last().copied().unwrap_or(0) + ms_to_us(cfg.drain_ms);

    while let Some((now, ev)) = q.pop() {
        if now > horizon {
            break;
        }
        match ev {
            Event::Arrive(i) => {
                let a = &arrivals[i];
                let m = a.model;
                let options = &routes[m.index()];
                if options.is_empty() {
                    // Model not scheduled at all: immediate drop.
                    report.model_mut(m, lm.slo_ms(m)).record_drop();
                    continue;
                }
                // Deficit-weighted route: least served relative to weight.
                let (pos, &(li, ai, w)) = options
                    .iter()
                    .enumerate()
                    .min_by(|(i1, r1), (i2, r2)| {
                        let k1 = served[m.index()][*i1] / r1.2.max(1e-9);
                        let k2 = served[m.index()][*i2] / r2.2.max(1e-9);
                        k1.total_cmp(&k2)
                    })
                    .unwrap();
                let _ = w;
                served[m.index()][pos] += 1.0;
                lets[li].asgs[ai].queue.push_back((a.id, now));
                let b_target = schedule.lets[li].assignments[ai].batch as usize;
                if !lets[li].busy && lets[li].asgs[ai].queue.len() >= b_target {
                    try_start(
                        li, lm, gt, schedule, &consts, &mut lets, &mut gpu_busy,
                        &mut gpu_waiters, &mut q, cfg, &mut rng, &mut report,
                    );
                } else if lets[li].asgs[ai].queue.len() == 1 {
                    // Arm the duty timeout for the queue head.
                    let token = {
                        let st = &mut lets[li].asgs[ai];
                        st.timer_token += 1;
                        st.timer_token
                    };
                    q.push_after_us(
                        consts[li][ai].timeout_us,
                        Event::Timeout { let_idx: li, asg_idx: ai, armed_at: token },
                    );
                }
            }
            Event::Timeout { let_idx, asg_idx, armed_at } => {
                if lets[let_idx].asgs[asg_idx].timer_token != armed_at {
                    continue; // stale timer
                }
                if lets[let_idx].asgs[asg_idx].queue.is_empty() {
                    continue;
                }
                if !lets[let_idx].busy {
                    try_start(
                        let_idx, lm, gt, schedule, &consts, &mut lets, &mut gpu_busy,
                        &mut gpu_waiters, &mut q, cfg, &mut rng, &mut report,
                    );
                } else {
                    // Re-arm: check again shortly after the current run.
                    let token = {
                        let st = &mut lets[let_idx].asgs[asg_idx];
                        st.timer_token += 1;
                        st.timer_token
                    };
                    q.push_after_us(500, Event::Timeout { let_idx, asg_idx, armed_at: token });
                }
            }
            Event::Done { let_idx } => {
                let gpu = schedule.lets[let_idx].spec.gpu;
                // Complete in-flight requests.
                let inflight = std::mem::take(&mut lets[let_idx].inflight);
                for (ai, _id, arr) in inflight {
                    let c = &consts[let_idx][ai];
                    let m = schedule.lets[let_idx].assignments[ai].model;
                    report.model_mut(m, c.slo_ms).record(us_to_ms(now - arr));
                }
                lets[let_idx].busy = false;
                lets[let_idx].running = None;
                if cfg.mode == ShareMode::TemporalOnly {
                    gpu_busy[gpu] = false;
                    if let Some(waiter) = gpu_waiters[gpu].pop_front() {
                        try_start(
                            waiter, lm, gt, schedule, &consts, &mut lets, &mut gpu_busy,
                            &mut gpu_waiters, &mut q, cfg, &mut rng, &mut report,
                        );
                    }
                }
                // Keep draining this let's own queues.
                if !lets[let_idx].busy {
                    try_start(
                        let_idx, lm, gt, schedule, &consts, &mut lets, &mut gpu_busy,
                        &mut gpu_waiters, &mut q, cfg, &mut rng, &mut report,
                    );
                }
            }
        }
    }

    // Anything still queued at the end of the drain window: dropped.
    for (li, ls) in lets.iter_mut().enumerate() {
        for (ai, st) in ls.asgs.iter_mut().enumerate() {
            let m = schedule.lets[li].assignments[ai].model;
            for _ in st.queue.drain(..) {
                report.model_mut(m, consts[li][ai].slo_ms).record_drop();
            }
        }
        for (ai, _, _) in ls.inflight.drain(..) {
            let m = schedule.lets[li].assignments[ai].model;
            report.model_mut(m, consts[li][ai].slo_ms).record_drop();
        }
    }
    report
}

/// Try to start the next batch on `let_idx` (must be idle). Picks the
/// next nonempty assignment round-robin, forms the batch, accounts
/// drops, computes the (interfered) execution time, and schedules Done.
#[allow(clippy::too_many_arguments)]
fn try_start(
    let_idx: usize,
    lm: &LatencyModel,
    gt: &GroundTruth,
    schedule: &Schedule,
    consts: &[Vec<AsgConst>],
    lets: &mut [LetState],
    gpu_busy: &mut [bool],
    gpu_waiters: &mut [VecDeque<usize>],
    q: &mut EventQueue<Event>,
    cfg: &SimConfig,
    rng: &mut Pcg32,
    report: &mut Report,
) {
    if lets[let_idx].busy {
        return;
    }
    let now = q.now_us();
    let lp = &schedule.lets[let_idx];
    let n_asgs = lp.assignments.len();

    // Pick next assignment with work, starting from the round-robin ptr.
    let mut chosen: Option<usize> = None;
    for k in 0..n_asgs {
        let ai = (lets[let_idx].next_asg + k) % n_asgs;
        let asg = &lp.assignments[ai];
        let c = &consts[let_idx][ai];
        // Drop hopeless heads first: even starting right now, the
        // request would finish past its SLO.
        let st = &mut lets[let_idx].asgs[ai];
        let before = st.queue.len();
        st.queue.retain(|&(_, arr)| now + c.exec_est_us <= arr + c.slo_us);
        let dropped = before - st.queue.len();
        for _ in 0..dropped {
            report.model_mut(asg.model, c.slo_ms).record_drop();
        }
        if !st.queue.is_empty() {
            let full = st.queue.len() >= asg.batch as usize;
            let head_arr = st.queue.front().unwrap().1;
            if full || now - head_arr >= c.timeout_us {
                chosen = Some(ai);
                break;
            }
            // Not ready: make sure a timer exists.
            let token = {
                st.timer_token += 1;
                st.timer_token
            };
            q.push_at_us(
                head_arr + c.timeout_us,
                Event::Timeout { let_idx, asg_idx: ai, armed_at: token },
            );
        }
    }
    let Some(ai) = chosen else { return };

    let gpu = lp.spec.gpu;
    if cfg.mode == ShareMode::TemporalOnly {
        if gpu_busy[gpu] {
            if !gpu_waiters[gpu].contains(&let_idx) {
                gpu_waiters[gpu].push_back(let_idx);
            }
            return;
        }
        gpu_busy[gpu] = true;
    }

    let asg = &lp.assignments[ai];
    let b_actual = (lets[let_idx].asgs[ai].queue.len() as u32).min(asg.batch).max(1);
    let mut inflight = Vec::with_capacity(b_actual as usize);
    for _ in 0..b_actual {
        let (id, arr) = lets[let_idx].asgs[ai].queue.pop_front().unwrap();
        inflight.push((ai, id, arr));
    }

    let p_exec = exec_fraction(cfg.mode, lp.spec.fraction());
    let mut exec = lm.latency_ms(asg.model, b_actual, p_exec);

    // Interference with the co-resident let (concurrent modes only).
    if cfg.mode != ShareMode::TemporalOnly {
        if let Some((co_idx, co)) = co_resident_running(schedule, lets, let_idx) {
            let co_lp = &schedule.lets[co_idx];
            let (co_ai, co_b) = co;
            let co_asg = &co_lp.assignments[co_ai];
            let my_prof = profile(asg.model);
            let co_prof = profile(co_asg.model);
            let p_me = lp.spec.fraction();
            let p_co = co_lp.spec.fraction();
            let me = TaskDemand {
                model: asg.model,
                batch: b_actual,
                l2: my_prof.l2_util(p_me, b_actual),
                bw: my_prof.bw_util(p_me, b_actual),
            };
            let other = TaskDemand {
                model: co_asg.model,
                batch: co_b,
                l2: co_prof.l2_util(p_co, co_b),
                bw: co_prof.bw_util(p_co, co_b),
            };
            let base = gt.factor(&me, &other) * cfg.mode.contention_amplification();
            let vol = cfg.mode.contention_volatility();
            let factor = (base * (1.0 + rng.normal(0.0, vol))).max(0.0);
            exec *= 1.0 + factor;
        }
    }

    lets[let_idx].busy = true;
    lets[let_idx].running = Some((ai, b_actual));
    lets[let_idx].inflight = inflight;
    lets[let_idx].next_asg = (ai + 1) % n_asgs;
    q.push_after_us(ms_to_us(exec), Event::Done { let_idx });
}

/// Effective execution fraction under a sharing mode: without static
/// provisioning (MPS default / temporal) a kernel sees the whole GPU.
fn exec_fraction(mode: ShareMode, nominal: f64) -> f64 {
    match mode {
        ShareMode::Partitioned => nominal,
        ShareMode::MpsDefault | ShareMode::TemporalOnly => 1.0,
    }
}

/// The co-resident gpu-let currently executing, if any.
fn co_resident_running(
    schedule: &Schedule,
    lets: &[LetState],
    let_idx: usize,
) -> Option<(usize, (usize, u32))> {
    let gpu = schedule.lets[let_idx].spec.gpu;
    schedule
        .lets
        .iter()
        .enumerate()
        .filter(|(i, lp)| *i != let_idx && lp.spec.gpu == gpu)
        .find_map(|(i, _)| lets[i].running.map(|r| (i, r)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelId;
    use crate::sched::{ElasticPartitioning, SchedCtx, Scheduler};
    use crate::workload::generate_arrivals;

    fn world() -> (LatencyModel, GroundTruth) {
        (LatencyModel::new(), GroundTruth::default())
    }

    fn sched_for(rates: &[f64; 5], gpus: usize) -> Schedule {
        let ctx = SchedCtx::new(gpus, None);
        ElasticPartitioning::gpulet().schedule(&ctx, rates).unwrap()
    }

    #[test]
    fn feasible_load_serves_within_slo() {
        let (lm, gt) = world();
        let rates = [50.0, 50.0, 0.0, 0.0, 0.0];
        let schedule = sched_for(&rates, 4);
        let arrivals = generate_arrivals(
            &[(ModelId::Lenet, 50.0), (ModelId::Googlenet, 50.0)],
            20.0,
            3,
        );
        let n = arrivals.len();
        let report = simulate(&lm, &gt, &schedule, &arrivals, 20.0, &SimConfig::default());
        let v = report.overall_violation_rate();
        assert!(v < 0.02, "violation rate {v}");
        let served: u64 = [ModelId::Lenet, ModelId::Googlenet]
            .iter()
            .map(|&m| report.model(m).map_or(0, |mm| mm.served))
            .sum();
        assert!(served as f64 > 0.98 * n as f64, "served {served}/{n}");
    }

    #[test]
    fn unscheduled_model_drops_everything() {
        let (lm, gt) = world();
        let schedule = sched_for(&[50.0, 0.0, 0.0, 0.0, 0.0], 1);
        let arrivals = generate_arrivals(&[(ModelId::Vgg, 10.0)], 5.0, 1);
        let report = simulate(&lm, &gt, &schedule, &arrivals, 5.0, &SimConfig::default());
        let mm = report.model(ModelId::Vgg).unwrap();
        assert_eq!(mm.served, 0);
        assert_eq!(mm.dropped as usize, arrivals.len());
    }

    #[test]
    fn overload_violates() {
        let (lm, gt) = world();
        // Schedule sized for 50 req/s but offered 10x that.
        let schedule = sched_for(&[0.0, 0.0, 0.0, 0.0, 50.0], 1);
        let arrivals = generate_arrivals(&[(ModelId::Vgg, 500.0)], 10.0, 2);
        let report = simulate(&lm, &gt, &schedule, &arrivals, 10.0, &SimConfig::default());
        assert!(
            report.overall_violation_rate() > 0.3,
            "overload must violate hard, got {}",
            report.overall_violation_rate()
        );
    }

    #[test]
    fn temporal_mode_serializes_and_hurts_consolidation() {
        // LeNet + VGG consolidated on one GPU: under temporal sharing
        // LeNet's 5 ms SLO suffers whenever VGG's long batch holds the
        // GPU (the Fig 5 motivation).
        let (lm, gt) = world();
        let ctx = SchedCtx::new(1, None);
        // Force a 20/80 partitioned schedule.
        let schedule = {
            use crate::gpu::gpulet::GpuLetSpec;
            use crate::sched::types::{Assignment, LetPlan};
            Schedule {
                lets: vec![
                    LetPlan {
                        spec: GpuLetSpec { gpu: 0, size_pct: 20 },
                        assignments: vec![Assignment {
                            model: ModelId::Lenet,
                            batch: 8,
                            rate: 400.0,
                        }],
                    },
                    LetPlan {
                        spec: GpuLetSpec { gpu: 0, size_pct: 80 },
                        assignments: vec![Assignment {
                            model: ModelId::Vgg,
                            batch: 16,
                            rate: 150.0,
                        }],
                    },
                ],
            }
        };
        let _ = ctx;
        let arrivals = generate_arrivals(
            &[(ModelId::Lenet, 400.0), (ModelId::Vgg, 150.0)],
            10.0,
            5,
        );
        let part = simulate(
            &lm, &gt, &schedule, &arrivals, 10.0,
            &SimConfig { mode: ShareMode::Partitioned, ..Default::default() },
        );
        let temp = simulate(
            &lm, &gt, &schedule, &arrivals, 10.0,
            &SimConfig { mode: ShareMode::TemporalOnly, ..Default::default() },
        );
        let vp = part.overall_violation_rate();
        let vt = temp.overall_violation_rate();
        assert!(vp < vt, "partitioned {vp} should beat temporal {vt}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (lm, gt) = world();
        let schedule = sched_for(&[50.0, 0.0, 0.0, 0.0, 50.0], 2);
        let arrivals = generate_arrivals(
            &[(ModelId::Lenet, 50.0), (ModelId::Vgg, 50.0)],
            5.0,
            7,
        );
        let r1 = simulate(&lm, &gt, &schedule, &arrivals, 5.0, &SimConfig::default());
        let r2 = simulate(&lm, &gt, &schedule, &arrivals, 5.0, &SimConfig::default());
        assert_eq!(r1.throughput_rps(), r2.throughput_rps());
        assert_eq!(r1.overall_violation_rate(), r2.overall_violation_rate());
    }
}
