//! One-shot discrete-event serving simulation: executes a `Schedule`
//! against an arrival trace under one of the three GPU sharing modes
//! (Fig 2/5) and reports per-model SLO metrics.
//!
//! Semantics per `ShareMode`:
//! * `Partitioned` — each gpu-let executes concurrently at its own
//!   fraction; when the co-resident gpu-let is mid-execution, the
//!   ground-truth interference stretches this execution (plus a small
//!   volatility term).
//! * `MpsDefault` — no static provisioning: every execution sees the
//!   whole GPU when alone, but overlapping executions contend hard and
//!   volatilely (amplified ground-truth factor).
//! * `TemporalOnly` — executions serialize on the physical GPU (whole-
//!   GPU kernels, coarse-grained switches): a busy GPU queues the next
//!   batch, at full-GPU latency.
//!
//! The event loop itself lives in [`super::engine::ServingEngine`] —
//! the persistent core that can also swap schedules mid-trace.
//! `simulate` is the one-shot convenience every figure harness uses; it
//! now streams the trace through the engine's source mux (one pending
//! arrival at a time, drain horizon derived from the source) instead of
//! bulk-injecting the whole future into the heap, and
//! `simulate_source` runs the same one-shot directly over pull-based
//! streams with no `Vec<Arrival>` anywhere. Both are byte-identical to
//! the bulk-inject path (`tests/streaming_equivalence.rs`), and
//! `tests/engine_equivalence.rs` still pins `simulate` against a frozen
//! copy of the pre-extraction monolithic loop.

use crate::interference::ground_truth::GroundTruth;
use crate::metrics::Report;
use crate::perfmodel::LatencyModel;
use crate::sched::Schedule;
use crate::workload::{Arrival, DynSourceMux};

use super::engine::ServingEngine;

pub use super::engine::SimConfig;

/// Simulate `schedule` over `arrivals`; `window_s` is the measurement
/// window for throughput (usually the trace duration). One-shot: the
/// engine serves the whole trace plus `cfg.drain_ms` of drain time,
/// then everything still queued or in flight is counted as dropped.
///
/// Legacy adapter: copies the trace once into a `MaterializedSource`
/// (the `&[Arrival]` call sites keep working). Hot paths that care
/// about footprint use [`simulate_source`] with pull-based streams and
/// never hold a trace vector at all.
pub fn simulate(
    lm: &LatencyModel,
    gt: &GroundTruth,
    schedule: &Schedule,
    arrivals: &[Arrival],
    window_s: f64,
    cfg: &SimConfig,
) -> Report {
    simulate_source(
        lm,
        gt,
        schedule,
        DynSourceMux::of_trace(arrivals.to_vec()),
        window_s,
        cfg,
    )
}

/// One-shot simulation over pull-based arrival streams: attach the
/// mux, drive it dry, run `cfg.drain_ms` past the last arrival the
/// source actually produced, and count leftovers as drops. The engine's
/// live event set stays O(#streams + #assignments + #gpu-lets) — no
/// arrival vector is ever materialized.
pub fn simulate_source(
    lm: &LatencyModel,
    gt: &GroundTruth,
    schedule: &Schedule,
    source: DynSourceMux,
    window_s: f64,
    cfg: &SimConfig,
) -> Report {
    let mut engine = ServingEngine::new(lm, gt, schedule.clone(), window_s, cfg);
    engine.attach_source(source);
    engine.run_stream();
    engine.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::ShareMode;
    use crate::models::ModelId;
    use crate::sched::{ElasticPartitioning, SchedCtx, Scheduler};
    use crate::workload::generate_arrivals;

    fn world() -> (LatencyModel, GroundTruth) {
        (LatencyModel::new(), GroundTruth::default())
    }

    fn sched_for(rates: &[f64; 5], gpus: usize) -> Schedule {
        let ctx = SchedCtx::new(gpus, None);
        ElasticPartitioning::gpulet().schedule(&ctx, rates).unwrap()
    }

    #[test]
    fn feasible_load_serves_within_slo() {
        let (lm, gt) = world();
        let rates = [50.0, 50.0, 0.0, 0.0, 0.0];
        let schedule = sched_for(&rates, 4);
        let arrivals = generate_arrivals(
            &[(ModelId::Lenet, 50.0), (ModelId::Googlenet, 50.0)],
            20.0,
            3,
        )
        .unwrap();
        let n = arrivals.len();
        let report = simulate(&lm, &gt, &schedule, &arrivals, 20.0, &SimConfig::default());
        let v = report.overall_violation_rate();
        assert!(v < 0.02, "violation rate {v}");
        let served: u64 = [ModelId::Lenet, ModelId::Googlenet]
            .iter()
            .map(|&m| report.model(m).map_or(0, |mm| mm.served))
            .sum();
        assert!(served as f64 > 0.98 * n as f64, "served {served}/{n}");
    }

    #[test]
    fn unscheduled_model_drops_everything() {
        let (lm, gt) = world();
        let schedule = sched_for(&[50.0, 0.0, 0.0, 0.0, 0.0], 1);
        let arrivals = generate_arrivals(&[(ModelId::Vgg, 10.0)], 5.0, 1).unwrap();
        let report = simulate(&lm, &gt, &schedule, &arrivals, 5.0, &SimConfig::default());
        let mm = report.model(ModelId::Vgg).unwrap();
        assert_eq!(mm.served, 0);
        assert_eq!(mm.dropped as usize, arrivals.len());
    }

    #[test]
    fn overload_violates() {
        let (lm, gt) = world();
        // Schedule sized for 50 req/s but offered 10x that.
        let schedule = sched_for(&[0.0, 0.0, 0.0, 0.0, 50.0], 1);
        let arrivals = generate_arrivals(&[(ModelId::Vgg, 500.0)], 10.0, 2).unwrap();
        let report = simulate(&lm, &gt, &schedule, &arrivals, 10.0, &SimConfig::default());
        assert!(
            report.overall_violation_rate() > 0.3,
            "overload must violate hard, got {}",
            report.overall_violation_rate()
        );
    }

    #[test]
    fn temporal_mode_serializes_and_hurts_consolidation() {
        // LeNet + VGG consolidated on one GPU: under temporal sharing
        // LeNet's 5 ms SLO suffers whenever VGG's long batch holds the
        // GPU (the Fig 5 motivation).
        let (lm, gt) = world();
        let ctx = SchedCtx::new(1, None);
        // Force a 20/80 partitioned schedule.
        let schedule = {
            use crate::gpu::gpulet::GpuLetSpec;
            use crate::sched::types::{Assignment, LetPlan};
            Schedule {
                lets: vec![
                    LetPlan {
                        spec: GpuLetSpec { gpu: 0, size_pct: 20 },
                        assignments: vec![Assignment {
                            model: ModelId::Lenet,
                            batch: 8,
                            rate: 400.0,
                        }],
                    },
                    LetPlan {
                        spec: GpuLetSpec { gpu: 0, size_pct: 80 },
                        assignments: vec![Assignment {
                            model: ModelId::Vgg,
                            batch: 16,
                            rate: 150.0,
                        }],
                    },
                ],
            }
        };
        let _ = ctx;
        let arrivals = generate_arrivals(
            &[(ModelId::Lenet, 400.0), (ModelId::Vgg, 150.0)],
            10.0,
            5,
        )
        .unwrap();
        let part = simulate(
            &lm, &gt, &schedule, &arrivals, 10.0,
            &SimConfig { mode: ShareMode::Partitioned, ..Default::default() },
        );
        let temp = simulate(
            &lm, &gt, &schedule, &arrivals, 10.0,
            &SimConfig { mode: ShareMode::TemporalOnly, ..Default::default() },
        );
        let vp = part.overall_violation_rate();
        let vt = temp.overall_violation_rate();
        assert!(vp < vt, "partitioned {vp} should beat temporal {vt}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (lm, gt) = world();
        let schedule = sched_for(&[50.0, 0.0, 0.0, 0.0, 50.0], 2);
        let arrivals = generate_arrivals(
            &[(ModelId::Lenet, 50.0), (ModelId::Vgg, 50.0)],
            5.0,
            7,
        )
        .unwrap();
        let r1 = simulate(&lm, &gt, &schedule, &arrivals, 5.0, &SimConfig::default());
        let r2 = simulate(&lm, &gt, &schedule, &arrivals, 5.0, &SimConfig::default());
        assert_eq!(r1.throughput_rps(), r2.throughput_rps());
        assert_eq!(r1.overall_violation_rate(), r2.overall_violation_rate());
        assert_eq!(r1.to_json().to_string(), r2.to_json().to_string());
    }
}
