//! The serving coordinator (frontend scheduler + backend executors).
//!
//! Three layers share the same scheduling/batching logic:
//!
//! * `engine` — the persistent continuous-time serving core
//!   (`ServingEngine`): owns queues, in-flight work, routing counters,
//!   and metrics across the whole trace, and swaps schedules live.
//! * `simserver` — the one-shot `simulate` wrapper over the engine;
//!   runs every paper experiment (partition sizes and MPS semantics
//!   behave like the paper's 4-GPU testbed).
//! * `server` — the real path: duty-cycle batching over the PJRT CPU
//!   runtime executing the AOT artifacts (examples/quickstart).
//!
//! `reorganizer` implements the periodic re-scheduling loop with the
//! 10-15 s background partition re-organization cost (§5, Fig 14),
//! driving one engine across the trace and swapping schedules at
//! re-organization boundaries — requests survive the hand-over.

pub mod batcher;
pub mod engine;
pub mod reorganizer;
pub mod server;
pub mod simserver;

pub use engine::{ServingEngine, SimConfig, SwapMode};
pub use reorganizer::{AdaptiveOutcome, AdaptiveServer, WindowStats};
pub use simserver::{simulate, simulate_source};
