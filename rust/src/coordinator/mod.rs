//! The serving coordinator (frontend scheduler + backend executors).
//!
//! Two execution paths share the same scheduling/batching logic:
//!
//! * `simserver` — discrete-event simulation under the virtual clock;
//!   runs every paper experiment (partition sizes and MPS semantics
//!   behave like the paper's 4-GPU testbed).
//! * `server` — the real path: duty-cycle batching over the PJRT CPU
//!   runtime executing the AOT artifacts (examples/quickstart).
//!
//! `reorganizer` implements the periodic re-scheduling loop with the
//! 10-15 s background partition re-organization cost (§5, Fig 14).

pub mod batcher;
pub mod reorganizer;
pub mod server;
pub mod simserver;

pub use reorganizer::{AdaptiveServer, WindowStats};
pub use simserver::{simulate, SimConfig};
