//! Per-model request queues + duty-cycle batch building (§5: "the
//! frontend scheduler accumulates the requests for each model
//! independently and forms a batch … dispatched when the desired batch
//! size is formed or a duty-cycle has passed").

use std::collections::VecDeque;

/// One queued request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Queued {
    pub id: u64,
    pub arrival_ms: f64,
}

/// A batch ready for dispatch.
#[derive(Clone, Debug, PartialEq)]
pub struct Batch {
    pub requests: Vec<Queued>,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Oldest arrival in the batch (drives latency accounting).
    pub fn oldest_ms(&self) -> f64 {
        self.requests
            .iter()
            .map(|r| r.arrival_ms)
            .fold(f64::INFINITY, f64::min)
    }
}

/// FIFO batch builder for one (model, gpu-let) assignment.
///
/// Policy: dispatch when `batch_size` requests are waiting, or when the
/// oldest waiter has been queued for `timeout_ms` (the duty-cycle bound
/// that keeps worst-case latency within SLO).
#[derive(Clone, Debug)]
pub struct BatchBuilder {
    pub batch_size: u32,
    pub timeout_ms: f64,
    queue: VecDeque<Queued>,
}

impl BatchBuilder {
    pub fn new(batch_size: u32, timeout_ms: f64) -> Self {
        assert!(batch_size >= 1);
        assert!(timeout_ms >= 0.0);
        BatchBuilder { batch_size, timeout_ms, queue: VecDeque::new() }
    }

    /// Enqueue an arrival. Returns a full batch if this arrival fills one.
    pub fn push(&mut self, req: Queued) -> Option<Batch> {
        self.queue.push_back(req);
        if self.queue.len() >= self.batch_size as usize {
            return self.take(self.batch_size as usize);
        }
        None
    }

    /// Time at which the current head would time out (None if empty).
    pub fn deadline_ms(&self) -> Option<f64> {
        self.queue.front().map(|q| q.arrival_ms + self.timeout_ms)
    }

    /// Fire the timeout path: dispatch whatever is queued (possibly a
    /// partial batch). Call when `now >= deadline_ms()`.
    pub fn flush(&mut self) -> Option<Batch> {
        if self.queue.is_empty() {
            None
        } else {
            self.take(self.queue.len().min(self.batch_size as usize))
        }
    }

    /// Drop every queued request that can no longer meet `slo_ms` even
    /// if an execution taking `exec_ms` started right now. Returns the
    /// dropped requests (§6.2 counts them as violations).
    pub fn drop_hopeless(&mut self, now_ms: f64, slo_ms: f64, exec_ms: f64) -> Vec<Queued> {
        let mut dropped = Vec::new();
        self.queue.retain(|q| {
            let would_finish = now_ms + exec_ms;
            if would_finish - q.arrival_ms > slo_ms {
                dropped.push(*q);
                false
            } else {
                true
            }
        });
        dropped
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    fn take(&mut self, n: usize) -> Option<Batch> {
        let n = n.min(self.queue.len());
        if n == 0 {
            return None;
        }
        let requests: Vec<Queued> = self.queue.drain(..n).collect();
        Some(Batch { requests })
    }
}

/// Timeout that keeps worst-case latency within SLO: leave room for one
/// execution (with safety factor) after the wait.
pub fn slo_timeout_ms(slo_ms: f64, exec_ms: f64) -> f64 {
    (slo_ms - 1.25 * exec_ms).max(0.2)
}

/// Integer-microsecond variant of [`slo_timeout_ms`] for the sim-clock
/// path (`simclock` keeps time in µs): `slo - 1.25 * exec`, floored at
/// 200 µs, all in exact integer arithmetic.
pub fn slo_timeout_us(slo_us: u64, exec_us: u64) -> u64 {
    slo_us.saturating_sub(exec_us + exec_us / 4).max(200)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(id: u64, t: f64) -> Queued {
        Queued { id, arrival_ms: t }
    }

    #[test]
    fn fills_batch_on_size() {
        let mut b = BatchBuilder::new(3, 100.0);
        assert!(b.push(q(0, 0.0)).is_none());
        assert!(b.push(q(1, 1.0)).is_none());
        let batch = b.push(q(2, 2.0)).unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(batch.oldest_ms(), 0.0);
        assert!(b.is_empty());
    }

    #[test]
    fn flush_emits_partial() {
        let mut b = BatchBuilder::new(8, 10.0);
        b.push(q(0, 0.0));
        b.push(q(1, 5.0));
        assert_eq!(b.deadline_ms(), Some(10.0));
        let batch = b.flush().unwrap();
        assert_eq!(batch.len(), 2);
        assert!(b.flush().is_none());
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = BatchBuilder::new(2, 10.0);
        b.push(q(7, 0.0));
        let batch = b.push(q(8, 1.0)).unwrap();
        assert_eq!(batch.requests[0].id, 7);
        assert_eq!(batch.requests[1].id, 8);
    }

    #[test]
    fn drop_hopeless_requests() {
        let mut b = BatchBuilder::new(8, 1000.0);
        b.push(q(0, 0.0)); // old
        b.push(q(1, 90.0)); // fresh
        // now=100, slo=50, exec=10: req0 would finish at 110 with latency 110 > 50.
        let dropped = b.drop_hopeless(100.0, 50.0, 10.0);
        assert_eq!(dropped.len(), 1);
        assert_eq!(dropped[0].id, 0);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn oversize_flush_respects_batch_cap() {
        let mut b = BatchBuilder::new(2, 1e9);
        for i in 0..5 {
            b.push(q(i, i as f64)); // cap 2: pushes at len>=2 emit batches
        }
        // pushes emitted batches at sizes 2, 2; one remains.
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn timeout_formula() {
        assert!((slo_timeout_ms(100.0, 20.0) - 75.0).abs() < 1e-12);
        assert_eq!(slo_timeout_ms(10.0, 20.0), 0.2); // clamped
    }

    #[test]
    fn timeout_formula_us_matches_ms_domain() {
        assert_eq!(slo_timeout_us(100_000, 20_000), 75_000);
        assert_eq!(slo_timeout_us(10_000, 20_000), 200); // clamped to 0.2 ms
        assert_eq!(slo_timeout_us(0, 0), 200);
        // Agrees with the f64 formula at µs resolution.
        for (slo, exec) in [(5_000u64, 1_234u64), (44_000, 7_000), (136_000, 64_000)] {
            let want = (slo_timeout_ms(slo as f64 / 1000.0, exec as f64 / 1000.0)
                * 1000.0)
                .round() as u64;
            let got = slo_timeout_us(slo, exec);
            assert!(got.abs_diff(want) <= 1, "slo={slo} exec={exec}: {got} vs {want}");
        }
    }
}
