//! Fig 3: batch inference latency vs gpu-let size (20%..100%) for each
//! model, batch 1..32. The paper reads these curves off real 2080 Ti
//! partitions; we read them off the calibrated latency substrate — the
//! shape (steep for large batches, flat beyond the knee for small ones)
//! is the reproduction target.

use std::collections::BTreeMap;

use crate::experiments::common::{Runnable, RunOutput};
use crate::models::ModelId;
use crate::perfmodel::{LatencyModel, BATCHES};
use crate::perfmodel::profile_table::PARTITIONS;
use crate::util::json::{obj, Json};

/// One model's profiled grid: `(batch, partition_pct, latency_ms)` in
/// batch-major order, plus the knee the scheduler uses.
pub struct ModelGrid {
    pub model: ModelId,
    pub rows: Vec<(u32, u32, f64)>,
    pub knee_pct: u32,
}

pub fn compute() -> Vec<ModelGrid> {
    let lm = LatencyModel::new();
    ModelId::ALL
        .iter()
        .map(|&m| {
            let mut rows = Vec::with_capacity(BATCHES.len() * PARTITIONS.len());
            for &b in &BATCHES {
                for p in PARTITIONS {
                    rows.push((b, p, lm.latency_ms(m, b, p as f64 / 100.0)));
                }
            }
            let knee_pct = crate::perfmodel::latency::knee(&lm.rate_curve(m, &PARTITIONS));
            ModelGrid { model: m, rows, knee_pct }
        })
        .collect()
}

pub fn render(grids: &[ModelGrid]) -> String {
    let mut out = String::new();
    out.push_str("# Fig 3: batch latency (ms) vs gpu-let size\n");
    for g in grids {
        out.push_str(&format!("\n## {}\nbatch", g.model.name()));
        for p in PARTITIONS {
            out.push_str(&format!("  {p:>3}%"));
        }
        out.push('\n');
        let mut rows = g.rows.iter();
        for &b in &BATCHES {
            out.push_str(&format!("{b:>5}"));
            for _ in PARTITIONS {
                let &(_, _, l) = rows.next().expect("full grid");
                out.push_str(&format!(" {l:>5.1}"));
            }
            out.push('\n');
        }
        // The knee summary the scheduler actually uses.
        out.push_str(&format!("knee (MaxEfficientPartition): {}%\n", g.knee_pct));
    }
    out
}

pub fn run() -> String {
    render(&compute())
}

/// Text + JSON for the CLI / bench harness (one grid pass): the full
/// L(b, p) grid and the per-model knee the scheduler uses.
pub fn report() -> RunOutput {
    let grids = compute();
    let mut models: BTreeMap<String, Json> = BTreeMap::new();
    for g in &grids {
        let grid: Vec<Json> = g
            .rows
            .iter()
            .map(|&(b, p, l)| {
                obj(vec![
                    ("batch", Json::Num(b as f64)),
                    ("partition_pct", Json::Num(p as f64)),
                    ("latency_ms", Json::Num(l)),
                ])
            })
            .collect();
        models.insert(
            g.model.name().to_string(),
            obj(vec![
                ("grid", Json::Arr(grid)),
                ("knee_pct", Json::Num(g.knee_pct as f64)),
            ]),
        );
    }
    RunOutput {
        text: render(&grids),
        payload: obj(vec![
            ("figure", Json::Str("fig03".into())),
            ("models", Json::Obj(models)),
        ]),
    }
}

/// Fig 3 as a CLI/bench-drivable experiment.
pub struct Experiment;

impl Runnable for Experiment {
    fn name(&self) -> &'static str {
        "fig03"
    }
    fn title(&self) -> &'static str {
        "batch latency vs gpu-let size (L(b,p) grid + knees)"
    }
    fn bench_file(&self) -> &'static str {
        "BENCH_fig03_latency.json"
    }
    fn run(&self) -> RunOutput {
        report()
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn report_payload_covers_grid() {
        let out = super::report();
        let models = out.payload.get("models").unwrap().as_obj().unwrap();
        assert_eq!(models.len(), 5);
        let lenet = &models["lenet"];
        assert_eq!(lenet.get("grid").unwrap().as_arr().unwrap().len(), 36);
        assert!(lenet.get("knee_pct").unwrap().as_f64().unwrap() <= 40.0);
    }

    #[test]
    fn renders_all_models_and_knees() {
        let s = super::run();
        for name in ["lenet", "googlenet", "resnet", "ssd_mobilenet", "vgg"] {
            assert!(s.contains(name), "{name} missing");
        }
        assert_eq!(s.matches("knee").count(), 5);
    }
}
