//! Fig 3: batch inference latency vs gpu-let size (20%..100%) for each
//! model, batch 1..32. The paper reads these curves off real 2080 Ti
//! partitions; we read them off the calibrated latency substrate — the
//! shape (steep for large batches, flat beyond the knee for small ones)
//! is the reproduction target.

use crate::models::ModelId;
use crate::perfmodel::{LatencyModel, BATCHES};
use crate::perfmodel::profile_table::PARTITIONS;

pub fn run() -> String {
    let lm = LatencyModel::new();
    let mut out = String::new();
    out.push_str("# Fig 3: batch latency (ms) vs gpu-let size\n");
    for m in ModelId::ALL {
        out.push_str(&format!("\n## {}\nbatch", m.name()));
        for p in PARTITIONS {
            out.push_str(&format!("  {p:>3}%"));
        }
        out.push('\n');
        for &b in &BATCHES {
            out.push_str(&format!("{b:>5}"));
            for p in PARTITIONS {
                out.push_str(&format!(" {:>5.1}", lm.latency_ms(m, b, p as f64 / 100.0)));
            }
            out.push('\n');
        }
        // The knee summary the scheduler actually uses.
        let kn = crate::perfmodel::latency::knee(&lm.rate_curve(m, &PARTITIONS));
        out.push_str(&format!("knee (MaxEfficientPartition): {kn}%\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn renders_all_models_and_knees() {
        let s = super::run();
        for name in ["lenet", "googlenet", "resnet", "ssd_mobilenet", "vgg"] {
            assert!(s.contains(name), "{name} missing");
        }
        assert_eq!(s.matches("knee").count(), 5);
    }
}
