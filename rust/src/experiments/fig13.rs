//! Fig 13: SLO violation rates of gpulet vs gpulet+int at the highest
//! rates either accepts. Paper point: the interference-oblivious
//! variant admits rates it then violates (>1% for equal/short-skew);
//! gpulet+int filters those by classifying them unschedulable or
//! scheduling around the interference.
//!
//! Each probe's trace streams through the serving engine via
//! `common::violation_rate_of` (per-model Poisson sources; no arrival
//! vector is materialized) — byte-identical reports to the old
//! generate-sort-simulate path.

use crate::sched::{ElasticPartitioning, Scheduler};
use crate::util::json::{obj, Json};

use super::common::{
    eval_workloads, max_schedulable, paper_ctx, scaled, violation_rate_of, Runnable, RunOutput,
};

pub struct Row {
    pub workload: String,
    /// Scale factor probed (max the oblivious scheduler accepts).
    pub scale: f64,
    pub viol_gpulet: f64,
    /// None = gpulet+int classified the rate Not Schedulable.
    pub viol_gpulet_int: Option<f64>,
}

pub fn compute(sim_duration_s: f64) -> Vec<Row> {
    let ctx_plain = paper_ctx(false);
    let ctx_int = paper_ctx(true);
    let gp = ElasticPartitioning::gpulet();
    let gi = ElasticPartitioning::gpulet_int();

    // Workloads are independent: probe all five stress points on the
    // worker pool; rows come back in workload order.
    let workloads = eval_workloads();
    let probed = crate::util::par::par_map(&workloads, |(_, base)| {
        // The stress point: the highest rate the oblivious variant
        // still accepts (the paper probes until both say no).
        let k = max_schedulable(&ctx_plain, &gp, base);
        let rates = scaled(base, k);
        let viol_gp = match gp.schedule(&ctx_plain, &rates) {
            Ok(s) => violation_rate_of(&ctx_plain, &s, &rates, sim_duration_s, 131),
            Err(_) => 1.0,
        };
        let viol_gi = gi
            .schedule(&ctx_int, &rates)
            .ok()
            .map(|s| violation_rate_of(&ctx_int, &s, &rates, sim_duration_s, 131));
        (k, viol_gp, viol_gi)
    });
    workloads
        .into_iter()
        .zip(probed)
        .map(|((name, _), (k, viol_gp, viol_gi))| Row {
            workload: name,
            scale: k,
            viol_gpulet: viol_gp,
            viol_gpulet_int: viol_gi,
        })
        .collect()
}

pub fn render(rows: &[Row]) -> String {
    let mut out = String::from(
        "# Fig 13: SLO violation at max gpulet-accepted rates\n\
         workload      scale  gpulet-viol%  gpulet+int\n",
    );
    for r in rows {
        let gi = match r.viol_gpulet_int {
            Some(v) => format!("{:.2}%", v * 100.0),
            None => "NotSchedulable".to_string(),
        };
        out.push_str(&format!(
            "{:<12} {:>6.2} {:>12.2} {:>13}\n",
            r.workload,
            r.scale,
            r.viol_gpulet * 100.0,
            gi
        ));
    }
    out.push_str("(paper: gpulet exceeds 1% on equal/short-skew; gpulet+int filters them)\n");
    out
}

pub fn run() -> String {
    render(&compute(12.0))
}

/// Text + JSON for the CLI / bench harness (one `compute()` pass).
pub fn report() -> RunOutput {
    let rows = compute(12.0);
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            obj(vec![
                ("workload", Json::Str(r.workload.clone())),
                ("scale", Json::Num(r.scale)),
                ("viol_gpulet", Json::Num(r.viol_gpulet)),
                (
                    "viol_gpulet_int",
                    match r.viol_gpulet_int {
                        Some(v) => Json::Num(v),
                        None => Json::Null, // classified Not Schedulable
                    },
                ),
            ])
        })
        .collect();
    RunOutput {
        text: render(&rows),
        payload: obj(vec![
            ("figure", Json::Str("fig13".into())),
            ("rows", Json::Arr(json_rows)),
        ]),
    }
}

/// Fig 13 as a CLI/bench-drivable experiment.
pub struct Experiment;

impl Runnable for Experiment {
    fn name(&self) -> &'static str {
        "fig13"
    }
    fn title(&self) -> &'static str {
        "SLO violation at the oblivious scheduler's stress point"
    }
    fn bench_file(&self) -> &'static str {
        "BENCH_fig13_slo_violation.json"
    }
    fn run(&self) -> RunOutput {
        report()
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn int_variant_filters_or_matches() {
        let rows = super::compute(6.0);
        assert_eq!(rows.len(), 5);
        for r in &rows {
            // Whenever gpulet+int does accept the stress rate, it must
            // not be *more* violating than the oblivious variant
            // (allowing sim noise).
            if let Some(v) = r.viol_gpulet_int {
                assert!(
                    v <= r.viol_gpulet + 0.02,
                    "{}: int {v} vs oblivious {}",
                    r.workload,
                    r.viol_gpulet
                );
            }
        }
        // At least one workload must show the paper's filtering effect:
        // the oblivious variant violating more, or int refusing the rate.
        assert!(
            rows.iter().any(|r| r.viol_gpulet_int.is_none()
                || r.viol_gpulet > r.viol_gpulet_int.unwrap() + 1e-4),
            "no workload shows interference filtering"
        );
    }
}
