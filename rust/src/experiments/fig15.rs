//! Fig 15: schedulable scenarios (of 1,023) — ideal exhaustive scheduler
//! vs gpulet+int. Paper: gpulet+int schedules 18 fewer, i.e. within
//! 1.8% of ideal.

use crate::sched::{ElasticPartitioning, IdealScheduler, Scheduler};
use crate::util::json::{obj, Json};
use crate::util::par;
use crate::workload::enumerate_all_scenarios;

use super::common::{paper_ctx, Runnable, RunOutput};

pub struct Fig15 {
    pub ideal: usize,
    pub gpulet_int: usize,
    pub total: usize,
    /// Scenarios ideal schedules but gpulet+int does not.
    pub gap: usize,
}

pub fn compute() -> Fig15 {
    let ctx_int = paper_ctx(true);
    let ctx_ideal = paper_ctx(false);
    let scenarios = enumerate_all_scenarios();
    // Scenarios are independent: fan the sweep out over the worker pool
    // (`--threads` / GPULETS_THREADS). Per-scenario verdicts come back
    // in input order, so the aggregate is identical for any thread
    // count.
    let verdicts = par::par_map(&scenarios, |sc| {
        let ok_ideal = IdealScheduler.schedule(&ctx_ideal, &sc.rates).is_ok();
        let ok_gi =
            ElasticPartitioning::gpulet_int().schedule(&ctx_int, &sc.rates).is_ok();
        (ok_ideal, ok_gi)
    });
    let mut n_ideal = 0;
    let mut n_gi = 0;
    let mut gap = 0;
    for (ok_ideal, ok_gi) in verdicts {
        n_ideal += ok_ideal as usize;
        n_gi += ok_gi as usize;
        gap += (ok_ideal && !ok_gi) as usize;
    }
    Fig15 { ideal: n_ideal, gpulet_int: n_gi, total: scenarios.len(), gap }
}

pub fn render(r: &Fig15) -> String {
    format!(
        "# Fig 15: schedulable scenarios out of {}\n\
         ideal (exhaustive): {}\n\
         gpulet+int:         {}\n\
         ideal-only gap:     {} ({:.1}% of population; paper: 18 = 1.8%)\n",
        r.total,
        r.ideal,
        r.gpulet_int,
        r.gap,
        r.gap as f64 / r.total as f64 * 100.0
    )
}

pub fn run() -> String {
    render(&compute())
}

/// Text + JSON for the CLI / bench harness (one `compute()` pass).
pub fn report() -> RunOutput {
    let r = compute();
    RunOutput {
        text: render(&r),
        payload: obj(vec![
            ("figure", Json::Str("fig15".into())),
            ("total", Json::Num(r.total as f64)),
            ("ideal", Json::Num(r.ideal as f64)),
            ("gpulet_int", Json::Num(r.gpulet_int as f64)),
            ("gap", Json::Num(r.gap as f64)),
        ]),
    }
}

/// Fig 15 as a CLI/bench-drivable experiment.
pub struct Experiment;

impl Runnable for Experiment {
    fn name(&self) -> &'static str {
        "fig15"
    }
    fn title(&self) -> &'static str {
        "schedulability: ideal exhaustive vs gpulet+int (1023 scenarios)"
    }
    fn bench_file(&self) -> &'static str {
        "BENCH_fig15_ideal_schedulability.json"
    }
    fn run(&self) -> RunOutput {
        report()
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn gpulet_int_close_to_ideal() {
        let r = super::compute();
        assert_eq!(r.total, 1023);
        assert!(r.ideal >= r.gpulet_int, "ideal must dominate");
        // Within a small gap of ideal (paper: 1.8%; we allow < 8%).
        assert!(
            (r.gap as f64) < 0.08 * r.total as f64,
            "gap {} too large vs ideal {}",
            r.gap,
            r.ideal
        );
        assert!(r.gpulet_int > 300, "gpulet+int schedules too few: {}", r.gpulet_int);
    }
}
