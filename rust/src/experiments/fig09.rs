//! Fig 9: CDF of the linear interference model's relative prediction
//! error on a held-out validation set. Paper headline: 90% of cases
//! within 10.26% error, 95% within 13.98%.

use crate::experiments::common::{Runnable, RunOutput};
use crate::interference::linear_model::{
    profiling_population, train_val_split, InterferenceModel,
};
use crate::interference::GroundTruth;
use crate::util::json::{obj, Json};
use crate::util::stats;

pub struct Fig09 {
    pub coef: [f64; 5],
    pub n_train: usize,
    pub n_val: usize,
    pub p90_err: f64,
    pub p95_err: f64,
    pub errors: Vec<f64>,
}

pub fn compute() -> Fig09 {
    let gt = GroundTruth::default();
    let population = profiling_population(&gt);
    let (train, val) = train_val_split(population, 0.7, 42);
    let model = InterferenceModel::fit(&train).expect("fit");
    let errors = model.validation_errors(&val);
    Fig09 {
        coef: model.coef,
        n_train: train.len(),
        n_val: val.len(),
        p90_err: stats::percentile(&errors, 90.0),
        p95_err: stats::percentile(&errors, 95.0),
        errors,
    }
}

/// Text + JSON for the CLI / bench harness (one `compute()` pass).
pub fn report() -> RunOutput {
    let r = compute();
    let quantiles: Vec<Json> = [50.0, 75.0, 90.0, 95.0, 99.0]
        .iter()
        .map(|&q| {
            obj(vec![
                ("quantile", Json::Num(q)),
                ("error", Json::Num(stats::percentile(&r.errors, q))),
            ])
        })
        .collect();
    RunOutput {
        text: render(&r),
        payload: obj(vec![
            ("figure", Json::Str("fig09".into())),
            ("coef", Json::Arr(r.coef.iter().map(|&c| Json::Num(c)).collect())),
            ("n_train", Json::Num(r.n_train as f64)),
            ("n_val", Json::Num(r.n_val as f64)),
            ("p90_err", Json::Num(r.p90_err)),
            ("p95_err", Json::Num(r.p95_err)),
            ("quantiles", Json::Arr(quantiles)),
        ]),
    }
}

/// Fig 9 as a CLI/bench-drivable experiment.
pub struct Experiment;

impl Runnable for Experiment {
    fn name(&self) -> &'static str {
        "fig09"
    }
    fn title(&self) -> &'static str {
        "linear interference model fit + held-out error CDF"
    }
    fn bench_file(&self) -> &'static str {
        "BENCH_fig09_interference_model.json"
    }
    fn run(&self) -> RunOutput {
        report()
    }
}

pub fn run() -> String {
    render(&compute())
}

pub fn render(r: &Fig09) -> String {
    let mut out = format!(
        "# Fig 9: interference model prediction error CDF\n\
         train/val: {}/{}\n\
         coefficients c1..c5: {:.4} {:.4} {:.4} {:.4} {:.4}\n\
         quantile  error%\n",
        r.n_train, r.n_val, r.coef[0], r.coef[1], r.coef[2], r.coef[3], r.coef[4]
    );
    for q in [50.0, 75.0, 90.0, 95.0, 99.0] {
        out.push_str(&format!(
            "{:>8.0} {:>7.2}\n",
            q,
            stats::percentile(&r.errors, q) * 100.0
        ));
    }
    out.push_str(&format!(
        "p90 error {:.2}% (paper 10.26%), p95 error {:.2}% (paper 13.98%)\n",
        r.p90_err * 100.0,
        r.p95_err * 100.0
    ));
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn error_cdf_in_paper_regime() {
        let r = super::compute();
        assert!(r.n_train > r.n_val);
        assert!(r.p90_err < 0.16, "p90 {}", r.p90_err);
        assert!(r.p95_err < 0.20, "p95 {}", r.p95_err);
        // Memory-bandwidth terms should matter (positive weight).
        assert!(r.coef[2] + r.coef[3] > 0.0);
    }
}
