//! Fig 16: maximum schedulable rate of gpulet+int normalized to the
//! ideal exhaustive scheduler, per evaluation workload. Paper: 92.3%
//! of ideal on average, worst case traffic at 87.7%.
//!
//! Pure scheduler-level searches (`common::max_schedulable`), so no
//! simulation runs here — but the shared `common` probe machinery this
//! module sits on now streams all simulated searches (see fig12).

use crate::sched::{ElasticPartitioning, IdealScheduler};
use crate::util::json::{obj, Json};
use crate::util::par;

use super::common::{eval_workloads, max_schedulable, paper_ctx, Runnable, RunOutput};

pub struct Row {
    pub workload: String,
    pub ideal_scale: f64,
    pub gpulet_int_scale: f64,
}

impl Row {
    pub fn normalized(&self) -> f64 {
        if self.ideal_scale > 0.0 {
            self.gpulet_int_scale / self.ideal_scale
        } else {
            f64::NAN
        }
    }
}

pub fn compute() -> Vec<Row> {
    let ctx_int = paper_ctx(true);
    let ctx_ideal = paper_ctx(false);
    // The per-workload max-rate bisections are independent: run the
    // (workload × scheduler) grid on the worker pool and reassemble in
    // fixed order (byte-identical output for any `--threads N`).
    let workloads = eval_workloads();
    let tasks: Vec<(usize, bool)> = (0..workloads.len())
        .flat_map(|w| [(w, false), (w, true)])
        .collect();
    let scales = par::par_map(&tasks, |&(w, int_variant)| {
        let base = &workloads[w].1;
        if int_variant {
            max_schedulable(&ctx_int, &ElasticPartitioning::gpulet_int(), base)
        } else {
            max_schedulable(&ctx_ideal, &IdealScheduler, base)
        }
    });
    workloads
        .into_iter()
        .enumerate()
        .map(|(w, (name, _))| Row {
            workload: name,
            ideal_scale: scales[2 * w],
            gpulet_int_scale: scales[2 * w + 1],
        })
        .collect()
}

/// Text + JSON for the CLI / bench harness (one `compute()` pass).
/// `normalized` is null when the ideal scheduler accepted no scale
/// (division by zero would otherwise poison the JSON with NaN).
pub fn report() -> RunOutput {
    let rows = compute();
    let num_or_null = |x: f64| if x.is_finite() { Json::Num(x) } else { Json::Null };
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            obj(vec![
                ("workload", Json::Str(r.workload.clone())),
                ("ideal_scale", Json::Num(r.ideal_scale)),
                ("gpulet_int_scale", Json::Num(r.gpulet_int_scale)),
                ("normalized", num_or_null(r.normalized())),
            ])
        })
        .collect();
    let valid: Vec<f64> = rows.iter().map(Row::normalized).filter(|n| n.is_finite()).collect();
    let avg = if valid.is_empty() {
        Json::Null
    } else {
        Json::Num(valid.iter().sum::<f64>() / valid.len() as f64)
    };
    RunOutput {
        text: render(&rows),
        payload: obj(vec![
            ("figure", Json::Str("fig16".into())),
            ("rows", Json::Arr(json_rows)),
            ("avg_normalized", avg),
        ]),
    }
}

/// Fig 16 as a CLI/bench-drivable experiment.
pub struct Experiment;

impl Runnable for Experiment {
    fn name(&self) -> &'static str {
        "fig16"
    }
    fn title(&self) -> &'static str {
        "max schedulable rate normalized to the ideal scheduler"
    }
    fn bench_file(&self) -> &'static str {
        "BENCH_fig16_ideal_rate.json"
    }
    fn run(&self) -> RunOutput {
        report()
    }
}

pub fn run() -> String {
    render(&compute())
}

pub fn render(rows: &[Row]) -> String {
    let mut out = String::from(
        "# Fig 16: max schedulable rate normalized to ideal\n\
         workload      ideal-scale  gpulet+int  normalized\n",
    );
    let mut sum = 0.0;
    for r in rows {
        sum += r.normalized();
        out.push_str(&format!(
            "{:<12} {:>11.2} {:>11.2} {:>10.1}%\n",
            r.workload,
            r.ideal_scale,
            r.gpulet_int_scale,
            r.normalized() * 100.0
        ));
    }
    out.push_str(&format!(
        "average: {:.1}% of ideal (paper: 92.3%)\n",
        sum / rows.len() as f64 * 100.0
    ));
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn gpulet_int_achieves_large_fraction_of_ideal() {
        let rows = super::compute();
        assert_eq!(rows.len(), 5);
        let avg: f64 =
            rows.iter().map(|r| r.normalized()).sum::<f64>() / rows.len() as f64;
        assert!(avg > 0.75, "average normalized rate {avg}");
        for r in &rows {
            assert!(
                r.gpulet_int_scale <= r.ideal_scale * 1.05,
                "{}: heuristic cannot beat ideal meaningfully",
                r.workload
            );
        }
    }
}
