//! Fig 12: maximum achievable throughput of the four schedulers over
//! the five evaluation workloads. Paper headlines: gpulet ~ +106% and
//! gpulet+int ~ +102.6% over SBP; gpulet+int ~ +74.8% over guided
//! self-tuning.
//!
//! Every `max_achievable_detail` search reuses ONE serving engine
//! across its whole descending probe grid (reset, not rebuilt) and
//! streams each probe's Poisson workload straight into it — the old
//! path re-generated, re-sorted, and bulk-injected a fresh trace per
//! grid point.

use crate::sched::{
    ElasticPartitioning, GuidedSelfTuning, Scheduler, SquishyBinPacking,
};
use crate::util::json::{obj, Json};
use crate::util::par;

use super::common::{eval_workloads, max_achievable_detail, paper_ctx, Runnable, RunOutput};

pub struct Row {
    pub workload: String,
    /// Total achieved req/s per scheduler: [sbp, selftune, gpulet, gpulet+int].
    pub rps: [f64; 4],
    /// Uniform scale of the base rate vector at which each scheduler held
    /// the violation budget.
    pub scales: [f64; 4],
    /// Measured SLO violation rate at the reported throughput; `None`
    /// when no probed scale produced an acceptable deployment.
    pub viols: [Option<f64>; 4],
}

pub const SCHED_NAMES: [&str; 4] = ["sbp", "selftune", "gpulet", "gpulet+int"];

pub fn compute(viol_budget: f64, sim_duration_s: f64) -> Vec<Row> {
    let ctx_plain = paper_ctx(false);
    let ctx_int = paper_ctx(true);
    let sbp = SquishyBinPacking::baseline();
    let st = GuidedSelfTuning;
    let gp = ElasticPartitioning::gpulet();
    let gi = ElasticPartitioning::gpulet_int();
    let runs: [(&dyn Scheduler, &crate::sched::SchedCtx); 4] =
        [(&sbp, &ctx_plain), (&st, &ctx_plain), (&gp, &ctx_plain), (&gi, &ctx_int)];

    // Every (workload, scheduler) max-rate search is independent: fan
    // the 20-task grid out over the worker pool and reassemble rows in
    // fixed order, so the rendered table and the BENCH payload are
    // byte-identical for any `--threads N`.
    let workloads = eval_workloads();
    let tasks: Vec<(usize, usize)> = (0..workloads.len())
        .flat_map(|w| (0..runs.len()).map(move |s| (w, s)))
        .collect();
    let results = par::par_map(&tasks, |&(w, s)| {
        let (sched, ctx) = runs[s];
        max_achievable_detail(ctx, sched, &workloads[w].1, viol_budget, sim_duration_s)
    });

    workloads
        .into_iter()
        .enumerate()
        .map(|(w, (name, _))| {
            let mut rps = [0.0; 4];
            let mut scales = [0.0; 4];
            let mut viols = [None; 4];
            for s in 0..runs.len() {
                let a = results[w * runs.len() + s];
                rps[s] = a.total_rps;
                scales[s] = a.scale;
                viols[s] = a.violation_rate;
            }
            Row { workload: name, rps, scales, viols }
        })
        .collect()
}

pub fn render(rows: &[Row]) -> String {
    let mut out = String::from(
        "# Fig 12: maximum achievable throughput (req/s)\n\
         workload       sbp  selftune    gpulet  gpulet+int   g+i/sbp\n",
    );
    let mut gains = Vec::new();
    for r in rows {
        let gain = if r.rps[0] > 0.0 { r.rps[3] / r.rps[0] } else { f64::NAN };
        gains.push(gain);
        out.push_str(&format!(
            "{:<11} {:>6.0} {:>9.0} {:>9.0} {:>11.0} {:>8.2}x\n",
            r.workload, r.rps[0], r.rps[1], r.rps[2], r.rps[3], gain
        ));
    }
    let avg_gain: f64 = gains.iter().sum::<f64>() / gains.len() as f64;
    out.push_str(&format!(
        "average gpulet+int / sbp: {:.2}x (paper: ~2.03x / +102.6%)\n",
        avg_gain
    ));
    out
}

pub fn run() -> String {
    render(&compute(0.01, 12.0))
}

/// Text + JSON for the CLI / bench harness (one `compute()` pass).
/// The payload carries, per workload and scheduler, the achieved
/// throughput, the accepted scale, and the SLO violation rate measured
/// at that throughput — the headline numbers every future perf PR is
/// diffed against.
pub fn report() -> RunOutput {
    let rows = compute(0.01, 12.0);
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut scheds: std::collections::BTreeMap<String, Json> =
                std::collections::BTreeMap::new();
            for (i, name) in SCHED_NAMES.iter().enumerate() {
                scheds.insert(
                    name.to_string(),
                    obj(vec![
                        ("throughput_rps", Json::Num(r.rps[i])),
                        ("scale", Json::Num(r.scales[i])),
                        (
                            "violation_rate",
                            match r.viols[i] {
                                Some(v) => Json::Num(v),
                                // No acceptable deployment at any scale.
                                None => Json::Null,
                            },
                        ),
                    ]),
                );
            }
            obj(vec![
                ("workload", Json::Str(r.workload.clone())),
                ("schedulers", Json::Obj(scheds)),
            ])
        })
        .collect();
    let avg_gain = {
        let gains: Vec<f64> = rows
            .iter()
            .filter(|r| r.rps[0] > 0.0)
            .map(|r| r.rps[3] / r.rps[0])
            .collect();
        if gains.is_empty() {
            0.0
        } else {
            gains.iter().sum::<f64>() / gains.len() as f64
        }
    };
    RunOutput {
        text: render(&rows),
        payload: obj(vec![
            ("figure", Json::Str("fig12".into())),
            ("workloads", Json::Arr(json_rows)),
            ("avg_gain_gpulet_int_vs_sbp", Json::Num(avg_gain)),
        ]),
    }
}

/// Fig 12 as a CLI/bench-drivable experiment — the paper's headline
/// throughput table.
pub struct Experiment;

impl Runnable for Experiment {
    fn name(&self) -> &'static str {
        "fig12"
    }
    fn title(&self) -> &'static str {
        "max achievable throughput, 4 schedulers x 5 workloads"
    }
    fn bench_file(&self) -> &'static str {
        "BENCH_fig12_throughput.json"
    }
    fn run(&self) -> RunOutput {
        report()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpulet_beats_sbp_on_average() {
        // Short sim windows keep the test affordable; the ordering is
        // what the paper claims, not the absolute numbers.
        let rows = compute(0.01, 6.0);
        assert_eq!(rows.len(), 5);
        let avg = |i: usize| -> f64 { rows.iter().map(|r| r.rps[i]).sum::<f64>() / 5.0 };
        let sbp = avg(0);
        let selftune = avg(1);
        let gpulet = avg(2);
        let gpulet_int = avg(3);
        assert!(gpulet > sbp * 1.3, "gpulet {gpulet} vs sbp {sbp}");
        assert!(gpulet_int > sbp * 1.3, "gpulet+int {gpulet_int} vs sbp {sbp}");
        assert!(gpulet_int > selftune, "gpulet+int {gpulet_int} vs selftune {selftune}");
        // Interference-aware is the (slightly) conservative variant.
        assert!(gpulet_int <= gpulet * 1.1);
    }
}
