//! Fig 4: number of schedulable scenarios (of the 1,023 population) for
//! SBP *without* vs *with* even 50:50 GPU partitioning, on 4 GPUs.
//! Paper result: partitioning eliminates most unschedulable scenarios.

use crate::sched::{Scheduler, SquishyBinPacking};
use crate::workload::enumerate_all_scenarios;

use super::common::paper_ctx;

pub struct Fig04 {
    pub sbp_plain: usize,
    pub sbp_partitioned: usize,
    pub total: usize,
}

pub fn compute() -> Fig04 {
    let ctx = paper_ctx(false);
    let scenarios = enumerate_all_scenarios();
    let plain = SquishyBinPacking::baseline();
    let part = SquishyBinPacking::with_even_partitioning();
    let mut n_plain = 0;
    let mut n_part = 0;
    for sc in &scenarios {
        if plain.schedule(&ctx, &sc.rates).is_ok() {
            n_plain += 1;
        }
        if part.schedule(&ctx, &sc.rates).is_ok() {
            n_part += 1;
        }
    }
    Fig04 { sbp_plain: n_plain, sbp_partitioned: n_part, total: scenarios.len() }
}

pub fn run() -> String {
    let r = compute();
    format!(
        "# Fig 4: schedulable scenarios out of {}\n\
         SBP (no partitioning):    {}\n\
         SBP (50:50 partitioning): {}\n\
         partitioning recovers:    {}\n",
        r.total,
        r.sbp_plain,
        r.sbp_partitioned,
        r.sbp_partitioned as i64 - r.sbp_plain as i64,
    )
}

#[cfg(test)]
mod tests {
    #[test]
    fn partitioning_recovers_scenarios() {
        let r = super::compute();
        assert_eq!(r.total, 1023);
        assert!(r.sbp_plain > 0);
        assert!(
            r.sbp_partitioned > r.sbp_plain,
            "partitioned {} !> plain {}",
            r.sbp_partitioned,
            r.sbp_plain
        );
    }
}
