//! Fig 4: number of schedulable scenarios (of the 1,023 population) for
//! SBP *without* vs *with* even 50:50 GPU partitioning, on 4 GPUs.
//! Paper result: partitioning eliminates most unschedulable scenarios.

use crate::sched::{Scheduler, SquishyBinPacking};
use crate::util::json::{obj, Json};
use crate::util::par;
use crate::workload::enumerate_all_scenarios;

use super::common::{paper_ctx, Runnable, RunOutput};

pub struct Fig04 {
    pub sbp_plain: usize,
    pub sbp_partitioned: usize,
    pub total: usize,
}

pub fn compute() -> Fig04 {
    let ctx = paper_ctx(false);
    let scenarios = enumerate_all_scenarios();
    // Independent per-scenario verdicts: sweep in parallel, merge in
    // input order (identical counts for any `--threads N`).
    let verdicts = par::par_map(&scenarios, |sc| {
        (
            SquishyBinPacking::baseline().schedule(&ctx, &sc.rates).is_ok(),
            SquishyBinPacking::with_even_partitioning().schedule(&ctx, &sc.rates).is_ok(),
        )
    });
    let n_plain = verdicts.iter().filter(|&&(p, _)| p).count();
    let n_part = verdicts.iter().filter(|&&(_, q)| q).count();
    Fig04 { sbp_plain: n_plain, sbp_partitioned: n_part, total: scenarios.len() }
}

pub fn render(r: &Fig04) -> String {
    format!(
        "# Fig 4: schedulable scenarios out of {}\n\
         SBP (no partitioning):    {}\n\
         SBP (50:50 partitioning): {}\n\
         partitioning recovers:    {}\n",
        r.total,
        r.sbp_plain,
        r.sbp_partitioned,
        r.sbp_partitioned as i64 - r.sbp_plain as i64,
    )
}

pub fn run() -> String {
    render(&compute())
}

/// Text + JSON for the CLI / bench harness (one `compute()` pass).
pub fn report() -> RunOutput {
    let r = compute();
    RunOutput {
        text: render(&r),
        payload: obj(vec![
            ("figure", Json::Str("fig04".into())),
            ("total", Json::Num(r.total as f64)),
            ("sbp_plain", Json::Num(r.sbp_plain as f64)),
            ("sbp_partitioned", Json::Num(r.sbp_partitioned as f64)),
        ]),
    }
}

/// Fig 4 as a CLI/bench-drivable experiment.
pub struct Experiment;

impl Runnable for Experiment {
    fn name(&self) -> &'static str {
        "fig04"
    }
    fn title(&self) -> &'static str {
        "SBP schedulability with/without 50:50 partitioning (1023 scenarios)"
    }
    fn bench_file(&self) -> &'static str {
        "BENCH_fig04_schedulability.json"
    }
    fn run(&self) -> RunOutput {
        report()
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn partitioning_recovers_scenarios() {
        let r = super::compute();
        assert_eq!(r.total, 1023);
        assert!(r.sbp_plain > 0);
        assert!(
            r.sbp_partitioned > r.sbp_plain,
            "partitioned {} !> plain {}",
            r.sbp_partitioned,
            r.sbp_plain
        );
    }
}
