//! Table 3/4/5 renderers — the static configuration tables of §6.1,
//! regenerated from the code's own catalog so docs can't drift.

use crate::models::{catalog, ModelId};
use crate::perfmodel::LatencyModel;
use crate::workload::named_scenarios;

/// Table 3: evaluated system specification (this repo's substitution).
pub fn table3() -> String {
    "# Table 3: evaluated system (substituted substrate)\n\
     paper: 4x RTX 2080 Ti (Turing, post-Volta MPS), PyTorch 1.2\n\
     here:  4 simulated GPUs (calibrated L(b,p) + interference ground\n\
     truth); real numerics via CPU PJRT executing AOT JAX/Pallas HLO\n\
     gpu-let sizes: 20/40/50/60/80/100%, max 2 per GPU\n"
        .to_string()
}

/// Table 4: the served models with SLOs and calibrated solo latencies.
pub fn table4() -> String {
    let lm = LatencyModel::new();
    let mut out = String::from(
        "# Table 4: served models\n\
         model           abbrev  SLO(ms)  solo b32 (ms)  need(32)\n",
    );
    for prof in catalog() {
        out.push_str(&format!(
            "{:<15} {:>6} {:>8.0} {:>14.1} {:>9.2}\n",
            prof.id.name(),
            prof.id.abbrev(),
            prof.slo_ms,
            lm.latency_ms(prof.id, 32, 1.0),
            prof.need(32),
        ));
    }
    out
}

/// Table 5: the named request scenarios.
pub fn table5() -> String {
    let mut out = String::from(
        "# Table 5: request scenarios (req/s)\n\
         scenario      le  goo  res  ssd  vgg\n",
    );
    for sc in named_scenarios() {
        out.push_str(&format!(
            "{:<11} {:>4.0} {:>4.0} {:>4.0} {:>4.0} {:>4.0}\n",
            sc.name,
            sc.rate(ModelId::Lenet),
            sc.rate(ModelId::Googlenet),
            sc.rate(ModelId::Resnet),
            sc.rate(ModelId::SsdMobilenet),
            sc.rate(ModelId::Vgg),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn tables_render() {
        assert!(super::table3().contains("gpu-let"));
        let t4 = super::table4();
        assert!(t4.contains("lenet") && t4.contains("136"));
        let t5 = super::table5();
        assert!(t5.contains("long-only"));
    }
}
