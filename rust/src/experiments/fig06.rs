//! Fig 6: CDF of interference-induced latency overhead across
//! consolidated pairs (10 model pairs x 5 batch sizes x 5 splits).
//! Paper headline: 90% of scenarios suffer < 18% overhead, with a long
//! tail — modest typically, severe occasionally.

use crate::experiments::common::{Runnable, RunOutput};
use crate::interference::ground_truth::{GroundTruth, TaskDemand};
use crate::models::{profile, ModelId};
use crate::util::json::{obj, Json};
use crate::util::stats;

/// All pairwise consolidation overheads (both sides of each pair), the
/// same population as §3.2.
pub fn overheads() -> Vec<f64> {
    let gt = GroundTruth::default();
    let splits = [(0.2, 0.8), (0.4, 0.6), (0.5, 0.5), (0.6, 0.4), (0.8, 0.2)];
    let batches = [2u32, 4, 8, 16, 32];
    let mut out = Vec::new();
    for (i, &m1) in ModelId::ALL.iter().enumerate() {
        for &m2 in &ModelId::ALL[i + 1..] {
            for &b in &batches {
                for &(p1, p2) in &splits {
                    let pr1 = profile(m1);
                    let pr2 = profile(m2);
                    let d1 = TaskDemand {
                        model: m1, batch: b,
                        l2: pr1.l2_util(p1, b), bw: pr1.bw_util(p1, b),
                    };
                    let d2 = TaskDemand {
                        model: m2, batch: b,
                        l2: pr2.l2_util(p2, b), bw: pr2.bw_util(p2, b),
                    };
                    let (f1, f2) = gt.pair_factors(&d1, &d2);
                    out.push(f1);
                    out.push(f2);
                }
            }
        }
    }
    out
}

pub fn run() -> String {
    render(&overheads())
}

pub fn render(ov: &[f64]) -> String {
    let mut out = format!(
        "# Fig 6: CDF of consolidation latency overhead ({} observations)\n\
         quantile  overhead%\n",
        ov.len()
    );
    for q in [10.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 100.0] {
        out.push_str(&format!(
            "{:>8.0} {:>9.1}\n",
            q,
            stats::percentile(ov, q) * 100.0
        ));
    }
    out.push_str(&format!(
        "share under 18% overhead: {:.1}% (paper: ~90%)\n",
        stats::cdf_at(ov, 0.18) * 100.0
    ));
    out
}

/// Text + JSON for the CLI / bench harness (one population pass).
pub fn report() -> RunOutput {
    let ov = overheads();
    let quantiles: Vec<Json> = [10.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 100.0]
        .iter()
        .map(|&q| {
            obj(vec![
                ("quantile", Json::Num(q)),
                ("overhead", Json::Num(stats::percentile(&ov, q))),
            ])
        })
        .collect();
    RunOutput {
        text: render(&ov),
        payload: obj(vec![
            ("figure", Json::Str("fig06".into())),
            ("observations", Json::Num(ov.len() as f64)),
            ("quantiles", Json::Arr(quantiles)),
            ("share_under_18pct", Json::Num(stats::cdf_at(&ov, 0.18))),
        ]),
    }
}

/// Fig 6 as a CLI/bench-drivable experiment.
pub struct Experiment;

impl Runnable for Experiment {
    fn name(&self) -> &'static str {
        "fig06"
    }
    fn title(&self) -> &'static str {
        "consolidation latency-overhead CDF (500 observations)"
    }
    fn bench_file(&self) -> &'static str {
        "BENCH_fig06_interference_cdf.json"
    }
    fn run(&self) -> RunOutput {
        report()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn population_size_matches_paper() {
        // 10 unordered pairs x 5 batches x 5 splits = 250 pairs, both
        // sides observed -> 500 overhead samples.
        assert_eq!(overheads().len(), 500);
    }

    #[test]
    fn modest_p90_long_tail() {
        let ov = overheads();
        let p90 = stats::percentile(&ov, 90.0);
        let max = ov.iter().fold(0.0f64, |a, &b| a.max(b));
        assert!(p90 < 0.30, "p90 {p90}");
        assert!(max > 1.4 * p90, "tail should extend well past p90 (max {max}, p90 {p90})");
        // Most of the mass is modest (paper: 90% < 18%).
        assert!(stats::cdf_at(&ov, 0.18) > 0.70, "p(overhead<18%) too small");
    }
}
