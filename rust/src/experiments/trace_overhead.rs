//! Tracing overhead: the same fleet run at three telemetry settings —
//! off (`trace_cap = 0`), sampled spans (1 in 1024), and full capture
//! (every span) — reporting events/s per arm and the relative wall
//! cost of turning the tracer on.
//!
//! Two invariants ride along with the timing and are asserted by the
//! tests (and recorded in the payload):
//!
//! * **Results are tracing-independent.** The merged report JSON and
//!   the routing counters are byte-identical across all three arms —
//!   telemetry observes the run, it never perturbs it.
//! * **The ledger reconciles.** The trace's exact event ledger (kept
//!   pre-sampling, `n`-weighted) matches the fleet's own accounting:
//!   `deal == dealt`, `batch-done == served`,
//!   `drop + timeout == dropped`, `lost == lost_to_failure`. The two
//!   sides are counted by independent code paths, so agreement means
//!   the trace is a faithful record, not an approximation.

use crate::config::Algo;
use crate::fleet::{FleetConfig, FleetEngine, FleetOutcome, FleetPlanner};
use crate::interference::GroundTruth;
use crate::perfmodel::LatencyModel;
use crate::sched::SchedCtx;
use crate::telemetry::EventKind;
use crate::util::json::{obj, Json};
use crate::workload::{dyn_sources, poisson_streams, SourceMux};

use super::common::{fitted_interference, Runnable, RunOutput};

/// Nodes in the measured fleet.
pub const NODES: usize = 2;

/// Trace length (s) per arm.
pub const DURATION_S: f64 = 120.0;

/// Ring capacity per tracer in the traced arms (the CLI default).
pub const TRACE_CAP: usize = 1 << 18;

/// One telemetry setting's measured run.
pub struct Arm {
    pub label: &'static str,
    /// Span-sampling modulus (0 = tracing off).
    pub sample_n: u64,
    pub outcome: FleetOutcome,
    pub wall_s: f64,
}

/// Run the fixed workload (equal scenario scaled per node) under one
/// telemetry setting.
pub fn compute(
    label: &'static str,
    trace_cap: usize,
    trace_sample: u64,
    nodes: usize,
    duration_s: f64,
    seed: u64,
) -> crate::error::Result<Arm> {
    let rates = [50.0 * nodes as f64; 5];
    let scheduler = Algo::Gpulet.scheduler();
    let ctx = SchedCtx::new(
        4,
        if scheduler.interference_aware() { Some(fitted_interference()) } else { None },
    );
    let planner = FleetPlanner::new(&ctx, scheduler.as_ref(), nodes);
    let plan = planner.plan(&rates)?;
    let pairs: Vec<_> = crate::models::ModelId::ALL
        .iter()
        .map(|&m| (m, rates[m.index()]))
        .collect();
    let streams = poisson_streams(&pairs, duration_s, seed)?;
    let lm = LatencyModel::new();
    let gt = GroundTruth::default();
    let cfg = FleetConfig { trace_cap, trace_sample, ..Default::default() };
    let mut engine = FleetEngine::new(
        &lm,
        &gt,
        planner,
        plan,
        SourceMux::new(dyn_sources(streams)),
        duration_s,
        &cfg,
    );
    let t0 = std::time::Instant::now();
    engine.run(duration_s);
    let outcome = engine.finish();
    let wall_s = t0.elapsed().as_secs_f64();
    Ok(Arm { label, sample_n: if trace_cap == 0 { 0 } else { trace_sample }, outcome, wall_s })
}

/// The three arms, in fixed order: off, sampled (1/1024), full (1/1).
pub fn arms(nodes: usize, duration_s: f64, seed: u64) -> crate::error::Result<Vec<Arm>> {
    Ok(vec![
        compute("off", 0, 1, nodes, duration_s, seed)?,
        compute("sampled", TRACE_CAP, 1024, nodes, duration_s, seed)?,
        compute("full", TRACE_CAP, 1, nodes, duration_s, seed)?,
    ])
}

fn events_per_s(a: &Arm) -> f64 {
    if a.wall_s > 0.0 {
        a.outcome.events_processed as f64 / a.wall_s
    } else {
        0.0
    }
}

/// Does the trace ledger agree with the fleet's own counters? (Always
/// vacuously true for the untraced arm.)
pub fn ledger_reconciles(out: &FleetOutcome) -> bool {
    if out.timeline.is_empty() {
        return true;
    }
    let tl = &out.timeline;
    let (served, dropped) = out.served_dropped();
    tl.count(EventKind::Deal) == out.offered.iter().sum::<u64>()
        && tl.count(EventKind::Arrival) == out.offered.iter().sum::<u64>()
        && tl.count(EventKind::Shed) == out.shed.iter().sum::<u64>()
        && tl.count(EventKind::Degrade) == out.degraded.iter().sum::<u64>()
        && tl.count(EventKind::BatchDone) == served.iter().sum::<u64>()
        && tl.count(EventKind::Drop) + tl.count(EventKind::Timeout)
            == dropped.iter().sum::<u64>()
        && tl.count(EventKind::Lost) == out.lost_to_failure().iter().sum::<u64>()
}

/// Serving results must be identical whatever the tracer does.
pub fn results_identical(arms: &[Arm]) -> bool {
    arms.windows(2).all(|w| {
        w[0].outcome.report.to_json().to_string() == w[1].outcome.report.to_json().to_string()
            && w[0].outcome.offered == w[1].outcome.offered
            && w[0].outcome.demand == w[1].outcome.demand
    })
}

/// Wall overhead of `arm` relative to the first (off) arm, in percent.
fn overhead_pct(arms: &[Arm], idx: usize) -> f64 {
    let base = arms[0].wall_s;
    if base > 0.0 {
        100.0 * (arms[idx].wall_s - base) / base
    } else {
        0.0
    }
}

pub fn render(arms: &[Arm]) -> String {
    let mut s = format!(
        "# trace_overhead: identical {NODES}-node fleet run ({DURATION_S:.0} s) at three \
         telemetry settings\n\
         arm       sample   events/s     wall_s   trace_events   dropped   reconciled\n",
    );
    for a in arms {
        let sample = if a.sample_n == 0 { "-".to_string() } else { format!("1/{}", a.sample_n) };
        s.push_str(&format!(
            "{:<9} {:>6} {:>10.0} {:>10.3} {:>14} {:>9} {:>12}\n",
            a.label,
            sample,
            events_per_s(a),
            a.wall_s,
            a.outcome.timeline.events.len(),
            a.outcome.timeline.dropped_events,
            if ledger_reconciles(&a.outcome) { "yes" } else { "NO" },
        ));
    }
    s.push_str(&format!(
        "overhead vs off: sampled {:+.1}%, full {:+.1}% wall\n\
         results identical across arms: {}\n",
        overhead_pct(arms, 1),
        overhead_pct(arms, 2),
        if results_identical(arms) { "yes" } else { "NO" },
    ));
    s
}

fn arm_json(a: &Arm) -> Json {
    obj(vec![
        ("arm", Json::Str(a.label.into())),
        ("sample_n", Json::Num(a.sample_n as f64)),
        ("wall_s", Json::Num(a.wall_s)),
        ("events_per_s", Json::Num(events_per_s(a))),
        ("events_processed", Json::Num(a.outcome.events_processed as f64)),
        ("trace_events", Json::Num(a.outcome.timeline.events.len() as f64)),
        ("dropped_events", Json::Num(a.outcome.timeline.dropped_events as f64)),
        ("ledger_reconciles", Json::Bool(ledger_reconciles(&a.outcome))),
    ])
}

/// Text + JSON for the CLI / bench harness.
pub fn report() -> RunOutput {
    let arms = arms(NODES, DURATION_S, 42).expect("equal scenario is plannable");
    RunOutput {
        text: render(&arms),
        payload: obj(vec![
            ("figure", Json::Str("trace_overhead".into())),
            ("overhead_sampled_pct", Json::Num(overhead_pct(&arms, 1))),
            ("overhead_full_pct", Json::Num(overhead_pct(&arms, 2))),
            ("results_identical", Json::Bool(results_identical(&arms))),
            ("arms", Json::Arr(arms.iter().map(arm_json).collect())),
        ]),
    }
}

/// Tracing overhead as a CLI/bench-drivable experiment.
pub struct Experiment;

impl Runnable for Experiment {
    fn name(&self) -> &'static str {
        "trace_overhead"
    }
    fn title(&self) -> &'static str {
        "telemetry cost: off vs sampled vs full-capture tracing"
    }
    fn bench_file(&self) -> &'static str {
        "BENCH_trace_overhead.json"
    }
    fn run(&self) -> RunOutput {
        report()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracing_never_perturbs_results_and_ledger_reconciles() {
        // A 1-node 30 s slice keeps the test quick; the full-size run
        // is the bench / CLI target.
        let arms = arms(1, 30.0, 7).unwrap();
        assert_eq!(arms.len(), 3);
        assert!(results_identical(&arms), "tracing changed the serving outcome");
        for a in &arms {
            assert!(a.outcome.conserved(), "arm {} lost requests", a.label);
            assert!(ledger_reconciles(&a.outcome), "arm {} ledger mismatch", a.label);
        }
        // The off arm records nothing; the traced arms record the same
        // exact ledger (sampling only thins the event stream).
        assert!(arms[0].outcome.timeline.is_empty());
        assert_eq!(arms[1].outcome.timeline.counts, arms[2].outcome.timeline.counts);
        assert!(
            arms[1].outcome.timeline.events.len() <= arms[2].outcome.timeline.events.len(),
            "sampled arm recorded more events than full capture"
        );
        assert!(arms[2].outcome.timeline.count(EventKind::Deal) > 1_000);
    }
}
