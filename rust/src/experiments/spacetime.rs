//! Space-time comparison: the Fig-12/13 scenario set rerun under the
//! three `SpaceTimeScheduler` modes (spatial-only / temporal-only /
//! combined), reporting each mode's maximum schedulable scale and its
//! highest zero-violation operating point (DESIGN.md §10).
//!
//! The structural claim this harness pins: combined is an acceptance
//! superset of spatial-only (it delegates to Elastic Partitioning and
//! only then tries temporal packing), so its schedulable scale and its
//! achieved throughput are >= spatial-only on every workload.

use crate::sched::{SchedCtx, Scheduler, SpaceTimeScheduler};
use crate::util::json::{obj, Json};

use super::common::{
    eval_workloads, max_schedulable, paper_ctx, scaled, violation_rate_of, Achieved,
    Runnable, RunOutput,
};

/// Mode order used by every `[_; 3]` array in this module.
pub const MODE_NAMES: [&str; 3] = ["spatial", "temporal", "combined"];

pub struct Row {
    pub workload: String,
    /// Pure-scheduler maximum schedulable scale per mode.
    pub schedulable: [f64; 3],
    /// Highest operating point holding the violation budget per mode.
    pub achieved: [Achieved; 3],
}

/// Descending probe grid from a scheduler-level maximum (same 24-point
/// convention as `common::max_achievable_detail`).
fn grid_from(k_max: f64) -> Vec<f64> {
    const GRID: usize = 24;
    if k_max <= 0.0 {
        return Vec::new();
    }
    (1..=GRID).rev().map(|i| k_max * i as f64 / GRID as f64).collect()
}

/// Highest grid scale whose deployment holds `viol_budget` (grid is
/// descending, so the first hit wins).
fn achieved_on(
    ctx: &SchedCtx,
    scheduler: &dyn Scheduler,
    base: &[f64; 5],
    grid: &[f64],
    viol_budget: f64,
    sim_duration_s: f64,
) -> Achieved {
    let total_base: f64 = base.iter().sum();
    for &k in grid {
        let rates = scaled(base, k);
        if let Ok(s) = scheduler.schedule(ctx, &rates) {
            let v = violation_rate_of(ctx, &s, &rates, sim_duration_s, 99);
            if v <= viol_budget {
                return Achieved {
                    scale: k,
                    total_rps: k * total_base,
                    violation_rate: Some(v),
                };
            }
        }
    }
    Achieved { scale: 0.0, total_rps: 0.0, violation_rate: None }
}

pub fn compute(viol_budget: f64, sim_duration_s: f64) -> Vec<Row> {
    // Every spacetime mode plans interference-aware (the temporal
    // feasibility check inflates duty cycles by predicted interference).
    let ctx = paper_ctx(true);
    let modes = [
        SpaceTimeScheduler::spatial_only(),
        SpaceTimeScheduler::temporal_only(),
        SpaceTimeScheduler::combined(),
    ];

    // Workloads are independent: fan out over the worker pool; rows
    // come back in workload order regardless of thread count.
    let workloads = eval_workloads();
    let probed = crate::util::par::par_map(&workloads, |(_, base)| {
        let k_sp = max_schedulable(&ctx, &modes[0], base);
        let k_tm = max_schedulable(&ctx, &modes[1], base);
        // Combined accepts everything spatial-only does (elastic-first
        // delegation), so its schedulable scale is >= spatial's; the
        // max() keeps that structural against bisection round-off.
        let k_cb = max_schedulable(&ctx, &modes[2], base).max(k_sp);

        let sp = achieved_on(&ctx, &modes[0], base, &grid_from(k_sp), viol_budget, sim_duration_s);
        let tm = achieved_on(&ctx, &modes[1], base, &grid_from(k_tm), viol_budget, sim_duration_s);
        // Probe combined on the union of its own grid and spatial's: at
        // every spatial grid point combined emits the identical
        // (delegated) schedule, so its zero-violation operating point
        // can never land below spatial's.
        let mut union = grid_from(k_cb);
        union.extend(grid_from(k_sp));
        union.sort_by(|a, b| b.total_cmp(a));
        union.dedup();
        let cb = achieved_on(&ctx, &modes[2], base, &union, viol_budget, sim_duration_s);
        ([k_sp, k_tm, k_cb], [sp, tm, cb])
    });
    workloads
        .into_iter()
        .zip(probed)
        .map(|((name, _), (schedulable, achieved))| Row {
            workload: name,
            schedulable,
            achieved,
        })
        .collect()
}

pub fn render(rows: &[Row]) -> String {
    let mut out = String::from(
        "# Space-time gpu-lets: spatial vs temporal vs combined\n\
         workload      mode       k_sched  k_achieved  rps_achieved  viol\n",
    );
    for r in rows {
        for (i, mode) in MODE_NAMES.iter().enumerate() {
            let viol = match r.achieved[i].violation_rate {
                Some(v) => format!("{:.2}%", v * 100.0),
                None => "-".to_string(),
            };
            out.push_str(&format!(
                "{:<12} {:<9} {:>8.2} {:>11.2} {:>13.0} {:>6}\n",
                r.workload, mode, r.schedulable[i], r.achieved[i].scale,
                r.achieved[i].total_rps, viol
            ));
        }
    }
    let strict: Vec<&str> = rows
        .iter()
        .filter(|r| r.schedulable[2] > r.schedulable[0] * (1.0 + 1e-6))
        .map(|r| r.workload.as_str())
        .collect();
    out.push_str(&format!(
        "(combined >= spatial on every workload; strictly higher schedulable scale on: {})\n",
        if strict.is_empty() { "none".to_string() } else { strict.join(", ") }
    ));
    out
}

pub fn run() -> String {
    render(&compute(0.0, 12.0))
}

/// Text + JSON for the CLI / bench harness (one `compute()` pass at a
/// zero violation budget: every reported operating point serves with no
/// SLO violations at all).
pub fn report() -> RunOutput {
    let rows = compute(0.0, 12.0);
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut fields: Vec<(&str, Json)> =
                vec![("workload", Json::Str(r.workload.clone()))];
            for (i, &mode) in MODE_NAMES.iter().enumerate() {
                fields.push((
                    mode,
                    obj(vec![
                        ("max_schedulable_scale", Json::Num(r.schedulable[i])),
                        ("achieved_scale", Json::Num(r.achieved[i].scale)),
                        ("achieved_rps", Json::Num(r.achieved[i].total_rps)),
                        (
                            "violation_rate",
                            match r.achieved[i].violation_rate {
                                Some(v) => Json::Num(v),
                                None => Json::Null,
                            },
                        ),
                    ]),
                ));
            }
            obj(fields)
        })
        .collect();
    let combined_ge_spatial = rows.iter().all(|r| {
        r.schedulable[2] >= r.schedulable[0] - 1e-9
            && r.achieved[2].total_rps >= r.achieved[0].total_rps - 1e-9
    });
    let strict: Vec<Json> = rows
        .iter()
        .filter(|r| {
            r.schedulable[2] > r.schedulable[0] * (1.0 + 1e-6)
                || r.achieved[2].total_rps > r.achieved[0].total_rps + 1e-6
        })
        .map(|r| Json::Str(r.workload.clone()))
        .collect();
    RunOutput {
        text: render(&rows),
        payload: obj(vec![
            ("figure", Json::Str("spacetime".into())),
            ("viol_budget", Json::Num(0.0)),
            ("combined_ge_spatial", Json::Bool(combined_ge_spatial)),
            ("strict_gain_workloads", Json::Arr(strict)),
            ("rows", Json::Arr(json_rows)),
        ]),
    }
}

/// The three-mode comparison as a CLI/bench-drivable experiment.
pub struct Experiment;

impl Runnable for Experiment {
    fn name(&self) -> &'static str {
        "spacetime"
    }
    fn title(&self) -> &'static str {
        "space-time scheduling: spatial vs temporal vs combined modes"
    }
    fn bench_file(&self) -> &'static str {
        "BENCH_spacetime_modes.json"
    }
    fn run(&self) -> RunOutput {
        report()
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn combined_dominates_spatial_with_zero_violations() {
        let rows = super::compute(0.0, 6.0);
        assert_eq!(rows.len(), 5);
        for r in &rows {
            assert!(
                r.schedulable[2] >= r.schedulable[0] - 1e-9,
                "{}: combined schedulable {} < spatial {}",
                r.workload,
                r.schedulable[2],
                r.schedulable[0]
            );
            assert!(
                r.achieved[2].total_rps >= r.achieved[0].total_rps - 1e-9,
                "{}: combined achieved {} < spatial {}",
                r.workload,
                r.achieved[2].total_rps,
                r.achieved[0].total_rps
            );
            // A zero violation budget means every reported operating
            // point serves with literally no violations.
            for (a, mode) in r.achieved.iter().zip(super::MODE_NAMES) {
                if let Some(v) = a.violation_rate {
                    assert_eq!(v, 0.0, "{} {mode}: nonzero violations reported", r.workload);
                }
            }
        }
    }
}
