//! Fig 5: SLO violation rate of LeNet + VGG-16 consolidated on one GPU
//! under Temporal-Sharing, MPS(default), and MPS(20:80) static
//! partitioning, as the offered rate rises. Paper result: the statically
//! partitioned gpu-lets sustain far higher rates before violating.

use crate::coordinator::simserver::{simulate, SimConfig};
use crate::experiments::common::{Runnable, RunOutput};
use crate::gpu::gpulet::GpuLetSpec;
use crate::gpu::ShareMode;
use crate::interference::GroundTruth;
use crate::models::ModelId;
use crate::perfmodel::LatencyModel;
use crate::sched::types::{Assignment, LetPlan, Schedule};
use crate::util::json::{obj, Json};
use crate::workload::generate_arrivals;

/// The consolidated deployment: LeNet on 20%, VGG on 80% (one GPU).
fn deployment(lm: &LatencyModel, lenet_rate: f64, vgg_rate: f64) -> Schedule {
    let b_le = lm
        .max_batch_within(ModelId::Lenet, 0.2, lm.slo_ms(ModelId::Lenet) / 2.0)
        .unwrap_or(1);
    let b_vg = lm
        .max_batch_within(ModelId::Vgg, 0.8, lm.slo_ms(ModelId::Vgg) / 2.0)
        .unwrap_or(1);
    Schedule {
        lets: vec![
            LetPlan {
                spec: GpuLetSpec { gpu: 0, size_pct: 20 },
                assignments: vec![Assignment {
                    model: ModelId::Lenet,
                    batch: b_le,
                    rate: lenet_rate,
                }],
            },
            LetPlan {
                spec: GpuLetSpec { gpu: 0, size_pct: 80 },
                assignments: vec![Assignment { model: ModelId::Vgg, batch: b_vg, rate: vgg_rate }],
            },
        ],
    }
}

pub struct Row {
    pub rate_each: f64,
    pub temporal: f64,
    pub mps_default: f64,
    pub partitioned: f64,
}

pub fn compute(rates: &[f64]) -> Vec<Row> {
    let lm = LatencyModel::new();
    let gt = GroundTruth::default();
    let duration = 15.0;
    rates
        .iter()
        .map(|&r| {
            let schedule = deployment(&lm, r, r);
            let arrivals = generate_arrivals(
                &[(ModelId::Lenet, r), (ModelId::Vgg, r)],
                duration,
                21,
            )
            .expect("fig05 sweep rates are finite");
            let mut viol = [0.0; 3];
            for (i, mode) in [
                ShareMode::TemporalOnly,
                ShareMode::MpsDefault,
                ShareMode::Partitioned,
            ]
            .iter()
            .enumerate()
            {
                let report = simulate(
                    &lm, &gt, &schedule, &arrivals, duration,
                    &SimConfig { mode: *mode, ..Default::default() },
                );
                viol[i] = report.overall_violation_rate();
            }
            Row { rate_each: r, temporal: viol[0], mps_default: viol[1], partitioned: viol[2] }
        })
        .collect()
}

pub fn default_rates() -> Vec<f64> {
    vec![25.0, 50.0, 100.0, 150.0, 200.0, 300.0, 400.0]
}

pub fn render(rows: &[Row]) -> String {
    let mut out = String::from(
        "# Fig 5: SLO violation %, LeNet+VGG consolidated on one GPU\n\
         rate(req/s each)  temporal  mps-default  mps(20:80)\n",
    );
    for row in rows {
        out.push_str(&format!(
            "{:>16.0} {:>9.1} {:>12.1} {:>11.1}\n",
            row.rate_each,
            row.temporal * 100.0,
            row.mps_default * 100.0,
            row.partitioned * 100.0,
        ));
    }
    out
}

pub fn run() -> String {
    render(&compute(&default_rates()))
}

/// Text + JSON for the CLI / bench harness (one `compute()` pass).
pub fn report() -> RunOutput {
    let rows = compute(&default_rates());
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            obj(vec![
                ("rate_each_rps", Json::Num(r.rate_each)),
                ("temporal", Json::Num(r.temporal)),
                ("mps_default", Json::Num(r.mps_default)),
                ("partitioned", Json::Num(r.partitioned)),
            ])
        })
        .collect();
    RunOutput {
        text: render(&rows),
        payload: obj(vec![
            ("figure", Json::Str("fig05".into())),
            ("rows", Json::Arr(json_rows)),
        ]),
    }
}

/// Fig 5 as a CLI/bench-drivable experiment.
pub struct Experiment;

impl Runnable for Experiment {
    fn name(&self) -> &'static str {
        "fig05"
    }
    fn title(&self) -> &'static str {
        "sharing-mode SLO violation sweep (temporal vs MPS vs partitioned)"
    }
    fn bench_file(&self) -> &'static str {
        "BENCH_fig05_sharing_modes.json"
    }
    fn run(&self) -> RunOutput {
        report()
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn partitioned_sustains_higher_rates() {
        // At a rate where temporal sharing collapses, static partitioning
        // must stay low — the Fig 5 ordering.
        let rows = super::compute(&[150.0, 300.0]);
        let hi = &rows[1];
        assert!(
            hi.partitioned < hi.temporal,
            "partitioned {} !< temporal {}",
            hi.partitioned,
            hi.temporal
        );
        assert!(hi.partitioned < 0.05, "partitioned violates: {}", hi.partitioned);
        assert!(hi.temporal > 0.10, "temporal should be violating at 300 req/s");
    }
}
