//! Fault recovery under a flash crowd: kill 1 of 4 nodes mid-swell and
//! compare admission policies (PR 9's robustness headline).
//!
//! The scenario composes the three new robustness pieces end to end: a
//! [`FlashCrowdSpec`] drives an equal-mix workload from a comfortable
//! quiet load (~60% of what the fleet can schedule) to a 3x peak the
//! fleet *cannot* hold, a scripted [`FaultPlan`] takes one node down in
//! the middle of the crowd's hold phase and recovers it two windows
//! before the crowd subsides, and the same trace runs three times —
//! admission `off` (admit everything, the pre-PR-9 behaviour), `shed`
//! (refuse the slice the active plan cannot serve), and `degrade`
//! (rewrite that slice to the cheapest model instead).
//!
//! The payload records, per mode, the full conservation ledger
//! (`demand = offered + shed`, `offered = served + dropped + lost`),
//! the re-plan failure count (the peak is deliberately infeasible, so
//! failover re-planning *must* fall back to the stale plan and say so),
//! the recovery time (node-down until the first post-recovery window
//! back under 5% violations), and the headline metric: **SLO attainment
//! of admitted traffic**, which shedding or degrading must raise over
//! the admit-everything baseline — that ordering is what
//! `BENCH_fault_recovery.json` tracks across PRs.

use crate::config::Algo;
use crate::fleet::{
    AdmissionMode, AdmissionSpec, FleetConfig, FleetEngine, FleetOutcome, FleetPlanner,
};
use crate::interference::GroundTruth;
use crate::models::ModelId;
use crate::perfmodel::LatencyModel;
use crate::sched::SchedCtx;
use crate::util::json::{obj, Json};
use crate::workload::{
    dyn_sources, flashcrowd_streams, FaultEvent, FaultKind, FaultPlan, FlashCrowdSpec,
    SourceMux,
};

use super::common::{max_schedulable, paper_ctx, Runnable, RunOutput};

/// Fleet size; the fault kills one of these nodes.
pub const NODES: usize = 4;

/// Full-scale trace length (s); tests run a shorter slice.
pub const DURATION_S: f64 = 240.0;

/// Crowd peak as a multiple of the base rates: 3x of a 60%-utilized
/// fleet is a 1.8x overload — infeasible by design, so the admission
/// gate has real work even before the node dies.
pub const PEAK_MULT: f64 = 3.0;

/// Quiet-phase fraction of the fleet's maximum schedulable load.
const BASE_UTIL: f64 = 0.6;

/// Post-recovery "healthy again" threshold on the per-window violation
/// rate of admitted traffic.
const RECOVERY_VIOL: f64 = 0.05;

/// Base (quiet-phase) rates: the equal-mix scenario scaled so NODES
/// nodes sit at ~`BASE_UTIL` of their schedulable limit — derived from
/// the scheduler itself rather than hard-coded, so the overload factor
/// survives capacity-model changes.
pub fn base_rates() -> [f64; 5] {
    let ctx = paper_ctx(false);
    let sched = Algo::Gpulet.scheduler();
    let k = max_schedulable(&ctx, sched.as_ref(), &[50.0; 5]);
    let mut base = [50.0; 5];
    base.iter_mut().for_each(|r| *r *= k * BASE_UTIL * NODES as f64);
    base
}

/// Crowd timeline as fractions of the run: quiet quarter, 1/8 ramp up,
/// quarter hold at peak, 1/8 ramp down, quiet tail.
fn crowd_spec(base: [f64; 5], duration_s: f64) -> FlashCrowdSpec {
    FlashCrowdSpec {
        base,
        peak_mult: PEAK_MULT,
        t_start_s: 0.25 * duration_s,
        ramp_s: 0.125 * duration_s,
        hold_s: 0.25 * duration_s,
    }
}

/// The admission policy under test: `degrade` falls back to LeNet (the
/// cheapest model) for every other model, mirroring the CLI default.
fn admission_for(mode: AdmissionMode) -> AdmissionSpec {
    let mut spec = AdmissionSpec { mode, ..AdmissionSpec::default() };
    if mode == AdmissionMode::Degrade {
        for m in ModelId::ALL {
            if m != ModelId::Lenet {
                spec.fallback[m.index()] = Some(ModelId::Lenet);
            }
        }
    }
    spec
}

pub fn mode_name(mode: AdmissionMode) -> &'static str {
    match mode {
        AdmissionMode::Off => "off",
        AdmissionMode::Shed => "shed",
        AdmissionMode::Degrade => "degrade",
    }
}

/// One admission mode's run over the identical trace and fault script.
pub struct ModeRun {
    pub mode: AdmissionMode,
    /// When the node died (s).
    pub t_down_s: f64,
    /// When it recovered (s).
    pub t_up_s: f64,
    pub outcome: FleetOutcome,
    pub wall_s: f64,
}

impl ModeRun {
    /// Time from the node's death until the first whole post-recovery
    /// window back under [`RECOVERY_VIOL`] violations; negative when the
    /// run never got healthy again before the trace ended.
    pub fn recovery_s(&self) -> f64 {
        for w in &self.outcome.windows {
            if w.t_start_s >= self.t_up_s && w.violation_rate <= RECOVERY_VIOL {
                return w.t_start_s + w.window_s - self.t_down_s;
            }
        }
        -1.0
    }

    pub fn attainment(&self) -> f64 {
        self.outcome.report.admitted_slo_attainment()
    }
}

/// Run the kill-1-of-NODES flash-crowd trace under one admission mode.
/// The fault script is a pure function of the duration: down at 45% of
/// the run (mid-hold), up at 65% (two 10 s windows before the crowd
/// fully subsides at full scale).
pub fn compute(
    mode: AdmissionMode,
    duration_s: f64,
    seed: u64,
) -> crate::error::Result<ModeRun> {
    let base = base_rates();
    let spec = crowd_spec(base, duration_s);
    let scheduler = Algo::Gpulet.scheduler();
    let ctx = SchedCtx::new(4, None);
    let planner = FleetPlanner::new(&ctx, scheduler.as_ref(), NODES);
    let plan = planner.plan(&base)?;
    let streams = flashcrowd_streams(&spec, duration_s, 1.0, seed)?;
    let lm = LatencyModel::new();
    let gt = GroundTruth::default();
    let mut cfg = FleetConfig::default();
    // 10 s windows: the gate re-aims and the failover re-plans twice as
    // often as the default 20 s cadence — recovery time is measured in
    // these windows.
    cfg.window_s = 10.0;
    let mut engine = FleetEngine::new(
        &lm,
        &gt,
        planner,
        plan,
        SourceMux::new(dyn_sources(streams)),
        duration_s,
        &cfg,
    );
    let t_down_s = 0.45 * duration_s;
    let t_up_s = 0.65 * duration_s;
    engine.set_fault_plan(FaultPlan::new(vec![
        FaultEvent { at_s: t_down_s, node: NODES - 1, kind: FaultKind::Down },
        FaultEvent { at_s: t_up_s, node: NODES - 1, kind: FaultKind::Up },
    ])?)?;
    engine.set_admission(admission_for(mode));
    let t0 = std::time::Instant::now();
    engine.run(duration_s);
    let outcome = engine.finish();
    Ok(ModeRun { mode, t_down_s, t_up_s, outcome, wall_s: t0.elapsed().as_secs_f64() })
}

/// All three admission arms over the identical trace + fault script.
pub fn matrix(duration_s: f64, seed: u64) -> Vec<ModeRun> {
    [AdmissionMode::Off, AdmissionMode::Shed, AdmissionMode::Degrade]
        .into_iter()
        .map(|mode| {
            compute(mode, duration_s, seed)
                .expect("fault_recovery base rates are plannable")
        })
        .collect()
}

pub fn render(runs: &[ModeRun]) -> String {
    let mut s = String::from(
        "# fault_recovery: 4-node fleet, flash crowd to 1.8x capacity,\n\
         # node 3 down at 45% / up at 65% of the run — per admission mode\n\
         mode       demand  offered     shed degraded   served  dropped     lost \
         replans  attain%  recover_s\n",
    );
    for r in runs {
        let (served, dropped) = r.outcome.served_dropped();
        s.push_str(&format!(
            "{:<8} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8} {:>7} {:>8.2} {:>10.1}\n",
            mode_name(r.mode),
            r.outcome.demand.iter().sum::<u64>(),
            r.outcome.offered.iter().sum::<u64>(),
            r.outcome.shed.iter().sum::<u64>(),
            r.outcome.degraded.iter().sum::<u64>(),
            served.iter().sum::<u64>(),
            dropped.iter().sum::<u64>(),
            r.outcome.lost_to_failure().iter().sum::<u64>(),
            r.outcome.replan_failures,
            r.attainment() * 100.0,
            r.recovery_s(),
        ));
    }
    s
}

pub fn run() -> String {
    render(&matrix(DURATION_S, 2024))
}

fn mode_json(r: &ModeRun) -> Json {
    let (served, dropped) = r.outcome.served_dropped();
    obj(vec![
        ("mode", Json::Str(mode_name(r.mode).into())),
        ("demand", Json::Num(r.outcome.demand.iter().sum::<u64>() as f64)),
        ("offered", Json::Num(r.outcome.offered.iter().sum::<u64>() as f64)),
        ("shed", Json::Num(r.outcome.shed.iter().sum::<u64>() as f64)),
        ("degraded", Json::Num(r.outcome.degraded.iter().sum::<u64>() as f64)),
        ("served", Json::Num(served.iter().sum::<u64>() as f64)),
        ("dropped", Json::Num(dropped.iter().sum::<u64>() as f64)),
        (
            "lost_to_failure",
            Json::Num(r.outcome.lost_to_failure().iter().sum::<u64>() as f64),
        ),
        ("rebalances", Json::Num(r.outcome.rebalances as f64)),
        ("replan_failures", Json::Num(r.outcome.replan_failures as f64)),
        ("conserved", Json::Bool(r.outcome.conserved())),
        (
            "violation_share",
            Json::Num(r.outcome.report.overall_violation_rate()),
        ),
        ("admitted_slo_attainment", Json::Num(r.attainment())),
        ("t_down_s", Json::Num(r.t_down_s)),
        ("t_up_s", Json::Num(r.t_up_s)),
        ("recovery_s", Json::Num(r.recovery_s())),
        ("wall_s", Json::Num(r.wall_s)),
    ])
}

/// Text + JSON for the CLI / bench harness.
pub fn report() -> RunOutput {
    let runs = matrix(DURATION_S, 2024);
    let attain_of = |m: AdmissionMode| {
        runs.iter().find(|r| r.mode == m).map_or(0.0, ModeRun::attainment)
    };
    let off = attain_of(AdmissionMode::Off);
    let shed = attain_of(AdmissionMode::Shed);
    let degrade = attain_of(AdmissionMode::Degrade);
    RunOutput {
        text: render(&runs),
        payload: obj(vec![
            ("figure", Json::Str("fault_recovery".into())),
            ("nodes", Json::Num(NODES as f64)),
            ("duration_s", Json::Num(DURATION_S)),
            ("peak_mult", Json::Num(PEAK_MULT)),
            ("attainment_off", Json::Num(off)),
            ("attainment_shed", Json::Num(shed)),
            ("attainment_degrade", Json::Num(degrade)),
            ("shed_minus_off", Json::Num(shed - off)),
            ("degrade_minus_off", Json::Num(degrade - off)),
            ("modes", Json::Arr(runs.iter().map(mode_json).collect())),
        ]),
    }
}

/// Fault recovery as a CLI/bench-drivable experiment.
pub struct Experiment;

impl Runnable for Experiment {
    fn name(&self) -> &'static str {
        "fault_recovery"
    }
    fn title(&self) -> &'static str {
        "kill 1 of 4 nodes mid-flash-crowd; admission off vs shed vs degrade"
    }
    fn bench_file(&self) -> &'static str {
        "BENCH_fault_recovery.json"
    }
    fn run(&self) -> RunOutput {
        report()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A 120 s slice keeps the test quick; the 240 s ladder is the
    /// bench / CLI target. Admission off must visibly suffer (drops +
    /// losses) and shedding must beat it on admitted SLO attainment —
    /// the experiment's headline claim.
    #[test]
    fn crowd_plus_fault_overload_and_shedding_raises_attainment() {
        let off = compute(AdmissionMode::Off, 120.0, 7).unwrap();
        let shed = compute(AdmissionMode::Shed, 120.0, 7).unwrap();
        assert!(off.outcome.conserved(), "off-mode ledger must balance");
        assert!(shed.outcome.conserved(), "shed-mode ledger must balance");
        // The scenario is a genuine overload + fault: the baseline
        // drops work, and the dead node destroys in-flight work.
        let (_, dropped) = off.outcome.served_dropped();
        assert!(dropped.iter().sum::<u64>() > 0, "1.8x peak must force drops");
        assert!(
            off.outcome.lost_to_failure().iter().sum::<u64>() > 0,
            "node death must lose queued/in-flight work"
        );
        assert_eq!(off.outcome.shed, [0u64; 5], "gate off must never shed");
        // The gate actually engaged, and admitted traffic fared better.
        assert!(
            shed.outcome.shed.iter().sum::<u64>() > 0,
            "shed gate must refuse part of the 1.8x peak"
        );
        assert!(
            shed.attainment() > off.attainment(),
            "shedding must raise admitted SLO attainment: {} vs {}",
            shed.attainment(),
            off.attainment()
        );
        // Determinism: same mode, same seed, same ledger.
        let again = compute(AdmissionMode::Off, 120.0, 7).unwrap();
        assert_eq!(off.outcome.demand, again.outcome.demand);
        assert_eq!(off.outcome.offered, again.outcome.offered);
        assert_eq!(
            off.outcome.report.to_json().to_string(),
            again.outcome.report.to_json().to_string()
        );
    }

    #[test]
    fn degrade_mode_rewrites_and_beats_the_baseline() {
        let off = compute(AdmissionMode::Off, 120.0, 7).unwrap();
        let deg = compute(AdmissionMode::Degrade, 120.0, 7).unwrap();
        assert!(deg.outcome.conserved(), "degrade-mode ledger must balance");
        assert!(
            deg.outcome.degraded.iter().sum::<u64>() > 0,
            "overload must trigger fallback rewrites"
        );
        // LeNet is the fallback, never degraded itself.
        assert_eq!(deg.outcome.degraded[ModelId::Lenet.index()], 0);
        assert!(
            deg.attainment() > off.attainment(),
            "degrading must raise admitted SLO attainment: {} vs {}",
            deg.attainment(),
            off.attainment()
        );
    }

    #[test]
    fn recovery_is_observed_after_the_node_returns() {
        let shed = compute(AdmissionMode::Shed, 120.0, 7).unwrap();
        // Service continued after the node's return: post-recovery
        // windows still deal traffic.
        let post: u64 = shed
            .outcome
            .windows
            .iter()
            .filter(|w| w.t_start_s >= shed.t_up_s)
            .map(|w| w.offered.iter().sum::<u64>())
            .sum();
        assert!(post > 0, "no traffic dealt after the node recovered");
        let rec = shed.recovery_s();
        assert!(
            rec < 0.0 || rec >= shed.t_up_s - shed.t_down_s,
            "recovery cannot precede the node's return: {rec}"
        );
    }
}
