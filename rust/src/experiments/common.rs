//! Shared experiment machinery: scenario vocabulary, schedulability +
//! maximum-achievable-throughput search (the measurement procedure of
//! §6.2: "gradually increasing the request rate until SLO violation"),
//! and the `Runnable` harness contract that lets the CLI (`gpulets
//! run-fig N`), the bench targets, and the tests drive one shared code
//! path per figure.

use crate::apps::App;
use crate::coordinator::engine::ServingEngine;
use crate::coordinator::simserver::SimConfig;
use crate::interference::linear_model::{
    profiling_population, train_val_split, InterferenceModel,
};
use crate::interference::GroundTruth;
use crate::models::ModelId;
use crate::sched::{SchedCtx, Schedule, Scheduler};
use crate::util::benchkit;
use crate::util::json::Json;
use crate::workload::{
    dyn_sources, named_scenarios, poisson_streams, DynSourceMux, Scenario, SourceMux,
};

/// Result of one experiment run: the human-readable report plus the
/// structured payload written to the experiment's BENCH file.
pub struct RunOutput {
    /// What `gpulets run-fig N` prints (same rows the paper reports).
    pub text: String,
    /// Machine-readable result, diffed across PRs for perf trajectory.
    pub payload: Json,
}

/// A paper experiment drivable by the CLI and the bench targets.
///
/// Implementations live next to each figure module (`fig03::Experiment`
/// … `fig16::Experiment`); `crate::experiments::registry()` lists them.
pub trait Runnable {
    /// Short name, e.g. `"fig12"`.
    fn name(&self) -> &'static str;
    /// One-line description for `gpulets run-fig list`.
    fn title(&self) -> &'static str;
    /// BENCH artifact file name, e.g. `"BENCH_fig12_throughput.json"`.
    fn bench_file(&self) -> &'static str;
    /// Execute at full (paper) scale.
    fn run(&self) -> RunOutput;
}

/// Drive one experiment the way the bench targets do: time it, print
/// the timing summary + text report, write the BENCH envelope. Returns
/// the bench file path.
pub fn run_and_write(
    exp: &dyn Runnable,
    warmup: usize,
    iters: usize,
) -> crate::error::Result<String> {
    let label = format!("{}: {}", exp.name(), exp.title());
    let (timing, out) = benchkit::bench(&label, warmup, iters, || exp.run());
    println!("{}", timing.summary());
    println!("\n{}", out.text);
    benchkit::write_json(exp.bench_file(), &benchkit::envelope(&timing, out.payload))?;
    eprintln!("[wrote {}]", exp.bench_file());
    Ok(exp.bench_file().to_string())
}

/// The five evaluation workloads of Fig 12/13/16: two multi-model apps
/// plus the three Table 5 request scenarios. Each yields a base
/// per-model rate vector that the throughput search scales uniformly.
pub fn eval_workloads() -> Vec<(String, [f64; 5])> {
    let mut out = Vec::new();
    // Apps evaluated at a unit rate of 10 req/s (scaled by the search).
    for app in [App::game(), App::traffic()] {
        out.push((app.name.to_string(), app.induced_rates(10.0)));
    }
    for sc in named_scenarios() {
        out.push((sc.name.clone(), sc.rates));
    }
    out
}

/// Build the standard interference-aware context: fit the linear model
/// on the profiled population exactly like §4.4 (70/30 split, seed 42).
pub fn fitted_interference() -> InterferenceModel {
    let gt = GroundTruth::default();
    let (train, _) = train_val_split(profiling_population(&gt), 0.7, 42);
    InterferenceModel::fit(&train).expect("interference fit")
}

/// Context factory for a paper-testbed cluster.
pub fn paper_ctx(interference_aware: bool) -> SchedCtx {
    SchedCtx::new(4, if interference_aware { Some(fitted_interference()) } else { None })
}

/// Scale a rate vector.
pub fn scaled(rates: &[f64; 5], k: f64) -> [f64; 5] {
    let mut out = *rates;
    out.iter_mut().for_each(|r| *r *= k);
    out
}

/// Per-model Poisson streams for an experiment rate vector — the
/// probe workload, pulled by the engine one arrival at a time (no
/// trace vector, no global sort).
fn probe_source(rates: &[f64; 5], duration_s: f64, seed: u64) -> DynSourceMux {
    let pairs: Vec<(ModelId, f64)> = ModelId::ALL
        .iter()
        .map(|&m| (m, rates[m.index()]))
        .filter(|&(_, r)| r > 0.0)
        .collect();
    let streams =
        poisson_streams(&pairs, duration_s, seed).expect("experiment rates are finite");
    SourceMux::new(dyn_sources(streams))
}

/// THE probe convention, shared by `violation_rate_of` (Fig 13) and
/// `max_achievable_detail` (Figs 12/16) so the two paths can never
/// measure violations differently: reset the engine (true-SLO latency
/// model, default `SimConfig` — the caller constructed it that way),
/// stream the Poisson workload through it, and read the overall
/// violation rate (drops included).
fn probe_violation_on(
    engine: &mut ServingEngine<'_>,
    schedule: Schedule,
    rates: &[f64; 5],
    duration_s: f64,
    seed: u64,
) -> f64 {
    engine.reset(schedule, duration_s);
    engine.attach_source(probe_source(rates, duration_s, seed));
    engine.run_stream();
    engine.close();
    engine.report().overall_violation_rate()
}

/// Run one schedule against a Poisson trace of `rates` and return the
/// SLO violation rate (drops included). The trace streams through the
/// engine — same per-stream draws and report as the old materialized
/// path, byte for byte.
pub fn violation_rate_of(
    _ctx: &SchedCtx,
    schedule: &Schedule,
    rates: &[f64; 5],
    duration_s: f64,
    seed: u64,
) -> f64 {
    let gt = GroundTruth::default();
    // Measure against the TRUE SLOs (the ctx's planning view is
    // tightened by SLO_PLANNING_SCALE).
    let lm_true = crate::perfmodel::LatencyModel::new();
    let cfg = SimConfig::default();
    let mut engine =
        ServingEngine::new(&lm_true, &gt, Schedule::default(), duration_s, &cfg);
    probe_violation_on(&mut engine, schedule.clone(), rates, duration_s, seed)
}

/// Detailed outcome of the maximum-achievable-throughput search.
#[derive(Clone, Copy, Debug)]
pub struct Achieved {
    /// Uniform scale of the base rate vector.
    pub scale: f64,
    /// Total achieved throughput (req/s summed over models).
    pub total_rps: f64,
    /// Measured SLO violation rate (drops included) at that scale;
    /// `None` when the search found no acceptable deployment — either
    /// nothing was schedulable, or every probed scale exceeded the
    /// violation budget.
    pub violation_rate: Option<f64>,
}

/// Maximum achievable throughput (req/s summed over models): largest
/// uniform scale of `base` that (a) the scheduler accepts and (b) the
/// simulated deployment serves with <= `viol_budget` violations.
/// Returns (scale, total_rate); `max_achievable_detail` also reports
/// the violation rate measured at the accepted scale.
pub fn max_achievable(
    ctx: &SchedCtx,
    scheduler: &dyn Scheduler,
    base: &[f64; 5],
    viol_budget: f64,
    sim_duration_s: f64,
) -> (f64, f64) {
    let a = max_achievable_detail(ctx, scheduler, base, viol_budget, sim_duration_s);
    (a.scale, a.total_rps)
}

/// See [`max_achievable`].
pub fn max_achievable_detail(
    ctx: &SchedCtx,
    scheduler: &dyn Scheduler,
    base: &[f64; 5],
    viol_budget: f64,
    sim_duration_s: f64,
) -> Achieved {
    let total_base: f64 = base.iter().sum();
    debug_assert!(total_base > 0.0);

    // The violation rate is not monotone in the scale (schedule shapes
    // jump at batch/partition thresholds), so a bisection can get stuck
    // in a local violation pocket. Instead: find the scheduler-level
    // limit, then scan a descending grid and report the highest scale
    // whose deployment actually holds the violation budget — exactly
    // the paper's "gradually increasing the request rate" sweep, run
    // from the top.
    //
    // One engine serves every probe: `reset` rewinds it to the fresh
    // state while keeping the event heap, route tables, and dedup-set
    // allocations, and each probe's trace streams from per-model
    // Poisson sources — the old path re-generated and re-sorted a full
    // arrival vector and rebuilt the engine for every grid point.
    let k_max = max_schedulable(ctx, scheduler, base);
    if k_max > 0.0 {
        let gt = GroundTruth::default();
        let lm_true = crate::perfmodel::LatencyModel::new();
        let cfg = SimConfig::default();
        let mut engine =
            ServingEngine::new(&lm_true, &gt, Schedule::default(), sim_duration_s, &cfg);
        const GRID: usize = 24;
        for i in (1..=GRID).rev() {
            let k = k_max * i as f64 / GRID as f64;
            let rates = scaled(base, k);
            if let Ok(s) = scheduler.schedule(ctx, &rates) {
                let v = probe_violation_on(&mut engine, s, &rates, sim_duration_s, 99);
                if v <= viol_budget {
                    return Achieved {
                        scale: k,
                        total_rps: k * total_base,
                        violation_rate: Some(v),
                    };
                }
            }
        }
    }
    Achieved { scale: 0.0, total_rps: 0.0, violation_rate: None }
}

/// Pure-scheduler maximum schedulable scale (no simulation): used for
/// Fig 16's "maximum schedulable rate" comparison.
pub fn max_schedulable(ctx: &SchedCtx, scheduler: &dyn Scheduler, base: &[f64; 5]) -> f64 {
    let ok = |k: f64| scheduler.schedule(ctx, &scaled(base, k)).is_ok();
    let mut lo = 0.0;
    let mut hi = 1.0;
    if ok(1.0) {
        lo = 1.0;
        while ok(hi * 2.0) {
            hi *= 2.0;
            lo = hi / 2.0;
            if hi > 1e5 {
                break;
            }
        }
        hi *= 2.0;
    }
    for _ in 0..14 {
        let mid = 0.5 * (lo + hi);
        if ok(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Render a scenario's rates compactly for logs.
pub fn fmt_rates(rates: &[f64; 5]) -> String {
    let parts: Vec<String> = ModelId::ALL
        .iter()
        .map(|&m| format!("{}={:.0}", m.abbrev(), rates[m.index()]))
        .collect();
    parts.join(" ")
}

/// Scenario helper used by schedulability studies.
pub fn scenario_rates(s: &Scenario) -> [f64; 5] {
    s.rates
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::ElasticPartitioning;

    #[test]
    fn eval_workloads_cover_fig12() {
        let w = eval_workloads();
        let names: Vec<&str> = w.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["game", "traffic", "equal", "long-only", "short-skew"]);
        // game at unit rate 10: 60 lenet + 10 resnet.
        assert_eq!(w[0].1[ModelId::Lenet.index()], 60.0);
        assert_eq!(w[0].1[ModelId::Resnet.index()], 10.0);
    }

    #[test]
    fn max_schedulable_bracketing() {
        let ctx = paper_ctx(false);
        let sched = ElasticPartitioning::gpulet();
        let k = max_schedulable(&ctx, &sched, &[50.0; 5]);
        assert!(k > 1.0, "equal scenario must be schedulable beyond 1x, got {k}");
        // The found scale is feasible, slightly above is not.
        assert!(sched.schedule(&ctx, &scaled(&[50.0; 5], k)).is_ok());
        assert!(sched.schedule(&ctx, &scaled(&[50.0; 5], k * 1.05)).is_err());
    }

    #[test]
    fn max_achievable_not_above_schedulable() {
        let ctx = paper_ctx(false);
        let sched = ElasticPartitioning::gpulet();
        let base = [50.0; 5];
        let (k_a, total) = max_achievable(&ctx, &sched, &base, 0.01, 10.0);
        let k_s = max_schedulable(&ctx, &sched, &base);
        assert!(k_a <= k_s * 1.01, "achievable {k_a} > schedulable {k_s}");
        assert!(total > 0.0);
    }
}
