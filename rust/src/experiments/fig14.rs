//! Fig 14: adaptation to rate fluctuation over a long window — per-model
//! throughput, sum of allocated gpu-let sizes, and SLO violation % per
//! 20 s period. Paper headline: violations are only 0.14% of requests
//! over the whole trace while partitions grow and shrink with the load.
//!
//! Served by one persistent `ServingEngine` across the entire trace
//! (requests survive re-organizations), so the overall violation share
//! is exact request-weighted accounting from the whole-trace report —
//! and `arrivals == served + dropped` holds across every swap. The
//! trace itself streams: per-model inhomogeneous Poisson sources feed
//! the engine one arrival at a time (`AdaptiveServer::run_source`), so
//! the run's footprint is O(in-flight work), not O(trace length) —
//! `benches/engine_scale.rs` measures the same load at 1x/10x/100x.

use crate::coordinator::{AdaptiveOutcome, AdaptiveServer};
use crate::models::ModelId;
use crate::sched::ElasticPartitioning;
use crate::util::json::{obj, Json};
use crate::workload::FluctuationTrace;

use super::common::{paper_ctx, Runnable, RunOutput};

pub fn compute(duration_s: f64, seed: u64) -> AdaptiveOutcome {
    let ctx = paper_ctx(false);
    let sched = ElasticPartitioning::gpulet();
    let srv = AdaptiveServer::new(&ctx, &sched);
    srv.run_trace(&FluctuationTrace::default(), duration_s, seed)
        .expect("fig14 trace rates are finite")
}

pub fn render(out: &AdaptiveOutcome) -> String {
    let mut s = String::from(
        "# Fig 14: adaptation to rate fluctuation (20 s windows)\n\
         t(s)   le   goo   res   ssd   vgg  alloc%  viol%  reorg\n",
    );
    for w in &out.windows {
        s.push_str(&format!(
            "{:>5.0} {:>4.0} {:>5.0} {:>5.0} {:>5.0} {:>5.0} {:>7} {:>6.2} {:>6}\n",
            w.t_start_s,
            w.throughput[ModelId::Lenet.index()],
            w.throughput[ModelId::Googlenet.index()],
            w.throughput[ModelId::Resnet.index()],
            w.throughput[ModelId::SsdMobilenet.index()],
            w.throughput[ModelId::Vgg.index()],
            w.allocated_pct,
            w.violation_rate * 100.0,
            if w.reorganized { "*" } else { "" },
        ));
    }
    // Whole-trace violation share (paper: 0.14%), exact over all
    // requests from the persistent engine's report.
    let offered: u64 = out.offered.iter().sum();
    s.push_str(&format!(
        "overall violation share: {:.2}% of {} requests (paper: 0.14%)\n",
        out.overall_violation_share() * 100.0,
        offered,
    ));
    s
}

pub fn run() -> String {
    render(&compute(FluctuationTrace::DURATION_S, 2024))
}

/// Text + JSON for the CLI / bench harness (one full-trace pass).
pub fn report() -> RunOutput {
    let out = compute(FluctuationTrace::DURATION_S, 2024);
    let windows: Vec<Json> = out
        .windows
        .iter()
        .map(|w| {
            obj(vec![
                ("t_start_s", Json::Num(w.t_start_s)),
                (
                    "throughput_rps",
                    Json::Arr(w.throughput.iter().map(|&t| Json::Num(t)).collect()),
                ),
                ("allocated_pct", Json::Num(w.allocated_pct as f64)),
                ("violation_rate", Json::Num(w.violation_rate)),
                ("reorganized", Json::Bool(w.reorganized)),
            ])
        })
        .collect();
    RunOutput {
        text: render(&out),
        payload: obj(vec![
            ("figure", Json::Str("fig14".into())),
            ("windows", Json::Arr(windows)),
            (
                "overall_violation_share",
                Json::Num(out.overall_violation_share()),
            ),
            (
                "offered_requests",
                Json::Num(out.offered.iter().sum::<u64>() as f64),
            ),
            ("report", out.report.to_json()),
        ]),
    }
}

/// Fig 14 as a CLI/bench-drivable experiment — the full 1,800 s
/// adaptation trace.
pub struct Experiment;

impl Runnable for Experiment {
    fn name(&self) -> &'static str {
        "fig14"
    }
    fn title(&self) -> &'static str {
        "adaptive serving over the 1800 s fluctuation trace"
    }
    fn bench_file(&self) -> &'static str {
        "BENCH_fig14_fluctuation.json"
    }
    fn run(&self) -> RunOutput {
        report()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_cover_trace_and_adapt() {
        // 600 s slice keeps the test quick; the full 1800 s run is the
        // fig14 bench / CLI target.
        let out = super::compute(600.0, 5);
        assert_eq!(out.windows.len(), 30);
        let min_alloc = out.windows.iter().map(|w| w.allocated_pct).min().unwrap();
        let max_alloc = out.windows.iter().map(|w| w.allocated_pct).max().unwrap();
        assert!(max_alloc > min_alloc, "allocation should move with the wave");
        // Conservation across windows and reorganizations.
        for m in ModelId::ALL {
            let total = out.report.model(m).map_or(0, |mm| mm.total());
            assert_eq!(total, out.offered[m.index()], "{m} lost requests");
        }
    }
}
