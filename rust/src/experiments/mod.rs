//! Experiment harnesses — one per paper figure/table (DESIGN.md §5).
//!
//! Each module exposes `run(...) -> String` producing the same
//! rows/series the paper reports, plus `report() -> RunOutput` adding a
//! machine-readable JSON payload, so `gpulets run-fig N`, the bench
//! targets, and the integration tests all share one code path. The
//! [`common::Runnable`] trait + [`registry`] list what can be driven.

pub mod common;
pub mod fault_recovery;
pub mod fig03;
pub mod fig04;
pub mod fig05;
pub mod fig06;
pub mod fig09;
pub mod fig12;
pub mod fig13;
pub mod fig14;
pub mod fig15;
pub mod fig16;
pub mod fleet_scale;
pub mod spacetime;
pub mod tables;
pub mod trace_overhead;

use common::Runnable;

/// Every drivable experiment: the paper figures in order, then the
/// scaling studies layered on top of the reproduction.
pub fn registry() -> Vec<Box<dyn Runnable>> {
    vec![
        Box::new(fig03::Experiment),
        Box::new(fig04::Experiment),
        Box::new(fig05::Experiment),
        Box::new(fig06::Experiment),
        Box::new(fig09::Experiment),
        Box::new(fig12::Experiment),
        Box::new(fig13::Experiment),
        Box::new(fig14::Experiment),
        Box::new(fig15::Experiment),
        Box::new(fig16::Experiment),
        Box::new(fleet_scale::Experiment),
        Box::new(spacetime::Experiment),
        Box::new(fault_recovery::Experiment),
        Box::new(trace_overhead::Experiment),
    ]
}

/// Look up one experiment by a forgiving name: exact names
/// (`fleet_scale`, `fig12`) resolve directly; figure shorthands (`12`,
/// `fig3`) are zero-padded to the canonical `figNN`.
pub fn find(name: &str) -> Option<Box<dyn Runnable>> {
    let trimmed = name.trim();
    if let Some(e) = registry().into_iter().find(|e| e.name() == trimmed) {
        return Some(e);
    }
    let digits = trimmed.trim_start_matches("fig");
    let canonical = match digits.parse::<u32>() {
        Ok(n) => format!("fig{n:02}"),
        Err(_) => return None,
    };
    registry().into_iter().find(|e| e.name() == canonical)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_and_files_are_unique() {
        let reg = registry();
        assert_eq!(reg.len(), 14);
        let mut names: Vec<&str> = reg.iter().map(|e| e.name()).collect();
        let mut files: Vec<&str> = reg.iter().map(|e| e.bench_file()).collect();
        names.sort_unstable();
        names.dedup();
        files.sort_unstable();
        files.dedup();
        assert_eq!(names.len(), 14);
        assert_eq!(files.len(), 14);
        assert!(files.iter().all(|f| f.starts_with("BENCH_") && f.ends_with(".json")));
    }

    #[test]
    fn find_accepts_forgiving_names() {
        assert_eq!(find("12").unwrap().name(), "fig12");
        assert_eq!(find("fig3").unwrap().name(), "fig03");
        assert_eq!(find("fig03").unwrap().name(), "fig03");
        assert_eq!(find("fleet_scale").unwrap().name(), "fleet_scale");
        assert_eq!(find("fault_recovery").unwrap().name(), "fault_recovery");
        assert!(find("fig07").is_none());
        assert!(find("bogus").is_none());
    }
}
