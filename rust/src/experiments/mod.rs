//! Experiment harnesses — one per paper figure/table (DESIGN.md §5).
//!
//! Each module exposes `run(...) -> String` producing the same
//! rows/series the paper reports, so `gpulets experiment figN`, the
//! bench targets, and the integration tests all share one code path.

pub mod common;
pub mod fig03;
pub mod fig04;
pub mod fig05;
pub mod fig06;
pub mod fig09;
pub mod fig12;
pub mod fig13;
pub mod fig14;
pub mod fig15;
pub mod fig16;
pub mod tables;
