//! Fleet scaling: the Fig-14 fluctuation workload scaled to 1 / 4 / 16
//! / 64 nodes, served by the fleet tier with periodic rebalancing —
//! under both a pinned-serial and the ambient-parallel worker pool.
//!
//! Each rung multiplies the Fig-14 per-model rates by the node count,
//! so every node sees roughly the single-server paper load and the
//! series isolates what the *fleet layer* adds: deterministic routing,
//! lockstep advancement of N engines, merged reporting, and re-planning
//! at window boundaries. Every rung runs twice — threads pinned to 1
//! (the serial reference) and at the ambient `util::par` resolution —
//! and the payload records events/s per (nodes, threads) cell, the
//! parallel speedup, a byte-equality check against the serial arm
//! (`matches_serial`: the advance must be thread-count invariant), and
//! the peak-RSS proxies (peak live events per node, peak routed-ahead
//! arrivals). The BENCH payload is the fleet row of the cross-PR perf
//! trajectory (`gpulets bench-compare`).
//!
//! Routing is deterministic for a fixed seed regardless of `--threads`:
//! dealing is serial by construction and the parallel node advance is
//! proven byte-identical (`tests/fleet_equivalence.rs`), so both arms
//! produce the same reports and differ only in wall clock.

use crate::config::Algo;
use crate::fleet::{FleetConfig, FleetEngine, FleetOutcome, FleetPlanner};
use crate::interference::GroundTruth;
use crate::models::ModelId;
use crate::perfmodel::LatencyModel;
use crate::sched::SchedCtx;
use crate::util::json::{obj, Json};
use crate::util::par;
use crate::workload::{dyn_sources, varying_streams, FluctuationTrace, SourceMux};

use super::common::{fitted_interference, Runnable, RunOutput};

/// Node counts of the scaling ladder.
pub const NODES: [usize; 4] = [1, 4, 16, 64];

/// Trace length per rung (s) — covers the first Fig-14 wave's rise,
/// peak, and fall.
pub const DURATION_S: f64 = 600.0;

/// One rung's outcome plus its wall-clock cost, tagged with the worker
/// count it ran under.
pub struct Rung {
    pub nodes: usize,
    /// Resolved worker count the advance ran with.
    pub threads: usize,
    pub outcome: FleetOutcome,
    pub wall_s: f64,
}

/// Run one rung: `nodes` nodes under `nodes`-times Fig-14 traffic,
/// planned per node by the scheduler `algo` names (any registered algo,
/// including `spacetime`, can drive the fleet tier). The worker count
/// is whatever `util::par` currently resolves to — the matrix runner
/// pins it per arm.
pub fn compute(algo: Algo, nodes: usize, duration_s: f64, seed: u64) -> crate::error::Result<Rung> {
    let scale = nodes as f64;
    let scheduler = algo.scheduler();
    let ctx = SchedCtx::new(
        4,
        if scheduler.interference_aware() { Some(fitted_interference()) } else { None },
    );
    let planner = FleetPlanner::new(&ctx, scheduler.as_ref(), nodes);
    let trace = FluctuationTrace::default();
    // Initial plan from the trace's t=0 rates; the wave's 3-4x swell is
    // the rebalancer's job, exactly like one node's Fig-14 reorganizer.
    let mut base = [0.0; 5];
    for m in ModelId::ALL {
        base[m.index()] = trace.rate_at(m, 0.0) * scale;
    }
    let plan = planner.plan(&base)?;
    let tr = trace.clone();
    let streams = varying_streams(
        &ModelId::ALL,
        move |m, t| tr.rate_at(m, t) * scale,
        duration_s,
        1.0,
        seed,
    )?;
    let lm = LatencyModel::new();
    let gt = GroundTruth::default();
    let cfg = FleetConfig::default(); // 20 s windows, rebalancing on
    let mut engine = FleetEngine::new(
        &lm,
        &gt,
        planner,
        plan,
        SourceMux::new(dyn_sources(streams)),
        duration_s,
        &cfg,
    );
    let t0 = std::time::Instant::now();
    engine.run(duration_s);
    let outcome = engine.finish();
    let wall_s = t0.elapsed().as_secs_f64();
    Ok(Rung { nodes, threads: par::threads(), outcome, wall_s })
}

fn events_per_s(r: &Rung) -> f64 {
    if r.wall_s > 0.0 {
        r.outcome.events_processed as f64 / r.wall_s
    } else {
        0.0
    }
}

/// One ladder rung measured under both arms.
pub struct MatrixRow {
    pub serial: Rung,
    pub parallel: Rung,
}

impl MatrixRow {
    /// Serving results must be thread-count invariant: merged report
    /// JSON, routing totals, and rebalance history all byte-equal.
    pub fn matches_serial(&self) -> bool {
        self.serial.outcome.report.to_json().to_string()
            == self.parallel.outcome.report.to_json().to_string()
            && self.serial.outcome.offered == self.parallel.outcome.offered
            && self.serial.outcome.rebalances == self.parallel.outcome.rebalances
    }

    /// Serial wall / parallel wall (1.0 when timing is degenerate).
    pub fn speedup(&self) -> f64 {
        if self.parallel.wall_s > 0.0 && self.serial.wall_s > 0.0 {
            self.serial.wall_s / self.parallel.wall_s
        } else {
            1.0
        }
    }
}

/// Run the (nodes × threads) matrix: each rung once with the worker
/// count pinned to 1 and once at the ambient resolution. The prior
/// thread override is restored exactly afterwards.
pub fn matrix(algo: Algo, nodes_list: &[usize], duration_s: f64, seed: u64) -> Vec<MatrixRow> {
    let saved = par::thread_override();
    let ambient = par::threads().max(1);
    let mut rows = Vec::with_capacity(nodes_list.len());
    for &n in nodes_list {
        par::set_threads(1);
        let serial =
            compute(algo, n, duration_s, seed).expect("fig14 rates are plannable");
        par::set_threads(ambient);
        let parallel =
            compute(algo, n, duration_s, seed).expect("fig14 rates are plannable");
        rows.push(MatrixRow { serial, parallel });
    }
    par::set_threads(saved);
    rows
}

pub fn render(rows: &[MatrixRow]) -> String {
    let mut s = String::from(
        "# fleet_scale: N nodes under N-times Fig-14 traffic (600 s, 20 s windows)\n\
         # each rung runs serial (1 worker) and parallel (ambient workers)\n\
         nodes threads   offered   events/s  speedup   viol%   rebalances   conserved   match\n",
    );
    for row in rows {
        for (r, arm_of) in [(&row.serial, None), (&row.parallel, Some(row))] {
            let offered: u64 = r.outcome.offered.iter().sum();
            let speedup = arm_of
                .map_or("      -".to_string(), |m| format!("{:>7.2}", m.speedup()));
            let matches = arm_of.map_or("    -".to_string(), |m| {
                if m.matches_serial() { "  yes".into() } else { "   NO".into() }
            });
            s.push_str(&format!(
                "{:>5} {:>7} {:>9} {:>10.0} {} {:>7.2} {:>12} {:>11} {}\n",
                r.nodes,
                r.threads,
                offered,
                events_per_s(r),
                speedup,
                r.outcome.report.overall_violation_rate() * 100.0,
                r.outcome.rebalances,
                if r.outcome.conserved() { "yes" } else { "NO" },
                matches,
            ));
        }
    }
    s
}

pub fn run() -> String {
    render(&matrix(Algo::Gpulet, &NODES, DURATION_S, 2024))
}

fn rung_json(r: &Rung, row: Option<&MatrixRow>) -> Json {
    let (served, dropped) = r.outcome.served_dropped();
    let mut fields = vec![
        ("nodes", Json::Num(r.nodes as f64)),
        ("threads", Json::Num(r.threads as f64)),
        (
            "arm",
            Json::Str(if row.is_some() { "parallel".into() } else { "serial".into() }),
        ),
        ("duration_s", Json::Num(DURATION_S)),
        (
            "offered_requests",
            Json::Num(r.outcome.offered.iter().sum::<u64>() as f64),
        ),
        ("served", Json::Num(served.iter().sum::<u64>() as f64)),
        ("dropped", Json::Num(dropped.iter().sum::<u64>() as f64)),
        ("events", Json::Num(r.outcome.events_processed as f64)),
        ("wall_s", Json::Num(r.wall_s)),
        ("events_per_s", Json::Num(events_per_s(r))),
        (
            "violation_share",
            Json::Num(r.outcome.report.overall_violation_rate()),
        ),
        ("rebalances", Json::Num(r.outcome.rebalances as f64)),
        ("conserved", Json::Bool(r.outcome.conserved())),
        (
            "peak_live_events",
            Json::Num(r.outcome.peak_live_events as f64),
        ),
        ("peak_routed", Json::Num(r.outcome.peak_routed as f64)),
    ];
    if let Some(m) = row {
        fields.push(("matches_serial", Json::Bool(m.matches_serial())));
        fields.push(("speedup", Json::Num(m.speedup())));
    }
    obj(fields)
}

/// Text + JSON for the CLI / bench harness.
pub fn report() -> RunOutput {
    let rows = matrix(Algo::Gpulet, &NODES, DURATION_S, 2024);
    let mut rungs: Vec<Json> = Vec::with_capacity(rows.len() * 2);
    for row in &rows {
        rungs.push(rung_json(&row.serial, None));
        rungs.push(rung_json(&row.parallel, Some(row)));
    }
    // The headline speedup cell: serial vs parallel at 16 nodes (the
    // largest rung every machine runs comfortably; 64 is the stress
    // rung).
    let speedup_16 = rows
        .iter()
        .find(|r| r.serial.nodes == 16)
        .map_or(1.0, MatrixRow::speedup);
    RunOutput {
        text: render(&rows),
        payload: obj(vec![
            ("figure", Json::Str("fleet_scale".into())),
            ("speedup_16_nodes", Json::Num(speedup_16)),
            ("rungs", Json::Arr(rungs)),
        ]),
    }
}

/// Fleet scaling as a CLI/bench-drivable experiment.
pub struct Experiment;

impl Runnable for Experiment {
    fn name(&self) -> &'static str {
        "fleet_scale"
    }
    fn title(&self) -> &'static str {
        "fleet tier at 1/4/16/64 nodes, serial vs parallel advance"
    }
    fn bench_file(&self) -> &'static str {
        "BENCH_fleet_scale.json"
    }
    fn run(&self) -> RunOutput {
        report()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_rung_conserves_and_is_seed_stable() {
        // A 60 s 2-node slice keeps the test quick; the full ladder is
        // the fleet_scale bench / CLI target.
        let a = compute(Algo::Gpulet, 2, 60.0, 7).unwrap();
        assert!(a.outcome.conserved(), "offered != served + dropped");
        let offered: u64 = a.outcome.offered.iter().sum();
        assert!(offered > 5_000, "load too small: {offered}");
        // Determinism: identical reports and routing for the same seed.
        let b = compute(Algo::Gpulet, 2, 60.0, 7).unwrap();
        assert_eq!(
            a.outcome.report.to_json().to_string(),
            b.outcome.report.to_json().to_string()
        );
        assert_eq!(a.outcome.offered, b.outcome.offered);
        assert_eq!(a.outcome.rebalances, b.outcome.rebalances);
    }

    #[test]
    fn matrix_parallel_arm_matches_serial_arm() {
        // The bench's own equality check must hold on a small matrix:
        // the parallel advance is byte-identical to the serial one.
        // (Thread settings race benignly with other tests — results are
        // thread-count invariant by design, which is exactly what this
        // asserts.)
        let rows = matrix(Algo::Gpulet, &[1, 2], 30.0, 7);
        assert_eq!(rows.len(), 2);
        for row in &rows {
            assert!(row.serial.outcome.conserved());
            assert!(row.parallel.outcome.conserved());
            assert!(
                row.matches_serial(),
                "parallel advance diverged from serial at {} nodes",
                row.serial.nodes
            );
            assert!(row.speedup() > 0.0);
        }
    }

    #[test]
    fn spacetime_algo_drives_the_fleet_tier() {
        // The fleet planner is scheduler-agnostic; this pins that the
        // new algo actually plans, serves, and conserves through it.
        let r = compute(Algo::Spacetime, 2, 30.0, 7).unwrap();
        assert!(r.outcome.conserved(), "offered != served + dropped");
        let offered: u64 = r.outcome.offered.iter().sum();
        assert!(offered > 1_000, "load too small: {offered}");
    }
}
