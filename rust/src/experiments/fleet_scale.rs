//! Fleet scaling: the Fig-14 fluctuation workload scaled to 1 / 4 / 16
//! nodes, served by the fleet tier with periodic rebalancing.
//!
//! Each rung multiplies the Fig-14 per-model rates by the node count,
//! so every node sees roughly the single-server paper load and the
//! series isolates what the *fleet layer* adds: deterministic routing,
//! lockstep advancement of N engines, merged reporting, and re-planning
//! at window boundaries. Reported per rung: offered requests, engine
//! events/s (wall-clock), the fleet-wide SLO-violation share (drops
//! included), rebalances applied, and the conservation check — the
//! BENCH payload is the fleet row of the cross-PR perf trajectory
//! (`gpulets bench-compare`).
//!
//! Routing is deterministic for a fixed seed regardless of `--threads`:
//! the rungs run serially and the router/engines never touch the
//! worker pool.

use crate::config::Algo;
use crate::fleet::{FleetConfig, FleetEngine, FleetOutcome, FleetPlanner};
use crate::interference::GroundTruth;
use crate::models::ModelId;
use crate::perfmodel::LatencyModel;
use crate::sched::SchedCtx;
use crate::util::json::{obj, Json};
use crate::workload::{dyn_sources, varying_streams, FluctuationTrace, SourceMux};

use super::common::{fitted_interference, Runnable, RunOutput};

/// Node counts of the scaling ladder.
pub const NODES: [usize; 3] = [1, 4, 16];

/// Trace length per rung (s) — covers the first Fig-14 wave's rise,
/// peak, and fall.
pub const DURATION_S: f64 = 600.0;

/// One rung's outcome plus its wall-clock cost.
pub struct Rung {
    pub nodes: usize,
    pub outcome: FleetOutcome,
    pub wall_s: f64,
}

/// Run one rung: `nodes` nodes under `nodes`-times Fig-14 traffic,
/// planned per node by the scheduler `algo` names (any registered algo,
/// including `spacetime`, can drive the fleet tier).
pub fn compute(algo: Algo, nodes: usize, duration_s: f64, seed: u64) -> crate::error::Result<Rung> {
    let scale = nodes as f64;
    let scheduler = algo.scheduler();
    let ctx = SchedCtx::new(
        4,
        if scheduler.interference_aware() { Some(fitted_interference()) } else { None },
    );
    let planner = FleetPlanner::new(&ctx, scheduler.as_ref(), nodes);
    let trace = FluctuationTrace::default();
    // Initial plan from the trace's t=0 rates; the wave's 3-4x swell is
    // the rebalancer's job, exactly like one node's Fig-14 reorganizer.
    let mut base = [0.0; 5];
    for m in ModelId::ALL {
        base[m.index()] = trace.rate_at(m, 0.0) * scale;
    }
    let plan = planner.plan(&base)?;
    let tr = trace.clone();
    let streams = varying_streams(
        &ModelId::ALL,
        move |m, t| tr.rate_at(m, t) * scale,
        duration_s,
        1.0,
        seed,
    )?;
    let lm = LatencyModel::new();
    let gt = GroundTruth::default();
    let cfg = FleetConfig::default(); // 20 s windows, rebalancing on
    let mut engine = FleetEngine::new(
        &lm,
        &gt,
        planner,
        plan,
        SourceMux::new(dyn_sources(streams)),
        duration_s,
        &cfg,
    );
    let t0 = std::time::Instant::now();
    engine.run(duration_s);
    let outcome = engine.finish();
    let wall_s = t0.elapsed().as_secs_f64();
    Ok(Rung { nodes, outcome, wall_s })
}

fn events_per_s(r: &Rung) -> f64 {
    if r.wall_s > 0.0 {
        r.outcome.events_processed as f64 / r.wall_s
    } else {
        0.0
    }
}

pub fn render(rungs: &[Rung]) -> String {
    let mut s = String::from(
        "# fleet_scale: N nodes under N-times Fig-14 traffic (600 s, 20 s windows)\n\
         nodes   offered   events/s   viol%   rebalances   conserved\n",
    );
    for r in rungs {
        let offered: u64 = r.outcome.offered.iter().sum();
        s.push_str(&format!(
            "{:>5} {:>9} {:>10.0} {:>7.2} {:>12} {:>11}\n",
            r.nodes,
            offered,
            events_per_s(r),
            r.outcome.report.overall_violation_rate() * 100.0,
            r.outcome.rebalances,
            if r.outcome.conserved() { "yes" } else { "NO" },
        ));
    }
    s
}

pub fn run() -> String {
    let rungs: Vec<Rung> = NODES
        .iter()
        .map(|&n| compute(Algo::Gpulet, n, DURATION_S, 2024).expect("fig14 rates are plannable"))
        .collect();
    render(&rungs)
}

/// Text + JSON for the CLI / bench harness.
pub fn report() -> RunOutput {
    let rungs: Vec<Rung> = NODES
        .iter()
        .map(|&n| compute(Algo::Gpulet, n, DURATION_S, 2024).expect("fig14 rates are plannable"))
        .collect();
    let rows: Vec<Json> = rungs
        .iter()
        .map(|r| {
            let (served, dropped) = r.outcome.served_dropped();
            obj(vec![
                ("nodes", Json::Num(r.nodes as f64)),
                ("duration_s", Json::Num(DURATION_S)),
                (
                    "offered_requests",
                    Json::Num(r.outcome.offered.iter().sum::<u64>() as f64),
                ),
                ("served", Json::Num(served.iter().sum::<u64>() as f64)),
                ("dropped", Json::Num(dropped.iter().sum::<u64>() as f64)),
                ("events", Json::Num(r.outcome.events_processed as f64)),
                ("wall_s", Json::Num(r.wall_s)),
                ("events_per_s", Json::Num(events_per_s(r))),
                (
                    "violation_share",
                    Json::Num(r.outcome.report.overall_violation_rate()),
                ),
                ("rebalances", Json::Num(r.outcome.rebalances as f64)),
                ("conserved", Json::Bool(r.outcome.conserved())),
                (
                    "peak_live_events",
                    Json::Num(r.outcome.peak_live_events as f64),
                ),
                ("peak_routed", Json::Num(r.outcome.peak_routed as f64)),
            ])
        })
        .collect();
    RunOutput {
        text: render(&rungs),
        payload: obj(vec![
            ("figure", Json::Str("fleet_scale".into())),
            ("rungs", Json::Arr(rows)),
        ]),
    }
}

/// Fleet scaling as a CLI/bench-drivable experiment.
pub struct Experiment;

impl Runnable for Experiment {
    fn name(&self) -> &'static str {
        "fleet_scale"
    }
    fn title(&self) -> &'static str {
        "fleet tier at 1/4/16 nodes under scaled Fig-14 traffic"
    }
    fn bench_file(&self) -> &'static str {
        "BENCH_fleet_scale.json"
    }
    fn run(&self) -> RunOutput {
        report()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_rung_conserves_and_is_seed_stable() {
        // A 60 s 2-node slice keeps the test quick; the full ladder is
        // the fleet_scale bench / CLI target.
        let a = compute(Algo::Gpulet, 2, 60.0, 7).unwrap();
        assert!(a.outcome.conserved(), "offered != served + dropped");
        let offered: u64 = a.outcome.offered.iter().sum();
        assert!(offered > 5_000, "load too small: {offered}");
        // Determinism: identical reports and routing for the same seed.
        let b = compute(Algo::Gpulet, 2, 60.0, 7).unwrap();
        assert_eq!(
            a.outcome.report.to_json().to_string(),
            b.outcome.report.to_json().to_string()
        );
        assert_eq!(a.outcome.offered, b.outcome.offered);
        assert_eq!(a.outcome.rebalances, b.outcome.rebalances);
    }

    #[test]
    fn spacetime_algo_drives_the_fleet_tier() {
        // The fleet planner is scheduler-agnostic; this pins that the
        // new algo actually plans, serves, and conserves through it.
        let r = compute(Algo::Spacetime, 2, 30.0, 7).unwrap();
        assert!(r.outcome.conserved(), "offered != served + dropped");
        let offered: u64 = r.outcome.offered.iter().sum();
        assert!(offered > 1_000, "load too small: {offered}");
    }
}
