//! Real-world multi-model applications (§6.1, Fig 10 / Fig 11).
//!
//! * `game` — streamed-video-game analytics: six parallel LeNet digit
//!   recognitions plus one ResNet-50 image recognition (one stage).
//! * `traffic` — traffic surveillance: SSD-MobileNet object detection,
//!   then GoogLeNet and VGG-16 recognizing two object types in parallel
//!   (two stages).
//!
//! An application request at rate `r` induces component-model request
//! rates (e.g. `game` at `r` → LeNet at `6r`, ResNet at `r`); the
//! scheduler operates on those induced rates, while the simulator
//! accounts app-level latency as sum-over-stages of max-over-branches.

use crate::models::ModelId;

/// One stage: a set of (model, parallel invocation count) branches that
/// run concurrently; the stage completes when all branches do.
#[derive(Clone, Debug, PartialEq)]
pub struct Stage {
    pub branches: Vec<(ModelId, u32)>,
}

/// A multi-model application DAG (linear chain of parallel stages).
#[derive(Clone, Debug, PartialEq)]
pub struct App {
    pub name: &'static str,
    pub stages: Vec<Stage>,
    /// End-to-end SLO (ms), set by doubling the longest component's solo
    /// latency (§6.1: game 95 ms, traffic 136 ms).
    pub slo_ms: f64,
}

impl App {
    /// The `game` application (Fig 10): 6× LeNet ∥ 1× ResNet-50.
    pub fn game() -> App {
        App {
            name: "game",
            stages: vec![Stage {
                branches: vec![(ModelId::Lenet, 6), (ModelId::Resnet, 1)],
            }],
            slo_ms: 95.0,
        }
    }

    /// The `traffic` application (Fig 11): SSD → (GoogLeNet ∥ VGG-16).
    pub fn traffic() -> App {
        App {
            name: "traffic",
            stages: vec![
                Stage { branches: vec![(ModelId::SsdMobilenet, 1)] },
                Stage {
                    branches: vec![(ModelId::Googlenet, 1), (ModelId::Vgg, 1)],
                },
            ],
            slo_ms: 136.0,
        }
    }

    pub fn by_name(name: &str) -> Option<App> {
        match name {
            "game" => Some(App::game()),
            "traffic" => Some(App::traffic()),
            _ => None,
        }
    }

    /// Component-model rates induced by serving this app at `rate` req/s,
    /// indexed by `ModelId::index`.
    pub fn induced_rates(&self, rate: f64) -> [f64; 5] {
        let mut out = [0.0; 5];
        for stage in &self.stages {
            for &(m, count) in &stage.branches {
                out[m.index()] += rate * count as f64;
            }
        }
        out
    }

    /// Total model invocations per app request.
    pub fn invocations_per_request(&self) -> u32 {
        self.stages
            .iter()
            .flat_map(|s| s.branches.iter())
            .map(|&(_, c)| c)
            .sum()
    }

    /// Critical-path solo latency estimate given per-model latencies
    /// (ms): sum over stages of the slowest branch.
    pub fn critical_path_ms<F: Fn(ModelId) -> f64>(&self, lat: F) -> f64 {
        self.stages
            .iter()
            .map(|s| {
                s.branches
                    .iter()
                    .map(|&(m, _)| lat(m))
                    .fold(0.0f64, f64::max)
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn game_structure() {
        let g = App::game();
        assert_eq!(g.stages.len(), 1);
        assert_eq!(g.invocations_per_request(), 7); // 6 LeNet + 1 ResNet
        assert_eq!(g.slo_ms, 95.0);
        let rates = g.induced_rates(100.0);
        assert_eq!(rates[ModelId::Lenet.index()], 600.0);
        assert_eq!(rates[ModelId::Resnet.index()], 100.0);
        assert_eq!(rates[ModelId::Vgg.index()], 0.0);
    }

    #[test]
    fn traffic_structure() {
        let t = App::traffic();
        assert_eq!(t.stages.len(), 2);
        assert_eq!(t.invocations_per_request(), 3);
        assert_eq!(t.slo_ms, 136.0);
        let rates = t.induced_rates(50.0);
        assert_eq!(rates[ModelId::SsdMobilenet.index()], 50.0);
        assert_eq!(rates[ModelId::Googlenet.index()], 50.0);
        assert_eq!(rates[ModelId::Vgg.index()], 50.0);
    }

    #[test]
    fn app_slos_are_twice_longest_component_solo() {
        // ResNet solo (b=32, full GPU) is 47.5 ms → game SLO 95 ms.
        // SSD solo is 68 ms → traffic SLO 136 ms.
        let lm = crate::perfmodel::LatencyModel::new();
        let game_long = lm.latency_ms(ModelId::Resnet, 32, 1.0);
        assert!((App::game().slo_ms - 2.0 * game_long).abs() < 1e-9);
        let traffic_long = lm.latency_ms(ModelId::SsdMobilenet, 32, 1.0);
        assert!((App::traffic().slo_ms - 2.0 * traffic_long).abs() < 1e-9);
    }

    #[test]
    fn critical_path() {
        let t = App::traffic();
        let cp = t.critical_path_ms(|m| match m {
            ModelId::SsdMobilenet => 10.0,
            ModelId::Googlenet => 5.0,
            ModelId::Vgg => 8.0,
            _ => 0.0,
        });
        assert_eq!(cp, 18.0); // 10 + max(5, 8)
    }

    #[test]
    fn by_name() {
        assert_eq!(App::by_name("game").unwrap().name, "game");
        assert_eq!(App::by_name("traffic").unwrap().name, "traffic");
        assert!(App::by_name("nope").is_none());
    }
}
