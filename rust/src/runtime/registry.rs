//! Model registry: every (model, batch) artifact compiled and held ready.
//!
//! The backend executors index into this registry on the hot path; all
//! compilation happens at startup (the serving analogue of the paper's
//! "loading required models and warming up" during reorganization).

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::models::ModelId;
use crate::runtime::engine::{Engine, Executable};
use crate::runtime::manifest::Manifest;

/// Compiled executables for every (model, batch) in the manifest.
pub struct ModelRegistry {
    pub manifest: Manifest,
    exes: BTreeMap<(ModelId, u32), Executable>,
}

impl ModelRegistry {
    /// Load the manifest from `dir` and compile every artifact.
    pub fn load(engine: &Engine, dir: impl AsRef<Path>) -> Result<ModelRegistry> {
        let manifest = Manifest::load(dir)?;
        Self::from_manifest(engine, manifest)
    }

    /// Compile all artifacts referenced by an already-parsed manifest.
    pub fn from_manifest(engine: &Engine, manifest: Manifest) -> Result<ModelRegistry> {
        let mut exes = BTreeMap::new();
        for (m, entry) in &manifest.models {
            for (&b, art) in &entry.artifacts {
                let exe = engine.load_hlo_text(&art.file)?;
                exes.insert((*m, b), exe);
            }
        }
        Ok(ModelRegistry { manifest, exes })
    }

    /// Load only selected models (faster startup for examples).
    pub fn load_models(
        engine: &Engine,
        dir: impl AsRef<Path>,
        models: &[ModelId],
    ) -> Result<ModelRegistry> {
        let mut manifest = Manifest::load(dir)?;
        manifest.models.retain(|m, _| models.contains(m));
        if manifest.models.is_empty() {
            return Err(Error::Model("no requested models in manifest".into()));
        }
        Self::from_manifest(engine, manifest)
    }

    /// Number of compiled executables.
    pub fn len(&self) -> usize {
        self.exes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.exes.is_empty()
    }

    /// Execute a batch: pads `inputs` (per-sample flattened f32) up to
    /// the smallest emitted batch >= the actual count, runs, and returns
    /// one output vector per real sample.
    pub fn infer(&self, m: ModelId, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if inputs.is_empty() {
            return Ok(vec![]);
        }
        let entry = self.manifest.entry(m)?;
        let want = inputs.len() as u32;
        let b = entry.batch_for(want).ok_or_else(|| {
            Error::Model(format!("{m}: batch {want} exceeds max emitted batch"))
        })?;
        let art = &entry.artifacts[&b];
        let sample_len: usize = entry.input_shape.iter().product();
        for (i, s) in inputs.iter().enumerate() {
            if s.len() != sample_len {
                return Err(Error::Model(format!(
                    "{m}: sample {i} has {} elements, expected {sample_len}",
                    s.len()
                )));
            }
        }
        // Pad with zeros to the artifact batch.
        let mut flat = Vec::with_capacity(art.input_len());
        for s in inputs {
            flat.extend_from_slice(s);
        }
        flat.resize(art.input_len(), 0.0);

        let exe = self
            .exes
            .get(&(m, b))
            .ok_or_else(|| Error::Model(format!("{m} b={b}: not compiled")))?;
        let out = exe.run_f32(&flat, &art.input_shape)?;
        let out_dim = art.output_len() / b as usize;
        Ok(out
            .chunks(out_dim)
            .take(inputs.len())
            .map(|c| c.to_vec())
            .collect())
    }
}

// Registry correctness over real artifacts is exercised by
// rust/tests/integration_runtime.rs (requires `make artifacts`).
