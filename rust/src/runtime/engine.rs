//! PJRT engine: compile HLO-text artifacts once, execute many times.
//!
//! Wraps the `xla` crate (PJRT C API). Interchange is HLO *text*:
//! jax >= 0.5 emits protos with 64-bit instruction ids that this XLA
//! build rejects, while the text parser reassigns ids cleanly.

use std::path::Path;

use crate::error::{Error, Result};

/// Process-wide PJRT client + compiler.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// Create a CPU PJRT engine.
    pub fn cpu() -> Result<Engine> {
        Ok(Engine { client: xla::PjRtClient::cpu()? })
    }

    /// Backend platform name (e.g. "cpu"/"Host").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load and compile one HLO-text artifact.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Other(format!("non-utf8 path {path:?}")))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable { exe })
    }
}

/// A compiled (model, batch) computation, ready for repeated execution.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute on one f32 input of logical shape `shape`.
    ///
    /// The AOT pipeline lowers with `return_tuple=True`, so the result
    /// is a 1-tuple wrapping the (batch, out_dim) output; this unwraps
    /// it and returns the flattened f32 output.
    pub fn run_f32(&self, input: &[f32], shape: &[usize]) -> Result<Vec<f32>> {
        let expected: usize = shape.iter().product();
        if input.len() != expected {
            return Err(Error::Model(format!(
                "input length {} != shape {:?} product {}",
                input.len(),
                shape,
                expected
            )));
        }
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input).reshape(&dims)?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// artifacts/ may not exist in a fresh checkout; integration tests in
    /// rust/tests/integration_runtime.rs cover the full path. Here we only
    /// check client bring-up and error paths (cheap, artifact-free).
    #[test]
    fn engine_boots_cpu_client() {
        let e = Engine::cpu().expect("PJRT CPU client");
        assert!(e.device_count() >= 1);
        assert!(!e.platform().is_empty());
    }

    #[test]
    fn load_missing_artifact_errors() {
        let e = Engine::cpu().unwrap();
        assert!(e.load_hlo_text("/nonexistent/x.hlo.txt").is_err());
    }
}
