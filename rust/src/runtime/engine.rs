//! PJRT engine: compile HLO-text artifacts once, execute many times.
//!
//! Two builds of the same API (DESIGN.md §7):
//!
//! * `--features pjrt` — wraps the `xla` crate (PJRT C API), which must
//!   be vendored into the build. Interchange is HLO *text*: jax >= 0.5
//!   emits protos with 64-bit instruction ids that this XLA build
//!   rejects, while the text parser reassigns ids cleanly.
//! * default — a stub whose constructor returns `Error::Xla`, so the
//!   crate (CLI, benches, sim experiments) builds and runs with zero
//!   external dependencies; only the real-execution paths
//!   (`serve-real`, the quickstart example, the artifact integration
//!   tests) report the missing runtime at startup.

use std::path::Path;

use crate::error::{Error, Result};

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Process-wide PJRT client + compiler.
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Create a CPU PJRT engine.
    pub fn cpu() -> Result<Engine> {
        Ok(Engine { client: xla::PjRtClient::cpu()? })
    }

    /// Backend platform name (e.g. "cpu"/"Host").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load and compile one HLO-text artifact.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Other(format!("non-utf8 path {path:?}")))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable { exe })
    }
}

/// A compiled (model, batch) computation, ready for repeated execution.
#[cfg(feature = "pjrt")]
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Execute on one f32 input of logical shape `shape`.
    ///
    /// The AOT pipeline lowers with `return_tuple=True`, so the result
    /// is a 1-tuple wrapping the (batch, out_dim) output; this unwraps
    /// it and returns the flattened f32 output.
    pub fn run_f32(&self, input: &[f32], shape: &[usize]) -> Result<Vec<f32>> {
        let expected: usize = shape.iter().product();
        if input.len() != expected {
            return Err(Error::Model(format!(
                "input length {} != shape {:?} product {}",
                input.len(),
                shape,
                expected
            )));
        }
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input).reshape(&dims)?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

#[cfg(not(feature = "pjrt"))]
const NO_PJRT: &str =
    "built without the `pjrt` feature — the PJRT runtime is unavailable \
     (rebuild with `cargo build --features pjrt` and a vendored `xla` crate)";

/// Stub engine (pjrt feature disabled): construction fails cleanly.
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    _never: (),
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    /// Always fails: the runtime was compiled out.
    pub fn cpu() -> Result<Engine> {
        Err(Error::Xla(NO_PJRT.into()))
    }

    pub fn platform(&self) -> String {
        "stub".into()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn load_hlo_text(&self, _path: impl AsRef<Path>) -> Result<Executable> {
        Err(Error::Xla(NO_PJRT.into()))
    }
}

/// Stub executable (pjrt feature disabled): unreachable in practice
/// because the stub `Engine` can never be constructed.
#[cfg(not(feature = "pjrt"))]
pub struct Executable {
    _never: (),
}

#[cfg(not(feature = "pjrt"))]
impl Executable {
    pub fn run_f32(&self, _input: &[f32], _shape: &[usize]) -> Result<Vec<f32>> {
        Err(Error::Xla(NO_PJRT.into()))
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    /// artifacts/ may not exist in a fresh checkout; integration tests in
    /// rust/tests/integration_runtime.rs cover the full path. Here we only
    /// check client bring-up and error paths (cheap, artifact-free).
    #[test]
    fn engine_boots_cpu_client() {
        let e = Engine::cpu().expect("PJRT CPU client");
        assert!(e.device_count() >= 1);
        assert!(!e.platform().is_empty());
    }

    #[test]
    fn load_missing_artifact_errors() {
        let e = Engine::cpu().unwrap();
        assert!(e.load_hlo_text("/nonexistent/x.hlo.txt").is_err());
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;

    #[test]
    fn stub_engine_fails_loudly() {
        let err = Engine::cpu().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "unhelpful stub error: {err}");
    }
}
