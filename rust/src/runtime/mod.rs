//! PJRT execution runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`
//! emitted by `python/compile/aot.py`) and runs them on the CPU PJRT
//! client from the L3 hot path. Python is never involved at runtime.

pub mod engine;
pub mod manifest;
pub mod registry;

pub use engine::Engine;
pub use manifest::{ArtifactInfo, Golden, Manifest, ModelEntry};
pub use registry::ModelRegistry;
