//! `artifacts/manifest.json` — the contract between the Python AOT
//! pipeline and the Rust runtime: which HLO file serves which
//! (model, batch), with input/output shapes and the model's SLO.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::models::ModelId;
use crate::util::json::Json;

/// One (model, batch) artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactInfo {
    pub file: PathBuf,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
}

impl ArtifactInfo {
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn output_len(&self) -> usize {
        self.output_shape.iter().product()
    }
}

/// Golden end-to-end vector: the Python-side model output on a fixed
/// deterministic input (`((i*31) % 17) / 17`), used to verify the Rust
/// runtime's numerics against L2.
#[derive(Clone, Debug, PartialEq)]
pub struct Golden {
    pub batch: u32,
    pub output: Vec<f64>,
}

/// All artifacts for one model.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelEntry {
    pub model: ModelId,
    pub slo_ms: f64,
    pub input_shape: Vec<usize>,
    /// batch -> artifact
    pub artifacts: BTreeMap<u32, ArtifactInfo>,
    /// Optional cross-language verification vector.
    pub golden: Option<Golden>,
}

impl ModelEntry {
    /// Smallest emitted batch >= `want` (serving pads up to it).
    pub fn batch_for(&self, want: u32) -> Option<u32> {
        self.artifacts.keys().copied().find(|&b| b >= want)
    }
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub batch_sizes: Vec<u32>,
    pub models: BTreeMap<ModelId, ModelEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Parse(format!("cannot read {}: {e} (run `make artifacts`)", path.display()))
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON with `dir` as the artifact root.
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let root = Json::parse(text)?;
        let batch_sizes = root
            .get("batch_sizes")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_usize()? as u32))
            .collect::<Result<Vec<u32>>>()?;
        let mut models = BTreeMap::new();
        for (name, entry) in root.get("models")?.as_obj()? {
            let model = ModelId::parse(name)?;
            let slo_ms = entry.get("slo_ms")?.as_f64()?;
            let input_shape = shape_of(entry.get("input_shape")?)?;
            let mut artifacts = BTreeMap::new();
            for (bstr, art) in entry.get("artifacts")?.as_obj()? {
                let b: u32 = bstr
                    .parse()
                    .map_err(|_| Error::parse(format!("bad batch key {bstr:?}")))?;
                artifacts.insert(
                    b,
                    ArtifactInfo {
                        file: dir.join(art.get("file")?.as_str()?),
                        input_shape: shape_of(art.get("input_shape")?)?,
                        output_shape: shape_of(art.get("output_shape")?)?,
                    },
                );
            }
            if artifacts.is_empty() {
                return Err(Error::Model(format!("{name}: no artifacts")));
            }
            let golden = match entry.opt("golden") {
                Some(g) => Some(Golden {
                    batch: g.get("batch")?.as_usize()? as u32,
                    output: g
                        .get("output")?
                        .as_arr()?
                        .iter()
                        .map(|v| v.as_f64())
                        .collect::<Result<Vec<f64>>>()?,
                }),
                None => None,
            };
            models.insert(model, ModelEntry { model, slo_ms, input_shape, artifacts, golden });
        }
        Ok(Manifest { batch_sizes, models, dir })
    }

    pub fn entry(&self, m: ModelId) -> Result<&ModelEntry> {
        self.models
            .get(&m)
            .ok_or_else(|| Error::Model(format!("{m} not in manifest")))
    }
}

fn shape_of(v: &Json) -> Result<Vec<usize>> {
    v.as_arr()?.iter().map(|x| x.as_usize()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "batch_sizes": [1, 2],
      "models": {
        "lenet": {
          "abbrev": "le", "slo_ms": 5.0, "input_shape": [28, 28, 1],
          "output_dim": 10,
          "artifacts": {
            "1": {"file": "lenet_b1.hlo.txt", "input_shape": [1,28,28,1], "output_shape": [1,10]},
            "2": {"file": "lenet_b2.hlo.txt", "input_shape": [2,28,28,1], "output_shape": [2,10]}
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/a")).unwrap();
        assert_eq!(m.batch_sizes, vec![1, 2]);
        let e = m.entry(ModelId::Lenet).unwrap();
        assert_eq!(e.slo_ms, 5.0);
        assert_eq!(e.artifacts.len(), 2);
        let a = &e.artifacts[&2];
        assert_eq!(a.file, PathBuf::from("/a/lenet_b2.hlo.txt"));
        assert_eq!(a.input_len(), 2 * 28 * 28);
        assert_eq!(a.output_len(), 20);
    }

    #[test]
    fn batch_for_rounds_up() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/a")).unwrap();
        let e = m.entry(ModelId::Lenet).unwrap();
        assert_eq!(e.batch_for(1), Some(1));
        assert_eq!(e.batch_for(2), Some(2));
        assert_eq!(e.batch_for(3), None);
    }

    #[test]
    fn missing_model_errors() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/a")).unwrap();
        assert!(m.entry(ModelId::Vgg).is_err());
    }

    #[test]
    fn rejects_bad_manifest() {
        assert!(Manifest::parse("{}", PathBuf::new()).is_err());
        assert!(Manifest::parse("not json", PathBuf::new()).is_err());
    }
}
