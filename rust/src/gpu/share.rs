//! GPU consolidation semantics (Fig 2 / Fig 5).

/// How co-located inference executions share one physical GPU.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShareMode {
    /// Nexus-style temporal sharing: one execution owns the whole GPU at
    /// a time; co-located work serializes (kernel-granularity switches).
    TemporalOnly,
    /// MPS without static provisioning: contexts run concurrently with
    /// no resource isolation — high utilization but volatile contention.
    MpsDefault,
    /// MPS with static partitioning into gpu-lets (the paper's system):
    /// each execution sees its fraction, with residual interference on
    /// shared L2 / DRAM bandwidth.
    Partitioned,
}

impl ShareMode {
    pub fn name(self) -> &'static str {
        match self {
            ShareMode::TemporalOnly => "temporal",
            ShareMode::MpsDefault => "mps-default",
            ShareMode::Partitioned => "partitioned",
        }
    }

    /// Contention amplification vs the partitioned ground truth. With no
    /// static provisioning MPS lets kernels fight for SMs as well as
    /// bandwidth, so observed interference is larger and more volatile
    /// (§2.3: "resource contention could lead to high performance
    /// volatility").
    pub fn contention_amplification(self) -> f64 {
        match self {
            ShareMode::TemporalOnly => 0.0, // never concurrent
            ShareMode::MpsDefault => 3.0,
            ShareMode::Partitioned => 1.0,
        }
    }

    /// Volatility of the contention term (std-dev multiplier on the
    /// interference factor) — zero under static partitioning isolation.
    pub fn contention_volatility(self) -> f64 {
        match self {
            ShareMode::TemporalOnly => 0.0,
            ShareMode::MpsDefault => 0.40,
            ShareMode::Partitioned => 0.05,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_and_ordering() {
        assert_eq!(ShareMode::Partitioned.name(), "partitioned");
        // MPS-default must contend harder than partitioned; temporal never.
        assert!(
            ShareMode::MpsDefault.contention_amplification()
                > ShareMode::Partitioned.contention_amplification()
        );
        assert_eq!(ShareMode::TemporalOnly.contention_amplification(), 0.0);
        assert!(
            ShareMode::MpsDefault.contention_volatility()
                > ShareMode::Partitioned.contention_volatility()
        );
    }
}
