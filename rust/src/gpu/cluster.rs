//! Cluster layout state: which gpu-lets currently exist on each GPU.
//!
//! The `DynamicPartitionReorganizer` (coordinator) diffs two layouts to
//! know which physical GPUs must be re-partitioned (a 10–15 s background
//! operation on the paper's testbed).

use crate::error::{Error, Result};
use crate::gpu::gpulet::{is_valid_size, GpuLetSpec, MAX_LETS_PER_GPU};

/// Partition layout of a homogeneous multi-GPU server.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClusterLayout {
    /// Per-GPU list of gpu-let sizes (percent), at most `MAX_LETS_PER_GPU`.
    gpus: Vec<Vec<u32>>,
}

impl ClusterLayout {
    /// All GPUs whole (one 100% gpu-let each) — the boot layout.
    pub fn whole(num_gpus: usize) -> Self {
        ClusterLayout { gpus: vec![vec![100]; num_gpus] }
    }

    /// Build from explicit per-GPU size lists, validating invariants.
    pub fn from_sizes(sizes: Vec<Vec<u32>>) -> Result<Self> {
        let layout = ClusterLayout { gpus: sizes };
        layout.validate()?;
        Ok(layout)
    }

    /// Structural invariants: every size valid, <= 2 lets per GPU, total
    /// <= 100 per GPU, no empty GPU entry.
    pub fn validate(&self) -> Result<()> {
        for (g, lets) in self.gpus.iter().enumerate() {
            if lets.is_empty() {
                return Err(Error::GpuLet(format!("gpu {g} has no gpu-lets")));
            }
            if lets.len() > MAX_LETS_PER_GPU {
                return Err(Error::GpuLet(format!(
                    "gpu {g} has {} gpu-lets (max {MAX_LETS_PER_GPU})",
                    lets.len()
                )));
            }
            for &s in lets {
                if !is_valid_size(s) {
                    return Err(Error::GpuLet(format!("gpu {g}: invalid size {s}%")));
                }
            }
            let total: u32 = lets.iter().sum();
            if total > 100 {
                return Err(Error::GpuLet(format!("gpu {g}: total {total}% > 100%")));
            }
        }
        Ok(())
    }

    pub fn num_gpus(&self) -> usize {
        self.gpus.len()
    }

    /// gpu-let sizes on one GPU.
    pub fn lets_on(&self, gpu: usize) -> &[u32] {
        &self.gpus[gpu]
    }

    /// Every gpu-let in the cluster as a spec list.
    pub fn all_lets(&self) -> Vec<GpuLetSpec> {
        self.gpus
            .iter()
            .enumerate()
            .flat_map(|(gpu, lets)| {
                lets.iter().map(move |&size_pct| GpuLetSpec { gpu, size_pct })
            })
            .collect()
    }

    /// Sum of allocated partition percentage across the cluster —
    /// Fig 14's "sum of utilized gpu-lets" series.
    pub fn total_allocated_pct(&self) -> u32 {
        self.gpus.iter().flat_map(|l| l.iter()).sum()
    }

    /// Replace one GPU's partitioning.
    pub fn set_gpu(&mut self, gpu: usize, mut lets: Vec<u32>) -> Result<()> {
        lets.sort_unstable();
        let old = std::mem::replace(&mut self.gpus[gpu], lets);
        if let Err(e) = self.validate() {
            self.gpus[gpu] = old;
            return Err(e);
        }
        Ok(())
    }

    /// GPUs whose partitioning differs between two layouts — these must
    /// be reorganized (MPS daemon restart + model reload + warmup).
    pub fn diff_gpus(&self, other: &ClusterLayout) -> Vec<usize> {
        let n = self.num_gpus().max(other.num_gpus());
        (0..n)
            .filter(|&g| {
                let a = self.gpus.get(g);
                let b = other.gpus.get(g);
                match (a, b) {
                    (Some(x), Some(y)) => {
                        let mut xs = x.clone();
                        let mut ys = y.clone();
                        xs.sort_unstable();
                        ys.sort_unstable();
                        xs != ys
                    }
                    _ => true,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn whole_layout() {
        let l = ClusterLayout::whole(4);
        assert_eq!(l.num_gpus(), 4);
        assert_eq!(l.all_lets().len(), 4);
        assert_eq!(l.total_allocated_pct(), 400);
        l.validate().unwrap();
    }

    #[test]
    fn from_sizes_validates() {
        assert!(ClusterLayout::from_sizes(vec![vec![20, 80], vec![100]]).is_ok());
        assert!(ClusterLayout::from_sizes(vec![vec![30, 70]]).is_err()); // invalid sizes
        assert!(ClusterLayout::from_sizes(vec![vec![50, 50, 20]]).is_err()); // >2 lets... also >100
        assert!(ClusterLayout::from_sizes(vec![vec![80, 80]]).is_err()); // >100%
        assert!(ClusterLayout::from_sizes(vec![vec![]]).is_err()); // empty gpu
    }

    #[test]
    fn undersubscribed_gpu_allowed() {
        // A GPU may run a single 60% gpu-let with 40% idle.
        ClusterLayout::from_sizes(vec![vec![60]]).unwrap().validate().unwrap();
    }

    #[test]
    fn set_gpu_rolls_back_on_error() {
        let mut l = ClusterLayout::whole(2);
        assert!(l.set_gpu(0, vec![20, 80]).is_ok());
        assert_eq!(l.lets_on(0), &[20, 80]);
        assert!(l.set_gpu(0, vec![80, 80]).is_err());
        assert_eq!(l.lets_on(0), &[20, 80], "failed set must roll back");
    }

    #[test]
    fn diff_detects_changes_order_insensitive() {
        let a = ClusterLayout::from_sizes(vec![vec![20, 80], vec![100]]).unwrap();
        let b = ClusterLayout::from_sizes(vec![vec![80, 20], vec![50, 50]]).unwrap();
        assert_eq!(a.diff_gpus(&b), vec![1]); // gpu0 same up to order
        assert_eq!(a.diff_gpus(&a), Vec::<usize>::new());
    }
}
