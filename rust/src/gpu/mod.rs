//! The *gpu-let* abstraction (§4) and the simulated multi-GPU cluster.
//!
//! A gpu-let is a virtual GPU: a spatial fraction of one physical GPU,
//! created through MPS-style partitioning. On the paper's Turing
//! testbed each physical GPU hosts up to two gpu-lets whose sizes are
//! drawn from the MPS active-thread-percentage ratios {20, 40, 50, 60,
//! 80, 100}. This module owns the size arithmetic (split/merge), the
//! cluster layout state, and the sharing-mode semantics the simulator
//! implements (Fig 5: temporal vs MPS-default vs partitioned).

pub mod cluster;
pub mod gpulet;
pub mod share;

pub use cluster::ClusterLayout;
pub use gpulet::{round_up_size, split_of, GpuLetSpec, MAX_LETS_PER_GPU, VALID_SIZES};
pub use share::ShareMode;
