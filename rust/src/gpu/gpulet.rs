//! gpu-let size arithmetic: valid sizes, split/merge, best-fit rounding.

use crate::error::{Error, Result};

/// Valid gpu-let sizes in percent. These are the paper's evaluated MPS
/// split ratios (2:8, 4:6, 5:5, 6:4, 8:2) plus the whole GPU.
pub const VALID_SIZES: [u32; 6] = [20, 40, 50, 60, 80, 100];

/// Post-Volta MPS on the paper's testbed provides at most two isolated
/// partitions per physical GPU ("up-to two virtual gpu-lets").
pub const MAX_LETS_PER_GPU: usize = 2;

/// A (physical GPU, size) pair identifying one gpu-let slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GpuLetSpec {
    /// Physical GPU index.
    pub gpu: usize,
    /// Partition size in percent (member of `VALID_SIZES`).
    pub size_pct: u32,
}

impl GpuLetSpec {
    pub fn new(gpu: usize, size_pct: u32) -> Result<Self> {
        if !VALID_SIZES.contains(&size_pct) {
            return Err(Error::GpuLet(format!("invalid gpu-let size {size_pct}%")));
        }
        Ok(GpuLetSpec { gpu, size_pct })
    }

    /// Size as a fraction of the GPU.
    pub fn fraction(&self) -> f64 {
        self.size_pct as f64 / 100.0
    }
}

/// True if `size` is an allowed gpu-let size.
pub fn is_valid_size(size_pct: u32) -> bool {
    VALID_SIZES.contains(&size_pct)
}

/// Smallest valid size >= `want_pct` (clamped to 100).
pub fn round_up_size(want_pct: u32) -> u32 {
    for &s in &VALID_SIZES {
        if s >= want_pct {
            return s;
        }
    }
    100
}

/// SPLIT (Algorithm 1 line 24): divide a whole GPU into
/// `(ideal, remainder)` where both halves are valid sizes and
/// `ideal >= want_pct`. Returns None when `want_pct` needs the whole GPU.
pub fn split_of(want_pct: u32) -> Option<(u32, u32)> {
    let ideal = round_up_size(want_pct);
    if ideal >= 100 {
        return None;
    }
    let rem = 100 - ideal;
    debug_assert!(is_valid_size(rem), "complement {rem} of {ideal} invalid");
    Some((ideal, rem))
}

/// MERGE / REVERTSPLIT helper: true if two sizes recombine into a whole GPU.
pub fn merges_to_whole(a_pct: u32, b_pct: u32) -> bool {
    a_pct + b_pct == 100 && is_valid_size(a_pct) && is_valid_size(b_pct)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valid_sizes_have_valid_complements() {
        for &s in &VALID_SIZES {
            if s < 100 {
                assert!(is_valid_size(100 - s), "complement of {s}");
            }
        }
    }

    #[test]
    fn round_up() {
        assert_eq!(round_up_size(1), 20);
        assert_eq!(round_up_size(20), 20);
        assert_eq!(round_up_size(21), 40);
        assert_eq!(round_up_size(55), 60);
        assert_eq!(round_up_size(81), 100);
        assert_eq!(round_up_size(150), 100);
    }

    #[test]
    fn split_round_trip() {
        for want in [1u32, 20, 35, 50, 79, 80] {
            let (a, b) = split_of(want).unwrap();
            assert!(a >= want);
            assert!(merges_to_whole(a, b), "{a}+{b}");
        }
        assert!(split_of(81).is_none());
        assert!(split_of(100).is_none());
    }

    #[test]
    fn spec_validation() {
        assert!(GpuLetSpec::new(0, 50).is_ok());
        assert!(GpuLetSpec::new(0, 30).is_err());
        assert_eq!(GpuLetSpec::new(1, 20).unwrap().fraction(), 0.2);
    }
}
