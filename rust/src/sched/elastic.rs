//! Elastic Partitioning — the paper's Algorithm 1.
//!
//! For every model in descending request-rate order, repeatedly:
//! 1. `MaxEfficientPartition` — the knee of the affordable-rate curve
//!    (most cost-effective gpu-let size).
//! 2. `MinRequiredPartition` — the smallest size that can absorb the
//!    still-unassigned rate within the SLO.
//! 3. `p_ideal = min(p_eff, p_req)`; `FindBestFit` scans the remaining
//!    gpu-lets ascending by size, splitting a whole GPU when needed
//!    (SPLIT), picks the SLO-max batch, and — if the placement can
//!    instead ride an already-allocated gpu-let via temporal sharing —
//!    MERGEs there and reverts the split (REVERTSPLIT).
//!
//! The `gpulet+int` variant adds the fitted linear interference
//! prediction to every SLO feasibility check (line 28), both for the
//! new placement and for the co-resident gpu-let it would disturb.

use crate::error::{Error, Result};
use crate::gpu::gpulet::{split_of, GpuLetSpec};
use crate::models::ModelId;
use crate::perfmodel::profile_table::PARTITIONS;
use crate::sched::types::{Assignment, LetPlan, SchedCtx, Schedule, Scheduler};

/// Residual-rate epsilon: request rates below this are considered served.
const EPS_RATE: f64 = 1e-6;

/// Elastic Partitioning scheduler (Algorithm 1).
#[derive(Clone, Copy, Debug)]
pub struct ElasticPartitioning {
    /// `true` = `gpulet+int` (interference-aware), `false` = `gpulet`.
    pub interference_aware: bool,
}

impl ElasticPartitioning {
    /// The interference-oblivious variant (`gpulet` in the paper's
    /// evaluation): Algorithm 1 with the interference term disabled.
    ///
    /// # Examples
    ///
    /// ```
    /// use gpulets::sched::{ElasticPartitioning, SchedCtx, Scheduler};
    ///
    /// let ctx = SchedCtx::new(4, None);
    /// let schedule = ElasticPartitioning::gpulet()
    ///     .schedule(&ctx, &[50.0, 0.0, 0.0, 0.0, 0.0])
    ///     .unwrap();
    /// schedule.validate(&ctx.lm, 4).unwrap();
    /// // LeNet barely uses 30% of a GPU: elastic partitioning must
    /// // carve small gpu-lets instead of burning a whole GPU on it.
    /// assert!(schedule.lets.iter().all(|l| l.spec.size_pct < 100));
    /// ```
    pub fn gpulet() -> Self {
        ElasticPartitioning { interference_aware: false }
    }

    /// The interference-aware variant (`gpulet+int`): every SLO
    /// feasibility check (Algorithm 1 line 28) adds the fitted linear
    /// interference prediction for the co-resident gpu-let.
    ///
    /// # Examples
    ///
    /// ```
    /// use gpulets::experiments::common::fitted_interference;
    /// use gpulets::sched::{ElasticPartitioning, SchedCtx, Scheduler};
    ///
    /// let ctx = SchedCtx::new(4, Some(fitted_interference()));
    /// let schedule = ElasticPartitioning::gpulet_int()
    ///     .schedule(&ctx, &[50.0; 5])
    ///     .unwrap();
    /// schedule.validate(&ctx.lm, 4).unwrap();
    /// let assigned: f64 = schedule.assigned_rates().iter().sum();
    /// assert!(assigned >= 250.0 - 1e-6, "covers the offered 250 req/s");
    /// ```
    pub fn gpulet_int() -> Self {
        ElasticPartitioning { interference_aware: true }
    }

    /// MINREQUIREDPARTITION: smallest size sustaining `rate` solo.
    /// (MAXEFFICIENTPARTITION is `ctx.knee_pct`, precomputed at context
    /// build — the curve only depends on the profiled latency model.)
    fn min_required_partition(&self, ctx: &SchedCtx, m: ModelId, rate: f64) -> u32 {
        for &p in &PARTITIONS {
            if let Some((r, _)) = ctx.max_rate(m, p) {
                if r * crate::sched::types::CAPACITY_FRACTION >= rate {
                    return p;
                }
            }
        }
        100
    }

    /// Predicted interference stretch for a hypothetical plan on `spec`,
    /// given the allocated co-resident let on the same GPU (if any).
    fn intf_for(
        &self,
        ctx: &SchedCtx,
        alloc: &[LetPlan],
        probe: &LetPlan,
    ) -> f64 {
        if !self.interference_aware {
            return 0.0;
        }
        alloc
            .iter()
            .filter(|lp| lp.spec.gpu == probe.spec.gpu && lp.spec != probe.spec)
            .map(|lp| ctx.predicted_intf(probe, lp))
            .fold(0.0, f64::max)
    }

    /// Co-resident plans of `probe`'s GPU must stay feasible once it
    /// lands next to them (interference-aware only). Because batch sizes
    /// are *squishy*, a disturbed neighbor may shrink its batches to
    /// re-fit — this returns the adapted neighbor plans (indexes into
    /// `alloc`) or `None` when no adaptation works.
    fn adapt_neighbors(
        &self,
        ctx: &SchedCtx,
        alloc: &[LetPlan],
        probe: &LetPlan,
    ) -> Option<Vec<(usize, LetPlan)>> {
        if !self.interference_aware {
            return Some(vec![]);
        }
        let mut adapted = Vec::new();
        for (i, lp) in alloc.iter().enumerate() {
            if lp.spec.gpu != probe.spec.gpu || lp.spec == probe.spec {
                continue;
            }
            let intf = ctx.predicted_intf(lp, probe);
            if lp.feasible(&ctx.lm, intf) {
                continue;
            }
            let new_plan = crate::sched::types::squish_plan(&ctx.lm, lp, intf)?;
            adapted.push((i, new_plan));
        }
        Some(adapted)
    }

    /// Try to MERGE `m` (rate `want`) into an allocated plan via temporal
    /// sharing. Returns the absorbed rate on success.
    fn try_merge(
        &self,
        ctx: &SchedCtx,
        alloc: &mut [LetPlan],
        m: ModelId,
        want: f64,
    ) -> Option<f64> {
        // Prefer the smallest-size plan that can absorb the whole want
        // (saves big lets for heavy models).
        let mut order: Vec<usize> = (0..alloc.len()).collect();
        order.sort_by_key(|&i| alloc[i].spec.size_pct);
        for i in order {
            let (spec, intf) = {
                let plan = &alloc[i];
                let others: Vec<&LetPlan> = alloc
                    .iter()
                    .filter(|lp| lp.spec.gpu == plan.spec.gpu && lp.spec != plan.spec)
                    .collect();
                let mut worst: f64 = 0.0;
                if self.interference_aware {
                    for o in &others {
                        worst = worst.max(ctx.predicted_intf(plan, o));
                    }
                }
                (plan.spec, worst)
            };
            // Largest batch that could work on this partition at all.
            let Some(max_b) = ctx.best_batch_half_slo(m, spec.size_pct) else {
                continue;
            };
            // Find the largest batch whose merged duty cycle still fits.
            let mut best: Option<(u32, f64)> = None;
            for &b in crate::perfmodel::BATCHES.iter().filter(|&&b| b <= max_b) {
                let head = alloc[i].headroom_rate(&ctx.lm, m, b, intf);
                if head >= want - EPS_RATE {
                    best = Some((b, head));
                }
            }
            if let Some((b, _)) = best {
                alloc[i].assignments.push(Assignment { model: m, batch: b, rate: want });
                debug_assert!(alloc[i].feasible(&ctx.lm, intf));
                return Some(want);
            }
        }
        None
    }

    /// FINDBESTFIT: place (m, remaining) on the best-fitting free gpu-let
    /// or merge into an allocated one. Mutates `remain`/`alloc`; returns
    /// the rate absorbed.
    fn find_best_fit(
        &self,
        ctx: &SchedCtx,
        remain: &mut Vec<GpuLetSpec>,
        alloc: &mut Vec<LetPlan>,
        m: ModelId,
        p_ideal: u32,
        remaining: f64,
    ) -> Option<f64> {
        // Best fit over the *post-split* size: a whole GPU that can SPLIT
        // down to exactly p_ideal is a perfect fit (fit 0), an oversized
        // leftover ranks by its excess. Equal fits tie-break on the
        // predicted interference against that GPU's allocated
        // co-residents (interference-aware only — this is what steers
        // two heavy models onto different GPUs), then on the smaller
        // original size (conserve whole GPUs). This is line 20's
        // ascending-size sweep generalized to the SPLIT option.
        let mut order: Vec<(u32, u32, u32, usize)> = remain
            .iter()
            .enumerate()
            .filter(|(_, s)| s.size_pct >= p_ideal)
            .map(|(idx, s)| {
                let use_size = if s.size_pct == 100 && p_ideal < 100 {
                    split_of(p_ideal).map_or(100, |(a, _)| a)
                } else {
                    s.size_pct
                };
                let intf_key = if self.interference_aware {
                    let b_guess = ctx.best_batch_half_slo(m, use_size).unwrap_or(1);
                    let probe = LetPlan {
                        spec: GpuLetSpec { gpu: s.gpu, size_pct: use_size },
                        assignments: vec![Assignment { model: m, batch: b_guess, rate: 0.0 }],
                    };
                    (self.intf_for(ctx, alloc, &probe) * 1000.0) as u32
                } else {
                    0
                };
                (use_size - p_ideal, intf_key, s.size_pct, idx)
            })
            .collect();
        order.sort_unstable();

        for (_, _, _, idx) in order {
            let cand = remain[idx];
            // SPLIT a whole GPU down to the ideal size (line 23-25).
            let (use_spec, leftover) = if cand.size_pct == 100 && p_ideal < 100 {
                match split_of(p_ideal) {
                    Some((a, rem)) => (
                        GpuLetSpec { gpu: cand.gpu, size_pct: a },
                        Some(GpuLetSpec { gpu: cand.gpu, size_pct: rem }),
                    ),
                    None => (cand, None),
                }
            } else {
                (cand, None)
            };

            let p = use_spec.fraction();
            // Line 27: b = argmax_b L(b, size) <= SLO budget. The duty-
            // cycle rule (2D <= SLO) makes the budget SLO/2 for a solo
            // let; memoized per (model, partition) in the capacity table.
            let Some(b) = ctx.best_batch_half_slo(m, use_spec.size_pct) else {
                continue;
            };
            // Build the probe plan to evaluate interference (line 28).
            let mut probe = LetPlan {
                spec: use_spec,
                assignments: vec![Assignment { model: m, batch: b, rate: 0.0 }],
            };
            let intf = self.intf_for(ctx, alloc, &probe);
            let exec = ctx.lm.latency_ms(m, b, p) * (1.0 + intf);
            if 2.0 * exec > ctx.lm.slo_ms(m) {
                // Interference pushes past SLO: try a smaller batch first.
                let Some(bb) = crate::perfmodel::BATCHES
                    .iter()
                    .copied()
                    .filter(|&bb| {
                        2.0 * ctx.lm.latency_ms(m, bb, p) * (1.0 + intf)
                            <= ctx.lm.slo_ms(m)
                    })
                    .last()
                else {
                    continue;
                };
                probe.assignments[0].batch = bb;
            }
            let b = probe.assignments[0].batch;
            let exec = ctx.lm.latency_ms(m, b, p) * (1.0 + intf);
            let capacity =
                b as f64 * 1000.0 / exec * crate::sched::types::CAPACITY_FRACTION;
            if capacity <= 0.0 {
                continue;
            }
            let Some(adapted) = self.adapt_neighbors(ctx, alloc, &probe) else {
                continue;
            };
            let assigned = remaining.min(capacity);
            probe.assignments[0].rate = assigned;
            debug_assert!(probe.feasible(&ctx.lm, intf));

            // Lines 33-38: prefer temporal-sharing MERGE when an already
            // allocated gpu-let can absorb this same load — then the
            // split is reverted and the free let stays free.
            if let Some(merged) = self.try_merge(ctx, alloc, m, assigned) {
                return Some(merged); // REVERTSPLIT: `remain` untouched.
            }

            // Commit: consume the candidate, release the leftover half,
            // re-squish disturbed neighbors.
            for (i, plan) in adapted {
                alloc[i] = plan;
            }
            remain.swap_remove(idx);
            if let Some(rest) = leftover {
                remain.push(rest);
            }
            alloc.push(probe);
            return Some(assigned);
        }

        // No free gpu-let fits; merging into allocated capacity is the
        // last resort (keeps Algorithm 1's spirit: use what exists).
        self.try_merge(ctx, alloc, m, remaining)
    }
}

impl Scheduler for ElasticPartitioning {
    fn name(&self) -> &'static str {
        if self.interference_aware {
            "gpulet+int"
        } else {
            "gpulet"
        }
    }

    fn interference_aware(&self) -> bool {
        self.interference_aware
    }

    fn schedule(&self, ctx: &SchedCtx, rates: &[f64; 5]) -> Result<Schedule> {
        crate::sched::types::validate_rates(rates)?;
        // Reset remain_gpulets: every GPU whole (lines 2-4).
        let mut remain: Vec<GpuLetSpec> = (0..ctx.num_gpus)
            .map(|gpu| GpuLetSpec { gpu, size_pct: 100 })
            .collect();
        let mut alloc: Vec<LetPlan> = Vec::new();

        // Models sorted by rate, descending (line 3).
        let mut models: Vec<(ModelId, f64)> = ModelId::ALL
            .iter()
            .map(|&m| (m, rates[m.index()]))
            .filter(|&(_, r)| r > 0.0)
            .collect();
        models.sort_by(|a, b| b.1.total_cmp(&a.1));

        for (m, rate) in models {
            let mut remaining = rate;
            let mut rounds = 0usize;
            while remaining > EPS_RATE {
                rounds += 1;
                if rounds > 4 * ctx.num_gpus.max(1) * PARTITIONS.len() {
                    return Err(Error::NotSchedulable(format!(
                        "{m}: no progress after {rounds} placement rounds"
                    )));
                }
                // MAXEFFICIENTPARTITION: precomputed at context build
                // (placement-independent knee of the rate curve).
                let p_eff = ctx.knee_pct(m);
                let p_req = self.min_required_partition(ctx, m, remaining);
                let p_ideal = p_eff.min(p_req);
                match self.find_best_fit(ctx, &mut remain, &mut alloc, m, p_ideal, remaining)
                {
                    Some(assigned) if assigned > EPS_RATE => remaining -= assigned,
                    _ => {
                        return Err(Error::NotSchedulable(format!(
                            "{m}: {remaining:.1} req/s left with no fitting gpu-let"
                        )))
                    }
                }
            }
        }

        let sched = Schedule { lets: alloc };
        sched.validate(&ctx.lm, ctx.num_gpus)?;
        Ok(sched)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(gpus: usize) -> SchedCtx {
        SchedCtx::new(gpus, None)
    }

    fn ctx_int(gpus: usize) -> SchedCtx {
        use crate::interference::linear_model::{
            profiling_population, train_val_split, InterferenceModel,
        };
        use crate::interference::GroundTruth;
        let (train, _) =
            train_val_split(profiling_population(&GroundTruth::default()), 0.7, 42);
        SchedCtx::new(gpus, Some(InterferenceModel::fit(&train).unwrap()))
    }

    #[test]
    fn schedules_light_load_on_one_gpu() {
        let c = ctx(4);
        let s = ElasticPartitioning::gpulet()
            .schedule(&c, &[50.0, 0.0, 0.0, 0.0, 0.0])
            .unwrap();
        s.validate(&c.lm, 4).unwrap();
        assert!(s.assigned_rates()[ModelId::Lenet.index()] >= 50.0 - 1e-6);
        // LeNet's knee is small: it must NOT get a whole GPU.
        assert!(s.lets.iter().all(|l| l.spec.size_pct <= 50));
    }

    #[test]
    fn covers_equal_scenario() {
        let c = ctx(4);
        let rates = [50.0; 5];
        let s = ElasticPartitioning::gpulet().schedule(&c, &rates).unwrap();
        s.validate(&c.lm, 4).unwrap();
        let assigned = s.assigned_rates();
        for m in ModelId::ALL {
            assert!(
                assigned[m.index()] >= rates[m.index()] - 1e-6,
                "{m}: assigned {} < offered {}",
                assigned[m.index()],
                rates[m.index()]
            );
        }
    }

    #[test]
    fn int_variant_also_covers_equal() {
        let c = ctx_int(4);
        let s = ElasticPartitioning::gpulet_int().schedule(&c, &[50.0; 5]).unwrap();
        s.validate(&c.lm, 4).unwrap();
        let assigned = s.assigned_rates();
        assert!(assigned.iter().sum::<f64>() >= 250.0 - 1e-6);
    }

    #[test]
    fn absurd_load_not_schedulable() {
        let c = ctx(4);
        let err = ElasticPartitioning::gpulet()
            .schedule(&c, &[1e9, 1e9, 1e9, 1e9, 1e9])
            .unwrap_err();
        assert!(matches!(err, Error::NotSchedulable(_)));
    }

    #[test]
    fn zero_rates_produce_empty_schedule() {
        let c = ctx(4);
        let s = ElasticPartitioning::gpulet().schedule(&c, &[0.0; 5]).unwrap();
        assert!(s.lets.is_empty());
        assert_eq!(s.total_allocated_pct(), 0);
    }

    #[test]
    fn heavy_model_gets_multiple_lets() {
        let c = ctx(4);
        // Well beyond one GPU's VGG capacity.
        let (r100, _) = c.lm.max_rate(ModelId::Vgg, 1.0).unwrap();
        let want = r100 * 2.5;
        let s = ElasticPartitioning::gpulet()
            .schedule(&c, &[0.0, 0.0, 0.0, 0.0, want])
            .unwrap();
        let vgg_lets = s
            .lets
            .iter()
            .filter(|l| l.assignments.iter().any(|a| a.model == ModelId::Vgg))
            .count();
        assert!(vgg_lets >= 3, "vgg spread over {vgg_lets} lets");
        assert!(s.assigned_rates()[ModelId::Vgg.index()] >= want - 1e-6);
    }

    #[test]
    fn partitioning_beats_whole_gpus_for_small_models() {
        // 4 GPUs of LeNet-only load: without partitioning, 4 lets of 100%
        // would waste most of each GPU. Elastic must allocate less than
        // the whole cluster for a load 4 whole GPUs could barely improve.
        let c = ctx(4);
        let (r_knee, _) = c.lm.max_rate(ModelId::Lenet, 0.2).unwrap();
        let s = ElasticPartitioning::gpulet()
            .schedule(&c, &[r_knee * 2.0, 0.0, 0.0, 0.0, 0.0])
            .unwrap();
        assert!(s.total_allocated_pct() <= 200, "allocated {}%", s.total_allocated_pct());
    }

    #[test]
    fn int_variant_is_more_conservative() {
        // Find a rate the oblivious variant accepts; the aware variant
        // must never accept a strictly higher violation risk (i.e. its
        // max accepted rate is <= the oblivious one for contended mixes).
        let co = ctx(1);
        let ci = ctx_int(1);
        let obl = ElasticPartitioning::gpulet();
        let aware = ElasticPartitioning::gpulet_int();
        let mut max_obl = 0.0f64;
        let mut max_aware = 0.0f64;
        for step in 1..=40 {
            let r = step as f64 * 25.0;
            let rates = [0.0, 0.0, r, 0.0, r];
            if obl.schedule(&co, &rates).is_ok() {
                max_obl = r;
            }
            if aware.schedule(&ci, &rates).is_ok() {
                max_aware = r;
            }
        }
        assert!(max_aware <= max_obl, "aware {max_aware} > oblivious {max_obl}");
        assert!(max_aware > 0.0);
    }

    #[test]
    fn respects_cluster_capacity_invariants() {
        let c = ctx(2);
        for rates in [
            [100.0, 100.0, 100.0, 50.0, 50.0],
            [600.0, 0.0, 0.0, 0.0, 0.0],
            [0.0, 200.0, 0.0, 200.0, 0.0],
        ] {
            if let Ok(s) = ElasticPartitioning::gpulet().schedule(&c, &rates) {
                s.validate(&c.lm, 2).unwrap();
            }
        }
    }
}
