//! Space-time scheduling: spatial gpu-let partitioning extended with a
//! temporal packing pass (the ROADMAP's "add the temporal axis" item;
//! cf. Dynamic Space-Time Scheduling, arXiv 1901.00041).
//!
//! The combined scheduler decides per model pair whether spatial
//! splitting, temporal sharing, or a dedicated gpu-let wins:
//!
//! 1. **Spatial first** — delegate to Elastic Partitioning (Algorithm 1,
//!    interference-aware whenever the ctx carries a fitted model). When
//!    it accepts, its schedule is returned unchanged, so `spacetime` is
//!    byte-identical to `gpulet`/`gpulet+int` on every load the spatial
//!    scheduler can handle (pinned by `tests/spacetime_equivalence.rs`).
//! 2. **Temporal fallback** — only when spatial partitioning alone
//!    rejects, re-pack from scratch with time-sliced duty cycles: a
//!    gpu-let may host two (or more) models whose executions interleave
//!    in one repeating round. Beyond Algorithm 1's full-absorption
//!    MERGE, this pass can boost existing assignments, absorb a rate
//!    *partially* across several lets, and squish a target let's
//!    batches to unlock a merge.
//!
//! Feasibility of a time-sliced let is the duty-cycle model of
//! `sched::types` plus two space-time-specific bounds:
//!
//! * **duty-sum** — the interference-inflated utilization
//!   `Σ rate_i·E_i/(b_i·1000)` must stay ≤ 1.0 (all co-tenants' time
//!   slices fit one wall-clock; enforced again by `Schedule::validate`);
//! * **timeout slack** — each co-tenant's predicted p99 must fit its
//!   SLO under the engine's `slo_timeout_us` semantics: the batcher
//!   arms `timeout = SLO − 1.25·D` and a batch dispatched at the
//!   timeout completes within its own execution, so we require
//!   `SLO_i ≥ 1.25·D + E_i` for every model i of a shared let (with D
//!   the summed, interference-inflated duty). This keeps every planned
//!   timeout constant at least the model's own (solo) duty — queueing
//!   behind co-tenants never eats the dispatch window.

use crate::error::{Error, Result};
use crate::gpu::gpulet::{split_of, GpuLetSpec};
use crate::models::ModelId;
use crate::perfmodel::profile_table::PARTITIONS;
use crate::perfmodel::{LatencyModel, BATCHES};
use crate::sched::elastic::ElasticPartitioning;
use crate::sched::types::{
    squish_plan, Assignment, LetPlan, SchedCtx, Schedule, Scheduler,
    CAPACITY_FRACTION,
};

/// Residual-rate epsilon: request rates below this are considered served.
const EPS_RATE: f64 = 1e-6;

/// Space-time scheduler (`--algo spacetime`): Elastic Partitioning with
/// a temporal packing fallback. The `spatial_only` / `temporal_only`
/// variants disable one axis each — the three-mode comparison of
/// `experiments::spacetime`.
///
/// # Examples
///
/// ```
/// use gpulets::sched::{SchedCtx, Scheduler, SpaceTimeScheduler};
///
/// let ctx = SchedCtx::new(4, None);
/// let schedule = SpaceTimeScheduler::combined()
///     .schedule(&ctx, &[50.0; 5])
///     .unwrap();
/// schedule.validate(&ctx.lm, 4).unwrap();
/// ```
#[derive(Clone, Copy, Debug)]
pub struct SpaceTimeScheduler {
    /// Allow spatial splitting (gpu-lets smaller than a whole GPU).
    spatial: bool,
    /// Allow the temporal packing fallback (time-sliced co-tenants).
    temporal: bool,
}

impl SpaceTimeScheduler {
    /// Both axes: spatial splitting first, temporal packing as the
    /// fallback. This is the registered `--algo spacetime` variant.
    pub fn combined() -> Self {
        SpaceTimeScheduler { spatial: true, temporal: true }
    }

    /// Temporal sharing disabled — pure delegation to Elastic
    /// Partitioning (the degenerate-equivalence baseline).
    pub fn spatial_only() -> Self {
        SpaceTimeScheduler { spatial: true, temporal: false }
    }

    /// Spatial splitting disabled — whole-GPU lets only, time-sliced.
    pub fn temporal_only() -> Self {
        SpaceTimeScheduler { spatial: false, temporal: true }
    }

    /// Smallest grid size sustaining `rate` solo (MinRequiredPartition).
    fn min_required_partition(ctx: &SchedCtx, m: ModelId, rate: f64) -> u32 {
        for &p in &PARTITIONS {
            if let Some((r, _)) = ctx.max_rate(m, p) {
                if r * CAPACITY_FRACTION >= rate {
                    return p;
                }
            }
        }
        100
    }

    /// Worst predicted interference stretch of `alloc[i]` against its
    /// co-resident lets (index-based exclusion, so a 50:50 GPU pairs
    /// correctly even when both specs compare equal).
    fn plan_intf(ctx: &SchedCtx, alloc: &[LetPlan], i: usize) -> f64 {
        let me = &alloc[i];
        alloc
            .iter()
            .enumerate()
            .filter(|(j, lp)| *j != i && lp.spec.gpu == me.spec.gpu)
            .map(|(_, lp)| ctx.predicted_intf(me, lp))
            .fold(0.0, f64::max)
    }

    /// Worst predicted stretch of a probe plan not yet in `alloc`.
    fn intf_against(ctx: &SchedCtx, alloc: &[LetPlan], probe: &LetPlan) -> f64 {
        alloc
            .iter()
            .filter(|lp| lp.spec.gpu == probe.spec.gpu)
            .map(|lp| ctx.predicted_intf(probe, lp))
            .fold(0.0, f64::max)
    }

    /// Timeout-slack bound for a time-sliced let: `SLO_i >= 1.25·D + E_i`
    /// for every assignment — the planned `slo_timeout_us` constant
    /// (`SLO − 1.25·D`) stays at least the model's own execution time.
    fn timeout_slack_ok(lm: &LatencyModel, lp: &LetPlan, intf: f64) -> bool {
        let d = lp.duty_cycle_ms(lm, intf);
        let p = lp.spec.fraction();
        lp.assignments.iter().all(|a| {
            let e = lm.latency_ms(a.model, a.batch, p) * (1.0 + intf);
            lm.slo_ms(a.model) + 1e-9 >= 1.25 * d + e
        })
    }

    /// Global feasibility of an allocation under mutually-predicted
    /// interference; time-sliced lets additionally honour the
    /// timeout-slack bound. Every mutation the packing pass commits is
    /// re-checked through here.
    fn all_feasible(&self, ctx: &SchedCtx, alloc: &[LetPlan]) -> bool {
        (0..alloc.len()).all(|i| {
            let intf = Self::plan_intf(ctx, alloc, i);
            let lp = &alloc[i];
            lp.feasible(&ctx.lm, intf)
                && lp.utilization(&ctx.lm, intf) <= 1.0 + 1e-9
                && (lp.assignments.len() < 2
                    || Self::timeout_slack_ok(&ctx.lm, lp, intf))
        })
    }

    /// One squish round over infeasible plans (a newly landed neighbour
    /// may disturb an existing let), then the authoritative global
    /// re-check — squishing changes batches, which shifts the predicted
    /// interference itself.
    fn repair(&self, ctx: &SchedCtx, trial: &mut [LetPlan]) -> bool {
        for i in 0..trial.len() {
            let intf = Self::plan_intf(ctx, trial, i);
            if !trial[i].feasible(&ctx.lm, intf) {
                match squish_plan(&ctx.lm, &trial[i], intf) {
                    Some(sq) => trial[i] = sq,
                    None => return false,
                }
            }
        }
        self.all_feasible(ctx, trial)
    }

    /// Raise the rate of an existing assignment of `m` with spare
    /// capacity (no structural change: duty cycles and interference are
    /// untouched, so the capacity cap is the only binding constraint).
    fn boost(&self, ctx: &SchedCtx, alloc: &mut [LetPlan], m: ModelId, want: f64) -> f64 {
        let mut best: Option<(usize, usize, f64)> = None;
        for (i, lp) in alloc.iter().enumerate() {
            let intf = Self::plan_intf(ctx, alloc, i);
            let d = lp.duty_cycle_ms(&ctx.lm, intf);
            for (j, a) in lp.assignments.iter().enumerate() {
                if a.model != m {
                    continue;
                }
                let cap = a.batch as f64 * 1000.0 / d * CAPACITY_FRACTION;
                let extra = (cap - a.rate).min(want);
                if extra > EPS_RATE
                    && best.is_none_or(|(_, _, e)| extra > e + EPS_RATE)
                {
                    best = Some((i, j, extra));
                }
            }
        }
        let Some((i, j, extra)) = best else { return 0.0 };
        alloc[i].assignments[j].rate += extra;
        debug_assert!(self.all_feasible(ctx, alloc));
        extra
    }

    /// Place `m` solo on a free gpu-let, best-fit by post-split size
    /// (SPLIT allowed only in spatial mode; temporal-only packs whole
    /// GPUs). Returns the absorbed rate.
    fn place_solo(
        &self,
        ctx: &SchedCtx,
        remain: &mut Vec<GpuLetSpec>,
        alloc: &mut Vec<LetPlan>,
        m: ModelId,
        want: f64,
    ) -> f64 {
        let p_ideal = if self.spatial {
            ctx.knee_pct(m).min(Self::min_required_partition(ctx, m, want))
        } else {
            100
        };
        let mut order: Vec<(u32, u32, usize, usize)> = remain
            .iter()
            .enumerate()
            .filter(|(_, s)| s.size_pct >= p_ideal)
            .map(|(idx, s)| {
                let use_size = if self.spatial && s.size_pct == 100 && p_ideal < 100 {
                    split_of(p_ideal).map_or(100, |(a, _)| a)
                } else {
                    s.size_pct
                };
                (use_size.saturating_sub(p_ideal), s.size_pct, s.gpu, idx)
            })
            .collect();
        order.sort_unstable();

        for (_, _, _, idx) in order {
            let cand = remain[idx];
            let (use_spec, leftover) =
                if self.spatial && cand.size_pct == 100 && p_ideal < 100 {
                    match split_of(p_ideal) {
                        Some((a, rem)) => (
                            GpuLetSpec { gpu: cand.gpu, size_pct: a },
                            Some(GpuLetSpec { gpu: cand.gpu, size_pct: rem }),
                        ),
                        None => (cand, None),
                    }
                } else {
                    (cand, None)
                };
            let Some(b) = ctx.best_batch_half_slo(m, use_spec.size_pct) else {
                continue;
            };
            let mut probe = LetPlan {
                spec: use_spec,
                assignments: vec![Assignment { model: m, batch: b, rate: 0.0 }],
            };
            let p = use_spec.fraction();
            let intf = Self::intf_against(ctx, alloc, &probe);
            if 2.0 * ctx.lm.latency_ms(m, b, p) * (1.0 + intf) > ctx.lm.slo_ms(m) {
                // Interference pushes past the SLO: shrink the batch.
                let Some(bb) = BATCHES
                    .iter()
                    .copied()
                    .filter(|&bb| {
                        2.0 * ctx.lm.latency_ms(m, bb, p) * (1.0 + intf)
                            <= ctx.lm.slo_ms(m)
                    })
                    .last()
                else {
                    continue;
                };
                probe.assignments[0].batch = bb;
            }
            let b = probe.assignments[0].batch;
            let exec = ctx.lm.latency_ms(m, b, p) * (1.0 + intf);
            let capacity = b as f64 * 1000.0 / exec * CAPACITY_FRACTION;
            if capacity <= EPS_RATE {
                continue;
            }
            let assigned = want.min(capacity);
            probe.assignments[0].rate = assigned;

            let mut trial = alloc.clone();
            trial.push(probe);
            if !self.repair(ctx, &mut trial) {
                continue;
            }
            *alloc = trial;
            remain.swap_remove(idx);
            if let Some(rest) = leftover {
                remain.push(rest);
            }
            return assigned;
        }
        0.0
    }

    /// Time-sliced MERGE of `m` into an allocated let. Unlike Algorithm
    /// 1's merge this may absorb `want` *partially* and may squish the
    /// target let's existing batches to make room; the candidate
    /// absorbing the most rate wins. Returns the absorbed rate.
    fn merge(
        &self,
        ctx: &SchedCtx,
        alloc: &mut Vec<LetPlan>,
        m: ModelId,
        want: f64,
    ) -> f64 {
        let mut best: Option<(f64, Vec<LetPlan>)> = None;
        for i in 0..alloc.len() {
            if alloc[i].assignments.iter().any(|a| a.model == m) {
                continue; // same-model top-ups are `boost`'s job
            }
            let Some(max_b) = ctx.best_batch_half_slo(m, alloc[i].spec.size_pct)
            else {
                continue;
            };
            for &b in BATCHES.iter().filter(|&&b| b <= max_b) {
                let mut trial = alloc.clone();
                trial[i]
                    .assignments
                    .push(Assignment { model: m, batch: b, rate: 0.0 });
                let mut intf = Self::plan_intf(ctx, &trial, i);
                if !trial[i].feasible(&ctx.lm, intf) {
                    // Squish the target's batches to open the round up.
                    let Some(sq) = squish_plan(&ctx.lm, &trial[i], intf) else {
                        continue;
                    };
                    trial[i] = sq;
                    intf = Self::plan_intf(ctx, &trial, i);
                    if !trial[i].feasible(&ctx.lm, intf) {
                        continue;
                    }
                }
                if !Self::timeout_slack_ok(&ctx.lm, &trial[i], intf) {
                    continue;
                }
                let d = trial[i].duty_cycle_ms(&ctx.lm, intf);
                let b_used = trial[i].assignments.last().map_or(b, |a| a.batch);
                let head =
                    (b_used as f64 * 1000.0 / d * CAPACITY_FRACTION).min(want);
                if head <= EPS_RATE {
                    continue;
                }
                if let Some(last) = trial[i].assignments.last_mut() {
                    last.rate = head;
                }
                if !self.repair(ctx, &mut trial) {
                    continue;
                }
                if best.as_ref().is_none_or(|(got, _)| head > got + EPS_RATE) {
                    best = Some((head, trial));
                }
            }
        }
        match best {
            Some((got, trial)) => {
                *alloc = trial;
                got
            }
            None => 0.0,
        }
    }

    /// The temporal packing pass: models in descending rate order; per
    /// round prefer boosting an existing assignment, then a dedicated
    /// (possibly split) let, then a time-sliced merge.
    fn packed(&self, ctx: &SchedCtx, rates: &[f64; 5]) -> Result<Schedule> {
        let mut remain: Vec<GpuLetSpec> = (0..ctx.num_gpus)
            .map(|gpu| GpuLetSpec { gpu, size_pct: 100 })
            .collect();
        let mut alloc: Vec<LetPlan> = Vec::new();

        let mut models: Vec<(ModelId, f64)> = ModelId::ALL
            .iter()
            .map(|&m| (m, rates[m.index()]))
            .filter(|&(_, r)| r > 0.0)
            .collect();
        models.sort_by(|a, b| b.1.total_cmp(&a.1));

        for (m, rate) in models {
            let mut remaining = rate;
            let mut rounds = 0usize;
            while remaining > EPS_RATE {
                rounds += 1;
                if rounds > 8 * ctx.num_gpus.max(1) * PARTITIONS.len() {
                    return Err(Error::NotSchedulable(format!(
                        "{m}: no progress after {rounds} space-time rounds"
                    )));
                }
                let mut got = self.boost(ctx, &mut alloc, m, remaining);
                if got <= EPS_RATE {
                    got = self.place_solo(ctx, &mut remain, &mut alloc, m, remaining);
                }
                if got <= EPS_RATE {
                    got = self.merge(ctx, &mut alloc, m, remaining);
                }
                if got <= EPS_RATE {
                    return Err(Error::NotSchedulable(format!(
                        "{m}: {remaining:.1} req/s left with no spatial or temporal fit"
                    )));
                }
                remaining -= got;
            }
        }

        let sched = Schedule { lets: alloc };
        sched.validate(&ctx.lm, ctx.num_gpus)?;
        Ok(sched)
    }
}

impl Scheduler for SpaceTimeScheduler {
    fn name(&self) -> &'static str {
        match (self.spatial, self.temporal) {
            (true, true) => "spacetime",
            (true, false) => "spacetime-spatial",
            (false, true) => "spacetime-temporal",
            (false, false) => unreachable!("constructors enable at least one axis"),
        }
    }

    fn interference_aware(&self) -> bool {
        true
    }

    fn schedule(&self, ctx: &SchedCtx, rates: &[f64; 5]) -> Result<Schedule> {
        crate::sched::types::validate_rates(rates)?;
        if self.spatial {
            // Elastic Partitioning first; its interference awareness
            // follows the ctx (predicted stretch is 0 without a fitted
            // model), so one variant covers gpulet and gpulet+int.
            let spatial = ElasticPartitioning::gpulet_int().schedule(ctx, rates);
            if spatial.is_ok() || !self.temporal {
                return spatial;
            }
        }
        self.packed(ctx, rates)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::Scheduler;

    fn ctx(gpus: usize) -> SchedCtx {
        SchedCtx::new(gpus, None)
    }

    fn ctx_int(gpus: usize) -> SchedCtx {
        use crate::interference::linear_model::{
            profiling_population, train_val_split, InterferenceModel,
        };
        use crate::interference::GroundTruth;
        let (train, _) =
            train_val_split(profiling_population(&GroundTruth::default()), 0.7, 42);
        SchedCtx::new(gpus, Some(InterferenceModel::fit(&train).unwrap()))
    }

    fn sample_rates() -> Vec<[f64; 5]> {
        vec![
            [50.0; 5],
            [100.0, 0.0, 50.0, 0.0, 25.0],
            [0.0, 200.0, 0.0, 0.0, 80.0],
            [300.0, 100.0, 100.0, 50.0, 50.0],
            [0.0; 5],
            [1e9; 5],
        ]
    }

    #[test]
    fn spatial_only_is_exactly_elastic() {
        for gpus in [1, 4] {
            for c in [ctx(gpus), ctx_int(gpus)] {
                for rates in sample_rates() {
                    let a = SpaceTimeScheduler::spatial_only().schedule(&c, &rates);
                    let b = ElasticPartitioning::gpulet_int().schedule(&c, &rates);
                    match (a, b) {
                        (Ok(x), Ok(y)) => assert_eq!(x, y, "{rates:?}"),
                        (Err(_), Err(_)) => {}
                        (x, y) => panic!(
                            "verdicts differ on {rates:?}: {:?} vs {:?}",
                            x.is_ok(),
                            y.is_ok()
                        ),
                    }
                }
            }
        }
    }

    #[test]
    fn combined_returns_elastic_schedule_when_spatial_accepts() {
        let c = ctx(4);
        for rates in sample_rates() {
            if let Ok(e) = ElasticPartitioning::gpulet().schedule(&c, &rates) {
                let s = SpaceTimeScheduler::combined().schedule(&c, &rates).unwrap();
                assert_eq!(s, e, "{rates:?}");
            }
        }
    }

    #[test]
    fn combined_beats_spatial_on_three_long_models_one_gpu() {
        // 1 GPU, three long-SLO models at 30 req/s each. Elastic places
        // GoogLeNet on a 20% split and ResNet on the 80% leftover, then
        // VGG finds no free let and no full-absorption merge (both duty
        // cycles would blow 2D <= SLO without shrinking the residents'
        // batches, which Algorithm 1's MERGE cannot do) — NotSchedulable.
        // The temporal pass squishes ResNet's batch and time-slices VGG
        // into the same let.
        let c = ctx(1);
        let rates = [0.0, 30.0, 30.0, 0.0, 30.0];
        let spatial_err = SpaceTimeScheduler::spatial_only().schedule(&c, &rates);
        assert!(spatial_err.is_err(), "elastic unexpectedly schedules the mix");
        let s = SpaceTimeScheduler::combined().schedule(&c, &rates).unwrap();
        s.validate(&c.lm, 1).unwrap();
        let assigned = s.assigned_rates();
        for m in [ModelId::Googlenet, ModelId::Resnet, ModelId::Vgg] {
            assert!(
                assigned[m.index()] >= 30.0 - 1e-6,
                "{m} assigned {}",
                assigned[m.index()]
            );
        }
        // The win comes from a time-sliced let.
        assert!(
            s.lets.iter().any(|lp| lp.assignments.len() >= 2),
            "expected a temporally shared let: {:?}",
            s.lets
        );
    }

    #[test]
    fn temporal_only_time_slices_a_whole_gpu() {
        // 1 GPU, no splitting allowed: GoogLeNet takes the whole let,
        // VGG must time-slice into it.
        let c = ctx(1);
        let s = SpaceTimeScheduler::temporal_only()
            .schedule(&c, &[0.0, 30.0, 0.0, 0.0, 30.0])
            .unwrap();
        s.validate(&c.lm, 1).unwrap();
        assert_eq!(s.lets.len(), 1);
        assert_eq!(s.lets[0].spec.size_pct, 100);
        assert_eq!(s.lets[0].assignments.len(), 2);
        // The shared let honours the duty-sum and timeout-slack bounds.
        let lp = &s.lets[0];
        assert!(lp.utilization(&c.lm, 0.0) <= 1.0 + 1e-9);
        assert!(SpaceTimeScheduler::timeout_slack_ok(&c.lm, lp, 0.0));
    }

    #[test]
    fn emitted_shared_lets_always_hold_spacetime_bounds() {
        // Deterministic mini-sweep: every accepted schedule across a
        // rate grid keeps utilization <= 1 and the timeout slack on all
        // time-sliced lets, under both ctx flavours.
        for c in [ctx(2), ctx_int(2)] {
            for sched in
                [SpaceTimeScheduler::combined(), SpaceTimeScheduler::temporal_only()]
            {
                for g in [0.0, 40.0, 160.0] {
                    for v in [0.0, 30.0, 90.0] {
                        for r in [0.0, 50.0] {
                            let rates = [0.0, g, r, 0.0, v];
                            let Ok(s) = sched.schedule(&c, &rates) else {
                                continue;
                            };
                            s.validate(&c.lm, 2).unwrap();
                            // The timeout-slack bound is the packing
                            // pass's contract; a combined run that
                            // delegated to Elastic Partitioning only
                            // promises 2D <= SLO (and byte-identical
                            // output to `gpulet+int`).
                            let from_packed = !sched.spatial
                                || SpaceTimeScheduler::spatial_only()
                                    .schedule(&c, &rates)
                                    .is_err();
                            for (i, lp) in s.lets.iter().enumerate() {
                                // Inflated bound for packed output;
                                // delegated schedules guarantee it at
                                // stretch 0 (the validate-level check).
                                let intf = if from_packed {
                                    SpaceTimeScheduler::plan_intf(&c, &s.lets, i)
                                } else {
                                    0.0
                                };
                                assert!(
                                    lp.utilization(&c.lm, intf) <= 1.0 + 1e-6,
                                    "{}: util > 1 on {rates:?}",
                                    sched.name()
                                );
                                if lp.assignments.len() >= 2 && from_packed {
                                    assert!(
                                        SpaceTimeScheduler::timeout_slack_ok(
                                            &c.lm, lp, intf
                                        ),
                                        "{}: slack broken on {rates:?}",
                                        sched.name()
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn zero_load_empty_and_absurd_load_rejected() {
        let c = ctx(4);
        for sched in [
            SpaceTimeScheduler::combined(),
            SpaceTimeScheduler::spatial_only(),
            SpaceTimeScheduler::temporal_only(),
        ] {
            let s = sched.schedule(&c, &[0.0; 5]).unwrap();
            assert!(s.lets.is_empty(), "{}", sched.name());
            let err = sched.schedule(&c, &[1e9; 5]).unwrap_err();
            assert!(matches!(err, Error::NotSchedulable(_)), "{}", sched.name());
        }
    }

    #[test]
    fn lenet_never_time_sliced_into_long_duty_cycles() {
        // LeNet's 5 ms SLO cannot absorb any co-tenant's duty cycle:
        // whatever the packing pass emits, LeNet only ever rides solo
        // lets. (2D <= SLO with D >= E_lenet + E_other is impossible for
        // every catalog pairing.)
        let c = ctx(2);
        for scale in [1.0, 2.0, 4.0] {
            let rates = [120.0 * scale, 40.0 * scale, 30.0 * scale, 0.0, 20.0 * scale];
            let Ok(s) = SpaceTimeScheduler::combined().schedule(&c, &rates) else {
                continue;
            };
            for lp in &s.lets {
                if lp.assignments.iter().any(|a| a.model == ModelId::Lenet) {
                    assert_eq!(lp.assignments.len(), 1, "lenet sharing a let");
                }
            }
        }
    }
}
