//! Guided self-tuning — the GSLICE [16] baseline (§6.1).
//!
//! GSLICE spatially shares GPUs but tunes (partition, batch) per model
//! at runtime and does not temporally share a partition between models.
//! The paper evaluates a *guided* version: instead of online trial and
//! error it is handed the profiled batch latencies and each model's
//! precomputed optimal partition — the same information our elastic
//! scheduler uses — to make the comparison fair.
//!
//! Concretely: each model gets dedicated gpu-lets of its profiled
//! optimal size (the knee, bumped up until the rate fits the available
//! let count), packed best-fit onto GPUs with at most two lets each.
//! No temporal-sharing merge — the paper attributes guided self-tuning's
//! losses on `game` exactly to this missing capability.

use crate::error::{Error, Result};
use crate::gpu::gpulet::{GpuLetSpec, MAX_LETS_PER_GPU};
use crate::models::ModelId;
use crate::perfmodel::profile_table::PARTITIONS;
use crate::sched::types::{Assignment, LetPlan, SchedCtx, Schedule, Scheduler};

const EPS_RATE: f64 = 1e-6;

/// GSLICE-style guided self-tuning scheduler.
#[derive(Clone, Copy, Debug, Default)]
pub struct GuidedSelfTuning;

/// Mutable per-GPU packing state.
struct GpuState {
    used_pct: u32,
    lets: usize,
}

impl GuidedSelfTuning {
    /// Place one gpu-let of `size` on the first GPU with room (best-fit
    /// by remaining space).
    fn place(
        gpus: &mut [GpuState],
        size: u32,
    ) -> Option<usize> {
        let mut best: Option<(usize, u32)> = None; // (gpu, leftover)
        for (g, st) in gpus.iter().enumerate() {
            if st.lets >= MAX_LETS_PER_GPU {
                continue;
            }
            if st.used_pct + size > 100 {
                continue;
            }
            let leftover = 100 - st.used_pct - size;
            if best.is_none_or(|(_, l)| leftover < l) {
                best = Some((g, leftover));
            }
        }
        let (g, _) = best?;
        gpus[g].used_pct += size;
        gpus[g].lets += 1;
        Some(g)
    }
}

impl Scheduler for GuidedSelfTuning {
    fn name(&self) -> &'static str {
        "selftune"
    }

    fn schedule(&self, ctx: &SchedCtx, rates: &[f64; 5]) -> Result<Schedule> {
        crate::sched::types::validate_rates(rates)?;
        let mut gpus: Vec<GpuState> = (0..ctx.num_gpus)
            .map(|_| GpuState { used_pct: 0, lets: 0 })
            .collect();
        let mut alloc: Vec<LetPlan> = Vec::new();

        let mut models: Vec<(ModelId, f64)> = ModelId::ALL
            .iter()
            .map(|&m| (m, rates[m.index()]))
            .filter(|&(_, r)| r > 0.0)
            .collect();
        models.sort_by(|a, b| b.1.total_cmp(&a.1));

        for (m, rate) in models {
            // Profiled optimal partition: the knee of the rate curve
            // (precomputed in the capacity table).
            let p_opt = ctx.knee_pct(m);
            let mut remaining = rate;
            // Bump the size up from the knee until the per-let rate and
            // the let count fit the cluster; GSLICE adjusts its partition
            // "to a suitable GPU partition size during runtime" — guided
            // here by the profile.
            let sizes_from_knee: Vec<u32> =
                PARTITIONS.iter().copied().filter(|&s| s >= p_opt).collect();

            'fill: while remaining > EPS_RATE {
                let progressed = false;
                for &size in &sizes_from_knee {
                    let Some((cap, b)) = ctx
                        .max_rate(m, size)
                        .map(|(r, b)| (r * crate::sched::types::CAPACITY_FRACTION, b))
                    else {
                        continue;
                    };
                    if cap <= EPS_RATE {
                        continue;
                    }
                    // Tentatively place a let of this size.
                    let snapshot: Vec<(u32, usize)> =
                        gpus.iter().map(|g| (g.used_pct, g.lets)).collect();
                    if let Some(g) = Self::place(&mut gpus, size) {
                        let take = remaining.min(cap);
                        // If this size cannot cover the remainder and a
                        // bigger one could, prefer bigger (fewer lets).
                        if take < remaining - EPS_RATE && size != 100 {
                            let bigger_helps = sizes_from_knee
                                .iter()
                                .any(|&s2| {
                                    s2 > size
                                        && ctx.max_rate(m, s2).is_some_and(|(c2, _)| {
                                            c2 * crate::sched::types::CAPACITY_FRACTION > cap
                                        })
                                });
                            if bigger_helps {
                                // Roll back and try the bigger size.
                                for (st, (u, l)) in gpus.iter_mut().zip(snapshot) {
                                    st.used_pct = u;
                                    st.lets = l;
                                }
                                continue;
                            }
                        }
                        alloc.push(LetPlan {
                            spec: GpuLetSpec { gpu: g, size_pct: size },
                            assignments: vec![Assignment { model: m, batch: b, rate: take }],
                        });
                        remaining -= take;
                        continue 'fill;
                    }
                }
                if !progressed {
                    return Err(Error::NotSchedulable(format!(
                        "selftune: {m} has {remaining:.1} req/s unplaced"
                    )));
                }
            }
        }

        // Snap each GPU's lets onto a valid layout: sizes already valid;
        // per-GPU counts enforced by `place`.
        let sched = Schedule { lets: alloc };
        sched.validate(&ctx.lm, ctx.num_gpus)?;
        Ok(sched)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(gpus: usize) -> SchedCtx {
        SchedCtx::new(gpus, None)
    }

    #[test]
    fn schedules_single_model() {
        let c = ctx(4);
        let s = GuidedSelfTuning.schedule(&c, &[100.0, 0.0, 0.0, 0.0, 0.0]).unwrap();
        s.validate(&c.lm, 4).unwrap();
        assert!(s.assigned_rates()[ModelId::Lenet.index()] >= 100.0 - 1e-6);
        // One model per gpu-let (no temporal sharing).
        assert!(s.lets.iter().all(|l| l.assignments.len() == 1));
    }

    #[test]
    fn never_temporally_shares() {
        let c = ctx(4);
        if let Ok(s) = GuidedSelfTuning.schedule(&c, &[50.0; 5]) {
            assert!(s.lets.iter().all(|l| l.assignments.len() == 1));
        }
    }

    #[test]
    fn game_like_mix_weaker_than_elastic() {
        // The paper: guided self-tuning underperforms on game (many
        // LeNets + one ResNet) because it cannot temporally share.
        use crate::sched::elastic::ElasticPartitioning;
        let c = ctx(4);
        let game = crate::apps::App::game();
        let mut max_st = 0.0f64;
        let mut max_el = 0.0f64;
        for step in 1..=60 {
            let r = step as f64 * 50.0;
            let rates = game.induced_rates(r);
            if GuidedSelfTuning.schedule(&c, &rates).is_ok() {
                max_st = r;
            }
            if ElasticPartitioning::gpulet().schedule(&c, &rates).is_ok() {
                max_el = r;
            }
        }
        assert!(max_el >= max_st, "elastic {max_el} < selftune {max_st}");
    }

    #[test]
    fn rejects_overload() {
        let c = ctx(1);
        assert!(GuidedSelfTuning.schedule(&c, &[0.0, 0.0, 0.0, 0.0, 1e7]).is_err());
    }
}
