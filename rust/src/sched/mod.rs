//! SLO-aware schedulers.
//!
//! * `elastic` — the paper's contribution: Elastic Partitioning
//!   (Algorithm 1) in `gpulet` (interference-oblivious) and
//!   `gpulet+int` (interference-aware) variants.
//! * `sbp` — the Nexus squishy bin-packing baseline (temporal sharing
//!   only), with an optional fixed 50:50 partitioning mode (Fig 4).
//! * `selftune` — GSLICE-style guided self-tuning (spatial only, no
//!   temporal-sharing merge), guided by profiled optima (§6.1).
//! * `ideal` — exhaustive search over per-GPU partition combinations
//!   (Fig 15 / Fig 16 comparator).
//!
//! All schedulers consume the same `SchedCtx` (profiled latency +
//! optional fitted interference model) and produce a `Schedule` that
//! the simulator can execute and `Schedule::validate` can check.

pub mod elastic;
pub mod ideal;
pub mod sbp;
pub mod selftune;
pub mod types;

pub use elastic::ElasticPartitioning;
pub use ideal::IdealScheduler;
pub use sbp::SquishyBinPacking;
pub use selftune::GuidedSelfTuning;
pub use types::{Assignment, LetPlan, SchedCtx, Schedule, Scheduler};
