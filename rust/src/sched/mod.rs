//! SLO-aware schedulers.
//!
//! * `elastic` — the paper's contribution: Elastic Partitioning
//!   (Algorithm 1) in `gpulet` (interference-oblivious) and
//!   `gpulet+int` (interference-aware) variants.
//! * `sbp` — the Nexus squishy bin-packing baseline (temporal sharing
//!   only), with an optional fixed 50:50 partitioning mode (Fig 4).
//! * `selftune` — GSLICE-style guided self-tuning (spatial only, no
//!   temporal-sharing merge), guided by profiled optima (§6.1).
//! * `ideal` — exhaustive search over per-GPU partition combinations
//!   (Fig 15 / Fig 16 comparator).
//! * `spacetime` — Elastic Partitioning extended with a temporal
//!   packing fallback: gpu-lets may time-slice two models in one duty
//!   cycle when spatial splitting alone rejects the load (DESIGN.md
//!   §10).
//!
//! All schedulers consume the same `SchedCtx` (profiled latency +
//! optional fitted interference model) and produce a `Schedule` that
//! the simulator can execute and `Schedule::validate` can check.

pub mod elastic;
pub mod ideal;
pub mod sbp;
pub mod selftune;
pub mod spacetime;
pub mod types;

pub use elastic::ElasticPartitioning;
pub use ideal::IdealScheduler;
pub use sbp::SquishyBinPacking;
pub use selftune::GuidedSelfTuning;
pub use spacetime::SpaceTimeScheduler;
pub use types::{Assignment, LetPlan, SchedCtx, Schedule, Scheduler};

/// One instance of every registered scheduler — the single list the
/// conformance battery (`tests/scheduler_conformance.rs`), the CLI's
/// `--algo` vocabulary, and the sweep harness enumerate. Adding a
/// scheduler here auto-enrolls it in the whole invariant battery; the
/// battery's round-trip test then forces the matching `config::Algo`
/// variant to exist.
pub fn registry() -> Vec<Box<dyn Scheduler>> {
    vec![
        Box::new(SquishyBinPacking::baseline()),
        Box::new(SquishyBinPacking::with_even_partitioning()),
        Box::new(GuidedSelfTuning),
        Box::new(ElasticPartitioning::gpulet()),
        Box::new(ElasticPartitioning::gpulet_int()),
        Box::new(IdealScheduler),
        Box::new(SpaceTimeScheduler::combined()),
    ]
}
