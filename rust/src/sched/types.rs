//! Shared scheduling types: schedules, assignments, feasibility math.
//!
//! Duty-cycle model (§2.2, Fig 1): a gpu-let serving models S runs a
//! repeating round. Model i contributes execution time
//! `E_i = L(b_i, p) * (1 + intf_i)`; the duty cycle is `D = Σ E_i`.
//! Feasibility of `(m_i, b_i, rate_i)` on the gpu-let:
//!
//! * throughput:  `rate_i * D <= b_i * 1000`  (arrivals per round fit the batch; D in ms)
//! * latency:     `2 D <= SLO_i`  (worst case: miss the batch close, wait
//!   a full round, then complete within the next round)
//!
//! For a solo model with `D = L(b, p)` this degenerates to the classic
//! `2 L <= SLO` rule used by `LatencyModel::max_rate`.

use crate::error::{Error, Result};
use crate::gpu::cluster::ClusterLayout;
use crate::gpu::gpulet::{is_valid_size, GpuLetSpec};
use crate::interference::InterferenceModel;
use crate::models::ModelId;
use crate::perfmodel::{CapacityTable, LatencyModel, ProfileTable};

/// Planning SLO tightening: schedulers see `SLO * SLO_PLANNING_SCALE`
/// so deployed schedules keep latency headroom for Poisson burstiness
/// and residual (mis-predicted) interference.
pub const SLO_PLANNING_SCALE: f64 = 0.88;

/// Utilization headroom: schedulers route at most this fraction of a
/// placement's theoretical capacity (queueing at utilization 1.0 is
/// unstable under stochastic arrivals).
pub const CAPACITY_FRACTION: f64 = 0.90;

/// One model's share of a gpu-let.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Assignment {
    pub model: ModelId,
    /// Batch size the batcher builds for this model on this gpu-let.
    pub batch: u32,
    /// Request rate (req/s) routed here.
    pub rate: f64,
}

/// A gpu-let with its assigned models (len > 1 = temporal sharing).
#[derive(Clone, Debug, PartialEq)]
pub struct LetPlan {
    pub spec: GpuLetSpec,
    pub assignments: Vec<Assignment>,
}

impl LetPlan {
    /// Duty cycle (ms) under a uniform interference stretch `intf`.
    pub fn duty_cycle_ms(&self, lm: &LatencyModel, intf: f64) -> f64 {
        let p = self.spec.fraction();
        self.assignments
            .iter()
            .map(|a| lm.latency_ms(a.model, a.batch, p) * (1.0 + intf))
            .sum()
    }

    /// Check throughput + latency feasibility of every assignment under
    /// interference stretch `intf`.
    pub fn feasible(&self, lm: &LatencyModel, intf: f64) -> bool {
        let d = self.duty_cycle_ms(lm, intf);
        self.assignments.iter().all(|a| {
            a.rate * d <= a.batch as f64 * 1000.0 + 1e-6
                && 2.0 * d <= lm.slo_ms(a.model) + 1e-9
        })
    }

    /// Duty-sum utilization under interference stretch `intf`:
    /// `Σ rate_i · E_i / (b_i · 1000)` with `E_i` the interference-
    /// inflated execution time — the fraction of wall-clock time the
    /// let must spend executing to keep up with its assigned rates.
    /// Any feasible plan has utilization ≤ 1.0 (each assignment's
    /// throughput constraint `rate_i · D ≤ b_i · 1000` bounds its term
    /// by `E_i / D`, and the terms sum to `D / D = 1`), so `> 1.0` is
    /// always a planner bug; `Schedule::validate` enforces the bound
    /// explicitly for temporally-shared lets.
    pub fn utilization(&self, lm: &LatencyModel, intf: f64) -> f64 {
        let p = self.spec.fraction();
        self.assignments
            .iter()
            .map(|a| {
                let e = lm.latency_ms(a.model, a.batch, p) * (1.0 + intf);
                a.rate * e / (a.batch as f64 * 1000.0)
            })
            .sum()
    }

    /// Max additional rate of `model` (batch `b`) this plan could accept
    /// while staying feasible — used by temporal-sharing merges.
    pub fn headroom_rate(&self, lm: &LatencyModel, model: ModelId, b: u32, intf: f64) -> f64 {
        let mut probe = self.clone();
        probe.assignments.push(Assignment { model, batch: b, rate: 0.0 });
        let d = probe.duty_cycle_ms(lm, intf);
        // Existing assignments must stay feasible at the larger cycle.
        let ok = probe.assignments[..probe.assignments.len() - 1]
            .iter()
            .all(|a| {
                a.rate * d <= a.batch as f64 * 1000.0 + 1e-6
                    && 2.0 * d <= lm.slo_ms(a.model) + 1e-9
            })
            && 2.0 * d <= lm.slo_ms(model) + 1e-9;
        if !ok {
            return 0.0;
        }
        b as f64 * 1000.0 / d * CAPACITY_FRACTION
    }
}

/// Shrink a plan's batches until it is feasible under interference
/// stretch `intf` while still sustaining its assigned rates — the
/// "squishy" property of squishy bin packing: batch sizes are the
/// elastic dimension. Returns the squished plan, or `None`.
pub fn squish_plan(
    lm: &LatencyModel,
    plan: &LetPlan,
    intf: f64,
) -> Option<LetPlan> {
    let mut cur = plan.clone();
    for _ in 0..64 {
        if cur.feasible(lm, intf) {
            return Some(cur);
        }
        // Shrink the assignment with the longest execution that can
        // still shrink; smaller batches shorten the duty cycle.
        let p = cur.spec.fraction();
        let mut pick: Option<(usize, f64, u32)> = None; // (idx, exec, next_batch)
        for (i, a) in cur.assignments.iter().enumerate() {
            let Some(&next) =
                crate::perfmodel::BATCHES.iter().rev().find(|&&b| b < a.batch)
            else {
                continue;
            };
            let exec = lm.latency_ms(a.model, a.batch, p);
            if pick.is_none_or(|(_, e, _)| exec > e) {
                pick = Some((i, exec, next));
            }
        }
        let (i, _, next) = pick?;
        cur.assignments[i].batch = next;
    }
    None
}

/// A complete scheduling decision for the cluster.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Schedule {
    pub lets: Vec<LetPlan>,
}

impl Schedule {
    /// Derived physical layout: allocated gpu-let sizes per GPU.
    /// GPUs with no allocation get a single whole gpu-let (idle).
    pub fn layout(&self, num_gpus: usize) -> Result<ClusterLayout> {
        let mut sizes: Vec<Vec<u32>> = vec![vec![]; num_gpus];
        for lp in &self.lets {
            if lp.spec.gpu >= num_gpus {
                return Err(Error::GpuLet(format!(
                    "gpu index {} out of range ({num_gpus} gpus)",
                    lp.spec.gpu
                )));
            }
            sizes[lp.spec.gpu].push(lp.spec.size_pct);
        }
        for s in sizes.iter_mut() {
            if s.is_empty() {
                s.push(100);
            }
            s.sort_unstable();
        }
        ClusterLayout::from_sizes(sizes)
    }

    /// Total rate assigned per model, indexed by `ModelId::index`.
    pub fn assigned_rates(&self) -> [f64; 5] {
        let mut out = [0.0; 5];
        for lp in &self.lets {
            for a in &lp.assignments {
                out[a.model.index()] += a.rate;
            }
        }
        out
    }

    /// Sum of allocated gpu-let sizes (percent) — Fig 14's middle series.
    pub fn total_allocated_pct(&self) -> u32 {
        self.lets.iter().map(|l| l.spec.size_pct).sum()
    }

    /// Structural + feasibility validation (interference stretch 0 —
    /// schedulers that model interference check stronger bounds
    /// themselves):
    /// 1. every gpu-let size valid; per-GPU count/size caps hold;
    /// 2. every assignment has positive rate and batch within limits;
    /// 3. every let's duty-sum utilization is ≤ 1.0 (the space-time
    ///    invariant: time slices of all co-tenants fit one wall-clock);
    /// 4. every let's duty cycle is feasible.
    ///
    /// # Examples
    ///
    /// ```
    /// use gpulets::gpu::gpulet::GpuLetSpec;
    /// use gpulets::models::ModelId;
    /// use gpulets::perfmodel::LatencyModel;
    /// use gpulets::sched::{Assignment, LetPlan, Schedule};
    ///
    /// let lm = LatencyModel::new();
    /// let schedule = Schedule {
    ///     lets: vec![LetPlan {
    ///         spec: GpuLetSpec { gpu: 0, size_pct: 50 },
    ///         assignments: vec![Assignment {
    ///             model: ModelId::Lenet,
    ///             batch: 8,
    ///             rate: 100.0,
    ///         }],
    ///     }],
    /// };
    /// schedule.validate(&lm, 1).unwrap();
    ///
    /// // Oversubscribing the GPU (50% + 80% > 100%) is rejected.
    /// let mut bad = schedule.clone();
    /// bad.lets.push(LetPlan {
    ///     spec: GpuLetSpec { gpu: 0, size_pct: 80 },
    ///     assignments: vec![Assignment {
    ///         model: ModelId::Vgg,
    ///         batch: 8,
    ///         rate: 10.0,
    ///     }],
    /// });
    /// assert!(bad.validate(&lm, 1).is_err());
    /// ```
    pub fn validate(&self, lm: &LatencyModel, num_gpus: usize) -> Result<()> {
        self.layout(num_gpus)?; // (1) via ClusterLayout::validate
        for lp in &self.lets {
            if !is_valid_size(lp.spec.size_pct) {
                return Err(Error::GpuLet(format!("invalid size {}", lp.spec.size_pct)));
            }
            if lp.assignments.is_empty() {
                return Err(Error::GpuLet("allocated gpu-let with no assignments".into()));
            }
            for a in &lp.assignments {
                if a.rate <= 0.0 {
                    return Err(Error::GpuLet(format!("{}: non-positive rate", a.model)));
                }
                if a.batch == 0 || a.batch > crate::perfmodel::MAX_BATCH {
                    return Err(Error::GpuLet(format!("{}: bad batch {}", a.model, a.batch)));
                }
            }
            let util = lp.utilization(lm, 0.0);
            if util > 1.0 + 1e-6 {
                return Err(Error::NotSchedulable(format!(
                    "gpu{} let {}%: duty-sum utilization {util:.4} > 1.0",
                    lp.spec.gpu, lp.spec.size_pct
                )));
            }
            if !lp.feasible(lm, 0.0) {
                return Err(Error::NotSchedulable(format!(
                    "gpu{} let {}%: duty-cycle infeasible",
                    lp.spec.gpu, lp.spec.size_pct
                )));
            }
        }
        // A GPU must not host two lets from the same plan twice... (count
        // and sums already enforced by layout()). Nothing more here.
        Ok(())
    }

    /// Worst-case predicted interference stretch for a let, given its
    /// co-resident let on the same GPU (None if alone).
    pub fn co_resident_of(&self, idx: usize) -> Option<&LetPlan> {
        let me = &self.lets[idx];
        self.lets
            .iter()
            .enumerate()
            .find(|(i, lp)| *i != idx && lp.spec.gpu == me.spec.gpu)
            .map(|(_, lp)| lp)
    }
}

/// Shared scheduler inputs: profiled performance + fitted interference.
pub struct SchedCtx {
    pub lm: LatencyModel,
    pub table: ProfileTable,
    /// Memoized `(max_rate, best_batch)` per (model, partition) — the
    /// O(1) lookups the scheduler hot paths use instead of rescanning
    /// `BATCHES` (DESIGN.md §6).
    pub cap: CapacityTable,
    /// Fitted linear interference model; `None` disables interference
    /// awareness (the `gpulet` variant).
    pub intf: Option<InterferenceModel>,
    pub num_gpus: usize,
}

impl SchedCtx {
    pub fn new(num_gpus: usize, intf: Option<InterferenceModel>) -> Self {
        // Planning view: tightened SLOs (see SLO_PLANNING_SCALE).
        let lm = LatencyModel::with_slo_scale(SLO_PLANNING_SCALE);
        let table = ProfileTable::build(&lm);
        let cap = CapacityTable::build(&lm);
        SchedCtx { lm, table, cap, intf, num_gpus }
    }

    /// Context without planning margins (used by conformance tests that
    /// reason about exact feasibility boundaries).
    pub fn unmargined(num_gpus: usize, intf: Option<InterferenceModel>) -> Self {
        let lm = LatencyModel::new();
        let table = ProfileTable::build(&lm);
        let cap = CapacityTable::build(&lm);
        SchedCtx { lm, table, cap, intf, num_gpus }
    }

    /// Memoized `LatencyModel::max_rate` for a grid-size gpu-let;
    /// off-grid sizes fall back to the latency model (identical math).
    #[inline]
    pub fn max_rate(&self, m: ModelId, size_pct: u32) -> Option<(f64, u32)> {
        match self.cap.lookup_rate(m, size_pct) {
            Some(memo) => memo,
            None => self.lm.max_rate(m, size_pct as f64 / 100.0),
        }
    }

    /// Memoized `max_batch_within(m, p, slo/2)` — the Algorithm-1
    /// line 27 batch pick for a solo duty cycle on a grid-size gpu-let.
    #[inline]
    pub fn best_batch_half_slo(&self, m: ModelId, size_pct: u32) -> Option<u32> {
        match self.cap.lookup_half_slo_batch(m, size_pct) {
            Some(memo) => memo,
            None => self.lm.max_batch_within(
                m,
                size_pct as f64 / 100.0,
                self.lm.slo_ms(m) / 2.0,
            ),
        }
    }

    /// `MaxEfficientPartition` (knee of the affordable-rate curve),
    /// precomputed per model at context build.
    #[inline]
    pub fn knee_pct(&self, m: ModelId) -> u32 {
        self.cap.knee_pct(m)
    }

    /// Predicted worst-case interference stretch between the models of
    /// two co-resident let plans (0 when no estimator configured).
    pub fn predicted_intf(&self, a: &LetPlan, b: &LetPlan) -> f64 {
        let Some(model) = &self.intf else { return 0.0 };
        let pa = a.spec.fraction();
        let pb = b.spec.fraction();
        let mut worst: f64 = 0.0;
        for x in &a.assignments {
            for y in &b.assignments {
                worst = worst.max(model.predict_pair(
                    x.model, x.batch, pa, y.model, y.batch, pb,
                ));
            }
        }
        worst
    }
}

/// Input guard every scheduler applies at its `schedule` boundary:
/// request rates must be finite and non-negative. A NaN rate would
/// otherwise panic deep inside the rate-descending sort
/// (`partial_cmp().unwrap()`), and an infinite one can never be served;
/// both are caller bugs reported as a proper `Error` instead.
pub fn validate_rates(rates: &[f64; 5]) -> Result<()> {
    for m in ModelId::ALL {
        let r = rates[m.index()];
        if !r.is_finite() || r < 0.0 {
            return Err(Error::Model(format!("{m}: invalid request rate {r}")));
        }
    }
    Ok(())
}

/// Common scheduler interface. `rates` is the offered per-model load
/// (req/s, indexed by `ModelId::index`; must pass [`validate_rates`]);
/// `Err(NotSchedulable)` when the cluster cannot serve it within SLOs.
///
/// `Sync` is a supertrait so `&dyn Scheduler` can be shared across the
/// experiment harness's worker threads (`util::par`); every scheduler
/// is a plain-data struct, so the bound is automatic.
pub trait Scheduler: Sync {
    fn name(&self) -> &'static str;
    /// Whether this scheduler consumes `SchedCtx::intf` (the fitted
    /// linear interference model). Drives automatic context selection
    /// in the conformance battery and the CLI: interference-aware
    /// schedulers get a ctx carrying the fitted model, the rest a plain
    /// one.
    fn interference_aware(&self) -> bool {
        false
    }
    fn schedule(&self, ctx: &SchedCtx, rates: &[f64; 5]) -> Result<Schedule>;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lm() -> LatencyModel {
        LatencyModel::new()
    }

    fn solo_plan(m: ModelId, size: u32, b: u32, rate: f64) -> LetPlan {
        LetPlan {
            spec: GpuLetSpec { gpu: 0, size_pct: size },
            assignments: vec![Assignment { model: m, batch: b, rate }],
        }
    }

    #[test]
    fn solo_feasibility_matches_max_rate() {
        let lm = lm();
        let (r, b) = lm.max_rate(ModelId::Vgg, 1.0).unwrap();
        let plan = solo_plan(ModelId::Vgg, 100, b, r * 0.999);
        assert!(plan.feasible(&lm, 0.0));
        let plan_over = solo_plan(ModelId::Vgg, 100, b, r * 1.05);
        assert!(!plan_over.feasible(&lm, 0.0));
    }

    #[test]
    fn interference_stretch_can_break_feasibility() {
        let lm = lm();
        let (r, b) = lm.max_rate(ModelId::Vgg, 0.5).unwrap();
        let plan = solo_plan(ModelId::Vgg, 50, b, r * 0.999);
        assert!(plan.feasible(&lm, 0.0));
        assert!(!plan.feasible(&lm, 0.5), "50% stretch must break a tight plan");
    }

    #[test]
    fn temporal_sharing_duty_cycle_sums() {
        let lm = lm();
        let plan = LetPlan {
            spec: GpuLetSpec { gpu: 0, size_pct: 100 },
            assignments: vec![
                Assignment { model: ModelId::Lenet, batch: 8, rate: 100.0 },
                Assignment { model: ModelId::Googlenet, batch: 8, rate: 50.0 },
            ],
        };
        let d = plan.duty_cycle_ms(&lm, 0.0);
        let want = lm.latency_ms(ModelId::Lenet, 8, 1.0)
            + lm.latency_ms(ModelId::Googlenet, 8, 1.0);
        assert!((d - want).abs() < 1e-12);
        // LeNet's 5 ms SLO cannot absorb GoogLeNet's duty cycle.
        assert!(!plan.feasible(&lm, 0.0));
    }

    #[test]
    fn validate_enforces_duty_sum_utilization_bound() {
        let lm = lm();
        let e = lm.latency_ms(ModelId::Lenet, 1, 1.0);
        // rate · E / (b · 1000) = 2.0 → needs twice the wall-clock.
        let plan = solo_plan(ModelId::Lenet, 100, 1, 2.0 * 1000.0 / e);
        assert!((plan.utilization(&lm, 0.0) - 2.0).abs() < 1e-9);
        let err = Schedule { lets: vec![plan] }.validate(&lm, 1).unwrap_err();
        assert!(err.to_string().contains("duty-sum utilization"), "{err}");
        // A feasible plan always sits at utilization ≤ 1.0.
        let (r, b) = lm.max_rate(ModelId::Vgg, 1.0).unwrap();
        let ok = solo_plan(ModelId::Vgg, 100, b, r * 0.999);
        assert!(ok.feasible(&lm, 0.0));
        assert!(ok.utilization(&lm, 0.0) <= 1.0 + 1e-9);
    }

    #[test]
    fn headroom_rate_zero_when_slo_tight() {
        let lm = lm();
        let plan = solo_plan(ModelId::Vgg, 100, 32, 100.0);
        // Adding LeNet (SLO 5ms) to a VGG cycle (65ms) is impossible.
        assert_eq!(plan.headroom_rate(&lm, ModelId::Lenet, 1, 0.0), 0.0);
        // Adding GoogLeNet may or may not fit; must be >= 0 and finite.
        let h = plan.headroom_rate(&lm, ModelId::Googlenet, 8, 0.0);
        assert!(h.is_finite() && h >= 0.0);
    }

    #[test]
    fn schedule_layout_and_validation() {
        let lm = lm();
        let (r, b) = lm.max_rate(ModelId::Resnet, 0.6).unwrap();
        let sched = Schedule {
            lets: vec![
                solo_plan(ModelId::Resnet, 60, b, r * 0.9),
                LetPlan {
                    spec: GpuLetSpec { gpu: 0, size_pct: 40 },
                    assignments: vec![Assignment {
                        model: ModelId::Lenet,
                        batch: lm.max_rate(ModelId::Lenet, 0.4).unwrap().1,
                        rate: 50.0,
                    }],
                },
            ],
        };
        sched.validate(&lm, 2).unwrap();
        let layout = sched.layout(2).unwrap();
        assert_eq!(layout.lets_on(0), &[40, 60]);
        assert_eq!(layout.lets_on(1), &[100]); // idle whole GPU
        assert_eq!(sched.total_allocated_pct(), 100);
        let rates = sched.assigned_rates();
        assert!(rates[ModelId::Resnet.index()] > 0.0);
    }

    #[test]
    fn validation_rejects_oversubscription() {
        let lm = lm();
        let sched = Schedule {
            lets: vec![
                solo_plan(ModelId::Lenet, 80, 1, 10.0),
                LetPlan {
                    spec: GpuLetSpec { gpu: 0, size_pct: 40 },
                    assignments: vec![Assignment { model: ModelId::Vgg, batch: 1, rate: 1.0 }],
                },
            ],
        };
        assert!(sched.validate(&lm, 1).is_err()); // 80+40 > 100
    }

    #[test]
    fn validation_rejects_empty_and_zero_rate() {
        let lm = lm();
        let empty = Schedule {
            lets: vec![LetPlan {
                spec: GpuLetSpec { gpu: 0, size_pct: 100 },
                assignments: vec![],
            }],
        };
        assert!(empty.validate(&lm, 1).is_err());
        let zero = Schedule { lets: vec![solo_plan(ModelId::Lenet, 100, 1, 0.0)] };
        assert!(zero.validate(&lm, 1).is_err());
    }

    #[test]
    fn co_resident_lookup() {
        let lm = lm();
        let _ = lm;
        let sched = Schedule {
            lets: vec![
                solo_plan(ModelId::Lenet, 20, 1, 1.0),
                LetPlan {
                    spec: GpuLetSpec { gpu: 0, size_pct: 80 },
                    assignments: vec![Assignment { model: ModelId::Vgg, batch: 8, rate: 10.0 }],
                },
                LetPlan {
                    spec: GpuLetSpec { gpu: 1, size_pct: 100 },
                    assignments: vec![Assignment { model: ModelId::Resnet, batch: 8, rate: 10.0 }],
                },
            ],
        };
        assert_eq!(sched.co_resident_of(0).unwrap().spec.size_pct, 80);
        assert!(sched.co_resident_of(2).is_none());
    }

    #[test]
    fn predicted_intf_zero_without_model() {
        let ctx = SchedCtx::new(4, None);
        let a = solo_plan(ModelId::Vgg, 50, 32, 10.0);
        let b = solo_plan(ModelId::Vgg, 50, 32, 10.0);
        assert_eq!(ctx.predicted_intf(&a, &b), 0.0);
    }

    #[test]
    fn validate_rates_rejects_non_finite_and_negative() {
        assert!(validate_rates(&[0.0; 5]).is_ok());
        assert!(validate_rates(&[1e9; 5]).is_ok());
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -1.0] {
            let mut rates = [10.0; 5];
            rates[2] = bad;
            let err = validate_rates(&rates).unwrap_err();
            assert!(err.to_string().contains("invalid request rate"), "{err}");
        }
    }

    #[test]
    fn ctx_memoized_lookups_match_latency_model() {
        let ctx = SchedCtx::new(1, None);
        for m in ModelId::ALL {
            // On-grid sizes hit the memo; off-grid (30%) falls back.
            for pct in [20u32, 50, 100, 30] {
                assert_eq!(
                    ctx.max_rate(m, pct),
                    ctx.lm.max_rate(m, pct as f64 / 100.0),
                    "{m} p={pct}"
                );
                assert_eq!(
                    ctx.best_batch_half_slo(m, pct),
                    ctx.lm.max_batch_within(
                        m,
                        pct as f64 / 100.0,
                        ctx.lm.slo_ms(m) / 2.0
                    ),
                    "{m} p={pct}"
                );
            }
        }
    }
}
