//! Squishy Bin Packing — the Nexus [32] baseline ported to this stack.
//!
//! Temporal sharing only: every execution owns its (whole or fixed-split)
//! GPU for the duration of a batch. The algorithm:
//! 1. For each model (descending rate), saturate dedicated bins with the
//!    throughput-optimal batch while a full bin's capacity is exceeded.
//! 2. First-fit the residual loads into partially-occupied bins,
//!    squishing batch sizes so the combined duty cycle holds every
//!    co-located model's SLO.
//!
//! `even_partitioning = true` is the Fig 4 "SBP + GPU partitioning"
//! variant: every GPU is pre-split into two independent 50% gpu-lets
//! that SBP then treats as bins.

use crate::error::{Error, Result};
use crate::gpu::gpulet::GpuLetSpec;
use crate::models::ModelId;
use crate::perfmodel::BATCHES;
use crate::sched::types::{Assignment, LetPlan, SchedCtx, Schedule, Scheduler};

const EPS_RATE: f64 = 1e-6;

/// Nexus-style squishy bin packing.
#[derive(Clone, Copy, Debug)]
pub struct SquishyBinPacking {
    /// Pre-split every GPU into two 50% bins (Fig 4 right bar).
    pub even_partitioning: bool,
}

impl SquishyBinPacking {
    pub fn baseline() -> Self {
        SquishyBinPacking { even_partitioning: false }
    }

    pub fn with_even_partitioning() -> Self {
        SquishyBinPacking { even_partitioning: true }
    }

    fn bins(&self, num_gpus: usize) -> Vec<GpuLetSpec> {
        if self.even_partitioning {
            (0..num_gpus)
                .flat_map(|gpu| {
                    [
                        GpuLetSpec { gpu, size_pct: 50 },
                        GpuLetSpec { gpu, size_pct: 50 },
                    ]
                })
                .collect()
        } else {
            (0..num_gpus).map(|gpu| GpuLetSpec { gpu, size_pct: 100 }).collect()
        }
    }

    /// Throughput-optimal (rate, batch) for a solo model on a bin,
    /// derated by the shared utilization headroom (memoized lookup).
    fn solo_capacity(&self, ctx: &SchedCtx, m: ModelId, size_pct: u32) -> Option<(f64, u32)> {
        ctx.max_rate(m, size_pct)
            .map(|(r, b)| (r * crate::sched::types::CAPACITY_FRACTION, b))
    }

    /// Try to add (m, rate) to an existing bin via *squishy* temporal
    /// sharing: probe every batch size for the incoming model and let
    /// the bin's existing batches shrink (squish) to make the combined
    /// duty cycle feasible — as long as every resident still sustains
    /// its assigned rate. Keeps the variant with the largest absorbed
    /// rate.
    fn try_fit(&self, ctx: &SchedCtx, plan: &mut LetPlan, m: ModelId, want: f64) -> f64 {
        let mut best: Option<(LetPlan, f64)> = None;
        for &b in &BATCHES {
            let mut cand = plan.clone();
            cand.assignments.push(Assignment { model: m, batch: b, rate: 0.0 });
            let Some(squished) = crate::sched::types::squish_plan(&ctx.lm, &cand, 0.0)
            else {
                continue;
            };
            // Capacity for the incoming model within the squished cycle
            // (squish preserves the assignment just pushed, so `last`
            // is the incoming model; fall back to the probed batch).
            let d = squished.duty_cycle_ms(&ctx.lm, 0.0);
            let b_new = squished.assignments.last().map_or(b, |a| a.batch);
            let cap = b_new as f64 * 1000.0 / d * crate::sched::types::CAPACITY_FRACTION;
            let take = want.min(cap);
            if take > EPS_RATE && best.as_ref().is_none_or(|(_, t)| take > *t) {
                let mut committed = squished;
                if let Some(last) = committed.assignments.last_mut() {
                    last.rate = take;
                }
                // Re-verify with the real rate in place.
                if committed.feasible(&ctx.lm, 0.0) {
                    best = Some((committed, take));
                }
            }
        }
        if let Some((committed, take)) = best {
            *plan = committed;
            take
        } else {
            0.0
        }
    }
}

impl Scheduler for SquishyBinPacking {
    fn name(&self) -> &'static str {
        if self.even_partitioning {
            "sbp+part"
        } else {
            "sbp"
        }
    }

    fn schedule(&self, ctx: &SchedCtx, rates: &[f64; 5]) -> Result<Schedule> {
        crate::sched::types::validate_rates(rates)?;
        let mut free = self.bins(ctx.num_gpus);
        let mut alloc: Vec<LetPlan> = Vec::new();

        let mut models: Vec<(ModelId, f64)> = ModelId::ALL
            .iter()
            .map(|&m| (m, rates[m.index()]))
            .filter(|&(_, r)| r > 0.0)
            .collect();
        models.sort_by(|a, b| b.1.total_cmp(&a.1));

        for (m, rate) in models {
            let mut remaining = rate;

            // Phase 1: dedicate full bins while the load saturates them.
            while remaining > EPS_RATE {
                let Some(&bin) = free.first() else { break };
                let Some((cap, b)) = self.solo_capacity(ctx, m, bin.size_pct) else { break };
                if remaining < cap {
                    break; // residual load: phase 2
                }
                free.remove(0);
                alloc.push(LetPlan {
                    spec: bin,
                    assignments: vec![Assignment { model: m, batch: b, rate: cap }],
                });
                remaining -= cap;
            }

            // Phase 2: squish the residual into existing bins first-fit,
            // then open a fresh bin if needed.
            while remaining > EPS_RATE {
                let mut placed = 0.0;
                for plan in alloc.iter_mut() {
                    placed = self.try_fit(ctx, plan, m, remaining);
                    if placed > EPS_RATE {
                        break;
                    }
                }
                if placed <= EPS_RATE {
                    // Open a new bin for the residual.
                    let Some(&bin) = free.first() else {
                        return Err(Error::NotSchedulable(format!(
                            "sbp: {m} has {remaining:.1} req/s and no free GPU"
                        )));
                    };
                    let Some((cap, b)) = self.solo_capacity(ctx, m, bin.size_pct) else {
                        return Err(Error::NotSchedulable(format!(
                            "sbp: {m} cannot meet SLO even on a dedicated bin"
                        )));
                    };
                    free.remove(0);
                    let take = remaining.min(cap);
                    alloc.push(LetPlan {
                        spec: bin,
                        assignments: vec![Assignment { model: m, batch: b, rate: take }],
                    });
                    placed = take;
                }
                remaining -= placed;
            }
        }

        let sched = Schedule { lets: alloc };
        sched.validate(&ctx.lm, ctx.num_gpus)?;
        Ok(sched)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(gpus: usize) -> SchedCtx {
        SchedCtx::new(gpus, None)
    }

    #[test]
    fn light_load_fits_one_bin() {
        let c = ctx(4);
        let s = SquishyBinPacking::baseline()
            .schedule(&c, &[10.0, 10.0, 0.0, 0.0, 0.0])
            .unwrap();
        s.validate(&c.lm, 4).unwrap();
        // Temporal sharing should consolidate both onto few whole GPUs.
        assert!(s.lets.len() <= 2);
        assert!(s.lets.iter().all(|l| l.spec.size_pct == 100));
    }

    #[test]
    fn saturating_load_dedicates_bins() {
        let c = ctx(4);
        let (cap, _) = c.lm.max_rate(ModelId::Vgg, 1.0).unwrap();
        let s = SquishyBinPacking::baseline()
            .schedule(&c, &[0.0, 0.0, 0.0, 0.0, cap * 2.5])
            .unwrap();
        let vgg_bins = s.lets.len();
        assert!(vgg_bins >= 3, "need >= 3 bins, got {vgg_bins}");
    }

    #[test]
    fn rejects_overload() {
        let c = ctx(2);
        let err = SquishyBinPacking::baseline()
            .schedule(&c, &[0.0, 0.0, 1e7, 0.0, 1e7])
            .unwrap_err();
        assert!(matches!(err, Error::NotSchedulable(_)));
    }

    #[test]
    fn even_partitioning_uses_half_bins() {
        let c = ctx(2);
        let s = SquishyBinPacking::with_even_partitioning()
            .schedule(&c, &[50.0, 0.0, 0.0, 0.0, 0.0])
            .unwrap();
        assert!(s.lets.iter().all(|l| l.spec.size_pct == 50));
        s.validate(&c.lm, 2).unwrap();
    }

    #[test]
    fn partitioned_sbp_schedules_more_lenet_scenarios() {
        // Fig 4's point: with fixed 50:50 splits, small-model loads that
        // waste whole GPUs become schedulable (more bins).
        let c = ctx(1);
        let base = SquishyBinPacking::baseline();
        let part = SquishyBinPacking::with_even_partitioning();
        // LeNet's knee is ~20-30%: a 50% bin sustains nearly the same
        // rate as a 100% bin, so two 50% bins beat one 100% bin.
        let (r100, _) = c.lm.max_rate(ModelId::Lenet, 1.0).unwrap();
        let probe = [r100 * 1.4, 0.0, 0.0, 0.0, 0.0];
        assert!(base.schedule(&c, &probe).is_err());
        assert!(part.schedule(&c, &probe).is_ok());
    }

    #[test]
    fn zero_load_empty_schedule() {
        let c = ctx(4);
        let s = SquishyBinPacking::baseline().schedule(&c, &[0.0; 5]).unwrap();
        assert!(s.lets.is_empty());
    }
}
