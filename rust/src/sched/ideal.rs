//! Ideal (exhaustive) scheduler — the Fig 15 / Fig 16 comparator.
//!
//! Enumerates every per-GPU partition combination from the four cases
//! the paper uses ({100}, {50,50}, {40,60}, {20,80}) — `4^N` layouts
//! for `N` GPUs — and, for each, greedily packs the offered rates onto
//! the fixed gpu-lets (temporal sharing allowed). The first layout that
//! serves everything within SLOs proves schedulability; the search is
//! exhaustive, so a `NotSchedulable` verdict is authoritative for this
//! partition vocabulary and packer.

use crate::error::{Error, Result};
use crate::gpu::gpulet::GpuLetSpec;
use crate::models::ModelId;
use crate::perfmodel::BATCHES;
use crate::sched::types::{Assignment, LetPlan, SchedCtx, Schedule, Scheduler};

const EPS_RATE: f64 = 1e-6;

/// Per-GPU partition cases (§6.2: "4 GPUs which can be partitioned into
/// 4 cases" → 4^4 layouts).
pub const GPU_CASES: [&[u32]; 4] = [&[100], &[50, 50], &[40, 60], &[20, 80]];

/// Exhaustive-search scheduler.
#[derive(Clone, Copy, Debug, Default)]
pub struct IdealScheduler;

impl IdealScheduler {
    /// Greedy packer over a fixed gpu-let set. Returns a schedule iff
    /// every model's full rate fits.
    fn try_assign(ctx: &SchedCtx, lets: &[GpuLetSpec], rates: &[f64; 5]) -> Option<Schedule> {
        let mut free: Vec<GpuLetSpec> = lets.to_vec();
        // Largest first: heavy models claim big lets.
        free.sort_by(|a, b| b.size_pct.cmp(&a.size_pct).then(a.gpu.cmp(&b.gpu)));
        let mut alloc: Vec<LetPlan> = Vec::new();

        let mut models: Vec<(ModelId, f64)> = ModelId::ALL
            .iter()
            .map(|&m| (m, rates[m.index()]))
            .filter(|&(_, r)| r > 0.0)
            .collect();
        models.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());

        for (m, rate) in models {
            let mut remaining = rate;
            while remaining > EPS_RATE {
                // Prefer the smallest free let that covers the remainder
                // (best fit), else the highest-capacity free let.
                let mut chosen: Option<(usize, f64, u32)> = None; // (idx, cap, batch)
                let mut best_cover: Option<(usize, f64, u32)> = None;
                for (i, spec) in free.iter().enumerate() {
                    let p = spec.fraction();
                    let Some((cap, b)) = ctx
                        .lm
                        .max_rate(m, p)
                        .map(|(r, b)| (r * crate::sched::types::CAPACITY_FRACTION, b))
                    else {
                        continue;
                    };
                    if cap >= remaining {
                        // Covers: keep the smallest such let.
                        if best_cover
                            .map_or(true, |(j, _, _)| spec.size_pct < free[j].size_pct)
                        {
                            best_cover = Some((i, cap, b));
                        }
                    }
                    if chosen.map_or(true, |(_, c, _)| cap > c) {
                        chosen = Some((i, cap, b));
                    }
                }
                let pick = best_cover.or(chosen);
                if let Some((i, cap, b)) = pick {
                    if cap > EPS_RATE {
                        let spec = free.swap_remove(i);
                        let take = remaining.min(cap);
                        alloc.push(LetPlan {
                            spec,
                            assignments: vec![Assignment { model: m, batch: b, rate: take }],
                        });
                        remaining -= take;
                        continue;
                    }
                }
                // No free let helps: temporal-sharing merge.
                let mut merged = false;
                for plan in alloc.iter_mut() {
                    let mut best: Option<(u32, f64)> = None;
                    for &b in &BATCHES {
                        let head = plan.headroom_rate(&ctx.lm, m, b, 0.0);
                        if head > EPS_RATE {
                            let take = remaining.min(head);
                            if best.map_or(true, |(_, t)| take > t) {
                                best = Some((b, take));
                            }
                        }
                    }
                    if let Some((b, take)) = best {
                        plan.assignments.push(Assignment { model: m, batch: b, rate: take });
                        remaining -= take;
                        merged = true;
                        break;
                    }
                }
                if !merged {
                    return None;
                }
            }
        }
        Some(Schedule { lets: alloc })
    }

    /// Iterate layouts in mixed-radix order; call `f` until it says stop.
    fn for_each_layout<F: FnMut(&[GpuLetSpec]) -> bool>(num_gpus: usize, mut f: F) {
        let mut digits = vec![0usize; num_gpus];
        loop {
            let lets: Vec<GpuLetSpec> = digits
                .iter()
                .enumerate()
                .flat_map(|(gpu, &d)| {
                    GPU_CASES[d].iter().map(move |&size_pct| GpuLetSpec { gpu, size_pct })
                })
                .collect();
            if f(&lets) {
                return;
            }
            // Increment mixed-radix counter.
            let mut i = 0;
            loop {
                if i == num_gpus {
                    return;
                }
                digits[i] += 1;
                if digits[i] < GPU_CASES.len() {
                    break;
                }
                digits[i] = 0;
                i += 1;
            }
        }
    }
}

impl Scheduler for IdealScheduler {
    fn name(&self) -> &'static str {
        "ideal"
    }

    fn schedule(&self, ctx: &SchedCtx, rates: &[f64; 5]) -> Result<Schedule> {
        let mut found: Option<Schedule> = None;
        Self::for_each_layout(ctx.num_gpus, |lets| {
            if let Some(s) = Self::try_assign(ctx, lets, rates) {
                found = Some(s);
                true // stop
            } else {
                false
            }
        });
        match found {
            Some(s) => {
                s.validate(&ctx.lm, ctx.num_gpus)?;
                Ok(s)
            }
            None => Err(Error::NotSchedulable(
                "ideal: no partition combination serves the load".into(),
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::elastic::ElasticPartitioning;

    fn ctx(gpus: usize) -> SchedCtx {
        SchedCtx::new(gpus, None)
    }

    #[test]
    fn layout_enumeration_counts() {
        let mut n = 0;
        IdealScheduler::for_each_layout(2, |_| {
            n += 1;
            false
        });
        assert_eq!(n, 16); // 4^2
    }

    #[test]
    fn schedules_simple_load() {
        let c = ctx(2);
        let s = IdealScheduler.schedule(&c, &[50.0, 50.0, 0.0, 0.0, 0.0]).unwrap();
        s.validate(&c.lm, 2).unwrap();
        let r = s.assigned_rates();
        assert!(r[0] >= 50.0 - 1e-6 && r[1] >= 50.0 - 1e-6);
    }

    #[test]
    fn ideal_dominates_elastic() {
        // Whatever elastic can schedule, ideal must also schedule
        // (it explores every partitioning the elastic one could build).
        let c = ctx(2);
        let elastic = ElasticPartitioning::gpulet();
        for rates in [
            [50.0; 5],
            [200.0, 0.0, 0.0, 0.0, 100.0],
            [0.0, 200.0, 200.0, 0.0, 0.0],
            [400.0, 100.0, 0.0, 100.0, 0.0],
        ] {
            if elastic.schedule(&c, &rates).is_ok() {
                assert!(
                    IdealScheduler.schedule(&c, &rates).is_ok(),
                    "ideal failed where elastic succeeded: {rates:?}"
                );
            }
        }
    }

    #[test]
    fn rejects_impossible_load() {
        let c = ctx(1);
        assert!(IdealScheduler.schedule(&c, &[0.0, 0.0, 0.0, 0.0, 1e7]).is_err());
    }

    #[test]
    fn uses_partitioning_when_it_helps() {
        let c = ctx(1);
        // A LeNet load beyond one whole GPU's rate but within 2x 50% lets.
        let (r100, _) = c.lm.max_rate(ModelId::Lenet, 1.0).unwrap();
        let (r50, _) = c.lm.max_rate(ModelId::Lenet, 0.5).unwrap();
        assert!(2.0 * r50 > r100 * 1.2, "calibration sanity");
        let s = IdealScheduler
            .schedule(&c, &[r100 * 1.3, 0.0, 0.0, 0.0, 0.0])
            .unwrap();
        assert!(s.lets.len() == 2);
    }
}
