//! Ideal (exhaustive) scheduler — the Fig 15 / Fig 16 comparator.
//!
//! Enumerates per-GPU partition combinations from the four cases the
//! paper uses ({100}, {50,50}, {40,60}, {20,80}) and, for each, greedily
//! packs the offered rates onto the fixed gpu-lets (temporal sharing
//! allowed). The first layout that serves everything within SLOs proves
//! schedulability; the search is exhaustive, so a `NotSchedulable`
//! verdict is authoritative for this partition vocabulary and packer.
//!
//! ## Layout-multiset symmetry
//!
//! Physical GPUs are interchangeable: the packer's decisions depend only
//! on gpu-let *sizes* (capacity, batch picks, merge headroom are all
//! functions of `size_pct`), never on the GPU index, and feasibility is
//! checked per let with no cross-GPU coupling. Two layouts whose per-GPU
//! case assignments are permutations of each other therefore produce
//! isomorphic packings — identical sizes, batches, and rates, with only
//! the GPU labels permuted — and in particular the same schedulability
//! verdict. The default search deduplicates the `4^N` digit vectors by
//! their case *multiset* (for the paper's `N = 4` testbed: 256 layouts
//! collapse to `C(4+4-1, 4) = 35` canonical ones, a 7.3× cut), visiting
//! the first occurrence of each multiset in the original mixed-radix
//! order so the found schedule matches what the full enumeration's
//! earliest-success layout would contain up to GPU relabeling.
//! `schedule_with(ctx, rates, false)` keeps the full enumeration as the
//! equivalence baseline (tested over the whole 1,023-scenario
//! population in `tests/perf_refactor_equivalence.rs`).
//!
//! Scratch buffers (`free`, the packing allocation, the layout vector,
//! the sorted model list) are allocated once per `schedule` call and
//! reused across all `try_assign` attempts.

use crate::error::{Error, Result};
use crate::gpu::gpulet::GpuLetSpec;
use crate::models::ModelId;
use crate::perfmodel::BATCHES;
use crate::sched::types::{Assignment, LetPlan, SchedCtx, Schedule, Scheduler};

const EPS_RATE: f64 = 1e-6;

/// Per-GPU partition cases (§6.2: "4 GPUs which can be partitioned into
/// 4 cases" → 4^4 layouts).
pub const GPU_CASES: [&[u32]; 4] = [&[100], &[50, 50], &[40, 60], &[20, 80]];

/// Exhaustive-search scheduler.
#[derive(Clone, Copy, Debug, Default)]
pub struct IdealScheduler;

impl IdealScheduler {
    /// Greedy packer over a fixed gpu-let set. On success `alloc` holds
    /// a schedule covering every model's full rate; `free` and `alloc`
    /// are caller-owned scratch reused across layouts.
    fn try_assign(
        ctx: &SchedCtx,
        lets: &[GpuLetSpec],
        models: &[(ModelId, f64)],
        free: &mut Vec<GpuLetSpec>,
        alloc: &mut Vec<LetPlan>,
    ) -> bool {
        free.clear();
        free.extend_from_slice(lets);
        // Largest first: heavy models claim big lets.
        free.sort_by(|a, b| b.size_pct.cmp(&a.size_pct).then(a.gpu.cmp(&b.gpu)));
        alloc.clear();

        for &(m, rate) in models {
            let mut remaining = rate;
            while remaining > EPS_RATE {
                // Prefer the smallest free let that covers the remainder
                // (best fit), else the highest-capacity free let.
                let mut chosen: Option<(usize, f64, u32)> = None; // (idx, cap, batch)
                let mut best_cover: Option<(usize, f64, u32)> = None;
                for (i, spec) in free.iter().enumerate() {
                    let Some((cap, b)) = ctx
                        .max_rate(m, spec.size_pct)
                        .map(|(r, b)| (r * crate::sched::types::CAPACITY_FRACTION, b))
                    else {
                        continue;
                    };
                    if cap >= remaining {
                        // Covers: keep the smallest such let.
                        if best_cover
                            .is_none_or(|(j, _, _)| spec.size_pct < free[j].size_pct)
                        {
                            best_cover = Some((i, cap, b));
                        }
                    }
                    if chosen.is_none_or(|(_, c, _)| cap > c) {
                        chosen = Some((i, cap, b));
                    }
                }
                let pick = best_cover.or(chosen);
                if let Some((i, cap, b)) = pick {
                    if cap > EPS_RATE {
                        let spec = free.swap_remove(i);
                        let take = remaining.min(cap);
                        alloc.push(LetPlan {
                            spec,
                            assignments: vec![Assignment { model: m, batch: b, rate: take }],
                        });
                        remaining -= take;
                        continue;
                    }
                }
                // No free let helps: temporal-sharing merge.
                let mut merged = false;
                for plan in alloc.iter_mut() {
                    let mut best: Option<(u32, f64)> = None;
                    for &b in &BATCHES {
                        let head = plan.headroom_rate(&ctx.lm, m, b, 0.0);
                        if head > EPS_RATE {
                            let take = remaining.min(head);
                            if best.is_none_or(|(_, t)| take > t) {
                                best = Some((b, take));
                            }
                        }
                    }
                    if let Some((b, take)) = best {
                        plan.assignments.push(Assignment { model: m, batch: b, rate: take });
                        remaining -= take;
                        merged = true;
                        break;
                    }
                }
                if !merged {
                    return false;
                }
            }
        }
        true
    }

    /// Iterate layouts in mixed-radix order; call `f` until it says
    /// stop. With `dedup` set, only the first occurrence of each per-GPU
    /// case multiset is visited (see the module docs for the symmetry
    /// argument).
    fn for_each_layout<F: FnMut(&[GpuLetSpec]) -> bool>(
        num_gpus: usize,
        dedup: bool,
        mut f: F,
    ) {
        let mut digits = vec![0usize; num_gpus];
        // Multiset key: per-case occurrence counts packed into a u64
        // (8 bits per case — ample for any realistic GPU count).
        let mut seen: std::collections::BTreeSet<u64> = std::collections::BTreeSet::new();
        let mut lets: Vec<GpuLetSpec> = Vec::with_capacity(2 * num_gpus);
        loop {
            let fresh = if dedup {
                let mut key = 0u64;
                for &d in &digits {
                    key += 1 << (8 * d);
                }
                seen.insert(key)
            } else {
                true
            };
            if fresh {
                lets.clear();
                for (gpu, &d) in digits.iter().enumerate() {
                    for &size_pct in GPU_CASES[d] {
                        lets.push(GpuLetSpec { gpu, size_pct });
                    }
                }
                if f(&lets) {
                    return;
                }
            }
            // Increment mixed-radix counter.
            let mut i = 0;
            loop {
                if i == num_gpus {
                    return;
                }
                digits[i] += 1;
                if digits[i] < GPU_CASES.len() {
                    break;
                }
                digits[i] = 0;
                i += 1;
            }
        }
    }

    /// Run the search with explicit control over layout deduplication.
    /// `dedup_layouts = true` is the production path (`schedule`);
    /// `false` forces the full `4^N` enumeration — the reference the
    /// equivalence tests and the micro benches compare against.
    pub fn schedule_with(
        ctx: &SchedCtx,
        rates: &[f64; 5],
        dedup_layouts: bool,
    ) -> Result<Schedule> {
        crate::sched::types::validate_rates(rates)?;
        let mut models: Vec<(ModelId, f64)> = ModelId::ALL
            .iter()
            .map(|&m| (m, rates[m.index()]))
            .filter(|&(_, r)| r > 0.0)
            .collect();
        models.sort_by(|a, b| b.1.total_cmp(&a.1));

        let mut free: Vec<GpuLetSpec> = Vec::new();
        let mut alloc: Vec<LetPlan> = Vec::new();
        let mut found: Option<Schedule> = None;
        Self::for_each_layout(ctx.num_gpus, dedup_layouts, |lets| {
            if Self::try_assign(ctx, lets, &models, &mut free, &mut alloc) {
                found = Some(Schedule { lets: std::mem::take(&mut alloc) });
                true // stop
            } else {
                false
            }
        });
        match found {
            Some(s) => {
                s.validate(&ctx.lm, ctx.num_gpus)?;
                Ok(s)
            }
            None => Err(Error::NotSchedulable(
                "ideal: no partition combination serves the load".into(),
            )),
        }
    }
}

impl Scheduler for IdealScheduler {
    fn name(&self) -> &'static str {
        "ideal"
    }

    fn schedule(&self, ctx: &SchedCtx, rates: &[f64; 5]) -> Result<Schedule> {
        Self::schedule_with(ctx, rates, true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::elastic::ElasticPartitioning;

    fn ctx(gpus: usize) -> SchedCtx {
        SchedCtx::new(gpus, None)
    }

    #[test]
    fn layout_enumeration_counts() {
        let mut full = 0;
        IdealScheduler::for_each_layout(2, false, |_| {
            full += 1;
            false
        });
        assert_eq!(full, 16); // 4^2
        let mut deduped = 0;
        IdealScheduler::for_each_layout(2, true, |_| {
            deduped += 1;
            false
        });
        assert_eq!(deduped, 10); // C(4+2-1, 2) multisets of 2 cases
        let mut deduped4 = 0;
        IdealScheduler::for_each_layout(4, true, |_| {
            deduped4 += 1;
            false
        });
        assert_eq!(deduped4, 35); // C(4+4-1, 4): the paper testbed
    }

    #[test]
    fn dedup_visits_first_occurrence_of_each_multiset() {
        // The canonical instance must appear at the same position the
        // multiset first shows up in the full mixed-radix order.
        let mut full_keys: Vec<Vec<u32>> = Vec::new();
        IdealScheduler::for_each_layout(3, false, |lets| {
            let mut sizes: Vec<u32> = lets.iter().map(|l| l.size_pct).collect();
            sizes.sort_unstable();
            full_keys.push(sizes);
            false
        });
        let mut first_seen: Vec<Vec<u32>> = Vec::new();
        for k in &full_keys {
            if !first_seen.contains(k) {
                first_seen.push(k.clone());
            }
        }
        let mut dedup_keys: Vec<Vec<u32>> = Vec::new();
        IdealScheduler::for_each_layout(3, true, |lets| {
            let mut sizes: Vec<u32> = lets.iter().map(|l| l.size_pct).collect();
            sizes.sort_unstable();
            dedup_keys.push(sizes);
            false
        });
        assert_eq!(dedup_keys, first_seen);
    }

    #[test]
    fn schedules_simple_load() {
        let c = ctx(2);
        let s = IdealScheduler.schedule(&c, &[50.0, 50.0, 0.0, 0.0, 0.0]).unwrap();
        s.validate(&c.lm, 2).unwrap();
        let r = s.assigned_rates();
        assert!(r[0] >= 50.0 - 1e-6 && r[1] >= 50.0 - 1e-6);
    }

    #[test]
    fn ideal_dominates_elastic() {
        // Whatever elastic can schedule, ideal must also schedule
        // (it explores every partitioning the elastic one could build).
        let c = ctx(2);
        let elastic = ElasticPartitioning::gpulet();
        for rates in [
            [50.0; 5],
            [200.0, 0.0, 0.0, 0.0, 100.0],
            [0.0, 200.0, 200.0, 0.0, 0.0],
            [400.0, 100.0, 0.0, 100.0, 0.0],
        ] {
            if elastic.schedule(&c, &rates).is_ok() {
                assert!(
                    IdealScheduler.schedule(&c, &rates).is_ok(),
                    "ideal failed where elastic succeeded: {rates:?}"
                );
            }
        }
    }

    #[test]
    fn dedup_and_full_agree_on_spot_checks() {
        let c = ctx(2);
        for rates in [
            [50.0; 5],
            [600.0, 0.0, 0.0, 0.0, 0.0],
            [0.0, 0.0, 400.0, 0.0, 400.0],
            [1e6, 0.0, 0.0, 0.0, 1e6],
        ] {
            let full = IdealScheduler::schedule_with(&c, &rates, false).is_ok();
            let dedup = IdealScheduler::schedule_with(&c, &rates, true).is_ok();
            assert_eq!(full, dedup, "{rates:?}");
        }
    }

    #[test]
    fn rejects_impossible_load() {
        let c = ctx(1);
        assert!(IdealScheduler.schedule(&c, &[0.0, 0.0, 0.0, 0.0, 1e7]).is_err());
    }

    #[test]
    fn uses_partitioning_when_it_helps() {
        let c = ctx(1);
        // A LeNet load beyond one whole GPU's rate but within 2x 50% lets.
        let (r100, _) = c.lm.max_rate(ModelId::Lenet, 1.0).unwrap();
        let (r50, _) = c.lm.max_rate(ModelId::Lenet, 0.5).unwrap();
        assert!(2.0 * r50 > r100 * 1.2, "calibration sanity");
        let s = IdealScheduler
            .schedule(&c, &[r100 * 1.3, 0.0, 0.0, 0.0, 0.0])
            .unwrap();
        assert!(s.lets.len() == 2);
    }
}
