//! Served-model catalog — the Rust-side mirror of the paper's Table 4.
//!
//! Holds the static, serving-relevant facts per model: SLO, the
//! calibrated cost parameters behind the `L(b, p)` latency model
//! (`perfmodel::latency`), and the solo resource-utilization vectors
//! the interference models consume (§4.4).
//!
//! Cost parameters are calibrated so that the solo latency at batch 32
//! on a full GPU equals SLO/2 — exactly how the paper derives Table 4's
//! SLOs ("set by doubling the solo execution latency … batch size 32").

use crate::error::{Error, Result};

/// The five served models (paper Table 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ModelId {
    Lenet,
    Googlenet,
    Resnet,
    SsdMobilenet,
    Vgg,
}

impl ModelId {
    /// All models, in Table 4 order.
    pub const ALL: [ModelId; 5] = [
        ModelId::Lenet,
        ModelId::Googlenet,
        ModelId::Resnet,
        ModelId::SsdMobilenet,
        ModelId::Vgg,
    ];

    /// Canonical artifact / manifest name.
    pub fn name(self) -> &'static str {
        match self {
            ModelId::Lenet => "lenet",
            ModelId::Googlenet => "googlenet",
            ModelId::Resnet => "resnet",
            ModelId::SsdMobilenet => "ssd_mobilenet",
            ModelId::Vgg => "vgg",
        }
    }

    /// Paper abbreviation (Table 4).
    pub fn abbrev(self) -> &'static str {
        match self {
            ModelId::Lenet => "le",
            ModelId::Googlenet => "goo",
            ModelId::Resnet => "res",
            ModelId::SsdMobilenet => "ssd",
            ModelId::Vgg => "vgg",
        }
    }

    /// Parse from either canonical name or abbreviation.
    pub fn parse(s: &str) -> Result<ModelId> {
        for m in ModelId::ALL {
            if s == m.name() || s == m.abbrev() {
                return Ok(m);
            }
        }
        Err(Error::Model(format!("unknown model {s:?}")))
    }

    /// Stable dense index (for arrays keyed by model).
    pub fn index(self) -> usize {
        match self {
            ModelId::Lenet => 0,
            ModelId::Googlenet => 1,
            ModelId::Resnet => 2,
            ModelId::SsdMobilenet => 3,
            ModelId::Vgg => 4,
        }
    }

    pub fn from_index(i: usize) -> ModelId {
        ModelId::ALL[i]
    }
}

impl std::fmt::Display for ModelId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Calibrated per-model cost + resource profile.
///
/// The latency model is
/// `L(b, p) = t0 + w1*b / min(p, need(b))`
/// with `need(b) = min(1, need0 + needk * sqrt(b))` — the fraction of the
/// GPU a batch-b inference can actually use. `need(b)` is where Fig 3's
/// knee sits: resource beyond it is wasted (flat region). `t0` is the
/// partition-independent part (kernel launches, framework overhead,
/// non-parallelizable layers); the parallel work `w1*b` is what the
/// gpu-let fraction accelerates. This form is monotone increasing in
/// `b` and non-increasing in `p` everywhere — as real batch latency is.
#[derive(Clone, Copy, Debug)]
pub struct ModelProfile {
    pub id: ModelId,
    /// SLO latency bound in ms (paper Table 4).
    pub slo_ms: f64,
    /// Partition-independent overhead per batch (ms).
    pub t0_ms: f64,
    /// Per-sample parallel work at full utilization (ms).
    pub w1_ms: f64,
    /// Parallelism intercept of `need(b)`.
    pub need0: f64,
    /// Parallelism slope of `need(b)` (vs sqrt(b)).
    pub needk: f64,
    /// L2 utilization (fraction) when saturating the GPU solo.
    pub l2_full: f64,
    /// DRAM bandwidth utilization (fraction) when saturating the GPU solo.
    pub bw_full: f64,
}

impl ModelProfile {
    /// Usable GPU fraction at batch `b` (the Fig 3 knee position).
    pub fn need(&self, b: u32) -> f64 {
        (self.need0 + self.needk * (b as f64).sqrt()).min(1.0)
    }

    /// Solo L2 utilization when running at partition `p` (fraction of GPU)
    /// with batch `b`. A floor term models the burstiness of inference
    /// kernels: even small batches saturate the memory system while
    /// their kernels run, so demand does not vanish with batch size.
    pub fn l2_util(&self, p: f64, b: u32) -> f64 {
        self.l2_full * (0.35 + 0.65 * p.min(self.need(b)))
    }

    /// Solo DRAM bandwidth utilization at partition `p`, batch `b`.
    pub fn bw_util(&self, p: f64, b: u32) -> f64 {
        self.bw_full * (0.35 + 0.65 * p.min(self.need(b)))
    }
}

/// Build the calibrated profile for one model.
///
/// `rho` is the fixed-overhead fraction of the solo batch-32 latency
/// (`t0 = rho * slo/2`); the constraint `L(32, 1.0) = slo/2` then pins
/// `w1 = (slo/2 - t0) * need(32) / 32`.
fn calibrate(
    id: ModelId,
    slo_ms: f64,
    rho: f64,
    need0: f64,
    needk: f64,
    l2_full: f64,
    bw_full: f64,
) -> ModelProfile {
    let need32 = (need0 + needk * 32f64.sqrt()).min(1.0);
    let t0 = rho * slo_ms / 2.0;
    let w1 = (slo_ms / 2.0 - t0) * need32 / 32.0;
    debug_assert!(w1 > 0.0, "SLO too tight for t0 ({id:?})");
    ModelProfile { id, slo_ms, t0_ms: t0, w1_ms: w1, need0, needk, l2_full, bw_full }
}

/// The paper's Table 4 catalog with calibrated cost parameters.
///
/// `need` parameters encode each model's ability to fill the GPU:
/// LeNet (tiny MNIST net) barely uses 30% even at batch 32, while
/// VGG-16 saturates the GPU from moderate batches — matching the Fig 3
/// observation that small models leave most of the GPU idle under SLOs.
/// `rho` is large for tiny models (overhead-dominated LeNet) and small
/// for compute-heavy ones.
pub fn catalog() -> [ModelProfile; 5] {
    [
        calibrate(ModelId::Lenet, 5.0, 0.30, 0.04, 0.045, 0.18, 0.12),
        calibrate(ModelId::Googlenet, 44.0, 0.15, 0.10, 0.085, 0.45, 0.35),
        calibrate(ModelId::Resnet, 95.0, 0.12, 0.12, 0.110, 0.55, 0.50),
        calibrate(ModelId::SsdMobilenet, 136.0, 0.12, 0.15, 0.105, 0.50, 0.45),
        calibrate(ModelId::Vgg, 130.0, 0.08, 0.20, 0.140, 0.70, 0.65),
    ]
}

/// Profile lookup by id.
pub fn profile(id: ModelId) -> ModelProfile {
    catalog()[id.index()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_matches_table4_slos() {
        let slos: Vec<f64> = catalog().iter().map(|m| m.slo_ms).collect();
        assert_eq!(slos, vec![5.0, 44.0, 95.0, 136.0, 130.0]);
    }

    #[test]
    fn parse_roundtrip() {
        for m in ModelId::ALL {
            assert_eq!(ModelId::parse(m.name()).unwrap(), m);
            assert_eq!(ModelId::parse(m.abbrev()).unwrap(), m);
            assert_eq!(ModelId::from_index(m.index()), m);
        }
        assert!(ModelId::parse("alexnet").is_err());
    }

    #[test]
    fn need_monotone_and_bounded() {
        for prof in catalog() {
            let mut prev = 0.0;
            for b in [1u32, 2, 4, 8, 16, 32] {
                let n = prof.need(b);
                assert!(n > 0.0 && n <= 1.0, "{:?} need({b})={n}", prof.id);
                assert!(n >= prev, "need must be monotone in b");
                prev = n;
            }
        }
    }

    #[test]
    fn lenet_underutilizes_vgg_saturates() {
        // The paper's core motivation: small models cannot fill the GPU.
        assert!(profile(ModelId::Lenet).need(32) < 0.4);
        assert!(profile(ModelId::Vgg).need(32) >= 0.9);
    }

    #[test]
    fn calibration_pins_half_slo_at_b32_full_gpu() {
        for prof in catalog() {
            let l = prof.t0_ms + prof.w1_ms * 32.0 / prof.need(32);
            assert!(
                (l - prof.slo_ms / 2.0).abs() < 1e-9,
                "{:?}: L(32,1)={l} want {}",
                prof.id,
                prof.slo_ms / 2.0
            );
        }
    }

    #[test]
    fn resource_vectors_in_unit_range() {
        for prof in catalog() {
            for b in [1u32, 8, 32] {
                for p in [0.2, 0.5, 1.0] {
                    let l2 = prof.l2_util(p, b);
                    let bw = prof.bw_util(p, b);
                    assert!((0.0..=1.0).contains(&l2));
                    assert!((0.0..=1.0).contains(&bw));
                }
            }
        }
    }

    #[test]
    fn utilization_caps_at_need() {
        let p = profile(ModelId::Lenet);
        // Beyond the knee, a bigger partition must not raise demand.
        assert_eq!(p.l2_util(0.5, 1), p.l2_util(1.0, 1));
    }
}
