//! `gpulets` — CLI launcher for the gpu-let inference serving stack.
//!
//! ```text
//! gpulets experiment <fig3|fig4|fig5|fig6|fig9|fig12|fig13|fig14|fig15|fig16|all>
//! gpulets serve [--config <toml>] [--algo A] [--gpus N] [--duration S] [--rate M=R ...]
//! gpulets serve-real [--artifacts DIR] [--duration S] [--rate M=R ...]
//! gpulets profile            # dump the offline L(b,p) profile grid
//! gpulets models             # Table 4
//! gpulets scenarios          # Table 5
//! ```
//!
//! (clap is unavailable offline — see Cargo.toml — so argument parsing
//! is a small hand-rolled matcher.)

use gpulets::config::{Algo, Config};
use gpulets::coordinator::server::RealServer;
use gpulets::coordinator::simserver::{simulate, SimConfig};
use gpulets::error::Result;
use gpulets::experiments as ex;
use gpulets::interference::GroundTruth;
use gpulets::models::ModelId;
use gpulets::runtime::{Engine, ModelRegistry};
use gpulets::sched::{
    ElasticPartitioning, GuidedSelfTuning, IdealScheduler, SchedCtx, Scheduler,
    SquishyBinPacking,
};
use gpulets::workload::generate_arrivals;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("experiment") => experiment(args.get(1).map(String::as_str).unwrap_or("all")),
        Some("serve") => serve(&args[1..]),
        Some("serve-real") => serve_real(&args[1..]),
        Some("profile") => {
            print!("{}", ex::fig03::run());
            Ok(())
        }
        Some("models") => {
            print!("{}", ex::tables::table4());
            Ok(())
        }
        Some("scenarios") => {
            print!("{}", ex::tables::table5());
            Ok(())
        }
        Some("help") | None => {
            print_usage();
            Ok(())
        }
        Some(other) => {
            print_usage();
            Err(gpulets::Error::Other(format!("unknown command {other:?}")))
        }
    }
}

fn print_usage() {
    println!(
        "gpulets — multi-model inference serving with GPU spatial partitioning\n\
         \n\
         USAGE:\n\
         \x20 gpulets experiment <fig3|fig4|fig5|fig6|fig9|fig12|fig13|fig14|fig15|fig16|tables|all>\n\
         \x20 gpulets serve [--config F] [--algo A] [--gpus N] [--duration S] [--seed X] [--rate model=R]...\n\
         \x20 gpulets serve-real [--artifacts DIR] [--duration S] [--rate model=R]...\n\
         \x20 gpulets profile | models | scenarios | help\n\
         \n\
         schedulers: gpulet gpulet+int sbp sbp+part selftune ideal"
    );
}

fn experiment(which: &str) -> Result<()> {
    let all = [
        ("fig3", ex::fig03::run as fn() -> String),
        ("fig4", ex::fig04::run),
        ("fig5", ex::fig05::run),
        ("fig6", ex::fig06::run),
        ("fig9", ex::fig09::run),
        ("fig12", ex::fig12::run),
        ("fig13", ex::fig13::run),
        ("fig14", ex::fig14::run),
        ("fig15", ex::fig15::run),
        ("fig16", ex::fig16::run),
    ];
    if which == "tables" {
        print!("{}", ex::tables::table3());
        print!("{}", ex::tables::table4());
        print!("{}", ex::tables::table5());
        return Ok(());
    }
    if which == "all" {
        print!("{}", ex::tables::table3());
        print!("{}", ex::tables::table4());
        print!("{}", ex::tables::table5());
        for (name, f) in all {
            eprintln!("[running {name}]");
            println!("{}", f());
        }
        return Ok(());
    }
    for (name, f) in all {
        if name == which {
            print!("{}", f());
            return Ok(());
        }
    }
    Err(gpulets::Error::Other(format!("unknown experiment {which:?}")))
}

/// Parse `--key value` style flags plus repeated `--rate model=R`.
fn parse_flags(args: &[String], cfg: &mut Config) -> Result<()> {
    let mut i = 0;
    while i < args.len() {
        let flag = args[i].as_str();
        let val = args.get(i + 1).cloned();
        let need = |name: &str| -> Result<String> {
            val.clone().ok_or_else(|| {
                gpulets::Error::Other(format!("flag {name} needs a value"))
            })
        };
        match flag {
            "--config" => *cfg = Config::load(need("--config")?)?,
            "--algo" => cfg.algo = Algo::parse(&need("--algo")?)?,
            "--gpus" => {
                cfg.num_gpus = need("--gpus")?.parse().map_err(|_| {
                    gpulets::Error::Other("--gpus expects an integer".into())
                })?
            }
            "--duration" => {
                cfg.duration_s = need("--duration")?.parse().map_err(|_| {
                    gpulets::Error::Other("--duration expects seconds".into())
                })?
            }
            "--seed" => {
                cfg.seed = need("--seed")?.parse().map_err(|_| {
                    gpulets::Error::Other("--seed expects an integer".into())
                })?
            }
            "--artifacts" => cfg.artifacts_dir = need("--artifacts")?,
            "--rate" => {
                let spec = need("--rate")?;
                let (name, rate) = spec.split_once('=').ok_or_else(|| {
                    gpulets::Error::Other("--rate expects model=req_per_s".into())
                })?;
                let m = ModelId::parse(name)?;
                cfg.rates[m.index()] = rate.parse().map_err(|_| {
                    gpulets::Error::Other(format!("bad rate {rate:?}"))
                })?;
            }
            other => {
                return Err(gpulets::Error::Other(format!("unknown flag {other:?}")))
            }
        }
        i += 2;
    }
    Ok(())
}

/// Simulated serving: schedule the configured rates, run the trace,
/// print the schedule and the per-model report.
fn serve(args: &[String]) -> Result<()> {
    let mut cfg = Config::default();
    parse_flags(args, &mut cfg)?;

    let interference_aware = cfg.algo == Algo::GpuletInt;
    let ctx = SchedCtx::new(
        cfg.num_gpus,
        if interference_aware {
            Some(ex::common::fitted_interference())
        } else {
            None
        },
    );
    let scheduler: Box<dyn Scheduler> = match cfg.algo {
        Algo::Gpulet => Box::new(ElasticPartitioning::gpulet()),
        Algo::GpuletInt => Box::new(ElasticPartitioning::gpulet_int()),
        Algo::Sbp => Box::new(SquishyBinPacking::baseline()),
        Algo::SbpPart => Box::new(SquishyBinPacking::with_even_partitioning()),
        Algo::Selftune => Box::new(GuidedSelfTuning),
        Algo::Ideal => Box::new(IdealScheduler),
    };

    println!(
        "scheduling {} on {} GPUs: {}",
        scheduler.name(),
        cfg.num_gpus,
        ex::common::fmt_rates(&cfg.rates)
    );
    let schedule = scheduler.schedule(&ctx, &cfg.rates)?;
    println!("allocated {}% of cluster over {} gpu-lets:", schedule.total_allocated_pct(), schedule.lets.len());
    for lp in &schedule.lets {
        let asg: Vec<String> = lp
            .assignments
            .iter()
            .map(|a| format!("{}@b{} {:.0}req/s", a.model.abbrev(), a.batch, a.rate))
            .collect();
        println!("  gpu{} {:>3}%: {}", lp.spec.gpu, lp.spec.size_pct, asg.join(" + "));
    }

    let pairs: Vec<(ModelId, f64)> = ModelId::ALL
        .iter()
        .map(|&m| (m, cfg.rates[m.index()]))
        .filter(|&(_, r)| r > 0.0)
        .collect();
    let arrivals = generate_arrivals(&pairs, cfg.duration_s, cfg.seed);
    println!("\nsimulating {} requests over {}s ({})...", arrivals.len(), cfg.duration_s, cfg.share_mode.name());
    let report = simulate(
        &ctx.lm,
        &GroundTruth::default(),
        &schedule,
        &arrivals,
        cfg.duration_s,
        &SimConfig { mode: cfg.share_mode, seed: cfg.seed, ..Default::default() },
    );
    println!("\n{}", report.table());
    println!(
        "throughput {:.0} req/s, goodput {:.0} req/s, violations {:.2}%",
        report.throughput_rps(),
        report.goodput_rps(),
        report.overall_violation_rate() * 100.0
    );
    Ok(())
}

/// Real serving on the PJRT CPU runtime (the `real` clock path).
fn serve_real(args: &[String]) -> Result<()> {
    let mut cfg = Config::default();
    // Modest defaults for CPU execution.
    cfg.rates = [20.0, 5.0, 5.0, 2.0, 5.0];
    cfg.duration_s = 5.0;
    parse_flags(args, &mut cfg)?;

    println!("loading artifacts from {}/ ...", cfg.artifacts_dir);
    let engine = Engine::cpu()?;
    println!("PJRT platform: {} ({} devices)", engine.platform(), engine.device_count());
    let registry = ModelRegistry::load(&engine, &cfg.artifacts_dir)?;
    println!("compiled {} (model, batch) executables", registry.len());

    let pairs: Vec<(ModelId, f64)> = ModelId::ALL
        .iter()
        .map(|&m| (m, cfg.rates[m.index()]))
        .filter(|&(_, r)| r > 0.0)
        .collect();
    let arrivals = generate_arrivals(&pairs, cfg.duration_s, cfg.seed);
    println!("serving {} requests over {}s...", arrivals.len(), cfg.duration_s);

    let server = RealServer::new(&registry);
    let outcome = server.serve(&arrivals, cfg.duration_s)?;
    println!("\n{}", outcome.report.table());
    println!(
        "throughput {:.0} req/s, PJRT busy {:.2}s, batches: {:?}",
        outcome.report.throughput_rps(),
        outcome.exec_wall_s,
        outcome.batches
    );
    Ok(())
}
