//! # gpu-lets: multi-model ML inference serving with GPU spatial partitioning
//!
//! Reproduction of Choi et al., *"Multi-model Machine Learning Inference
//! Serving with GPU Spatial Partitioning"* (2021), as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: the *gpu-let* virtual
//!   GPU abstraction, the Elastic Partitioning scheduler (Algorithm 1),
//!   the interference model, duty-cycle batching, and the serving runtime.
//! * **L2/L1 (python/, build-time only)** — JAX models over Pallas kernels,
//!   AOT-lowered to `artifacts/*.hlo.txt`, executed here through the PJRT
//!   CPU client (`runtime`). Python is never on the request path.
//!
//! See `DESIGN.md` for the module inventory and the experiment index
//! mapping every paper figure/table to a bench target, and `README.md`
//! for the CLI quickstart (`gpulets run-fig 12`).
//!
//! # Examples
//!
//! Schedule the paper's `equal` scenario (50 req/s per model, Table 5)
//! on a 4-GPU cluster with Elastic Partitioning, then check the plan:
//!
//! ```
//! use gpulets::sched::{ElasticPartitioning, SchedCtx, Scheduler};
//!
//! let ctx = SchedCtx::new(4, None);
//! let schedule = ElasticPartitioning::gpulet()
//!     .schedule(&ctx, &[50.0; 5])
//!     .expect("the equal scenario fits four GPUs");
//!
//! // The schedule is structurally valid and covers the offered load.
//! schedule.validate(&ctx.lm, 4).unwrap();
//! let assigned: f64 = schedule.assigned_rates().iter().sum();
//! assert!(assigned >= 250.0 - 1e-6);
//! assert!(schedule.total_allocated_pct() <= 400);
//! ```
pub mod analysis;
pub mod apps;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod experiments;
pub mod fleet;
pub mod gpu;
pub mod interference;
pub mod metrics;
pub mod models;
pub mod perfmodel;
pub mod runtime;
pub mod sched;
pub mod simclock;
pub mod telemetry;
pub mod util;
pub mod workload;

pub use error::{Error, Result};
