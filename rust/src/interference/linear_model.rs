//! The paper's interference estimator (§4.4):
//!
//! `factor = c1*l2_m1 + c2*l2_m2 + c3*mem_m1 + c4*mem_m2 + c5`
//!
//! Features are the two tasks' *solo* L2 and memory-bandwidth
//! utilizations at their assigned partitions; coefficients come from
//! least squares over profiled pairs. `gpulet+int` adds the predicted
//! overhead to the SLO feasibility check (Algorithm 1, line 28).

use crate::error::Result;
use crate::interference::ground_truth::{GroundTruth, TaskDemand};
use crate::interference::linalg::least_squares;
use crate::models::{profile, ModelId};
use crate::perfmodel::BATCHES;
use crate::util::rng::Pcg32;

/// One profiled consolidation observation.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    /// Victim's solo L2 utilization.
    pub l2_m1: f64,
    /// Aggressor's solo L2 utilization.
    pub l2_m2: f64,
    /// Victim's solo memory-bandwidth utilization.
    pub mem_m1: f64,
    /// Aggressor's solo memory-bandwidth utilization.
    pub mem_m2: f64,
    /// Measured interference factor (latency stretch − 1).
    pub factor: f64,
}

impl Sample {
    fn features(&self) -> Vec<f64> {
        vec![self.l2_m1, self.l2_m2, self.mem_m1, self.mem_m2, 1.0]
    }
}

/// Fitted linear interference model (c1..c5).
#[derive(Clone, Debug)]
pub struct InterferenceModel {
    pub coef: [f64; 5],
}

impl InterferenceModel {
    /// Fit by ordinary least squares.
    pub fn fit(samples: &[Sample]) -> Result<InterferenceModel> {
        let xs: Vec<Vec<f64>> = samples.iter().map(|s| s.features()).collect();
        let ys: Vec<f64> = samples.iter().map(|s| s.factor).collect();
        let beta = least_squares(&xs, &ys)?;
        Ok(InterferenceModel { coef: [beta[0], beta[1], beta[2], beta[3], beta[4]] })
    }

    /// Predicted interference factor for a victim/aggressor pair.
    pub fn predict(&self, l2_m1: f64, l2_m2: f64, mem_m1: f64, mem_m2: f64) -> f64 {
        (self.coef[0] * l2_m1
            + self.coef[1] * l2_m2
            + self.coef[2] * mem_m1
            + self.coef[3] * mem_m2
            + self.coef[4])
            .max(0.0)
    }

    /// Predicted factor for model `m1` (batch `b1`, partition `p1`)
    /// co-resident with `m2` — the form the scheduler calls.
    pub fn predict_pair(
        &self,
        m1: ModelId,
        b1: u32,
        p1: f64,
        m2: ModelId,
        b2: u32,
        p2: f64,
    ) -> f64 {
        let pr1 = profile(m1);
        let pr2 = profile(m2);
        self.predict(
            pr1.l2_util(p1, b1),
            pr2.l2_util(p2, b2),
            pr1.bw_util(p1, b1),
            pr2.bw_util(p2, b2),
        )
    }

    /// Relative prediction errors |pred − true| / (1 + true) on a
    /// validation set — the Fig 9 metric (error on the latency stretch).
    pub fn validation_errors(&self, samples: &[Sample]) -> Vec<f64> {
        samples
            .iter()
            .map(|s| {
                let pred = self.predict(s.l2_m1, s.l2_m2, s.mem_m1, s.mem_m2);
                (pred - s.factor).abs() / (1.0 + s.factor)
            })
            .collect()
    }
}

/// Generate the paper's profiling population: pairs of the five models
/// with per-side batches from {2,4,8,16,32} on splits {2:8, 4:6, 5:5,
/// 6:4, 8:2}, "measured" against the ground truth. Every co-residency
/// yields two observations (each side suffers its own factor, §4.4) —
/// comfortably more than the paper's 2,500 data points.
pub fn profiling_population(gt: &GroundTruth) -> Vec<Sample> {
    let splits = [(0.2, 0.8), (0.4, 0.6), (0.5, 0.5), (0.6, 0.4), (0.8, 0.2)];
    let batches: Vec<u32> = BATCHES.iter().copied().filter(|&b| b >= 2).collect();
    let mut samples = Vec::new();
    for m1 in ModelId::ALL {
        for m2 in ModelId::ALL {
            for &b1 in &batches {
                for &b2 in &batches {
                    for &(p1, p2) in &splits {
                        let pr1 = profile(m1);
                        let pr2 = profile(m2);
                        let d1 = TaskDemand {
                            model: m1, batch: b1,
                            l2: pr1.l2_util(p1, b1), bw: pr1.bw_util(p1, b1),
                        };
                        let d2 = TaskDemand {
                            model: m2, batch: b2,
                            l2: pr2.l2_util(p2, b2), bw: pr2.bw_util(p2, b2),
                        };
                        let (f1, f2) = gt.pair_factors(&d1, &d2);
                        samples.push(Sample {
                            l2_m1: d1.l2, l2_m2: d2.l2,
                            mem_m1: d1.bw, mem_m2: d2.bw, factor: f1,
                        });
                        samples.push(Sample {
                            l2_m1: d2.l2, l2_m2: d1.l2,
                            mem_m1: d2.bw, mem_m2: d1.bw, factor: f2,
                        });
                    }
                }
            }
        }
    }
    samples
}

/// Shuffle and split into (train, validation) like the paper's 1,750/750.
pub fn train_val_split(
    mut samples: Vec<Sample>,
    train_frac: f64,
    seed: u64,
) -> (Vec<Sample>, Vec<Sample>) {
    let mut rng = Pcg32::seeded(seed);
    rng.shuffle(&mut samples);
    let cut = ((samples.len() as f64) * train_frac).round() as usize;
    let val = samples.split_off(cut.min(samples.len()));
    (samples, val)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::percentile;

    #[test]
    fn fits_exact_linear_ground_truth() {
        // If the world IS linear, the fit must be near-perfect.
        let mut samples = Vec::new();
        let mut rng = Pcg32::seeded(1);
        for _ in 0..400 {
            let (a, b, c, d) = (rng.f64(), rng.f64(), rng.f64(), rng.f64());
            samples.push(Sample {
                l2_m1: a, l2_m2: b, mem_m1: c, mem_m2: d,
                factor: 0.1 * a + 0.2 * b + 0.3 * c + 0.4 * d + 0.05,
            });
        }
        let m = InterferenceModel::fit(&samples).unwrap();
        for (i, want) in [0.1, 0.2, 0.3, 0.4, 0.05].iter().enumerate() {
            assert!((m.coef[i] - want).abs() < 1e-6, "c{}={}", i + 1, m.coef[i]);
        }
    }

    #[test]
    fn fig9_error_cdf_on_nonlinear_truth() {
        // The paper: 90% of validation cases within ~10.3% error, 95%
        // within ~14%. Our nonlinear ground truth should land in the
        // same regime for a linear fit.
        let gt = GroundTruth::default();
        let population = profiling_population(&gt);
        assert!(population.len() >= 2_500, "population {}", population.len());
        let (train, val) = train_val_split(population, 0.7, 42);
        let m = InterferenceModel::fit(&train).unwrap();
        let errs = m.validation_errors(&val);
        let p90 = percentile(&errs, 90.0);
        let p95 = percentile(&errs, 95.0);
        assert!(p90 < 0.20, "p90 error {p90}");
        assert!(p95 < 0.25, "p95 error {p95}");
    }

    #[test]
    fn predict_pair_uses_solo_profiles() {
        let gt = GroundTruth::default();
        let (train, _) = train_val_split(profiling_population(&gt), 0.7, 7);
        let m = InterferenceModel::fit(&train).unwrap();
        let heavy = m.predict_pair(ModelId::Vgg, 32, 0.5, ModelId::Vgg, 32, 0.5);
        let light = m.predict_pair(ModelId::Lenet, 1, 0.2, ModelId::Lenet, 1, 0.2);
        assert!(heavy > light, "heavy={heavy} light={light}");
        assert!(heavy > 0.05);
    }

    #[test]
    fn prediction_clamped_nonnegative() {
        let m = InterferenceModel { coef: [0.0, 0.0, 0.0, 0.0, -1.0] };
        assert_eq!(m.predict(0.5, 0.5, 0.5, 0.5), 0.0);
    }

    #[test]
    fn split_fractions() {
        let gt = GroundTruth::default();
        let pop = profiling_population(&gt);
        let n = pop.len();
        let (tr, va) = train_val_split(pop, 0.7, 3);
        assert_eq!(tr.len() + va.len(), n);
        assert!((tr.len() as f64 / n as f64 - 0.7).abs() < 0.01);
    }
}
