//! Interference modeling for consolidated executions (§3.2, §4.4).
//!
//! * `ground_truth` — the hidden, nonlinear contention behaviour of the
//!   simulated GPU (stands in for real-hardware measurements; shaped to
//!   reproduce the Fig 6 overhead CDF).
//! * `linear_model` — the paper's contribution: a 5-coefficient linear
//!   predictor over solo L2 / DRAM-bandwidth utilizations, fit by least
//!   squares (`linalg`), evaluated exactly like Fig 9.

pub mod ground_truth;
pub mod linalg;
pub mod linear_model;

pub use ground_truth::GroundTruth;
pub use linear_model::{InterferenceModel, Sample};
