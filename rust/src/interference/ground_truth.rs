//! Hidden ground-truth interference behaviour of the simulated GPU.
//!
//! Stands in for what the paper measures with Nsight on real hardware
//! (DESIGN.md §3): when two gpu-lets share a physical GPU, each task's
//! latency stretches by `1 + factor`, where `factor` depends on the
//! combined L2 and DRAM-bandwidth pressure. The function is deliberately
//! *nonlinear* (saturating capacity knees + a superlinear tail + stable
//! pair-specific residue), so the paper's linear estimator has a real
//! approximation error to measure (Fig 9), and the overhead CDF shows
//! Fig 6's modest-median / long-tail shape.
//!
//! Schedulers MUST NOT call this module directly — they only see the
//! fitted `linear_model`. Only the simulator (and the experiment
//! harnesses that play the role of "measurement") may query it.

use crate::models::ModelId;
use crate::util::rng::{fnv1a, splitmix64};

/// One co-resident task's solo resource demand (from `ModelProfile`).
#[derive(Clone, Copy, Debug)]
pub struct TaskDemand {
    pub model: ModelId,
    pub batch: u32,
    /// Solo L2 utilization at its partition (0..=1).
    pub l2: f64,
    /// Solo DRAM bandwidth utilization at its partition (0..=1).
    pub bw: f64,
}

/// Ground-truth interference generator.
#[derive(Clone, Debug)]
pub struct GroundTruth {
    /// Combined-L2 pressure knee (capacity fraction where contention starts).
    pub l2_knee: f64,
    /// Combined-bandwidth pressure knee.
    pub bw_knee: f64,
    /// Linear L2 contention weight.
    pub a_l2: f64,
    /// Linear bandwidth contention weight.
    pub a_bw: f64,
    /// Superlinear (tail) bandwidth weight.
    pub a_bw2: f64,
    /// Pair-noise amplitude (deterministic per (m1,b1,m2,b2) pair).
    pub noise: f64,
}

impl Default for GroundTruth {
    fn default() -> Self {
        // Calibrated against Fig 6: p50 ~ 5%, p90 ~ 18%, tail to ~60%.
        GroundTruth {
            l2_knee: 0.50,
            bw_knee: 0.45,
            a_l2: 0.22,
            a_bw: 0.32,
            a_bw2: 1.00,
            noise: 0.025,
        }
    }
}

impl GroundTruth {
    /// Latency-stretch factor suffered by `victim` while `aggressor` is
    /// co-resident on the same physical GPU. Returns `f >= 0`; the
    /// simulator applies latency `L * (1 + f)`.
    pub fn factor(&self, victim: &TaskDemand, aggressor: &TaskDemand) -> f64 {
        let l2_sum = victim.l2 + aggressor.l2;
        let bw_sum = victim.bw + aggressor.bw;
        let l2_over = (l2_sum - self.l2_knee).max(0.0);
        let bw_over = (bw_sum - self.bw_knee).max(0.0);

        // The victim suffers in proportion to how much of the contended
        // resource it needs itself.
        let l2_share = if l2_sum > 1e-12 { victim.l2 / l2_sum } else { 0.0 };
        let bw_share = if bw_sum > 1e-12 { victim.bw / bw_sum } else { 0.0 };

        let base = self.a_l2 * l2_over * (0.4 + 0.5 * l2_share)
            + self.a_bw * bw_over * (0.4 + 0.5 * bw_share)
            + self.a_bw2 * bw_over * bw_over;

        (base + self.pair_noise(victim, aggressor)).max(0.0)
    }

    /// Deterministic, zero-mean pair residue: stable across calls so the
    /// "measurement" experiments are reproducible, but invisible to the
    /// linear features — it bounds any estimator's accuracy like real
    /// microarchitectural noise would.
    fn pair_noise(&self, victim: &TaskDemand, aggressor: &TaskDemand) -> f64 {
        let key = format!(
            "{}:{}|{}:{}",
            victim.model.name(),
            victim.batch,
            aggressor.model.name(),
            aggressor.batch
        );
        let h = splitmix64(fnv1a(&key));
        // Map to [-1, 1).
        let u = (h >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0;
        self.noise * u
    }

    /// Convenience: symmetric pair factors `(f_victim1, f_victim2)`.
    pub fn pair_factors(&self, t1: &TaskDemand, t2: &TaskDemand) -> (f64, f64) {
        (self.factor(t1, t2), self.factor(t2, t1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{profile, ModelId};

    fn demand(m: ModelId, b: u32, p: f64) -> TaskDemand {
        let prof = profile(m);
        TaskDemand { model: m, batch: b, l2: prof.l2_util(p, b), bw: prof.bw_util(p, b) }
    }

    #[test]
    fn light_pairs_interfere_little() {
        let gt = GroundTruth::default();
        let a = demand(ModelId::Lenet, 1, 0.2);
        let b = demand(ModelId::Lenet, 1, 0.8);
        let (f1, f2) = gt.pair_factors(&a, &b);
        assert!(f1 < 0.06, "f1={f1}");
        assert!(f2 < 0.06, "f2={f2}");
    }

    #[test]
    fn heavy_pairs_interfere_a_lot() {
        let gt = GroundTruth::default();
        let a = demand(ModelId::Vgg, 32, 0.5);
        let b = demand(ModelId::Vgg, 32, 0.5);
        let f = gt.factor(&a, &b);
        assert!(f > 0.15, "vgg+vgg factor {f}");
    }

    #[test]
    fn factor_nonnegative_and_deterministic() {
        let gt = GroundTruth::default();
        for m1 in ModelId::ALL {
            for m2 in ModelId::ALL {
                let a = demand(m1, 8, 0.5);
                let b = demand(m2, 8, 0.5);
                let f = gt.factor(&a, &b);
                assert!(f >= 0.0);
                assert_eq!(f, gt.factor(&a, &b));
            }
        }
    }

    #[test]
    fn monotone_in_aggressor_pressure() {
        let gt = GroundTruth { noise: 0.0, ..Default::default() };
        let v = demand(ModelId::Resnet, 16, 0.5);
        let light = demand(ModelId::Lenet, 1, 0.2);
        let heavy = demand(ModelId::Vgg, 32, 0.8);
        assert!(gt.factor(&v, &heavy) >= gt.factor(&v, &light));
    }

    #[test]
    fn fig6_cdf_shape() {
        // Reproduce the Fig 6 population: 10 model pairs x 5 batches x 5
        // splits; check modest p90 and a long tail (paper: 90% < 18%).
        let gt = GroundTruth::default();
        let splits = [(0.2, 0.8), (0.4, 0.6), (0.5, 0.5), (0.6, 0.4), (0.8, 0.2)];
        let mut overheads = Vec::new();
        for (i, m1) in ModelId::ALL.iter().enumerate() {
            for m2 in &ModelId::ALL[i + 1..] {
                for &b in &[2u32, 4, 8, 16, 32] {
                    for &(p1, p2) in &splits {
                        let d1 = demand(*m1, b, p1);
                        let d2 = demand(*m2, b, p2);
                        let (f1, f2) = gt.pair_factors(&d1, &d2);
                        overheads.push(f1);
                        overheads.push(f2);
                    }
                }
            }
        }
        let p50 = crate::util::stats::percentile(&overheads, 50.0);
        let p90 = crate::util::stats::percentile(&overheads, 90.0);
        let max = overheads.iter().fold(0.0f64, |a, &b| a.max(b));
        assert!(p50 < 0.10, "p50={p50}");
        assert!((0.08..=0.30).contains(&p90), "p90={p90}");
        assert!(max > 0.25, "tail max={max}");
    }
}
