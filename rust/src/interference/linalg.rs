//! Dense linear algebra just big enough for ordinary least squares:
//! normal equations + Gaussian elimination with partial pivoting.

use crate::error::{Error, Result};

/// Solve `A x = b` for square `A` (row-major, n x n) in place.
pub fn solve(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Result<Vec<f64>> {
    let n = a.len();
    if n == 0 || a.iter().any(|row| row.len() != n) || b.len() != n {
        return Err(Error::Other("solve: non-square system".into()));
    }
    for col in 0..n {
        // Partial pivot.
        // `col..n` is nonempty (col < n), so max_by always yields a
        // pivot; total_cmp keeps the choice total even against NaN
        // input (the singularity check below still rejects it).
        let pivot = (col..n)
            .max_by(|&i, &j| a[i][col].abs().total_cmp(&a[j][col].abs()))
            .unwrap_or(col);
        if a[pivot][col].abs() < 1e-12 {
            return Err(Error::Other("solve: singular matrix".into()));
        }
        a.swap(col, pivot);
        b.swap(col, pivot);
        // Eliminate below.
        for row in col + 1..n {
            let f = a[row][col] / a[col][col];
            if f == 0.0 {
                continue;
            }
            for k in col..n {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    // Back substitution.
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut acc = b[row];
        for k in row + 1..n {
            acc -= a[row][k] * x[k];
        }
        x[row] = acc / a[row][row];
    }
    Ok(x)
}

/// Ordinary least squares: find beta minimizing ||X beta - y||^2 via the
/// normal equations X'X beta = X'y. `xs` rows are feature vectors.
pub fn least_squares(xs: &[Vec<f64>], y: &[f64]) -> Result<Vec<f64>> {
    if xs.is_empty() || xs.len() != y.len() {
        return Err(Error::Other("least_squares: empty or mismatched data".into()));
    }
    let d = xs[0].len();
    if xs.iter().any(|r| r.len() != d) {
        return Err(Error::Other("least_squares: ragged rows".into()));
    }
    // X'X (d x d) and X'y (d).
    let mut xtx = vec![vec![0.0; d]; d];
    let mut xty = vec![0.0; d];
    for (row, &yi) in xs.iter().zip(y) {
        for i in 0..d {
            xty[i] += row[i] * yi;
            for j in i..d {
                xtx[i][j] += row[i] * row[j];
            }
        }
    }
    for i in 0..d {
        for j in 0..i {
            xtx[i][j] = xtx[j][i];
        }
        // Tiny ridge for numerical safety on collinear features.
        xtx[i][i] += 1e-9;
    }
    solve(xtx, xty)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solves_known_system() {
        // x + 2y = 5; 3x - y = 1  =>  x = 1, y = 2
        let x = solve(vec![vec![1.0, 2.0], vec![3.0, -1.0]], vec![5.0, 1.0]).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-9);
        assert!((x[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn pivoting_handles_zero_leading() {
        // First pivot is zero; requires row swap.
        let x = solve(vec![vec![0.0, 1.0], vec![1.0, 0.0]], vec![3.0, 4.0]).unwrap();
        assert!((x[0] - 4.0).abs() < 1e-9);
        assert!((x[1] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn rejects_singular() {
        assert!(solve(vec![vec![1.0, 2.0], vec![2.0, 4.0]], vec![1.0, 2.0]).is_err());
    }

    #[test]
    fn least_squares_recovers_exact_linear_fn() {
        // y = 2*a - 3*b + 0.5 over a grid.
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..10 {
            for j in 0..10 {
                let (a, b) = (i as f64 / 10.0, j as f64 / 10.0);
                xs.push(vec![a, b, 1.0]);
                ys.push(2.0 * a - 3.0 * b + 0.5);
            }
        }
        let beta = least_squares(&xs, &ys).unwrap();
        assert!((beta[0] - 2.0).abs() < 1e-6);
        assert!((beta[1] + 3.0).abs() < 1e-6);
        assert!((beta[2] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn least_squares_minimizes_noisy_fit() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(5);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..500 {
            let a = rng.f64();
            xs.push(vec![a, 1.0]);
            ys.push(4.0 * a + 1.0 + rng.normal(0.0, 0.01));
        }
        let beta = least_squares(&xs, &ys).unwrap();
        assert!((beta[0] - 4.0).abs() < 0.05, "slope {}", beta[0]);
        assert!((beta[1] - 1.0).abs() < 0.05, "intercept {}", beta[1]);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(least_squares(&[], &[]).is_err());
        assert!(least_squares(&[vec![1.0]], &[1.0, 2.0]).is_err());
        assert!(least_squares(&[vec![1.0], vec![1.0, 2.0]], &[1.0, 2.0]).is_err());
    }
}
