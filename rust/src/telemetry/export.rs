//! Timeline exporters: Chrome trace-event JSON (`chrome://tracing` /
//! Perfetto loadable), the tidy per-window gauge CSV, and the text
//! summary behind `gpulets timeline`.
//!
//! Export runs once, after the sim — formatting here may allocate
//! freely; the hot-path constraints live in [`super::Tracer`].
//!
//! Chrome mapping: one *process* per node (`pid = node + 1`; the
//! router/fleet scope is `pid = 0`), one *thread* per gpu-let
//! (`tid = let + 1`; node/fleet-scoped markers land on `tid = 0`).
//! Batch executions become complete (`"ph":"X"`) slices by pairing
//! each `batch-start` with the next `batch-done` on the same
//! (node, gpu-let, model) — the engines retire batches FIFO per
//! assignment, so the pairing is exact. Everything else becomes an
//! instant (`"ph":"i"`). The exact event ledger, the sampling modulus
//! and the gauge windows ride along as extra top-level keys, which
//! Chrome ignores and `gpulets timeline` reads back.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::models::ModelId;
use crate::util::json::{obj, Json};

use super::{EventKind, Timeline, TraceEvent, WindowGauges, KINDS, NO_LET, NO_MODEL, NO_NODE};

fn model_name(idx: u8) -> &'static str {
    if (idx as usize) < ModelId::ALL.len() {
        ModelId::from_index(idx as usize).name()
    } else {
        "-"
    }
}

/// One event as a flat JSON object (the JSONL wire form). Sentinel
/// fields are omitted.
pub fn event_json(ev: &TraceEvent) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![
        ("t_us", Json::Num(ev.t_us as f64)),
        ("kind", Json::Str(ev.kind.name().to_string())),
        ("epoch", Json::Num(ev.epoch as f64)),
        ("id", Json::Num(ev.id as f64)),
        ("n", Json::Num(ev.n as f64)),
    ];
    if ev.node != NO_NODE {
        fields.push(("node", Json::Num(ev.node as f64)));
    }
    if ev.let_idx != NO_LET {
        fields.push(("let", Json::Num(ev.let_idx as f64)));
    }
    if ev.model != NO_MODEL {
        fields.push(("model", Json::Str(model_name(ev.model).to_string())));
    }
    obj(fields)
}

/// The exact event ledger as a JSON object (kind name → count).
pub fn ledger_json(counts: &[u64; KINDS]) -> Json {
    obj(EventKind::ALL
        .iter()
        .map(|k| (k.name(), Json::Num(counts[*k as usize] as f64)))
        .collect())
}

fn pid_of(node: u32) -> f64 {
    if node == NO_NODE {
        0.0
    } else {
        node as f64 + 1.0
    }
}

fn tid_of(let_idx: u32) -> f64 {
    if let_idx == NO_LET {
        0.0
    } else {
        let_idx as f64 + 1.0
    }
}

fn meta_event(pid: f64, tid: Option<f64>, what: &str, name: String) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Num(pid)),
        ("name", Json::Str(what.to_string())),
        ("args", obj(vec![("name", Json::Str(name))])),
    ];
    if let Some(tid) = tid {
        fields.push(("tid", Json::Num(tid)));
    }
    obj(fields)
}

fn instant(ev: &TraceEvent) -> Json {
    obj(vec![
        ("name", Json::Str(ev.kind.name().to_string())),
        ("cat", Json::Str(category(ev.kind).to_string())),
        ("ph", Json::Str("i".to_string())),
        ("s", Json::Str("p".to_string())),
        ("ts", Json::Num(ev.t_us as f64)),
        ("pid", Json::Num(pid_of(ev.node))),
        ("tid", Json::Num(tid_of(ev.let_idx))),
        ("args", instant_args(ev)),
    ])
}

fn instant_args(ev: &TraceEvent) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![
        ("epoch", Json::Num(ev.epoch as f64)),
        ("n", Json::Num(ev.n as f64)),
    ];
    match ev.kind {
        EventKind::NodeDown | EventKind::NodeUp | EventKind::Rebalance | EventKind::ReplanFailed => {
            fields.push(("node", Json::Num(ev.id as f64)));
        }
        _ => {
            if ev.model != NO_MODEL {
                fields.push(("model", Json::Str(model_name(ev.model).to_string())));
            }
            fields.push(("id", Json::Num(ev.id as f64)));
        }
    }
    obj(fields)
}

fn category(kind: EventKind) -> &'static str {
    match kind {
        EventKind::Arrival | EventKind::Admit | EventKind::Shed | EventKind::Degrade | EventKind::Deal => "gate",
        EventKind::Enqueue | EventKind::Drop | EventKind::Timeout => "queue",
        EventKind::BatchForm | EventKind::BatchStart | EventKind::BatchDone => "batch",
        EventKind::Lost | EventKind::NodeDown | EventKind::NodeUp => "fault",
        EventKind::Swap | EventKind::ReplanFailed | EventKind::Rebalance => "plan",
    }
}

/// Render a [`Timeline`] as a Chrome trace-event JSON document.
pub fn chrome_trace(tl: &Timeline) -> Json {
    let mut events: Vec<Json> = Vec::new();

    // Track naming: every (pid) and (pid, tid) seen in the stream.
    let mut pids: BTreeMap<u64, ()> = BTreeMap::new();
    let mut tids: BTreeMap<(u64, u64), ()> = BTreeMap::new();
    for ev in &tl.events {
        pids.insert(pid_of(ev.node) as u64, ());
        tids.insert((pid_of(ev.node) as u64, tid_of(ev.let_idx) as u64), ());
    }
    for pid in pids.keys() {
        let name = if *pid == 0 { "fleet/router".to_string() } else { format!("node {}", pid - 1) };
        events.push(meta_event(*pid as f64, None, "process_name", name));
    }
    for (pid, tid) in tids.keys() {
        let name = if *tid == 0 { "control".to_string() } else { format!("gpu-let {}", tid - 1) };
        events.push(meta_event(*pid as f64, Some(*tid as f64), "thread_name", name));
    }

    // FIFO pairing of batch-start → batch-done per (node, let, model).
    let mut open: BTreeMap<(u32, u32, u8), Vec<&TraceEvent>> = BTreeMap::new();
    let mut slice = |start: &TraceEvent, end_us: u64, closed: bool| -> Json {
        obj(vec![
            ("name", Json::Str(format!("{}\u{00d7}{}", model_name(start.model), start.n))),
            ("cat", Json::Str("batch".to_string())),
            ("ph", Json::Str("X".to_string())),
            ("ts", Json::Num(start.t_us as f64)),
            ("dur", Json::Num(end_us.saturating_sub(start.t_us) as f64)),
            ("pid", Json::Num(pid_of(start.node))),
            ("tid", Json::Num(tid_of(start.let_idx))),
            ("args", obj(vec![
                ("model", Json::Str(model_name(start.model).to_string())),
                ("size", Json::Num(start.n as f64)),
                ("epoch", Json::Num(start.epoch as f64)),
                ("closed", Json::Bool(closed)),
            ])),
        ])
    };
    let mut last_t = 0u64;
    for ev in &tl.events {
        last_t = last_t.max(ev.t_us);
        let key = (ev.node, ev.let_idx, ev.model);
        match ev.kind {
            EventKind::BatchStart => open.entry(key).or_default().push(ev),
            EventKind::BatchDone => {
                let started = open.get_mut(&key).filter(|q| !q.is_empty()).map(|q| q.remove(0));
                match started {
                    Some(start) => events.push(slice(start, ev.t_us, true)),
                    // A done without a start (ring overwrote it):
                    // keep it visible as an instant.
                    None => events.push(instant(ev)),
                }
            }
            _ => events.push(instant(ev)),
        }
    }
    // Batches still open at the end of the trace (lost to a node
    // failure, or cut off by the horizon): zero-length open slices.
    for starts in open.values() {
        for start in starts {
            events.push(slice(start, last_t, false));
        }
    }

    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
        ("ledger", ledger_json(&tl.counts)),
        ("sample_n", Json::Num(tl.sample_n.max(1) as f64)),
        ("dropped_events", Json::Num(tl.dropped_events as f64)),
        ("gauges", Json::Arr(tl.windows.iter().map(window_json).collect())),
    ])
}

fn window_json(w: &WindowGauges) -> Json {
    let nodes: Vec<Json> = w
        .nodes
        .iter()
        .map(|n| {
            let queues: Vec<Json> = n
                .queues
                .iter()
                .map(|q| {
                    obj(vec![
                        ("let", Json::Num(q.let_idx as f64)),
                        ("model", Json::Str(model_name(q.model).to_string())),
                        ("depth", Json::Num(q.depth as f64)),
                    ])
                })
                .collect();
            obj(vec![
                ("node", Json::Num(n.node as f64)),
                ("alive", Json::Bool(n.alive)),
                ("in_flight", Json::Num(n.in_flight as f64)),
                ("util", Json::Num(n.util)),
                ("queues", Json::Arr(queues)),
            ])
        })
        .collect();
    obj(vec![
        ("t_s", Json::Num(w.t_s)),
        ("alive", Json::Num(w.alive as f64)),
        ("deals", Json::Arr(w.deals.iter().map(|&d| Json::Num(d as f64)).collect())),
        ("admit_frac", Json::Arr(w.admit_frac.iter().map(|&f| Json::Num(f)).collect())),
        ("nodes", Json::Arr(nodes)),
    ])
}

/// Tidy (long-format) CSV of the per-window gauge series:
/// `t_s,gauge,node,let,model,value` — one observation per row, empty
/// fields where a dimension does not apply.
pub fn gauges_csv(tl: &Timeline) -> String {
    let mut out = String::from("t_s,gauge,node,let,model,value\n");
    for w in &tl.windows {
        let _ = writeln!(out, "{:.3},alive_nodes,,,,{}", w.t_s, w.alive);
        for m in ModelId::ALL {
            let i = m.index();
            let _ = writeln!(out, "{:.3},deals,,,{},{}", w.t_s, m.name(), w.deals[i]);
            let _ = writeln!(out, "{:.3},admit_frac,,,{},{:.6}", w.t_s, m.name(), w.admit_frac[i]);
        }
        for n in &w.nodes {
            let _ = writeln!(out, "{:.3},in_flight,{},,,{}", w.t_s, n.node, n.in_flight);
            let _ = writeln!(out, "{:.3},util,{},,,{:.6}", w.t_s, n.node, n.util);
            for q in &n.queues {
                let _ = writeln!(
                    out,
                    "{:.3},queue_depth,{},{},{},{}",
                    w.t_s,
                    n.node,
                    q.let_idx,
                    model_name(q.model),
                    q.depth
                );
            }
        }
    }
    out
}

/// Replay a saved Chrome-trace document (the [`chrome_trace`] shape)
/// into a text summary: the event ledger, per-track batch statistics,
/// and the fault/plan marker timeline. This is `gpulets timeline`.
pub fn summarize(doc: &Json) -> crate::error::Result<String> {
    let events = doc
        .get("traceEvents")
        .map_err(|_| crate::error::Error::parse("not a trace file: no traceEvents key"))?
        .as_arr()?;
    let mut out = String::new();

    // Ledger first — the exact counts, independent of sampling.
    if let Some(ledger) = doc.opt("ledger") {
        out.push_str("event ledger (exact, pre-sampling):\n");
        for k in EventKind::ALL {
            if let Some(c) = ledger.opt(k.name()).and_then(|v| v.as_f64().ok()) {
                if c > 0.0 {
                    let _ = writeln!(out, "  {:<16} {:>10}", k.name(), c as u64);
                }
            }
        }
    }
    if let Some(n) = doc.opt("sample_n").and_then(|v| v.as_f64().ok()) {
        let _ = writeln!(out, "span sampling: 1/{}", n as u64);
    }
    if let Some(d) = doc.opt("dropped_events").and_then(|v| v.as_f64().ok()) {
        if d > 0.0 {
            let _ = writeln!(out, "WARNING: ring overflow dropped {} events", d as u64);
        }
    }

    // Per-track batch stats and the marker timeline.
    #[derive(Default)]
    struct Track {
        batches: u64,
        reqs: u64,
        busy_us: f64,
        t_max: f64,
    }
    let mut names: BTreeMap<(u64, u64), String> = BTreeMap::new();
    let mut procs: BTreeMap<u64, String> = BTreeMap::new();
    let mut tracks: BTreeMap<(u64, u64), Track> = BTreeMap::new();
    let mut markers: Vec<(f64, String)> = Vec::new();
    let mut instants = 0u64;
    for ev in events {
        let ph = ev.opt("ph").and_then(|p| p.as_str().ok()).unwrap_or("");
        let pid = ev.opt("pid").and_then(|p| p.as_f64().ok()).unwrap_or(0.0) as u64;
        let tid = ev.opt("tid").and_then(|p| p.as_f64().ok()).unwrap_or(0.0) as u64;
        let name = ev.opt("name").and_then(|p| p.as_str().ok()).unwrap_or("");
        match ph {
            "M" => {
                let label = ev
                    .opt("args")
                    .and_then(|a| a.opt("name"))
                    .and_then(|n| n.as_str().ok())
                    .unwrap_or("")
                    .to_string();
                if name == "process_name" {
                    procs.insert(pid, label);
                } else if name == "thread_name" {
                    names.insert((pid, tid), label);
                }
            }
            "X" => {
                let ts = ev.opt("ts").and_then(|p| p.as_f64().ok()).unwrap_or(0.0);
                let dur = ev.opt("dur").and_then(|p| p.as_f64().ok()).unwrap_or(0.0);
                let size = ev
                    .opt("args")
                    .and_then(|a| a.opt("size"))
                    .and_then(|s| s.as_f64().ok())
                    .unwrap_or(0.0);
                let t = tracks.entry((pid, tid)).or_default();
                t.batches += 1;
                t.reqs += size as u64;
                t.busy_us += dur;
                t.t_max = t.t_max.max(ts + dur);
            }
            "i" => {
                instants += 1;
                let cat = ev.opt("cat").and_then(|c| c.as_str().ok()).unwrap_or("");
                if cat == "fault" || cat == "plan" {
                    let ts = ev.opt("ts").and_then(|p| p.as_f64().ok()).unwrap_or(0.0);
                    let node = ev
                        .opt("args")
                        .and_then(|a| a.opt("node"))
                        .and_then(|n| n.as_f64().ok());
                    let who = match node {
                        Some(n) => format!("{name} node {}", n as u64),
                        None => name.to_string(),
                    };
                    markers.push((ts, who));
                }
            }
            _ => {}
        }
    }

    if !tracks.is_empty() {
        out.push_str("\nper-track batch execution:\n");
        let _ = writeln!(
            out,
            "  {:<14} {:<10} {:>8} {:>10} {:>12} {:>7}",
            "process", "track", "batches", "requests", "busy ms", "busy%"
        );
        for ((pid, tid), t) in &tracks {
            let pname = procs.get(pid).cloned().unwrap_or_else(|| format!("pid {pid}"));
            let tname = names.get(&(*pid, *tid)).cloned().unwrap_or_else(|| format!("tid {tid}"));
            let busy_pct = if t.t_max > 0.0 { 100.0 * t.busy_us / t.t_max } else { 0.0 };
            let _ = writeln!(
                out,
                "  {:<14} {:<10} {:>8} {:>10} {:>12.1} {:>6.1}%",
                pname,
                tname,
                t.batches,
                t.reqs,
                t.busy_us / 1000.0,
                busy_pct
            );
        }
    }
    let _ = writeln!(out, "\n{} instant event(s) in the stream", instants);

    markers.sort_by(|a, b| a.0.total_cmp(&b.0));
    if !markers.is_empty() {
        out.push_str("fault / plan timeline:\n");
        for (ts, who) in &markers {
            let _ = writeln!(out, "  {:>10.1} ms  {}", ts / 1000.0, who);
        }
    }
    if let Some(gauges) = doc.opt("gauges").and_then(|g| g.as_arr().ok()) {
        let _ = writeln!(out, "{} gauge window(s) recorded (export CSV with --gauges)", gauges.len());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Tracer;

    fn demo_timeline() -> Timeline {
        let mut t = Tracer::new(0, 1 << 10, 1);
        t.span(100, EventKind::Enqueue, 2, ModelId::Resnet, 1, 7);
        t.batch(200, EventKind::BatchStart, 2, ModelId::Resnet, 1, 0, 8);
        t.batch(900, EventKind::BatchDone, 2, ModelId::Resnet, 1, 0, 8);
        t.batch(950, EventKind::BatchStart, 2, ModelId::Resnet, 1, 1, 4);
        t.mark(1000, EventKind::NodeDown, 1, 0, 1);
        let mut f = Tracer::new(NO_NODE, 1 << 10, 1);
        f.mark(1500, EventKind::Rebalance, 2, 0, 1);
        let mut tl = Timeline { sample_n: 1, ..Default::default() };
        f.drain_into(&mut tl);
        t.drain_into(&mut tl);
        tl.sort_events();
        tl.windows.push(WindowGauges {
            t_s: 2.0,
            alive: 1,
            deals: [3, 0, 5, 0, 0],
            admit_frac: [1.0; 5],
            nodes: vec![super::super::NodeGauges {
                node: 0,
                alive: true,
                in_flight: 1,
                util: 0.5,
                queues: vec![super::super::LetQueueGauge { let_idx: 2, model: 2, depth: 4 }],
            }],
        });
        tl
    }

    #[test]
    fn chrome_trace_pairs_batches_and_parses_back() {
        let tl = demo_timeline();
        let doc = chrome_trace(&tl);
        let parsed = Json::parse(&doc.to_string()).expect("chrome doc parses");
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // One closed X slice (200..900) and one open X slice.
        let slices: Vec<&Json> = events
            .iter()
            .filter(|e| e.opt("ph").and_then(|p| p.as_str().ok()) == Some("X"))
            .collect();
        assert_eq!(slices.len(), 2, "{doc}");
        let closed = slices
            .iter()
            .find(|s| {
                s.opt("args").and_then(|a| a.opt("closed")).and_then(|c| c.as_bool().ok())
                    == Some(true)
            })
            .expect("closed slice");
        assert_eq!(closed.get("ts").unwrap().as_f64().unwrap(), 200.0);
        assert_eq!(closed.get("dur").unwrap().as_f64().unwrap(), 700.0);
        // Ledger rode along and reconciles with the tracer counts.
        let ledger = parsed.get("ledger").unwrap();
        assert_eq!(ledger.get("enqueue").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(ledger.get("batch-done").unwrap().as_f64().unwrap(), 8.0);
        // Process/thread naming metadata present.
        assert!(doc.to_string().contains("gpu-let 2"));
        assert!(doc.to_string().contains("fleet/router"));
    }

    #[test]
    fn summary_reads_its_own_export() {
        let tl = demo_timeline();
        let doc = chrome_trace(&tl);
        let text = summarize(&doc).expect("summarize own export");
        assert!(text.contains("event ledger"), "{text}");
        assert!(text.contains("node-down"), "{text}");
        assert!(text.contains("rebalance"), "{text}");
        assert!(text.contains("batches"), "{text}");
        assert!(text.contains("1 gauge window"), "{text}");
        // Not a trace file → proper error, not a panic.
        assert!(summarize(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn gauge_csv_is_tidy_and_complete() {
        let tl = demo_timeline();
        let csv = gauges_csv(&tl);
        let mut lines = csv.lines();
        assert_eq!(lines.next(), Some("t_s,gauge,node,let,model,value"));
        assert!(csv.contains("2.000,alive_nodes,,,,1"), "{csv}");
        assert!(csv.contains("2.000,queue_depth,0,2,resnet,4"), "{csv}");
        assert!(csv.contains("2.000,deals,,,lenet,3"), "{csv}");
        assert!(csv.contains("2.000,in_flight,0,,,1"), "{csv}");
        // Every row has exactly 5 commas (6 columns).
        for line in csv.lines() {
            assert_eq!(line.matches(',').count(), 5, "{line}");
        }
    }

    #[test]
    fn event_json_omits_sentinels() {
        let ev = TraceEvent {
            t_us: 9,
            kind: EventKind::Swap,
            node: 3,
            let_idx: NO_LET,
            model: NO_MODEL,
            epoch: 2,
            id: 0,
            n: 1,
        };
        let s = event_json(&ev).to_string();
        assert!(s.contains("\"node\""), "{s}");
        assert!(!s.contains("\"let\""), "{s}");
        assert!(!s.contains("\"model\""), "{s}");
    }
}
