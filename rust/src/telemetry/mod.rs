//! Deterministic, sim-time-stamped telemetry (DESIGN.md §13).
//!
//! The serving stack's central claim — bounded latency under SLO
//! constraints while gpu-lets are repartitioned — is only debuggable
//! with per-request and per-window visibility. This module is that
//! layer: typed lifecycle events ([`TraceEvent`]) recorded through a
//! [`TraceSink`] by the engines (`coordinator::engine`,
//! `fleet::router`, `fleet::engine`), per-window gauge series
//! ([`WindowGauges`]) snapshotted at lockstep boundaries, and a merged
//! [`Timeline`] appended to `FleetOutcome` that the exporters in
//! [`export`] turn into a Chrome-trace JSON or a tidy gauge CSV.
//!
//! Design constraints, in order:
//!
//! 1. **Sim time only.** Every timestamp is the integer-µs sim clock
//!    (`simclock::SimTimeUs`). Wall clocks are banned from the serving
//!    layers by the `no-wall-clock` lint rule, so telemetry can never
//!    silently drift from the clock the SLO accounting uses.
//! 2. **Free when off.** A disabled [`Tracer`] costs one predictable
//!    branch per hook and allocates nothing — the PR 7 `// lint:
//!    no-alloc` hot-loop regions hold with the hooks inlined, and
//!    `benches/trace_overhead.rs` pins the throughput claim.
//! 3. **Deterministic across thread counts.** Each node engine records
//!    into its *own* ring; the fleet merges the per-node buffers in
//!    node order and stable-sorts by timestamp, so the merged event
//!    stream is a pure function of (seed, plan, fault script) — byte
//!    identical for any `util::par` worker count.
//! 4. **Sampling without RNG.** Request spans are kept when
//!    `splitmix64(request id) % sample_n == 0`. The id is assigned by
//!    the arrival mux in merged order (a deterministic function of the
//!    per-stream draws), so the sampled subset is the same on every
//!    run and every thread count — no RNG state, no coordination.
//!
//! Ledger invariant: [`Tracer::emit`] counts every *logical* event
//! (weight `n`) before sampling drops any span, so
//! [`Timeline::counts`] reconciles exactly with the run's
//! `FleetOutcome` counters even under heavy sampling; only the
//! materialized event list thins out.

pub mod export;

use crate::models::ModelId;
use crate::simclock::SimTimeUs;

/// Sentinel: event not attributed to a node (router / fleet scope).
pub const NO_NODE: u32 = u32::MAX;
/// Sentinel: event not attributed to a gpu-let.
pub const NO_LET: u32 = u32::MAX;
/// Sentinel: event not attributed to a model.
pub const NO_MODEL: u8 = u8::MAX;

/// Number of event kinds (the size of a ledger array).
pub const KINDS: usize = 17;

/// Typed lifecycle event kinds — the full catalog (DESIGN.md §13).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum EventKind {
    /// A request entered the system (router demand, or engine injection
    /// when no router is in front).
    Arrival = 0,
    /// Admission gate verdict: admitted as-is.
    Admit,
    /// Admission gate verdict: shed (rejected up front).
    Shed,
    /// Admission gate verdict: degraded to a cheaper fallback model
    /// (`model` is the original; the follow-up `Deal` with the same id
    /// carries the fallback).
    Degrade,
    /// Router dealt the request to `node`.
    Deal,
    /// Engine accepted the request into a (gpu-let, model) queue.
    Enqueue,
    /// A batch was formed from queue heads (`n` = batch size).
    BatchForm,
    /// A batch began executing on its gpu-let (`n` = batch size).
    BatchStart,
    /// A batch retired (`n` = batch size).
    BatchDone,
    /// A request was dropped (no route for its model, or engine close).
    Drop,
    /// A request was dropped because its deadline became hopeless.
    Timeout,
    /// Work destroyed by a node failure (`n` = requests lost).
    Lost,
    /// An epoch-tagged schedule swap on a node (`epoch` = new epoch).
    Swap,
    /// A node was killed at a lockstep boundary.
    NodeDown,
    /// A node recovered at a lockstep boundary.
    NodeUp,
    /// A failover / rebalance re-plan came back infeasible; the fleet
    /// kept the current plan (was an `eprintln!` before PR 10).
    ReplanFailed,
    /// The fleet re-planned from observed rates and retargeted routing.
    Rebalance,
}

impl EventKind {
    /// Every kind, in ledger order.
    pub const ALL: [EventKind; KINDS] = [
        EventKind::Arrival,
        EventKind::Admit,
        EventKind::Shed,
        EventKind::Degrade,
        EventKind::Deal,
        EventKind::Enqueue,
        EventKind::BatchForm,
        EventKind::BatchStart,
        EventKind::BatchDone,
        EventKind::Drop,
        EventKind::Timeout,
        EventKind::Lost,
        EventKind::Swap,
        EventKind::NodeDown,
        EventKind::NodeUp,
        EventKind::ReplanFailed,
        EventKind::Rebalance,
    ];

    /// Stable wire name (ledger keys, Chrome-trace `name`/`cat`).
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Arrival => "arrival",
            EventKind::Admit => "admit",
            EventKind::Shed => "shed",
            EventKind::Degrade => "degrade",
            EventKind::Deal => "deal",
            EventKind::Enqueue => "enqueue",
            EventKind::BatchForm => "batch-form",
            EventKind::BatchStart => "batch-start",
            EventKind::BatchDone => "batch-done",
            EventKind::Drop => "drop",
            EventKind::Timeout => "timeout",
            EventKind::Lost => "lost_to_failure",
            EventKind::Swap => "swap",
            EventKind::NodeDown => "node-down",
            EventKind::NodeUp => "node-up",
            EventKind::ReplanFailed => "replan-failed",
            EventKind::Rebalance => "rebalance",
        }
    }

    /// Per-request span events — the kinds the deterministic sampler
    /// may thin out. Batch, fault and plan events are always kept
    /// (their volume is bounded by batches/windows, not requests).
    pub fn per_request(self) -> bool {
        matches!(
            self,
            EventKind::Arrival
                | EventKind::Admit
                | EventKind::Shed
                | EventKind::Degrade
                | EventKind::Deal
                | EventKind::Enqueue
                | EventKind::Drop
                | EventKind::Timeout
        )
    }
}

/// One telemetry event: fixed-size, `Copy`, allocation-free to record.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    /// Integer-µs sim time.
    pub t_us: SimTimeUs,
    pub kind: EventKind,
    /// Node index, or [`NO_NODE`] for router/fleet scope.
    pub node: u32,
    /// Gpu-let index on the node, or [`NO_LET`].
    pub let_idx: u32,
    /// `ModelId::index()`, or [`NO_MODEL`].
    pub model: u8,
    /// Schedule epoch the event happened under.
    pub epoch: u32,
    /// Request id for span events (the sampling key); batch/fault
    /// events use it for the secondary subject (a batch's head request,
    /// the node a fault hits). A degraded request keeps its id, so the
    /// Degrade event (original model) and the follow-up Deal (fallback
    /// model) correlate.
    pub id: u64,
    /// Event weight: batch size, requests lost, or 1.
    pub n: u32,
}

/// splitmix64 finalizer — the sampling hash. Stateless and exact, so
/// span selection is a pure function of the request id.
#[inline]
pub fn hash64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    x
}

/// Keep the span for `id` at sampling modulus `sample_n`?
/// `sample_n <= 1` keeps everything.
#[inline]
pub fn span_sampled(id: u64, sample_n: u64) -> bool {
    sample_n <= 1 || hash64(id) % sample_n == 0
}

/// Where recorded events go. The engines hold a concrete
/// [`Tracer`]-over-[`RingSink`] (hot path); export-time consumers
/// implement the trait to stream a finished timeline elsewhere
/// ([`JsonLinesSink`]).
pub trait TraceSink {
    fn record(&mut self, ev: &TraceEvent);
}

/// Bounded ring-buffer sink. Grows lazily up to `cap`, then overwrites
/// the oldest event (and counts the overwrites), so a runaway trace
/// degrades to "most recent window" instead of unbounded memory.
#[derive(Clone, Debug, Default)]
pub struct RingSink {
    buf: Vec<TraceEvent>,
    cap: usize,
    /// Next overwrite position once full (oldest event).
    head: usize,
    overwritten: u64,
}

impl RingSink {
    pub fn new(cap: usize) -> RingSink {
        RingSink { buf: Vec::new(), cap, head: 0, overwritten: 0 }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events this ring discarded (overwrote) after filling up.
    pub fn overwritten(&self) -> u64 {
        self.overwritten
    }

    /// Append the ring's events, oldest first, to `out`, leaving the
    /// ring empty.
    pub fn drain_ordered(&mut self, out: &mut Vec<TraceEvent>) {
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        self.buf.clear();
        self.head = 0;
    }
}

impl TraceSink for RingSink {
    #[inline]
    fn record(&mut self, ev: &TraceEvent) {
        if self.cap == 0 {
            return;
        }
        if self.buf.len() < self.cap {
            self.buf.push(*ev);
        } else {
            self.buf[self.head] = *ev;
            self.head += 1;
            if self.head == self.cap {
                self.head = 0;
            }
            self.overwritten += 1;
        }
    }
}

/// Streaming sink: one compact JSON object per event per line
/// (JSONL). Used at export time (`Timeline::stream_to`), never on the
/// sim hot path — formatting allocates.
pub struct JsonLinesSink<W: std::io::Write> {
    w: W,
    pub errored: bool,
}

impl<W: std::io::Write> JsonLinesSink<W> {
    pub fn new(w: W) -> Self {
        JsonLinesSink { w, errored: false }
    }

    pub fn into_inner(self) -> W {
        self.w
    }
}

impl<W: std::io::Write> TraceSink for JsonLinesSink<W> {
    fn record(&mut self, ev: &TraceEvent) {
        if self.errored {
            return;
        }
        if writeln!(self.w, "{}", export::event_json(ev)).is_err() {
            self.errored = true;
        }
    }
}

/// The recorder the engines own: enabled flag + deterministic span
/// sampler + exact ledger + bounded ring. All owned data (`Send`), one
/// per node so parallel advance never shares a sink.
#[derive(Clone, Debug, Default)]
pub struct Tracer {
    enabled: bool,
    /// Span-sampling modulus (1 = keep every span).
    sample_n: u64,
    /// Node index stamped on every event ([`NO_NODE`] for fleet scope).
    node: u32,
    /// Exact per-kind ledger, weighted by `TraceEvent::n`, counted
    /// before sampling.
    counts: [u64; KINDS],
    ring: RingSink,
}

impl Tracer {
    /// A disabled tracer: every hook is a single-branch no-op and
    /// nothing is ever allocated. This is the engines' default.
    pub fn off() -> Tracer {
        Tracer { enabled: false, sample_n: 1, node: NO_NODE, counts: [0; KINDS], ring: RingSink::new(0) }
    }

    /// An enabled tracer recording up to `cap` events for `node`,
    /// keeping request spans at modulus `sample_n`.
    pub fn new(node: u32, cap: usize, sample_n: u64) -> Tracer {
        Tracer {
            enabled: true,
            sample_n: sample_n.max(1),
            node,
            counts: [0; KINDS],
            ring: RingSink::new(cap),
        }
    }

    /// A fresh tracer with this tracer's configuration (same
    /// enabled/node/sampling, empty ring and ledger) — what an engine
    /// `reset` re-arms so a reset run records from scratch.
    pub fn fresh(&self) -> Tracer {
        if self.enabled {
            Tracer::new(self.node, self.ring.cap, self.sample_n)
        } else {
            Tracer::off()
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn node(&self) -> u32 {
        self.node
    }

    pub fn sample_n(&self) -> u64 {
        self.sample_n
    }

    /// Exact ledger count for one kind (pre-sampling, `n`-weighted).
    pub fn count(&self, kind: EventKind) -> u64 {
        self.counts[kind as usize]
    }

    /// Record one event. Counts it exactly, then keeps or thins it by
    /// the span sampler. The disabled path is the first branch.
    #[inline]
    pub fn emit(&mut self, ev: TraceEvent) {
        if !self.enabled {
            return;
        }
        self.counts[ev.kind as usize] += ev.n as u64;
        if ev.kind.per_request() && !span_sampled(ev.id, self.sample_n) {
            return;
        }
        self.ring.record(&ev);
    }

    /// Hook: per-request span event (weight 1).
    #[inline]
    pub fn span(&mut self, t_us: SimTimeUs, kind: EventKind, let_idx: u32, model: ModelId, epoch: u32, id: u64) {
        if !self.enabled {
            return;
        }
        self.emit(TraceEvent { t_us, kind, node: self.node, let_idx, model: model.index() as u8, epoch, id, n: 1 });
    }

    /// Hook: batch-scoped event (`n` = batch size / request count).
    #[inline]
    pub fn batch(&mut self, t_us: SimTimeUs, kind: EventKind, let_idx: u32, model: ModelId, epoch: u32, id: u64, n: u32) {
        if !self.enabled {
            return;
        }
        self.emit(TraceEvent { t_us, kind, node: self.node, let_idx, model: model.index() as u8, epoch, id, n });
    }

    /// Hook: node/fleet-scoped marker (swap, fault, re-plan).
    #[inline]
    pub fn mark(&mut self, t_us: SimTimeUs, kind: EventKind, epoch: u32, id: u64, n: u32) {
        if !self.enabled {
            return;
        }
        self.emit(TraceEvent { t_us, kind, node: self.node, let_idx: NO_LET, model: NO_MODEL, epoch, id, n });
    }

    /// Move this tracer's events and counts into `tl`, leaving the
    /// tracer empty (but still enabled). Called serially at merge
    /// points, in node order, so the result is thread-count invariant.
    pub fn drain_into(&mut self, tl: &mut Timeline) {
        if !self.enabled {
            return;
        }
        tl.dropped_events += self.ring.overwritten;
        self.ring.overwritten = 0;
        self.ring.drain_ordered(&mut tl.events);
        for k in 0..KINDS {
            tl.counts[k] += self.counts[k];
            self.counts[k] = 0;
        }
    }
}

/// Queue depth of one (gpu-let, model) pair at a window boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LetQueueGauge {
    pub let_idx: u32,
    /// `ModelId::index()` of the queue's model.
    pub model: u8,
    pub depth: u32,
}

/// One node's gauges at a window boundary.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct NodeGauges {
    pub node: u32,
    pub alive: bool,
    /// Batches currently executing on the node.
    pub in_flight: u64,
    /// Share of assignments mid-batch — the duty-cycle utilization
    /// proxy at the boundary instant.
    pub util: f64,
    /// Per-(gpu-let, model) queue depths (every assignment, zero
    /// included, in arena order — deterministic).
    pub queues: Vec<LetQueueGauge>,
}

/// Fleet-wide gauges snapshotted at one lockstep boundary.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WindowGauges {
    /// Window end (s).
    pub t_s: f64,
    /// Nodes alive at the boundary.
    pub alive: u32,
    /// Router deals this window, per model.
    pub deals: [u64; 5],
    /// Admission-gate admitted fraction this window, per model
    /// (1.0 when the gate is off or the model saw no demand).
    pub admit_frac: [f64; 5],
    pub nodes: Vec<NodeGauges>,
}

/// The merged observability record of one run: time-ordered events,
/// the exact event ledger, and the per-window gauge series. Appended
/// to `FleetOutcome`; exporters live in [`export`].
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    /// Merged events, stable-sorted by `t_us` (per-source order kept).
    pub events: Vec<TraceEvent>,
    /// Exact per-kind ledger (pre-sampling, `n`-weighted).
    pub counts: [u64; KINDS],
    pub windows: Vec<WindowGauges>,
    /// Events the bounded rings overwrote (0 = the event list is
    /// complete at the configured sampling).
    pub dropped_events: u64,
    /// Span-sampling modulus the run recorded at.
    pub sample_n: u64,
}

impl Timeline {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty() && self.windows.is_empty() && self.counts == [0; KINDS]
    }

    pub fn count(&self, kind: EventKind) -> u64 {
        self.counts[kind as usize]
    }

    /// Stable-sort the merged events by timestamp. Sources are drained
    /// in a fixed order (router first, then nodes ascending), so ties
    /// resolve deterministically regardless of worker threads.
    pub fn sort_events(&mut self) {
        self.events.sort_by_key(|e| e.t_us);
    }

    /// Replay every event into a sink (e.g. a [`JsonLinesSink`]).
    pub fn stream_to(&self, sink: &mut dyn TraceSink) {
        for ev in &self.events {
            sink.record(ev);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t_us: u64, kind: EventKind, id: u64) -> TraceEvent {
        TraceEvent { t_us, kind, node: 0, let_idx: 1, model: 0, epoch: 0, id, n: 1 }
    }

    #[test]
    fn disabled_tracer_records_and_counts_nothing() {
        let mut t = Tracer::off();
        assert!(!t.enabled());
        t.span(5, EventKind::Arrival, NO_LET, ModelId::Lenet, 0, 1);
        t.mark(6, EventKind::Swap, 1, 0, 1);
        let mut tl = Timeline::default();
        t.drain_into(&mut tl);
        assert!(tl.is_empty());
    }

    #[test]
    fn ledger_counts_are_exact_under_sampling() {
        // Heavy sampling: spans thin out, the ledger does not.
        let mut t = Tracer::new(0, 1 << 12, 64);
        for id in 0..1000u64 {
            t.span(id, EventKind::Enqueue, 0, ModelId::Resnet, 0, id);
        }
        t.batch(2000, EventKind::BatchDone, 0, ModelId::Resnet, 0, 0, 32);
        assert_eq!(t.count(EventKind::Enqueue), 1000);
        assert_eq!(t.count(EventKind::BatchDone), 32);
        let mut tl = Timeline::default();
        t.drain_into(&mut tl);
        assert_eq!(tl.count(EventKind::Enqueue), 1000);
        let kept = tl.events.iter().filter(|e| e.kind == EventKind::Enqueue).count();
        assert!(kept < 1000, "sampling must thin the span list");
        let expected = (0..1000u64).filter(|&id| span_sampled(id, 64)).count();
        assert_eq!(kept, expected, "sampler must be the pure hash-mod rule");
        // Batch events are never sampled away.
        assert_eq!(tl.events.iter().filter(|e| e.kind == EventKind::BatchDone).count(), 1);
    }

    #[test]
    fn ring_bounds_memory_and_keeps_newest() {
        let mut r = RingSink::new(4);
        for i in 0..10u64 {
            r.record(&ev(i, EventKind::Arrival, i));
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.overwritten(), 6);
        let mut out = Vec::new();
        r.drain_ordered(&mut out);
        let ids: Vec<u64> = out.iter().map(|e| e.id).collect();
        assert_eq!(ids, [6, 7, 8, 9], "oldest-first, newest kept");
        assert!(r.is_empty());
    }

    #[test]
    fn sampler_is_deterministic_and_unclustered() {
        // Pure function: same answers on every call.
        for id in 0..256u64 {
            assert_eq!(span_sampled(id, 16), span_sampled(id, 16));
        }
        // The hash decorrelates sequential ids: modulus 16 keeps
        // roughly 1/16 of a sequential id range, not a prefix.
        let kept: Vec<u64> = (0..4096u64).filter(|&id| span_sampled(id, 16)).collect();
        assert!(kept.len() > 128 && kept.len() < 512, "kept {}", kept.len());
        assert!(kept.windows(2).any(|w| w[1] - w[0] > 16), "not a strided pick");
        // Modulus 1 and 0 keep everything.
        assert!((0..100u64).all(|id| span_sampled(id, 1)));
        assert!((0..100u64).all(|id| span_sampled(id, 0)));
    }

    #[test]
    fn timeline_merge_is_source_order_stable() {
        let mut a = Tracer::new(0, 64, 1);
        let mut b = Tracer::new(1, 64, 1);
        a.batch(10, EventKind::BatchStart, 0, ModelId::Lenet, 0, 1, 4);
        b.batch(10, EventKind::BatchStart, 0, ModelId::Lenet, 0, 2, 4);
        a.batch(5, EventKind::BatchStart, 0, ModelId::Lenet, 0, 3, 4);
        let mut tl = Timeline::default();
        a.drain_into(&mut tl);
        b.drain_into(&mut tl);
        tl.sort_events();
        let order: Vec<(u64, u32)> = tl.events.iter().map(|e| (e.t_us, e.node)).collect();
        assert_eq!(order, [(5, 0), (10, 0), (10, 1)], "stable: node 0 before node 1 at t=10");
        assert_eq!(tl.count(EventKind::BatchStart), 12);
    }

    #[test]
    fn jsonl_sink_streams_one_object_per_line() {
        let mut tl = Timeline::default();
        tl.events.push(ev(42, EventKind::BatchDone, 7));
        tl.events.push(ev(43, EventKind::Drop, 8));
        let mut sink = JsonLinesSink::new(Vec::new());
        tl.stream_to(&mut sink);
        let text = String::from_utf8(sink.into_inner()).expect("utf8");
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let doc = crate::util::json::Json::parse(line).expect("each line parses");
            assert!(doc.get("kind").is_ok());
        }
        assert!(text.contains("batch-done"));
    }
}
