//! Discrete-event simulation clock: a virtual-time event queue.
//!
//! All paper experiments run under this clock (DESIGN.md §1 "sim"
//! mode). Time is kept as **integer microseconds** (`u64`): heap
//! ordering is two integer compares instead of an f64 `partial_cmp`
//! chain, ties are exact (no epsilon tolerances on deadline checks),
//! and event ordering is bit-for-bit deterministic on every platform.
//! Millisecond-domain callers convert at the boundary with
//! [`ms_to_us`] / [`us_to_ms`]; 1 µs resolution is ~4 orders of
//! magnitude below the smallest SLO in the catalog (5 ms), so the
//! quantization is far inside the model's noise floor.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Virtual simulation time in integer microseconds.
pub type SimTimeUs = u64;

/// Convert milliseconds (the latency model's unit) to integer
/// microseconds, rounding to nearest. Panics on non-finite or negative
/// input — event times must be real instants.
#[inline]
pub fn ms_to_us(ms: f64) -> SimTimeUs {
    assert!(ms.is_finite() && ms >= 0.0, "invalid time {ms} ms");
    (ms * 1000.0).round() as SimTimeUs
}

/// Convert integer microseconds back to milliseconds (for reporting).
#[inline]
pub fn us_to_ms(us: SimTimeUs) -> f64 {
    us as f64 / 1000.0
}

/// Internal heap entry — min-heap by (time, seq).
struct Entry<E> {
    time_us: SimTimeUs,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time_us == other.time_us && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap semantics on BinaryHeap (a max-heap).
        other
            .time_us
            .cmp(&self.time_us)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic discrete-event queue over virtual microseconds.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now_us: SimTimeUs,
    /// High-water mark of `heap.len()` since creation/`clear`.
    peak_len: usize,
    /// Pushes whose time was in the past and got clamped to `now`.
    /// The clamp is deliberate (see [`EventQueue::push_at_us`]), but a
    /// *systematic* clamp stream is an ordering bug in the caller —
    /// this counter keeps it observable instead of silently absorbed.
    clamped: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self::with_capacity(0)
    }

    /// A queue with `cap` pre-allocated event slots — bulk injectors
    /// reserve once instead of growing the heap push by push.
    pub fn with_capacity(cap: usize) -> Self {
        EventQueue {
            heap: BinaryHeap::with_capacity(cap),
            seq: 0,
            now_us: 0,
            peak_len: 0,
            clamped: 0,
        }
    }

    /// Reserve room for at least `additional` more events. Bulk feeders
    /// (`ServingEngine::inject`) and steady-state bounds (one `Done`
    /// slot per gpu-let at `install_schedule`) reserve up front so the
    /// heap never grows inside the event loop.
    pub fn reserve(&mut self, additional: usize) {
        self.heap.reserve(additional);
    }

    /// Drop every pending event and reset the clock, sequence counter,
    /// and diagnostics to a fresh state, keeping the heap's allocation
    /// (probe harnesses reset one queue across many runs).
    pub fn clear(&mut self) {
        self.heap.clear();
        self.seq = 0;
        self.now_us = 0;
        self.peak_len = 0;
        self.clamped = 0;
    }

    /// Current virtual time (µs). Advances on `pop`.
    pub fn now_us(&self) -> SimTimeUs {
        self.now_us
    }

    /// Current virtual time in milliseconds (reporting convenience).
    pub fn now_ms(&self) -> f64 {
        us_to_ms(self.now_us)
    }

    /// Schedule `event` at absolute virtual time `time_us`.
    ///
    /// Events in the past are clamped to `now` (they fire next, in
    /// insertion order) — simpler and safer than panicking inside
    /// long experiment sweeps.
    pub fn push_at_us(&mut self, time_us: SimTimeUs, event: E) {
        if time_us < self.now_us {
            self.clamped += 1;
        }
        let t = time_us.max(self.now_us);
        let seq = self.alloc_seq();
        self.heap.push(Entry { time_us: t, seq, event });
        self.peak_len = self.peak_len.max(self.heap.len());
    }

    /// Hand out the next tie-break sequence number without pushing.
    ///
    /// Events kept *outside* the heap (the serving engine's per-
    /// assignment duty-timer slots, its per-stream pending arrivals)
    /// take their ordering ticket from the same counter, so a merged
    /// pop over heap + slots reproduces exactly the order an all-in-
    /// the-heap implementation would have produced at equal timestamps.
    pub fn alloc_seq(&mut self) -> u64 {
        let s = self.seq;
        self.seq += 1;
        s
    }

    /// Schedule `event` after a relative delay in microseconds.
    pub fn push_after_us(&mut self, delay_us: SimTimeUs, event: E) {
        self.push_at_us(self.now_us + delay_us, event);
    }

    /// Millisecond-domain convenience for [`EventQueue::push_at_us`].
    pub fn push_at(&mut self, time_ms: f64, event: E) {
        self.push_at_us(ms_to_us(time_ms), event);
    }

    /// Millisecond-domain convenience for [`EventQueue::push_after_us`].
    pub fn push_after(&mut self, delay_ms: f64, event: E) {
        assert!(delay_ms >= 0.0, "negative delay");
        self.push_after_us(ms_to_us(delay_ms), event);
    }

    /// Pop the earliest event, advancing the clock to its time (µs).
    pub fn pop(&mut self) -> Option<(SimTimeUs, E)> {
        self.heap.pop().map(|e| {
            debug_assert!(e.time_us >= self.now_us);
            self.now_us = e.time_us;
            (e.time_us, e.event)
        })
    }

    /// Remove and return every pending event *without* advancing the
    /// clock (arbitrary order). Teardown accounting: a node failure
    /// destroys its future events, but the engine keeps running on the
    /// shared clock, so — unlike a `pop` loop — `now` must not jump to
    /// the drained events' times.
    pub fn drain_events(&mut self) -> Vec<(SimTimeUs, E)> {
        self.heap.drain().map(|e| (e.time_us, e.event)).collect()
    }

    /// Time of the next event (µs) without popping.
    pub fn peek_time_us(&self) -> Option<SimTimeUs> {
        self.heap.peek().map(|e| e.time_us)
    }

    /// `(time, seq)` of the next event — the full ordering key, for
    /// callers merging the heap with externally-held events whose seq
    /// came from [`EventQueue::alloc_seq`].
    pub fn peek_time_seq_us(&self) -> Option<(SimTimeUs, u64)> {
        self.heap.peek().map(|e| (e.time_us, e.seq))
    }

    /// Advance the clock to `t_us` without popping (no-op if the clock
    /// is already past). Lets a run-until loop leave the clock at the
    /// window boundary even when the queue went quiet earlier, so
    /// follow-up actions (schedule swaps, injections) see a consistent
    /// `now`. Must not skip pending events: callers drain everything at
    /// or before `t_us` first.
    pub fn advance_to(&mut self, t_us: SimTimeUs) {
        debug_assert!(
            self.heap.peek().is_none_or(|e| e.time_us >= t_us),
            "advance_to({t_us}) would skip a pending event"
        );
        self.now_us = self.now_us.max(t_us);
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// High-water mark of the heap length since creation/`clear` —
    /// the "how much future did this simulation hold at once" metric
    /// the streaming engine drives to O(active).
    pub fn peak_len(&self) -> usize {
        self.peak_len
    }

    /// How many pushes were silently clamped from the past to `now`.
    pub fn clamped_pushes(&self) -> u64 {
        self.clamped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push_at(5.0, "c");
        q.push_at(1.0, "a");
        q.push_at(3.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push_at_us(1_000, 1);
        q.push_at_us(1_000, 2);
        q.push_at_us(1_000, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push_at_us(10_000, ());
        q.push_at_us(20_000, ());
        assert_eq!(q.now_us(), 0);
        assert_eq!(q.now_ms(), 0.0);
        q.pop();
        assert_eq!(q.now_us(), 10_000);
        assert_eq!(q.now_ms(), 10.0);
        // Past events clamp to now.
        q.push_at_us(5_000, ());
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 10_000);
        q.pop();
        assert_eq!(q.now_us(), 20_000);
    }

    #[test]
    fn advance_to_moves_clock_without_events() {
        let mut q: EventQueue<()> = EventQueue::new();
        q.advance_to(5_000);
        assert_eq!(q.now_us(), 5_000);
        // Never moves backwards.
        q.advance_to(1_000);
        assert_eq!(q.now_us(), 5_000);
        // Future pushes are relative to the advanced clock.
        q.push_after_us(500, ());
        assert_eq!(q.peek_time_us(), Some(5_500));
    }

    #[test]
    fn push_after_relative() {
        let mut q = EventQueue::new();
        q.push_at(10.0, "x");
        q.pop();
        q.push_after(2.5, "y");
        assert_eq!(q.peek_time_us(), Some(12_500));
        assert_eq!(q.len(), 1);
        assert!(!q.is_empty());
    }

    #[test]
    fn ms_roundtrip_at_us_resolution() {
        assert_eq!(ms_to_us(0.0), 0);
        assert_eq!(ms_to_us(1.0), 1_000);
        assert_eq!(ms_to_us(0.0004), 0); // rounds to nearest µs
        assert_eq!(ms_to_us(0.0006), 1);
        assert_eq!(us_to_ms(12_500), 12.5);
        assert_eq!(ms_to_us(us_to_ms(987_654_321)), 987_654_321);
    }

    #[test]
    fn clamped_pushes_are_counted() {
        let mut q = EventQueue::new();
        q.push_at_us(10_000, ());
        q.pop();
        assert_eq!(q.clamped_pushes(), 0);
        // A push into the past clamps to now — and is counted, so the
        // clamp can't silently mask an ordering bug upstream.
        q.push_at_us(5_000, ());
        assert_eq!(q.clamped_pushes(), 1);
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 10_000);
        // An exactly-at-now push is not a clamp.
        q.push_at_us(10_000, ());
        assert_eq!(q.clamped_pushes(), 1);
    }

    #[test]
    fn capacity_clear_and_peak_tracking() {
        let mut q: EventQueue<u32> = EventQueue::with_capacity(16);
        q.reserve(8);
        for i in 0..5 {
            q.push_at_us(i * 100, i as u32);
        }
        assert_eq!(q.peak_len(), 5);
        q.pop();
        q.pop();
        assert_eq!(q.len(), 3);
        assert_eq!(q.peak_len(), 5, "peak is a high-water mark");
        q.clear();
        assert!(q.is_empty());
        assert_eq!(q.now_us(), 0);
        assert_eq!(q.peak_len(), 0);
        assert_eq!(q.clamped_pushes(), 0);
        // Fresh seq counter after clear: ties break by new insertion order.
        q.push_at_us(50, 7);
        q.push_at_us(50, 8);
        assert_eq!(q.peek_time_seq_us(), Some((50, 0)));
        assert_eq!(q.pop().unwrap().1, 7);
    }

    #[test]
    fn alloc_seq_interleaves_with_pushes() {
        let mut q: EventQueue<&str> = EventQueue::new();
        let s0 = q.alloc_seq();
        q.push_at_us(1_000, "pushed");
        let s2 = q.alloc_seq();
        assert_eq!(s0, 0);
        assert_eq!(s2, 2, "push consumed seq 1 from the same counter");
        assert_eq!(q.peek_time_seq_us(), Some((1_000, 1)));
    }

    #[test]
    #[should_panic]
    fn rejects_nan_time() {
        let mut q = EventQueue::new();
        q.push_at(f64::NAN, ());
    }

    #[test]
    #[should_panic]
    fn rejects_negative_time() {
        ms_to_us(-1.0);
    }
}
