//! Discrete-event simulation clock: a virtual-time event queue.
//!
//! All paper experiments run under this clock (DESIGN.md §1 "sim"
//! mode): simulated milliseconds, deterministic ordering (time, then
//! insertion sequence), no wall-clock dependence.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Internal heap entry — min-heap by (time, seq).
struct Entry<E> {
    time_ms: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time_ms == other.time_ms && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap semantics on BinaryHeap (a max-heap).
        other
            .time_ms
            .partial_cmp(&self.time_ms)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic discrete-event queue over virtual milliseconds.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now_ms: f64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now_ms: 0.0 }
    }

    /// Current virtual time (ms). Advances on `pop`.
    pub fn now_ms(&self) -> f64 {
        self.now_ms
    }

    /// Schedule `event` at absolute virtual time `time_ms`.
    ///
    /// Events in the past are clamped to `now` (they fire next, in
    /// insertion order) — simpler and safer than panicking inside
    /// long experiment sweeps.
    pub fn push_at(&mut self, time_ms: f64, event: E) {
        assert!(time_ms.is_finite(), "non-finite event time");
        let t = time_ms.max(self.now_ms);
        self.heap.push(Entry { time_ms: t, seq: self.seq, event });
        self.seq += 1;
    }

    /// Schedule `event` after a relative delay.
    pub fn push_after(&mut self, delay_ms: f64, event: E) {
        assert!(delay_ms >= 0.0, "negative delay");
        self.push_at(self.now_ms + delay_ms, event);
    }

    /// Pop the earliest event, advancing the clock to its time.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|e| {
            debug_assert!(e.time_ms >= self.now_ms);
            self.now_ms = e.time_ms;
            (e.time_ms, e.event)
        })
    }

    /// Time of the next event without popping.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time_ms)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push_at(5.0, "c");
        q.push_at(1.0, "a");
        q.push_at(3.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push_at(1.0, 1);
        q.push_at(1.0, 2);
        q.push_at(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push_at(10.0, ());
        q.push_at(20.0, ());
        assert_eq!(q.now_ms(), 0.0);
        q.pop();
        assert_eq!(q.now_ms(), 10.0);
        // Past events clamp to now.
        q.push_at(5.0, ());
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 10.0);
        q.pop();
        assert_eq!(q.now_ms(), 20.0);
    }

    #[test]
    fn push_after_relative() {
        let mut q = EventQueue::new();
        q.push_at(10.0, "x");
        q.pop();
        q.push_after(2.5, "y");
        assert_eq!(q.peek_time(), Some(12.5));
        assert_eq!(q.len(), 1);
        assert!(!q.is_empty());
    }

    #[test]
    #[should_panic]
    fn rejects_nan_time() {
        let mut q = EventQueue::new();
        q.push_at(f64::NAN, ());
    }
}
