//! Deterministic fork-join parallelism over `std::thread::scope` (the
//! offline stand-in for `rayon`).
//!
//! `par_map` fans a slice out over a worker pool and returns results in
//! **input order**, independent of thread count or scheduling — callers
//! that serialize the output (the experiment sweeps writing BENCH
//! payloads) get byte-identical JSON for any `--threads N`. Work is
//! dispatched by an atomic index so uneven items (scheduling passes
//! vary widely in cost) load-balance instead of tail-stalling a static
//! chunking.
//!
//! `par_for_each_mut` / `par_map_mut` are the mutable fork-join forms:
//! each worker claims an index from the same atomic counter and gets
//! the **exclusive** `&mut` to that item (every index is handed out
//! exactly once, so the borrows are provably disjoint). The fleet tier
//! advances its per-node serving engines this way — each engine's
//! computation is identical to the serial loop's, so results stay
//! byte-identical for any worker count.
//!
//! The worker count resolves, in priority order: the process-wide
//! override set by the CLI `--threads` flag (`set_threads`), the
//! `GPULETS_THREADS` environment variable (how the bench targets are
//! steered), then `std::thread::available_parallelism()`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Process-wide worker-count override; 0 means "auto".
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Set the process-wide worker count (`--threads N`). `0` restores the
/// automatic choice (env var, then `available_parallelism`).
pub fn set_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::Relaxed);
}

/// The raw override value (`0` = auto) — lets callers that temporarily
/// re-pin the worker count (the fleet-scale bench's serial/parallel
/// arms) restore the exact prior state instead of freezing the
/// auto-resolved value into an explicit override.
pub fn thread_override() -> usize {
    THREAD_OVERRIDE.load(Ordering::Relaxed)
}

/// Resolved worker count for the next `par_map` call.
pub fn threads() -> usize {
    let o = THREAD_OVERRIDE.load(Ordering::Relaxed);
    if o > 0 {
        return o;
    }
    if let Ok(v) = std::env::var("GPULETS_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map_or(1, |n| n.get())
}

/// Map `f` over `items` on the configured worker count; results are in
/// input order (deterministic merge).
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    par_map_threads(threads(), items, f)
}

/// `par_map` with an explicit worker count (1 = fully serial, no
/// threads spawned — the reference path the equivalence tests compare
/// against).
pub fn par_map_threads<T, R, F>(workers: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Mutex<Vec<Option<R>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                // Compute outside the lock; the critical section is one
                // slot store (tasks here are ms-scale scheduling passes,
                // so the lock is uncontended in practice).
                let r = f(&items[i]);
                out.lock().unwrap()[i] = Some(r);
            });
        }
    });
    out.into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("par_map worker skipped a slot"))
        .collect()
}

/// Base pointer of a `&mut [T]` handed to scoped workers. Sharing it is
/// sound because the atomic dispatch index gives out each element index
/// exactly once, so no two workers ever touch the same item.
struct SlicePtr<T>(*mut T);
// SAFETY: sharing the base pointer across scoped workers is sound
// because the atomic dispatch index hands out each element index
// exactly once — no two workers ever form a reference to the same
// item — and `T: Send` lets the items themselves move between
// threads. The pointer is only dereferenced inside the scope that
// borrows the slice, so it cannot dangle.
unsafe impl<T: Send> Sync for SlicePtr<T> {}

/// Apply `f` to every item through an exclusive `&mut`, fanned out over
/// the configured worker count. Same atomic work-stealing dispatch as
/// `par_map`; a worker panic propagates to the caller after all workers
/// join (`std::thread::scope` semantics).
pub fn par_for_each_mut<T, F>(items: &mut [T], f: F)
where
    T: Send,
    F: Fn(&mut T) + Sync,
{
    par_for_each_mut_threads(threads(), items, f)
}

/// `par_for_each_mut` with an explicit worker count (1 = fully serial,
/// no threads spawned — the reference path equivalence tests compare
/// against).
pub fn par_for_each_mut_threads<T, F>(workers: usize, items: &mut [T], f: F)
where
    T: Send,
    F: Fn(&mut T) + Sync,
{
    let n = items.len();
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        for item in items.iter_mut() {
            f(item);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let base = SlicePtr(items.as_mut_ptr());
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                let base = &base;
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    // SAFETY: `fetch_add` hands index `i` to exactly
                    // one worker, so this is the only live `&mut` to
                    // items[i]; the slice outlives the scope (it is
                    // borrowed across it) and `i < n` is checked above.
                    f(unsafe { &mut *base.0.add(i) });
                }
            });
        }
    });
}

/// `par_map` over exclusive `&mut` items: mutate in place and collect
/// `f`'s results in **input order**, independent of worker count.
pub fn par_map_mut<T, R, F>(items: &mut [T], f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(&mut T) -> R + Sync,
{
    par_map_mut_threads(threads(), items, f)
}

/// `par_map_mut` with an explicit worker count (1 = fully serial).
pub fn par_map_mut_threads<T, R, F>(workers: usize, items: &mut [T], f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(&mut T) -> R + Sync,
{
    let n = items.len();
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return items.iter_mut().map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let base = SlicePtr(items.as_mut_ptr());
    let out: Mutex<Vec<Option<R>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                let base = &base;
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    // SAFETY: as in `par_for_each_mut_threads` — the
                    // atomic index makes the `&mut` exclusive.
                    let r = f(unsafe { &mut *base.0.add(i) });
                    out.lock().unwrap()[i] = Some(r);
                }
            });
        }
    });
    out.into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("par_map_mut worker skipped a slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_input_order_for_any_worker_count() {
        let items: Vec<u64> = (0..97).collect();
        let want: Vec<u64> = items.iter().map(|x| x * x).collect();
        for workers in [1, 2, 3, 8, 64] {
            let got = par_map_threads(workers, &items, |&x| x * x);
            assert_eq!(got, want, "workers={workers}");
        }
    }

    #[test]
    fn handles_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(par_map_threads(4, &empty, |&x| x).is_empty());
        assert_eq!(par_map_threads(4, &[7u32], |&x| x + 1), vec![8]);
    }

    #[test]
    fn override_wins_and_clears() {
        set_threads(3);
        assert_eq!(threads(), 3);
        assert_eq!(thread_override(), 3);
        set_threads(0);
        assert_eq!(thread_override(), 0);
        assert!(threads() >= 1);
    }

    #[test]
    fn par_map_mut_matches_the_serial_reference_in_input_order() {
        // The 1-worker path is the serial reference; every worker count
        // must produce the same mutations and the same ordered results.
        let step = |x: &mut u64| {
            *x = x.wrapping_mul(3) + 1;
            *x ^ 7
        };
        let mut reference: Vec<u64> = (0..97).collect();
        let want: Vec<u64> = reference.iter_mut().map(step).collect();
        for workers in [1, 2, 3, 8, 64] {
            let mut items: Vec<u64> = (0..97).collect();
            let got = par_map_mut_threads(workers, &mut items, step);
            assert_eq!(got, want, "workers={workers}: results out of order");
            assert_eq!(items, reference, "workers={workers}: mutations diverged");
        }
    }

    #[test]
    fn par_for_each_mut_visits_every_item_exactly_once() {
        for workers in [1, 2, 7, 32] {
            let mut items = vec![0u32; 1000];
            par_for_each_mut_threads(workers, &mut items, |x| *x += 1);
            assert!(
                items.iter().all(|&x| x == 1),
                "workers={workers}: an item was skipped or double-visited"
            );
        }
        let mut empty: Vec<u32> = vec![];
        par_for_each_mut_threads(4, &mut empty, |x| *x += 1);
        assert!(empty.is_empty());
    }

    #[test]
    #[should_panic]
    fn par_for_each_mut_propagates_worker_panics() {
        let mut items: Vec<u32> = (0..64).collect();
        par_for_each_mut_threads(4, &mut items, |x| {
            if *x == 13 {
                panic!("worker panic must reach the caller");
            }
        });
    }

    /// Property form of the serial-equivalence claim: random sizes and
    /// worker counts, compared against the 1-worker reference.
    #[test]
    fn par_map_mut_equals_serial_for_random_sizes_and_workers() {
        use crate::util::proptest_mini as pt;
        #[derive(Clone, Debug)]
        struct Case {
            n: usize,
            workers: usize,
        }
        pt::run(
            pt::Config { cases: 64, ..Default::default() },
            |rng| Case { n: rng.below(200), workers: 1 + rng.below(9) },
            |c| {
                let mut out = Vec::new();
                if c.n > 0 {
                    out.push(Case { n: c.n / 2, ..*c });
                }
                if c.workers > 1 {
                    out.push(Case { workers: c.workers / 2, ..*c });
                }
                out
            },
            |c| {
                let step = |x: &mut u64| {
                    *x = x.wrapping_add(11);
                    *x * 2
                };
                let mut a: Vec<u64> = (0..c.n as u64).collect();
                let mut b = a.clone();
                let want: Vec<u64> = b.iter_mut().map(step).collect();
                let got = par_map_mut_threads(c.workers, &mut a, step);
                if got != want {
                    return Err(format!("results diverged at n={} w={}", c.n, c.workers));
                }
                if a != b {
                    return Err(format!("mutations diverged at n={} w={}", c.n, c.workers));
                }
                Ok(())
            },
        );
    }
}
