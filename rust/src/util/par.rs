//! Deterministic fork-join parallelism over `std::thread::scope` (the
//! offline stand-in for `rayon`).
//!
//! `par_map` fans a slice out over a worker pool and returns results in
//! **input order**, independent of thread count or scheduling — callers
//! that serialize the output (the experiment sweeps writing BENCH
//! payloads) get byte-identical JSON for any `--threads N`. Work is
//! dispatched by an atomic index so uneven items (scheduling passes
//! vary widely in cost) load-balance instead of tail-stalling a static
//! chunking.
//!
//! The worker count resolves, in priority order: the process-wide
//! override set by the CLI `--threads` flag (`set_threads`), the
//! `GPULETS_THREADS` environment variable (how the bench targets are
//! steered), then `std::thread::available_parallelism()`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Process-wide worker-count override; 0 means "auto".
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Set the process-wide worker count (`--threads N`). `0` restores the
/// automatic choice (env var, then `available_parallelism`).
pub fn set_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::Relaxed);
}

/// Resolved worker count for the next `par_map` call.
pub fn threads() -> usize {
    let o = THREAD_OVERRIDE.load(Ordering::Relaxed);
    if o > 0 {
        return o;
    }
    if let Ok(v) = std::env::var("GPULETS_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map_or(1, |n| n.get())
}

/// Map `f` over `items` on the configured worker count; results are in
/// input order (deterministic merge).
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    par_map_threads(threads(), items, f)
}

/// `par_map` with an explicit worker count (1 = fully serial, no
/// threads spawned — the reference path the equivalence tests compare
/// against).
pub fn par_map_threads<T, R, F>(workers: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Mutex<Vec<Option<R>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                // Compute outside the lock; the critical section is one
                // slot store (tasks here are ms-scale scheduling passes,
                // so the lock is uncontended in practice).
                let r = f(&items[i]);
                out.lock().unwrap()[i] = Some(r);
            });
        }
    });
    out.into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("par_map worker skipped a slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_input_order_for_any_worker_count() {
        let items: Vec<u64> = (0..97).collect();
        let want: Vec<u64> = items.iter().map(|x| x * x).collect();
        for workers in [1, 2, 3, 8, 64] {
            let got = par_map_threads(workers, &items, |&x| x * x);
            assert_eq!(got, want, "workers={workers}");
        }
    }

    #[test]
    fn handles_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(par_map_threads(4, &empty, |&x| x).is_empty());
        assert_eq!(par_map_threads(4, &[7u32], |&x| x + 1), vec![8]);
    }

    #[test]
    fn override_wins_and_clears() {
        set_threads(3);
        assert_eq!(threads(), 3);
        set_threads(0);
        assert!(threads() >= 1);
    }
}
