//! Deterministic PRNG + distributions (offline stand-in for `rand`/`rand_distr`).
//!
//! PCG-XSH-RR 64/32 core with helpers for the distributions the serving
//! simulator needs: uniform, exponential (Poisson-process inter-arrival
//! gaps), Poisson counts, and normal (interference noise).

/// PCG-XSH-RR 64/32 — small, fast, statistically solid, reproducible.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seed with an arbitrary `(seed, stream)` pair.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed from a single value (stream derived by splitmix).
    pub fn seeded(seed: u64) -> Self {
        Self::new(splitmix64(seed), splitmix64(seed ^ 0x9E3779B97F4A7C15))
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Multiply-shift; bias negligible for our n << 2^32.
        ((self.next_u32() as u64 * n as u64) >> 32) as usize
    }

    /// Exponential with rate `lambda` (mean 1/lambda) — Poisson gaps.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0, "exp rate must be positive");
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / lambda
    }

    /// Poisson count with mean `lambda` (Knuth for small, PTRS-lite via
    /// normal approximation for large means).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            // Normal approximation with continuity correction.
            let x = self.normal(lambda, lambda.sqrt());
            x.max(0.0).round() as u64
        }
    }

    /// Normal via Box–Muller.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// SplitMix64 — seed expander (also usable as a cheap stateless hash).
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

/// Deterministic hash of a string to u64 (FNV-1a) — stable pair noise keys.
pub fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_runs() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Pcg32::seeded(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = Pcg32::seeded(11);
        let lambda = 200.0; // 200 req/s
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.0002, "mean={mean}");
    }

    #[test]
    fn poisson_small_mean() {
        let mut r = Pcg32::seeded(13);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.poisson(3.5) as f64).sum::<f64>() / n as f64;
        assert!((mean - 3.5).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn poisson_large_mean_normal_branch() {
        let mut r = Pcg32::seeded(17);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.poisson(400.0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 400.0).abs() < 2.0, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(19);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(10.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.05);
        assert!((var - 4.0).abs() < 0.15);
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Pcg32::seeded(23);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(29);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fnv1a_stable() {
        assert_eq!(fnv1a("lenet"), fnv1a("lenet"));
        assert_ne!(fnv1a("lenet"), fnv1a("vgg"));
    }
}
