//! Self-built substrates that would normally come from crates.io.
//!
//! The build environment is offline with only the `xla` dependency
//! closure cached, so the usual serving-stack dependencies (serde,
//! rand, tokio, criterion, proptest) are reimplemented here at the
//! scale this project needs. Each submodule carries its own tests.

pub mod benchkit;
pub mod json;
pub mod logging;
pub mod par;
pub mod proptest_mini;
pub mod rng;
pub mod stats;
pub mod tomlmini;
