//! Minimal TOML-subset parser + writer (offline stand-in for the `toml`
//! crate).
//!
//! Supports what `configs/*.toml` uses: `[section]` / `[section.sub]`
//! headers, `key = value` with string / integer / float / bool / array
//! values, `#` comments. Values are exposed through dotted-path lookup.
//! `TomlDoc::to_toml` renders a document back out; for any text this
//! module can parse, `parse(to_toml(parse(text)))` reproduces the same
//! document (strings must not contain `"` or newlines — the grammar
//! has no escape syntax).

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed TOML-subset value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => Err(Error::parse(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            TomlValue::Int(i) => Ok(*i),
            other => Err(Error::parse(format!("expected integer, got {other:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            other => Err(Error::parse(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => Err(Error::parse(format!("expected bool, got {other:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[TomlValue]> {
        match self {
            TomlValue::Arr(a) => Ok(a),
            other => Err(Error::parse(format!("expected array, got {other:?}"))),
        }
    }

    /// Render as TOML-subset text (inverse of `parse_value`). Finite
    /// integral floats keep a decimal point — whatever their magnitude —
    /// so they re-parse as floats, not integers. Strings must not
    /// contain `"` or newlines (the grammar has no escapes); debug
    /// builds assert, release builds would emit text that re-parses
    /// differently.
    pub fn render(&self) -> String {
        match self {
            TomlValue::Str(s) => {
                debug_assert!(
                    !s.contains('"') && !s.contains('\n'),
                    "unescapable string {s:?} (tomlmini has no escape syntax)"
                );
                format!("\"{s}\"")
            }
            TomlValue::Int(i) => i.to_string(),
            TomlValue::Float(x) => {
                debug_assert!(
                    x.is_finite(),
                    "non-finite float {x} has no TOML-subset representation"
                );
                if x.is_finite() && x.fract() == 0.0 {
                    format!("{x:.1}")
                } else {
                    format!("{x}")
                }
            }
            TomlValue::Bool(b) => b.to_string(),
            TomlValue::Arr(a) => {
                let items: Vec<String> = a.iter().map(TomlValue::render).collect();
                format!("[{}]", items.join(", "))
            }
        }
    }
}

/// Flat dotted-key table: `[gpu]` + `count = 4` is stored as `gpu.count`.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    /// Parse a document.
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| Error::parse(format!("line {}: bad section", lineno + 1)))?
                    .trim();
                if name.is_empty() {
                    return Err(Error::parse(format!("line {}: empty section", lineno + 1)));
                }
                section = name.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| Error::parse(format!("line {}: expected key = value", lineno + 1)))?;
            let key = line[..eq].trim();
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|e| Error::parse(format!("line {}: {e}", lineno + 1)))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            doc.entries.insert(full, val);
        }
        Ok(doc)
    }

    /// Lookup by dotted path.
    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.entries.get(path)
    }

    /// Lookup with a default when missing.
    pub fn f64_or(&self, path: &str, default: f64) -> Result<f64> {
        match self.get(path) {
            None => Ok(default),
            Some(v) => v.as_f64(),
        }
    }

    pub fn i64_or(&self, path: &str, default: i64) -> Result<i64> {
        match self.get(path) {
            None => Ok(default),
            Some(v) => v.as_i64(),
        }
    }

    pub fn str_or(&self, path: &str, default: &str) -> Result<String> {
        match self.get(path) {
            None => Ok(default.to_string()),
            Some(v) => Ok(v.as_str()?.to_string()),
        }
    }

    pub fn bool_or(&self, path: &str, default: bool) -> Result<bool> {
        match self.get(path) {
            None => Ok(default),
            Some(v) => v.as_bool(),
        }
    }

    /// All keys under a dotted prefix (e.g. every `rates.<model>` entry).
    pub fn keys_under<'a>(
        &'a self,
        prefix: &'a str,
    ) -> impl Iterator<Item = (&'a str, &'a TomlValue)> {
        let pfx = format!("{prefix}.");
        self.entries.iter().filter_map(move |(k, v)| {
            k.strip_prefix(&pfx).map(|rest| (rest, v))
        })
    }

    /// Insert/overwrite a value at a dotted path (programmatic doc
    /// building for `to_toml`).
    pub fn set(&mut self, path: impl Into<String>, v: TomlValue) {
        self.entries.insert(path.into(), v);
    }

    /// Number of key/value entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Render back to TOML-subset text: top-level (undotted) keys first,
    /// then one `[section]` per dotted prefix (the section is everything
    /// before the *last* dot, matching how `parse` builds dotted keys).
    pub fn to_toml(&self) -> String {
        let mut root: Vec<(&str, &TomlValue)> = Vec::new();
        let mut sections: BTreeMap<&str, Vec<(&str, &TomlValue)>> = BTreeMap::new();
        for (k, v) in &self.entries {
            match k.rfind('.') {
                None => root.push((k, v)),
                Some(i) => sections.entry(&k[..i]).or_default().push((&k[i + 1..], v)),
            }
        }
        let mut out = String::new();
        for (k, v) in root {
            out.push_str(k);
            out.push_str(" = ");
            out.push_str(&v.render());
            out.push('\n');
        }
        for (sec, entries) in sections {
            out.push('[');
            out.push_str(sec);
            out.push_str("]\n");
            for (k, v) in entries {
                out.push_str(k);
                out.push_str(" = ");
                out.push_str(&v.render());
                out.push('\n');
            }
        }
        out
    }
}

fn strip_comment(line: &str) -> &str {
    // Honour '#' outside of quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> std::result::Result<TomlValue, String> {
    if text.is_empty() {
        return Err("empty value".to_string());
    }
    if let Some(inner) = text.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if text == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if text == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items = split_top_level(inner)
            .into_iter()
            .map(|s| parse_value(s.trim()))
            .collect::<std::result::Result<Vec<_>, _>>()?;
        return Ok(TomlValue::Arr(items));
    }
    if let Ok(i) = text.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = text.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value {text:?}"))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# cluster config
name = "paper"
[gpu]
count = 4            # four 2080 Ti
max_lets = 2
sizes = [20, 40, 50, 60, 80, 100]
[sched]
algo = "elastic"
interference = true
period_s = 20.0
[rates]
lenet = 50.0
vgg = 50.0
"#;

    #[test]
    fn parses_sections_and_types() {
        let d = TomlDoc::parse(DOC).unwrap();
        assert_eq!(d.get("name").unwrap().as_str().unwrap(), "paper");
        assert_eq!(d.get("gpu.count").unwrap().as_i64().unwrap(), 4);
        assert_eq!(d.get("sched.period_s").unwrap().as_f64().unwrap(), 20.0);
        assert!(d.get("sched.interference").unwrap().as_bool().unwrap());
        let sizes = d.get("gpu.sizes").unwrap().as_arr().unwrap();
        assert_eq!(sizes.len(), 6);
        assert_eq!(sizes[0].as_i64().unwrap(), 20);
    }

    #[test]
    fn defaults_and_prefix_iteration() {
        let d = TomlDoc::parse(DOC).unwrap();
        assert_eq!(d.i64_or("gpu.count", 1).unwrap(), 4);
        assert_eq!(d.i64_or("gpu.missing", 7).unwrap(), 7);
        let rates: Vec<_> = d.keys_under("rates").collect();
        assert_eq!(rates.len(), 2);
    }

    #[test]
    fn comment_inside_string_preserved() {
        let d = TomlDoc::parse(r##"key = "a#b" # trailing"##).unwrap();
        assert_eq!(d.get("key").unwrap().as_str().unwrap(), "a#b");
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlDoc::parse("[unclosed").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("k = [1, 2").is_err());
    }

    #[test]
    fn to_toml_round_trips() {
        let d = TomlDoc::parse(DOC).unwrap();
        let rendered = d.to_toml();
        let d2 = TomlDoc::parse(&rendered).unwrap();
        assert_eq!(d.entries, d2.entries, "round trip changed the doc:\n{rendered}");
        // Floats stay floats, ints stay ints.
        assert_eq!(d2.get("sched.period_s").unwrap(), &TomlValue::Float(20.0));
        assert_eq!(d2.get("gpu.count").unwrap(), &TomlValue::Int(4));
    }

    #[test]
    fn set_and_render_programmatic_doc() {
        let mut d = TomlDoc::default();
        assert!(d.is_empty());
        d.set("name", TomlValue::Str("run".into()));
        d.set("gpu.count", TomlValue::Int(2));
        d.set("rates.lenet", TomlValue::Float(62.5));
        d.set("sched.nested.deep", TomlValue::Bool(true));
        assert_eq!(d.len(), 4);
        let d2 = TomlDoc::parse(&d.to_toml()).unwrap();
        assert_eq!(d.entries, d2.entries);
        assert!(d2.get("sched.nested.deep").unwrap().as_bool().unwrap());
    }

    #[test]
    fn value_render_matches_parse() {
        for v in [
            TomlValue::Int(-3),
            TomlValue::Float(0.25),
            TomlValue::Float(100.0),
            TomlValue::Float(1e15), // integral float beyond i64-friendly range
            TomlValue::Bool(false),
            TomlValue::Str("hello world".into()),
            TomlValue::Arr(vec![TomlValue::Int(1), TomlValue::Float(2.5)]),
        ] {
            let text = v.render();
            let back = parse_value(&text).unwrap();
            assert_eq!(v, back, "render {text:?}");
        }
    }
}
