//! Minimal benchmarking harness (offline stand-in for `criterion`).
//!
//! `cargo bench` runs the `rust/benches/*.rs` targets (harness = false);
//! each uses this kit to time its workload with warmup + repeated
//! measurement and to print a stable, parseable summary line.

use std::time::Instant;

/// One timing summary.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
}

impl BenchResult {
    pub fn summary(&self) -> String {
        format!(
            "bench {:<40} {:>5} iters  mean {:>10.3} ms  min {:>10.3} ms  max {:>10.3} ms",
            self.name, self.iters, self.mean_ms, self.min_ms, self.max_ms
        )
    }
}

/// Time `f` with `warmup` unmeasured runs and `iters` measured runs.
/// The closure's result is returned from the last run so the compiler
/// cannot elide the work.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> (BenchResult, T) {
    assert!(iters >= 1);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    let mut last = None;
    for _ in 0..iters {
        let t0 = Instant::now();
        last = Some(std::hint::black_box(f()));
        times.push(t0.elapsed().as_secs_f64() * 1000.0);
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0f64, f64::max);
    (
        BenchResult {
            name: name.to_string(),
            iters,
            mean_ms: mean,
            min_ms: min,
            max_ms: max,
        },
        last.unwrap(),
    )
}

/// Convenience: run, print the summary, return the workload result.
pub fn run<T>(name: &str, warmup: usize, iters: usize, f: impl FnMut() -> T) -> T {
    let (res, out) = bench(name, warmup, iters, f);
    println!("{}", res.summary());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_work() {
        let (res, out) = bench("spin", 1, 3, || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert_eq!(out, (0..10_000u64).fold(0u64, |a, b| a.wrapping_add(b)));
        assert_eq!(res.iters, 3);
        assert!(res.min_ms <= res.mean_ms && res.mean_ms <= res.max_ms + 1e-9);
        assert!(res.summary().contains("spin"));
    }
}
