//! Minimal benchmarking harness (offline stand-in for `criterion`).
//!
//! `cargo bench` runs the `rust/benches/*.rs` targets (harness = false);
//! each uses this kit to time its workload with warmup + repeated
//! measurement, print a stable, parseable summary line, and write a
//! machine-readable `BENCH_<target>.json` envelope (timing + payload)
//! that the perf-trajectory tooling diffs across PRs.

use std::fmt::Write as _;
use std::time::Instant;

use crate::util::json::{obj, Json};

/// One timing summary.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
}

impl BenchResult {
    pub fn summary(&self) -> String {
        format!(
            "bench {:<40} {:>5} iters  mean {:>10.3} ms  min {:>10.3} ms  max {:>10.3} ms",
            self.name, self.iters, self.mean_ms, self.min_ms, self.max_ms
        )
    }

    /// Timing as a JSON object (one entry of a BENCH file).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("mean_ms", Json::Num(self.mean_ms)),
            ("min_ms", Json::Num(self.min_ms)),
            ("max_ms", Json::Num(self.max_ms)),
        ])
    }
}

/// The standard BENCH-file schema: `{"bench": <timing>, "result": <payload>}`.
pub fn envelope(timing: &BenchResult, payload: Json) -> Json {
    obj(vec![("bench", timing.to_json()), ("result", payload)])
}

/// A BENCH file holding only timings (the micro benches): `{"bench": [...]}`.
pub fn timings_envelope(timings: &[BenchResult]) -> Json {
    Json::Obj(
        [(
            "bench".to_string(),
            Json::Arr(timings.iter().map(BenchResult::to_json).collect()),
        )]
        .into_iter()
        .collect(),
    )
}

/// Write a JSON document (newline-terminated) to `path`.
pub fn write_json(path: impl AsRef<std::path::Path>, doc: &Json) -> std::io::Result<()> {
    let mut text = doc.to_string();
    text.push('\n');
    std::fs::write(path, text)
}

/// Extract `(name, mean_ms)` timing entries from a BENCH document —
/// either the multi-entry `{"bench": [...]}` micro-bench shape or the
/// single-entry `{"bench": {...}, "result": ...}` envelope.
fn timing_entries(doc: &Json) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    let Some(bench) = doc.opt("bench") else { return out };
    let one = |e: &Json| -> Option<(String, f64)> {
        Some((
            e.get("name").ok()?.as_str().ok()?.to_string(),
            e.get("mean_ms").ok()?.as_f64().ok()?,
        ))
    };
    match bench {
        Json::Arr(entries) => out.extend(entries.iter().filter_map(one)),
        single @ Json::Obj(_) => out.extend(one(single)),
        _ => {}
    }
    out
}

/// Compare two BENCH documents by bench name: for every entry present
/// in both, report `speedup = baseline_mean / fresh_mean` (>1 means the
/// fresh run is faster). Entries only on one side are listed so a
/// renamed or new bench is visible instead of silently dropped.
pub fn compare(baseline: &Json, fresh: &Json) -> String {
    let base = timing_entries(baseline);
    let new = timing_entries(fresh);
    let mut out = String::from(
        "# bench compare (speedup = baseline mean / fresh mean; >1.00x is faster)\n",
    );
    // Surface provenance notes (e.g. a committed seed-stub baseline)
    // so nobody reads placeholder ratios as real measurements.
    for (side, doc) in [("baseline", baseline), ("fresh", fresh)] {
        if let Some(note) = doc.opt("note").and_then(|n| n.as_str().ok()) {
            let _ = writeln!(out, "NOTE ({side}): {note}");
        }
    }
    let _ = writeln!(
        out,
        "{:<52} {:>12} {:>12} {:>9}",
        "bench", "baseline ms", "fresh ms", "speedup"
    );
    let mut matched = 0usize;
    for (name, fresh_ms) in &new {
        if let Some((_, base_ms)) = base.iter().find(|(n, _)| n == name) {
            matched += 1;
            let speedup = if *fresh_ms > 0.0 { base_ms / fresh_ms } else { f64::INFINITY };
            let _ = writeln!(
                out,
                "{:<52} {:>12.3} {:>12.3} {:>8.2}x",
                name, base_ms, fresh_ms, speedup
            );
        }
    }
    for (name, _) in &new {
        if !base.iter().any(|(n, _)| n == name) {
            let _ = writeln!(out, "{name:<52} {:>12} (new bench, no baseline)", "-");
        }
    }
    for (name, _) in &base {
        if !new.iter().any(|(n, _)| n == name) {
            let _ = writeln!(out, "{name:<52} {:>12} (baseline only, gone)", "-");
        }
    }
    if matched == 0 {
        out.push_str("(no overlapping bench names)\n");
    }
    out
}

/// [`compare`] over two BENCH files on disk.
pub fn compare_files(
    baseline_path: impl AsRef<std::path::Path>,
    fresh_path: impl AsRef<std::path::Path>,
) -> crate::error::Result<String> {
    let base = Json::parse(std::fs::read_to_string(baseline_path)?.trim())?;
    let fresh = Json::parse(std::fs::read_to_string(fresh_path)?.trim())?;
    Ok(compare(&base, &fresh))
}

/// Time `f` with `warmup` unmeasured runs and `iters` measured runs.
/// The closure's result is returned from the last run so the compiler
/// cannot elide the work.
pub fn bench<T>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: impl FnMut() -> T,
) -> (BenchResult, T) {
    assert!(iters >= 1);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    let mut last = None;
    for _ in 0..iters {
        let t0 = Instant::now();
        last = Some(std::hint::black_box(f()));
        times.push(t0.elapsed().as_secs_f64() * 1000.0);
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0f64, f64::max);
    (
        BenchResult {
            name: name.to_string(),
            iters,
            mean_ms: mean,
            min_ms: min,
            max_ms: max,
        },
        last.unwrap(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_envelope_round_trips() {
        let res = BenchResult {
            name: "unit".into(),
            iters: 3,
            mean_ms: 1.5,
            min_ms: 1.0,
            max_ms: 2.0,
        };
        let payload = obj(vec![("answer", Json::Num(42.0))]);
        let doc = envelope(&res, payload);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().get("iters").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(parsed.get("result").unwrap().get("answer").unwrap().as_f64().unwrap(), 42.0);

        let multi = timings_envelope(&[res.clone(), res]);
        let parsed = Json::parse(&multi.to_string()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn write_json_emits_parseable_file() {
        let path = std::env::temp_dir().join("gpulets_benchkit_test.json");
        write_json(&path, &obj(vec![("k", Json::Str("v".into()))])).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'));
        assert_eq!(
            Json::parse(text.trim()).unwrap().get("k").unwrap().as_str().unwrap(),
            "v"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compare_reports_speedups_and_orphans() {
        let mk = |name: &str, mean: f64| BenchResult {
            name: name.into(),
            iters: 1,
            mean_ms: mean,
            min_ms: mean,
            max_ms: mean,
        };
        let baseline = timings_envelope(&[mk("sweep", 12.0), mk("gone", 1.0)]);
        let fresh = timings_envelope(&[mk("sweep", 3.0), mk("brand-new", 2.0)]);
        let table = compare(&baseline, &fresh);
        assert!(table.contains("sweep"), "{table}");
        assert!(table.contains("4.00x"), "{table}");
        assert!(table.contains("brand-new") && table.contains("no baseline"), "{table}");
        assert!(table.contains("gone") && table.contains("baseline only"), "{table}");

        // The single-entry envelope shape also compares.
        let b1 = envelope(&mk("fig", 10.0), obj(vec![]));
        let f1 = envelope(&mk("fig", 5.0), obj(vec![]));
        assert!(compare(&b1, &f1).contains("2.00x"));
        // Disjoint names: flagged, not a panic.
        assert!(compare(&b1, &fresh).contains("no baseline"));
    }

    #[test]
    fn compare_surfaces_provenance_notes() {
        let base = Json::parse(
            r#"{"note":"SEED STUB: placeholder timings","bench":[{"name":"a","iters":1,"mean_ms":2.0,"min_ms":2.0,"max_ms":2.0}]}"#,
        )
        .unwrap();
        let fresh = timings_envelope(&[BenchResult {
            name: "a".into(),
            iters: 1,
            mean_ms: 1.0,
            min_ms: 1.0,
            max_ms: 1.0,
        }]);
        let table = compare(&base, &fresh);
        assert!(
            table.contains("NOTE (baseline): SEED STUB: placeholder timings"),
            "{table}"
        );
        assert!(table.contains("2.00x"), "{table}");
        // No note key: no NOTE line.
        assert!(!compare(&fresh, &fresh).contains("NOTE"), "notes must be opt-in");
    }

    #[test]
    fn compare_files_round_trips_via_disk() {
        let dir = std::env::temp_dir();
        let a = dir.join("gpulets_cmp_base.json");
        let b = dir.join("gpulets_cmp_fresh.json");
        let mk = |mean: f64| BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ms: mean,
            min_ms: mean,
            max_ms: mean,
        };
        write_json(&a, &timings_envelope(&[mk(8.0)])).unwrap();
        write_json(&b, &timings_envelope(&[mk(2.0)])).unwrap();
        let table = compare_files(&a, &b).unwrap();
        assert!(table.contains("4.00x"), "{table}");
        let _ = std::fs::remove_file(&a);
        let _ = std::fs::remove_file(&b);
    }

    #[test]
    fn times_work() {
        let (res, out) = bench("spin", 1, 3, || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert_eq!(out, (0..10_000u64).fold(0u64, |a, b| a.wrapping_add(b)));
        assert_eq!(res.iters, 3);
        assert!(res.min_ms <= res.mean_ms && res.mean_ms <= res.max_ms + 1e-9);
        assert!(res.summary().contains("spin"));
    }
}
