//! Minimal JSON parser + writer (offline stand-in for `serde_json`).
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) — enough to read `artifacts/manifest.json`
//! and to emit experiment result files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value. Object keys are kept sorted (BTreeMap) so output
/// is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::parse(format!(
                "trailing garbage at byte {} of JSON document",
                p.pos
            )));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => Err(Error::parse(format!("expected object, got {other:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(Error::parse(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(Error::parse(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(Error::parse(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(Error::parse(format!("expected bool, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(Error::parse(format!("expected non-negative integer, got {n}")));
        }
        Ok(n as usize)
    }

    /// Field lookup on an object.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::parse(format!("missing key {key:?}")))
    }

    /// Optional field lookup.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    // ---- writer ----------------------------------------------------------
    // Compact serialization via `Display` (so `json.to_string()` keeps
    // working through the blanket `ToString`).

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience: build an object from pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::parse(format!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            )))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::parse(format!(
                "unexpected {:?} at byte {}",
                other.map(|b| b as char),
                self.pos
            ))),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(Error::parse(format!("bad literal at byte {}", self.pos)))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => {
                    return Err(Error::parse(format!(
                        "expected ',' or '}}' at byte {}, found {:?}",
                        self.pos,
                        other.map(|b| b as char)
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => {
                    return Err(Error::parse(format!(
                        "expected ',' or ']' at byte {}, found {:?}",
                        self.pos,
                        other.map(|b| b as char)
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::parse("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(Error::parse("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| Error::parse("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::parse("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        other => {
                            return Err(Error::parse(format!(
                                "bad escape {:?}",
                                other.map(|b| b as char)
                            )))
                        }
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // UTF-8 passthrough: consume one code point.
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| Error::parse("invalid utf-8 in string"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::parse(format!("bad number {text:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{"batch_sizes":[1,2,32],"models":{"lenet":{"slo_ms":5.0,"artifacts":{"1":{"file":"lenet_b1.hlo.txt","input_shape":[1,28,28,1]}}}}}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("batch_sizes").unwrap().as_arr().unwrap().len(), 3);
        let slo = v
            .get("models").unwrap()
            .get("lenet").unwrap()
            .get("slo_ms").unwrap()
            .as_f64().unwrap();
        assert_eq!(slo, 5.0);
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,-3],"b":"hi\nthere","c":true,"d":null,"e":{}}"#;
        let v = Json::parse(doc).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""A\t\"x\"""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A\t\"x\"");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64().unwrap(), -1500.0);
        assert_eq!(Json::parse("42").unwrap().as_usize().unwrap(), 42);
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse(r#""héllo — ok""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ok");
    }
}
