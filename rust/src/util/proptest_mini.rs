//! Miniature property-testing harness (offline stand-in for `proptest`).
//!
//! `Runner::run` draws N random cases from a user generator, checks a
//! property, and on failure retries the failing case through a
//! user-supplied shrink function until it reaches a local minimum —
//! then panics with the seed and the minimal counterexample's Debug.

use crate::util::rng::Pcg32;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_iters: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, seed: 0xC0FFEE, max_shrink_iters: 500 }
    }
}

/// Run `property` against `cases` inputs drawn from `gen`.
///
/// * `gen`: draws a random case from the RNG.
/// * `shrink`: proposes strictly "smaller" variants of a failing case
///   (return an empty vec when no further shrinking is possible).
/// * `property`: returns `Err(reason)` on violation.
pub fn run<T, G, S, P>(cfg: Config, mut gen: G, shrink: S, property: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Pcg32) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Pcg32::seeded(cfg.seed);
    for case_idx in 0..cfg.cases {
        let case = gen(&mut rng);
        if let Err(first_reason) = property(&case) {
            // Shrink to a local minimum.
            let mut best = case;
            let mut reason = first_reason;
            let mut iters = 0;
            'outer: loop {
                if iters >= cfg.max_shrink_iters {
                    break;
                }
                for candidate in shrink(&best) {
                    iters += 1;
                    if let Err(r) = property(&candidate) {
                        best = candidate;
                        reason = r;
                        continue 'outer;
                    }
                    if iters >= cfg.max_shrink_iters {
                        break 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (seed={:#x}, case #{case_idx}): {reason}\nminimal counterexample: {best:#?}",
                cfg.seed
            );
        }
    }
}

/// Shrinker for vectors: drop one element at a time.
pub fn shrink_vec_by_removal<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    (0..v.len())
        .map(|i| {
            let mut c = v.to_vec();
            c.remove(i);
            c
        })
        .collect()
}

/// Shrinker for non-negative numbers: halve toward zero.
pub fn shrink_f64(x: f64) -> Vec<f64> {
    if x.abs() < 1e-9 {
        vec![]
    } else {
        vec![0.0, x / 2.0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        run(
            Config { cases: 64, ..Default::default() },
            |rng| rng.below(100),
            |_| vec![],
            |&x| {
                if x < 100 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_counterexample() {
        run(
            Config { cases: 64, ..Default::default() },
            |rng| rng.below(100) as i64,
            |&x| if x > 0 { vec![x / 2] } else { vec![] },
            |&x| {
                if x < 50 {
                    Ok(())
                } else {
                    Err(format!("{x} >= 50"))
                }
            },
        );
    }

    #[test]
    fn shrinkers() {
        assert_eq!(shrink_vec_by_removal(&[1, 2, 3]).len(), 3);
        assert!(shrink_f64(0.0).is_empty());
        assert_eq!(shrink_f64(8.0), vec![0.0, 4.0]);
    }
}
