//! Tiny leveled logger (offline stand-in for `log` + `env_logger`).
//!
//! Level comes from `GPULETS_LOG` (error|warn|info|debug|trace), default
//! `info`. Output goes to stderr so experiment stdout stays parseable.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn init_from_env() -> u8 {
    let lvl = match std::env::var("GPULETS_LOG").ok().as_deref() {
        Some("error") => Level::Error,
        Some("warn") => Level::Warn,
        Some("debug") => Level::Debug,
        Some("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    MAX_LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// True if `level` is currently enabled.
pub fn enabled(level: Level) -> bool {
    let mut max = MAX_LEVEL.load(Ordering::Relaxed);
    if max == 255 {
        max = init_from_env();
    }
    (level as u8) <= max
}

/// Force the level (used by tests and the CLI `-q`/`-v` flags).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Log at a level; prefer the macros.
pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {args}");
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
    }
}
