//! Statistics helpers: summaries, percentiles, CDFs, EWMA, online histograms.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation on the sorted copy. `q` in [0,100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    percentile_sorted(&v, q)
}

/// Percentile on an already-sorted slice.
pub fn percentile_sorted(v: &[f64], q: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 100.0);
    let rank = q / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let f = rank - lo as f64;
        v[lo] * (1.0 - f) + v[hi] * f
    }
}

/// Empirical CDF evaluated at chosen quantile levels: returns (q, value) rows.
pub fn cdf_points(xs: &[f64], qs: &[f64]) -> Vec<(f64, f64)> {
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    qs.iter().map(|&q| (q, percentile_sorted(&v, q))).collect()
}

/// Fraction of samples <= x.
pub fn cdf_at(xs: &[f64], x: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().filter(|&&v| v <= x).count() as f64 / xs.len() as f64
}

/// Exponentially-weighted moving average — the paper tracks per-model
/// request rates with an EWMA to decide when to re-schedule (§4.3).
#[derive(Clone, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    /// `alpha` in (0, 1]: weight of the newest observation.
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha out of (0,1]");
        Ewma { alpha, value: None }
    }

    /// Feed one observation, returning the updated average.
    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    /// Current average (None until the first update).
    pub fn get(&self) -> Option<f64> {
        self.value
    }

    /// Reset to the unobserved state.
    pub fn reset(&mut self) {
        self.value = None;
    }
}

/// Fixed-bin latency histogram (ms) with overflow bin; cheap percentile
/// queries for serving metrics without retaining every sample.
#[derive(Clone, Debug)]
pub struct Histogram {
    bin_width: f64,
    bins: Vec<u64>,
    overflow: u64,
    count: u64,
    sum: f64,
    max: f64,
}

impl Histogram {
    pub fn new(bin_width: f64, num_bins: usize) -> Self {
        assert!(bin_width > 0.0 && num_bins > 0);
        Histogram {
            bin_width,
            bins: vec![0; num_bins],
            overflow: 0,
            count: 0,
            sum: 0.0,
            max: 0.0,
        }
    }

    pub fn record(&mut self, x: f64) {
        let idx = (x / self.bin_width) as usize;
        if idx < self.bins.len() {
            self.bins[idx] += 1;
        } else {
            self.overflow += 1;
        }
        self.count += 1;
        self.sum += x;
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Approximate percentile, interpolated within the containing bin.
    ///
    /// The old implementation returned the bin's *upper edge*
    /// (`(i+1) * bin_width`), overstating every quantile by up to one
    /// bin width — with the report histograms' 0.5 ms bins that biased
    /// p50/p99 latencies high by up to 0.5 ms. The fractional rank is
    /// now placed uniformly inside the bin (the standard histogram-
    /// quantile estimate), a rank landing past the counted bins is
    /// resolved from the overflow bin explicitly (it has no upper edge
    /// to interpolate against, so the tracked true maximum is
    /// reported), and no estimate ever exceeds the observed maximum.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        // Fractional rank in (0, count]; q=0 maps to the lower edge of
        // the first occupied bin, q=100 to the maximum.
        let target = q.clamp(0.0, 100.0) / 100.0 * self.count as f64;
        let in_bins = self.count - self.overflow;
        if target > in_bins as f64 {
            // The rank lands in the overflow bin.
            return self.max;
        }
        let mut seen = 0u64;
        for (i, &c) in self.bins.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let before = seen as f64;
            seen += c;
            if seen as f64 >= target {
                let frac = ((target - before) / c as f64).clamp(0.0, 1.0);
                return ((i as f64 + frac) * self.bin_width).min(self.max);
            }
        }
        self.max
    }

    pub fn reset(&mut self) {
        self.bins.iter_mut().for_each(|b| *b = 0);
        self.overflow = 0;
        self.count = 0;
        self.sum = 0.0;
        self.max = 0.0;
    }

    /// Fold `other` into `self` bin-by-bin. Because binning is
    /// deterministic, the merged histogram is *exactly* the histogram
    /// that would have recorded both sample sets in one pass — so the
    /// interpolated percentiles of a fleet-merged report equal those of
    /// an equivalent single-server run, never an approximation of an
    /// approximation. Both histograms must share the same bin geometry
    /// (all serving metrics use one configuration).
    pub fn merge(&mut self, other: &Histogram) {
        // lint: no-alloc — merge runs per node per window on the fleet
        // hot path; both arms reuse `self`'s bin allocation.
        assert!(
            self.bin_width == other.bin_width && self.bins.len() == other.bins.len(),
            "merging histograms with different bin geometry ({} x {} vs {} x {})",
            self.bin_width,
            self.bins.len(),
            other.bin_width,
            other.bins.len(),
        );
        if self.count == 0 && self.overflow == 0 {
            // Nothing recorded yet: adopt `other`'s bins wholesale
            // (reusing our allocation) instead of adding into a zeroed
            // vector — `0 + x == x` for every counter, and `sum`/`max`
            // start at exactly 0.0, so this is bit-identical to the
            // general path below.
            self.bins.clone_from(&other.bins);
            self.overflow = other.overflow;
            self.count = other.count;
            self.sum += other.sum;
            if other.max > self.max {
                self.max = other.max;
            }
            self.debug_check_conserved();
            return;
        }
        for (b, o) in self.bins.iter_mut().zip(other.bins.iter()) {
            *b += o;
        }
        self.overflow += other.overflow;
        self.count += other.count;
        self.sum += other.sum;
        if other.max > self.max {
            self.max = other.max;
        }
        self.debug_check_conserved();
        // lint: end-no-alloc
    }

    /// Debug-only conservation check: binned + overflow observations
    /// must equal the total count — `record` maintains this one sample
    /// at a time, and both `merge` arms must preserve it exactly (the
    /// merged bins are the sum of the inputs' bins).
    fn debug_check_conserved(&self) {
        debug_assert_eq!(
            self.bins.iter().sum::<u64>() + self.overflow,
            self.count,
            "histogram bins diverged from the observation count"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((std_dev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 90.0) - 90.1).abs() < 0.2);
    }

    #[test]
    fn cdf() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(cdf_at(&xs, 2.0), 0.5);
        assert_eq!(cdf_at(&xs, 0.0), 0.0);
        assert_eq!(cdf_at(&xs, 10.0), 1.0);
        let pts = cdf_points(&xs, &[50.0]);
        assert_eq!(pts.len(), 1);
    }

    #[test]
    fn ewma_behaviour() {
        let mut e = Ewma::new(0.5);
        assert_eq!(e.get(), None);
        assert_eq!(e.update(10.0), 10.0);
        assert_eq!(e.update(20.0), 15.0);
        assert_eq!(e.update(20.0), 17.5);
        e.reset();
        assert_eq!(e.get(), None);
    }

    #[test]
    #[should_panic]
    fn ewma_rejects_bad_alpha() {
        Ewma::new(0.0);
    }

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::new(1.0, 200);
        for i in 1..=100 {
            h.record(i as f64 - 0.5);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 50.0).abs() < 0.01);
        let p50 = h.percentile(50.0);
        assert!((49.0..=51.0).contains(&p50), "p50={p50}");
        let p99 = h.percentile(99.0);
        assert!((98.0..=100.0).contains(&p99), "p99={p99}");
    }

    #[test]
    fn histogram_overflow_and_reset() {
        let mut h = Histogram::new(1.0, 10);
        h.record(100.0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.percentile(100.0), 100.0);
        h.reset();
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn empty_inputs_return_zero_not_nan() {
        // Empty-slice guards across the free functions (the engine's
        // report math must never emit NaN into a JSON payload).
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile_sorted(&[], 99.0), 0.0);
        assert_eq!(cdf_at(&[], 1.0), 0.0);
        let pts = cdf_points(&[], &[50.0]);
        assert_eq!(pts, vec![(50.0, 0.0)]);
    }

    #[test]
    fn histogram_percentile_empty_is_zero() {
        let h = Histogram::new(0.5, 10);
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.percentile(99.0), 0.0);
    }

    #[test]
    fn histogram_percentile_single_sample_not_upper_edge() {
        // One 0.25 ms sample in a 0.5 ms bin: the old upper-edge rule
        // reported every quantile as 0.5 ms (a +100% bias); the
        // interpolated estimate stays within the bin and never exceeds
        // the observed maximum.
        let mut h = Histogram::new(0.5, 100);
        h.record(0.25);
        let p50 = h.percentile(50.0);
        assert!(p50 <= 0.25 + 1e-12, "p50={p50} exceeds the observed max");
        assert!(p50 > 0.0);
        assert_eq!(h.percentile(100.0), 0.25);
        assert_eq!(h.percentile(0.0), 0.0); // lower edge of the bin
    }

    #[test]
    fn histogram_percentile_interpolates_within_bin() {
        // 100 samples spread over bins [0,1) and [1,2): p25 must land
        // inside the first bin, p75 inside the second, both strictly
        // below the old upper-edge answers (1.0 / 2.0).
        let mut h = Histogram::new(1.0, 10);
        for _ in 0..50 {
            h.record(0.5);
        }
        for _ in 0..50 {
            h.record(1.5);
        }
        let p25 = h.percentile(25.0);
        assert!((0.0..1.0).contains(&p25), "p25={p25}");
        let p75 = h.percentile(75.0);
        assert!((1.0..2.0).contains(&p75), "p75={p75}");
        assert_eq!(h.percentile(100.0), 1.5); // capped at the true max
    }

    #[test]
    fn histogram_merge_empty_into_full_and_back() {
        let mut full = Histogram::new(0.5, 100);
        for i in 1..=20 {
            full.record(i as f64 * 0.3);
        }
        let snapshot = (full.count(), full.mean(), full.max(), full.percentile(50.0));
        // Merging an empty histogram is the identity…
        let empty = Histogram::new(0.5, 100);
        full.merge(&empty);
        assert_eq!(
            (full.count(), full.mean(), full.max(), full.percentile(50.0)),
            snapshot
        );
        // …and merging into an empty one reproduces the original.
        let mut target = Histogram::new(0.5, 100);
        target.merge(&full);
        assert_eq!(
            (target.count(), target.mean(), target.max(), target.percentile(50.0)),
            snapshot
        );
        assert_eq!(target.percentile(99.0), full.percentile(99.0));
    }

    #[test]
    fn histogram_merge_combines_overflow_bins_and_true_max() {
        let mut a = Histogram::new(1.0, 4);
        a.record(0.5);
        a.record(50.0); // overflow
        let mut b = Histogram::new(1.0, 4);
        b.record(80.0); // overflow, larger true max
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max(), 80.0);
        // Ranks landing in the merged overflow mass report the merged
        // true maximum (there is no upper edge to interpolate against).
        assert_eq!(a.percentile(99.0), 80.0);
        assert_eq!(a.percentile(100.0), 80.0);
        // Low ranks still resolve inside the counted bins.
        let p10 = a.percentile(10.0);
        assert!((0.0..1.0).contains(&p10), "p10={p10}");
    }

    #[test]
    fn histogram_merge_percentiles_match_single_pass() {
        // Interpolated-percentile stability: merging two histograms is
        // byte-for-byte the histogram of the concatenated samples, so
        // every percentile matches the single-pass answer exactly.
        // (Samples are multiples of 0.5 so the running sums are exact
        // and even the means compare bit-for-bit.)
        let samples_a: Vec<f64> = (0..250).map(|i| ((i * 7) % 180) as f64 * 0.5).collect();
        let samples_b: Vec<f64> = (0..175).map(|i| 40.0 + ((i * 13) % 120) as f64 * 0.5).collect();
        let mut one_pass = Histogram::new(0.5, 2000);
        let mut a = Histogram::new(0.5, 2000);
        let mut b = Histogram::new(0.5, 2000);
        for &x in &samples_a {
            one_pass.record(x);
            a.record(x);
        }
        for &x in &samples_b {
            one_pass.record(x);
            b.record(x);
        }
        a.merge(&b);
        for q in [0.0, 1.0, 25.0, 50.0, 75.0, 90.0, 99.0, 99.9, 100.0] {
            assert_eq!(a.percentile(q), one_pass.percentile(q), "q={q}");
        }
        assert_eq!(a.count(), one_pass.count());
        assert_eq!(a.mean(), one_pass.mean());
        assert_eq!(a.max(), one_pass.max());
    }

    #[test]
    #[should_panic(expected = "different bin geometry")]
    fn histogram_merge_rejects_mismatched_geometry() {
        let mut a = Histogram::new(0.5, 100);
        let b = Histogram::new(1.0, 100);
        a.merge(&b);
    }

    #[test]
    fn histogram_percentile_overflow_heavy() {
        // Most of the mass past the counted bins: any rank landing in
        // the overflow bin reports the tracked true maximum explicitly
        // (there is no upper edge to interpolate against).
        let mut h = Histogram::new(1.0, 4);
        h.record(0.5);
        for k in 0..9 {
            h.record(50.0 + k as f64);
        }
        assert_eq!(h.percentile(99.0), 58.0);
        assert_eq!(h.percentile(100.0), 58.0);
        // The sub-10% ranks still resolve inside the counted bins.
        let p5 = h.percentile(5.0);
        assert!((0.0..1.0).contains(&p5), "p5={p5}");
    }
}
