//! Deterministic node-fault plans for fleet robustness runs.
//!
//! A [`FaultPlan`] scripts when fleet nodes die and recover:
//! `NodeDown{at, node}` destroys the node's queued backlog and
//! in-flight work (accounted as `lost_to_failure`) and `NodeUp{at,
//! node}` re-admits it. The fleet engine consumes the plan at lockstep
//! window boundaries — an event with time `t` fires at the first
//! boundary `>= t` — so fault timing is a pure function of the plan and
//! the window grid, independent of worker-thread count (the repo's
//! byte-identity invariant extends to faulty runs).
//!
//! Plans come from two deterministic constructors: a TOML `[faults]`
//! section (`events = ["down@12.5:0", "up@30:0"]`, each entry
//! `kind@seconds:node`) and a seeded generator ([`FaultPlan::generate`])
//! that draws non-overlapping down→up episodes from a `Pcg32` stream.

use crate::error::{Error, Result};
use crate::util::rng::Pcg32;
use crate::util::tomlmini::TomlDoc;

/// What happens to the node at the event time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The node fails: its queued/in-flight work is lost (counted) and
    /// the survivors are re-planned.
    Down,
    /// The node recovers and is re-admitted at the next re-plan.
    Up,
}

/// One scripted fault event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    /// Virtual time (s) the event takes effect (snapped to the next
    /// lockstep boundary by the consumer).
    pub at_s: f64,
    /// Fleet node index.
    pub node: usize,
    pub kind: FaultKind,
}

/// A time-sorted script of node failures and recoveries.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Events sorted by `at_s` (stable: equal times keep insertion
    /// order, so "down then up at t" means exactly that).
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// A plan from explicit events; times must be finite and
    /// non-negative. Events are stably sorted by time.
    pub fn new(mut events: Vec<FaultEvent>) -> Result<FaultPlan> {
        for e in &events {
            if !e.at_s.is_finite() || e.at_s < 0.0 {
                return Err(Error::parse(format!(
                    "fault event time must be finite and >= 0, got {}",
                    e.at_s
                )));
            }
        }
        events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        Ok(FaultPlan { events })
    }

    /// The empty plan (no faults) — the default for every run.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Parse the `[faults]` TOML section of `doc`: an `events` array of
    /// `"kind@seconds:node"` strings, e.g.
    /// `events = ["down@12.5:0", "up@30:0"]`. A missing section is the
    /// empty plan.
    pub fn from_toml(doc: &TomlDoc) -> Result<FaultPlan> {
        let Some(v) = doc.get("faults.events") else {
            return Ok(FaultPlan::none());
        };
        let mut events = Vec::new();
        for item in v.as_arr()? {
            events.push(parse_event(item.as_str()?)?);
        }
        FaultPlan::new(events)
    }

    /// A seeded random plan: `episodes` non-overlapping down→up pairs,
    /// each on a random node, with the down time uniform in the first
    /// 70% of the horizon and an outage of 5–25% of it (clipped to the
    /// horizon — a node still down at the end simply never recovers).
    /// Episodes that would overlap an existing outage on the same node
    /// are skipped, so the plan is always well-formed. Deterministic in
    /// `(seed, nodes, duration_s, episodes)`.
    pub fn generate(
        seed: u64,
        nodes: usize,
        duration_s: f64,
        episodes: usize,
    ) -> Result<FaultPlan> {
        if nodes == 0 {
            return Err(Error::parse("fault plan needs >= 1 node".into()));
        }
        if !duration_s.is_finite() || duration_s <= 0.0 {
            return Err(Error::parse(format!("bad fault horizon {duration_s}")));
        }
        let mut rng = Pcg32::new(seed, 0xFA17);
        let mut spans: Vec<(usize, f64, f64)> = Vec::new(); // (node, down, up)
        for _ in 0..episodes {
            let node = rng.below(nodes);
            let down = rng.f64() * 0.7 * duration_s;
            let up = down + (0.05 + rng.f64() * 0.20) * duration_s;
            let overlaps = spans
                .iter()
                .any(|&(n, d, u)| n == node && down < u && d < up);
            if !overlaps {
                spans.push((node, down, up));
            }
        }
        let mut events = Vec::new();
        for (node, down, up) in spans {
            events.push(FaultEvent { at_s: down, node, kind: FaultKind::Down });
            if up < duration_s {
                events.push(FaultEvent { at_s: up, node, kind: FaultKind::Up });
            }
        }
        FaultPlan::new(events)
    }

    /// The scripted events, time-sorted.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Largest node index referenced, if any — fleet construction
    /// validates it against the actual node count.
    pub fn max_node(&self) -> Option<usize> {
        self.events.iter().map(|e| e.node).max()
    }
}

/// One `"kind@seconds:node"` event, e.g. `"down@12.5:0"`.
fn parse_event(s: &str) -> Result<FaultEvent> {
    let bad = || Error::parse(format!("bad fault event {s:?} (want kind@seconds:node)"));
    let (kind, rest) = s.split_once('@').ok_or_else(bad)?;
    let kind = match kind.trim() {
        "down" => FaultKind::Down,
        "up" => FaultKind::Up,
        _ => return Err(bad()),
    };
    let (at, node) = rest.split_once(':').ok_or_else(bad)?;
    let at_s: f64 = at.trim().parse().map_err(|_| bad())?;
    let node: usize = node.trim().parse().map_err(|_| bad())?;
    Ok(FaultEvent { at_s, node, kind })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_toml_events_and_sorts() {
        let doc = TomlDoc::parse(
            "[faults]\nevents = [\"up@30:1\", \"down@12.5:1\", \"down@40:0\"]\n",
        )
        .unwrap();
        let plan = FaultPlan::from_toml(&doc).unwrap();
        let ev = plan.events();
        assert_eq!(ev.len(), 3);
        assert_eq!(ev[0], FaultEvent { at_s: 12.5, node: 1, kind: FaultKind::Down });
        assert_eq!(ev[1], FaultEvent { at_s: 30.0, node: 1, kind: FaultKind::Up });
        assert_eq!(ev[2].kind, FaultKind::Down);
        assert_eq!(plan.max_node(), Some(1));
    }

    #[test]
    fn missing_section_is_empty_plan() {
        let doc = TomlDoc::parse("[fleet]\nnodes = 4\n").unwrap();
        assert!(FaultPlan::from_toml(&doc).unwrap().is_empty());
        assert!(FaultPlan::none().is_empty());
        assert_eq!(FaultPlan::none().max_node(), None);
    }

    #[test]
    fn rejects_malformed_events() {
        for bad in ["sideways@1:0", "down@x:0", "down@1:x", "down@1", "down"] {
            let doc =
                TomlDoc::parse(&format!("[faults]\nevents = [\"{bad}\"]\n")).unwrap();
            assert!(FaultPlan::from_toml(&doc).is_err(), "{bad} must not parse");
        }
        assert!(FaultPlan::new(vec![FaultEvent {
            at_s: f64::NAN,
            node: 0,
            kind: FaultKind::Down,
        }])
        .is_err());
    }

    #[test]
    fn generator_is_deterministic_and_well_formed() {
        let a = FaultPlan::generate(7, 4, 100.0, 3).unwrap();
        let b = FaultPlan::generate(7, 4, 100.0, 3).unwrap();
        assert_eq!(a, b, "same seed must script the same faults");
        assert!(!a.is_empty());
        // Per node, downs and ups strictly alternate (no double-down).
        for node in 0..4 {
            let mut down = false;
            for e in a.events().iter().filter(|e| e.node == node) {
                match e.kind {
                    FaultKind::Down => {
                        assert!(!down, "node {node} went down twice");
                        down = true;
                    }
                    FaultKind::Up => {
                        assert!(down, "node {node} came up while up");
                        down = false;
                    }
                }
            }
        }
        // Different seeds differ (overwhelmingly likely).
        let c = FaultPlan::generate(8, 4, 100.0, 3).unwrap();
        assert_ne!(a, c);
        assert!(FaultPlan::generate(7, 0, 100.0, 1).is_err());
        assert!(FaultPlan::generate(7, 4, f64::NAN, 1).is_err());
    }
}
