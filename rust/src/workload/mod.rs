//! Workload generation: Poisson request arrivals, the paper's request
//! scenarios (Table 5 + the 1,023-scenario population), and the Fig 14
//! rate-fluctuation traces.

pub mod generator;
pub mod scenarios;
pub mod trace;

pub use generator::{generate_arrivals, Arrival};
pub use scenarios::{enumerate_all_scenarios, named_scenarios, Scenario};
pub use trace::FluctuationTrace;
