//! Workload generation: Poisson request arrivals (materialized traces
//! and pull-based streams), the paper's request scenarios (Table 5 +
//! the 1,023-scenario population), the Fig 14 rate-fluctuation traces,
//! flash-crowd burst sources, and scripted node-fault plans.

pub mod fault;
pub mod flashcrowd;
pub mod generator;
pub mod scenarios;
pub mod source;
pub mod trace;

pub use fault::{FaultEvent, FaultKind, FaultPlan};
pub use flashcrowd::{flashcrowd_streams, FlashCrowdSource, FlashCrowdSpec};
pub use generator::{generate_arrivals, generate_varying, Arrival};
pub use scenarios::{enumerate_all_scenarios, named_scenarios, Scenario};
pub use source::{
    dyn_sources, poisson_streams, varying_streams, ArrivalSource, DynSource,
    DynSourceMux, MaterializedSource, PoissonSource, SourceMux, VaryingSource,
};
pub use trace::FluctuationTrace;
