//! Poisson arrival generation (§6.1: "we sample inter-arrival time for
//! each model from a Poisson random distribution", following Treadmill's
//! observation that real-world arrivals are Poisson).
//!
//! Since PR 4 the materializing generators are thin wrappers over the
//! pull-based streams in [`super::source`]: each model's stream draws
//! the same `Pcg32` sequence as before, the [`super::SourceMux`] k-way
//! merge reproduces the old stable sort order exactly (a frozen copy of
//! the sort-based implementation pins this in the tests below), and the
//! global sort + full trace materialization are gone from the serving
//! hot path — `generate_arrivals` only materializes when a caller
//! actually asks for a `Vec<Arrival>`.
//!
//! Rates are validated at this boundary: non-finite or negative rates
//! are caller bugs reported as a proper `Error` (the same NaN class
//! `sched::types::validate_rates` rejects at `Scheduler::schedule`)
//! instead of panicking inside a sort or looping forever.

use crate::error::{Error, Result};
use crate::models::ModelId;

use super::source::{poisson_streams, varying_streams, SourceMux};

/// One inference request arrival.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Arrival {
    /// Virtual arrival time in ms.
    pub time_ms: f64,
    /// Requested model.
    pub model: ModelId,
    /// Request id, unique within a generated trace.
    pub id: u64,
}

pub(crate) fn validate_rate(model: ModelId, rate: f64) -> Result<()> {
    if !rate.is_finite() || rate < 0.0 {
        return Err(Error::Model(format!("{model}: invalid arrival rate {rate}")));
    }
    Ok(())
}

pub(crate) fn validate_duration(duration_s: f64) -> Result<()> {
    // A NaN/∞ horizon would make the sampling loops run away (the
    // comparison against it is never true) rather than fail.
    if !duration_s.is_finite() || duration_s < 0.0 {
        return Err(Error::Model(format!("invalid trace duration {duration_s} s")));
    }
    Ok(())
}

pub(crate) fn validate_step(step_s: f64) -> Result<()> {
    if !(step_s.is_finite() && step_s > 0.0) {
        return Err(Error::Model(format!("invalid rate step {step_s} s")));
    }
    Ok(())
}

/// Generate a merged, time-sorted arrival trace for `duration_s` seconds
/// where each model's arrivals form an independent Poisson process at
/// its configured rate (req/s). Zero-rate models produce no arrivals;
/// non-finite or negative rates are rejected with an error.
///
/// Materializing adapter over [`super::source::poisson_streams`] — the
/// serving engine consumes the streams directly without this `Vec`.
pub fn generate_arrivals(
    rates: &[(ModelId, f64)],
    duration_s: f64,
    seed: u64,
) -> Result<Vec<Arrival>> {
    Ok(SourceMux::new(poisson_streams(rates, duration_s, seed)?).materialize())
}

/// Generate arrivals for a time-varying rate function, treated as
/// piecewise-constant over `step_s` windows (used by the Fig 14
/// fluctuation experiment).
///
/// Samples the exact inhomogeneous process by integrating unit-rate
/// exposure: one `Exp(1)` draw is consumed against `rate * dt` across
/// step boundaries, so the residual inter-arrival time carries over
/// instead of being re-drawn at every step (the old per-step restart
/// leaned on exponential memorylessness; carrying the residual is the
/// canonical sampler, stays exact under the rate change itself, and
/// draws one exponential per arrival instead of one extra per step).
///
/// Materializing adapter over [`super::source::varying_streams`].
pub fn generate_varying<F>(
    models: &[ModelId],
    rate_at: F,
    duration_s: f64,
    step_s: f64,
    seed: u64,
) -> Result<Vec<Arrival>>
where
    F: Fn(ModelId, f64) -> f64 + Clone,
{
    Ok(SourceMux::new(varying_streams(models, rate_at, duration_s, step_s, seed)?)
        .materialize())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Frozen copy of the pre-streaming `generate_arrivals` (global
    /// sort over fully materialized per-model streams, PR 3 state):
    /// the mux must reproduce it element-for-element.
    fn frozen_generate_arrivals(
        rates: &[(ModelId, f64)],
        duration_s: f64,
        seed: u64,
    ) -> Vec<Arrival> {
        let mut out = Vec::new();
        let horizon_ms = duration_s * 1000.0;
        for (i, &(model, rate)) in rates.iter().enumerate() {
            if rate <= 0.0 {
                continue;
            }
            let mut rng = Pcg32::new(seed, i as u64 + 1);
            let mut t = 0.0;
            loop {
                t += rng.exp(rate) * 1000.0;
                if t >= horizon_ms {
                    break;
                }
                out.push(Arrival { time_ms: t, model, id: 0 });
            }
        }
        out.sort_by(|a, b| a.time_ms.total_cmp(&b.time_ms));
        for (i, a) in out.iter_mut().enumerate() {
            a.id = i as u64;
        }
        out
    }

    /// Frozen copy of the pre-streaming `generate_varying` sampler.
    fn frozen_generate_varying<F>(
        models: &[ModelId],
        rate_at: F,
        duration_s: f64,
        step_s: f64,
        seed: u64,
    ) -> Vec<Arrival>
    where
        F: Fn(ModelId, f64) -> f64,
    {
        let mut out = Vec::new();
        for (i, &model) in models.iter().enumerate() {
            let mut rng = Pcg32::new(seed, i as u64 + 101);
            let mut win = 0u64;
            let mut t = 0.0f64;
            let mut need = rng.exp(1.0);
            loop {
                let w0 = win as f64 * step_s;
                if w0 >= duration_s {
                    break;
                }
                let window_end = ((win + 1) as f64 * step_s).min(duration_s);
                let rate = rate_at(model, w0);
                if rate <= 0.0 {
                    win += 1;
                    t = window_end;
                    continue;
                }
                let t_lo = t.max(w0);
                let exposure = rate * (window_end - t_lo).max(0.0);
                if need < exposure {
                    let t_arr = t_lo + need / rate;
                    if t_arr < duration_s {
                        out.push(Arrival { time_ms: t_arr * 1000.0, model, id: 0 });
                    }
                    t = t_arr;
                    need = rng.exp(1.0);
                } else {
                    need -= exposure;
                    win += 1;
                    t = window_end;
                }
            }
        }
        out.sort_by(|a, b| a.time_ms.total_cmp(&b.time_ms));
        for (i, a) in out.iter_mut().enumerate() {
            a.id = i as u64;
        }
        out
    }

    #[test]
    fn streaming_matches_frozen_sort_based_generator() {
        let rates = [
            (ModelId::Lenet, 150.0),
            (ModelId::Googlenet, 80.0),
            (ModelId::Resnet, 0.0),
            (ModelId::SsdMobilenet, 33.0),
            (ModelId::Vgg, 60.0),
        ];
        for seed in [1u64, 42, 2024] {
            let new = generate_arrivals(&rates, 20.0, seed).unwrap();
            let old = frozen_generate_arrivals(&rates, 20.0, seed);
            assert_eq!(new, old, "seed {seed}: mux order diverged from sort order");
        }
    }

    #[test]
    fn streaming_matches_frozen_varying_generator() {
        let wave = |m: ModelId, t: f64| {
            40.0 + 30.0 * ((t / 60.0 + m.index() as f64).sin().abs())
        };
        for seed in [5u64, 99] {
            let new = generate_varying(&ModelId::ALL, wave, 90.0, 1.0, seed).unwrap();
            let old = frozen_generate_varying(&ModelId::ALL, wave, 90.0, 1.0, seed);
            assert_eq!(new, old, "seed {seed}: varying mux diverged");
        }
    }

    #[test]
    fn empirical_rate_matches_request() {
        let arrivals = generate_arrivals(&[(ModelId::Lenet, 200.0)], 30.0, 1).unwrap();
        let rate = arrivals.len() as f64 / 30.0;
        assert!((rate - 200.0).abs() < 15.0, "rate={rate}");
    }

    #[test]
    fn sorted_and_unique_ids() {
        let arrivals = generate_arrivals(
            &[(ModelId::Lenet, 100.0), (ModelId::Vgg, 50.0)],
            10.0,
            2,
        )
        .unwrap();
        assert!(arrivals.windows(2).all(|w| w[0].time_ms <= w[1].time_ms));
        for (i, a) in arrivals.iter().enumerate() {
            assert_eq!(a.id, i as u64);
        }
    }

    #[test]
    fn zero_rate_no_arrivals() {
        let arrivals = generate_arrivals(&[(ModelId::Lenet, 0.0)], 10.0, 3).unwrap();
        assert!(arrivals.is_empty());
    }

    #[test]
    fn invalid_rates_rejected_not_panicking() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -5.0] {
            let err = generate_arrivals(&[(ModelId::Lenet, bad)], 1.0, 1).unwrap_err();
            assert!(err.to_string().contains("invalid arrival rate"), "{err}");
            let err = generate_varying(&[ModelId::Lenet], move |_, _| bad, 1.0, 1.0, 1)
                .unwrap_err();
            assert!(err.to_string().contains("invalid arrival rate"), "{err}");
        }
        assert!(generate_varying(&[ModelId::Lenet], |_, _| 1.0, 1.0, f64::NAN, 1)
            .is_err());
        // Non-finite durations would otherwise loop forever / OOM.
        for bad in [f64::NAN, f64::INFINITY] {
            assert!(generate_arrivals(&[(ModelId::Lenet, 1.0)], bad, 1).is_err());
            assert!(generate_varying(&[ModelId::Lenet], |_, _| 1.0, bad, 1.0, 1)
                .is_err());
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate_arrivals(&[(ModelId::Resnet, 100.0)], 5.0, 7).unwrap();
        let b = generate_arrivals(&[(ModelId::Resnet, 100.0)], 5.0, 7).unwrap();
        assert_eq!(a, b);
        let c = generate_arrivals(&[(ModelId::Resnet, 100.0)], 5.0, 8).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn per_model_streams_independent() {
        // Adding a second model must not perturb the first's arrivals.
        let solo = generate_arrivals(&[(ModelId::Lenet, 100.0)], 5.0, 9).unwrap();
        let duo = generate_arrivals(
            &[(ModelId::Lenet, 100.0), (ModelId::Vgg, 100.0)],
            5.0,
            9,
        )
        .unwrap();
        let lenet_times: Vec<f64> = duo
            .iter()
            .filter(|a| a.model == ModelId::Lenet)
            .map(|a| a.time_ms)
            .collect();
        let solo_times: Vec<f64> = solo.iter().map(|a| a.time_ms).collect();
        assert_eq!(lenet_times, solo_times);
    }

    #[test]
    fn varying_rate_tracks_windows() {
        let arr = generate_varying(
            &[ModelId::Lenet],
            |_, t| if t < 5.0 { 400.0 } else { 50.0 },
            10.0,
            1.0,
            4,
        )
        .unwrap();
        let early = arr.iter().filter(|a| a.time_ms < 5_000.0).count();
        let late = arr.len() - early;
        assert!(early > late * 4, "early={early} late={late}");
    }

    #[test]
    fn varying_residual_carries_across_steps() {
        // A constant-rate varying trace must hit the same empirical
        // rate as the homogeneous generator regardless of how finely
        // the steps slice it — the residual inter-arrival time survives
        // every boundary (no draw is discarded at a step cut).
        for step in [0.125, 1.0, 7.0] {
            let arr =
                generate_varying(&[ModelId::Googlenet], |_, _| 40.0, 60.0, step, 6)
                    .unwrap();
            let rate = arr.len() as f64 / 60.0;
            assert!((rate - 40.0).abs() < 5.0, "step={step}: rate={rate}");
        }
        // Zero-rate gaps pause, not reset, the pending gap: arrivals
        // resume after the gap with the same total count statistics.
        let gappy = generate_varying(
            &[ModelId::Googlenet],
            |_, t| if (10.0..20.0).contains(&t) { 0.0 } else { 40.0 },
            30.0,
            1.0,
            6,
        )
        .unwrap();
        assert!(gappy.iter().all(|a| {
            let s = a.time_ms / 1000.0;
            !(10.0..20.0).contains(&s)
        }));
        let rate = gappy.len() as f64 / 20.0; // 20 s of live time
        assert!((rate - 40.0).abs() < 6.0, "gappy rate={rate}");
    }
}
