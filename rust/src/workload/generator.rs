//! Poisson arrival generation (§6.1: "we sample inter-arrival time for
//! each model from a Poisson random distribution", following Treadmill's
//! observation that real-world arrivals are Poisson).

use crate::models::ModelId;
use crate::util::rng::Pcg32;

/// One inference request arrival.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Arrival {
    /// Virtual arrival time in ms.
    pub time_ms: f64,
    /// Requested model.
    pub model: ModelId,
    /// Request id, unique within a generated trace.
    pub id: u64,
}

/// Generate a merged, time-sorted arrival trace for `duration_s` seconds
/// where each model's arrivals form an independent Poisson process at
/// its configured rate (req/s). Zero-rate models produce no arrivals.
pub fn generate_arrivals(
    rates: &[(ModelId, f64)],
    duration_s: f64,
    seed: u64,
) -> Vec<Arrival> {
    let mut out = Vec::new();
    let horizon_ms = duration_s * 1000.0;
    let mut id = 0u64;
    for (i, &(model, rate)) in rates.iter().enumerate() {
        if rate <= 0.0 {
            continue;
        }
        // Independent stream per model so traces are stable under
        // changes to the other models' rates.
        let mut rng = Pcg32::new(seed, i as u64 + 1);
        let mut t = 0.0;
        loop {
            t += rng.exp(rate) * 1000.0; // gap in ms
            if t >= horizon_ms {
                break;
            }
            out.push(Arrival { time_ms: t, model, id });
            id += 1;
        }
    }
    out.sort_by(|a, b| a.time_ms.partial_cmp(&b.time_ms).unwrap());
    // Re-number in arrival order for readable logs.
    for (i, a) in out.iter_mut().enumerate() {
        a.id = i as u64;
    }
    out
}

/// Generate arrivals for a time-varying rate function by thinning a
/// piecewise-constant approximation over `step_s` windows (used by the
/// Fig 14 fluctuation experiment).
pub fn generate_varying<F>(
    models: &[ModelId],
    rate_at: F,
    duration_s: f64,
    step_s: f64,
    seed: u64,
) -> Vec<Arrival>
where
    F: Fn(ModelId, f64) -> f64,
{
    let mut out = Vec::new();
    let mut id = 0u64;
    for (i, &model) in models.iter().enumerate() {
        let mut rng = Pcg32::new(seed, i as u64 + 101);
        let mut window_start = 0.0;
        while window_start < duration_s {
            let rate = rate_at(model, window_start);
            let window_end = (window_start + step_s).min(duration_s);
            if rate > 0.0 {
                let mut t = window_start;
                loop {
                    t += rng.exp(rate);
                    if t >= window_end {
                        break;
                    }
                    out.push(Arrival { time_ms: t * 1000.0, model, id });
                    id += 1;
                }
            }
            window_start = window_end;
        }
    }
    out.sort_by(|a, b| a.time_ms.partial_cmp(&b.time_ms).unwrap());
    for (i, a) in out.iter_mut().enumerate() {
        a.id = i as u64;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empirical_rate_matches_request() {
        let arrivals = generate_arrivals(&[(ModelId::Lenet, 200.0)], 30.0, 1);
        let rate = arrivals.len() as f64 / 30.0;
        assert!((rate - 200.0).abs() < 15.0, "rate={rate}");
    }

    #[test]
    fn sorted_and_unique_ids() {
        let arrivals = generate_arrivals(
            &[(ModelId::Lenet, 100.0), (ModelId::Vgg, 50.0)],
            10.0,
            2,
        );
        assert!(arrivals.windows(2).all(|w| w[0].time_ms <= w[1].time_ms));
        for (i, a) in arrivals.iter().enumerate() {
            assert_eq!(a.id, i as u64);
        }
    }

    #[test]
    fn zero_rate_no_arrivals() {
        let arrivals = generate_arrivals(&[(ModelId::Lenet, 0.0)], 10.0, 3);
        assert!(arrivals.is_empty());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate_arrivals(&[(ModelId::Resnet, 100.0)], 5.0, 7);
        let b = generate_arrivals(&[(ModelId::Resnet, 100.0)], 5.0, 7);
        assert_eq!(a, b);
        let c = generate_arrivals(&[(ModelId::Resnet, 100.0)], 5.0, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn per_model_streams_independent() {
        // Adding a second model must not perturb the first's arrivals.
        let solo = generate_arrivals(&[(ModelId::Lenet, 100.0)], 5.0, 9);
        let duo = generate_arrivals(
            &[(ModelId::Lenet, 100.0), (ModelId::Vgg, 100.0)],
            5.0,
            9,
        );
        let lenet_times: Vec<f64> = duo
            .iter()
            .filter(|a| a.model == ModelId::Lenet)
            .map(|a| a.time_ms)
            .collect();
        let solo_times: Vec<f64> = solo.iter().map(|a| a.time_ms).collect();
        assert_eq!(lenet_times, solo_times);
    }

    #[test]
    fn varying_rate_tracks_windows() {
        let arr = generate_varying(
            &[ModelId::Lenet],
            |_, t| if t < 5.0 { 400.0 } else { 50.0 },
            10.0,
            1.0,
            4,
        );
        let early = arr.iter().filter(|a| a.time_ms < 5_000.0).count();
        let late = arr.len() - early;
        assert!(early > late * 4, "early={early} late={late}");
    }
}
