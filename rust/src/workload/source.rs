//! Pull-based arrival streams: the O(active) alternative to
//! materializing a whole trace as a `Vec<Arrival>`.
//!
//! The paper's serving loop (§5) and the max-rate searches behind
//! Figs 12/13/16 are continuous processes over unbounded request
//! streams; pre-generating every arrival makes the simulator's memory
//! and heap depth scale with *trace length* instead of *in-flight
//! work*. An [`ArrivalSource`] yields one arrival at a time in
//! nondecreasing time order; a [`SourceMux`] k-way-merges per-model
//! streams by next-arrival time, holding exactly **one pending arrival
//! per stream**. The serving engine pulls from the mux lazily, so its
//! live event set is bounded by `#streams + #assignments + #gpu-lets`
//! regardless of how long the trace runs.
//!
//! Determinism contract: a mux over the per-model Poisson (or
//! inhomogeneous) streams yields *exactly* the sequence the old
//! sort-based generators produced — same `Pcg32` per-stream draws, same
//! stable tie-break (equal times resolve to the lower stream index),
//! same sequential ids. `generate_arrivals`/`generate_varying` are now
//! thin `materialize()` wrappers over these sources, and
//! `tests/streaming_equivalence.rs` pins the streamed and materialized
//! serving paths to byte-identical reports.

use std::sync::Arc;

use crate::error::Result;
use crate::models::ModelId;
use crate::util::rng::Pcg32;

use super::generator::{validate_duration, validate_rate, validate_step, Arrival};

/// A pull-based arrival stream: yields `(time_ms, model)` pairs in
/// nondecreasing time order, `None` once exhausted (exhaustion is
/// permanent).
pub trait ArrivalSource {
    /// Next arrival of this stream, or `None` when the stream is dry.
    fn next(&mut self) -> Option<(f64, ModelId)>;
}

/// Object-safe, clonable, thread-movable arrival stream — the form the
/// serving engine owns. Implemented automatically for every
/// `ArrivalSource + Clone + Send + 'static`; cloning is how the
/// adaptive server taps a stream for rate observation without
/// disturbing the serving copy (the clone replays the same draws).
pub trait DynSource: ArrivalSource + Send {
    fn clone_dyn(&self) -> Box<dyn DynSource>;
}

impl<T> DynSource for T
where
    T: ArrivalSource + Clone + Send + 'static,
{
    fn clone_dyn(&self) -> Box<dyn DynSource> {
        Box::new(self.clone())
    }
}

impl ArrivalSource for Box<dyn DynSource> {
    fn next(&mut self) -> Option<(f64, ModelId)> {
        (**self).next()
    }
}

impl Clone for Box<dyn DynSource> {
    fn clone(&self) -> Self {
        // Dispatch on the inner trait object (NOT on the box, which
        // would re-enter this impl through the blanket `DynSource`).
        (**self).clone_dyn()
    }
}

/// Box a homogeneous set of streams into the engine-owned form.
pub fn dyn_sources<S: DynSource + 'static>(streams: Vec<S>) -> Vec<Box<dyn DynSource>> {
    streams.into_iter().map(|s| Box::new(s) as Box<dyn DynSource>).collect()
}

/// The boxed mux the serving engine and the adaptive server consume.
pub type DynSourceMux = SourceMux<Box<dyn DynSource>>;

/// K-way merge of arrival streams by next-arrival time: one pending
/// arrival per stream, ids assigned sequentially in merged order.
///
/// Tie-break matches the old materializing generators exactly: equal
/// `f64` times resolve to the lower stream index (the stable sort over
/// stream-major concatenation did the same), so a mux over the same
/// per-stream draws reproduces the sorted trace element-for-element.
#[derive(Clone)]
pub struct SourceMux<S: ArrivalSource> {
    streams: Vec<S>,
    /// One pending `(time_ms, model)` per stream (`None` = dry).
    pending: Vec<Option<(f64, ModelId)>>,
    /// Cached index of the earliest pending arrival — recomputed once
    /// per pull, so peeks on the engine's per-event hot path are O(1).
    best: Option<usize>,
    /// Streams whose slot is `Some` (kept incrementally for the same
    /// reason).
    pending_count: usize,
    next_id: u64,
    /// Time of the last pulled arrival (0.0 before the first pull) —
    /// the drain horizon is derived from this, not from a materialized
    /// `arrivals.last()`.
    last_ms: f64,
}

impl<S: ArrivalSource> SourceMux<S> {
    pub fn new(streams: Vec<S>) -> Self {
        let mut streams = streams;
        let pending: Vec<Option<(f64, ModelId)>> =
            streams.iter_mut().map(|s| s.next()).collect();
        let best = Self::compute_best(&pending);
        let pending_count = pending.iter().filter(|p| p.is_some()).count();
        SourceMux { streams, pending, best, pending_count, next_id: 0, last_ms: 0.0 }
    }

    /// Index of the stream holding the earliest pending arrival
    /// (strict `<` keeps the lowest index on exact time ties).
    fn compute_best(pending: &[Option<(f64, ModelId)>]) -> Option<usize> {
        let mut best: Option<(f64, usize)> = None;
        for (i, p) in pending.iter().enumerate() {
            if let Some((t, _)) = p {
                if best.is_none_or(|(bt, _)| *t < bt) {
                    best = Some((*t, i));
                }
            }
        }
        best.map(|(_, i)| i)
    }

    /// Time (ms) of the next merged arrival without consuming it. O(1).
    pub fn peek_time_ms(&self) -> Option<f64> {
        self.best.and_then(|i| self.pending[i]).map(|(t, _)| t)
    }

    /// Consume the next merged arrival, refilling that stream's slot.
    pub fn pull(&mut self) -> Option<Arrival> {
        let i = self.best?;
        let (time_ms, model) = self.pending[i].take().expect("best slot is pending");
        self.pending[i] = self.streams[i].next();
        if self.pending[i].is_none() {
            self.pending_count -= 1;
        }
        self.best = Self::compute_best(&self.pending);
        let id = self.next_id;
        self.next_id += 1;
        self.last_ms = time_ms;
        Some(Arrival { time_ms, model, id })
    }

    /// Number of merged streams (each holds at most one pending event).
    pub fn n_streams(&self) -> usize {
        self.streams.len()
    }

    /// How many streams still hold a pending arrival. O(1).
    pub fn pending_len(&self) -> usize {
        self.pending_count
    }

    /// Arrivals pulled so far.
    pub fn pulled(&self) -> u64 {
        self.next_id
    }

    /// Time (ms) of the last pulled arrival; 0.0 before any pull. Once
    /// the mux is exhausted this is the trace's last arrival — the
    /// drain horizon the one-shot simulation runs to.
    pub fn last_arrival_ms(&self) -> f64 {
        self.last_ms
    }

    /// True when every stream is dry.
    pub fn is_exhausted(&self) -> bool {
        self.pending_count == 0
    }

    /// Drain the whole mux into a sorted, sequentially-numbered trace
    /// (the legacy `Vec<Arrival>` shape).
    pub fn materialize(mut self) -> Vec<Arrival> {
        let mut out = Vec::new();
        while let Some(a) = self.pull() {
            out.push(a);
        }
        out
    }
}

impl SourceMux<Box<dyn DynSource>> {
    /// A mux over a single pre-materialized trace — the adapter that
    /// keeps the legacy `&[Arrival]` entry points on the streaming
    /// path.
    pub fn of_trace(arrivals: Vec<Arrival>) -> Self {
        SourceMux::new(dyn_sources(vec![MaterializedSource::new(arrivals)]))
    }
}

/// Adapter: an already-materialized (time-sorted) trace as a stream.
/// The trace is held behind an `Arc`, so clones (the adaptive server's
/// observation tap) share one copy and only carry their own cursor.
#[derive(Clone)]
pub struct MaterializedSource {
    arrivals: Arc<[Arrival]>,
    idx: usize,
}

impl MaterializedSource {
    /// Every generator output is already time-sorted; an unsorted
    /// trace is sorted here (stably, by time) — the same effective
    /// order the old bulk heap imposed on unsorted input via its
    /// `(time, insertion-seq)` keys, so the legacy "any order goes in,
    /// time order comes out" contract survives in release builds too.
    pub fn new(mut arrivals: Vec<Arrival>) -> Self {
        if !arrivals.windows(2).all(|w| w[0].time_ms <= w[1].time_ms) {
            arrivals.sort_by(|a, b| a.time_ms.total_cmp(&b.time_ms));
        }
        MaterializedSource { arrivals: arrivals.into(), idx: 0 }
    }
}

impl ArrivalSource for MaterializedSource {
    fn next(&mut self) -> Option<(f64, ModelId)> {
        let a = self.arrivals.get(self.idx)?;
        self.idx += 1;
        Some((a.time_ms, a.model))
    }
}

/// Homogeneous Poisson stream for one model at a fixed rate (req/s),
/// truncated at the horizon. Exactly the per-model stream
/// `generate_arrivals` drew: same `Pcg32::new(seed, stream)` state,
/// same `t += exp(rate) * 1000` accumulation, same `t >= horizon`
/// cutoff.
#[derive(Clone)]
pub struct PoissonSource {
    model: ModelId,
    rate: f64,
    horizon_ms: f64,
    t_ms: f64,
    rng: Pcg32,
    done: bool,
}

impl PoissonSource {
    /// `stream` is the per-model stream id (`generate_arrivals` used
    /// `index_in_rates + 1`); `rate` must be finite and positive.
    /// Crate-private so every externally-reachable construction goes
    /// through [`poisson_streams`], whose validation turns a NaN/∞
    /// rate into a proper `Error` instead of a mid-simulation panic.
    pub(crate) fn new(
        model: ModelId,
        rate: f64,
        duration_s: f64,
        seed: u64,
        stream: u64,
    ) -> Self {
        debug_assert!(rate.is_finite() && rate > 0.0, "validated by poisson_streams");
        PoissonSource {
            model,
            rate,
            horizon_ms: duration_s * 1000.0,
            t_ms: 0.0,
            rng: Pcg32::new(seed, stream),
            done: false,
        }
    }
}

impl ArrivalSource for PoissonSource {
    fn next(&mut self) -> Option<(f64, ModelId)> {
        if self.done {
            return None;
        }
        self.t_ms += self.rng.exp(self.rate) * 1000.0;
        if self.t_ms >= self.horizon_ms {
            self.done = true;
            return None;
        }
        Some((self.t_ms, self.model))
    }
}

/// Per-model Poisson streams for a rate table — the streaming form of
/// [`super::generate_arrivals`]. Stream ids follow the table index
/// (zero-rate entries are skipped but still consume their index, so a
/// model's draws are independent of the other models' rates). Rates and
/// the duration are validated here, exactly like the generator did.
pub fn poisson_streams(
    rates: &[(ModelId, f64)],
    duration_s: f64,
    seed: u64,
) -> Result<Vec<PoissonSource>> {
    validate_duration(duration_s)?;
    let mut out = Vec::new();
    for (i, &(model, rate)) in rates.iter().enumerate() {
        validate_rate(model, rate)?;
        if rate <= 0.0 {
            continue;
        }
        out.push(PoissonSource::new(model, rate, duration_s, seed, i as u64 + 1));
    }
    Ok(out)
}

/// Inhomogeneous (piecewise-constant rate) stream for one model — the
/// streaming form of one `generate_varying` per-model pass: the same
/// unit-rate-exposure sampler, resumable one arrival at a time. The
/// `Exp(1)` residual carries across window boundaries and the window is
/// tracked by integer index, exactly as in the generator.
#[derive(Clone)]
pub struct VaryingSource<F: Fn(ModelId, f64) -> f64 + Clone> {
    model: ModelId,
    rate_at: F,
    duration_s: f64,
    step_s: f64,
    win: u64,
    t: f64,
    need: f64,
    rng: Pcg32,
    done: bool,
}

impl<F: Fn(ModelId, f64) -> f64 + Clone> VaryingSource<F> {
    /// `stream` is the per-model stream id (`generate_varying` used
    /// `index_in_models + 101`). Crate-private so rates are always
    /// pre-validated over every window by [`varying_streams`] (a NaN
    /// rate discovered mid-stream could only panic, not `Err`).
    pub(crate) fn new(
        model: ModelId,
        rate_at: F,
        duration_s: f64,
        step_s: f64,
        seed: u64,
        stream: u64,
    ) -> Self {
        let mut rng = Pcg32::new(seed, stream);
        let need = rng.exp(1.0);
        VaryingSource {
            model,
            rate_at,
            duration_s,
            step_s,
            win: 0,
            t: 0.0,
            need,
            rng,
            done: false,
        }
    }
}

impl<F: Fn(ModelId, f64) -> f64 + Clone> ArrivalSource for VaryingSource<F> {
    fn next(&mut self) -> Option<(f64, ModelId)> {
        if self.done {
            return None;
        }
        loop {
            let w0 = self.win as f64 * self.step_s;
            if w0 >= self.duration_s {
                self.done = true;
                return None;
            }
            let window_end = ((self.win + 1) as f64 * self.step_s).min(self.duration_s);
            let rate = (self.rate_at)(self.model, w0);
            debug_assert!(
                rate.is_finite() && rate >= 0.0,
                "rates are validated at stream construction"
            );
            if rate <= 0.0 {
                self.win += 1;
                self.t = window_end;
                continue;
            }
            let t_lo = self.t.max(w0);
            let exposure = rate * (window_end - t_lo).max(0.0);
            if self.need < exposure {
                let t_arr = t_lo + self.need / rate;
                self.t = t_arr;
                self.need = self.rng.exp(1.0);
                if t_arr < self.duration_s {
                    return Some((t_arr * 1000.0, self.model));
                }
            } else {
                self.need -= exposure;
                self.win += 1;
                self.t = window_end;
            }
        }
    }
}

/// Per-model inhomogeneous streams for a time-varying rate function —
/// the streaming form of [`super::generator::generate_varying`]. Every
/// window's rate is validated up front for every model (the generator
/// validated lazily as it swept the same windows; first error matches).
pub fn varying_streams<F>(
    models: &[ModelId],
    rate_at: F,
    duration_s: f64,
    step_s: f64,
    seed: u64,
) -> Result<Vec<VaryingSource<F>>>
where
    F: Fn(ModelId, f64) -> f64 + Clone,
{
    validate_duration(duration_s)?;
    validate_step(step_s)?;
    for &model in models {
        let mut win = 0u64;
        loop {
            let w0 = win as f64 * step_s;
            if w0 >= duration_s {
                break;
            }
            validate_rate(model, rate_at(model, w0))?;
            win += 1;
        }
    }
    Ok(models
        .iter()
        .enumerate()
        .map(|(i, &m)| {
            VaryingSource::new(m, rate_at.clone(), duration_s, step_s, seed, i as u64 + 101)
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::generate_arrivals;

    /// A hand-scripted source for merge-order tests.
    #[derive(Clone)]
    struct Scripted {
        times: Vec<f64>,
        model: ModelId,
        idx: usize,
    }

    impl ArrivalSource for Scripted {
        fn next(&mut self) -> Option<(f64, ModelId)> {
            let t = *self.times.get(self.idx)?;
            self.idx += 1;
            Some((t, self.model))
        }
    }

    #[test]
    fn mux_merges_by_time_with_stable_ties() {
        let a = Scripted { times: vec![1.0, 5.0, 5.0], model: ModelId::Lenet, idx: 0 };
        let b = Scripted { times: vec![2.0, 5.0, 9.0], model: ModelId::Vgg, idx: 0 };
        let mux = SourceMux::new(vec![a, b]);
        let out = mux.materialize();
        let times: Vec<f64> = out.iter().map(|x| x.time_ms).collect();
        assert_eq!(times, vec![1.0, 2.0, 5.0, 5.0, 5.0, 9.0]);
        // Exact time tie at 5.0: stream 0's arrivals come first (the
        // stable-sort order the materializing generator produced).
        let models_at_5: Vec<ModelId> =
            out.iter().filter(|x| x.time_ms == 5.0).map(|x| x.model).collect();
        assert_eq!(models_at_5, vec![ModelId::Lenet, ModelId::Lenet, ModelId::Vgg]);
        // Ids are sequential in merged order.
        for (i, x) in out.iter().enumerate() {
            assert_eq!(x.id, i as u64);
        }
    }

    #[test]
    fn mux_tracks_last_arrival_and_exhaustion() {
        let a = Scripted { times: vec![3.0, 7.0], model: ModelId::Lenet, idx: 0 };
        let mut mux = SourceMux::new(vec![a]);
        assert_eq!(mux.n_streams(), 1);
        assert_eq!(mux.pending_len(), 1);
        assert!(!mux.is_exhausted());
        assert_eq!(mux.last_arrival_ms(), 0.0);
        assert_eq!(mux.peek_time_ms(), Some(3.0));
        mux.pull().unwrap();
        mux.pull().unwrap();
        assert!(mux.is_exhausted());
        assert_eq!(mux.peek_time_ms(), None);
        assert!(mux.pull().is_none());
        assert_eq!(mux.last_arrival_ms(), 7.0);
        assert_eq!(mux.pulled(), 2);
    }

    #[test]
    fn cloned_tap_replays_without_disturbing_original() {
        let streams =
            poisson_streams(&[(ModelId::Lenet, 80.0), (ModelId::Vgg, 40.0)], 5.0, 17)
                .unwrap();
        let mux = SourceMux::new(dyn_sources(streams));
        let tap = mux.clone();
        let a = mux.materialize();
        let b = tap.materialize();
        assert_eq!(a, b, "a cloned source must replay the identical stream");
    }

    #[test]
    fn poisson_streams_match_generator_exactly() {
        let rates = [
            (ModelId::Lenet, 120.0),
            (ModelId::Googlenet, 0.0), // zero-rate holds its stream index
            (ModelId::Vgg, 45.0),
        ];
        for seed in [1u64, 7, 0xD15C0] {
            let streamed =
                SourceMux::new(poisson_streams(&rates, 8.0, seed).unwrap()).materialize();
            let materialized = generate_arrivals(&rates, 8.0, seed).unwrap();
            assert_eq!(streamed, materialized);
        }
    }

    #[test]
    fn materialized_source_sorts_unsorted_input() {
        // Legacy contract: the bulk heap ordered unsorted traces by
        // (time, insertion order); the adapter must keep doing so.
        let shuffled = vec![
            Arrival { time_ms: 5.0, model: ModelId::Vgg, id: 0 },
            Arrival { time_ms: 1.0, model: ModelId::Lenet, id: 1 },
            Arrival { time_ms: 3.0, model: ModelId::Vgg, id: 2 },
        ];
        let out = SourceMux::new(vec![MaterializedSource::new(shuffled)]).materialize();
        let times: Vec<f64> = out.iter().map(|a| a.time_ms).collect();
        assert_eq!(times, vec![1.0, 3.0, 5.0]);
        assert_eq!(out[0].model, ModelId::Lenet);
        for (i, a) in out.iter().enumerate() {
            assert_eq!(a.id, i as u64, "ids renumbered in merged order");
        }
    }

    #[test]
    fn stream_validation_mirrors_generators() {
        assert!(poisson_streams(&[(ModelId::Lenet, f64::NAN)], 1.0, 1).is_err());
        assert!(poisson_streams(&[(ModelId::Lenet, 1.0)], f64::INFINITY, 1).is_err());
        assert!(varying_streams(&[ModelId::Lenet], |_, _| -1.0, 2.0, 1.0, 1).is_err());
        assert!(varying_streams(&[ModelId::Lenet], |_, _| 1.0, 2.0, 0.0, 1).is_err());
        // A rate that only turns invalid mid-trace is still caught up
        // front (the generator found it when its sweep got there).
        assert!(varying_streams(
            &[ModelId::Lenet],
            |_, t| if t < 5.0 { 1.0 } else { f64::NAN },
            10.0,
            1.0,
            1
        )
        .is_err());
    }
}
