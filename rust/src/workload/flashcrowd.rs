//! Flash-crowd arrival source: a deterministic burst modulation layered
//! on the exact-draw inhomogeneous sampler.
//!
//! A [`FlashCrowdSpec`] multiplies a per-model base rate table by a
//! shared burst envelope — quiet at 1×, a sinusoidal ramp up to
//! `peak_mult`, a plateau, and a symmetric ramp down (`ramp_s = 0`
//! degenerates to a step) — the "correlated multi-model burst" shape
//! the ROADMAP's millions-of-users scenario engine calls for. Each
//! [`FlashCrowdSource`] wraps the *same* unit-rate-exposure sampler as
//! [`VaryingSource`] (piecewise-constant over `step_s` windows, one
//! `Pcg32` stream per model), so draws are exact, resumable, and
//! byte-reproducible for a given seed, and every window's rate is
//! validated up front exactly like [`varying_streams`].
//!
//! [`varying_streams`]: super::source::varying_streams

use std::f64::consts::PI;

use crate::error::Result;
use crate::models::ModelId;

use super::generator::{validate_duration, validate_rate, validate_step};
use super::source::{ArrivalSource, DynSource, VaryingSource};

/// Shape of a flash crowd over a base rate table. All models burst
/// together (correlated), scaled by the same envelope.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FlashCrowdSpec {
    /// Baseline rate (req/s) per model, `ModelId::index`-indexed.
    pub base: [f64; 5],
    /// Envelope multiplier at the crowd's peak (>= 1).
    pub peak_mult: f64,
    /// Burst onset (s).
    pub t_start_s: f64,
    /// Sinusoidal ramp length (s) on each side; 0 = step modulation.
    pub ramp_s: f64,
    /// Plateau at the peak (s).
    pub hold_s: f64,
}

impl Default for FlashCrowdSpec {
    fn default() -> Self {
        FlashCrowdSpec {
            base: [0.0; 5],
            peak_mult: 3.0,
            t_start_s: 0.0,
            ramp_s: 0.0,
            hold_s: 0.0,
        }
    }
}

impl FlashCrowdSpec {
    /// The burst envelope at time `t_s`: 1.0 when quiet, `peak_mult` on
    /// the plateau, half-sinusoid in between.
    pub fn envelope(&self, t_s: f64) -> f64 {
        let dt = t_s - self.t_start_s;
        let end = 2.0 * self.ramp_s + self.hold_s;
        if dt < 0.0 || dt >= end {
            return 1.0;
        }
        let gain = self.peak_mult - 1.0;
        let shape = if dt < self.ramp_s {
            (PI / 2.0 * dt / self.ramp_s).sin()
        } else if dt < self.ramp_s + self.hold_s {
            1.0
        } else {
            (PI / 2.0 * (end - dt) / self.ramp_s).sin()
        };
        1.0 + gain * shape
    }

    /// Offered rate for `m` at time `t_s` (req/s).
    pub fn rate_at(&self, m: ModelId, t_s: f64) -> f64 {
        self.base[m.index()] * self.envelope(t_s)
    }

    /// Peak offered rate per model (the plateau level) — what a planner
    /// would need to hold to serve the whole crowd within SLO.
    pub fn peak_rates(&self) -> [f64; 5] {
        let mut r = self.base;
        r.iter_mut().for_each(|x| *x *= self.peak_mult);
        r
    }
}

/// One model's flash-crowd arrival stream — the exact-draw
/// inhomogeneous sampler with the spec's envelope as its rate function.
#[derive(Clone)]
pub struct FlashCrowdSource {
    inner: Box<dyn DynSource>,
}

impl FlashCrowdSource {
    /// Crate-private like the other sources: external construction goes
    /// through [`flashcrowd_streams`], which validates every window of
    /// every model's rate up front.
    pub(crate) fn new(
        spec: FlashCrowdSpec,
        model: ModelId,
        duration_s: f64,
        step_s: f64,
        seed: u64,
        stream: u64,
    ) -> Self {
        let inner = VaryingSource::new(
            model,
            move |m, t| spec.rate_at(m, t),
            duration_s,
            step_s,
            seed,
            stream,
        );
        FlashCrowdSource { inner: Box::new(inner) }
    }
}

impl ArrivalSource for FlashCrowdSource {
    fn next(&mut self) -> Option<(f64, ModelId)> {
        self.inner.next()
    }
}

/// Per-model flash-crowd streams over `spec` — one stream per model
/// with a positive base rate, stream ids `i + 201` (disjoint from the
/// Poisson `i + 1` and varying `i + 101` id spaces, so flash-crowd
/// draws never collide with other sources on the same seed). The spec
/// and every window's rate are validated here.
pub fn flashcrowd_streams(
    spec: &FlashCrowdSpec,
    duration_s: f64,
    step_s: f64,
    seed: u64,
) -> Result<Vec<FlashCrowdSource>> {
    validate_duration(duration_s)?;
    validate_step(step_s)?;
    for (i, m) in ModelId::ALL.into_iter().enumerate() {
        // Validate the base itself first: a negative or NaN base must
        // error even though zero-base models emit no stream.
        validate_rate(m, spec.base[i])?;
        if spec.base[i] == 0.0 {
            continue;
        }
        let mut win = 0u64;
        loop {
            let w0 = win as f64 * step_s;
            if w0 >= duration_s {
                break;
            }
            validate_rate(m, spec.rate_at(m, w0))?;
            win += 1;
        }
    }
    Ok(ModelId::ALL
        .into_iter()
        .enumerate()
        .filter(|&(i, _)| spec.base[i] > 0.0)
        .map(|(i, m)| {
            FlashCrowdSource::new(*spec, m, duration_s, step_s, seed, i as u64 + 201)
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{dyn_sources, SourceMux};

    fn spec() -> FlashCrowdSpec {
        FlashCrowdSpec {
            base: [100.0, 0.0, 40.0, 0.0, 20.0],
            peak_mult: 3.0,
            t_start_s: 10.0,
            ramp_s: 5.0,
            hold_s: 10.0,
        }
    }

    #[test]
    fn envelope_shape_is_quiet_ramp_peak_ramp_quiet() {
        let s = spec();
        assert_eq!(s.envelope(0.0), 1.0);
        assert_eq!(s.envelope(9.999), 1.0);
        let mid_ramp = s.envelope(12.5);
        assert!(mid_ramp > 1.0 && mid_ramp < 3.0, "{mid_ramp}");
        assert!((s.envelope(15.0) - 3.0).abs() < 1e-9);
        assert!((s.envelope(20.0) - 3.0).abs() < 1e-9);
        let falling = s.envelope(27.5);
        assert!(falling > 1.0 && falling < 3.0, "{falling}");
        assert_eq!(s.envelope(30.0), 1.0);
        assert_eq!(s.envelope(1e9), 1.0);
        // Step modulation: ramp_s = 0 jumps straight to the peak.
        let step = FlashCrowdSpec { ramp_s: 0.0, hold_s: 10.0, ..s };
        assert_eq!(step.envelope(9.999), 1.0);
        assert!((step.envelope(10.0) - 3.0).abs() < 1e-9);
        assert_eq!(step.envelope(20.0), 1.0);
        assert_eq!(s.peak_rates(), [300.0, 0.0, 120.0, 0.0, 60.0]);
    }

    #[test]
    fn draws_match_varying_streams_exactly() {
        // The flash-crowd source IS the varying sampler with the
        // envelope rate function — pin the byte-identity (modulo the
        // disjoint stream-id space, reproduced here explicitly).
        let s = spec();
        let duration = 40.0;
        let streamed = SourceMux::new(dyn_sources(
            flashcrowd_streams(&s, duration, 1.0, 42).unwrap(),
        ))
        .materialize();
        let models: Vec<ModelId> = ModelId::ALL
            .into_iter()
            .filter(|m| s.base[m.index()] > 0.0)
            .collect();
        let reference: Vec<_> = models
            .iter()
            .map(|&m| {
                VaryingSource::new(
                    m,
                    move |mm, t| s.rate_at(mm, t),
                    duration,
                    1.0,
                    42,
                    m.index() as u64 + 201,
                )
            })
            .collect();
        let expect = SourceMux::new(dyn_sources(reference)).materialize();
        assert_eq!(streamed, expect);
        assert!(!streamed.is_empty());
    }

    #[test]
    fn burst_windows_carry_more_arrivals_and_replay_identically() {
        let s = spec();
        let a = SourceMux::new(dyn_sources(flashcrowd_streams(&s, 40.0, 1.0, 7).unwrap()))
            .materialize();
        let b = SourceMux::new(dyn_sources(flashcrowd_streams(&s, 40.0, 1.0, 7).unwrap()))
            .materialize();
        assert_eq!(a, b, "same seed must replay byte-identically");
        let quiet = a.iter().filter(|x| x.time_ms < 10_000.0).count() as f64 / 10.0;
        let peak = a
            .iter()
            .filter(|x| (15_000.0..25_000.0).contains(&x.time_ms))
            .count() as f64
            / 10.0;
        assert!(
            peak > 2.0 * quiet,
            "peak windows must burst: {peak:.1}/s vs quiet {quiet:.1}/s"
        );
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let mut bad = spec();
        bad.base[0] = f64::NAN;
        assert!(flashcrowd_streams(&bad, 10.0, 1.0, 1).is_err());
        let mut neg = spec();
        neg.peak_mult = -4.0; // envelope dips negative mid-burst
        assert!(flashcrowd_streams(&neg, 40.0, 1.0, 1).is_err());
        assert!(flashcrowd_streams(&spec(), f64::NAN, 1.0, 1).is_err());
        assert!(flashcrowd_streams(&spec(), 10.0, 0.0, 1).is_err());
    }
}
