//! Request scenarios: the paper's Table 5 named mixes and the 1,023
//! scenario population used for the schedulability studies (§3.1,
//! Fig 4 / Fig 15: rates {0, 200, 400, 600} per model, all-zero excluded).

use crate::models::ModelId;

/// A per-model request-rate vector (req/s), indexed by `ModelId::index`.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    pub name: String,
    pub rates: [f64; 5],
}

impl Scenario {
    pub fn new(name: impl Into<String>, rates: [f64; 5]) -> Self {
        Scenario { name: name.into(), rates }
    }

    pub fn rate(&self, m: ModelId) -> f64 {
        self.rates[m.index()]
    }

    /// Total offered load (req/s).
    pub fn total_rate(&self) -> f64 {
        self.rates.iter().sum()
    }

    /// Rate pairs for the workload generator (nonzero only).
    pub fn rate_pairs(&self) -> Vec<(ModelId, f64)> {
        ModelId::ALL
            .iter()
            .map(|&m| (m, self.rate(m)))
            .filter(|&(_, r)| r > 0.0)
            .collect()
    }

    /// Uniformly scale all rates (the "x2.0" escalation in Fig 13).
    pub fn scaled(&self, factor: f64) -> Scenario {
        let mut rates = self.rates;
        rates.iter_mut().for_each(|r| *r *= factor);
        Scenario::new(format!("{}@x{factor:.2}", self.name), rates)
    }
}

/// Table 5: the three particularly chosen request scenarios.
/// Order: [le, goo, res, ssd, vgg] per `ModelId` index.
pub fn named_scenarios() -> Vec<Scenario> {
    vec![
        Scenario::new("equal", [50.0, 50.0, 50.0, 50.0, 50.0]),
        Scenario::new("long-only", [0.0, 0.0, 100.0, 100.0, 100.0]),
        Scenario::new("short-skew", [100.0, 100.0, 100.0, 50.0, 50.0]),
    ]
}

/// The full 4^5 − 1 = 1,023 scenario population with per-model rates in
/// {0, 200, 400, 600} req/s, excluding the all-zero vector (§3.1).
pub fn enumerate_all_scenarios() -> Vec<Scenario> {
    const LEVELS: [f64; 4] = [0.0, 200.0, 400.0, 600.0];
    let mut out = Vec::with_capacity(1023);
    for a in 0..4 {
        for b in 0..4 {
            for c in 0..4 {
                for d in 0..4 {
                    for e in 0..4 {
                        if a + b + c + d + e == 0 {
                            continue;
                        }
                        let rates = [
                            LEVELS[a], LEVELS[b], LEVELS[c], LEVELS[d], LEVELS[e],
                        ];
                        out.push(Scenario::new(
                            format!("s{a}{b}{c}{d}{e}"),
                            rates,
                        ));
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_values() {
        let s = named_scenarios();
        assert_eq!(s.len(), 3);
        assert_eq!(s[0].rate(ModelId::Lenet), 50.0);
        assert_eq!(s[1].rate(ModelId::Lenet), 0.0);
        assert_eq!(s[1].rate(ModelId::Vgg), 100.0);
        assert_eq!(s[2].rate(ModelId::Googlenet), 100.0);
        assert_eq!(s[2].rate(ModelId::SsdMobilenet), 50.0);
    }

    #[test]
    fn population_is_1023() {
        let all = enumerate_all_scenarios();
        assert_eq!(all.len(), 1023);
        // No all-zero; no duplicates.
        assert!(all.iter().all(|s| s.total_rate() > 0.0));
        let mut names: Vec<&str> = all.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 1023);
    }

    #[test]
    fn scaling() {
        let s = Scenario::new("t", [10.0, 0.0, 0.0, 0.0, 30.0]).scaled(2.0);
        assert_eq!(s.rates, [20.0, 0.0, 0.0, 0.0, 60.0]);
        assert_eq!(s.total_rate(), 80.0);
        assert_eq!(s.rate_pairs().len(), 2);
    }
}
