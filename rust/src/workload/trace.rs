//! Rate-fluctuation traces for the Fig 14 adaptation experiment:
//! per-model rate waves over a 1,800 s window ("the rate gradually
//! increases and decreases … the following wave starting from 900 s
//! rises to a higher peak").

use crate::models::ModelId;

/// Piecewise wave: base rate plus two half-sine humps, the second taller,
/// with per-model phase offsets so the traces are "unique … different
/// from one another".
#[derive(Clone, Debug)]
pub struct FluctuationTrace {
    /// Baseline rate per model (req/s).
    pub base: [f64; 5],
    /// First-hump peak amplitude per model.
    pub peak1: [f64; 5],
    /// Second-hump peak amplitude per model.
    pub peak2: [f64; 5],
    /// Per-model phase offset in seconds.
    pub phase_s: [f64; 5],
}

impl Default for FluctuationTrace {
    fn default() -> Self {
        // Scaled to keep the 4-GPU cluster in its feasible envelope while
        // forcing partition growth/shrink across the waves: the peaks
        // push ResNet/SSD/VGG past their knee-sized gpu-let capacities
        // so the scheduler must widen partitions, then shrink them back.
        FluctuationTrace {
            base: [40.0, 20.0, 15.0, 10.0, 10.0],
            peak1: [160.0, 120.0, 150.0, 120.0, 120.0],
            peak2: [260.0, 200.0, 240.0, 190.0, 190.0],
            phase_s: [0.0, 30.0, 60.0, 90.0, 120.0],
        }
    }
}

impl FluctuationTrace {
    /// Total window length (s).
    pub const DURATION_S: f64 = 1800.0;

    /// Instantaneous offered rate for `m` at time `t_s`.
    pub fn rate_at(&self, m: ModelId, t_s: f64) -> f64 {
        let i = m.index();
        let t = (t_s - self.phase_s[i]).max(0.0);
        let hump = |t: f64, start: f64, len: f64, peak: f64| -> f64 {
            if t < start || t > start + len {
                0.0
            } else {
                let x = (t - start) / len * std::f64::consts::PI;
                peak * x.sin()
            }
        };
        // Wave 1: 0–600 s; wave 2 (taller): 900–1500 s (§6.2).
        self.base[i]
            + hump(t, 0.0, 600.0, self.peak1[i])
            + hump(t, 900.0, 600.0, self.peak2[i])
    }

    /// Rate vector at time `t_s`, indexed by model.
    pub fn rates_at(&self, t_s: f64) -> [f64; 5] {
        let mut out = [0.0; 5];
        for m in ModelId::ALL {
            out[m.index()] = self.rate_at(m, t_s);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_outside_waves() {
        let tr = FluctuationTrace::default();
        // Between the waves (t=800 with zero phase) only base remains.
        let r = tr.rate_at(ModelId::Lenet, 800.0);
        assert!((r - tr.base[0]).abs() < 1e-9);
    }

    #[test]
    fn second_wave_taller() {
        let tr = FluctuationTrace::default();
        let w1_peak = tr.rate_at(ModelId::Lenet, 300.0); // mid of wave 1
        let w2_peak = tr.rate_at(ModelId::Lenet, 1200.0); // mid of wave 2
        assert!(w2_peak > w1_peak, "{w2_peak} <= {w1_peak}");
    }

    #[test]
    fn rates_nonnegative_everywhere() {
        let tr = FluctuationTrace::default();
        for t in (0..1800).step_by(10) {
            for r in tr.rates_at(t as f64) {
                assert!(r >= 0.0);
            }
        }
    }

    #[test]
    fn models_have_distinct_traces() {
        let tr = FluctuationTrace::default();
        let a: Vec<f64> = (0..18).map(|i| tr.rate_at(ModelId::Lenet, i as f64 * 100.0)).collect();
        let b: Vec<f64> = (0..18).map(|i| tr.rate_at(ModelId::Vgg, i as f64 * 100.0)).collect();
        assert_ne!(a, b);
    }
}
