//! Fleet tier: multi-node serving behind a deterministic front end.
//!
//! The paper maximizes *one* server's GPUs via gpu-let spatial
//! partitioning; production traffic at the ROADMAP's scale means many
//! such servers behind a front-end router (the regime ParvaGPU targets
//! for large-scale cloud DNN inference). This module composes N
//! single-server reproductions into a cluster:
//!
//! * [`FleetSpec`] — the topology: N homogeneous nodes × GPUs with a
//!   per-node scheduler algorithm, loadable from a `[fleet]` TOML
//!   section (`config::Config::parse`).
//! * [`FleetPlanner`] — splits each model's offered rate across nodes
//!   (first-fit-decreasing water-fill guided by the memoized
//!   `perfmodel::CapacityTable`), validates every loaded node with a
//!   real per-node `Scheduler::schedule` call, and returns a
//!   [`FleetPlan`] of per-node schedules plus per-(node, model) rate
//!   shares — or a proper `Error` when the fleet cannot hold the load.
//! * [`Router`] — a deterministic arrival splitter: consumes one
//!   `DynSourceMux` and deals each arrival to a node via deficit-
//!   bounded quota counters matching the plan shares. Seed-stable and
//!   byte-reproducible; arrivals for models with no placement are
//!   dealt uniformly and counted, so the serving engines drop them
//!   *visibly* — nothing leaves the system silently.
//! * [`FleetEngine`] — owns N `ServingEngine`s advanced in lockstep on
//!   the shared µs clock: the router deals serially (determinism), then
//!   all nodes advance **in parallel** over the `util::par` worker pool
//!   with recycled chunk buffers — byte-identical to the serial advance
//!   for any thread count. It aggregates per-node reports into one
//!   fleet report (`Report::merge`), carves per-node `WindowReport`s
//!   each window, and periodically *rebalances*: re-plans from observed
//!   per-window rates and applies per-node
//!   `swap_schedule(…, Migrate)` — the PR 3 epoch-tagged hand-over, so
//!   backlog migrates and in-flight batches finish under their old
//!   constants. Queued work is never lost at a rebalance.
//!
//! Robustness (PR 9) extends the tier with *fault tolerance* and
//! *admission control*: a scripted `workload::FaultPlan` kills and
//! recovers nodes at lockstep boundaries (destroyed work is accounted
//! as `lost_to_failure`, survivors are re-planned via
//! [`FleetPlanner::plan_masked`]), and an [`AdmissionSpec`] arms a
//! deterministic front-end gate that sheds — or degrades to a cheaper
//! fallback model — the slice of demand the active plan cannot serve
//! within SLO.
//!
//! The tier is *conservative*: a 1-node fleet is byte-identical (JSON
//! report) to `coordinator::simulate_source` on the same mux/seed, and
//! fleet-wide conservation (`demand == offered + shed` at the gate and
//! `offered == served + dropped + lost_to_failure`, per model) holds
//! for any node count, including across mid-trace rebalances and node
//! failures — `tests/fleet_equivalence.rs` pins both.

pub mod engine;
pub mod planner;
pub mod router;

use crate::config::Algo;

pub use engine::{FleetConfig, FleetEngine, FleetOutcome, FleetWindowStats};
pub use planner::{FleetPlan, FleetPlanner};
pub use router::{AdmissionMode, AdmissionSpec, Router};

/// Fleet topology: N homogeneous nodes, each a paper-testbed-style
/// multi-GPU server scheduled by `algo`. Loadable from the `[fleet]`
/// TOML section (`fleet.nodes`, `fleet.gpus_per_node`, `fleet.algo`,
/// `fleet.rebalance_s`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FleetSpec {
    /// Number of serving nodes.
    pub nodes: usize,
    /// Physical GPUs per node (homogeneous fleet).
    pub gpus_per_node: usize,
    /// Per-node scheduling algorithm.
    pub algo: Algo,
    /// Fleet rebalance cadence in seconds (<= 0 disables rebalancing).
    pub rebalance_s: f64,
}

impl Default for FleetSpec {
    fn default() -> Self {
        FleetSpec { nodes: 1, gpus_per_node: 4, algo: Algo::GpuletInt, rebalance_s: 20.0 }
    }
}
