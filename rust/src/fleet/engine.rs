//! The fleet serving core: N persistent [`ServingEngine`]s advanced in
//! lockstep on the shared µs clock behind one deterministic [`Router`].
//!
//! ## Lockstep advance
//!
//! `run_until(t)` first lets the router deal every arrival with time
//! `<= t` into per-node staging buffers — serially, because the
//! Balinski–Young dealer's determinism lives in the order it consumes
//! the merged stream — then hands each node its chunk
//! ([`ServingEngine::attach_chunk`], which recycles the buffer back
//! through the router) and advances **all nodes in parallel**
//! (`util::par::par_for_each_mut`). Each node pulls its arrivals lazily
//! at the exact virtual times a dedicated single-server engine would —
//! the stepped `run_until` path is byte-identical to the one-shot
//! streamed path (`tests/streaming_equivalence.rs`), which is what
//! makes a 1-node fleet byte-identical to `simulate_source` on the same
//! mux/seed (`tests/fleet_equivalence.rs`). Nodes are independent: no
//! event on one node can affect another within an advance, and each
//! engine's computation is a deterministic function of its own state
//! and chunk — so which worker thread runs it cannot change the result,
//! and the fleet outcome is byte-identical for any thread count
//! (`tests/fleet_equivalence.rs` pins threads {1, 2, 5}).
//!
//! ## Rebalancing
//!
//! `run(duration_s)` carves the run into windows. At each boundary the
//! router's per-window dealt counts feed an EWMA rate monitor; when the
//! smoothed rates drift past the reorganizer's trigger
//! (`coordinator::reorganizer::rates_changed` — same notion of "the
//! load moved" as one node's §5 reorganization), the fleet re-plans via
//! its [`FleetPlanner`] and applies the new plan with per-node
//! `swap_schedule(…, SwapMode::Migrate)`: in-flight batches retire
//! under their old epoch's constants, queued backlog re-routes FIFO,
//! and a model that lost every route on a node drops *counted* — the
//! PR 3 hand-over semantics, now fleet-wide. The router re-targets its
//! quota counters to the new shares in the same instant. An infeasible
//! re-plan (the observed load outgrew the fleet) keeps the current
//! plan serving — rebalancing degrades, never destroys.
//!
//! ## Conservation
//!
//! Every arrival the router deals is offered to exactly one node, and
//! each node's engine accounts every offered request as served or
//! dropped (including across swaps and at close). So fleet-wide,
//! `offered[m] == served[m] + dropped[m]` exactly, for any node count
//! and any rebalance history — `tests/fleet_equivalence.rs` pins it.

use crate::coordinator::reorganizer::{headroomed, rates_changed};
use crate::coordinator::{ServingEngine, SimConfig, SwapMode};
use crate::error::Result;
use crate::interference::GroundTruth;
use crate::metrics::{CounterSnapshot, Report, WindowReport};
use crate::models::ModelId;
use crate::perfmodel::{LatencyModel, RateMonitor};
use crate::simclock::{ms_to_us, SimTimeUs};
use crate::util::par;
use crate::workload::{Arrival, DynSourceMux};

use super::planner::{FleetPlan, FleetPlanner};
use super::router::Router;

/// Fleet run configuration (the per-node engines share `sim`).
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Per-node simulation parameters (mode, seed, drain).
    pub sim: SimConfig,
    /// Window length (s) for per-window telemetry and the rebalance
    /// cadence of [`FleetEngine::run`].
    pub window_s: f64,
    /// Re-plan from observed per-window rates at window boundaries.
    pub rebalance: bool,
    /// EWMA smoothing for observed rates.
    pub ewma_alpha: f64,
    /// Rate-change threshold that triggers a re-plan.
    pub change_threshold: f64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            sim: SimConfig::default(),
            window_s: 20.0,
            rebalance: true,
            ewma_alpha: 0.6,
            change_threshold: 0.10,
        }
    }
}

/// One window of fleet telemetry.
#[derive(Clone, Debug)]
pub struct FleetWindowStats {
    pub t_start_s: f64,
    pub window_s: f64,
    /// Requests the router dealt this window, per model.
    pub offered: [u64; 5],
    /// Windowed delta report per node.
    pub per_node: Vec<WindowReport>,
    /// Fleet-wide SLO violation rate (drops included) this window.
    pub violation_rate: f64,
    /// True if a rebalance was applied at this window's end.
    pub rebalanced: bool,
}

/// Final fleet accounting: the merged report plus everything needed to
/// audit the run (per-node reports, windows, conservation inputs).
#[derive(Clone, Debug)]
pub struct FleetOutcome {
    /// Fleet-wide report: per-node reports merged bin-exactly
    /// (`Report::merge`).
    pub report: Report,
    /// Each node's own whole-run report.
    pub per_node: Vec<Report>,
    /// Per-window telemetry from [`FleetEngine::run`].
    pub windows: Vec<FleetWindowStats>,
    /// Requests the router offered per model (== served + dropped).
    pub offered: [u64; 5],
    /// Offered requests for models that had no placement when dealt.
    pub unplaced: [u64; 5],
    /// Rebalances applied.
    pub rebalances: u64,
    /// Events processed across all node engines.
    pub events_processed: u64,
    /// Sum of per-node peak live-event counts (each node is O(active)).
    pub peak_live_events: usize,
    /// High-water mark of router-staged arrivals awaiting a lockstep
    /// advance.
    pub peak_routed: usize,
}

impl FleetOutcome {
    /// Fleet-wide served/dropped totals per model.
    pub fn served_dropped(&self) -> ([u64; 5], [u64; 5]) {
        let mut served = [0u64; 5];
        let mut dropped = [0u64; 5];
        for m in ModelId::ALL {
            if let Some(mm) = self.report.model(m) {
                served[m.index()] = mm.served;
                dropped[m.index()] = mm.dropped;
            }
        }
        (served, dropped)
    }

    /// Exact conservation check: offered == served + dropped, per model.
    pub fn conserved(&self) -> bool {
        let (served, dropped) = self.served_dropped();
        ModelId::ALL
            .iter()
            .all(|&m| self.offered[m.index()] == served[m.index()] + dropped[m.index()])
    }
}

/// N single-server engines behind one deterministic router. See the
/// module docs for the lockstep and rebalance semantics.
pub struct FleetEngine<'a> {
    planner: FleetPlanner<'a>,
    plan: FleetPlan,
    nodes: Vec<ServingEngine<'a>>,
    router: Router,
    /// Per-node recycled chunk buffers: router staging -> engine chunk
    /// -> back here -> router staging, so lockstep windows allocate
    /// nothing once capacities stabilize.
    spares: Vec<Vec<Arrival>>,
    cfg: FleetConfig,
    monitor: RateMonitor,
    /// Rates the current plan was made for (rebalance baseline).
    last_planned: [f64; 5],
    prev_counts: Vec<CounterSnapshot>,
    windows: Vec<FleetWindowStats>,
    rebalances: u64,
}

impl<'a> FleetEngine<'a> {
    /// A fleet serving `plan` (from `planner.plan(...)`) fed by
    /// `source`. `window_s` is the whole-run measurement window for the
    /// per-node reports (usually the trace duration, like
    /// `simulate_source`).
    pub fn new(
        lm: &'a LatencyModel,
        gt: &'a GroundTruth,
        planner: FleetPlanner<'a>,
        plan: FleetPlan,
        source: DynSourceMux,
        window_s: f64,
        cfg: &FleetConfig,
    ) -> Self {
        assert!(!plan.schedules.is_empty(), "fleet plan must cover >= 1 node");
        assert_eq!(
            plan.nodes(),
            planner.nodes,
            "plan/planner node counts must match (rebalance re-plans at the \
             planner's node count)"
        );
        let nodes: Vec<ServingEngine<'a>> = plan
            .schedules
            .iter()
            .map(|s| ServingEngine::new(lm, gt, s.clone(), window_s, &cfg.sim))
            .collect();
        let router = Router::new(source, &plan.node_rates);
        let n = nodes.len();
        let mut last_planned = [0.0; 5];
        for m in ModelId::ALL {
            last_planned[m.index()] = plan.total_share(m);
        }
        FleetEngine {
            planner,
            plan,
            nodes,
            router,
            spares: (0..n).map(|_| Vec::new()).collect(),
            cfg: cfg.clone(),
            monitor: RateMonitor::new(cfg.ewma_alpha),
            last_planned,
            prev_counts: vec![CounterSnapshot::default(); n],
            windows: Vec::new(),
            rebalances: 0,
        }
    }

    /// Deal every arrival with time `<= t_us` and advance every node to
    /// `t_us` in lockstep: dealing stays serial (the dealer's
    /// determinism), node advance fans out over the worker pool.
    pub fn run_until(&mut self, t_us: SimTimeUs) {
        // lint: no-alloc — the PR 7 lockstep advance: chunk buffers
        // recycle through `spares`, so steady-state windows allocate
        // nothing once capacities stabilize.
        self.router.deal_until(t_us);
        for (ni, eng) in self.nodes.iter_mut().enumerate() {
            let chunk = self
                .router
                .take_buffer_with(ni, std::mem::take(&mut self.spares[ni]));
            self.spares[ni] = if chunk.is_empty() {
                chunk // nothing dealt: keep the spare, skip the attach
            } else {
                eng.attach_chunk(chunk)
            };
        }
        // Byte-identical to the serial loop for any worker count: nodes
        // share no state within an advance, and each engine's run is a
        // deterministic function of its own state and chunk.
        par::par_for_each_mut(&mut self.nodes, |eng| eng.run_until(t_us));
        // lint: end-no-alloc
    }

    /// Re-plan for `rates` and hand the fleet over live: every node
    /// swaps to its new schedule with `SwapMode::Migrate` (in-flight
    /// work retires under old constants, backlog re-routes, nothing is
    /// lost) and the router re-targets its quota counters to the new
    /// shares. An infeasible re-plan leaves the fleet untouched.
    pub fn rebalance(&mut self, rates: &[f64; 5]) -> Result<()> {
        let next = self.planner.plan(rates)?;
        for (eng, s) in self.nodes.iter_mut().zip(next.schedules.iter()) {
            eng.swap_schedule(s.clone(), SwapMode::Migrate);
        }
        self.router.retarget(&next.node_rates);
        self.plan = next;
        self.last_planned = *rates;
        self.rebalances += 1;
        Ok(())
    }

    /// Serve `duration_s` of the source in telemetry windows, auto-
    /// rebalancing at boundaries when configured, then drain past the
    /// last arrival exactly like the one-shot `simulate_source` path
    /// (`run_until(last_arrival + drain)`).
    pub fn run(&mut self, duration_s: f64) {
        let end_ms = duration_s * 1000.0;
        let window_ms = (self.cfg.window_s * 1000.0).max(1.0);
        let mut t_ms = 0.0;
        while t_ms < end_ms {
            let t_end_ms = (t_ms + window_ms).min(end_ms);
            self.run_until(ms_to_us(t_end_ms));
            let final_window = t_end_ms >= end_ms;
            self.note_window(t_ms / 1000.0, (t_end_ms - t_ms) / 1000.0, !final_window);
            t_ms = t_end_ms;
        }
        // Arrivals past the nominal duration (a source longer than the
        // run) still stream through — dealt in one batch and drained
        // with a single lockstep advance to the last arrival (no
        // rebalance boundary can intervene past the nominal end), then
        // a catch-up telemetry window so Σ windows.offered always
        // equals the outcome's offered totals. Note `peak_routed` sees
        // the whole tail staged at once; it is a router-footprint
        // diagnostic, not part of the serving result.
        let mut tail_end_ms = t_ms;
        if self.router.peek_time_ms().is_some() {
            self.router.deal_all();
            let last = self.router.last_arrival_ms();
            self.run_until(ms_to_us(last));
            tail_end_ms = tail_end_ms.max(last);
        }
        if tail_end_ms > t_ms {
            self.note_window(t_ms / 1000.0, (tail_end_ms - t_ms) / 1000.0, false);
        }
        let horizon =
            ms_to_us(self.router.last_arrival_ms()) + ms_to_us(self.cfg.sim.drain_ms);
        self.run_until(horizon.max(ms_to_us(end_ms)));
    }

    /// Close every node and fold the fleet's accounting together.
    pub fn finish(mut self) -> FleetOutcome {
        let mut per_node = Vec::with_capacity(self.nodes.len());
        let mut events = 0u64;
        let mut peak = 0usize;
        for eng in &mut self.nodes {
            eng.close();
            events += eng.events_processed();
            peak += eng.peak_live_events();
            per_node.push(eng.report().clone());
        }
        let mut report = Report::new(per_node.first().map_or(0.0, |r| r.window_s));
        for r in &per_node {
            report.merge(r);
        }
        FleetOutcome {
            report,
            per_node,
            windows: self.windows,
            offered: self.router.offered_per_model(),
            unplaced: self.router.unplaced_per_model(),
            rebalances: self.rebalances,
            events_processed: events,
            peak_live_events: peak,
            peak_routed: self.router.peak_buffered(),
        }
    }

    /// Currently installed fleet plan.
    pub fn plan(&self) -> &FleetPlan {
        &self.plan
    }

    /// Rebalances applied so far.
    pub fn rebalances(&self) -> u64 {
        self.rebalances
    }

    /// Router-side offered counts so far, per model.
    pub fn offered_per_model(&self) -> [u64; 5] {
        self.router.offered_per_model()
    }

    /// Time of the last routed arrival (drain-horizon anchor for
    /// callers stepping `run_until` manually).
    pub fn last_arrival_ms(&self) -> f64 {
        self.router.last_arrival_ms()
    }

    /// Record one window's telemetry and, when allowed, consider a
    /// rebalance from the smoothed observed rates.
    fn note_window(&mut self, t_start_s: f64, window_s: f64, may_rebalance: bool) {
        let offered = self.router.take_window_dealt();
        for m in ModelId::ALL {
            self.monitor.observe(m, offered[m.index()]);
        }
        self.monitor.tick(window_s.max(1e-9));
        let mut per_node = Vec::with_capacity(self.nodes.len());
        let mut served_total = 0u64;
        let mut bad_total = 0u64;
        for (ni, eng) in self.nodes.iter().enumerate() {
            let w = eng.report().snapshot_window(&self.prev_counts[ni], window_s);
            self.prev_counts[ni] = eng.report().counters();
            served_total += w.served.iter().sum::<u64>();
            bad_total += w.violations.iter().sum::<u64>() + w.dropped.iter().sum::<u64>();
            per_node.push(w);
        }
        let total = served_total + per_node
            .iter()
            .map(|w| w.dropped.iter().sum::<u64>())
            .sum::<u64>();
        let violation_rate = if total == 0 { 0.0 } else { bad_total as f64 / total as f64 };

        let mut rebalanced = false;
        if may_rebalance && self.cfg.rebalance {
            let mut observed = [0.0; 5];
            for m in ModelId::ALL {
                observed[m.index()] = self.monitor.rate(m);
            }
            if rates_changed(&observed, &self.last_planned, self.cfg.change_threshold) {
                // Plan with prediction headroom, like one node's
                // reorganizer; baseline moves even when the re-plan is
                // infeasible so a hopeless load doesn't re-plan every
                // window.
                let target = headroomed(&observed);
                rebalanced = self.rebalance(&target).is_ok();
                self.last_planned = observed;
            }
        }
        self.windows.push(FleetWindowStats {
            t_start_s,
            window_s,
            offered,
            per_node,
            violation_rate,
            rebalanced,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{ElasticPartitioning, SchedCtx};
    use crate::workload::{dyn_sources, poisson_streams, SourceMux};

    fn mux_for(pairs: &[(ModelId, f64)], duration_s: f64, seed: u64) -> DynSourceMux {
        SourceMux::new(dyn_sources(poisson_streams(pairs, duration_s, seed).unwrap()))
    }

    #[test]
    fn lockstep_fleet_conserves_and_spans_nodes_past_one_server() {
        let ctx = SchedCtx::new(4, None);
        let sched = ElasticPartitioning::gpulet();
        let lm = LatencyModel::new();
        let gt = GroundTruth::default();
        // Grow the load until one node rejects it, so the plan must
        // genuinely span nodes.
        let mut rates = [100.0, 0.0, 50.0, 0.0, 40.0];
        use crate::sched::Scheduler;
        while sched.schedule(&ctx, &rates).is_ok() {
            rates.iter_mut().for_each(|r| *r *= 2.0);
            assert!(rates[0] < 1e7, "load never overflowed one node");
        }
        let planner = FleetPlanner::new(&ctx, &sched, 4);
        let plan = planner.plan(&rates).unwrap();
        assert!(plan.active_nodes() >= 2, "load must span nodes");
        let pairs: Vec<(ModelId, f64)> = ModelId::ALL
            .iter()
            .map(|&m| (m, rates[m.index()]))
            .filter(|&(_, r)| r > 0.0)
            .collect();
        let duration = 6.0;
        let cfg = FleetConfig { window_s: 2.0, rebalance: false, ..Default::default() };
        let mut fleet = FleetEngine::new(
            &lm,
            &gt,
            planner,
            plan,
            mux_for(&pairs, duration, 9),
            duration,
            &cfg,
        );
        fleet.run(duration);
        let out = fleet.finish();
        assert!(out.conserved(), "offered != served + dropped");
        assert_eq!(out.windows.len(), 3);
        let offered_total: u64 = out.offered.iter().sum();
        assert!(offered_total > 2_000, "load too small: {offered_total}");
        // At least two nodes actually served work.
        let serving_nodes = out
            .per_node
            .iter()
            .filter(|r| {
                ModelId::ALL
                    .iter()
                    .map(|&m| r.model(m).map_or(0, |mm| mm.served))
                    .sum::<u64>()
                    > 0
            })
            .count();
        assert!(serving_nodes >= 2, "only {serving_nodes} nodes served");
        // Windowed offered counts sum to the total.
        let windowed: u64 = out
            .windows
            .iter()
            .flat_map(|w| w.offered.iter())
            .sum();
        assert_eq!(windowed, offered_total);
    }

    #[test]
    fn auto_rebalance_fires_and_conserves_under_load_shift() {
        let ctx = SchedCtx::new(4, None);
        let sched = ElasticPartitioning::gpulet();
        let lm = LatencyModel::new();
        let gt = GroundTruth::default();
        let planner = FleetPlanner::new(&ctx, &sched, 2);
        // Plan for a light LeNet-only load, then offer much more plus a
        // second model: the observed rates drift far past the trigger.
        let plan = planner.plan(&[80.0, 0.0, 0.0, 0.0, 0.0]).unwrap();
        let pairs = [(ModelId::Lenet, 300.0), (ModelId::Vgg, 60.0)];
        let duration = 8.0;
        let cfg = FleetConfig { window_s: 2.0, rebalance: true, ..Default::default() };
        let mut fleet = FleetEngine::new(
            &lm,
            &gt,
            planner,
            plan,
            mux_for(&pairs, duration, 21),
            duration,
            &cfg,
        );
        fleet.run(duration);
        assert!(fleet.rebalances() >= 1, "load shift must trigger a rebalance");
        let out = fleet.finish();
        assert!(out.conserved(), "conservation must survive rebalances");
        assert!(out.windows.iter().any(|w| w.rebalanced));
        // VGG had no placement before the rebalance: its early arrivals
        // dropped counted, later ones served.
        let vgg = out.report.model(ModelId::Vgg).unwrap();
        assert!(vgg.dropped > 0, "pre-rebalance VGG must drop counted");
        assert!(vgg.served > 0, "post-rebalance VGG must be served");
    }

    #[test]
    fn infeasible_rebalance_keeps_serving() {
        let ctx = SchedCtx::new(4, None);
        let sched = ElasticPartitioning::gpulet();
        let lm = LatencyModel::new();
        let gt = GroundTruth::default();
        let planner = FleetPlanner::new(&ctx, &sched, 2);
        let rates = [100.0, 0.0, 0.0, 0.0, 0.0];
        let plan = planner.plan(&rates).unwrap();
        let duration = 3.0;
        let cfg = FleetConfig { window_s: 1.0, rebalance: false, ..Default::default() };
        let mut fleet = FleetEngine::new(
            &lm,
            &gt,
            planner,
            plan,
            mux_for(&[(ModelId::Lenet, 100.0)], duration, 3),
            duration,
            &cfg,
        );
        fleet.run_until(ms_to_us(1_000.0));
        assert!(fleet.rebalance(&[1e9; 5]).is_err(), "impossible load must not plan");
        assert_eq!(fleet.rebalances(), 0);
        fleet.run_until(ms_to_us(duration * 1000.0));
        fleet.run_until(
            ms_to_us(fleet.last_arrival_ms()) + ms_to_us(cfg.sim.drain_ms),
        );
        let out = fleet.finish();
        assert!(out.conserved());
        let mm = out.report.model(ModelId::Lenet).unwrap();
        assert!(mm.served > 0, "fleet must keep serving after a failed re-plan");
    }
}
