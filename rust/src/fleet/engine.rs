//! The fleet serving core: N persistent [`ServingEngine`]s advanced in
//! lockstep on the shared µs clock behind one deterministic [`Router`].
//!
//! ## Lockstep advance
//!
//! `run_until(t)` first lets the router deal every arrival with time
//! `<= t` into per-node staging buffers — serially, because the
//! Balinski–Young dealer's determinism lives in the order it consumes
//! the merged stream — then hands each node its chunk
//! ([`ServingEngine::attach_chunk`], which recycles the buffer back
//! through the router) and advances **all nodes in parallel**
//! (`util::par::par_for_each_mut`). Each node pulls its arrivals lazily
//! at the exact virtual times a dedicated single-server engine would —
//! the stepped `run_until` path is byte-identical to the one-shot
//! streamed path (`tests/streaming_equivalence.rs`), which is what
//! makes a 1-node fleet byte-identical to `simulate_source` on the same
//! mux/seed (`tests/fleet_equivalence.rs`). Nodes are independent: no
//! event on one node can affect another within an advance, and each
//! engine's computation is a deterministic function of its own state
//! and chunk — so which worker thread runs it cannot change the result,
//! and the fleet outcome is byte-identical for any thread count
//! (`tests/fleet_equivalence.rs` pins threads {1, 2, 5}).
//!
//! ## Rebalancing
//!
//! `run(duration_s)` carves the run into windows. At each boundary the
//! router's per-window dealt counts feed an EWMA rate monitor; when the
//! smoothed rates drift past the reorganizer's trigger
//! (`coordinator::reorganizer::rates_changed` — same notion of "the
//! load moved" as one node's §5 reorganization), the fleet re-plans via
//! its [`FleetPlanner`] and applies the new plan with per-node
//! `swap_schedule(…, SwapMode::Migrate)`: in-flight batches retire
//! under their old epoch's constants, queued backlog re-routes FIFO,
//! and a model that lost every route on a node drops *counted* — the
//! PR 3 hand-over semantics, now fleet-wide. The router re-targets its
//! quota counters to the new shares in the same instant. An infeasible
//! re-plan (the observed load outgrew the fleet) keeps the current
//! plan serving — rebalancing degrades, never destroys — and is
//! *counted* in [`FleetEngine::replan_failures`] with a log line, so a
//! fleet silently limping on a stale plan is observable.
//!
//! ## Faults
//!
//! A [`FaultPlan`] scripts node failures and recoveries. `run`
//! consumes it at window boundaries (an event at time `t` fires at the
//! first boundary `>= t`, so fault timing is a pure function of the
//! plan and the window grid — thread-count independent). `NodeDown`
//! destroys the node's backlog and in-flight work
//! ([`ServingEngine::fail`], every request accounted as
//! `lost_to_failure`), marks it dead in the router, and re-plans the
//! survivors via [`FleetPlanner::plan_masked`]; `NodeUp` re-admits the
//! node and re-plans the full fleet. Either re-plan may be infeasible;
//! the fleet then keeps the stale plan (dead nodes still take no new
//! arrivals — the router's liveness mask zeroes their weights) and
//! counts the failure.
//!
//! ## Admission
//!
//! An optional [`AdmissionSpec`] arms the router's front-end gate.
//! Each window boundary re-aims it: the EWMA-observed *demand* rate
//! per model (counted pre-gate, so shedding cannot hide the overload
//! it is shedding) is compared with the active plan's schedulable
//! capacity (`FleetPlan::total_share`), and the admitted fraction is
//! set to keep admitted load inside `capacity * headroom`. Over-quota
//! arrivals shed (counted) or degrade to a configured cheaper model.
//!
//! ## Conservation
//!
//! Every arrival pulled from the source is either shed at the gate
//! (counted per original model) or dealt to exactly one node, and each
//! node's engine accounts every dealt request as served, dropped, or —
//! when the node fails — lost (including across swaps and at close).
//! So fleet-wide, `demand[m] == offered[m] + shed[m]` and
//! `offered[m] == served[m] + dropped[m] + lost_to_failure[m]`
//! exactly, for any node count, any rebalance history, and any fault
//! script — `tests/fleet_equivalence.rs` pins it. (Degraded arrivals
//! are offered under their fallback model, so the per-model demand
//! split holds whenever degrade is off; the aggregate identity holds
//! always.)

use crate::coordinator::reorganizer::{headroomed, rates_changed};
use crate::coordinator::{ServingEngine, SimConfig, SwapMode};
use crate::error::Result;
use crate::interference::GroundTruth;
use crate::metrics::{CounterSnapshot, Report, WindowReport};
use crate::models::ModelId;
use crate::perfmodel::{LatencyModel, RateMonitor};
use crate::simclock::{ms_to_us, SimTimeUs};
use crate::telemetry::{EventKind, NodeGauges, Timeline, Tracer, WindowGauges, NO_NODE};
use crate::util::par;
use crate::workload::{Arrival, DynSourceMux, FaultKind, FaultPlan};

use super::planner::{FleetPlan, FleetPlanner};
use super::router::{AdmissionSpec, Router};

/// Fleet run configuration (the per-node engines share `sim`).
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Per-node simulation parameters (mode, seed, drain).
    pub sim: SimConfig,
    /// Window length (s) for per-window telemetry and the rebalance
    /// cadence of [`FleetEngine::run`].
    pub window_s: f64,
    /// Re-plan from observed per-window rates at window boundaries.
    pub rebalance: bool,
    /// EWMA smoothing for observed rates.
    pub ewma_alpha: f64,
    /// Rate-change threshold that triggers a re-plan.
    pub change_threshold: f64,
    /// Telemetry ring capacity per tracer (router/fleet plus one per
    /// node). 0 disables tracing entirely — every hook is a single
    /// predictable branch and [`FleetOutcome::timeline`] stays empty.
    pub trace_cap: usize,
    /// Request-span sampling modulus: keep spans whose id hashes to
    /// `0 mod trace_sample` (1 = keep everything). Batch, fault and
    /// plan events are always kept; the event ledger is always exact.
    pub trace_sample: u64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            sim: SimConfig::default(),
            window_s: 20.0,
            rebalance: true,
            ewma_alpha: 0.6,
            change_threshold: 0.10,
            trace_cap: 0,
            trace_sample: 1,
        }
    }
}

/// One window of fleet telemetry.
#[derive(Clone, Debug)]
pub struct FleetWindowStats {
    pub t_start_s: f64,
    pub window_s: f64,
    /// Requests the router dealt (post-gate) this window, per model.
    pub offered: [u64; 5],
    /// Requests pulled from the source this window per *original*
    /// model, admitted or not (`offered` + shed, modulo degrades).
    pub demand: [u64; 5],
    /// Requests the admission gate refused this window, per original
    /// model.
    pub shed: [u64; 5],
    /// Windowed delta report per node.
    pub per_node: Vec<WindowReport>,
    /// Fleet-wide SLO violation rate (drops included) this window.
    pub violation_rate: f64,
    /// True if a rebalance was applied at this window's end.
    pub rebalanced: bool,
}

/// Final fleet accounting: the merged report plus everything needed to
/// audit the run (per-node reports, windows, conservation inputs).
#[derive(Clone, Debug)]
pub struct FleetOutcome {
    /// Fleet-wide report: per-node reports merged bin-exactly
    /// (`Report::merge`).
    pub report: Report,
    /// Each node's own whole-run report.
    pub per_node: Vec<Report>,
    /// Per-window telemetry from [`FleetEngine::run`].
    pub windows: Vec<FleetWindowStats>,
    /// Requests the router dealt (post-gate) per model
    /// (== served + dropped + lost_to_failure).
    pub offered: [u64; 5],
    /// Requests pulled from the source per *original* model, admitted
    /// or not (Σ demand == Σ offered + Σ shed).
    pub demand: [u64; 5],
    /// Requests the admission gate refused, per original model.
    pub shed: [u64; 5],
    /// Requests rewritten to their fallback model, per original model
    /// (diagnostic — served/dropped accounting lives under the
    /// fallback).
    pub degraded: [u64; 5],
    /// Offered requests for models that had no placement when dealt.
    pub unplaced: [u64; 5],
    /// Rebalances applied.
    pub rebalances: u64,
    /// Re-plans (auto-rebalance or failover) that found no feasible
    /// placement and left the previous plan serving.
    pub replan_failures: u64,
    /// Events processed across all node engines.
    pub events_processed: u64,
    /// Sum of per-node peak live-event counts (each node is O(active)).
    pub peak_live_events: usize,
    /// High-water mark of router-staged arrivals awaiting a lockstep
    /// advance.
    pub peak_routed: usize,
    /// The run's merged telemetry: time-ordered lifecycle events, the
    /// exact event ledger, and the per-window gauge series. Empty when
    /// `FleetConfig::trace_cap` is 0. Not part of the serving result —
    /// the report/counter fields above are byte-identical with tracing
    /// on or off.
    pub timeline: Timeline,
}

impl FleetOutcome {
    /// Fleet-wide served/dropped totals per model.
    pub fn served_dropped(&self) -> ([u64; 5], [u64; 5]) {
        let mut served = [0u64; 5];
        let mut dropped = [0u64; 5];
        for m in ModelId::ALL {
            if let Some(mm) = self.report.model(m) {
                served[m.index()] = mm.served;
                dropped[m.index()] = mm.dropped;
            }
        }
        (served, dropped)
    }

    /// Fleet-wide lost-to-failure totals per model.
    pub fn lost_to_failure(&self) -> [u64; 5] {
        let mut lost = [0u64; 5];
        for m in ModelId::ALL {
            if let Some(mm) = self.report.model(m) {
                lost[m.index()] = mm.lost_to_failure;
            }
        }
        lost
    }

    /// Exact conservation check, per model:
    /// `offered == served + dropped + lost_to_failure` (every dealt
    /// request is accounted by its node) and, at the gate,
    /// `Σ demand == Σ offered + Σ shed` (every pulled request is shed
    /// or dealt). When nothing was degraded the gate identity holds
    /// per model too; a degraded request is demanded under its
    /// original model but offered under its fallback.
    pub fn conserved(&self) -> bool {
        let (served, dropped) = self.served_dropped();
        let lost = self.lost_to_failure();
        let dealt_ok = ModelId::ALL.iter().all(|&m| {
            let i = m.index();
            self.offered[i] == served[i] + dropped[i] + lost[i]
        });
        let demand_total: u64 = self.demand.iter().sum();
        let gate_ok =
            demand_total == self.offered.iter().sum::<u64>() + self.shed.iter().sum::<u64>();
        let per_model_gate_ok = self.degraded != [0u64; 5]
            || ModelId::ALL.iter().all(|&m| {
                let i = m.index();
                self.demand[i] == self.offered[i] + self.shed[i]
            });
        dealt_ok && gate_ok && per_model_gate_ok
    }
}

/// N single-server engines behind one deterministic router. See the
/// module docs for the lockstep and rebalance semantics.
pub struct FleetEngine<'a> {
    lm: &'a LatencyModel,
    planner: FleetPlanner<'a>,
    plan: FleetPlan,
    nodes: Vec<ServingEngine<'a>>,
    router: Router,
    /// Per-node recycled chunk buffers: router staging -> engine chunk
    /// -> back here -> router staging, so lockstep windows allocate
    /// nothing once capacities stabilize.
    spares: Vec<Vec<Arrival>>,
    cfg: FleetConfig,
    monitor: RateMonitor,
    /// Rates the current plan was made for (rebalance baseline, and
    /// the demand estimate failover re-plans place for).
    last_planned: [f64; 5],
    prev_counts: Vec<CounterSnapshot>,
    windows: Vec<FleetWindowStats>,
    rebalances: u64,
    /// Scripted faults, consumed in order at window boundaries.
    faults: FaultPlan,
    fault_pos: usize,
    /// Node liveness (mirrors the router's mask; the planner masks
    /// placements by it).
    alive: Vec<bool>,
    replan_failures: u64,
    /// Fleet-scope telemetry recorder (fault and re-plan marks).
    tracer: Tracer,
    /// Accumulating gauge windows; per-source events merge in at
    /// `finish` (fleet, then router, then nodes ascending — a fixed
    /// serial order, so the result is thread-count invariant).
    timeline: Timeline,
}

impl<'a> FleetEngine<'a> {
    /// A fleet serving `plan` (from `planner.plan(...)`) fed by
    /// `source`. `window_s` is the whole-run measurement window for the
    /// per-node reports (usually the trace duration, like
    /// `simulate_source`).
    pub fn new(
        lm: &'a LatencyModel,
        gt: &'a GroundTruth,
        planner: FleetPlanner<'a>,
        plan: FleetPlan,
        source: DynSourceMux,
        window_s: f64,
        cfg: &FleetConfig,
    ) -> Self {
        assert!(!plan.schedules.is_empty(), "fleet plan must cover >= 1 node");
        assert_eq!(
            plan.nodes(),
            planner.nodes,
            "plan/planner node counts must match (rebalance re-plans at the \
             planner's node count)"
        );
        let mut nodes: Vec<ServingEngine<'a>> = plan
            .schedules
            .iter()
            .map(|s| ServingEngine::new(lm, gt, s.clone(), window_s, &cfg.sim))
            .collect();
        let mut router = Router::new(source, &plan.node_rates);
        let mut timeline = Timeline::default();
        let mut tracer = Tracer::off();
        if cfg.trace_cap > 0 {
            let sample = cfg.trace_sample.max(1);
            timeline.sample_n = sample;
            tracer = Tracer::new(NO_NODE, cfg.trace_cap, sample);
            router.set_tracer(Tracer::new(NO_NODE, cfg.trace_cap, sample));
            for (ni, eng) in nodes.iter_mut().enumerate() {
                eng.set_tracer(Tracer::new(ni as u32, cfg.trace_cap, sample));
            }
        }
        let n = nodes.len();
        let mut last_planned = [0.0; 5];
        for m in ModelId::ALL {
            last_planned[m.index()] = plan.total_share(m);
        }
        FleetEngine {
            lm,
            planner,
            plan,
            nodes,
            router,
            spares: (0..n).map(|_| Vec::new()).collect(),
            cfg: cfg.clone(),
            monitor: RateMonitor::new(cfg.ewma_alpha),
            last_planned,
            prev_counts: vec![CounterSnapshot::default(); n],
            windows: Vec::new(),
            rebalances: 0,
            faults: FaultPlan::none(),
            fault_pos: 0,
            alive: vec![true; n],
            replan_failures: 0,
            tracer,
            timeline,
        }
    }

    /// Arm a scripted fault plan, consumed by [`run`] at window
    /// boundaries (an event at `t` fires at the first boundary
    /// `>= t`). Errors if the plan references a node the fleet does
    /// not have.
    ///
    /// [`run`]: FleetEngine::run
    pub fn set_fault_plan(&mut self, plan: FaultPlan) -> Result<()> {
        if let Some(max) = plan.max_node() {
            if max >= self.nodes.len() {
                return Err(crate::error::Error::Other(format!(
                    "fault plan references node {max}, fleet has {}",
                    self.nodes.len()
                )));
            }
        }
        self.faults = plan;
        self.fault_pos = 0;
        Ok(())
    }

    /// Arm the router's admission gate (default off). The gate is
    /// re-aimed from observed demand at every window boundary.
    pub fn set_admission(&mut self, spec: AdmissionSpec) {
        self.router.set_admission(spec);
    }

    /// Deal every arrival with time `<= t_us` and advance every node to
    /// `t_us` in lockstep: dealing stays serial (the dealer's
    /// determinism), node advance fans out over the worker pool.
    pub fn run_until(&mut self, t_us: SimTimeUs) {
        // lint: no-alloc — the PR 7 lockstep advance: chunk buffers
        // recycle through `spares`, so steady-state windows allocate
        // nothing once capacities stabilize.
        self.router.deal_until(t_us);
        for (ni, eng) in self.nodes.iter_mut().enumerate() {
            let chunk = self
                .router
                .take_buffer_with(ni, std::mem::take(&mut self.spares[ni]));
            self.spares[ni] = if chunk.is_empty() {
                chunk // nothing dealt: keep the spare, skip the attach
            } else {
                eng.attach_chunk(chunk)
            };
        }
        // Byte-identical to the serial loop for any worker count: nodes
        // share no state within an advance, and each engine's run is a
        // deterministic function of its own state and chunk.
        par::par_for_each_mut(&mut self.nodes, |eng| eng.run_until(t_us));
        // lint: end-no-alloc
    }

    /// Re-plan for `rates` and hand the fleet over live: every node
    /// swaps to its new schedule with `SwapMode::Migrate` (in-flight
    /// work retires under old constants, backlog re-routes, nothing is
    /// lost) and the router re-targets its quota counters to the new
    /// shares. An infeasible re-plan leaves the fleet untouched.
    pub fn rebalance(&mut self, rates: &[f64; 5]) -> Result<()> {
        let next = self.planner.plan_masked(rates, &self.alive)?;
        self.install_plan(next);
        self.last_planned = *rates;
        self.rebalances += 1;
        Ok(())
    }

    /// Swap every node to `next` (Migrate semantics) and re-target the
    /// router in the same instant.
    fn install_plan(&mut self, next: FleetPlan) {
        for (eng, s) in self.nodes.iter_mut().zip(next.schedules.iter()) {
            eng.swap_schedule(s.clone(), SwapMode::Migrate);
        }
        self.router.retarget(&next.node_rates);
        self.plan = next;
    }

    /// Fire every scripted fault with `at_s <= t_s`, in plan order.
    /// Down: destroy the node's work (counted as lost), mask it out of
    /// routing, and re-plan the survivors for the demand the current
    /// plan was made for. Up: unmask and re-plan the full fleet. A
    /// failed re-plan keeps the stale plan serving (the dead node
    /// still takes no new arrivals) and is counted + traced
    /// (`replan-failed`) — no stderr chatter; `--trace` captures it.
    fn apply_faults(&mut self, t_s: f64) {
        let t_us = ms_to_us(t_s * 1000.0);
        while self.fault_pos < self.faults.events().len()
            && self.faults.events()[self.fault_pos].at_s <= t_s
        {
            let ev = self.faults.events()[self.fault_pos];
            self.fault_pos += 1;
            match ev.kind {
                FaultKind::Down => {
                    if !self.alive[ev.node] {
                        continue; // already down — nothing to destroy
                    }
                    self.nodes[ev.node].fail();
                    self.alive[ev.node] = false;
                    self.router.set_alive(ev.node, false);
                    self.tracer.mark(t_us, EventKind::NodeDown, 0, ev.node as u64, 1);
                }
                FaultKind::Up => {
                    if self.alive[ev.node] {
                        continue;
                    }
                    self.alive[ev.node] = true;
                    self.router.set_alive(ev.node, true);
                    self.tracer.mark(t_us, EventKind::NodeUp, 0, ev.node as u64, 1);
                }
            }
            let target = self.last_planned;
            match self.planner.plan_masked(&target, &self.alive) {
                Ok(next) => self.install_plan(next),
                Err(_) => {
                    self.replan_failures += 1;
                    self.tracer.mark(t_us, EventKind::ReplanFailed, 0, ev.node as u64, 1);
                }
            }
        }
    }

    /// Serve `duration_s` of the source in telemetry windows, auto-
    /// rebalancing at boundaries when configured, then drain past the
    /// last arrival exactly like the one-shot `simulate_source` path
    /// (`run_until(last_arrival + drain)`).
    pub fn run(&mut self, duration_s: f64) {
        let end_ms = duration_s * 1000.0;
        let window_ms = (self.cfg.window_s * 1000.0).max(1.0);
        let mut t_ms = 0.0;
        while t_ms < end_ms {
            let t_end_ms = (t_ms + window_ms).min(end_ms);
            self.run_until(ms_to_us(t_end_ms));
            // Scripted faults fire at the first boundary at/after their
            // time — before the window's telemetry, so the lost counts
            // land in the window that ends at the fault.
            self.apply_faults(t_end_ms / 1000.0);
            let final_window = t_end_ms >= end_ms;
            self.note_window(t_ms / 1000.0, (t_end_ms - t_ms) / 1000.0, !final_window);
            t_ms = t_end_ms;
        }
        // Arrivals past the nominal duration (a source longer than the
        // run) still stream through — dealt in one batch and drained
        // with a single lockstep advance to the last arrival (no
        // rebalance boundary can intervene past the nominal end), then
        // a catch-up telemetry window so Σ windows.offered always
        // equals the outcome's offered totals. Note `peak_routed` sees
        // the whole tail staged at once; it is a router-footprint
        // diagnostic, not part of the serving result.
        let mut tail_end_ms = t_ms;
        if self.router.peek_time_ms().is_some() {
            self.router.deal_all();
            let last = self.router.last_arrival_ms();
            self.run_until(ms_to_us(last));
            tail_end_ms = tail_end_ms.max(last);
        }
        if tail_end_ms > t_ms {
            self.note_window(t_ms / 1000.0, (tail_end_ms - t_ms) / 1000.0, false);
        }
        let horizon =
            ms_to_us(self.router.last_arrival_ms()) + ms_to_us(self.cfg.sim.drain_ms);
        self.run_until(horizon.max(ms_to_us(end_ms)));
    }

    /// Close every node and fold the fleet's accounting together.
    pub fn finish(mut self) -> FleetOutcome {
        let mut per_node = Vec::with_capacity(self.nodes.len());
        let mut events = 0u64;
        let mut peak = 0usize;
        for eng in &mut self.nodes {
            eng.close();
            events += eng.events_processed();
            peak += eng.peak_live_events();
            per_node.push(eng.report().clone());
        }
        // Merge the per-source rings in a fixed serial order (fleet,
        // router, nodes ascending), then stable-sort by timestamp: the
        // merged stream is a pure function of (seed, plan, faults) —
        // byte-identical for any worker-thread count.
        let mut timeline = self.timeline;
        self.tracer.drain_into(&mut timeline);
        self.router.tracer_mut().drain_into(&mut timeline);
        for eng in &mut self.nodes {
            eng.tracer_mut().drain_into(&mut timeline);
        }
        timeline.sort_events();
        let mut report = Report::new(per_node.first().map_or(0.0, |r| r.window_s));
        for r in &per_node {
            report.merge(r);
        }
        // Shed requests never reached a node, so no engine counted
        // them — fold the router's gate counts into the merged report
        // here, under each original model's SLO, so the fleet report's
        // own conservation (`total == served + dropped + shed + lost`)
        // closes.
        let shed = self.router.shed_per_model();
        for m in ModelId::ALL {
            if shed[m.index()] > 0 {
                report.model_mut(m, self.lm.slo_ms(m)).shed += shed[m.index()];
            }
        }
        // Degradations likewise happen at the gate, under the original
        // model — fold them in so the report's table/JSON show the same
        // per-model counts as `FleetOutcome::degraded`.
        let degraded = self.router.degraded_per_model();
        for m in ModelId::ALL {
            if degraded[m.index()] > 0 {
                report.model_mut(m, self.lm.slo_ms(m)).degraded += degraded[m.index()];
            }
        }
        FleetOutcome {
            report,
            per_node,
            windows: self.windows,
            offered: self.router.offered_per_model(),
            demand: self.router.demand_per_model(),
            shed,
            degraded,
            unplaced: self.router.unplaced_per_model(),
            rebalances: self.rebalances,
            replan_failures: self.replan_failures,
            events_processed: events,
            peak_live_events: peak,
            peak_routed: self.router.peak_buffered(),
            timeline,
        }
    }

    /// Currently installed fleet plan.
    pub fn plan(&self) -> &FleetPlan {
        &self.plan
    }

    /// Rebalances applied so far.
    pub fn rebalances(&self) -> u64 {
        self.rebalances
    }

    /// Re-plans (auto-rebalance or failover) that found no feasible
    /// placement so far.
    pub fn replan_failures(&self) -> u64 {
        self.replan_failures
    }

    /// Per-node liveness under the armed fault plan.
    pub fn alive(&self) -> &[bool] {
        &self.alive
    }

    /// Router-side offered counts so far, per model.
    pub fn offered_per_model(&self) -> [u64; 5] {
        self.router.offered_per_model()
    }

    /// Time of the last routed arrival (drain-horizon anchor for
    /// callers stepping `run_until` manually).
    pub fn last_arrival_ms(&self) -> f64 {
        self.router.last_arrival_ms()
    }

    /// Record one window's telemetry and, when allowed, consider a
    /// rebalance from the smoothed observed rates.
    fn note_window(&mut self, t_start_s: f64, window_s: f64, may_rebalance: bool) {
        let offered = self.router.take_window_dealt();
        let demand = self.router.take_window_demand();
        let shed = self.router.take_window_shed();
        // The monitor sees pre-gate demand: the planner and the
        // admission gate must aim at what users ask for, not at what
        // the gate already let through. With admission off the demand
        // and dealt windows are the same counts.
        for m in ModelId::ALL {
            self.monitor.observe(m, demand[m.index()]);
        }
        self.monitor.tick(window_s.max(1e-9));
        let mut per_node = Vec::with_capacity(self.nodes.len());
        let mut served_total = 0u64;
        let mut bad_total = 0u64;
        for (ni, eng) in self.nodes.iter().enumerate() {
            let w = eng.report().snapshot_window(&self.prev_counts[ni], window_s);
            self.prev_counts[ni] = eng.report().counters();
            served_total += w.served.iter().sum::<u64>();
            bad_total += w.violations.iter().sum::<u64>() + w.dropped.iter().sum::<u64>();
            per_node.push(w);
        }
        let total = served_total + per_node
            .iter()
            .map(|w| w.dropped.iter().sum::<u64>())
            .sum::<u64>();
        let violation_rate = if total == 0 { 0.0 } else { bad_total as f64 / total as f64 };

        let mut observed = [0.0; 5];
        for m in ModelId::ALL {
            observed[m.index()] = self.monitor.rate(m);
        }
        let mut rebalanced = false;
        if may_rebalance
            && self.cfg.rebalance
            && rates_changed(&observed, &self.last_planned, self.cfg.change_threshold)
        {
            // Plan with prediction headroom, like one node's
            // reorganizer; baseline moves even when the re-plan is
            // infeasible so a hopeless load doesn't re-plan every
            // window.
            let target = headroomed(&observed);
            let boundary_us = ms_to_us((t_start_s + window_s) * 1000.0);
            match self.rebalance(&target) {
                Ok(()) => {
                    rebalanced = true;
                    self.tracer.mark(boundary_us, EventKind::Rebalance, 0, 0, 1);
                }
                Err(_) => {
                    // The observed load outgrew the fleet: keep the
                    // stale plan serving, but never silently — count
                    // it and trace it (`replan-failed`).
                    self.replan_failures += 1;
                    self.tracer.mark(boundary_us, EventKind::ReplanFailed, 0, 0, 1);
                }
            }
            // The baseline tracks the *observed* rates either way, so
            // a hopeless load doesn't re-plan every window.
            self.last_planned = observed;
        }
        // Re-aim the admission gate every window from smoothed demand
        // vs what the (possibly just-swapped) plan can schedule. A
        // no-op with admission off.
        let mut capacity = [0.0; 5];
        for m in ModelId::ALL {
            capacity[m.index()] = self.plan.total_share(m);
        }
        self.router.update_admission(&observed, &capacity);
        if self.tracer.enabled() {
            // Gauge snapshot at the lockstep boundary: every node's
            // queue depths / in-flight state observed at the same
            // instant, in node order (deterministic).
            let mut gauges = WindowGauges {
                t_s: t_start_s + window_s,
                alive: self.alive.iter().filter(|&&a| a).count() as u32,
                deals: offered,
                admit_frac: self.router.admit_fractions(),
                nodes: Vec::with_capacity(self.nodes.len()),
            };
            for (ni, eng) in self.nodes.iter().enumerate() {
                let mut ng = NodeGauges {
                    node: ni as u32,
                    alive: self.alive[ni],
                    in_flight: eng.in_flight_batches(),
                    util: eng.busy_fraction(),
                    queues: Vec::new(),
                };
                eng.queue_gauges(&mut ng.queues);
                gauges.nodes.push(ng);
            }
            self.timeline.windows.push(gauges);
        }
        self.windows.push(FleetWindowStats {
            t_start_s,
            window_s,
            offered,
            demand,
            shed,
            per_node,
            violation_rate,
            rebalanced,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{ElasticPartitioning, SchedCtx};
    use crate::workload::{dyn_sources, poisson_streams, SourceMux};

    fn mux_for(pairs: &[(ModelId, f64)], duration_s: f64, seed: u64) -> DynSourceMux {
        SourceMux::new(dyn_sources(poisson_streams(pairs, duration_s, seed).unwrap()))
    }

    #[test]
    fn lockstep_fleet_conserves_and_spans_nodes_past_one_server() {
        let ctx = SchedCtx::new(4, None);
        let sched = ElasticPartitioning::gpulet();
        let lm = LatencyModel::new();
        let gt = GroundTruth::default();
        // Grow the load until one node rejects it, so the plan must
        // genuinely span nodes.
        let mut rates = [100.0, 0.0, 50.0, 0.0, 40.0];
        use crate::sched::Scheduler;
        while sched.schedule(&ctx, &rates).is_ok() {
            rates.iter_mut().for_each(|r| *r *= 2.0);
            assert!(rates[0] < 1e7, "load never overflowed one node");
        }
        let planner = FleetPlanner::new(&ctx, &sched, 4);
        let plan = planner.plan(&rates).unwrap();
        assert!(plan.active_nodes() >= 2, "load must span nodes");
        let pairs: Vec<(ModelId, f64)> = ModelId::ALL
            .iter()
            .map(|&m| (m, rates[m.index()]))
            .filter(|&(_, r)| r > 0.0)
            .collect();
        let duration = 6.0;
        let cfg = FleetConfig { window_s: 2.0, rebalance: false, ..Default::default() };
        let mut fleet = FleetEngine::new(
            &lm,
            &gt,
            planner,
            plan,
            mux_for(&pairs, duration, 9),
            duration,
            &cfg,
        );
        fleet.run(duration);
        let out = fleet.finish();
        assert!(out.conserved(), "offered != served + dropped");
        assert_eq!(out.windows.len(), 3);
        let offered_total: u64 = out.offered.iter().sum();
        assert!(offered_total > 2_000, "load too small: {offered_total}");
        // At least two nodes actually served work.
        let serving_nodes = out
            .per_node
            .iter()
            .filter(|r| {
                ModelId::ALL
                    .iter()
                    .map(|&m| r.model(m).map_or(0, |mm| mm.served))
                    .sum::<u64>()
                    > 0
            })
            .count();
        assert!(serving_nodes >= 2, "only {serving_nodes} nodes served");
        // Windowed offered counts sum to the total.
        let windowed: u64 = out
            .windows
            .iter()
            .flat_map(|w| w.offered.iter())
            .sum();
        assert_eq!(windowed, offered_total);
    }

    #[test]
    fn auto_rebalance_fires_and_conserves_under_load_shift() {
        let ctx = SchedCtx::new(4, None);
        let sched = ElasticPartitioning::gpulet();
        let lm = LatencyModel::new();
        let gt = GroundTruth::default();
        let planner = FleetPlanner::new(&ctx, &sched, 2);
        // Plan for a light LeNet-only load, then offer much more plus a
        // second model: the observed rates drift far past the trigger.
        let plan = planner.plan(&[80.0, 0.0, 0.0, 0.0, 0.0]).unwrap();
        let pairs = [(ModelId::Lenet, 300.0), (ModelId::Vgg, 60.0)];
        let duration = 8.0;
        let cfg = FleetConfig { window_s: 2.0, rebalance: true, ..Default::default() };
        let mut fleet = FleetEngine::new(
            &lm,
            &gt,
            planner,
            plan,
            mux_for(&pairs, duration, 21),
            duration,
            &cfg,
        );
        fleet.run(duration);
        assert!(fleet.rebalances() >= 1, "load shift must trigger a rebalance");
        let out = fleet.finish();
        assert!(out.conserved(), "conservation must survive rebalances");
        assert!(out.windows.iter().any(|w| w.rebalanced));
        // VGG had no placement before the rebalance: its early arrivals
        // dropped counted, later ones served.
        let vgg = out.report.model(ModelId::Vgg).unwrap();
        assert!(vgg.dropped > 0, "pre-rebalance VGG must drop counted");
        assert!(vgg.served > 0, "post-rebalance VGG must be served");
    }

    #[test]
    fn node_failure_loses_counted_and_recovery_restores_service() {
        use crate::workload::FaultEvent;
        let ctx = SchedCtx::new(4, None);
        let sched = ElasticPartitioning::gpulet();
        let lm = LatencyModel::new();
        let gt = GroundTruth::default();
        let planner = FleetPlanner::new(&ctx, &sched, 2);
        // Light load: one survivor can hold it, so the failover re-plan
        // succeeds and nothing is shed.
        let rates = [120.0, 0.0, 0.0, 0.0, 40.0];
        let plan = planner.plan(&rates).unwrap();
        let duration = 8.0;
        let cfg = FleetConfig { window_s: 1.0, rebalance: false, ..Default::default() };
        let pairs = [(ModelId::Lenet, 120.0), (ModelId::Vgg, 40.0)];
        let mut fleet = FleetEngine::new(
            &lm,
            &gt,
            planner,
            plan,
            mux_for(&pairs, duration, 11),
            duration,
            &cfg,
        );
        fleet
            .set_fault_plan(
                crate::workload::FaultPlan::new(vec![
                    FaultEvent { at_s: 2.0, node: 0, kind: FaultKind::Down },
                    FaultEvent { at_s: 5.0, node: 0, kind: FaultKind::Up },
                ])
                .unwrap(),
            )
            .unwrap();
        fleet.run(duration);
        assert_eq!(fleet.replan_failures(), 0, "survivor can hold this load");
        assert_eq!(fleet.alive(), &[true, true], "node 0 must be back up");
        let out = fleet.finish();
        assert!(out.conserved(), "conservation must survive down->up->re-plan");
        let lost: u64 = out.lost_to_failure().iter().sum();
        assert!(lost > 0, "the killed node had work to lose");
        assert_eq!(out.shed, [0; 5]);
        // Node 0 served again after recovery: its whole-run served
        // count exceeds what it could have amassed before the 2 s kill
        // alone is not provable cheaply, but the fleet as a whole kept
        // serving and node 0's report shows service.
        let n0: u64 = ModelId::ALL
            .iter()
            .map(|&m| out.per_node[0].model(m).map_or(0, |mm| mm.served))
            .sum();
        assert!(n0 > 0, "recovered node must have served");
        // An out-of-range fault plan is rejected up front.
        let mut fleet2 = FleetEngine::new(
            &lm,
            &gt,
            FleetPlanner::new(&ctx, &sched, 2),
            FleetPlanner::new(&ctx, &sched, 2).plan(&rates).unwrap(),
            mux_for(&pairs, 1.0, 11),
            1.0,
            &cfg,
        );
        assert!(fleet2
            .set_fault_plan(
                crate::workload::FaultPlan::new(vec![FaultEvent {
                    at_s: 0.5,
                    node: 7,
                    kind: FaultKind::Down,
                }])
                .unwrap(),
            )
            .is_err());
    }

    #[test]
    fn infeasible_failover_counts_replan_failure_and_conserves() {
        use crate::workload::FaultEvent;
        let ctx = SchedCtx::new(4, None);
        let sched = ElasticPartitioning::gpulet();
        let lm = LatencyModel::new();
        let gt = GroundTruth::default();
        use crate::sched::Scheduler;
        // A load one node rejects: killing one of two nodes makes the
        // failover re-plan infeasible — the stale plan keeps serving,
        // the dead node takes nothing, and the failure is counted.
        let mut rates = [100.0, 0.0, 50.0, 0.0, 40.0];
        while sched.schedule(&ctx, &rates).is_ok() {
            rates.iter_mut().for_each(|r| *r *= 2.0);
            assert!(rates[0] < 1e7);
        }
        let planner = FleetPlanner::new(&ctx, &sched, 2);
        let Ok(plan) = planner.plan(&rates) else {
            // Two nodes can't hold it either — grow the fleet instead
            // of asserting on capacity specifics.
            return;
        };
        let pairs: Vec<(ModelId, f64)> = ModelId::ALL
            .iter()
            .map(|&m| (m, rates[m.index()]))
            .filter(|&(_, r)| r > 0.0)
            .collect();
        let duration = 4.0;
        let cfg = FleetConfig { window_s: 1.0, rebalance: false, ..Default::default() };
        let mut fleet = FleetEngine::new(
            &lm,
            &gt,
            planner,
            plan,
            mux_for(&pairs, duration, 13),
            duration,
            &cfg,
        );
        fleet
            .set_fault_plan(
                crate::workload::FaultPlan::new(vec![FaultEvent {
                    at_s: 1.5,
                    node: 1,
                    kind: FaultKind::Down,
                }])
                .unwrap(),
            )
            .unwrap();
        fleet.run(duration);
        assert!(fleet.replan_failures() >= 1, "infeasible failover must be counted");
        assert_eq!(fleet.alive(), &[true, false]);
        let out = fleet.finish();
        assert!(out.conserved(), "stale-plan serving must still conserve");
        assert!(out.lost_to_failure().iter().sum::<u64>() > 0);
        assert!(out.replan_failures >= 1, "outcome must surface the count");
    }

    #[test]
    fn infeasible_rebalance_keeps_serving() {
        let ctx = SchedCtx::new(4, None);
        let sched = ElasticPartitioning::gpulet();
        let lm = LatencyModel::new();
        let gt = GroundTruth::default();
        let planner = FleetPlanner::new(&ctx, &sched, 2);
        let rates = [100.0, 0.0, 0.0, 0.0, 0.0];
        let plan = planner.plan(&rates).unwrap();
        let duration = 3.0;
        let cfg = FleetConfig { window_s: 1.0, rebalance: false, ..Default::default() };
        let mut fleet = FleetEngine::new(
            &lm,
            &gt,
            planner,
            plan,
            mux_for(&[(ModelId::Lenet, 100.0)], duration, 3),
            duration,
            &cfg,
        );
        fleet.run_until(ms_to_us(1_000.0));
        assert!(fleet.rebalance(&[1e9; 5]).is_err(), "impossible load must not plan");
        assert_eq!(fleet.rebalances(), 0);
        fleet.run_until(ms_to_us(duration * 1000.0));
        fleet.run_until(
            ms_to_us(fleet.last_arrival_ms()) + ms_to_us(cfg.sim.drain_ms),
        );
        let out = fleet.finish();
        assert!(out.conserved());
        let mm = out.report.model(ModelId::Lenet).unwrap();
        assert!(mm.served > 0, "fleet must keep serving after a failed re-plan");
    }
}
