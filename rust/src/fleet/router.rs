//! Deterministic front-end arrival splitter.
//!
//! The router consumes ONE merged arrival stream (a [`DynSourceMux`] —
//! the same pull-based form the serving engine eats) and deals each
//! arrival to a node with deficit-bounded quota counters matching the
//! plan's per-(node, model) rate shares:
//!
//! * **Quota rule** (Balinski–Young): for model `m` with share vector
//!   `w`, the `k`-th arrival goes to the node with the highest
//!   next-share priority `w[n] / (dealt[n] + 1)` among nodes still
//!   *under quota* (`dealt[n] < k * w[n] / Σw`). The eligible set is
//!   never empty (the dealt counts sum to `k - 1 < k = Σ quotas`), and
//!   the resulting counts provably stay within one arrival of the
//!   ideal fractional split `k·w[n]/Σw` — above by construction, below
//!   by the quota method's staying-within-the-quota theorem. The
//!   property test below pins the bound for random shares and node
//!   counts.
//! * **Determinism**: no randomness — node choice is a pure function
//!   of the counters, and exact priority ties resolve to the lowest
//!   node index. The same mux/seed deals the same arrival to the same
//!   node, byte-for-byte, regardless of thread count.
//! * **No placement, no loss**: a model whose plan share is zero
//!   everywhere is dealt *uniformly* (weight 1 per node) and counted in
//!   [`Router::unplaced_per_model`]; the receiving engine has no route
//!   for it and drops it **counted**, exactly like the single-server
//!   path — fleet conservation (`offered == served + dropped + shed +
//!   lost_to_failure`) holds per model with no silent escape hatch.
//! * **Admission gate** (optional): before dealing, each arrival passes
//!   a per-model largest-remainder gate aimed by
//!   [`Router::update_admission`] from observed demand vs schedulable
//!   capacity. Over-quota arrivals are **shed** (refused, counted under
//!   the original model) or **degraded** (rewritten to a configured
//!   cheaper fallback model and dealt — offered counts then accrue to
//!   the fallback, with a separate per-original-model `degraded`
//!   diagnostic). The gate is a pure function of the arrival sequence
//!   and the admit fractions, so admission decisions are
//!   byte-reproducible; with [`AdmissionMode::Off`] the deal path is
//!   bit-for-bit the ungated one.
//! * **Liveness mask**: [`Router::set_alive`] marks nodes down/up and
//!   rebuilds the dealing weights from the retained plan shares — dead
//!   nodes get weight zero, and a model whose only shares sit on dead
//!   nodes falls back to uniform dealing over the *alive* nodes.
//!
//! Dealt arrivals accumulate in per-node buffers the [`FleetEngine`]
//! drains each lockstep advance; the buffer high-water mark is tracked
//! so the windowed dealing footprint stays observable.
//!
//! [`FleetEngine`]: super::FleetEngine

use crate::error::{Error, Result};
use crate::models::ModelId;
use crate::simclock::{ms_to_us, SimTimeUs};
use crate::telemetry::{EventKind, TraceEvent, Tracer, NO_LET};
use crate::workload::{Arrival, DynSourceMux};

/// What the admission gate does with an over-quota arrival.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AdmissionMode {
    /// No gate: every arrival is dealt (the historical behavior).
    #[default]
    Off,
    /// Refuse over-quota arrivals; counted per model as `shed`.
    Shed,
    /// Rewrite over-quota arrivals to the model's configured cheaper
    /// fallback and deal them; models without a fallback shed instead.
    Degrade,
}

impl AdmissionMode {
    /// Parse a CLI/config spelling: `off` | `shed` | `degrade`.
    pub fn parse(s: &str) -> Result<AdmissionMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" => Ok(AdmissionMode::Off),
            "shed" => Ok(AdmissionMode::Shed),
            "degrade" => Ok(AdmissionMode::Degrade),
            other => Err(Error::parse(format!(
                "unknown admission mode {other:?} (want off|shed|degrade)"
            ))),
        }
    }
}

/// Admission-control policy for the router's front-end gate.
#[derive(Clone, Debug, PartialEq)]
pub struct AdmissionSpec {
    pub mode: AdmissionMode,
    /// Degrade target per original model (`ModelId::index`-indexed).
    /// `None` = no fallback: over-quota arrivals shed even in
    /// `Degrade` mode. A degraded arrival bypasses the fallback's own
    /// gate (documented limitation: one rewrite, no cascades).
    pub fallback: [Option<ModelId>; 5],
    /// Target utilization of schedulable capacity: the gate admits up
    /// to `capacity * headroom` req/s per model, keeping the admitted
    /// load strictly inside what the plan can serve within SLO.
    pub headroom: f64,
}

impl Default for AdmissionSpec {
    fn default() -> Self {
        AdmissionSpec { mode: AdmissionMode::Off, fallback: [None; 5], headroom: 0.9 }
    }
}

impl AdmissionSpec {
    /// The degrade target for `m`, if configured and distinct from `m`.
    fn fallback_for(&self, m: ModelId) -> Option<ModelId> {
        self.fallback[m.index()].filter(|&f| f != m)
    }
}

/// Deterministic arrival splitter over one merged source. See the
/// module docs for the dealing rule.
pub struct Router {
    mux: DynSourceMux,
    nodes: usize,
    /// Dealing weights per (model, node). A model with no planned
    /// share anywhere gets uniform weight 1 per node (and is tracked
    /// as unplaced).
    weights: [Vec<f64>; 5],
    /// Σ weights per model.
    totals: [f64; 5],
    /// Dealt counts per (model, node) since the last retarget.
    dealt: [Vec<u64>; 5],
    /// Σ dealt per model since the last retarget.
    dealt_model: [u64; 5],
    /// Lifetime offered counts per model (survives retargets). Counted
    /// *post-gate*: a degraded arrival is offered under its fallback
    /// model, a shed one under none — so `offered == served + dropped`
    /// holds per dealt model and shed is accounted separately.
    offered: [u64; 5],
    /// Offered (post-gate) counts since the last `take_window_dealt`.
    window: [u64; 5],
    /// Lifetime demand counts: every pulled arrival under its
    /// *original* model, gate or no gate.
    demand: [u64; 5],
    /// Demand counts since the last `take_window_demand` — what the
    /// rate monitor and the admission updater must see (feeding them
    /// post-gate counts would hide the very overload being shed).
    demand_window: [u64; 5],
    /// Lifetime dealt counts for models with no placement.
    unplaced: [u64; 5],
    placed: [bool; 5],
    /// Per-node staging buffers (drained by the fleet engine).
    buffers: Vec<Vec<Arrival>>,
    /// High-water mark of total buffered arrivals.
    peak_buffered: usize,
    /// The active plan's per-(node, model) shares, retained so the
    /// dealing weights can be rebuilt when liveness changes.
    node_rates: Vec<[f64; 5]>,
    /// Liveness mask: dead nodes take no new arrivals.
    alive: Vec<bool>,
    admission: AdmissionSpec,
    /// Admitted fraction per model (1.0 = admit everything), aimed by
    /// `update_admission`.
    admit_frac: [f64; 5],
    /// Arrivals seen / admitted per model since the last re-aim — the
    /// largest-remainder pair: admit while `admitted < ceil(seen *
    /// frac)`, which realizes the fraction exactly (within one arrival)
    /// with a deterministic, evenly interleaved pattern.
    gate_seen: [u64; 5],
    gate_admitted: [u64; 5],
    /// Lifetime shed counts per *original* model.
    shed: [u64; 5],
    /// Shed counts since the last `take_window_shed`.
    shed_window: [u64; 5],
    /// Lifetime degraded counts per *original* model (diagnostic; the
    /// offered/served accounting lives under the fallback model).
    degraded: [u64; 5],
    /// Telemetry recorder (router scope: gate verdicts and deals).
    /// Span ids are the mux-assigned `Arrival::id` — a deterministic
    /// function of (stream, seq) — so sampling is reproducible.
    tracer: Tracer,
}

impl Router {
    /// A router dealing by the plan's per-(node, model) rate shares
    /// (`node_rates[node][model.index()]`, req/s — only ratios matter).
    pub fn new(mux: DynSourceMux, node_rates: &[[f64; 5]]) -> Self {
        let nodes = node_rates.len();
        assert!(nodes >= 1, "router needs at least one node");
        let mut r = Router {
            mux,
            nodes,
            weights: Default::default(),
            totals: [0.0; 5],
            dealt: Default::default(),
            dealt_model: [0; 5],
            offered: [0; 5],
            window: [0; 5],
            demand: [0; 5],
            demand_window: [0; 5],
            unplaced: [0; 5],
            placed: [false; 5],
            buffers: (0..nodes).map(|_| Vec::new()).collect(),
            peak_buffered: 0,
            node_rates: Vec::new(),
            alive: vec![true; nodes],
            admission: AdmissionSpec::default(),
            admit_frac: [1.0; 5],
            gate_seen: [0; 5],
            gate_admitted: [0; 5],
            shed: [0; 5],
            shed_window: [0; 5],
            degraded: [0; 5],
            tracer: Tracer::off(),
        };
        r.retarget(node_rates);
        r
    }

    /// Install a telemetry recorder (default: disabled). Gate verdicts
    /// (admit/shed/degrade) and deals are recorded at router scope;
    /// `Deal` events carry the *target* node in their node field.
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    /// The router's telemetry recorder (ledger access).
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// Mutable recorder access — the fleet drains the router ring
    /// through this at merge points.
    pub fn tracer_mut(&mut self) -> &mut Tracer {
        &mut self.tracer
    }

    /// Install an admission policy (default: [`AdmissionMode::Off`]).
    /// The gate starts wide open — `update_admission` aims it from
    /// observed demand at window boundaries.
    pub fn set_admission(&mut self, spec: AdmissionSpec) {
        self.admission = spec;
    }

    /// Re-target the split to a new plan's shares (fleet rebalance).
    /// The deficit counters restart — the new shares govern the split
    /// from here on, exactly like the serving engine rebuilds its route
    /// counters at a schedule swap. Buffered (already-dealt) arrivals
    /// stay where they were dealt.
    pub fn retarget(&mut self, node_rates: &[[f64; 5]]) {
        assert_eq!(node_rates.len(), self.nodes, "retarget must keep the node count");
        self.node_rates.clear();
        self.node_rates.extend_from_slice(node_rates);
        self.rebuild_weights();
    }

    /// Mark a node down (`false`) or back up (`true`) and rebuild the
    /// dealing weights from the retained plan shares. A dead node takes
    /// no new arrivals; its already-dealt buffer stays put (the fleet
    /// engine accounts it as lost). The deficit counters restart, like
    /// a retarget.
    pub fn set_alive(&mut self, node: usize, alive: bool) {
        assert!(node < self.nodes, "node {node} out of range");
        self.alive[node] = alive;
        self.rebuild_weights();
    }

    /// Dealing weights from the retained shares masked by liveness.
    fn rebuild_weights(&mut self) {
        let any_alive = self.alive.iter().any(|&a| a);
        for m in ModelId::ALL {
            let mi = m.index();
            let w: Vec<f64> = (0..self.nodes)
                .map(|ni| {
                    if self.alive[ni] {
                        self.node_rates[ni][mi].max(0.0)
                    } else {
                        0.0
                    }
                })
                .collect();
            let total: f64 = w.iter().sum();
            self.placed[mi] = total > 0.0;
            if self.placed[mi] {
                self.weights[mi] = w;
                self.totals[mi] = total;
            } else {
                // Unplaced — or every share sits on a dead node: deal
                // uniformly over the alive nodes so the engines can
                // drop it counted — never swallowed at the front end.
                // With no node alive at all, uniform over everything
                // (the dealt arrivals land in dead buffers and the
                // fleet engine accounts them as lost).
                self.weights[mi] = (0..self.nodes)
                    .map(|ni| if !any_alive || self.alive[ni] { 1.0 } else { 0.0 })
                    .collect();
                self.totals[mi] = self.weights[mi].iter().sum();
            }
            self.dealt[mi].clear();
            self.dealt[mi].resize(self.nodes, 0);
            self.dealt_model[mi] = 0;
        }
    }

    /// Re-aim the admission gate: per model, compare the observed
    /// demand rate (req/s — typically the fleet's EWMA estimate) with
    /// the active plan's schedulable capacity and set the admitted
    /// fraction to `min(1, capacity * headroom / observed)`. Resets the
    /// gate's seen/admitted counters so the new fraction applies from
    /// the next arrival. No-op when admission is off.
    pub fn update_admission(&mut self, observed: &[f64; 5], capacity: &[f64; 5]) {
        if self.admission.mode == AdmissionMode::Off {
            return;
        }
        for mi in 0..5 {
            let allowed = capacity[mi] * self.admission.headroom;
            self.admit_frac[mi] = if observed[mi] <= allowed || observed[mi] <= 0.0 {
                1.0
            } else {
                (allowed / observed[mi]).clamp(0.0, 1.0)
            };
            self.gate_seen[mi] = 0;
            self.gate_admitted[mi] = 0;
        }
    }

    /// Balinski–Young quota pick for one arrival of model `mi`: highest
    /// next-share priority among under-quota nodes, ties to the lowest
    /// index.
    fn pick(&self, mi: usize) -> usize {
        let w = &self.weights[mi];
        let total = self.totals[mi];
        let k = (self.dealt_model[mi] + 1) as f64;
        let mut best: Option<usize> = None;
        let mut best_priority = f64::NEG_INFINITY;
        for ni in 0..self.nodes {
            if w[ni] <= 0.0 {
                continue;
            }
            let quota = k * w[ni] / total;
            if (self.dealt[mi][ni] as f64) >= quota {
                continue; // at upper quota — ineligible this round
            }
            let priority = w[ni] / (self.dealt[mi][ni] + 1) as f64;
            if priority > best_priority {
                best_priority = priority;
                best = Some(ni);
            }
        }
        // The eligible set cannot be empty: Σ dealt = k-1 < k = Σ quota,
        // so some node is under quota. The fallback only guards float
        // edge cases at exact quota boundaries.
        best.unwrap_or_else(|| {
            (0..self.nodes)
                .filter(|&ni| w[ni] > 0.0)
                .min_by(|&a, &b| {
                    let ka = self.dealt[mi][a] as f64 / w[a];
                    let kb = self.dealt[mi][b] as f64 / w[b];
                    ka.total_cmp(&kb)
                })
                .expect("model has at least one positive dealing weight")
        })
    }

    /// Deal every arrival with µs time <= `t_us` into the per-node
    /// buffers (the boundary convention matches the serving engine's
    /// `run_until`, so dealing and serving agree on which side of a
    /// window cut an arrival lands).
    pub fn deal_until(&mut self, t_us: SimTimeUs) {
        while self.mux.peek_time_ms().is_some_and(|t| ms_to_us(t) <= t_us) {
            let mut a = self.mux.pull().expect("peeked arrival vanished");
            let at = ms_to_us(a.time_ms);
            let orig = a.model.index();
            let orig_model = a.model;
            self.demand[orig] += 1;
            self.demand_window[orig] += 1;
            if self.admission.mode != AdmissionMode::Off {
                // Largest-remainder gate: admit while the admitted
                // count is under ceil(seen * frac) — realizes the
                // fraction exactly with an evenly interleaved,
                // deterministic pattern.
                self.gate_seen[orig] += 1;
                let quota =
                    (self.gate_seen[orig] as f64 * self.admit_frac[orig]).ceil() as u64;
                if self.gate_admitted[orig] < quota {
                    self.gate_admitted[orig] += 1;
                    self.tracer.span(at, EventKind::Admit, NO_LET, orig_model, 0, a.id);
                } else {
                    match self.admission.fallback_for(a.model) {
                        Some(fb) if self.admission.mode == AdmissionMode::Degrade => {
                            a.model = fb;
                            self.degraded[orig] += 1;
                            // The follow-up Deal (same id) carries the
                            // fallback model the request continues as.
                            self.tracer.span(at, EventKind::Degrade, NO_LET, orig_model, 0, a.id);
                        }
                        _ => {
                            self.shed[orig] += 1;
                            self.shed_window[orig] += 1;
                            self.tracer.span(at, EventKind::Shed, NO_LET, orig_model, 0, a.id);
                            continue;
                        }
                    }
                }
            }
            let mi = a.model.index();
            let ni = self.pick(mi);
            self.dealt[mi][ni] += 1;
            self.dealt_model[mi] += 1;
            self.offered[mi] += 1;
            self.window[mi] += 1;
            if !self.placed[mi] {
                self.unplaced[mi] += 1;
            }
            self.tracer.emit(TraceEvent {
                t_us: at,
                kind: EventKind::Deal,
                node: ni as u32,
                let_idx: NO_LET,
                model: mi as u8,
                epoch: 0,
                id: a.id,
                n: 1,
            });
            self.buffers[ni].push(a);
        }
        let buffered: usize = self.buffers.iter().map(Vec::len).sum();
        self.peak_buffered = self.peak_buffered.max(buffered);
    }

    /// Deal the rest of the source unconditionally.
    pub fn deal_all(&mut self) {
        self.deal_until(SimTimeUs::MAX);
    }

    /// Take node `n`'s staged arrivals (time-ordered — the mux pulls in
    /// nondecreasing time order and dealing preserves it per node).
    pub fn take_buffer(&mut self, node: usize) -> Vec<Arrival> {
        self.take_buffer_with(node, Vec::new())
    }

    /// `take_buffer`, leaving `spare` (cleared) behind as the node's
    /// next staging buffer. The fleet engine hands back each node's
    /// previously consumed chunk here, so steady-state dealing pushes
    /// into retained-capacity buffers instead of growing fresh ones
    /// every lockstep window.
    pub fn take_buffer_with(&mut self, node: usize, mut spare: Vec<Arrival>) -> Vec<Arrival> {
        spare.clear();
        std::mem::replace(&mut self.buffers[node], spare)
    }

    /// Offered (post-gate, dealt) counts per model since the last call.
    pub fn take_window_dealt(&mut self) -> [u64; 5] {
        std::mem::replace(&mut self.window, [0; 5])
    }

    /// Demand counts per *original* model since the last call — every
    /// arrival pulled from the source, admitted or not (windowed rate
    /// observation for rebalancing and admission aiming).
    pub fn take_window_demand(&mut self) -> [u64; 5] {
        std::mem::replace(&mut self.demand_window, [0; 5])
    }

    /// Shed counts per original model since the last call.
    pub fn take_window_shed(&mut self) -> [u64; 5] {
        std::mem::replace(&mut self.shed_window, [0; 5])
    }

    /// Lifetime demand counts per original model (pre-gate).
    pub fn demand_per_model(&self) -> [u64; 5] {
        self.demand
    }

    /// Lifetime shed counts per original model.
    pub fn shed_per_model(&self) -> [u64; 5] {
        self.shed
    }

    /// Lifetime degraded counts per original model (served/dropped
    /// accounting for these lives under the fallback model).
    pub fn degraded_per_model(&self) -> [u64; 5] {
        self.degraded
    }

    /// The current per-model admitted fractions (1.0 = gate open).
    pub fn admit_fractions(&self) -> [f64; 5] {
        self.admit_frac
    }

    /// Per-node liveness mask.
    pub fn alive(&self) -> &[bool] {
        &self.alive
    }

    /// Lifetime offered (dealt) counts per model.
    pub fn offered_per_model(&self) -> [u64; 5] {
        self.offered
    }

    /// Lifetime dealt counts for models that had no placement at deal
    /// time (the engines drop these, counted).
    pub fn unplaced_per_model(&self) -> [u64; 5] {
        self.unplaced
    }

    /// Dealt counts per node for one model since the last retarget.
    pub fn dealt_counts(&self, m: ModelId) -> &[u64] {
        &self.dealt[m.index()]
    }

    /// Time of the next undealt arrival, if any.
    pub fn peek_time_ms(&self) -> Option<f64> {
        self.mux.peek_time_ms()
    }

    /// Time of the last dealt arrival (0.0 before the first) — the
    /// fleet's drain horizon anchor, same contract as the mux's.
    pub fn last_arrival_ms(&self) -> f64 {
        self.mux.last_arrival_ms()
    }

    /// True when the source is dry.
    pub fn is_exhausted(&self) -> bool {
        self.mux.is_exhausted()
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// High-water mark of simultaneously buffered (dealt, not yet
    /// drained) arrivals.
    pub fn peak_buffered(&self) -> usize {
        self.peak_buffered
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_mini as pt;
    use crate::workload::{dyn_sources, poisson_streams, MaterializedSource, SourceMux};

    fn lenet_trace(k: usize) -> DynSourceMux {
        let arrivals: Vec<Arrival> = (0..k)
            .map(|i| Arrival { time_ms: i as f64, model: ModelId::Lenet, id: i as u64 })
            .collect();
        DynSourceMux::of_trace(arrivals)
    }

    fn node_rates_for(weights: &[f64]) -> Vec<[f64; 5]> {
        weights
            .iter()
            .map(|&w| {
                let mut r = [0.0; 5];
                r[ModelId::Lenet.index()] = w;
                r
            })
            .collect()
    }

    /// Satellite property: for random plan shares and node counts, the
    /// dealt counts per node stay within 1 of the deficit-ideal share
    /// `k * w[n] / Σw`, and the per-model totals equal the source's.
    #[test]
    fn dealt_counts_stay_within_one_of_ideal_share() {
        #[derive(Clone, Debug)]
        struct Case {
            weights: Vec<f64>,
            k: usize,
        }
        pt::run(
            pt::Config { cases: 128, ..Default::default() },
            |rng| {
                let n = 1 + rng.below(6);
                let weights: Vec<f64> =
                    (0..n).map(|_| 0.05 + rng.f64() * 4.0).collect();
                Case { weights, k: 1 + rng.below(400) }
            },
            |c| {
                let mut out = Vec::new();
                if c.k > 1 {
                    out.push(Case { k: c.k / 2, ..c.clone() });
                }
                if c.weights.len() > 1 {
                    for i in 0..c.weights.len() {
                        let mut w = c.weights.clone();
                        w.remove(i);
                        out.push(Case { weights: w, k: c.k });
                    }
                }
                out
            },
            |c| {
                let mut router = Router::new(lenet_trace(c.k), &node_rates_for(&c.weights));
                router.deal_all();
                let total_w: f64 = c.weights.iter().sum();
                let counts = router.dealt_counts(ModelId::Lenet);
                let dealt_total: u64 = counts.iter().sum();
                if dealt_total != c.k as u64 {
                    return Err(format!("dealt {dealt_total} of {} arrivals", c.k));
                }
                for (ni, &w) in c.weights.iter().enumerate() {
                    let ideal = c.k as f64 * w / total_w;
                    let got = counts[ni] as f64;
                    if (got - ideal).abs() > 1.0 + 1e-6 {
                        return Err(format!(
                            "node {ni}: dealt {got} vs ideal {ideal:.3} (k={}, w={:?})",
                            c.k, c.weights
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn zero_share_nodes_receive_nothing() {
        let mut router = Router::new(lenet_trace(100), &node_rates_for(&[2.0, 0.0, 1.0]));
        router.deal_all();
        let counts = router.dealt_counts(ModelId::Lenet);
        assert_eq!(counts[1], 0, "zero-share node must stay empty");
        assert_eq!(counts.iter().sum::<u64>(), 100);
        assert!(router.take_buffer(1).is_empty());
        // 2:1 split within one arrival of ideal.
        assert!((counts[0] as f64 - 100.0 * 2.0 / 3.0).abs() <= 1.0 + 1e-9);
    }

    #[test]
    fn unplaced_models_deal_uniformly_and_are_counted() {
        // Shares only for LeNet; VGG arrivals have no placement.
        let arrivals: Vec<Arrival> = (0..60)
            .map(|i| Arrival {
                time_ms: i as f64,
                model: if i % 2 == 0 { ModelId::Lenet } else { ModelId::Vgg },
                id: i as u64,
            })
            .collect();
        let mut router =
            Router::new(DynSourceMux::of_trace(arrivals), &node_rates_for(&[1.0, 1.0]));
        router.deal_all();
        let unplaced = router.unplaced_per_model();
        assert_eq!(unplaced[ModelId::Vgg.index()], 30);
        assert_eq!(unplaced[ModelId::Lenet.index()], 0);
        // Uniform dealing: 15 VGG per node.
        let vgg = router.dealt_counts(ModelId::Vgg);
        assert_eq!(vgg, &[15, 15]);
        let offered = router.offered_per_model();
        assert_eq!(offered[ModelId::Lenet.index()], 30);
        assert_eq!(offered[ModelId::Vgg.index()], 30);
    }

    #[test]
    fn dealing_is_byte_reproducible_and_time_ordered() {
        let pairs = [(ModelId::Lenet, 120.0), (ModelId::Vgg, 45.0)];
        let shares = [[80.0, 0.0, 0.0, 0.0, 30.0], [40.0, 0.0, 0.0, 0.0, 15.0]];
        let deal = || {
            let mux = SourceMux::new(dyn_sources(
                poisson_streams(&pairs, 4.0, 77).unwrap(),
            ));
            let mut router = Router::new(mux, &shares);
            router.deal_all();
            (router.take_buffer(0), router.take_buffer(1))
        };
        let (a0, a1) = deal();
        let (b0, b1) = deal();
        assert_eq!(a0, b0, "same seed must deal identically");
        assert_eq!(a1, b1);
        for chunk in [&a0, &a1] {
            assert!(!chunk.is_empty());
            assert!(
                chunk.windows(2).all(|w| w[0].time_ms <= w[1].time_ms),
                "per-node chunks must stay time-ordered"
            );
        }
    }

    #[test]
    fn retarget_restarts_counters_and_keeps_buffers() {
        let mut router =
            Router::new(lenet_trace(40), &node_rates_for(&[1.0, 1.0]));
        router.deal_until(ms_to_us(19.0)); // first 20 arrivals
        assert_eq!(router.dealt_counts(ModelId::Lenet).iter().sum::<u64>(), 20);
        // Retarget everything onto node 1.
        router.retarget(&node_rates_for(&[0.0, 1.0]));
        assert_eq!(router.dealt_counts(ModelId::Lenet), &[0, 0]);
        router.deal_all();
        assert_eq!(router.dealt_counts(ModelId::Lenet), &[0, 20]);
        // Pre-retarget deals stayed in node 0's buffer.
        assert_eq!(router.take_buffer(0).len(), 10);
        assert_eq!(router.take_buffer(1).len(), 30);
        assert_eq!(router.offered_per_model()[ModelId::Lenet.index()], 40);
    }

    #[test]
    fn single_node_router_passes_everything_through_in_order() {
        let mux = SourceMux::new(dyn_sources(
            poisson_streams(&[(ModelId::Lenet, 200.0)], 2.0, 5).unwrap(),
        ));
        let reference: Vec<Arrival> = mux.clone().materialize();
        let mut router = Router::new(mux, &node_rates_for(&[1.0]));
        router.deal_all();
        assert_eq!(router.take_buffer(0), reference);
        assert!(router.is_exhausted());
        assert_eq!(router.last_arrival_ms(), reference.last().unwrap().time_ms);
    }

    #[test]
    fn shed_gate_realizes_the_admit_fraction_exactly() {
        let gated = |frac_setup: &dyn Fn(&mut Router)| {
            let mut router =
                Router::new(lenet_trace(100), &node_rates_for(&[1.0, 1.0]));
            router.set_admission(AdmissionSpec {
                mode: AdmissionMode::Shed,
                headroom: 1.0,
                ..Default::default()
            });
            frac_setup(&mut router);
            router.deal_all();
            router
        };
        // Observed demand at 2x capacity → admit exactly half,
        // interleaved (largest-remainder), rest shed under the model.
        let mut caps = [0.0; 5];
        caps[ModelId::Lenet.index()] = 100.0;
        let mut demand = [0.0; 5];
        demand[ModelId::Lenet.index()] = 200.0;
        let r = gated(&|r| r.update_admission(&demand, &caps));
        let li = ModelId::Lenet.index();
        assert_eq!(r.shed_per_model()[li], 50);
        assert_eq!(r.offered_per_model()[li], 50);
        assert_eq!(r.dealt_counts(ModelId::Lenet).iter().sum::<u64>(), 50);
        assert!((r.admit_fractions()[li] - 0.5).abs() < 1e-12);
        // Replays byte-identically.
        let r2 = gated(&|r| r.update_admission(&demand, &caps));
        assert_eq!(r.shed_per_model(), r2.shed_per_model());
        // Demand under capacity*headroom → gate wide open, nothing shed.
        let open = gated(&|r| r.update_admission(&caps, &demand));
        assert_eq!(open.shed_per_model(), [0; 5]);
        assert_eq!(open.offered_per_model()[li], 100);
        // Default (un-aimed) gate also admits everything.
        let idle = gated(&|_| {});
        assert_eq!(idle.shed_per_model(), [0; 5]);
    }

    #[test]
    fn degrade_rewrites_to_fallback_and_keeps_conservation_per_model() {
        // VGG over capacity with LeNet as its cheaper fallback: the
        // over-quota half is dealt *as LeNet* and diagnosed as
        // degraded[VGG]; nothing is shed.
        let arrivals: Vec<Arrival> = (0..100)
            .map(|i| Arrival { time_ms: i as f64, model: ModelId::Vgg, id: i as u64 })
            .collect();
        let shares = [[50.0, 50.0, 0.0, 0.0, 0.0], [50.0, 50.0, 0.0, 0.0, 0.0]];
        let mut router = Router::new(DynSourceMux::of_trace(arrivals), &shares);
        let mut fallback = [None; 5];
        fallback[ModelId::Vgg.index()] = Some(ModelId::Lenet);
        router.set_admission(AdmissionSpec {
            mode: AdmissionMode::Degrade,
            fallback,
            headroom: 1.0,
        });
        let (vi, li) = (ModelId::Vgg.index(), ModelId::Lenet.index());
        let mut demand = [0.0; 5];
        demand[vi] = 200.0;
        let mut caps = [0.0; 5];
        caps[vi] = 100.0;
        caps[li] = 1000.0;
        router.update_admission(&demand, &caps);
        router.deal_all();
        assert_eq!(router.shed_per_model(), [0; 5], "degrade must not shed");
        assert_eq!(router.degraded_per_model()[vi], 50);
        assert_eq!(router.offered_per_model()[vi], 50);
        assert_eq!(router.offered_per_model()[li], 50, "fallback takes the rest");
        let demand_w = router.take_window_demand();
        assert_eq!(demand_w[vi], 100, "demand window counts the original model");
        assert_eq!(demand_w[li], 0);
        // No fallback configured → Degrade mode sheds like Shed mode.
        let mut router2 =
            Router::new(lenet_trace(100), &node_rates_for(&[1.0, 1.0]));
        router2.set_admission(AdmissionSpec {
            mode: AdmissionMode::Degrade,
            ..Default::default()
        });
        let mut d2 = [0.0; 5];
        d2[li] = 200.0;
        let mut c2 = [0.0; 5];
        c2[li] = 100.0;
        router2.update_admission(&d2, &c2);
        router2.deal_all();
        assert_eq!(router2.shed_per_model()[li], 50);
    }

    #[test]
    fn admission_off_leaves_the_deal_path_untouched() {
        let deal = |gate: bool| {
            let mut router =
                Router::new(lenet_trace(50), &node_rates_for(&[2.0, 1.0]));
            if gate {
                // Off mode: update_admission is a no-op even with
                // demand far over capacity.
                router.update_admission(&[1e6; 5], &[1.0; 5]);
            }
            router.deal_all();
            (
                router.take_buffer(0),
                router.take_buffer(1),
                router.shed_per_model(),
                router.take_window_dealt(),
                router.take_window_demand(),
            )
        };
        let a = deal(false);
        let b = deal(true);
        assert_eq!(a, b, "Off mode must be bit-for-bit the ungated path");
        assert_eq!(a.2, [0; 5]);
        assert_eq!(
            a.3, a.4,
            "with no gate the dealt and demand windows are the same counts"
        );
    }

    #[test]
    fn set_alive_reroutes_to_survivors_and_restores_on_recovery() {
        let mut router = Router::new(lenet_trace(90), &node_rates_for(&[1.0, 1.0, 1.0]));
        router.deal_until(ms_to_us(29.0)); // 30 dealt across all three
        router.set_alive(0, false);
        router.deal_until(ms_to_us(59.0)); // 30 more, node 0 dead
        let after_down = router.dealt_counts(ModelId::Lenet).to_vec();
        assert_eq!(after_down[0], 0, "dead node must take nothing");
        assert_eq!(after_down.iter().sum::<u64>(), 30);
        router.set_alive(0, true);
        router.deal_all(); // last 30, full fleet again
        assert!(router.dealt_counts(ModelId::Lenet)[0] > 0, "recovered node serves");
        assert_eq!(router.offered_per_model()[ModelId::Lenet.index()], 90);
        // A model placed ONLY on the dead node falls back to uniform
        // dealing over the alive nodes (dropped counted downstream).
        let mut solo = Router::new(lenet_trace(20), &node_rates_for(&[1.0, 0.0]));
        solo.set_alive(0, false);
        solo.deal_all();
        assert_eq!(solo.dealt_counts(ModelId::Lenet), &[0, 20]);
        assert_eq!(solo.alive(), &[false, true]);
    }

    #[test]
    fn materialized_source_is_usable_directly() {
        // The router's mux contract is the engine's: any DynSourceMux,
        // including a single materialized stream.
        let arrivals =
            vec![Arrival { time_ms: 1.0, model: ModelId::Resnet, id: 0 }];
        let mux = SourceMux::new(dyn_sources(vec![MaterializedSource::new(arrivals)]));
        let mut router = Router::new(mux, &[[0.0, 0.0, 5.0, 0.0, 0.0]]);
        router.deal_all();
        assert_eq!(router.dealt_counts(ModelId::Resnet), &[1]);
    }
}
