//! Deterministic front-end arrival splitter.
//!
//! The router consumes ONE merged arrival stream (a [`DynSourceMux`] —
//! the same pull-based form the serving engine eats) and deals each
//! arrival to a node with deficit-bounded quota counters matching the
//! plan's per-(node, model) rate shares:
//!
//! * **Quota rule** (Balinski–Young): for model `m` with share vector
//!   `w`, the `k`-th arrival goes to the node with the highest
//!   next-share priority `w[n] / (dealt[n] + 1)` among nodes still
//!   *under quota* (`dealt[n] < k * w[n] / Σw`). The eligible set is
//!   never empty (the dealt counts sum to `k - 1 < k = Σ quotas`), and
//!   the resulting counts provably stay within one arrival of the
//!   ideal fractional split `k·w[n]/Σw` — above by construction, below
//!   by the quota method's staying-within-the-quota theorem. The
//!   property test below pins the bound for random shares and node
//!   counts.
//! * **Determinism**: no randomness — node choice is a pure function
//!   of the counters, and exact priority ties resolve to the lowest
//!   node index. The same mux/seed deals the same arrival to the same
//!   node, byte-for-byte, regardless of thread count.
//! * **No placement, no loss**: a model whose plan share is zero
//!   everywhere is dealt *uniformly* (weight 1 per node) and counted in
//!   [`Router::unplaced_per_model`]; the receiving engine has no route
//!   for it and drops it **counted**, exactly like the single-server
//!   path — fleet conservation (`offered == served + dropped`) holds
//!   per model with no silent escape hatch.
//!
//! Dealt arrivals accumulate in per-node buffers the [`FleetEngine`]
//! drains each lockstep advance; the buffer high-water mark is tracked
//! so the windowed dealing footprint stays observable.
//!
//! [`FleetEngine`]: super::FleetEngine

use crate::models::ModelId;
use crate::simclock::{ms_to_us, SimTimeUs};
use crate::workload::{Arrival, DynSourceMux};

/// Deterministic arrival splitter over one merged source. See the
/// module docs for the dealing rule.
pub struct Router {
    mux: DynSourceMux,
    nodes: usize,
    /// Dealing weights per (model, node). A model with no planned
    /// share anywhere gets uniform weight 1 per node (and is tracked
    /// as unplaced).
    weights: [Vec<f64>; 5],
    /// Σ weights per model.
    totals: [f64; 5],
    /// Dealt counts per (model, node) since the last retarget.
    dealt: [Vec<u64>; 5],
    /// Σ dealt per model since the last retarget.
    dealt_model: [u64; 5],
    /// Lifetime offered counts per model (survives retargets).
    offered: [u64; 5],
    /// Offered counts since the last `take_window_dealt`.
    window: [u64; 5],
    /// Lifetime dealt counts for models with no placement.
    unplaced: [u64; 5],
    placed: [bool; 5],
    /// Per-node staging buffers (drained by the fleet engine).
    buffers: Vec<Vec<Arrival>>,
    /// High-water mark of total buffered arrivals.
    peak_buffered: usize,
}

impl Router {
    /// A router dealing by the plan's per-(node, model) rate shares
    /// (`node_rates[node][model.index()]`, req/s — only ratios matter).
    pub fn new(mux: DynSourceMux, node_rates: &[[f64; 5]]) -> Self {
        let nodes = node_rates.len();
        assert!(nodes >= 1, "router needs at least one node");
        let mut r = Router {
            mux,
            nodes,
            weights: Default::default(),
            totals: [0.0; 5],
            dealt: Default::default(),
            dealt_model: [0; 5],
            offered: [0; 5],
            window: [0; 5],
            unplaced: [0; 5],
            placed: [false; 5],
            buffers: (0..nodes).map(|_| Vec::new()).collect(),
            peak_buffered: 0,
        };
        r.retarget(node_rates);
        r
    }

    /// Re-target the split to a new plan's shares (fleet rebalance).
    /// The deficit counters restart — the new shares govern the split
    /// from here on, exactly like the serving engine rebuilds its route
    /// counters at a schedule swap. Buffered (already-dealt) arrivals
    /// stay where they were dealt.
    pub fn retarget(&mut self, node_rates: &[[f64; 5]]) {
        assert_eq!(node_rates.len(), self.nodes, "retarget must keep the node count");
        for m in ModelId::ALL {
            let mi = m.index();
            let w: Vec<f64> = node_rates.iter().map(|r| r[mi].max(0.0)).collect();
            let total: f64 = w.iter().sum();
            self.placed[mi] = total > 0.0;
            if self.placed[mi] {
                self.weights[mi] = w;
                self.totals[mi] = total;
            } else {
                // Unplaced: deal uniformly so the engines can drop it
                // counted — never swallowed at the front end.
                self.weights[mi] = vec![1.0; self.nodes];
                self.totals[mi] = self.nodes as f64;
            }
            self.dealt[mi].clear();
            self.dealt[mi].resize(self.nodes, 0);
            self.dealt_model[mi] = 0;
        }
    }

    /// Balinski–Young quota pick for one arrival of model `mi`: highest
    /// next-share priority among under-quota nodes, ties to the lowest
    /// index.
    fn pick(&self, mi: usize) -> usize {
        let w = &self.weights[mi];
        let total = self.totals[mi];
        let k = (self.dealt_model[mi] + 1) as f64;
        let mut best: Option<usize> = None;
        let mut best_priority = f64::NEG_INFINITY;
        for ni in 0..self.nodes {
            if w[ni] <= 0.0 {
                continue;
            }
            let quota = k * w[ni] / total;
            if (self.dealt[mi][ni] as f64) >= quota {
                continue; // at upper quota — ineligible this round
            }
            let priority = w[ni] / (self.dealt[mi][ni] + 1) as f64;
            if priority > best_priority {
                best_priority = priority;
                best = Some(ni);
            }
        }
        // The eligible set cannot be empty: Σ dealt = k-1 < k = Σ quota,
        // so some node is under quota. The fallback only guards float
        // edge cases at exact quota boundaries.
        best.unwrap_or_else(|| {
            (0..self.nodes)
                .filter(|&ni| w[ni] > 0.0)
                .min_by(|&a, &b| {
                    let ka = self.dealt[mi][a] as f64 / w[a];
                    let kb = self.dealt[mi][b] as f64 / w[b];
                    ka.total_cmp(&kb)
                })
                .expect("model has at least one positive dealing weight")
        })
    }

    /// Deal every arrival with µs time <= `t_us` into the per-node
    /// buffers (the boundary convention matches the serving engine's
    /// `run_until`, so dealing and serving agree on which side of a
    /// window cut an arrival lands).
    pub fn deal_until(&mut self, t_us: SimTimeUs) {
        while self.mux.peek_time_ms().is_some_and(|t| ms_to_us(t) <= t_us) {
            let a = self.mux.pull().expect("peeked arrival vanished");
            let mi = a.model.index();
            let ni = self.pick(mi);
            self.dealt[mi][ni] += 1;
            self.dealt_model[mi] += 1;
            self.offered[mi] += 1;
            self.window[mi] += 1;
            if !self.placed[mi] {
                self.unplaced[mi] += 1;
            }
            self.buffers[ni].push(a);
        }
        let buffered: usize = self.buffers.iter().map(Vec::len).sum();
        self.peak_buffered = self.peak_buffered.max(buffered);
    }

    /// Deal the rest of the source unconditionally.
    pub fn deal_all(&mut self) {
        self.deal_until(SimTimeUs::MAX);
    }

    /// Take node `n`'s staged arrivals (time-ordered — the mux pulls in
    /// nondecreasing time order and dealing preserves it per node).
    pub fn take_buffer(&mut self, node: usize) -> Vec<Arrival> {
        self.take_buffer_with(node, Vec::new())
    }

    /// `take_buffer`, leaving `spare` (cleared) behind as the node's
    /// next staging buffer. The fleet engine hands back each node's
    /// previously consumed chunk here, so steady-state dealing pushes
    /// into retained-capacity buffers instead of growing fresh ones
    /// every lockstep window.
    pub fn take_buffer_with(&mut self, node: usize, mut spare: Vec<Arrival>) -> Vec<Arrival> {
        spare.clear();
        std::mem::replace(&mut self.buffers[node], spare)
    }

    /// Offered counts per model since the last call (windowed rate
    /// observation for rebalancing).
    pub fn take_window_dealt(&mut self) -> [u64; 5] {
        std::mem::replace(&mut self.window, [0; 5])
    }

    /// Lifetime offered (dealt) counts per model.
    pub fn offered_per_model(&self) -> [u64; 5] {
        self.offered
    }

    /// Lifetime dealt counts for models that had no placement at deal
    /// time (the engines drop these, counted).
    pub fn unplaced_per_model(&self) -> [u64; 5] {
        self.unplaced
    }

    /// Dealt counts per node for one model since the last retarget.
    pub fn dealt_counts(&self, m: ModelId) -> &[u64] {
        &self.dealt[m.index()]
    }

    /// Time of the next undealt arrival, if any.
    pub fn peek_time_ms(&self) -> Option<f64> {
        self.mux.peek_time_ms()
    }

    /// Time of the last dealt arrival (0.0 before the first) — the
    /// fleet's drain horizon anchor, same contract as the mux's.
    pub fn last_arrival_ms(&self) -> f64 {
        self.mux.last_arrival_ms()
    }

    /// True when the source is dry.
    pub fn is_exhausted(&self) -> bool {
        self.mux.is_exhausted()
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// High-water mark of simultaneously buffered (dealt, not yet
    /// drained) arrivals.
    pub fn peak_buffered(&self) -> usize {
        self.peak_buffered
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_mini as pt;
    use crate::workload::{dyn_sources, poisson_streams, MaterializedSource, SourceMux};

    fn lenet_trace(k: usize) -> DynSourceMux {
        let arrivals: Vec<Arrival> = (0..k)
            .map(|i| Arrival { time_ms: i as f64, model: ModelId::Lenet, id: i as u64 })
            .collect();
        DynSourceMux::of_trace(arrivals)
    }

    fn node_rates_for(weights: &[f64]) -> Vec<[f64; 5]> {
        weights
            .iter()
            .map(|&w| {
                let mut r = [0.0; 5];
                r[ModelId::Lenet.index()] = w;
                r
            })
            .collect()
    }

    /// Satellite property: for random plan shares and node counts, the
    /// dealt counts per node stay within 1 of the deficit-ideal share
    /// `k * w[n] / Σw`, and the per-model totals equal the source's.
    #[test]
    fn dealt_counts_stay_within_one_of_ideal_share() {
        #[derive(Clone, Debug)]
        struct Case {
            weights: Vec<f64>,
            k: usize,
        }
        pt::run(
            pt::Config { cases: 128, ..Default::default() },
            |rng| {
                let n = 1 + rng.below(6);
                let weights: Vec<f64> =
                    (0..n).map(|_| 0.05 + rng.f64() * 4.0).collect();
                Case { weights, k: 1 + rng.below(400) }
            },
            |c| {
                let mut out = Vec::new();
                if c.k > 1 {
                    out.push(Case { k: c.k / 2, ..c.clone() });
                }
                if c.weights.len() > 1 {
                    for i in 0..c.weights.len() {
                        let mut w = c.weights.clone();
                        w.remove(i);
                        out.push(Case { weights: w, k: c.k });
                    }
                }
                out
            },
            |c| {
                let mut router = Router::new(lenet_trace(c.k), &node_rates_for(&c.weights));
                router.deal_all();
                let total_w: f64 = c.weights.iter().sum();
                let counts = router.dealt_counts(ModelId::Lenet);
                let dealt_total: u64 = counts.iter().sum();
                if dealt_total != c.k as u64 {
                    return Err(format!("dealt {dealt_total} of {} arrivals", c.k));
                }
                for (ni, &w) in c.weights.iter().enumerate() {
                    let ideal = c.k as f64 * w / total_w;
                    let got = counts[ni] as f64;
                    if (got - ideal).abs() > 1.0 + 1e-6 {
                        return Err(format!(
                            "node {ni}: dealt {got} vs ideal {ideal:.3} (k={}, w={:?})",
                            c.k, c.weights
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn zero_share_nodes_receive_nothing() {
        let mut router = Router::new(lenet_trace(100), &node_rates_for(&[2.0, 0.0, 1.0]));
        router.deal_all();
        let counts = router.dealt_counts(ModelId::Lenet);
        assert_eq!(counts[1], 0, "zero-share node must stay empty");
        assert_eq!(counts.iter().sum::<u64>(), 100);
        assert!(router.take_buffer(1).is_empty());
        // 2:1 split within one arrival of ideal.
        assert!((counts[0] as f64 - 100.0 * 2.0 / 3.0).abs() <= 1.0 + 1e-9);
    }

    #[test]
    fn unplaced_models_deal_uniformly_and_are_counted() {
        // Shares only for LeNet; VGG arrivals have no placement.
        let arrivals: Vec<Arrival> = (0..60)
            .map(|i| Arrival {
                time_ms: i as f64,
                model: if i % 2 == 0 { ModelId::Lenet } else { ModelId::Vgg },
                id: i as u64,
            })
            .collect();
        let mut router =
            Router::new(DynSourceMux::of_trace(arrivals), &node_rates_for(&[1.0, 1.0]));
        router.deal_all();
        let unplaced = router.unplaced_per_model();
        assert_eq!(unplaced[ModelId::Vgg.index()], 30);
        assert_eq!(unplaced[ModelId::Lenet.index()], 0);
        // Uniform dealing: 15 VGG per node.
        let vgg = router.dealt_counts(ModelId::Vgg);
        assert_eq!(vgg, &[15, 15]);
        let offered = router.offered_per_model();
        assert_eq!(offered[ModelId::Lenet.index()], 30);
        assert_eq!(offered[ModelId::Vgg.index()], 30);
    }

    #[test]
    fn dealing_is_byte_reproducible_and_time_ordered() {
        let pairs = [(ModelId::Lenet, 120.0), (ModelId::Vgg, 45.0)];
        let shares = [[80.0, 0.0, 0.0, 0.0, 30.0], [40.0, 0.0, 0.0, 0.0, 15.0]];
        let deal = || {
            let mux = SourceMux::new(dyn_sources(
                poisson_streams(&pairs, 4.0, 77).unwrap(),
            ));
            let mut router = Router::new(mux, &shares);
            router.deal_all();
            (router.take_buffer(0), router.take_buffer(1))
        };
        let (a0, a1) = deal();
        let (b0, b1) = deal();
        assert_eq!(a0, b0, "same seed must deal identically");
        assert_eq!(a1, b1);
        for chunk in [&a0, &a1] {
            assert!(!chunk.is_empty());
            assert!(
                chunk.windows(2).all(|w| w[0].time_ms <= w[1].time_ms),
                "per-node chunks must stay time-ordered"
            );
        }
    }

    #[test]
    fn retarget_restarts_counters_and_keeps_buffers() {
        let mut router =
            Router::new(lenet_trace(40), &node_rates_for(&[1.0, 1.0]));
        router.deal_until(ms_to_us(19.0)); // first 20 arrivals
        assert_eq!(router.dealt_counts(ModelId::Lenet).iter().sum::<u64>(), 20);
        // Retarget everything onto node 1.
        router.retarget(&node_rates_for(&[0.0, 1.0]));
        assert_eq!(router.dealt_counts(ModelId::Lenet), &[0, 0]);
        router.deal_all();
        assert_eq!(router.dealt_counts(ModelId::Lenet), &[0, 20]);
        // Pre-retarget deals stayed in node 0's buffer.
        assert_eq!(router.take_buffer(0).len(), 10);
        assert_eq!(router.take_buffer(1).len(), 30);
        assert_eq!(router.offered_per_model()[ModelId::Lenet.index()], 40);
    }

    #[test]
    fn single_node_router_passes_everything_through_in_order() {
        let mux = SourceMux::new(dyn_sources(
            poisson_streams(&[(ModelId::Lenet, 200.0)], 2.0, 5).unwrap(),
        ));
        let reference: Vec<Arrival> = mux.clone().materialize();
        let mut router = Router::new(mux, &node_rates_for(&[1.0]));
        router.deal_all();
        assert_eq!(router.take_buffer(0), reference);
        assert!(router.is_exhausted());
        assert_eq!(router.last_arrival_ms(), reference.last().unwrap().time_ms);
    }

    #[test]
    fn materialized_source_is_usable_directly() {
        // The router's mux contract is the engine's: any DynSourceMux,
        // including a single materialized stream.
        let arrivals =
            vec![Arrival { time_ms: 1.0, model: ModelId::Resnet, id: 0 }];
        let mux = SourceMux::new(dyn_sources(vec![MaterializedSource::new(arrivals)]));
        let mut router = Router::new(mux, &[[0.0, 0.0, 5.0, 0.0, 0.0]]);
        router.deal_all();
        assert_eq!(router.dealt_counts(ModelId::Resnet), &[1]);
    }
}
