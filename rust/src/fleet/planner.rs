//! Fleet-level placement: split per-model offered rates across N
//! homogeneous nodes so every node's slice is schedulable on its own
//! GPUs.
//!
//! The placement is a first-fit-decreasing water-fill over a capacity
//! *estimate*, validated by ground truth: models are ordered by how
//! much of one node their demand consumes (from the memoized
//! `CapacityTable` full-GPU rates), poured into the lowest-index node
//! with estimated headroom, and spilled onto the next node only when
//! one fills up — consolidating load onto as few nodes as possible,
//! like the paper consolidates models onto as few gpu-lets as
//! possible. Every loaded node is then checked with a real per-node
//! [`Scheduler::schedule`] call (the estimate ignores duty-cycle
//! interactions between co-placed models); if any node rejects its
//! slice, the whole placement is retried at a lower fill target, which
//! spreads the load wider. A load no retry can place yields a proper
//! `Error::NotSchedulable`.
//!
//! The single-node fleet bypasses the estimate entirely and asks the
//! scheduler directly, so a 1-node fleet accepts *exactly* the loads a
//! single server accepts — the conservativeness anchor
//! `tests/fleet_equivalence.rs` builds on.

use crate::error::{Error, Result};
use crate::models::ModelId;
use crate::sched::types::validate_rates;
use crate::sched::{SchedCtx, Schedule, Scheduler};

const EPS_RATE: f64 = 1e-6;

/// Fill-target ladder: the first attempt consolidates maximally; each
/// retry after a per-node scheduler rejection spreads the load wider.
const FILL_LADDER: [f64; 6] = [1.0, 0.85, 0.72, 0.61, 0.52, 0.44];

/// A complete fleet placement: one schedule per node plus the planned
/// per-(node, model) rate shares the router splits arrivals by.
#[derive(Clone, Debug, Default)]
pub struct FleetPlan {
    /// Per-node schedules (`Schedule::default()` = idle node).
    pub schedules: Vec<Schedule>,
    /// Planned rate share (req/s) per node and model:
    /// `node_rates[node][model.index()]`.
    pub node_rates: Vec<[f64; 5]>,
}

impl FleetPlan {
    pub fn nodes(&self) -> usize {
        self.schedules.len()
    }

    /// Total planned rate for `m` across the fleet.
    pub fn total_share(&self, m: ModelId) -> f64 {
        self.node_rates.iter().map(|r| r[m.index()]).sum()
    }

    /// True when some node holds a share of `m`.
    pub fn placed(&self, m: ModelId) -> bool {
        self.total_share(m) > EPS_RATE
    }

    /// Nodes actually serving load (non-empty schedule).
    pub fn active_nodes(&self) -> usize {
        self.schedules.iter().filter(|s| !s.lets.is_empty()).count()
    }
}

/// Splits offered rates across a homogeneous fleet. `ctx` is the
/// per-node scheduling context (its `num_gpus` is the node's GPU
/// count); `scheduler` plans each node's slice.
#[derive(Clone, Copy)]
pub struct FleetPlanner<'a> {
    pub ctx: &'a SchedCtx,
    pub scheduler: &'a dyn Scheduler,
    pub nodes: usize,
}

impl<'a> FleetPlanner<'a> {
    pub fn new(ctx: &'a SchedCtx, scheduler: &'a dyn Scheduler, nodes: usize) -> Self {
        FleetPlanner { ctx, scheduler, nodes }
    }

    /// Place `rates` (req/s per model, `ModelId::index`-indexed) across
    /// the fleet. Deterministic: same inputs, same plan.
    pub fn plan(&self, rates: &[f64; 5]) -> Result<FleetPlan> {
        validate_rates(rates)?;
        if self.nodes == 0 {
            return Err(Error::Other("fleet must have at least one node".into()));
        }
        // One node: the scheduler IS the planner — no estimate in the
        // way, so the 1-node fleet accepts exactly what a single
        // server accepts.
        if self.nodes == 1 {
            let s = self.scheduler.schedule(self.ctx, rates)?;
            return Ok(FleetPlan { schedules: vec![s], node_rates: vec![*rates] });
        }
        // Per-model one-node capacity estimate: the memoized full-GPU
        // max rate times the node's GPU count. Smaller partitions can
        // be *more* rate-efficient than one 100% gpu-let (the knee of
        // the affordable-rate curve), so this may under-estimate — safe:
        // it only spreads load wider than strictly necessary.
        let mut node_cap = [0.0f64; 5];
        for m in ModelId::ALL {
            if rates[m.index()] <= 0.0 {
                continue;
            }
            let Some((full, _)) = self.ctx.max_rate(m, 100) else {
                return Err(Error::NotSchedulable(format!(
                    "{m}: cannot meet its SLO even on a whole GPU"
                )));
            };
            node_cap[m.index()] = full * self.ctx.num_gpus as f64;
        }
        let mut last_err = None;
        for &fill in &FILL_LADDER {
            match self.try_fill(rates, &node_cap, fill) {
                Ok(plan) => return Ok(plan),
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.unwrap_or_else(|| {
            Error::NotSchedulable("fleet placement found no feasible split".into())
        }))
    }

    /// Place `rates` across only the *alive* subset of the fleet
    /// (`alive[i]` = node `i` may take load). Dead nodes get the empty
    /// schedule and zero rate shares, so the plan still spans all
    /// `self.nodes` slots and the fleet engine's node/router indexing
    /// is unchanged. With every node alive this is exactly [`plan`];
    /// with none alive it is `NotSchedulable`.
    ///
    /// [`plan`]: FleetPlanner::plan
    pub fn plan_masked(&self, rates: &[f64; 5], alive: &[bool]) -> Result<FleetPlan> {
        if alive.len() != self.nodes {
            return Err(Error::Other(format!(
                "alive mask covers {} nodes, fleet has {}",
                alive.len(),
                self.nodes
            )));
        }
        if alive.iter().all(|&a| a) {
            return self.plan(rates);
        }
        let survivors: Vec<usize> =
            (0..self.nodes).filter(|&i| alive[i]).collect();
        if survivors.is_empty() {
            return Err(Error::NotSchedulable(
                "no alive node to place load on".into(),
            ));
        }
        // Plan a dense sub-fleet of the survivors, then scatter the
        // schedules/shares back to their original node slots.
        let sub = FleetPlanner::new(self.ctx, self.scheduler, survivors.len());
        let dense = sub.plan(rates)?;
        let mut plan = FleetPlan {
            schedules: vec![Schedule::default(); self.nodes],
            node_rates: vec![[0.0f64; 5]; self.nodes],
        };
        for (di, &ni) in survivors.iter().enumerate() {
            plan.schedules[ni] = dense.schedules[di].clone();
            plan.node_rates[ni] = dense.node_rates[di];
        }
        Ok(plan)
    }

    /// One FFD water-fill pass at a given estimated fill target,
    /// validated by per-node scheduler calls.
    fn try_fill(
        &self,
        rates: &[f64; 5],
        node_cap: &[f64; 5],
        fill: f64,
    ) -> Result<FleetPlan> {
        let n = self.nodes;
        let mut node_rates = vec![[0.0f64; 5]; n];
        // Estimated utilization fraction per node.
        let mut used = vec![0.0f64; n];
        // FFD order: models descending by the fraction of one node
        // their demand consumes (stable sort keeps `ModelId` order on
        // exact ties — deterministic).
        let mut order: Vec<(usize, f64)> = (0..5)
            .filter(|&i| rates[i] > 0.0)
            .map(|i| (i, rates[i] / node_cap[i]))
            .collect();
        order.sort_by(|a, b| b.1.total_cmp(&a.1));
        for (mi, _) in order {
            let mut left = rates[mi];
            for ni in 0..n {
                if left <= EPS_RATE {
                    break;
                }
                let headroom = (fill - used[ni]) * node_cap[mi];
                if headroom <= EPS_RATE {
                    continue;
                }
                let take = left.min(headroom);
                node_rates[ni][mi] += take;
                used[ni] += take / node_cap[mi];
                left -= take;
            }
            if left > EPS_RATE {
                return Err(Error::NotSchedulable(format!(
                    "{}: {left:.1} req/s unplaced with all {n} nodes at {:.0}% of \
                     estimated capacity",
                    ModelId::from_index(mi),
                    fill * 100.0,
                )));
            }
        }
        // Ground truth: every loaded node must actually schedule its
        // slice; idle nodes get the empty schedule without a call.
        let mut schedules = Vec::with_capacity(n);
        for nr in &node_rates {
            if nr.iter().all(|&r| r <= EPS_RATE) {
                schedules.push(Schedule::default());
            } else {
                schedules.push(self.scheduler.schedule(self.ctx, nr)?);
            }
        }
        Ok(FleetPlan { schedules, node_rates })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::ElasticPartitioning;

    fn planner_ctx() -> SchedCtx {
        SchedCtx::new(4, None)
    }

    #[test]
    fn one_node_plan_matches_single_server_scheduler() {
        let ctx = planner_ctx();
        let sched = ElasticPartitioning::gpulet();
        let rates = [50.0; 5];
        let plan = FleetPlanner::new(&ctx, &sched, 1).plan(&rates).unwrap();
        assert_eq!(plan.nodes(), 1);
        assert_eq!(plan.node_rates, vec![rates]);
        let direct = sched.schedule(&ctx, &rates).unwrap();
        assert_eq!(plan.schedules[0], direct);
        // And it rejects exactly what the single server rejects.
        let impossible = [1e9; 5];
        assert!(FleetPlanner::new(&ctx, &sched, 1).plan(&impossible).is_err());
        assert!(sched.schedule(&ctx, &impossible).is_err());
    }

    #[test]
    fn shares_cover_offered_rates_and_nodes_schedule() {
        let ctx = planner_ctx();
        let sched = ElasticPartitioning::gpulet();
        let rates = [300.0, 150.0, 100.0, 60.0, 90.0];
        for n in [2usize, 4, 8] {
            let plan = FleetPlanner::new(&ctx, &sched, n).plan(&rates).unwrap();
            assert_eq!(plan.nodes(), n);
            for m in ModelId::ALL {
                let total = plan.total_share(m);
                assert!(
                    (total - rates[m.index()]).abs() < 1e-6,
                    "{m}: shares {total} != offered {} (n={n})",
                    rates[m.index()]
                );
            }
            // Every node's slice is genuinely schedulable, and the
            // schedules carry the slice's models.
            for (ni, s) in plan.schedules.iter().enumerate() {
                let nr = &plan.node_rates[ni];
                if nr.iter().all(|&r| r <= 1e-6) {
                    assert!(s.lets.is_empty(), "idle node {ni} must have no lets");
                } else {
                    assert!(!s.lets.is_empty(), "loaded node {ni} must have lets");
                }
            }
        }
    }

    #[test]
    fn consolidates_small_loads_onto_few_nodes() {
        let ctx = planner_ctx();
        let sched = ElasticPartitioning::gpulet();
        // A load one node holds easily must not be smeared over 8.
        let plan = FleetPlanner::new(&ctx, &sched, 8)
            .plan(&[40.0, 20.0, 0.0, 0.0, 10.0])
            .unwrap();
        assert_eq!(plan.active_nodes(), 1, "small load should consolidate");
    }

    #[test]
    fn fleet_scales_past_a_single_node() {
        let ctx = planner_ctx();
        let sched = ElasticPartitioning::gpulet();
        // Find a load one node rejects: double the equal scenario until
        // the single-node scheduler gives up (at most 2x its capacity).
        let mut heavy = [50.0; 5];
        while sched.schedule(&ctx, &heavy).is_ok() {
            heavy.iter_mut().for_each(|r| *r *= 2.0);
            assert!(heavy[0] < 1e7, "equal scenario never became infeasible");
        }
        // …and show a fleet holds it, with every model split-covered.
        let plan = FleetPlanner::new(&ctx, &sched, 8).plan(&heavy).unwrap();
        for m in ModelId::ALL {
            assert!((plan.total_share(m) - heavy[m.index()]).abs() < 1e-6);
        }
        assert!(plan.active_nodes() >= 2, "heavy load must span nodes");
    }

    #[test]
    fn infeasible_fleet_reports_proper_error() {
        let ctx = planner_ctx();
        let sched = ElasticPartitioning::gpulet();
        let err = FleetPlanner::new(&ctx, &sched, 2).plan(&[1e9; 5]).unwrap_err();
        assert!(matches!(err, Error::NotSchedulable(_)), "{err}");
        let err = FleetPlanner::new(&ctx, &sched, 0).plan(&[1.0; 5]).unwrap_err();
        assert!(err.to_string().contains("at least one node"), "{err}");
        // NaN rates are caller bugs reported at the boundary.
        let mut bad = [10.0; 5];
        bad[2] = f64::NAN;
        assert!(FleetPlanner::new(&ctx, &sched, 2).plan(&bad).is_err());
    }

    #[test]
    fn masked_plan_skips_dead_nodes_and_covers_rates() {
        let ctx = planner_ctx();
        let sched = ElasticPartitioning::gpulet();
        let rates = [300.0, 150.0, 100.0, 60.0, 90.0];
        let planner = FleetPlanner::new(&ctx, &sched, 4);
        let plan = planner.plan_masked(&rates, &[true, false, true, true]).unwrap();
        assert_eq!(plan.nodes(), 4, "masked plan must keep full node indexing");
        assert!(plan.schedules[1].lets.is_empty(), "dead node must stay idle");
        assert_eq!(plan.node_rates[1], [0.0; 5]);
        for m in ModelId::ALL {
            assert!(
                (plan.total_share(m) - rates[m.index()]).abs() < 1e-6,
                "{m}: survivors must absorb the full offered rate"
            );
        }
        // All-alive mask is exactly the unmasked plan.
        let all = planner.plan_masked(&rates, &[true; 4]).unwrap();
        let direct = planner.plan(&rates).unwrap();
        assert_eq!(all.node_rates, direct.node_rates);
        assert_eq!(all.schedules, direct.schedules);
        // No survivors / wrong mask length are proper errors.
        assert!(matches!(
            planner.plan_masked(&rates, &[false; 4]).unwrap_err(),
            Error::NotSchedulable(_)
        ));
        assert!(planner.plan_masked(&rates, &[true; 3]).is_err());
    }

    #[test]
    fn planning_is_deterministic() {
        let ctx = planner_ctx();
        let sched = ElasticPartitioning::gpulet();
        let rates = [500.0, 200.0, 150.0, 80.0, 120.0];
        let a = FleetPlanner::new(&ctx, &sched, 4).plan(&rates).unwrap();
        let b = FleetPlanner::new(&ctx, &sched, 4).plan(&rates).unwrap();
        assert_eq!(a.node_rates, b.node_rates);
        assert_eq!(a.schedules, b.schedules);
    }
}
