//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (the offline build has no
//! `thiserror`); the variant messages are part of the public contract —
//! `scheduler_conformance` asserts on the `not schedulable:` prefix.

use std::fmt;

/// Unified error for the serving stack.
#[derive(Debug)]
pub enum Error {
    /// Manifest / config / trace parse failures.
    Parse(String),

    /// I/O wrapper.
    Io(std::io::Error),

    /// PJRT / XLA runtime failures (or the pjrt-less stub refusing to run).
    Xla(String),

    /// Unknown model name, missing artifact, bad batch size…
    Model(String),

    /// Scheduler could not place the offered load within SLOs.
    NotSchedulable(String),

    /// Invalid gpu-let operation (bad size, over-subscription, …).
    GpuLet(String),

    /// Anything else.
    Other(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Model(m) => write!(f, "model error: {m}"),
            Error::NotSchedulable(m) => write!(f, "not schedulable: {m}"),
            Error::GpuLet(m) => write!(f, "gpulet error: {m}"),
            Error::Other(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Convenience constructor for parse errors.
    pub fn parse(msg: impl Into<String>) -> Self {
        Error::Parse(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_are_stable() {
        assert_eq!(
            Error::NotSchedulable("too much".into()).to_string(),
            "not schedulable: too much"
        );
        assert_eq!(Error::Parse("x".into()).to_string(), "parse error: x");
        assert_eq!(Error::Other("free-form".into()).to_string(), "free-form");
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().starts_with("io error:"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
