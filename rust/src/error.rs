//! Crate-wide error type.

use thiserror::Error;

/// Unified error for the serving stack.
#[derive(Error, Debug)]
pub enum Error {
    /// Manifest / config / trace parse failures.
    #[error("parse error: {0}")]
    Parse(String),

    /// I/O wrapper.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// PJRT / XLA runtime failures.
    #[error("xla error: {0}")]
    Xla(String),

    /// Unknown model name, missing artifact, bad batch size…
    #[error("model error: {0}")]
    Model(String),

    /// Scheduler could not place the offered load within SLOs.
    #[error("not schedulable: {0}")]
    NotSchedulable(String),

    /// Invalid gpu-let operation (bad size, over-subscription, …).
    #[error("gpulet error: {0}")]
    GpuLet(String),

    /// Anything else.
    #[error("{0}")]
    Other(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Convenience constructor for parse errors.
    pub fn parse(msg: impl Into<String>) -> Self {
        Error::Parse(msg.into())
    }
}
