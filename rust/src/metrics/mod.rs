//! Serving metrics: per-model latency/SLO accounting, throughput, and
//! the report rows the experiment harnesses print.

use std::collections::BTreeMap;

use crate::models::ModelId;
use crate::util::stats::Histogram;

/// Accumulates per-model serving outcomes over a measurement window.
#[derive(Clone, Debug)]
pub struct ModelMetrics {
    pub slo_ms: f64,
    pub served: u64,
    pub violations: u64,
    pub dropped: u64,
    hist: Histogram,
}

impl ModelMetrics {
    fn new(slo_ms: f64) -> Self {
        // 0.5 ms bins up to 1 s; the overflow bin catches stragglers.
        ModelMetrics {
            slo_ms,
            served: 0,
            violations: 0,
            dropped: 0,
            hist: Histogram::new(0.5, 2000),
        }
    }

    /// Record a completed request with end-to-end latency `ms`.
    pub fn record(&mut self, ms: f64) {
        self.served += 1;
        self.hist.record(ms);
        if ms > self.slo_ms {
            self.violations += 1;
        }
    }

    /// Record a dropped request — counted as an SLO violation (§6.2:
    /// "counting dropped tasks also as SLO violating cases").
    pub fn record_drop(&mut self) {
        self.dropped += 1;
    }

    /// Total requests that entered the system.
    pub fn total(&self) -> u64 {
        self.served + self.dropped
    }

    /// SLO violation rate including drops, in [0, 1].
    pub fn violation_rate(&self) -> f64 {
        let total = self.total();
        if total == 0 {
            0.0
        } else {
            (self.violations + self.dropped) as f64 / total as f64
        }
    }

    /// Goodput fraction: served within SLO / total offered.
    pub fn goodput_fraction(&self) -> f64 {
        let total = self.total();
        if total == 0 {
            1.0
        } else {
            (self.served - self.violations) as f64 / total as f64
        }
    }

    pub fn p50_ms(&self) -> f64 {
        self.hist.percentile(50.0)
    }

    pub fn p99_ms(&self) -> f64 {
        self.hist.percentile(99.0)
    }

    pub fn mean_ms(&self) -> f64 {
        self.hist.mean()
    }

    pub fn max_ms(&self) -> f64 {
        self.hist.max()
    }
}

/// Whole-run metrics: one `ModelMetrics` per served model.
#[derive(Clone, Debug, Default)]
pub struct Report {
    models: BTreeMap<ModelId, ModelMetrics>,
    /// Measurement window (s) for throughput computation.
    pub window_s: f64,
}

impl Report {
    pub fn new(window_s: f64) -> Self {
        Report { models: BTreeMap::new(), window_s }
    }

    pub fn model_mut(&mut self, m: ModelId, slo_ms: f64) -> &mut ModelMetrics {
        self.models.entry(m).or_insert_with(|| ModelMetrics::new(slo_ms))
    }

    pub fn model(&self, m: ModelId) -> Option<&ModelMetrics> {
        self.models.get(&m)
    }

    pub fn models(&self) -> impl Iterator<Item = (&ModelId, &ModelMetrics)> {
        self.models.iter()
    }

    /// Aggregate SLO violation rate across all models (drops included).
    pub fn overall_violation_rate(&self) -> f64 {
        let total: u64 = self.models.values().map(|m| m.total()).sum();
        if total == 0 {
            return 0.0;
        }
        let bad: u64 = self
            .models
            .values()
            .map(|m| m.violations + m.dropped)
            .sum();
        bad as f64 / total as f64
    }

    /// Requests served per second over the window.
    pub fn throughput_rps(&self) -> f64 {
        if self.window_s <= 0.0 {
            return 0.0;
        }
        let served: u64 = self.models.values().map(|m| m.served).sum();
        served as f64 / self.window_s
    }

    /// Requests served *within SLO* per second.
    pub fn goodput_rps(&self) -> f64 {
        if self.window_s <= 0.0 {
            return 0.0;
        }
        let good: u64 = self
            .models
            .values()
            .map(|m| m.served - m.violations)
            .sum();
        good as f64 / self.window_s
    }

    /// Pretty per-model table (used by the CLI and examples).
    pub fn table(&self) -> String {
        let mut s = String::from(
            "model           served  dropped  viol%   p50ms   p99ms    max\n",
        );
        for (m, mm) in &self.models {
            s.push_str(&format!(
                "{:<15} {:>6} {:>8} {:>6.2} {:>7.1} {:>7.1} {:>6.1}\n",
                m.name(),
                mm.served,
                mm.dropped,
                mm.violation_rate() * 100.0,
                mm.p50_ms(),
                mm.p99_ms(),
                mm.max_ms(),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn violation_accounting_includes_drops() {
        let mut r = Report::new(10.0);
        let mm = r.model_mut(ModelId::Lenet, 5.0);
        mm.record(3.0); // ok
        mm.record(6.0); // violation
        mm.record_drop(); // violation
        assert_eq!(mm.total(), 3);
        assert!((mm.violation_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert!((r.overall_violation_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn throughput_and_goodput() {
        let mut r = Report::new(2.0);
        let mm = r.model_mut(ModelId::Vgg, 130.0);
        for _ in 0..10 {
            mm.record(50.0);
        }
        mm.record(200.0); // served but violating
        assert!((r.throughput_rps() - 5.5).abs() < 1e-12);
        assert!((r.goodput_rps() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles_track_latencies() {
        let mut r = Report::new(1.0);
        let mm = r.model_mut(ModelId::Resnet, 95.0);
        for i in 1..=100 {
            mm.record(i as f64);
        }
        assert!(mm.p50_ms() >= 45.0 && mm.p50_ms() <= 55.0);
        assert!(mm.p99_ms() >= 95.0);
        assert_eq!(mm.max_ms(), 100.0);
        assert!((mm.mean_ms() - 50.5).abs() < 0.1);
    }

    #[test]
    fn empty_report() {
        let r = Report::new(1.0);
        assert_eq!(r.overall_violation_rate(), 0.0);
        assert_eq!(r.throughput_rps(), 0.0);
        assert!(r.model(ModelId::Lenet).is_none());
    }

    #[test]
    fn table_renders() {
        let mut r = Report::new(1.0);
        r.model_mut(ModelId::Lenet, 5.0).record(1.0);
        let t = r.table();
        assert!(t.contains("lenet"));
        assert!(t.lines().count() >= 2);
    }
}
