//! Serving metrics: per-model latency/SLO accounting, throughput, and
//! the report rows the experiment harnesses print.

use std::collections::BTreeMap;

use crate::models::ModelId;
use crate::util::json::{obj, Json};
use crate::util::stats::Histogram;

/// Accumulates per-model serving outcomes over a measurement window.
#[derive(Clone, Debug)]
pub struct ModelMetrics {
    pub slo_ms: f64,
    pub served: u64,
    pub violations: u64,
    pub dropped: u64,
    /// Requests refused at the admission gate (never dealt to a node).
    pub shed: u64,
    /// Requests rewritten to a cheaper fallback model at the admission
    /// gate, counted under the *original* model. Diagnostic only: the
    /// serving outcome (served/dropped/lost) is accounted under the
    /// fallback model, so `degraded` is not a conservation term and is
    /// excluded from [`ModelMetrics::total`] and
    /// [`ModelMetrics::admitted`].
    pub degraded: u64,
    /// Requests destroyed by a node failure: queued backlog, in-flight
    /// batches, and staged arrivals on the node at the instant it died.
    pub lost_to_failure: u64,
    hist: Histogram,
}

impl ModelMetrics {
    fn new(slo_ms: f64) -> Self {
        // 0.5 ms bins up to 1 s; the overflow bin catches stragglers.
        ModelMetrics {
            slo_ms,
            served: 0,
            violations: 0,
            dropped: 0,
            shed: 0,
            degraded: 0,
            lost_to_failure: 0,
            hist: Histogram::new(0.5, 2000),
        }
    }

    /// Record a completed request with end-to-end latency `ms`.
    pub fn record(&mut self, ms: f64) {
        self.served += 1;
        self.hist.record(ms);
        if ms > self.slo_ms {
            self.violations += 1;
        }
    }

    /// Record a dropped request — counted as an SLO violation (§6.2:
    /// "counting dropped tasks also as SLO violating cases").
    pub fn record_drop(&mut self) {
        self.dropped += 1;
    }

    /// Record a request shed at the admission gate. Shed traffic never
    /// enters a queue, so it is *not* admitted and does not count
    /// against the SLO attainment of admitted traffic.
    pub fn record_shed(&mut self) {
        self.shed += 1;
    }

    /// Record a request rewritten to its fallback model at the gate
    /// (counted under the original model; see the field doc).
    pub fn record_degraded(&mut self) {
        self.degraded += 1;
    }

    /// Record a request destroyed by a node failure (queued, staged, or
    /// in flight on the node when it died). Counted as an SLO failure
    /// of admitted traffic, like a drop.
    pub fn record_lost(&mut self) {
        self.lost_to_failure += 1;
    }

    /// Total requests that entered accounting — the per-model
    /// conservation total: `served + dropped + shed + lost_to_failure`.
    pub fn total(&self) -> u64 {
        self.served + self.dropped + self.shed + self.lost_to_failure
    }

    /// Requests the admission gate let through (everything except
    /// shed): served, dropped, or lost to a failure after admission.
    pub fn admitted(&self) -> u64 {
        self.served + self.dropped + self.lost_to_failure
    }

    /// SLO violation rate of *admitted* traffic, in [0, 1] — drops and
    /// failure losses count as violations; shed requests are excluded
    /// from both numerator and denominator (they were never promised a
    /// latency). With zero shed/lost this is the historical
    /// drops-included violation rate.
    pub fn violation_rate(&self) -> f64 {
        let admitted = self.admitted();
        if admitted == 0 {
            0.0
        } else {
            (self.violations + self.dropped + self.lost_to_failure) as f64
                / admitted as f64
        }
    }

    /// Goodput fraction: served within SLO / admitted.
    pub fn goodput_fraction(&self) -> f64 {
        let admitted = self.admitted();
        if admitted == 0 {
            1.0
        } else {
            (self.served - self.violations) as f64 / admitted as f64
        }
    }

    /// Median end-to-end latency, interpolated within the histogram
    /// bin (previously the bin's upper edge, which biased the estimate
    /// high by up to one 0.5 ms bin).
    pub fn p50_ms(&self) -> f64 {
        self.hist.percentile(50.0)
    }

    /// 99th-percentile end-to-end latency (bin-interpolated, like
    /// [`ModelMetrics::p50_ms`]).
    pub fn p99_ms(&self) -> f64 {
        self.hist.percentile(99.0)
    }

    pub fn mean_ms(&self) -> f64 {
        self.hist.mean()
    }

    pub fn max_ms(&self) -> f64 {
        self.hist.max()
    }

    /// Fold another node's accounting for the same model into this one.
    /// Counters add; the latency histograms merge bin-exactly, so the
    /// combined percentiles equal a single-pass histogram over both
    /// sample sets (see [`Histogram::merge`]) — fleet aggregation cannot
    /// skew p50/p99 beyond what one server's binning already does.
    pub fn merge(&mut self, other: &ModelMetrics) {
        // lint: no-alloc — counters add in place; the histogram merge
        // reuses self's bins (see Histogram::merge).
        debug_assert!(
            (self.slo_ms - other.slo_ms).abs() < 1e-9,
            "merging model metrics with mismatched SLOs ({} vs {})",
            self.slo_ms,
            other.slo_ms,
        );
        self.served += other.served;
        self.violations += other.violations;
        self.dropped += other.dropped;
        self.shed += other.shed;
        self.degraded += other.degraded;
        self.lost_to_failure += other.lost_to_failure;
        self.hist.merge(&other.hist);
        // lint: end-no-alloc
    }
}

/// Whole-run metrics: one `ModelMetrics` per served model.
#[derive(Clone, Debug, Default)]
pub struct Report {
    models: BTreeMap<ModelId, ModelMetrics>,
    /// Measurement window (s) for throughput computation.
    pub window_s: f64,
}

impl Report {
    pub fn new(window_s: f64) -> Self {
        Report { models: BTreeMap::new(), window_s }
    }

    pub fn model_mut(&mut self, m: ModelId, slo_ms: f64) -> &mut ModelMetrics {
        self.models.entry(m).or_insert_with(|| ModelMetrics::new(slo_ms))
    }

    pub fn model(&self, m: ModelId) -> Option<&ModelMetrics> {
        self.models.get(&m)
    }

    pub fn models(&self) -> impl Iterator<Item = (&ModelId, &ModelMetrics)> {
        self.models.iter()
    }

    /// Aggregate SLO violation rate of admitted traffic across all
    /// models (drops and failure losses included; shed excluded).
    pub fn overall_violation_rate(&self) -> f64 {
        let admitted: u64 = self.models.values().map(|m| m.admitted()).sum();
        if admitted == 0 {
            return 0.0;
        }
        let bad: u64 = self
            .models
            .values()
            .map(|m| m.violations + m.dropped + m.lost_to_failure)
            .sum();
        bad as f64 / admitted as f64
    }

    /// SLO attainment of *admitted* traffic: served-within-SLO over
    /// everything the admission gate let through. This is the headline
    /// admission-control metric — shedding infeasible load should raise
    /// it relative to an admit-everything baseline.
    pub fn admitted_slo_attainment(&self) -> f64 {
        let admitted: u64 = self.models.values().map(|m| m.admitted()).sum();
        if admitted == 0 {
            return 1.0;
        }
        let good: u64 = self
            .models
            .values()
            .map(|m| m.served - m.violations)
            .sum();
        good as f64 / admitted as f64
    }

    /// Requests served per second over the window.
    pub fn throughput_rps(&self) -> f64 {
        if self.window_s <= 0.0 {
            return 0.0;
        }
        let served: u64 = self.models.values().map(|m| m.served).sum();
        served as f64 / self.window_s
    }

    /// Requests served *within SLO* per second.
    pub fn goodput_rps(&self) -> f64 {
        if self.window_s <= 0.0 {
            return 0.0;
        }
        let good: u64 = self
            .models
            .values()
            .map(|m| m.served - m.violations)
            .sum();
        good as f64 / self.window_s
    }

    /// Fold another report into this one, per model: counters add and
    /// latency histograms merge bin-exactly. This is how the fleet tier
    /// aggregates N per-node reports into one fleet view — merging a
    /// single report into an empty one reproduces it byte-for-byte
    /// (same JSON), so a 1-node fleet is indistinguishable from a
    /// single server. `self.window_s` is kept: the caller sets the
    /// fleet-wide measurement window when constructing the target.
    pub fn merge(&mut self, other: &Report) {
        // lint: no-alloc — the steady-state path (model already seen)
        // merges entirely in place through the entry API; the one
        // first-sight clone below is pinned in lint_allow.toml.
        use std::collections::btree_map::Entry;
        for (&m, mm) in &other.models {
            match self.models.entry(m) {
                Entry::Occupied(e) => e.into_mut().merge(mm),
                // First sight of this model: one pre-sized clone instead
                // of building a zero-filled histogram and folding into
                // it bin by bin (the fleet's `finish` merges N node
                // reports — this is the bulk of that fold).
                Entry::Vacant(v) => {
                    v.insert(mm.clone());
                }
            }
        }
        // lint: end-no-alloc
    }

    /// Counters-only snapshot for later [`Report::snapshot_window`]
    /// deltas — how the continuously-accumulating engine report is
    /// carved into per-window views without resetting any state.
    pub fn counters(&self) -> CounterSnapshot {
        CounterSnapshot {
            rows: self
                .models
                .iter()
                .map(|(m, mm)| {
                    (
                        *m,
                        (
                            mm.served,
                            mm.violations,
                            mm.dropped,
                            mm.shed,
                            mm.degraded,
                            mm.lost_to_failure,
                        ),
                    )
                })
                .collect(),
        }
    }

    /// The per-window delta view since `prev` (a snapshot taken at the
    /// window start): served/violations/dropped/shed/degraded/lost per
    /// model over the last `window_s` seconds.
    pub fn snapshot_window(&self, prev: &CounterSnapshot, window_s: f64) -> WindowReport {
        let mut w = WindowReport { window_s, ..WindowReport::default() };
        for (m, mm) in &self.models {
            let (ps, pv, pd, psh, pdg, pl) =
                prev.rows.get(m).copied().unwrap_or((0, 0, 0, 0, 0, 0));
            let i = m.index();
            w.served[i] = mm.served - ps;
            w.violations[i] = mm.violations - pv;
            w.dropped[i] = mm.dropped - pd;
            w.shed[i] = mm.shed - psh;
            w.degraded[i] = mm.degraded - pdg;
            w.lost[i] = mm.lost_to_failure - pl;
        }
        w
    }

    /// Machine-readable form (deterministic key order via `util::json`).
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .models
            .iter()
            .map(|(m, mm)| {
                obj(vec![
                    ("model", Json::Str(m.name().into())),
                    ("slo_ms", Json::Num(mm.slo_ms)),
                    ("served", Json::Num(mm.served as f64)),
                    ("violations", Json::Num(mm.violations as f64)),
                    ("dropped", Json::Num(mm.dropped as f64)),
                    ("shed", Json::Num(mm.shed as f64)),
                    ("degraded", Json::Num(mm.degraded as f64)),
                    ("lost_to_failure", Json::Num(mm.lost_to_failure as f64)),
                    ("p50_ms", Json::Num(mm.p50_ms())),
                    ("p99_ms", Json::Num(mm.p99_ms())),
                    ("mean_ms", Json::Num(mm.mean_ms())),
                    ("max_ms", Json::Num(mm.max_ms())),
                ])
            })
            .collect();
        obj(vec![
            ("window_s", Json::Num(self.window_s)),
            ("throughput_rps", Json::Num(self.throughput_rps())),
            ("goodput_rps", Json::Num(self.goodput_rps())),
            ("violation_rate", Json::Num(self.overall_violation_rate())),
            ("admitted_slo_attainment", Json::Num(self.admitted_slo_attainment())),
            ("models", Json::Arr(rows)),
        ])
    }

    /// Pretty per-model table (used by the CLI and examples). Renders
    /// the same counters as [`Report::to_json`] — shed, degraded, and
    /// lost-to-failure included — so the text output of `gpulets fleet`
    /// reconciles column-for-column with the JSON ledger.
    pub fn table(&self) -> String {
        let mut s = String::from(
            "model           served  dropped   shed   degr   lost  viol%   p50ms   p99ms    max\n",
        );
        for (m, mm) in &self.models {
            s.push_str(&format!(
                "{:<15} {:>6} {:>8} {:>6} {:>6} {:>6} {:>6.2} {:>7.1} {:>7.1} {:>6.1}\n",
                m.name(),
                mm.served,
                mm.dropped,
                mm.shed,
                mm.degraded,
                mm.lost_to_failure,
                mm.violation_rate() * 100.0,
                mm.p50_ms(),
                mm.p99_ms(),
                mm.max_ms(),
            ));
        }
        s
    }
}

/// Counters-only snapshot of a [`Report`] at a point in time; pair with
/// [`Report::snapshot_window`] to read windowed deltas off a
/// continuously-running engine.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    /// Per-model (served, violations, dropped, shed, degraded,
    /// lost_to_failure) at snapshot time.
    rows: BTreeMap<ModelId, (u64, u64, u64, u64, u64, u64)>,
}

/// One window's worth of serving outcomes (deltas between two
/// [`CounterSnapshot`]s), indexed by `ModelId::index`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WindowReport {
    pub window_s: f64,
    pub served: [u64; 5],
    pub violations: [u64; 5],
    pub dropped: [u64; 5],
    pub shed: [u64; 5],
    /// Gate degradations per *original* model (diagnostic — the
    /// outcome is accounted under the fallback, so this is not part of
    /// [`WindowReport::total`]).
    pub degraded: [u64; 5],
    pub lost: [u64; 5],
}

impl WindowReport {
    /// Requests that entered accounting in this window (the
    /// conservation total: served + dropped + shed + lost).
    pub fn total(&self) -> u64 {
        self.served.iter().sum::<u64>()
            + self.dropped.iter().sum::<u64>()
            + self.shed.iter().sum::<u64>()
            + self.lost.iter().sum::<u64>()
    }

    /// SLO violation rate of admitted traffic (drops and failure
    /// losses included, shed excluded) in this window, in [0, 1].
    pub fn violation_rate(&self) -> f64 {
        let admitted = self.served.iter().sum::<u64>()
            + self.dropped.iter().sum::<u64>()
            + self.lost.iter().sum::<u64>();
        if admitted == 0 {
            return 0.0;
        }
        let bad: u64 = self.violations.iter().sum::<u64>()
            + self.dropped.iter().sum::<u64>()
            + self.lost.iter().sum::<u64>();
        bad as f64 / admitted as f64
    }

    /// Served req/s for one model over the window.
    pub fn throughput(&self, m: ModelId) -> f64 {
        if self.window_s <= 0.0 {
            return 0.0;
        }
        self.served[m.index()] as f64 / self.window_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn violation_accounting_includes_drops() {
        let mut r = Report::new(10.0);
        let mm = r.model_mut(ModelId::Lenet, 5.0);
        mm.record(3.0); // ok
        mm.record(6.0); // violation
        mm.record_drop(); // violation
        assert_eq!(mm.total(), 3);
        assert!((mm.violation_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert!((r.overall_violation_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn throughput_and_goodput() {
        let mut r = Report::new(2.0);
        let mm = r.model_mut(ModelId::Vgg, 130.0);
        for _ in 0..10 {
            mm.record(50.0);
        }
        mm.record(200.0); // served but violating
        assert!((r.throughput_rps() - 5.5).abs() < 1e-12);
        assert!((r.goodput_rps() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles_track_latencies() {
        let mut r = Report::new(1.0);
        let mm = r.model_mut(ModelId::Resnet, 95.0);
        for i in 1..=100 {
            mm.record(i as f64);
        }
        assert!(mm.p50_ms() >= 45.0 && mm.p50_ms() <= 55.0);
        assert!(mm.p99_ms() >= 95.0);
        assert_eq!(mm.max_ms(), 100.0);
        assert!((mm.mean_ms() - 50.5).abs() < 0.1);
    }

    #[test]
    fn empty_report() {
        let r = Report::new(1.0);
        assert_eq!(r.overall_violation_rate(), 0.0);
        assert_eq!(r.throughput_rps(), 0.0);
        assert!(r.model(ModelId::Lenet).is_none());
    }

    #[test]
    fn window_snapshots_delta_correctly() {
        let mut r = Report::new(40.0);
        r.model_mut(ModelId::Lenet, 5.0).record(1.0);
        r.model_mut(ModelId::Lenet, 5.0).record(9.0); // violation
        let snap = r.counters();
        // Second window: one more served, one drop, plus a new model.
        r.model_mut(ModelId::Lenet, 5.0).record(2.0);
        r.model_mut(ModelId::Lenet, 5.0).record_drop();
        r.model_mut(ModelId::Vgg, 130.0).record(50.0);
        let w = r.snapshot_window(&snap, 20.0);
        assert_eq!(w.served[ModelId::Lenet.index()], 1);
        assert_eq!(w.violations[ModelId::Lenet.index()], 0);
        assert_eq!(w.dropped[ModelId::Lenet.index()], 1);
        assert_eq!(w.served[ModelId::Vgg.index()], 1);
        assert_eq!(w.total(), 3);
        assert!((w.violation_rate() - 1.0 / 3.0).abs() < 1e-12);
        assert!((w.throughput(ModelId::Lenet) - 0.05).abs() < 1e-12);
        // Empty delta: snapshot against itself.
        let w0 = r.snapshot_window(&r.counters(), 20.0);
        assert_eq!(w0.total(), 0);
        assert_eq!(w0.violation_rate(), 0.0);
    }

    #[test]
    fn merge_empty_report_is_identity_both_ways() {
        let mut r = Report::new(5.0);
        let mm = r.model_mut(ModelId::Lenet, 5.0);
        mm.record(1.0);
        mm.record(7.0); // violation
        mm.record_drop();
        let json = r.to_json().to_string();
        // Empty into full: identity.
        r.merge(&Report::new(5.0));
        assert_eq!(r.to_json().to_string(), json);
        // Full into empty (same window): byte-identical reproduction —
        // the property the 1-node fleet equivalence rests on.
        let mut fresh = Report::new(5.0);
        fresh.merge(&r);
        assert_eq!(fresh.to_json().to_string(), json);
    }

    #[test]
    fn merge_matches_single_report_accounting() {
        // Two "nodes" vs one server recording the same outcomes: every
        // counter, rate, and interpolated percentile must agree exactly.
        // (Latencies are multiples of 0.5 ms so the running sums — and
        // therefore the JSON means — are bit-exact under any addition
        // order.)
        let mut one = Report::new(10.0);
        let mut a = Report::new(10.0);
        let mut b = Report::new(10.0);
        for i in 0..40u64 {
            let ms = 1.0 + ((i * 7) % 18) as f64 * 0.5;
            one.model_mut(ModelId::Lenet, 5.0).record(ms);
            let node = if i % 2 == 0 { &mut a } else { &mut b };
            node.model_mut(ModelId::Lenet, 5.0).record(ms);
        }
        one.model_mut(ModelId::Vgg, 130.0).record(50.0);
        b.model_mut(ModelId::Vgg, 130.0).record(50.0);
        one.model_mut(ModelId::Vgg, 130.0).record_drop();
        a.model_mut(ModelId::Vgg, 130.0).record_drop();
        let mut merged = Report::new(10.0);
        merged.merge(&a);
        merged.merge(&b);
        assert_eq!(merged.to_json().to_string(), one.to_json().to_string());
        assert_eq!(merged.overall_violation_rate(), one.overall_violation_rate());
        assert_eq!(merged.throughput_rps(), one.throughput_rps());
    }

    #[test]
    fn merge_keeps_overflow_latencies_honest() {
        // A straggler past the histogram's counted bins on one node must
        // surface as the merged report's max / high percentiles.
        let mut a = Report::new(1.0);
        a.model_mut(ModelId::Resnet, 95.0).record(10.0);
        let mut b = Report::new(1.0);
        b.model_mut(ModelId::Resnet, 95.0).record(5_000.0); // overflow bin
        a.merge(&b);
        let mm = a.model(ModelId::Resnet).unwrap();
        assert_eq!(mm.served, 2);
        assert_eq!(mm.max_ms(), 5_000.0);
        assert_eq!(mm.p99_ms(), 5_000.0);
    }

    #[test]
    fn report_json_is_deterministic_and_complete() {
        let mut r = Report::new(2.0);
        r.model_mut(ModelId::Lenet, 5.0).record(1.0);
        r.model_mut(ModelId::Lenet, 5.0).record_drop();
        let j = r.to_json().to_string();
        assert!(j.contains("\"violation_rate\""));
        assert!(j.contains("\"lenet\""));
        assert_eq!(j, r.to_json().to_string());
    }

    #[test]
    fn shed_and_lost_accounting() {
        let mut r = Report::new(10.0);
        let mm = r.model_mut(ModelId::Lenet, 5.0);
        mm.record(1.0); // within SLO
        mm.record(9.0); // violation
        mm.record_drop();
        mm.record_shed();
        mm.record_shed();
        mm.record_lost();
        mm.record_degraded();
        // Conservation total counts everything; admitted excludes
        // shed, and degraded is diagnostic-only (outcome accounted
        // under the fallback model).
        assert_eq!(mm.total(), 6);
        assert_eq!(mm.admitted(), 4);
        assert_eq!(mm.degraded, 1);
        // Violation rate is over admitted traffic: 1 violation + 1 drop
        // + 1 lost out of 4 admitted.
        assert!((mm.violation_rate() - 3.0 / 4.0).abs() < 1e-12);
        assert!((mm.goodput_fraction() - 1.0 / 4.0).abs() < 1e-12);
        assert!((r.admitted_slo_attainment() - 1.0 / 4.0).abs() < 1e-12);
        // Counters survive merge and the window-delta path.
        let snap = r.counters();
        let mm = r.model_mut(ModelId::Lenet, 5.0);
        mm.record_shed();
        mm.record_lost();
        mm.record_degraded();
        let w = r.snapshot_window(&snap, 10.0);
        assert_eq!(w.shed[ModelId::Lenet.index()], 1);
        assert_eq!(w.lost[ModelId::Lenet.index()], 1);
        assert_eq!(w.degraded[ModelId::Lenet.index()], 1);
        let mut merged = Report::new(10.0);
        merged.merge(&r);
        let mm = merged.model(ModelId::Lenet).unwrap();
        assert_eq!(mm.shed, 3);
        assert_eq!(mm.lost_to_failure, 2);
        assert_eq!(mm.degraded, 2);
        let j = merged.to_json().to_string();
        assert!(j.contains("\"shed\""));
        assert!(j.contains("\"degraded\""));
        assert!(j.contains("\"lost_to_failure\""));
        assert!(j.contains("\"admitted_slo_attainment\""));
    }

    #[test]
    fn table_renders() {
        let mut r = Report::new(1.0);
        r.model_mut(ModelId::Lenet, 5.0).record(1.0);
        let t = r.table();
        assert!(t.contains("lenet"));
        // The header carries every ledger counter the JSON does.
        assert!(t.contains("degr"));
        assert!(t.contains("shed"));
        assert!(t.contains("lost"));
        assert!(t.lines().count() >= 2);
    }
}
