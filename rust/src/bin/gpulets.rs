//! `gpulets` — CLI launcher for the gpu-let inference serving stack.
//!
//! ```text
//! gpulets run-fig <03|04|05|06|09|12|13|14|15|16|fleet_scale|spacetime|all|list>
//! gpulets sweep [--scheduler <gpulet|gpulet+int|sbp|sbp+part|selftune|ideal|spacetime|all>]
//!               [--gpus N]
//! gpulets serve [--scenario <equal|long-only|short-skew|game|traffic|flashcrowd>]
//!               [--scale K] [--config <toml>] [--algo A] [--gpus N] [--duration S]
//!               [--seed X] [--rate model=R ...]
//!               [--trace out.json [--trace-sample N]] [--gauges out.csv]
//! gpulets fleet [--nodes N] [--rebalance S] [--scenario NAME] [--scale K]
//!               [--seed X] [--algo A] [--gpus N] [--duration S] [--config <toml>]
//!               [--admission <off|shed|degrade>] [--faults <toml>|N]
//!               [--fault-seed X [--fault-episodes N]]
//!               [--trace out.json [--trace-sample N]] [--gauges out.csv]
//! gpulets timeline <trace.json>            # summarize a saved trace
//! gpulets serve-real [--artifacts DIR] [--duration S] [--rate M=R ...]
//! gpulets experiment <fig3|...|fig16|tables|all>   # legacy alias of run-fig
//! gpulets lint [path] [--json] [--fix-allowlist]   # static-analysis gate
//! gpulets profile            # dump the offline L(b,p) profile grid
//! gpulets models             # Table 4
//! gpulets scenarios          # Table 5
//! ```
//!
//! `run-fig N` drives the same `experiments::figNN` harness as the
//! bench targets and writes the machine-readable `BENCH_fig*.json`
//! next to the working directory (clap is unavailable offline — see
//! Cargo.toml — so argument parsing is a small hand-rolled matcher).

use gpulets::apps::App;
use gpulets::config::{Algo, Config};
use gpulets::coordinator::server::RealServer;
use gpulets::coordinator::{ServingEngine, SimConfig};
use gpulets::error::Result;
use gpulets::experiments as ex;
use gpulets::fleet::{AdmissionMode, FleetConfig, FleetEngine, FleetPlanner};
use gpulets::interference::GroundTruth;
use gpulets::models::ModelId;
use gpulets::runtime::{Engine, ModelRegistry};
use gpulets::sched::{SchedCtx, Scheduler};
use gpulets::telemetry::{export, EventKind, Timeline, Tracer};
use gpulets::util::benchkit;
use gpulets::util::json::{obj, Json};
use gpulets::workload::{
    dyn_sources, enumerate_all_scenarios, flashcrowd_streams, generate_arrivals,
    named_scenarios, poisson_streams, DynSourceMux, FaultPlan, FlashCrowdSpec,
    SourceMux,
};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("run-fig") => {
            let (which, flags) = split_positional(args.get(1..).unwrap_or(&[]), "list");
            parse_threads(flags)?;
            run_fig(which)
        }
        Some("experiment") => {
            let (which, flags) = split_positional(args.get(1..).unwrap_or(&[]), "all");
            parse_threads(flags)?;
            experiment(which)
        }
        Some("sweep") => sweep(&args[1..]),
        Some("serve") => serve(&args[1..]),
        Some("fleet") => fleet(&args[1..]),
        Some("serve-real") => serve_real(&args[1..]),
        Some("timeline") => timeline_cmd(&args[1..]),
        Some("bench-compare") => bench_compare(&args[1..]),
        Some("lint") => lint_cmd(&args[1..]),
        Some("profile") => {
            print!("{}", ex::fig03::run());
            Ok(())
        }
        Some("models") => {
            print!("{}", ex::tables::table4());
            Ok(())
        }
        Some("scenarios") => {
            print!("{}", ex::tables::table5());
            Ok(())
        }
        Some("help") | None => {
            print_usage();
            Ok(())
        }
        Some(other) => {
            print_usage();
            Err(gpulets::Error::Other(format!("unknown command {other:?}")))
        }
    }
}

fn print_usage() {
    println!(
        "gpulets — multi-model inference serving with GPU spatial partitioning\n\
         \n\
         USAGE:\n\
         \x20 gpulets run-fig <03|...|16|fleet_scale|spacetime|all|list> [--threads N]\n\
         \x20 gpulets sweep [--scheduler NAME|all] [--gpus N] [--threads N]\n\
         \x20 gpulets serve [--scenario NAME] [--scale K] [--config F] [--algo A]\n\
         \x20               [--gpus N] [--duration S] [--seed X] [--rate model=R]...\n\
         \x20               [--trace out.json [--trace-sample N]] [--gauges out.csv]\n\
         \x20 gpulets fleet [--nodes N] [--rebalance S] [--scenario NAME] [--scale K]\n\
         \x20               [--seed X] [--algo A] [--gpus N] [--duration S] [--config F]\n\
         \x20               [--admission off|shed|degrade] [--faults F|N]\n\
         \x20               [--fault-seed X [--fault-episodes N]]\n\
         \x20               [--trace out.json [--trace-sample N]] [--gauges out.csv]\n\
         \x20 gpulets timeline <trace.json>\n\
         \x20 gpulets serve-real [--artifacts DIR] [--duration S] [--rate model=R]...\n\
         \x20 gpulets experiment <fig3|...|fig16|tables|all> [--threads N]\n\
         \x20 gpulets bench-compare <baseline.json> <fresh.json>\n\
         \x20 gpulets lint [path] [--json] [--fix-allowlist]\n\
         \x20 gpulets profile | models | scenarios | help\n\
         \n\
         schedulers: gpulet gpulet+int sbp sbp+part selftune ideal spacetime\n\
         scenarios:  equal long-only short-skew game traffic flashcrowd\n\
         \n\
         --scenario flashcrowd serves the configured rates with a 3x\n\
         correlated burst mid-trace (deterministic exact-draw source).\n\
         fleet's --admission gates arrivals at the front end when the\n\
         observed demand outgrows the plan (shed = refuse counted,\n\
         degrade = rewrite to the [admission] fallback.<model> from the\n\
         config, defaulting to lenet); --faults scripts node failures\n\
         from a [faults] TOML section (or, given a bare integer N,\n\
         generates N seeded episodes); --fault-seed generates them\n\
         from an explicit seed.\n\
         \n\
         --threads N caps the experiment worker pool (default: all\n\
         cores, or GPULETS_THREADS); results are byte-identical for\n\
         any N — only wall time changes.\n\
         \n\
         run-fig writes BENCH_fig*.json (same envelope as the cargo\n\
         bench targets); sweep writes BENCH_sweep_schedulability.json\n\
         (plain counts, no timing envelope). Both land in the CWD.\n\
         bench-compare diffs two BENCH files by bench name and prints\n\
         per-bench speedups (baseline mean / fresh mean).\n\
         \n\
         --trace records the request-lifecycle event stream (sim-time\n\
         stamped, deterministic) and writes a Chrome trace-event JSON\n\
         loadable in chrome://tracing or Perfetto; --trace-sample N\n\
         keeps every Nth request span (hash-based, seedless — the exact\n\
         event ledger rides along regardless); --gauges writes the\n\
         per-window gauge series (queue depths, utilization, deals,\n\
         admit fractions) as tidy CSV. `timeline` replays a saved\n\
         trace file into a text summary.\n\
         \n\
         lint runs the determinism & soundness static-analysis pass\n\
         (DESIGN.md 11) over <path>/src (default: the rust/ crate) and\n\
         exits 1 on findings not pinned in lint_allow.toml;\n\
         --fix-allowlist regenerates the allowlist in place."
    );
}

/// `gpulets lint [path] [--json] [--fix-allowlist]` — the blocking CI
/// gate. Exit 0 when clean, 1 on unallowlisted findings, 2 on
/// operational errors (unreadable tree, malformed allowlist).
fn lint_cmd(args: &[String]) -> Result<()> {
    let mut root: Option<String> = None;
    let mut json = false;
    let mut fix = false;
    for a in args {
        match a.as_str() {
            "--json" => json = true,
            "--fix-allowlist" => fix = true,
            flag if flag.starts_with("--") => {
                return Err(gpulets::Error::Other(format!("unknown lint flag {flag:?}")))
            }
            path => root = Some(path.to_string()),
        }
    }
    let root = match root {
        Some(p) => std::path::PathBuf::from(p),
        // Run from either the crate dir (CI's working-directory) or
        // the repo root.
        None if std::path::Path::new("src").is_dir() => std::path::PathBuf::from("."),
        None => std::path::PathBuf::from("rust"),
    };
    if fix {
        let text = gpulets::analysis::fix_allowlist(&root)?;
        eprintln!(
            "wrote {} ({} entries)",
            root.join("lint_allow.toml").display(),
            text.lines().filter(|l| l.starts_with("[allow.")).count()
        );
    }
    let report = gpulets::analysis::lint_tree(&root)?;
    if json {
        println!("{}", report.render_json());
    } else {
        print!("{}", report.render_human());
    }
    if !report.clean() {
        std::process::exit(1);
    }
    Ok(())
}

/// Split an optional leading positional argument from trailing flags:
/// `(positional_or_default, flags)`. Lets `run-fig --threads 4` work
/// without a figure name instead of misparsing the flag as one.
fn split_positional<'a>(args: &'a [String], default: &'a str) -> (&'a str, &'a [String]) {
    match args.first() {
        Some(first) if !first.starts_with("--") => (first.as_str(), &args[1..]),
        _ => (default, args),
    }
}

/// THE flag-table walker every subcommand shares: args are uniform
/// `--flag value` pairs; `apply` returns `Ok(true)` when it recognized
/// the flag, `Ok(false)` to report it unknown. Value extraction,
/// missing-value errors, and unknown-flag errors live here once instead
/// of being re-rolled per subcommand.
fn parse_kv_flags(
    args: &[String],
    mut apply: impl FnMut(&str, &str) -> Result<bool>,
) -> Result<()> {
    let mut i = 0;
    while i < args.len() {
        let flag = args[i].as_str();
        if !flag.starts_with("--") {
            return Err(gpulets::Error::Other(format!("unknown flag {flag:?}")));
        }
        let val = args.get(i + 1).ok_or_else(|| {
            gpulets::Error::Other(format!("flag {flag} needs a value"))
        })?;
        if !apply(flag, val)? {
            return Err(gpulets::Error::Other(format!("unknown flag {flag:?}")));
        }
        i += 2;
    }
    Ok(())
}

/// Validate and apply a `--threads` value (shared by every subcommand
/// that accepts the flag).
fn set_threads_flag(val: &str) -> Result<()> {
    let n: usize = val
        .parse()
        .ok()
        .filter(|&n| n >= 1)
        .ok_or_else(|| gpulets::Error::Other("--threads expects an integer >= 1".into()))?;
    gpulets::util::par::set_threads(n);
    Ok(())
}

fn parse_num<T: std::str::FromStr>(flag: &str, val: &str, what: &str) -> Result<T> {
    val.parse()
        .map_err(|_| gpulets::Error::Other(format!("{flag} expects {what}")))
}

/// Ring capacity per tracer when `--trace`/`--gauges` is on. Overflow
/// overwrites the oldest events; the export reports the count as
/// `dropped_events` (the exact ledger is unaffected). Raise sampling
/// (`--trace-sample`) rather than expecting an unbounded ring.
const TRACE_CAP: usize = 1 << 18;

/// The `--trace` / `--trace-sample` / `--gauges` flag trio shared by
/// `serve` and `fleet`.
#[derive(Default)]
struct TraceOpts {
    trace: Option<String>,
    gauges: Option<String>,
    sample: u64,
}

impl TraceOpts {
    /// Recognize and absorb one of the trace flags.
    fn apply(&mut self, flag: &str, val: &str) -> Result<bool> {
        match flag {
            "--trace" => self.trace = Some(val.to_string()),
            "--gauges" => self.gauges = Some(val.to_string()),
            "--trace-sample" => {
                self.sample = parse_num::<u64>(flag, val, "an integer >= 1")?.max(1);
            }
            _ => return Ok(false),
        }
        Ok(true)
    }

    fn enabled(&self) -> bool {
        self.trace.is_some() || self.gauges.is_some()
    }

    fn sample_n(&self) -> u64 {
        self.sample.max(1)
    }

    /// Write whatever outputs were requested from the finished run's
    /// timeline.
    fn write(&self, tl: &Timeline) -> Result<()> {
        if let Some(path) = &self.trace {
            std::fs::write(path, export::chrome_trace(tl).to_string())?;
            println!(
                "[wrote {path}: {} trace events ({} lost to ring overflow), \
                 {} gauge window(s) — load in chrome://tracing or Perfetto]",
                tl.events.len(),
                tl.dropped_events,
                tl.windows.len(),
            );
        }
        if let Some(path) = &self.gauges {
            std::fs::write(path, export::gauges_csv(tl))?;
            println!("[wrote {path}: {} gauge window(s) as tidy CSV]", tl.windows.len());
        }
        Ok(())
    }
}

/// `gpulets timeline <trace.json>`: replay a saved Chrome-trace export
/// into a text summary (ledger, per-track batch stats, fault markers).
fn timeline_cmd(args: &[String]) -> Result<()> {
    let Some(path) = args.first() else {
        return Err(gpulets::Error::Other("timeline expects <trace.json>".into()));
    };
    let text = std::fs::read_to_string(path)?;
    let doc = Json::parse(&text)?;
    print!("{}", export::summarize(&doc)?);
    Ok(())
}

/// Parse a trailing `--threads N` (the only flag `run-fig` takes) and
/// configure the experiment worker pool.
fn parse_threads(args: &[String]) -> Result<()> {
    parse_kv_flags(args, |flag, val| match flag {
        "--threads" => {
            set_threads_flag(val)?;
            Ok(true)
        }
        _ => Ok(false),
    })
}

/// `bench-compare`: diff a fresh BENCH file against a baseline.
fn bench_compare(args: &[String]) -> Result<()> {
    let (Some(baseline), Some(fresh)) = (args.first(), args.get(1)) else {
        return Err(gpulets::Error::Other(
            "bench-compare expects <baseline.json> <fresh.json>".into(),
        ));
    };
    print!("{}", benchkit::compare_files(baseline, fresh)?);
    Ok(())
}

/// `run-fig`: drive one (or all) figure experiments through the shared
/// Runnable harness, printing the report and writing BENCH_fig*.json.
fn run_fig(which: &str) -> Result<()> {
    match which {
        "list" => {
            println!("available figures:");
            for e in ex::registry() {
                println!("  {:<7} {:<55} -> {}", e.name(), e.title(), e.bench_file());
            }
            Ok(())
        }
        "all" => {
            for e in ex::registry() {
                eprintln!("[running {}]", e.name());
                ex::common::run_and_write(e.as_ref(), 0, 1)?;
            }
            Ok(())
        }
        name => match ex::find(name) {
            Some(e) => {
                ex::common::run_and_write(e.as_ref(), 0, 1)?;
                Ok(())
            }
            None => Err(gpulets::Error::Other(format!(
                "unknown figure {name:?} (try `gpulets run-fig list`)"
            ))),
        },
    }
}

/// Legacy `experiment` command: tables stay text-only; figures route
/// through the same harness as `run-fig`.
fn experiment(which: &str) -> Result<()> {
    match which {
        "tables" => {
            print!("{}", ex::tables::table3());
            print!("{}", ex::tables::table4());
            print!("{}", ex::tables::table5());
            Ok(())
        }
        "all" => {
            print!("{}", ex::tables::table3());
            print!("{}", ex::tables::table4());
            print!("{}", ex::tables::table5());
            run_fig("all")
        }
        name => run_fig(name),
    }
}

/// Build the scheduler + context pair the CLI vocabulary names. The
/// scheduler's own `interference_aware()` decides whether the context
/// carries the fitted interference model, so new algos get the right
/// context without touching this function.
fn scheduler_for(algo: Algo, gpus: usize) -> (Box<dyn Scheduler>, SchedCtx) {
    let scheduler = algo.scheduler();
    let ctx = SchedCtx::new(
        gpus,
        if scheduler.interference_aware() {
            Some(ex::common::fitted_interference())
        } else {
            None
        },
    );
    (scheduler, ctx)
}

/// Per-model rates for a named scenario: the Table 5 mixes, or one of
/// the multi-model applications at a 50 req/s base app rate.
fn scenario_rates(name: &str) -> Result<[f64; 5]> {
    for sc in named_scenarios() {
        if sc.name == name {
            return Ok(sc.rates);
        }
    }
    if let Some(app) = App::by_name(name) {
        return Ok(app.induced_rates(50.0));
    }
    Err(gpulets::Error::Other(format!(
        "unknown scenario {name:?} (equal|long-only|short-skew|game|traffic)"
    )))
}

/// `sweep`: schedulability of the 1,023-scenario population for one (or
/// every) scheduler; writes BENCH_sweep_schedulability.json.
fn sweep(args: &[String]) -> Result<()> {
    let mut which = "gpulet+int".to_string();
    let mut gpus = 4usize;
    parse_kv_flags(args, |flag, val| match flag {
        "--scheduler" => {
            which = val.to_string();
            Ok(true)
        }
        "--gpus" => {
            gpus = parse_num(flag, val, "an integer")?;
            Ok(true)
        }
        "--threads" => {
            set_threads_flag(val)?;
            Ok(true)
        }
        _ => Ok(false),
    })?;

    let names: Vec<String> = if which == "all" {
        ["sbp", "sbp+part", "selftune", "gpulet", "gpulet+int", "ideal", "spacetime"]
            .iter()
            .map(|s| s.to_string())
            .collect()
    } else {
        vec![which]
    };

    let scenarios = enumerate_all_scenarios();
    println!(
        "# schedulability sweep: {} scenarios on {gpus} GPUs (rates 0/200/400/600), \
         {} worker threads",
        scenarios.len(),
        gpulets::util::par::threads()
    );
    println!("{:<12} {:>11} {:>10}", "scheduler", "schedulable", "elapsed");
    let mut entries = Vec::new();
    for name in &names {
        let algo = Algo::parse(name)?;
        let (scheduler, ctx) = scheduler_for(algo, gpus);
        let t0 = std::time::Instant::now();
        // Independent per-scenario verdicts: fan out over the worker
        // pool; the count (and the JSON below) is thread-count
        // independent.
        let n = gpulets::util::par::par_map(&scenarios, |sc| {
            scheduler.schedule(&ctx, &sc.rates).is_ok()
        })
        .into_iter()
        .filter(|&ok| ok)
        .count();
        let dt = t0.elapsed().as_secs_f64();
        println!("{:<12} {:>6}/{:<4} {:>9.2}s", name, n, scenarios.len(), dt);
        entries.push(obj(vec![
            ("scheduler", Json::Str(name.clone())),
            ("schedulable", Json::Num(n as f64)),
            ("total", Json::Num(scenarios.len() as f64)),
            ("elapsed_s", Json::Num(dt)),
        ]));
    }
    let doc = obj(vec![
        ("gpus", Json::Num(gpus as f64)),
        ("sweep", Json::Arr(entries)),
    ]);
    benchkit::write_json("BENCH_sweep_schedulability.json", &doc)?;
    eprintln!("[wrote BENCH_sweep_schedulability.json]");
    Ok(())
}

/// The shared `--key value` vocabulary over a `Config` (serve,
/// serve-real, fleet): returns `Ok(true)` when the flag was recognized.
/// `--scenario` loads a named rate vector; a later `--scale K`
/// multiplies whatever rates are in effect; `--algo`/`--gpus` also
/// shape the fleet's per-node topology so `gpulets fleet --algo …`
/// behaves like `serve`.
fn apply_config_flag(cfg: &mut Config, flag: &str, val: &str) -> Result<bool> {
    match flag {
        "--config" => *cfg = Config::load(val)?,
        "--scenario" => cfg.rates = scenario_rates(val)?,
        "--scale" => {
            let k: f64 = parse_num(flag, val, "a number")?;
            cfg.rates.iter_mut().for_each(|r| *r *= k);
        }
        "--algo" => {
            cfg.algo = Algo::parse(val)?;
            cfg.fleet.algo = cfg.algo;
        }
        "--gpus" => {
            cfg.num_gpus = parse_num(flag, val, "an integer")?;
            cfg.fleet.gpus_per_node = cfg.num_gpus;
        }
        "--duration" => cfg.duration_s = parse_num(flag, val, "seconds")?,
        "--seed" => cfg.seed = parse_num(flag, val, "an integer")?,
        "--artifacts" => cfg.artifacts_dir = val.to_string(),
        "--threads" => set_threads_flag(val)?,
        "--rate" => {
            let (name, rate) = val.split_once('=').ok_or_else(|| {
                gpulets::Error::Other("--rate expects model=req_per_s".into())
            })?;
            let m = ModelId::parse(name)?;
            cfg.rates[m.index()] = rate
                .parse()
                .map_err(|_| gpulets::Error::Other(format!("bad rate {rate:?}")))?;
        }
        _ => return Ok(false),
    }
    Ok(true)
}

/// Parse the shared config flags (serve / serve-real accept nothing
/// else).
fn parse_flags(args: &[String], cfg: &mut Config) -> Result<()> {
    parse_kv_flags(args, |flag, val| apply_config_flag(cfg, flag, val))
}

/// Streamed Poisson workload for a rate vector (shared by serve and
/// fleet): one source per model with a positive rate, k-way merged.
/// Returns the mux and the stream count (for the O(active) log lines).
fn poisson_mux(rates: &[f64; 5], duration_s: f64, seed: u64) -> Result<(DynSourceMux, usize)> {
    let pairs: Vec<(ModelId, f64)> = ModelId::ALL
        .iter()
        .map(|&m| (m, rates[m.index()]))
        .filter(|&(_, r)| r > 0.0)
        .collect();
    let streams = poisson_streams(&pairs, duration_s, seed)?;
    let n = streams.len();
    Ok((SourceMux::new(dyn_sources(streams)), n))
}

/// The `--scenario flashcrowd` envelope: the configured rates as the
/// baseline with a 3x correlated burst over the middle half of the
/// trace (sinusoidal ramps, exact-draw deterministic source).
fn flashcrowd_spec(rates: &[f64; 5], duration_s: f64) -> FlashCrowdSpec {
    FlashCrowdSpec {
        base: *rates,
        peak_mult: 3.0,
        t_start_s: duration_s * 0.25,
        ramp_s: duration_s * 0.125,
        hold_s: duration_s * 0.25,
    }
}

/// Streamed flash-crowd workload over the configured rates (shared by
/// serve and fleet when `--scenario flashcrowd` is in effect).
fn flashcrowd_mux(
    rates: &[f64; 5],
    duration_s: f64,
    seed: u64,
) -> Result<(DynSourceMux, usize)> {
    let spec = flashcrowd_spec(rates, duration_s);
    let streams = flashcrowd_streams(&spec, duration_s, 1.0, seed)?;
    let n = streams.len();
    Ok((SourceMux::new(dyn_sources(streams)), n))
}

/// Print one schedule's gpu-let layout (shared by serve and fleet).
fn print_schedule(schedule: &gpulets::sched::Schedule, indent: &str) {
    for lp in &schedule.lets {
        let asg: Vec<String> = lp
            .assignments
            .iter()
            .map(|a| format!("{}@b{} {:.0}req/s", a.model.abbrev(), a.batch, a.rate))
            .collect();
        println!(
            "{indent}gpu{} {:>3}%: {}",
            lp.spec.gpu,
            lp.spec.size_pct,
            asg.join(" + ")
        );
    }
}

/// Simulated serving: schedule the configured rates, run the trace,
/// print the schedule and the per-model report.
fn serve(args: &[String]) -> Result<()> {
    let mut cfg = Config::default();
    let mut flashcrowd = false;
    let mut trace = TraceOpts::default();
    parse_kv_flags(args, |flag, val| {
        if flag == "--scenario" && val == "flashcrowd" {
            flashcrowd = true;
            return Ok(true);
        }
        if trace.apply(flag, val)? {
            return Ok(true);
        }
        apply_config_flag(&mut cfg, flag, val)
    })?;

    let (scheduler, ctx) = scheduler_for(cfg.algo, cfg.num_gpus);

    println!(
        "scheduling {} on {} GPUs: {}",
        scheduler.name(),
        cfg.num_gpus,
        ex::common::fmt_rates(&cfg.rates)
    );
    let schedule = scheduler.schedule(&ctx, &cfg.rates)?;
    println!(
        "allocated {}% of cluster over {} gpu-lets:",
        schedule.total_allocated_pct(),
        schedule.lets.len()
    );
    print_schedule(&schedule, "  ");

    // The workload streams into the engine (one pending arrival per
    // model), so `--scale N` can push the offered load arbitrarily high
    // without ever materializing an arrival vector.
    let (mux, n_streams) = if flashcrowd {
        flashcrowd_mux(&cfg.rates, cfg.duration_s, cfg.seed)?
    } else {
        poisson_mux(&cfg.rates, cfg.duration_s, cfg.seed)?
    };
    let kind = if flashcrowd {
        "flash-crowd (3x burst mid-trace)"
    } else {
        "Poisson"
    };
    println!(
        "\nserving a streamed {kind} workload for {}s ({}; {n_streams} arrival streams)...",
        cfg.duration_s,
        cfg.share_mode.name()
    );
    let gt = GroundTruth::default();
    let mut engine = ServingEngine::new(
        &ctx.lm,
        &gt,
        schedule.clone(),
        cfg.duration_s,
        &SimConfig { mode: cfg.share_mode, seed: cfg.seed, ..Default::default() },
    );
    if trace.enabled() {
        engine.set_tracer(Tracer::new(0, TRACE_CAP, trace.sample_n()));
    }
    engine.attach_source(mux);
    engine.run_stream();
    engine.close();
    if trace.enabled() {
        // Single-server run: one tracer, no gauge windows (the
        // per-window series is fleet-tier — `gpulets fleet --gauges`).
        let mut tl = Timeline { sample_n: trace.sample_n(), ..Default::default() };
        engine.tracer_mut().drain_into(&mut tl);
        tl.sort_events();
        trace.write(&tl)?;
    }
    let report = engine.report();
    println!("\n{}", report.table());
    println!(
        "throughput {:.0} req/s, goodput {:.0} req/s, violations {:.2}%",
        report.throughput_rps(),
        report.goodput_rps(),
        report.overall_violation_rate() * 100.0
    );
    let offered: u64 = engine.injected_per_model().iter().sum();
    let (served, dropped) = ModelId::ALL.iter().fold((0u64, 0u64), |acc, &m| {
        report
            .model(m)
            .map_or(acc, |mm| (acc.0 + mm.served, acc.1 + mm.dropped))
    });
    println!(
        "requests: {offered} offered = {served} served + {dropped} dropped{}",
        if served + dropped == offered { " (conserved)" } else { " (LOST!)" }
    );
    let total_asgs: usize = schedule.lets.iter().map(|l| l.assignments.len()).sum();
    println!(
        "engine: {} events processed, peak {} live events \
         (O(active) bound: {n_streams} streams + {total_asgs} assignments + {} gpu-lets)",
        engine.events_processed(),
        engine.peak_live_events(),
        schedule.lets.len(),
    );
    Ok(())
}

/// Fleet-tier serving: plan the configured rates across N nodes, route
/// a streamed Poisson workload through the deterministic front end, and
/// report the merged fleet metrics plus per-node breakdown.
fn fleet(args: &[String]) -> Result<()> {
    let mut cfg = Config::default();
    let mut flashcrowd = false;
    let mut fault_seed: Option<u64> = None;
    let mut fault_episodes = 1usize;
    let mut faults_file: Option<String> = None;
    let mut trace = TraceOpts::default();
    parse_kv_flags(args, |flag, val| {
        if trace.apply(flag, val)? {
            return Ok(true);
        }
        match flag {
        "--nodes" => {
            cfg.fleet.nodes = parse_num::<usize>(flag, val, "an integer >= 1")?.max(1);
            Ok(true)
        }
        "--rebalance" => {
            cfg.fleet.rebalance_s = parse_num(flag, val, "seconds (0 disables)")?;
            Ok(true)
        }
        "--admission" => {
            cfg.admission.mode = AdmissionMode::parse(val)?;
            Ok(true)
        }
        "--faults" => {
            faults_file = Some(val.to_string());
            Ok(true)
        }
        "--fault-seed" => {
            fault_seed = Some(parse_num(flag, val, "an integer")?);
            Ok(true)
        }
        "--fault-episodes" => {
            fault_episodes = parse_num(flag, val, "an integer")?;
            Ok(true)
        }
        "--scenario" if val == "flashcrowd" => {
            flashcrowd = true;
            Ok(true)
        }
        _ => apply_config_flag(&mut cfg, flag, val),
        }
    })?;
    if let Some(spec) = &faults_file {
        // `--faults N` (a bare integer) generates N outage episodes
        // from the run seed; anything else is a [faults] TOML path.
        if let Ok(episodes) = spec.parse::<usize>() {
            cfg.faults =
                FaultPlan::generate(cfg.seed, cfg.fleet.nodes, cfg.duration_s, episodes)?;
        } else {
            let text = std::fs::read_to_string(spec)?;
            cfg.faults =
                FaultPlan::from_toml(&gpulets::util::tomlmini::TomlDoc::parse(&text)?)?;
        }
    } else if let Some(seed) = fault_seed {
        cfg.faults =
            FaultPlan::generate(seed, cfg.fleet.nodes, cfg.duration_s, fault_episodes)?;
    }
    // CLI `--admission degrade` without configured fallbacks degrades
    // everything to the cheapest model rather than shedding it all.
    if cfg.admission.mode == AdmissionMode::Degrade
        && cfg.admission.fallback.iter().all(Option::is_none)
    {
        for m in ModelId::ALL {
            if m != ModelId::Lenet {
                cfg.admission.fallback[m.index()] = Some(ModelId::Lenet);
            }
        }
        println!("(no [admission] fallbacks configured: degrading to lenet)");
    }

    let spec = cfg.fleet;
    let (scheduler, ctx) = scheduler_for(spec.algo, spec.gpus_per_node);
    let planner = FleetPlanner::new(&ctx, scheduler.as_ref(), spec.nodes);
    println!(
        "planning {} nodes x {} GPUs ({}): {}",
        spec.nodes,
        spec.gpus_per_node,
        scheduler.name(),
        ex::common::fmt_rates(&cfg.rates)
    );
    let plan = planner.plan(&cfg.rates)?;
    for (ni, s) in plan.schedules.iter().enumerate() {
        if s.lets.is_empty() {
            println!("node {ni}: idle");
            continue;
        }
        println!(
            "node {ni}: {}% allocated over {} gpu-lets ({})",
            s.total_allocated_pct(),
            s.lets.len(),
            ex::common::fmt_rates(&plan.node_rates[ni]),
        );
        print_schedule(s, "  ");
    }

    let (mux, _) = if flashcrowd {
        flashcrowd_mux(&cfg.rates, cfg.duration_s, cfg.seed)?
    } else {
        poisson_mux(&cfg.rates, cfg.duration_s, cfg.seed)?
    };
    let cadence = if spec.rebalance_s > 0.0 {
        format!("rebalance every {}s", spec.rebalance_s)
    } else {
        "rebalancing off".to_string()
    };
    let kind = if flashcrowd { "flash-crowd" } else { "Poisson" };
    println!(
        "\nrouting a streamed {kind} workload for {}s across {} nodes ({cadence}, \
         admission {})...",
        cfg.duration_s,
        spec.nodes,
        match cfg.admission.mode {
            AdmissionMode::Off => "off",
            AdmissionMode::Shed => "shed",
            AdmissionMode::Degrade => "degrade",
        },
    );
    if !cfg.faults.is_empty() {
        for e in cfg.faults.events() {
            println!("  fault: node {} {:?} at {:.1}s", e.node, e.kind, e.at_s);
        }
    }
    // Serve/measure against the TRUE SLOs (the experiments' convention;
    // `ctx.lm` is the planner's SLO-tightened view).
    let lm = gpulets::perfmodel::LatencyModel::new();
    let gt = GroundTruth::default();
    let fleet_cfg = FleetConfig {
        sim: SimConfig { mode: cfg.share_mode, seed: cfg.seed, ..Default::default() },
        window_s: if spec.rebalance_s > 0.0 { spec.rebalance_s } else { cfg.period_s },
        rebalance: spec.rebalance_s > 0.0,
        trace_cap: if trace.enabled() { TRACE_CAP } else { 0 },
        trace_sample: trace.sample_n(),
        ..Default::default()
    };
    let mut engine = FleetEngine::new(
        &lm,
        &gt,
        planner,
        plan,
        mux,
        cfg.duration_s,
        &fleet_cfg,
    );
    engine.set_admission(cfg.admission.clone());
    engine.set_fault_plan(cfg.faults.clone())?;
    let t0 = std::time::Instant::now();
    engine.run(cfg.duration_s);
    let wall_s = t0.elapsed().as_secs_f64();
    let out = engine.finish();

    println!("\n{}", out.report.table());
    println!(
        "fleet throughput {:.0} req/s, goodput {:.0} req/s, violations {:.2}%, \
         {} rebalances, {} re-plan failures",
        out.report.throughput_rps(),
        out.report.goodput_rps(),
        out.report.overall_violation_rate() * 100.0,
        out.rebalances,
        out.replan_failures,
    );
    println!(
        "admitted SLO attainment {:.2}% (goodput over admitted traffic)",
        out.report.admitted_slo_attainment() * 100.0
    );
    for (ni, r) in out.per_node.iter().enumerate() {
        let (served, dropped) = ModelId::ALL.iter().fold((0u64, 0u64), |acc, &m| {
            r.model(m).map_or(acc, |mm| (acc.0 + mm.served, acc.1 + mm.dropped))
        });
        println!(
            "  node {ni}: {served} served, {dropped} dropped, {:.2}% violations",
            r.overall_violation_rate() * 100.0
        );
    }
    let demand: u64 = out.demand.iter().sum();
    let offered: u64 = out.offered.iter().sum();
    let shed: u64 = out.shed.iter().sum();
    let lost: u64 = out.lost_to_failure().iter().sum();
    let (served, dropped) = out.served_dropped();
    let (served, dropped) =
        (served.iter().sum::<u64>(), dropped.iter().sum::<u64>());
    println!(
        "requests: {demand} demand = {offered} dealt + {shed} shed; \
         {offered} dealt = {served} served + {dropped} dropped + {lost} lost{}",
        if out.conserved() { " (conserved)" } else { " (LOST!)" }
    );
    let degraded: u64 = out.degraded.iter().sum();
    if degraded > 0 {
        println!("  ({degraded} arrivals degraded to their fallback model)");
    }
    let unplaced: u64 = out.unplaced.iter().sum();
    if unplaced > 0 {
        println!("  ({unplaced} arrivals had no fleet placement and were dropped counted)");
    }
    // Manual runs double as measurements (mirrors `gpulets serve`):
    // events/s over the wall clock, the worker count the parallel
    // advance resolved, and the peak-RSS proxies.
    let eps = if wall_s > 0.0 { out.events_processed as f64 / wall_s } else { 0.0 };
    println!(
        "fleet: {} events processed in {wall_s:.2}s ({eps:.0} events/s on {} worker \
         threads), peak {} live events across nodes, peak {} routed-ahead arrivals",
        out.events_processed,
        gpulets::util::par::threads(),
        out.peak_live_events,
        out.peak_routed,
    );
    if trace.enabled() {
        trace.write(&out.timeline)?;
        reconcile_trace(&out);
    }
    Ok(())
}

/// Cross-check the trace's exact event ledger against the fleet's own
/// counters — the two are kept by independent code paths (tracer hooks
/// vs. router/report accounting), so agreement here means the trace is
/// a faithful record of the run, not an approximation of it.
fn reconcile_trace(out: &gpulets::fleet::FleetOutcome) {
    let tl = &out.timeline;
    let (served, dropped) = out.served_dropped();
    let checks: [(&str, u64, u64); 7] = [
        ("deal == dealt", tl.count(EventKind::Deal), out.offered.iter().sum()),
        ("arrival == dealt", tl.count(EventKind::Arrival), out.offered.iter().sum()),
        ("shed", tl.count(EventKind::Shed), out.shed.iter().sum()),
        ("degrade", tl.count(EventKind::Degrade), out.degraded.iter().sum()),
        ("batch-done == served", tl.count(EventKind::BatchDone), served.iter().sum()),
        (
            "drop + timeout == dropped",
            tl.count(EventKind::Drop) + tl.count(EventKind::Timeout),
            dropped.iter().sum(),
        ),
        ("lost", tl.count(EventKind::Lost), out.lost_to_failure().iter().sum()),
    ];
    let mut clean = true;
    for (what, ledger, counter) in checks {
        if ledger != counter {
            println!("  trace ledger MISMATCH: {what}: {ledger} != {counter}");
            clean = false;
        }
    }
    if clean {
        println!("  (trace ledger reconciles exactly with the fleet counters)");
    }
}

/// Real serving on the PJRT CPU runtime (the `real` clock path). Without
/// `--features pjrt` the engine constructor reports the missing runtime.
fn serve_real(args: &[String]) -> Result<()> {
    let mut cfg = Config::default();
    // Modest defaults for CPU execution.
    cfg.rates = [20.0, 5.0, 5.0, 2.0, 5.0];
    cfg.duration_s = 5.0;
    parse_flags(args, &mut cfg)?;

    println!("loading artifacts from {}/ ...", cfg.artifacts_dir);
    let engine = Engine::cpu()?;
    println!("PJRT platform: {} ({} devices)", engine.platform(), engine.device_count());
    let registry = ModelRegistry::load(&engine, &cfg.artifacts_dir)?;
    println!("compiled {} (model, batch) executables", registry.len());

    let pairs: Vec<(ModelId, f64)> = ModelId::ALL
        .iter()
        .map(|&m| (m, cfg.rates[m.index()]))
        .filter(|&(_, r)| r > 0.0)
        .collect();
    let arrivals = generate_arrivals(&pairs, cfg.duration_s, cfg.seed)?;
    println!("serving {} requests over {}s...", arrivals.len(), cfg.duration_s);

    let server = RealServer::new(&registry);
    let outcome = server.serve(&arrivals, cfg.duration_s)?;
    println!("\n{}", outcome.report.table());
    println!(
        "throughput {:.0} req/s, PJRT busy {:.2}s, batches: {:?}",
        outcome.report.throughput_rps(),
        outcome.exec_wall_s,
        outcome.batches
    );
    Ok(())
}
