//! Request-rate monitoring (§4.3: "incoming request rates of each model
//! are tracked with an exponentially-weighted moving average").

use std::collections::BTreeMap;

use crate::models::ModelId;
use crate::util::stats::Ewma;

/// Per-model EWMA rate tracker with windowed counting.
///
/// `observe` records arrivals; `tick(window_s)` folds the window's count
/// into the EWMA and resets the window. `rates()` is what the scheduler
/// consumes each period.
#[derive(Clone, Debug)]
pub struct RateMonitor {
    alpha: f64,
    counts: BTreeMap<ModelId, u64>,
    ewmas: BTreeMap<ModelId, Ewma>,
}

impl RateMonitor {
    pub fn new(alpha: f64) -> Self {
        RateMonitor { alpha, counts: BTreeMap::new(), ewmas: BTreeMap::new() }
    }

    /// Record `n` arrivals for `m` in the current window.
    pub fn observe(&mut self, m: ModelId, n: u64) {
        *self.counts.entry(m).or_insert(0) += n;
    }

    /// Close the window of `window_s` seconds; update EWMAs.
    pub fn tick(&mut self, window_s: f64) {
        assert!(window_s > 0.0);
        for m in ModelId::ALL {
            let count = self.counts.get(&m).copied().unwrap_or(0);
            let rate = count as f64 / window_s;
            self.ewmas
                .entry(m)
                .or_insert_with(|| Ewma::new(self.alpha))
                .update(rate);
        }
        self.counts.clear();
    }

    /// Smoothed rate for one model (0 until the first tick).
    pub fn rate(&self, m: ModelId) -> f64 {
        self.ewmas.get(&m).and_then(|e| e.get()).unwrap_or(0.0)
    }

    /// Smoothed rates for all models, descending by rate (the scheduler
    /// sorts models this way — Algorithm 1 line 2).
    pub fn rates_desc(&self) -> Vec<(ModelId, f64)> {
        let mut v: Vec<(ModelId, f64)> =
            ModelId::ALL.iter().map(|&m| (m, self.rate(m))).collect();
        v.sort_by(|a, b| b.1.total_cmp(&a.1));
        v
    }

    /// True if any model's smoothed rate moved more than `threshold`
    /// (relative) vs `baseline` — the re-scheduling trigger.
    pub fn changed_vs(&self, baseline: &BTreeMap<ModelId, f64>, threshold: f64) -> bool {
        ModelId::ALL.iter().any(|&m| {
            let now = self.rate(m);
            let base = baseline.get(&m).copied().unwrap_or(0.0);
            let denom = base.max(1e-9);
            (now - base).abs() / denom > threshold
        })
    }

    /// Snapshot of the smoothed rates.
    pub fn snapshot(&self) -> BTreeMap<ModelId, f64> {
        ModelId::ALL.iter().map(|&m| (m, self.rate(m))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_produce_rates() {
        let mut mon = RateMonitor::new(1.0); // no smoothing: rate = last window
        mon.observe(ModelId::Lenet, 100);
        mon.tick(2.0);
        assert_eq!(mon.rate(ModelId::Lenet), 50.0);
        assert_eq!(mon.rate(ModelId::Vgg), 0.0);
    }

    #[test]
    fn ewma_smooths() {
        let mut mon = RateMonitor::new(0.5);
        mon.observe(ModelId::Vgg, 100);
        mon.tick(1.0); // rate 100
        mon.tick(1.0); // rate 0 -> ewma 50
        assert!((mon.rate(ModelId::Vgg) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn rates_desc_sorted() {
        let mut mon = RateMonitor::new(1.0);
        mon.observe(ModelId::Lenet, 10);
        mon.observe(ModelId::Vgg, 100);
        mon.tick(1.0);
        let rates = mon.rates_desc();
        assert_eq!(rates[0].0, ModelId::Vgg);
        assert!(rates.windows(2).all(|w| w[0].1 >= w[1].1));
    }

    #[test]
    fn change_detection() {
        let mut mon = RateMonitor::new(1.0);
        mon.observe(ModelId::Lenet, 100);
        mon.tick(1.0);
        let baseline = mon.snapshot();
        assert!(!mon.changed_vs(&baseline, 0.1));
        mon.observe(ModelId::Lenet, 200);
        mon.tick(1.0);
        assert!(mon.changed_vs(&baseline, 0.1));
    }
}
