//! Discrete profile tables — the paper's offline profiling output.
//!
//! The scheduler never queries the analytic model directly at runtime;
//! it reads a `ProfileTable` built once per model over the (batch,
//! partition) grid — exactly the artifact the paper's profiler produces
//! on real gpu-lets. Lookups between grid points are conservative
//! (round batch up, partition down) so scheduling errs on the safe side.
//!
//! Storage is a dense flat array indexed arithmetically
//! (model-major, then batch, then partition — see [`ProfileTable::rows`]
//! for the documented order), not a tree map: every lookup is a couple
//! of table scans over 6-element constant arrays plus one array index,
//! with no pointer chasing and no per-build allocations beyond the one
//! backing vector.

use crate::models::ModelId;
use crate::perfmodel::{LatencyModel, BATCHES};

/// Valid gpu-let sizes in percent (paper §3.2 split ratios + whole GPU).
pub const PARTITIONS: [u32; 6] = [20, 40, 50, 60, 80, 100];

/// Number of profiled batch sizes per model.
const NB: usize = BATCHES.len();
/// Number of profiled partition sizes per model.
const NP: usize = PARTITIONS.len();

/// Index of `b` in [`BATCHES`], if profiled.
#[inline]
fn batch_index(b: u32) -> Option<usize> {
    BATCHES.iter().position(|&x| x == b)
}

/// Index of `p_pct` in [`PARTITIONS`], if profiled (shared with the
/// capacity table, which indexes the same grid).
#[inline]
pub(crate) fn part_index(p_pct: u32) -> Option<usize> {
    PARTITIONS.iter().position(|&x| x == p_pct)
}

/// Profiled latency grid for all models, stored dense.
#[derive(Clone, Debug)]
pub struct ProfileTable {
    /// `latency_ms[(m.index() * NB + batch_idx) * NP + part_idx]`.
    grid: Vec<f64>,
}

impl ProfileTable {
    /// Build by "profiling" the latency substrate over the full grid —
    /// the sim-clock analogue of the paper's offline profiling pass.
    pub fn build(model: &LatencyModel) -> Self {
        let mut grid = Vec::with_capacity(ModelId::ALL.len() * NB * NP);
        for m in ModelId::ALL {
            for &b in &BATCHES {
                for &p in &PARTITIONS {
                    grid.push(model.latency_ms(m, b, p as f64 / 100.0));
                }
            }
        }
        ProfileTable { grid }
    }

    /// Flat index of a (model, batch index, partition index) cell.
    #[inline]
    fn idx(m: ModelId, bi: usize, pi: usize) -> usize {
        (m.index() * NB + bi) * NP + pi
    }

    /// Exact grid lookup.
    pub fn get(&self, m: ModelId, b: u32, p_pct: u32) -> Option<f64> {
        let bi = batch_index(b)?;
        let pi = part_index(p_pct)?;
        Some(self.grid[Self::idx(m, bi, pi)])
    }

    /// Conservative lookup for arbitrary (b, p): round the batch up to
    /// the next profiled size and the partition down to the previous
    /// profiled size. Returns None if b exceeds the profiled maximum or
    /// p is below the smallest profiled partition.
    pub fn latency_ms(&self, m: ModelId, b: u32, p_pct: u32) -> Option<f64> {
        let bi = BATCHES.iter().position(|&x| x >= b)?;
        let pi = PARTITIONS.iter().rposition(|&x| x <= p_pct)?;
        Some(self.grid[Self::idx(m, bi, pi)])
    }

    /// Number of profiled grid points.
    pub fn len(&self) -> usize {
        self.grid.len()
    }

    pub fn is_empty(&self) -> bool {
        self.grid.is_empty()
    }

    /// Dump rows for one model (Fig 3 regeneration): `(batch, partition,
    /// ms)`, read directly from the model's own contiguous block of the
    /// grid (no full-table scan).
    ///
    /// Row order is documented and stable: batches ascending in
    /// [`BATCHES`] order (outer), partitions ascending in [`PARTITIONS`]
    /// order (inner) — i.e. lexicographic in `(batch, partition)`.
    pub fn rows(&self, m: ModelId) -> Vec<(u32, u32, f64)> {
        let block = &self.grid[Self::idx(m, 0, 0)..Self::idx(m, 0, 0) + NB * NP];
        let mut out = Vec::with_capacity(NB * NP);
        for (bi, &b) in BATCHES.iter().enumerate() {
            for (pi, &p) in PARTITIONS.iter().enumerate() {
                out.push((b, p, block[bi * NP + pi]));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> ProfileTable {
        ProfileTable::build(&LatencyModel::new())
    }

    #[test]
    fn full_grid_profiled() {
        let t = table();
        assert_eq!(t.len(), 5 * BATCHES.len() * PARTITIONS.len());
        assert!(!t.is_empty());
    }

    #[test]
    fn exact_lookup_matches_model() {
        let t = table();
        let m = LatencyModel::new();
        let want = m.latency_ms(ModelId::Vgg, 16, 0.6);
        assert_eq!(t.get(ModelId::Vgg, 16, 60).unwrap(), want);
    }

    #[test]
    fn conservative_rounding() {
        let t = table();
        // b=5 rounds up to 8; p=75 rounds down to 60.
        let got = t.latency_ms(ModelId::Resnet, 5, 75).unwrap();
        let want = t.get(ModelId::Resnet, 8, 60).unwrap();
        assert_eq!(got, want);
        // Conservative: must over-estimate the true (b=5, p=0.75) latency.
        let truth = LatencyModel::new().latency_ms(ModelId::Resnet, 5, 0.75);
        assert!(got >= truth);
    }

    #[test]
    fn out_of_range_lookups() {
        let t = table();
        assert!(t.latency_ms(ModelId::Lenet, 64, 100).is_none()); // b too big
        assert!(t.latency_ms(ModelId::Lenet, 1, 10).is_none()); // p too small
        assert!(t.latency_ms(ModelId::Lenet, 1, 100).is_some());
        assert!(t.get(ModelId::Lenet, 3, 100).is_none()); // off-grid batch
        assert!(t.get(ModelId::Lenet, 4, 30).is_none()); // off-grid partition
    }

    #[test]
    fn rows_cover_one_model_in_documented_order() {
        let t = table();
        for m in ModelId::ALL {
            let rows = t.rows(m);
            assert_eq!(rows.len(), BATCHES.len() * PARTITIONS.len());
            assert!(rows.iter().all(|&(_, _, l)| l > 0.0));
            // Lexicographic (batch, partition) and grid-exact.
            let mut i = 0;
            for &b in &BATCHES {
                for &p in &PARTITIONS {
                    assert_eq!(rows[i].0, b);
                    assert_eq!(rows[i].1, p);
                    assert_eq!(rows[i].2, t.get(m, b, p).unwrap());
                    i += 1;
                }
            }
        }
    }
}
