//! Discrete profile tables — the paper's offline profiling output.
//!
//! The scheduler never queries the analytic model directly at runtime;
//! it reads a `ProfileTable` built once per model over the (batch,
//! partition) grid — exactly the artifact the paper's profiler produces
//! on real gpu-lets. Lookups between grid points are conservative
//! (round batch up, partition down) so scheduling errs on the safe side.

use std::collections::BTreeMap;

use crate::models::ModelId;
use crate::perfmodel::{LatencyModel, BATCHES};

/// Valid gpu-let sizes in percent (paper §3.2 split ratios + whole GPU).
pub const PARTITIONS: [u32; 6] = [20, 40, 50, 60, 80, 100];

/// Profiled latency grid for all models.
#[derive(Clone, Debug)]
pub struct ProfileTable {
    /// latency_ms[(model, batch, partition_pct)]
    grid: BTreeMap<(ModelId, u32, u32), f64>,
}

impl ProfileTable {
    /// Build by "profiling" the latency substrate over the full grid —
    /// the sim-clock analogue of the paper's offline profiling pass.
    pub fn build(model: &LatencyModel) -> Self {
        let mut grid = BTreeMap::new();
        for m in ModelId::ALL {
            for &b in &BATCHES {
                for &p in &PARTITIONS {
                    grid.insert((m, b, p), model.latency_ms(m, b, p as f64 / 100.0));
                }
            }
        }
        ProfileTable { grid }
    }

    /// Exact grid lookup.
    pub fn get(&self, m: ModelId, b: u32, p_pct: u32) -> Option<f64> {
        self.grid.get(&(m, b, p_pct)).copied()
    }

    /// Conservative lookup for arbitrary (b, p): round the batch up to
    /// the next profiled size and the partition down to the previous
    /// profiled size. Returns None if b exceeds the profiled maximum or
    /// p is below the smallest profiled partition.
    pub fn latency_ms(&self, m: ModelId, b: u32, p_pct: u32) -> Option<f64> {
        let b_up = BATCHES.iter().copied().find(|&x| x >= b)?;
        let p_down = PARTITIONS.iter().copied().rev().find(|&x| x <= p_pct)?;
        self.get(m, b_up, p_down)
    }

    /// Number of profiled grid points.
    pub fn len(&self) -> usize {
        self.grid.len()
    }

    pub fn is_empty(&self) -> bool {
        self.grid.is_empty()
    }

    /// Dump rows for one model (Fig 3 regeneration): (batch, partition, ms).
    pub fn rows(&self, m: ModelId) -> Vec<(u32, u32, f64)> {
        self.grid
            .iter()
            .filter(|((id, _, _), _)| *id == m)
            .map(|(&(_, b, p), &l)| (b, p, l))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> ProfileTable {
        ProfileTable::build(&LatencyModel::new())
    }

    #[test]
    fn full_grid_profiled() {
        let t = table();
        assert_eq!(t.len(), 5 * BATCHES.len() * PARTITIONS.len());
        assert!(!t.is_empty());
    }

    #[test]
    fn exact_lookup_matches_model() {
        let t = table();
        let m = LatencyModel::new();
        let want = m.latency_ms(ModelId::Vgg, 16, 0.6);
        assert_eq!(t.get(ModelId::Vgg, 16, 60).unwrap(), want);
    }

    #[test]
    fn conservative_rounding() {
        let t = table();
        // b=5 rounds up to 8; p=75 rounds down to 60.
        let got = t.latency_ms(ModelId::Resnet, 5, 75).unwrap();
        let want = t.get(ModelId::Resnet, 8, 60).unwrap();
        assert_eq!(got, want);
        // Conservative: must over-estimate the true (b=5, p=0.75) latency.
        let truth = LatencyModel::new().latency_ms(ModelId::Resnet, 5, 0.75);
        assert!(got >= truth);
    }

    #[test]
    fn out_of_range_lookups() {
        let t = table();
        assert!(t.latency_ms(ModelId::Lenet, 64, 100).is_none()); // b too big
        assert!(t.latency_ms(ModelId::Lenet, 1, 10).is_none()); // p too small
        assert!(t.latency_ms(ModelId::Lenet, 1, 100).is_some());
    }

    #[test]
    fn rows_cover_one_model() {
        let t = table();
        let rows = t.rows(ModelId::Lenet);
        assert_eq!(rows.len(), BATCHES.len() * PARTITIONS.len());
        assert!(rows.iter().all(|&(_, _, l)| l > 0.0));
    }
}
