//! The latency function `L(b, p)` and derived scheduling quantities.
//!
//! `L(b, p) = t0 + w1*b / min(p, need(b))`
//!
//! * For `p >= need(b)` latency is flat — extra resource is wasted
//!   (Fig 3's flat region; the motivation for spatial partitioning).
//! * For `p < need(b)` latency scales as `1/p` (the steep region).
//!
//! Derived quantities implemented here, used by every scheduler:
//! * `max_rate(p)` — the highest request rate a gpu-let of size `p` can
//!   sustain for the model within its SLO (squishy bin-packing math:
//!   batch-collection time + execution time <= SLO, execution <= collection
//!   for stability).
//! * `best_batch(p)` — the batch size achieving `max_rate(p)`.
//! * `knee(rates)` — Kneedle-style most-cost-effective partition
//!   (`MaxEfficientPartition` in Algorithm 1).

use crate::models::{ModelId, ModelProfile};

/// Analytic latency model over the full model catalog.
///
/// `slo_scale` tightens the SLOs this model reports: schedulers plan
/// against `slo * slo_scale` (< 1) so the deployed schedule keeps
/// headroom for Poisson burstiness and residual interference, while
/// the simulator/metrics measure against the true SLO (scale 1.0).
#[derive(Clone, Debug)]
pub struct LatencyModel {
    profiles: [ModelProfile; 5],
    slo_scale: f64,
}

impl Default for LatencyModel {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyModel {
    pub fn new() -> Self {
        LatencyModel { profiles: crate::models::catalog(), slo_scale: 1.0 }
    }

    /// Planning-view model with tightened SLOs (see `SchedCtx`).
    pub fn with_slo_scale(slo_scale: f64) -> Self {
        assert!(slo_scale > 0.0 && slo_scale <= 1.0);
        LatencyModel { profiles: crate::models::catalog(), slo_scale }
    }

    pub fn profile(&self, m: ModelId) -> &ModelProfile {
        &self.profiles[m.index()]
    }

    /// Batch-`b` execution latency (ms) on a gpu-let of size `p` (0..=1].
    pub fn latency_ms(&self, m: ModelId, b: u32, p: f64) -> f64 {
        let prof = self.profile(m);
        assert!(b >= 1, "batch must be >= 1");
        assert!(p > 0.0 && p <= 1.0, "partition fraction out of (0,1]: {p}");
        let eff = p.min(prof.need(b));
        prof.t0_ms + prof.w1_ms * b as f64 / eff
    }

    /// SLO bound for the model (ms), scaled by the planning margin.
    pub fn slo_ms(&self, m: ModelId) -> f64 {
        self.profile(m).slo_ms * self.slo_scale
    }

    /// Max sustainable rate (req/s) for model `m` alone on a gpu-let of
    /// size `p`, with the batch that achieves it. Returns None if even
    /// batch 1 cannot meet the SLO.
    ///
    /// Squishy bin-packing feasibility for batch `b` at rate `r`:
    ///   collect = b/r,  exec = L(b,p)
    ///   (i) exec <= collect        (stability: drain as fast as we fill)
    ///   (ii) collect + exec <= SLO (worst-case first-request latency)
    /// The max rate for a given b is r = b / max(L, SLO - L), feasible
    /// iff 2L <= SLO or L <= SLO - L ... i.e. L <= SLO/2 guarantees both
    /// with r = b/L; for SLO/2 < L < SLO the rate is throttled to
    /// r = b/L but collect (b/r = L) + L = 2L > SLO violates (ii), so
    /// the feasibility cutoff is L <= SLO/2.
    pub fn max_rate(&self, m: ModelId, p: f64) -> Option<(f64, u32)> {
        let slo = self.slo_ms(m);
        let mut best: Option<(f64, u32)> = None;
        for b in super::BATCHES {
            let l = self.latency_ms(m, b, p);
            if 2.0 * l > slo {
                continue;
            }
            // At rate r = b/collect with collect = SLO - L >= L, both
            // constraints hold; the throughput-optimal choice is
            // collect = L (duty cycle = exec time), r = b / L.
            let r = b as f64 / l * 1000.0; // L in ms -> req/s
            if best.is_none_or(|(br, _)| r > br) {
                best = Some((r, b));
            }
        }
        best
    }

    /// The largest batch whose latency meets `budget_ms` on size `p`
    /// (Algorithm 1 line 27: argmax_b L(b, p) <= budget).
    pub fn max_batch_within(&self, m: ModelId, p: f64, budget_ms: f64) -> Option<u32> {
        let mut best = None;
        for b in super::BATCHES {
            if self.latency_ms(m, b, p) <= budget_ms {
                best = Some(b);
            }
        }
        best
    }

    /// Affordable-rate curve over the given partition sizes (percent).
    pub fn rate_curve(&self, m: ModelId, sizes_pct: &[u32]) -> Vec<(u32, f64)> {
        sizes_pct
            .iter()
            .map(|&s| {
                let r = self.max_rate(m, s as f64 / 100.0).map_or(0.0, |(r, _)| r);
                (s, r)
            })
            .collect()
    }
}

/// `MaxEfficientPartition`: the knee of the affordable-rate curve —
/// the size where the discrete curvature is most negative, i.e. where
/// the marginal rate gain collapses (Fig 8: "the knee, where the
/// curvature has the local maximum, implies the most cost-effective
/// sweet spot").
///
/// `curve` is (size_pct, rate) sorted ascending by size; infeasible
/// sizes carry rate 0 and are excluded. If the feasible curve never
/// bends (convex/linear — the model keeps gaining from more resource),
/// the whole GPU is the cost-effective choice.
pub fn knee(curve: &[(u32, f64)]) -> u32 {
    debug_assert!(!curve.is_empty());
    let pts: Vec<(f64, f64)> = curve
        .iter()
        .filter(|&&(_, r)| r > 0.0)
        .map(|&(s, r)| (s as f64, r))
        .collect();
    let fallback = curve[curve.len() - 1].0;
    if pts.len() < 3 {
        // Too few feasible points to measure curvature: take the best.
        return pts
            .iter()
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .map_or(fallback, |&(s, _)| s as u32);
    }
    let slope = |a: (f64, f64), b: (f64, f64)| (b.1 - a.1) / (b.0 - a.0);
    let mut best: Option<(u32, f64)> = None; // (size, curvature)
    for i in 1..pts.len() - 1 {
        let curv = slope(pts[i], pts[i + 1]) - slope(pts[i - 1], pts[i]);
        if curv < -1e-9 && best.is_none_or(|(_, c)| curv < c) {
            best = Some((pts[i].0 as u32, curv));
        }
    }
    // Flat tail with no interior bend: the first point where the curve
    // stops improving; otherwise (still gaining at the top) take 100%.
    best.map_or_else(
        || {
            for w in pts.windows(2) {
                if w[1].1 <= w[0].1 * (1.0 + 1e-9) {
                    return w[0].0 as u32;
                }
            }
            pts[pts.len() - 1].0 as u32
        },
        |(s, _)| s,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelId;

    fn lm() -> LatencyModel {
        LatencyModel::new()
    }

    #[test]
    fn latency_monotone_decreasing_in_p() {
        let m = lm();
        for id in ModelId::ALL {
            for b in super::super::BATCHES {
                let mut prev = f64::INFINITY;
                for pct in [20, 40, 50, 60, 80, 100] {
                    let l = m.latency_ms(id, b, pct as f64 / 100.0);
                    assert!(l <= prev + 1e-12, "{id:?} b={b} p={pct}: {l} > {prev}");
                    prev = l;
                }
            }
        }
    }

    #[test]
    fn latency_monotone_increasing_in_b() {
        let m = lm();
        for id in ModelId::ALL {
            for pct in [20, 50, 100] {
                let mut prev = 0.0;
                for b in super::super::BATCHES {
                    let l = m.latency_ms(id, b, pct as f64 / 100.0);
                    assert!(l > prev, "{id:?} p={pct} b={b}");
                    prev = l;
                }
            }
        }
    }

    #[test]
    fn small_batch_flat_beyond_knee() {
        // Fig 3: with batch 1, extra resource beyond the knee is wasted.
        let m = lm();
        let l50 = m.latency_ms(ModelId::Lenet, 1, 0.5);
        let l100 = m.latency_ms(ModelId::Lenet, 1, 1.0);
        assert!((l50 - l100).abs() < 1e-12, "lenet b=1 should be flat 50->100%");
        // Large batch on VGG keeps improving up to 100%.
        let v50 = m.latency_ms(ModelId::Vgg, 32, 0.5);
        let v100 = m.latency_ms(ModelId::Vgg, 32, 1.0);
        assert!(v50 > v100 * 1.5, "vgg b=32 must gain from more resource");
    }

    #[test]
    fn b32_full_gpu_hits_half_slo() {
        let m = lm();
        for id in ModelId::ALL {
            let l = m.latency_ms(id, 32, 1.0);
            assert!((l - m.slo_ms(id) / 2.0).abs() < 1e-9, "{id:?}");
        }
    }

    #[test]
    fn max_rate_monotone_in_p() {
        let m = lm();
        for id in ModelId::ALL {
            let mut prev = 0.0;
            for pct in [20, 40, 50, 60, 80, 100] {
                let r = m.max_rate(id, pct as f64 / 100.0).map_or(0.0, |(r, _)| r);
                assert!(r >= prev - 1e-9, "{id:?} p={pct}");
                prev = r;
            }
            assert!(prev > 0.0, "{id:?} must be servable at p=1");
        }
    }

    #[test]
    fn max_rate_prefers_bigger_batches_on_bigger_lets() {
        let m = lm();
        let (_, b_small) = m.max_rate(ModelId::Vgg, 0.2).unwrap();
        let (_, b_big) = m.max_rate(ModelId::Vgg, 1.0).unwrap();
        assert!(b_big > b_small, "b at 100% ({b_big}) vs 20% ({b_small})");
        assert_eq!(b_big, 32); // calibration makes b=32 optimal at p=1
    }

    #[test]
    fn max_batch_within_budget() {
        let m = lm();
        let slo = m.slo_ms(ModelId::Vgg);
        let b = m.max_batch_within(ModelId::Vgg, 1.0, slo / 2.0).unwrap();
        assert_eq!(b, 32);
        assert!(m.max_batch_within(ModelId::Vgg, 0.2, 0.1).is_none());
    }

    #[test]
    fn knee_detection_on_synthetic_curves() {
        // Saturating curve: knee where the slope collapses.
        let curve =
            vec![(20, 100.0), (40, 190.0), (50, 200.0), (60, 202.0), (80, 203.0), (100, 204.0)];
        assert_eq!(knee(&curve), 40);
        // Superlinear curve: keeps gaining — take the whole GPU.
        let sup = vec![(20, 0.0), (40, 40.0), (50, 60.0), (60, 90.0), (80, 160.0), (100, 300.0)];
        assert_eq!(knee(&sup), 100);
        // All-zero: only a whole GPU could ever help.
        let zero: Vec<(u32, f64)> = [20, 40, 100].iter().map(|&s| (s, 0.0)).collect();
        assert_eq!(knee(&zero), 100);
        // Hard saturation: flat tail with no interior bend.
        let flat =
            vec![(20, 0.0), (40, 500.0), (50, 500.0), (60, 500.0), (80, 500.0), (100, 500.0)];
        assert_eq!(knee(&flat), 40);
    }

    #[test]
    fn knee_small_for_lenet_large_for_vgg() {
        let m = lm();
        let sizes = [20, 40, 50, 60, 80, 100];
        let kl = knee(&m.rate_curve(ModelId::Lenet, &sizes));
        let kv = knee(&m.rate_curve(ModelId::Vgg, &sizes));
        assert!(kl <= 40, "lenet knee {kl}");
        assert!(kv >= 50, "vgg knee {kv}");
        assert!(kv > kl, "vgg knee {kv} <= lenet knee {kl}");
    }

    #[test]
    #[should_panic]
    fn rejects_zero_partition() {
        lm().latency_ms(ModelId::Lenet, 1, 0.0);
    }
}
