//! Performance modeling: the profiled latency function `L(b, p)`, the
//! knee/affordable-rate analysis behind `MaxEfficientPartition`, profile
//! tables, and the EWMA request-rate monitor.
//!
//! The paper profiles each model offline on real 2080 Ti gpu-lets; our
//! substrate is the calibrated analytic model in `latency` (DESIGN.md
//! §3), which the discrete `ProfileTable` snapshots exactly like the
//! paper's offline profiling pass would.

pub mod capacity;
pub mod latency;
pub mod profile_table;
pub mod rate;

pub use capacity::CapacityTable;
pub use latency::LatencyModel;
pub use profile_table::ProfileTable;
pub use rate::RateMonitor;

/// Batch sizes the paper profiles (Fig 3) and serves (Table 4 cap).
pub const BATCHES: [u32; 6] = [1, 2, 4, 8, 16, 32];

/// Largest batch the server will form (Table 4: "we use the batch size
/// of 32, since larger engenders the SLO unrealistically long").
pub const MAX_BATCH: u32 = 32;
