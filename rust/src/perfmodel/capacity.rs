//! Memoized scheduling capacities — the `(max_rate, best_batch)` table
//! every scheduler inner loop reads instead of rescanning `BATCHES`.
//!
//! `LatencyModel::max_rate` and `max_batch_within` scan all six batch
//! sizes per call; the schedulers call them inside feasibility loops
//! that run per model × per candidate gpu-let × per placement round, so
//! a single 1,023-scenario sweep re-derives the same ~30 grid values
//! millions of times. A `CapacityTable` computes each once per
//! `SchedCtx` over the (model, partition) grid — like `ProfileTable`,
//! it is the artifact an offline profiling pass would hand the online
//! planner. Values are produced by the exact same `LatencyModel` calls
//! the schedulers used to make inline (identical floating-point
//! results, equivalence-tested in `tests/perf_refactor_equivalence.rs`).

use crate::models::ModelId;
use crate::perfmodel::latency::knee;
use crate::perfmodel::profile_table::{part_index, PARTITIONS};
use crate::perfmodel::LatencyModel;

const NP: usize = PARTITIONS.len();

/// Precomputed per-(model, partition) scheduling capacities.
#[derive(Clone, Debug)]
pub struct CapacityTable {
    /// `LatencyModel::max_rate(m, p)` per grid cell: None = the model
    /// cannot meet its SLO on that partition even at batch 1.
    rate: [[Option<(f64, u32)>; NP]; 5],
    /// `LatencyModel::max_batch_within(m, p, slo/2)` per grid cell —
    /// the Algorithm-1 line 27 batch pick for a solo duty cycle.
    half_slo_batch: [[Option<u32>; NP]; 5],
    /// `MaxEfficientPartition`: knee of the affordable-rate curve.
    knees: [u32; 5],
}

impl CapacityTable {
    /// Build over the full (model, partition) grid.
    pub fn build(lm: &LatencyModel) -> Self {
        let mut rate = [[None; NP]; 5];
        let mut half_slo_batch = [[None; NP]; 5];
        let mut knees = [0u32; 5];
        for m in ModelId::ALL {
            for (pi, &pct) in PARTITIONS.iter().enumerate() {
                let p = pct as f64 / 100.0;
                rate[m.index()][pi] = lm.max_rate(m, p);
                half_slo_batch[m.index()][pi] =
                    lm.max_batch_within(m, p, lm.slo_ms(m) / 2.0);
            }
            let curve: Vec<(u32, f64)> = PARTITIONS
                .iter()
                .enumerate()
                .map(|(pi, &pct)| (pct, rate[m.index()][pi].map_or(0.0, |(r, _)| r)))
                .collect();
            knees[m.index()] = knee(&curve);
        }
        CapacityTable { rate, half_slo_batch, knees }
    }

    /// Memoized `max_rate`. Outer `None` = `size_pct` is not a grid
    /// size (callers fall back to the latency model); inner `None` =
    /// infeasible even at batch 1.
    pub fn lookup_rate(&self, m: ModelId, size_pct: u32) -> Option<Option<(f64, u32)>> {
        part_index(size_pct).map(|pi| self.rate[m.index()][pi])
    }

    /// Memoized `max_batch_within(m, p, slo/2)`; outer/inner `None` as
    /// in [`CapacityTable::lookup_rate`].
    pub fn lookup_half_slo_batch(&self, m: ModelId, size_pct: u32) -> Option<Option<u32>> {
        part_index(size_pct).map(|pi| self.half_slo_batch[m.index()][pi])
    }

    /// `MaxEfficientPartition` (Algorithm 1): knee of the model's
    /// affordable-rate curve over the partition grid.
    pub fn knee_pct(&self, m: ModelId) -> u32 {
        self.knees[m.index()]
    }

    /// The memoized affordable-rate curve (infeasible cells carry 0.0),
    /// in ascending partition order — same shape as
    /// `LatencyModel::rate_curve(m, &PARTITIONS)`.
    pub fn rate_curve(&self, m: ModelId) -> Vec<(u32, f64)> {
        PARTITIONS
            .iter()
            .enumerate()
            .map(|(pi, &pct)| (pct, self.rate[m.index()][pi].map_or(0.0, |(r, _)| r)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_matches_latency_model() {
        let lm = LatencyModel::new();
        let cap = CapacityTable::build(&lm);
        for m in ModelId::ALL {
            for &pct in &PARTITIONS {
                let p = pct as f64 / 100.0;
                assert_eq!(cap.lookup_rate(m, pct).unwrap(), lm.max_rate(m, p));
                assert_eq!(
                    cap.lookup_half_slo_batch(m, pct).unwrap(),
                    lm.max_batch_within(m, p, lm.slo_ms(m) / 2.0)
                );
            }
            assert_eq!(cap.knee_pct(m), knee(&lm.rate_curve(m, &PARTITIONS)));
            assert_eq!(cap.rate_curve(m), lm.rate_curve(m, &PARTITIONS));
        }
    }

    #[test]
    fn off_grid_sizes_report_none() {
        let cap = CapacityTable::build(&LatencyModel::new());
        assert!(cap.lookup_rate(ModelId::Vgg, 30).is_none());
        assert!(cap.lookup_half_slo_batch(ModelId::Vgg, 99).is_none());
        assert!(cap.lookup_rate(ModelId::Vgg, 100).is_some());
    }
}
