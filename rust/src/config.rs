//! Serving/experiment configuration, loaded from TOML
//! (`configs/*.toml`) or built programmatically.

use std::path::Path;

use crate::error::Result;
use crate::fleet::{AdmissionMode, AdmissionSpec, FleetSpec};
use crate::gpu::ShareMode;
use crate::models::ModelId;
use crate::util::tomlmini::TomlDoc;
use crate::workload::FaultPlan;

/// Which scheduling algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    Gpulet,
    GpuletInt,
    Sbp,
    SbpPart,
    Selftune,
    Ideal,
    Spacetime,
}

impl Algo {
    pub fn parse(s: &str) -> Result<Algo> {
        Ok(match s {
            "gpulet" => Algo::Gpulet,
            "gpulet+int" | "gpulet_int" => Algo::GpuletInt,
            "sbp" => Algo::Sbp,
            "sbp+part" | "sbp_part" => Algo::SbpPart,
            "selftune" => Algo::Selftune,
            "ideal" => Algo::Ideal,
            "spacetime" => Algo::Spacetime,
            other => {
                return Err(crate::error::Error::parse(format!(
                    "unknown scheduler {other:?} \
                     (gpulet|gpulet+int|sbp|sbp+part|selftune|ideal|spacetime)"
                )))
            }
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Algo::Gpulet => "gpulet",
            Algo::GpuletInt => "gpulet+int",
            Algo::Sbp => "sbp",
            Algo::SbpPart => "sbp+part",
            Algo::Selftune => "selftune",
            Algo::Ideal => "ideal",
            Algo::Spacetime => "spacetime",
        }
    }

    /// Instantiate the scheduler this algo names — the one
    /// `Algo`-to-scheduler mapping, shared by the CLI (`--algo`), the
    /// fleet planner, and the experiment harnesses. The instance's own
    /// `Scheduler::interference_aware()` says whether its `SchedCtx`
    /// needs the fitted interference model.
    pub fn scheduler(self) -> Box<dyn crate::sched::Scheduler> {
        use crate::sched::{
            ElasticPartitioning, GuidedSelfTuning, IdealScheduler, SpaceTimeScheduler,
            SquishyBinPacking,
        };
        match self {
            Algo::Gpulet => Box::new(ElasticPartitioning::gpulet()),
            Algo::GpuletInt => Box::new(ElasticPartitioning::gpulet_int()),
            Algo::Sbp => Box::new(SquishyBinPacking::baseline()),
            Algo::SbpPart => Box::new(SquishyBinPacking::with_even_partitioning()),
            Algo::Selftune => Box::new(GuidedSelfTuning),
            Algo::Ideal => Box::new(IdealScheduler),
            Algo::Spacetime => Box::new(SpaceTimeScheduler::combined()),
        }
    }
}

/// Full serving configuration (Table 3 defaults).
#[derive(Clone, Debug)]
pub struct Config {
    /// Number of physical GPUs (paper: 4x RTX 2080 Ti).
    pub num_gpus: usize,
    pub algo: Algo,
    pub share_mode: ShareMode,
    /// Offered rates (req/s) per model.
    pub rates: [f64; 5],
    /// Trace duration (s).
    pub duration_s: f64,
    pub seed: u64,
    /// Scheduling period (s) for the adaptive server.
    pub period_s: f64,
    /// Background reorganization latency (s).
    pub reorg_s: f64,
    /// Artifact directory for the real runtime.
    pub artifacts_dir: String,
    /// Fleet topology (`[fleet]` section; defaults follow the
    /// single-server settings: `gpus_per_node` = `gpu.count`, `algo` =
    /// `sched.algo`, `rebalance_s` = `sched.period_s`).
    pub fleet: FleetSpec,
    /// Admission policy (`[admission]` section: `mode`, `headroom`,
    /// `fallback.<model> = "<cheaper model>"`); default off.
    pub admission: AdmissionSpec,
    /// Scripted node faults (`[faults]` section,
    /// `events = ["down@12.5:0", ...]`); default none.
    pub faults: FaultPlan,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            num_gpus: 4,
            algo: Algo::GpuletInt,
            share_mode: ShareMode::Partitioned,
            rates: [50.0; 5],
            duration_s: 30.0,
            seed: 42,
            period_s: 20.0,
            reorg_s: 12.0,
            artifacts_dir: "artifacts".into(),
            fleet: FleetSpec::default(),
            admission: AdmissionSpec::default(),
            faults: FaultPlan::none(),
        }
    }
}

impl Config {
    /// Load from a TOML file; missing keys fall back to defaults.
    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Config> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = Config::default();
        cfg.num_gpus = doc.i64_or("gpu.count", cfg.num_gpus as i64)? as usize;
        cfg.algo = Algo::parse(&doc.str_or("sched.algo", cfg.algo.name())?)?;
        cfg.share_mode = match doc.str_or("gpu.share_mode", "partitioned")?.as_str() {
            "temporal" => ShareMode::TemporalOnly,
            "mps-default" => ShareMode::MpsDefault,
            _ => ShareMode::Partitioned,
        };
        cfg.duration_s = doc.f64_or("workload.duration_s", cfg.duration_s)?;
        cfg.seed = doc.i64_or("workload.seed", cfg.seed as i64)? as u64;
        cfg.period_s = doc.f64_or("sched.period_s", cfg.period_s)?;
        cfg.reorg_s = doc.f64_or("sched.reorg_s", cfg.reorg_s)?;
        cfg.artifacts_dir = doc.str_or("runtime.artifacts_dir", &cfg.artifacts_dir)?;
        cfg.fleet = FleetSpec {
            nodes: doc.i64_or("fleet.nodes", 1)?.max(1) as usize,
            gpus_per_node: doc
                .i64_or("fleet.gpus_per_node", cfg.num_gpus as i64)?
                .max(1) as usize,
            algo: Algo::parse(&doc.str_or("fleet.algo", cfg.algo.name())?)?,
            rebalance_s: doc.f64_or("fleet.rebalance_s", cfg.period_s)?,
        };
        for (name, v) in doc.keys_under("rates") {
            let m = ModelId::parse(name)?;
            cfg.rates[m.index()] = v.as_f64()?;
        }
        cfg.admission.mode =
            AdmissionMode::parse(&doc.str_or("admission.mode", "off")?)?;
        cfg.admission.headroom = doc.f64_or("admission.headroom", cfg.admission.headroom)?;
        if !(cfg.admission.headroom.is_finite()
            && cfg.admission.headroom > 0.0
            && cfg.admission.headroom <= 1.0)
        {
            return Err(crate::error::Error::parse(format!(
                "admission.headroom must be in (0, 1], got {}",
                cfg.admission.headroom
            )));
        }
        for (name, v) in doc.keys_under("admission.fallback") {
            let from = ModelId::parse(name)?;
            let to = ModelId::parse(v.as_str()?)?;
            if from == to {
                return Err(crate::error::Error::parse(format!(
                    "admission.fallback.{name} maps {from} to itself"
                )));
            }
            cfg.admission.fallback[from.index()] = Some(to);
        }
        cfg.faults = FaultPlan::from_toml(&doc)?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_testbed() {
        let c = Config::default();
        assert_eq!(c.num_gpus, 4);
        assert_eq!(c.period_s, 20.0);
        assert_eq!(c.algo, Algo::GpuletInt);
    }

    #[test]
    fn parses_full_config() {
        let c = Config::parse(
            r#"
[gpu]
count = 2
share_mode = "temporal"
[sched]
algo = "sbp"
period_s = 10.0
[workload]
duration_s = 60.0
seed = 7
[rates]
lenet = 100.0
vgg = 25.0
"#,
        )
        .unwrap();
        assert_eq!(c.num_gpus, 2);
        assert_eq!(c.algo, Algo::Sbp);
        assert_eq!(c.share_mode, ShareMode::TemporalOnly);
        assert_eq!(c.duration_s, 60.0);
        assert_eq!(c.rates[ModelId::Lenet.index()], 100.0);
        assert_eq!(c.rates[ModelId::Vgg.index()], 25.0);
        assert_eq!(c.rates[ModelId::Resnet.index()], 50.0); // default
    }

    #[test]
    fn fleet_section_parses_with_single_server_defaults() {
        // No [fleet] section: one node shaped like the configured server.
        let c = Config::parse("[gpu]\ncount = 2\n[sched]\nalgo = \"sbp\"\n").unwrap();
        assert_eq!(c.fleet.nodes, 1);
        assert_eq!(c.fleet.gpus_per_node, 2);
        assert_eq!(c.fleet.algo, Algo::Sbp);
        assert_eq!(c.fleet.rebalance_s, c.period_s);
        // Explicit [fleet] section overrides each field.
        let c = Config::parse(
            r#"
[gpu]
count = 4
[fleet]
nodes = 16
gpus_per_node = 8
algo = "gpulet"
rebalance_s = 5.0
"#,
        )
        .unwrap();
        assert_eq!(
            c.fleet,
            FleetSpec { nodes: 16, gpus_per_node: 8, algo: Algo::Gpulet, rebalance_s: 5.0 }
        );
        // Degenerate node counts clamp to 1 instead of panicking later.
        let c = Config::parse("[fleet]\nnodes = 0\n").unwrap();
        assert_eq!(c.fleet.nodes, 1);
    }

    #[test]
    fn admission_and_faults_sections_parse() {
        // Absent sections: gate off, no faults.
        let c = Config::parse("[gpu]\ncount = 4\n").unwrap();
        assert_eq!(c.admission.mode, AdmissionMode::Off);
        assert!(c.faults.is_empty());
        let c = Config::parse(
            r#"
[admission]
mode = "degrade"
headroom = 0.8
[admission.fallback]
vgg = "lenet"
resnet = "lenet"
[faults]
events = ["down@12.5:0", "up@30.0:0"]
"#,
        )
        .unwrap();
        assert_eq!(c.admission.mode, AdmissionMode::Degrade);
        assert_eq!(c.admission.headroom, 0.8);
        assert_eq!(c.admission.fallback[ModelId::Vgg.index()], Some(ModelId::Lenet));
        assert_eq!(c.admission.fallback[ModelId::Resnet.index()], Some(ModelId::Lenet));
        assert_eq!(c.admission.fallback[ModelId::Lenet.index()], None);
        assert_eq!(c.faults.events().len(), 2);
        // Self-fallback, bad mode, and out-of-range headroom all error.
        assert!(Config::parse("[admission.fallback]\nvgg = \"vgg\"\n").is_err());
        assert!(Config::parse("[admission]\nmode = \"maybe\"\n").is_err());
        assert!(Config::parse("[admission]\nheadroom = 1.5\n").is_err());
        assert!(Config::parse("[admission]\nheadroom = 0.0\n").is_err());
    }

    #[test]
    fn algo_names_roundtrip() {
        for a in [
            Algo::Gpulet,
            Algo::GpuletInt,
            Algo::Sbp,
            Algo::SbpPart,
            Algo::Selftune,
            Algo::Ideal,
            Algo::Spacetime,
        ] {
            assert_eq!(Algo::parse(a.name()).unwrap(), a);
        }
        assert!(Algo::parse("nexus").is_err());
    }
}
