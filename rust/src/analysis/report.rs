//! Lint findings and the human/JSON report renderers.

use crate::util::json::{obj, Json};

/// One rule violation at a `file:line` span.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    /// Rule id (`no-hash-iter`, `total-cmp-sorts`, …).
    pub rule: &'static str,
    /// Repo-relative path with forward slashes (e.g. `src/sched/sbp.rs`).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub message: String,
}

impl Finding {
    pub fn new(rule: &'static str, file: &str, line: usize, message: impl Into<String>) -> Self {
        Finding { rule, file: file.to_string(), line, message: message.into() }
    }

    /// The `file:line` span string used in both report forms.
    pub fn span(&self) -> String {
        format!("{}:{}", self.file, self.line)
    }
}

/// The outcome of a full lint run, after the allowlist is applied.
#[derive(Clone, Debug, Default)]
pub struct LintReport {
    /// Findings NOT covered by the allowlist — nonempty means exit 1.
    pub findings: Vec<Finding>,
    /// Findings suppressed by allowlist entries.
    pub suppressed: usize,
    /// Allowlist entries whose budget exceeds the current finding count
    /// (`(rule, file, allowed, found)`) — candidates for tightening.
    pub slack: Vec<(String, String, usize, usize)>,
    /// Allowlist entries that matched nothing at all — stale pins.
    pub stale: Vec<(String, String)>,
    /// Files scanned.
    pub files_scanned: usize,
}

impl LintReport {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Human-readable report: one `file:line [rule] message` per
    /// finding, then the suppression/slack summary.
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!("{} [{}] {}\n", f.span(), f.rule, f.message));
        }
        for (rule, file, allowed, found) in &self.slack {
            out.push_str(&format!(
                "note: allowlist slack: [{rule}] {file} allows {allowed}, found {found} \
                 — tighten the count\n"
            ));
        }
        for (rule, file) in &self.stale {
            out.push_str(&format!(
                "note: stale allowlist entry: [{rule}] {file} matched no findings\n"
            ));
        }
        out.push_str(&format!(
            "lint: {} file(s), {} finding(s), {} suppressed by allowlist\n",
            self.files_scanned,
            self.findings.len(),
            self.suppressed
        ));
        out
    }

    /// Machine-readable report via `util::json` (BTreeMap-backed, so
    /// output is deterministic).
    pub fn render_json(&self) -> String {
        let findings: Vec<Json> = self
            .findings
            .iter()
            .map(|f| {
                obj(vec![
                    ("rule", Json::Str(f.rule.to_string())),
                    ("file", Json::Str(f.file.clone())),
                    ("line", Json::Num(f.line as f64)),
                    ("message", Json::Str(f.message.clone())),
                ])
            })
            .collect();
        let slack: Vec<Json> = self
            .slack
            .iter()
            .map(|(rule, file, allowed, found)| {
                obj(vec![
                    ("rule", Json::Str(rule.clone())),
                    ("file", Json::Str(file.clone())),
                    ("allowed", Json::Num(*allowed as f64)),
                    ("found", Json::Num(*found as f64)),
                ])
            })
            .collect();
        obj(vec![
            ("findings", Json::Arr(findings)),
            ("suppressed", Json::Num(self.suppressed as f64)),
            ("slack", Json::Arr(slack)),
            ("files_scanned", Json::Num(self.files_scanned as f64)),
            ("clean", Json::Bool(self.clean())),
        ])
        .to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_report_has_file_line_spans() {
        let mut r = LintReport { files_scanned: 1, ..Default::default() };
        r.findings.push(Finding::new("no-hash-iter", "src/sched/x.rs", 12, "HashMap banned"));
        let text = r.render_human();
        assert!(text.contains("src/sched/x.rs:12 [no-hash-iter] HashMap banned"));
        assert!(!r.clean());
    }

    #[test]
    fn json_report_parses_back() {
        let mut r = LintReport { files_scanned: 3, suppressed: 2, ..Default::default() };
        r.findings.push(Finding::new("total-cmp-sorts", "src/a.rs", 7, "partial_cmp in sort_by"));
        let parsed = Json::parse(&r.render_json()).expect("self-rendered JSON must parse");
        let fs = parsed.get("findings").unwrap().as_arr().unwrap();
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].get("line").unwrap().as_usize().unwrap(), 7);
        assert_eq!(parsed.get("suppressed").unwrap().as_usize().unwrap(), 2);
    }
}
