//! `gpulets lint` — the zero-dependency determinism & soundness
//! static-analysis pass (DESIGN.md §11).
//!
//! Every headline claim in this repo rests on the simulator being
//! deterministic: iteration order, float comparisons and tie-breaks
//! must be bit-stable, and `util::par`'s unsafe hand-off must stay
//! justified. The runtime equivalence batteries catch a regression
//! *after* it ships nondeterminism; this pass catches the source
//! patterns at review time, as a blocking CI gate.
//!
//! Layout: [`lexer`] splits source lines into code/comment channels,
//! [`rules`] holds the seven checks, [`allowlist`] is the count-based
//! ratchet (`rust/lint_allow.toml`), [`report`] renders human and JSON
//! output. `lint_tree` walks `<root>/src/**/*.rs` in sorted order —
//! the lint's own output is deterministic, like everything else here.

pub mod allowlist;
pub mod lexer;
pub mod report;
pub mod rules;

use std::path::{Path, PathBuf};

use crate::error::Result;

pub use allowlist::Allowlist;
pub use report::{Finding, LintReport};

/// Run the per-file rules over one source text, as if it lived at
/// `relpath` (repo-relative, forward slashes). The fixture tests feed
/// synthetic paths through this to exercise the path-scoped rules.
pub fn lint_source(relpath: &str, text: &str) -> Vec<Finding> {
    rules::check_file(relpath, &lexer::lex(text))
}

/// Walk `<root>/src/**/*.rs` (sorted), run every per-file rule plus
/// the cross-file registry check. Returns raw findings (allowlist not
/// yet applied) and the number of files scanned.
pub fn collect_tree(root: &Path) -> Result<(Vec<Finding>, usize)> {
    let src = root.join("src");
    let mut files = Vec::new();
    walk(&src, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    let mut config_lines = None;
    let mut sched_lines = None;
    for path in &files {
        let text = std::fs::read_to_string(path)?;
        let rel = relpath(root, path);
        let lines = lexer::lex(&text);
        findings.extend(rules::check_file(&rel, &lines));
        if rel == "src/config.rs" {
            config_lines = Some(lines);
        } else if rel == "src/sched/mod.rs" {
            sched_lines = Some(lines);
        }
    }
    if let (Some(cfg), Some(sched)) = (&config_lines, &sched_lines) {
        findings.extend(rules::check_registry("src/config.rs", cfg, sched));
    }
    Ok((findings, files.len()))
}

/// Full lint run: collect findings, fold through the allowlist at
/// `<root>/lint_allow.toml`. `report.clean()` decides the exit code.
pub fn lint_tree(root: &Path) -> Result<LintReport> {
    let (findings, files_scanned) = collect_tree(root)?;
    let allow = Allowlist::load(&root.join("lint_allow.toml"))?;
    let mut report = LintReport { files_scanned, ..Default::default() };
    allow.apply(findings, &mut report);
    Ok(report)
}

/// Regenerate `<root>/lint_allow.toml` to pin exactly the current
/// findings, carrying forward existing reasons (`--fix-allowlist`).
/// Returns the rendered text after writing it.
pub fn fix_allowlist(root: &Path) -> Result<String> {
    let (findings, _) = collect_tree(root)?;
    let path = root.join("lint_allow.toml");
    let prior = Allowlist::load(&path)?;
    let text = Allowlist::regenerate(&findings, &prior);
    std::fs::write(&path, &text)?;
    Ok(text)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn relpath(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .to_string_lossy()
        .replace('\\', "/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lint_source_routes_path_scoping() {
        let src = "use std::collections::HashMap;\nfn f() { x.unwrap(); }\n";
        let fs = lint_source("src/fleet/x.rs", src);
        assert!(fs.iter().any(|f| f.rule == "no-hash-iter" && f.line == 1));
        assert!(fs.iter().any(|f| f.rule == "no-unwrap-in-lib" && f.line == 2));
        assert!(lint_source("src/bin/x.rs", src).is_empty());
    }

    #[test]
    fn the_real_tree_is_clean() {
        // The same invariant CI enforces: zero unallowlisted findings
        // over this repo's own sources.
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let report = lint_tree(root).expect("lint over the real tree must run");
        assert!(
            report.clean(),
            "lint found violations:\n{}",
            report.render_human()
        );
        assert!(report.files_scanned > 40, "walked {} files", report.files_scanned);
    }
}
