//! The count-based ratchet allowlist (`rust/lint_allow.toml`, parsed
//! with `tomlmini`).
//!
//! Each `[allow.NN]` entry pins one `(rule, file)` pair to at most
//! `count` findings, with a mandatory one-line `reason`. Semantics:
//!
//! * found `<=` count — all findings for the pair are suppressed; a
//!   strict undershoot is reported as *slack* (tighten the count).
//! * found `>` count — the ratchet fires: **every** finding for the
//!   pair is reported, so a regression cannot hide under an old budget.
//! * an entry with no findings at all is reported as *stale*.
//! * an entry with a missing/empty `reason` is itself a blocking
//!   finding (`allowlist-policy`) — justifications are not optional.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::Result;
use crate::util::tomlmini::{TomlDoc, TomlValue};

use super::report::{Finding, LintReport};

/// One `[allow.NN]` entry.
#[derive(Clone, Debug)]
pub struct AllowEntry {
    /// The `NN` section key (kept for diagnostics).
    pub key: String,
    pub rule: String,
    pub file: String,
    pub count: usize,
    pub reason: String,
}

/// The parsed allowlist.
#[derive(Clone, Debug, Default)]
pub struct Allowlist {
    pub entries: Vec<AllowEntry>,
}

impl Allowlist {
    /// Parse allowlist TOML text.
    pub fn parse(text: &str) -> Result<Allowlist> {
        let doc = TomlDoc::parse(text)?;
        let mut by_key: BTreeMap<String, BTreeMap<String, TomlValue>> = BTreeMap::new();
        for (rest, v) in doc.keys_under("allow") {
            if let Some((key, field)) = rest.split_once('.') {
                by_key.entry(key.to_string()).or_default().insert(field.to_string(), v.clone());
            }
        }
        let mut entries = Vec::new();
        for (key, fields) in by_key {
            let rule = match fields.get("rule") {
                Some(v) => v.as_str()?.to_string(),
                None => {
                    return Err(crate::error::Error::parse(format!(
                        "allowlist entry [allow.{key}] has no `rule`"
                    )))
                }
            };
            let file = match fields.get("file") {
                Some(v) => v.as_str()?.to_string(),
                None => {
                    return Err(crate::error::Error::parse(format!(
                        "allowlist entry [allow.{key}] has no `file`"
                    )))
                }
            };
            let count = match fields.get("count") {
                Some(v) => v.as_i64()?.max(0) as usize,
                None => {
                    return Err(crate::error::Error::parse(format!(
                        "allowlist entry [allow.{key}] has no `count`"
                    )))
                }
            };
            let reason = match fields.get("reason") {
                Some(v) => v.as_str()?.trim().to_string(),
                None => String::new(),
            };
            entries.push(AllowEntry { key, rule, file, count, reason });
        }
        Ok(Allowlist { entries })
    }

    /// Load from `path`; a missing file is an empty allowlist.
    pub fn load(path: &Path) -> Result<Allowlist> {
        if !path.exists() {
            return Ok(Allowlist::default());
        }
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Fold raw findings through the ratchet into `report`.
    pub fn apply(&self, findings: Vec<Finding>, report: &mut LintReport) {
        let mut groups: BTreeMap<(String, String), Vec<Finding>> = BTreeMap::new();
        for f in findings {
            groups.entry((f.rule.to_string(), f.file.clone())).or_default().push(f);
        }
        // Sum budgets per (rule, file) — split entries are legal when
        // two sites in one file need different justifications.
        let mut budget: BTreeMap<(String, String), usize> = BTreeMap::new();
        for e in &self.entries {
            *budget.entry((e.rule.clone(), e.file.clone())).or_default() += e.count;
            if e.reason.is_empty() {
                report.findings.push(Finding::new(
                    "allowlist-policy",
                    "lint_allow.toml",
                    1,
                    format!(
                        "[allow.{}] ({} {}) has no `reason` — every entry needs a \
                         one-line justification",
                        e.key, e.rule, e.file
                    ),
                ));
            }
        }
        for ((rule, file), allowed) in &budget {
            match groups.get(&(rule.clone(), file.clone())).map(Vec::len) {
                None => report.stale.push((rule.clone(), file.clone())),
                Some(found) if found <= *allowed => {
                    report.suppressed += found;
                    groups.remove(&(rule.clone(), file.clone()));
                    if found < *allowed {
                        report.slack.push((rule.clone(), file.clone(), *allowed, found));
                    }
                }
                // Over budget: the whole group stays visible below.
                Some(_) => {}
            }
        }
        for (_, fs) in groups {
            report.findings.extend(fs);
        }
        report.findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    }

    /// Render a fresh allowlist pinning exactly the given findings,
    /// carrying forward reasons from `prior` where the (rule, file)
    /// pair already had one (`gpulets lint --fix-allowlist`).
    pub fn regenerate(findings: &[Finding], prior: &Allowlist) -> String {
        let mut counts: BTreeMap<(String, String), usize> = BTreeMap::new();
        for f in findings {
            *counts.entry((f.rule.to_string(), f.file.clone())).or_default() += 1;
        }
        let mut doc = TomlDoc::default();
        for (n, ((rule, file), count)) in counts.iter().enumerate() {
            let reason = prior
                .entries
                .iter()
                .find(|e| &e.rule == rule && &e.file == file && !e.reason.is_empty())
                .map_or("TODO: justify this entry", |e| e.reason.as_str());
            let key = format!("allow.{:02}", n + 1);
            doc.set(format!("{key}.rule"), TomlValue::Str(rule.clone()));
            doc.set(format!("{key}.file"), TomlValue::Str(file.clone()));
            doc.set(format!("{key}.count"), TomlValue::Int(*count as i64));
            doc.set(format!("{key}.reason"), TomlValue::Str(reason.to_string()));
        }
        let mut out = String::from(
            "# gpulets lint allowlist — a count-based ratchet.\n\
             # Every [allow.NN] entry pins (rule, file) to at most `count` findings and\n\
             # MUST carry a one-line `reason`; see DESIGN.md §11 for the policy.\n\
             # Regenerate with `cargo run --bin gpulets -- lint --fix-allowlist`.\n",
        );
        out.push_str(&doc.to_toml());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALLOW: &str = "\
[allow.01]\nrule = \"no-unwrap-in-lib\"\nfile = \"src/a.rs\"\ncount = 2\nreason = \"infallible\"\n\
[allow.02]\nrule = \"no-hash-iter\"\nfile = \"src/sched/b.rs\"\ncount = 1\nreason = \"\"\n";

    fn f(rule: &'static str, file: &str, line: usize) -> Finding {
        Finding::new(rule, file, line, "m")
    }

    #[test]
    fn suppresses_within_budget_and_ratchets_over() {
        let a = Allowlist::parse(ALLOW).unwrap();
        let mut r = LintReport::default();
        a.apply(
            vec![
                f("no-unwrap-in-lib", "src/a.rs", 3),
                f("no-unwrap-in-lib", "src/a.rs", 9),
                f("no-hash-iter", "src/sched/b.rs", 1),
                f("no-hash-iter", "src/sched/b.rs", 2),
            ],
            &mut r,
        );
        assert_eq!(r.suppressed, 2, "within-budget pair suppressed");
        // Entry 02 is over budget (found 2 > allowed 1): both visible.
        let hash: Vec<_> = r.findings.iter().filter(|x| x.rule == "no-hash-iter").collect();
        assert_eq!(hash.len(), 2, "ratchet must surface the whole group");
        // Entry 02 also has an empty reason: policy finding.
        assert!(r.findings.iter().any(|x| x.rule == "allowlist-policy"));
    }

    #[test]
    fn slack_and_stale_are_noted() {
        let a = Allowlist::parse(ALLOW).unwrap();
        let mut r = LintReport::default();
        a.apply(vec![f("no-unwrap-in-lib", "src/a.rs", 3)], &mut r);
        assert_eq!(r.slack.len(), 1);
        assert_eq!(r.slack[0].2, 2);
        assert_eq!(r.slack[0].3, 1);
        assert_eq!(r.stale.len(), 1, "entry 02 matched nothing");
    }

    #[test]
    fn regenerate_round_trips_and_keeps_reasons() {
        let prior = Allowlist::parse(ALLOW).unwrap();
        let findings =
            vec![f("no-unwrap-in-lib", "src/a.rs", 3), f("no-unwrap-in-lib", "src/a.rs", 5)];
        let text = Allowlist::regenerate(&findings, &prior);
        let back = Allowlist::parse(&text).unwrap();
        assert_eq!(back.entries.len(), 1);
        assert_eq!(back.entries[0].count, 2);
        assert_eq!(back.entries[0].reason, "infallible", "reason carried forward");
        let mut r = LintReport::default();
        back.apply(findings, &mut r);
        assert!(r.clean(), "regenerated allowlist must suppress exactly the findings");
    }

    #[test]
    fn missing_fields_are_parse_errors_and_missing_file_is_empty() {
        assert!(Allowlist::parse("[allow.01]\nrule = \"x\"\n").is_err());
        let a = Allowlist::load(Path::new("/nonexistent/lint_allow.toml")).unwrap();
        assert!(a.entries.is_empty());
    }
}
