//! The seven lint rules. Each is a line/region pass over the lexed
//! code/comment channels of one file, except `registry-enrollment`,
//! which is a cross-file structural check over `config.rs` and
//! `sched/mod.rs`. DESIGN.md §11 catalogs what each rule pins and why.

use super::lexer::{has_word, Line};
use super::report::Finding;

/// Rule ids, in report order.
pub const RULES: &[&str] = &[
    "no-hash-iter",
    "total-cmp-sorts",
    "safety-comment",
    "no-unwrap-in-lib",
    "no-alloc-region",
    "no-wall-clock",
    "registry-enrollment",
];

/// Directories where hashed collections are banned outright: anything
/// whose iteration order feeds a scheduling decision, a merge, or a
/// report.
const HASH_SCOPED_DIRS: &[&str] = &[
    "src/sched/",
    "src/coordinator/",
    "src/fleet/",
    "src/metrics/",
    "src/simclock/",
    "src/workload/",
];

/// Calls inside a `// lint: no-alloc` region that allocate. Matched on
/// blanked code with a left identifier boundary, so `.clone_from(`
/// (which reuses the destination's buffers) does not trip `.clone()`.
const ALLOC_PATTERNS: &[&str] = &[
    "Vec::new",
    "VecDeque::new",
    "String::new",
    "BTreeMap::new",
    "BTreeSet::new",
    "HashMap::new",
    "HashSet::new",
    "Box::new",
    "vec!",
    "format!",
    ".to_vec()",
    ".to_string()",
    ".to_owned()",
    ".collect()",
    ".collect::<",
    "with_capacity(",
    ".clone()",
];

/// Run every per-file rule over one lexed file. `relpath` is
/// repo-relative with forward slashes (`src/sched/sbp.rs`); path
/// scoping keys off it.
pub fn check_file(relpath: &str, lines: &[Line]) -> Vec<Finding> {
    let mut out = Vec::new();
    no_hash_iter(relpath, lines, &mut out);
    total_cmp_sorts(relpath, lines, &mut out);
    safety_comment(relpath, lines, &mut out);
    no_unwrap_in_lib(relpath, lines, &mut out);
    no_alloc_region(relpath, lines, &mut out);
    no_wall_clock(relpath, lines, &mut out);
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// Rule 1 — `no-hash-iter`: `HashMap`/`HashSet` are banned in the
/// deterministic core (scheduling, serving, fleet, metrics, clock,
/// workload). Their iteration order is randomized per process, which is
/// exactly the nondeterminism the byte-equality batteries exist to
/// catch — use `BTreeMap`/`BTreeSet` or an indexed arena.
fn no_hash_iter(relpath: &str, lines: &[Line], out: &mut Vec<Finding>) {
    if !HASH_SCOPED_DIRS.iter().any(|d| relpath.starts_with(d)) {
        return;
    }
    for (i, line) in lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for ty in ["HashMap", "HashSet"] {
            if has_word(&line.code, ty) {
                out.push(Finding::new(
                    "no-hash-iter",
                    relpath,
                    i + 1,
                    format!("{ty} in a determinism-scoped dir; use BTreeMap/BTreeSet"),
                ));
            }
        }
    }
}

/// Rule 2 — `total-cmp-sorts`: float comparators passed to
/// `sort_by`/`sort_unstable_by`/`min_by`/`max_by` must use `total_cmp`.
/// `partial_cmp(..).unwrap()` panics on NaN and `unwrap_or` variants
/// silently reorder — either way the tie-break is not total (PR 2's
/// fix, now enforced).
fn total_cmp_sorts(relpath: &str, lines: &[Line], out: &mut Vec<Finding>) {
    const CALLS: &[&str] = &[".sort_by(", ".sort_unstable_by(", ".min_by(", ".max_by("];
    for (i, line) in lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for call in CALLS {
            for pos in find_all(&line.code, call) {
                let window = paren_window(lines, i, pos + call.len() - 1);
                if window.contains("partial_cmp") {
                    out.push(Finding::new(
                        "total-cmp-sorts",
                        relpath,
                        i + 1,
                        format!("partial_cmp inside {}..); use total_cmp", &call[1..call.len() - 1]),
                    ));
                }
            }
        }
    }
}

/// Rule 3 — `safety-comment`: every `unsafe` occurrence needs a
/// `// SAFETY:` comment on the same line or on the comment block
/// directly above it, stating the invariant that makes it sound (the
/// `util::par` SlicePtr hand-off is the motivating site).
fn safety_comment(relpath: &str, lines: &[Line], out: &mut Vec<Finding>) {
    for (i, line) in lines.iter().enumerate() {
        if line.in_test || !has_word(&line.code, "unsafe") {
            continue;
        }
        let mut ok = line.comment.contains("SAFETY:");
        let mut j = i;
        while !ok && j > 0 && lines[j - 1].code.trim().is_empty() {
            j -= 1;
            ok = lines[j].comment.contains("SAFETY:");
        }
        if !ok {
            out.push(Finding::new(
                "safety-comment",
                relpath,
                i + 1,
                "unsafe without an adjacent `// SAFETY:` comment",
            ));
        }
    }
}

/// Rule 4 — `no-unwrap-in-lib`: `unwrap()` / `expect(` / `panic!` are
/// banned in library code (everything under `src/` except `src/bin/`).
/// Reachable failures must travel the `Error` path; structurally
/// infallible sites get pinned in `lint_allow.toml` with a reason.
///
/// Known limitation: `.expect(` on a `self` receiver is skipped — that
/// shape is a user-defined method (`util::json`'s `Parser::expect`),
/// not `Option::expect`.
fn no_unwrap_in_lib(relpath: &str, lines: &[Line], out: &mut Vec<Finding>) {
    if !relpath.starts_with("src/") || relpath.starts_with("src/bin/") {
        return;
    }
    for (i, line) in lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let code = &line.code;
        for pat in [".unwrap()", ".expect(", "panic!"] {
            for pos in find_all(code, pat) {
                if pat == ".expect(" && self_receiver(&code[..pos]) {
                    continue;
                }
                if pat == "panic!" && pos > 0 && is_ident_left(code.as_bytes()[pos - 1]) {
                    continue;
                }
                out.push(Finding::new(
                    "no-unwrap-in-lib",
                    relpath,
                    i + 1,
                    format!("`{pat}` in library code; return Error or allowlist with a reason"),
                ));
            }
        }
    }
}

/// Rule 5 — `no-alloc-region`: inside `// lint: no-alloc` …
/// `// lint: end-no-alloc` regions (the PR 7 steady-state hot loops),
/// flag calls that allocate. The regions are the engine's
/// allocation-free contract made mechanical — `cargo bench` catches the
/// throughput regression, this catches the cause at review time.
fn no_alloc_region(relpath: &str, lines: &[Line], out: &mut Vec<Finding>) {
    let mut open: Option<usize> = None;
    for (i, line) in lines.iter().enumerate() {
        // A marker is a comment *starting* with the directive (after
        // the `//`s) — prose that merely mentions the markers, like
        // this module's own docs, is not a region boundary.
        let directive = line.comment.trim_start_matches(['/', '*', ' ']);
        if directive.starts_with("lint: end-no-alloc") {
            if open.is_none() {
                out.push(Finding::new(
                    "no-alloc-region",
                    relpath,
                    i + 1,
                    "`lint: end-no-alloc` without a matching `lint: no-alloc`",
                ));
            }
            open = None;
            continue;
        }
        if directive.starts_with("lint: no-alloc") {
            if open.is_some() {
                out.push(Finding::new(
                    "no-alloc-region",
                    relpath,
                    i + 1,
                    "nested `lint: no-alloc` region",
                ));
            }
            open = Some(i + 1);
            continue;
        }
        if open.is_none() || line.in_test {
            continue;
        }
        for pat in ALLOC_PATTERNS {
            for pos in find_all(&line.code, pat) {
                if pos > 0 && pat.starts_with(|c: char| c.is_ascii_alphabetic())
                    && is_ident_left(line.code.as_bytes()[pos - 1])
                {
                    continue;
                }
                out.push(Finding::new(
                    "no-alloc-region",
                    relpath,
                    i + 1,
                    format!("allocating call `{pat}` inside a no-alloc region"),
                ));
            }
        }
    }
    if let Some(start) = open {
        out.push(Finding::new(
            "no-alloc-region",
            relpath,
            start,
            "unclosed `lint: no-alloc` region (missing `lint: end-no-alloc`)",
        ));
    }
}

/// Rule 6 — `no-wall-clock`: `std::time::Instant` / `SystemTime` are
/// banned in library code. Everything on the serving path is stamped
/// with integer-µs *sim* time (`simclock`) — a wall-clock read is
/// either a determinism leak (results that vary run to run) or a
/// measurement that belongs in a bench harness. The bench/CLI timing
/// sites that legitimately read the wall clock are pinned in
/// `lint_allow.toml`; `src/util/par.rs` (the worker pool) is exempt by
/// scope, like the `benches/` tree.
fn no_wall_clock(relpath: &str, lines: &[Line], out: &mut Vec<Finding>) {
    if !relpath.starts_with("src/") || relpath == "src/util/par.rs" {
        return;
    }
    for (i, line) in lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for ty in ["Instant", "SystemTime"] {
            if has_word(&line.code, ty) {
                out.push(Finding::new(
                    "no-wall-clock",
                    relpath,
                    i + 1,
                    format!(
                        "{ty} in library code; use simclock sim time, or allowlist a \
                         bench-timing site with a reason"
                    ),
                ));
            }
        }
    }
}

/// Rule 7 — `registry-enrollment`: every `Algo` enum variant must have
/// a `Algo::V => Box::new(CTOR)` arm in `config.rs`, and that exact
/// constructor (whitespace-normalized) must appear in
/// `sched::registry()`. This closes the PR 6 auto-enrollment loop
/// mechanically: a scheduler reachable from `--algo` that is absent
/// from the registry would silently skip the whole conformance battery.
pub fn check_registry(
    config_rel: &str,
    config_lines: &[Line],
    sched_lines: &[Line],
) -> Vec<Finding> {
    let mut out = Vec::new();
    let variants = enum_variants(config_lines, "Algo");
    if variants.is_empty() {
        out.push(Finding::new(
            "registry-enrollment",
            config_rel,
            1,
            "could not find `enum Algo` variants to check",
        ));
        return out;
    }
    let config_code = normalized_code(config_lines);
    let sched_code = normalized_code(sched_lines);
    for (variant, lineno) in variants {
        let arm_key = format!("Algo::{variant}=>Box::new(");
        let Some(pos) = config_code.find(&arm_key) else {
            out.push(Finding::new(
                "registry-enrollment",
                config_rel,
                lineno,
                format!("Algo::{variant} has no `Algo::{variant} => Box::new(..)` arm in scheduler()"),
            ));
            continue;
        };
        let Some(ctor) = balanced(&config_code[pos + arm_key.len()..]) else {
            out.push(Finding::new(
                "registry-enrollment",
                config_rel,
                lineno,
                format!("unbalanced constructor expression for Algo::{variant}"),
            ));
            continue;
        };
        let enrolled = format!("Box::new({ctor})");
        if !sched_code.contains(&enrolled) {
            out.push(Finding::new(
                "registry-enrollment",
                config_rel,
                lineno,
                format!("constructor `{ctor}` for Algo::{variant} is not enrolled in sched::registry()"),
            ));
        }
    }
    out
}

/// Variant idents (with 1-based line numbers) of `enum <name>` —
/// non-test code lines between the enum's braces whose first token is a
/// capitalized ident.
fn enum_variants(lines: &[Line], name: &str) -> Vec<(String, usize)> {
    let header = format!("enum {name}");
    let mut out = Vec::new();
    let mut depth: Option<i64> = None;
    let mut level: i64 = 0;
    for (i, line) in lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let starting = depth.is_none() && line.code.contains(&header);
        for ch in line.code.chars() {
            match ch {
                '{' => {
                    level += 1;
                    if starting && depth.is_none() {
                        depth = Some(level);
                    }
                }
                '}' => {
                    if depth == Some(level) {
                        return out;
                    }
                    level -= 1;
                }
                _ => {}
            }
        }
        if depth.is_some() && !starting {
            let t = line.code.trim().trim_end_matches(',');
            let ident: String =
                t.chars().take_while(|c| c.is_ascii_alphanumeric() || *c == '_').collect();
            if ident.chars().next().is_some_and(|c| c.is_ascii_uppercase()) {
                out.push((ident, i + 1));
            }
        }
    }
    out
}

/// All non-test code, joined and stripped of whitespace — the
/// normalization both sides of the registry comparison share.
fn normalized_code(lines: &[Line]) -> String {
    lines
        .iter()
        .filter(|l| !l.in_test)
        .flat_map(|l| l.code.chars())
        .filter(|c| !c.is_whitespace())
        .collect()
}

/// The prefix of `s` up to the `)` balancing an already-open paren.
fn balanced(s: &str) -> Option<&str> {
    let mut depth = 1i64;
    for (i, c) in s.char_indices() {
        match c {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    return Some(&s[..i]);
                }
            }
            _ => {}
        }
    }
    None
}

/// The argument window of a call: characters from the `(` at
/// `(li, col)` through its balancing `)`, spanning up to 40 lines.
fn paren_window(lines: &[Line], li: usize, col: usize) -> String {
    let mut out = String::new();
    let mut depth = 0i64;
    for (k, line) in lines.iter().enumerate().skip(li).take(40) {
        let start = if k == li { col } else { 0 };
        for c in line.code[start.min(line.code.len())..].chars() {
            out.push(c);
            match c {
                '(' => depth += 1,
                ')' => {
                    depth -= 1;
                    if depth == 0 {
                        return out;
                    }
                }
                _ => {}
            }
        }
        out.push(' ');
    }
    out
}

/// Byte offsets of every occurrence of `pat` in `s`.
fn find_all(s: &str, pat: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = s[from..].find(pat) {
        out.push(from + p);
        from += p + 1;
    }
    out
}

fn is_ident_left(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// True when the text before a `.expect(` occurrence ends with the
/// whole word `self` (so `myself.expect(` still counts as a finding).
fn self_receiver(before: &str) -> bool {
    before.strip_suffix("self").is_some_and(|rest| {
        rest.bytes().next_back().is_none_or(|b| !is_ident_left(b))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn findings(rel: &str, src: &str) -> Vec<Finding> {
        check_file(rel, &lex(src))
    }

    #[test]
    fn hash_iter_scoped_to_deterministic_dirs() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(findings("src/sched/x.rs", src).len(), 1);
        assert!(findings("src/gpu/x.rs", src).is_empty(), "out-of-scope dir");
        let test_src = "#[cfg(test)]\nmod t {\n use std::collections::HashMap;\n}\n";
        assert!(findings("src/sched/x.rs", test_src).is_empty(), "tests exempt");
    }

    #[test]
    fn total_cmp_window_spans_lines() {
        let src = "fn f(v: &mut Vec<f64>) {\n    v.sort_by(|a, b| {\n        a.partial_cmp(b).unwrap()\n    });\n}\n";
        let fs = findings("src/x.rs", src);
        assert!(fs.iter().any(|f| f.rule == "total-cmp-sorts" && f.line == 2));
        let good = "fn f(v: &mut Vec<f64>) { v.sort_by(|a, b| a.total_cmp(b)); }\n";
        assert!(findings("src/x.rs", good).iter().all(|f| f.rule != "total-cmp-sorts"));
    }

    #[test]
    fn safety_comment_looks_up_through_comment_block() {
        let good = "// SAFETY: index handed out exactly once.\n// (second comment line)\nunsafe impl Sync for X {}\n";
        assert!(findings("src/util/x.rs", good).iter().all(|f| f.rule != "safety-comment"));
        let bad = "fn f() {\n    unsafe { work() };\n}\n";
        let fs = findings("src/util/x.rs", bad);
        assert!(fs.iter().any(|f| f.rule == "safety-comment" && f.line == 2));
    }

    #[test]
    fn unwrap_rule_skips_bins_tests_and_self_expect() {
        let src = "fn f() { x.unwrap(); }\n";
        assert_eq!(findings("src/sched/x.rs", src).len(), 1);
        assert!(findings("src/bin/x.rs", src).is_empty());
        assert!(findings("tests/x.rs", src).is_empty());
        let method = "fn f(&mut self) { self.expect(b) }\n";
        assert!(findings("src/util/x.rs", method).is_empty());
        let strings = "fn f() { log(\"don't panic!\"); } // unwrap() in comment\n";
        assert!(findings("src/util/x.rs", strings).is_empty());
    }

    #[test]
    fn no_alloc_region_flags_allocs_not_clone_from() {
        let src = "fn f(dst: &mut Vec<u8>, src: &Vec<u8>) {\n    // lint: no-alloc\n    dst.clone_from(src);\n    let v = src.clone();\n    // lint: end-no-alloc\n    let w = src.clone();\n}\n";
        let fs = findings("src/x.rs", src);
        let alloc: Vec<_> = fs.iter().filter(|f| f.rule == "no-alloc-region").collect();
        assert_eq!(alloc.len(), 1, "{alloc:?}");
        assert_eq!(alloc[0].line, 4);
    }

    #[test]
    fn unclosed_region_is_a_finding() {
        let fs = findings("src/x.rs", "// lint: no-alloc\nfn f() {}\n");
        assert!(fs.iter().any(|f| f.rule == "no-alloc-region" && f.line == 1));
    }

    #[test]
    fn wall_clock_scoped_and_word_bounded() {
        let src = "use std::time::Instant;\nfn f() { let t = Instant::now(); }\n";
        assert_eq!(
            findings("src/sched/x.rs", src)
                .iter()
                .filter(|f| f.rule == "no-wall-clock")
                .count(),
            2
        );
        // Exempt scopes: the worker pool, benches, tests.
        assert!(findings("src/util/par.rs", src).iter().all(|f| f.rule != "no-wall-clock"));
        assert!(findings("benches/x.rs", src).is_empty());
        let test_src = "#[cfg(test)]\nmod t {\n use std::time::Instant;\n}\n";
        assert!(findings("src/x.rs", test_src).is_empty());
        // Word boundary: prose-ish identifiers and comments don't trip.
        let near = "fn f() { let x = Instantiate::new(); } // Instant in comment\n";
        assert!(findings("src/x.rs", near).iter().all(|f| f.rule != "no-wall-clock"));
        let sys = "fn f() { let t = std::time::SystemTime::now(); }\n";
        assert!(findings("src/x.rs", sys).iter().any(|f| f.rule == "no-wall-clock"));
    }

    #[test]
    fn registry_rule_matches_ctor_text() {
        let config = "pub enum Algo {\n    Good,\n    Missing,\n}\nimpl Algo {\n    pub fn scheduler(self) -> B {\n        match self {\n            Algo::Good => Box::new(GoodSched::new()),\n            Algo::Missing => Box::new(MissingSched::make()),\n        }\n    }\n}\n";
        let sched = "pub fn registry() -> V {\n    vec![Box::new(GoodSched::new())]\n}\n";
        let fs = check_registry("src/config.rs", &lex(config), &lex(sched));
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].line, 3, "span must point at the variant");
        assert!(fs[0].message.contains("MissingSched::make()"));
    }
}
